"""Sanity checks on the L1 roofline model (DESIGN.md §8)."""

from compile.roofline import VMEM_BYTES, corr_estimate, report


def test_default_tiling_fits_vmem():
    for m, n in [(128, 64), (512, 256), (2048, 512), (16384, 96)]:
        e = corr_estimate(m, n, 128, min(64, n))
        assert e.fits_vmem(), f"{m}x{n}: {e.vmem_double_buffered} > {VMEM_BYTES}"


def test_corr_is_bandwidth_bound():
    # Aᵀr has O(1) arithmetic intensity — must be HBM-bound everywhere.
    for m, n in [(512, 256), (16384, 96)]:
        e = corr_estimate(m, n, 128, 64)
        assert e.bound == "HBM"
        assert e.intensity < 2.0


def test_roofline_monotone_in_problem_size():
    small = corr_estimate(512, 256, 128, 64)
    big = corr_estimate(16384, 96, 128, 32)
    assert big.t_roofline_us > small.t_roofline_us


def test_report_renders():
    s = report()
    assert "corr kernel roofline" in s
    assert "16384x96" in s
    assert "HBM" in s


def test_huge_tile_violates_vmem():
    # A 16384x512 f32 tile is 32 MiB — double-buffered it blows the
    # 16 MiB budget (why the CPU artifacts' giant tiles are a schedule
    # choice for interpret mode, not a TPU tiling).
    e = corr_estimate(16384, 512, 16384, 512)
    assert not e.fits_vmem()
