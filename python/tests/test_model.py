"""L2 correctness: the composed model graphs preserve LARS semantics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import corr_ref, gamma_ref

jax.config.update("jax_enable_x64", False)


def _problem(seed, m=128, n=64, k=3):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, n)).astype(np.float32)
    a /= np.linalg.norm(a, axis=0, keepdims=True)
    support = rng.choice(n, size=k, replace=False)
    x = np.zeros(n, np.float32)
    x[support] = rng.normal(size=k).astype(np.float32) + np.sign(
        rng.normal(size=k)
    ).astype(np.float32)
    b = a @ x
    return jnp.asarray(a), jnp.asarray(b), np.sort(support)


def test_corr_model_returns_tuple():
    a, b, _ = _problem(0)
    (c,) = model.corr_model(a, b)
    np.testing.assert_allclose(c, corr_ref(a, b), rtol=2e-5, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_gstep_model_composition(seed):
    a, b, _ = _problem(seed)
    m, n = a.shape
    rng = np.random.default_rng(seed + 1)
    u = jnp.asarray(rng.normal(size=(m,)).astype(np.float32))
    u = u / jnp.linalg.norm(u)
    c = corr_ref(a, b)
    mask = jnp.zeros((n,), jnp.float32).at[:2].set(1.0)
    ck = jnp.float32(float(jnp.max(jnp.abs(c))))
    h = jnp.float32(0.9)
    av, gammas = model.gstep_model(a, u, c, mask, ck, h)
    np.testing.assert_allclose(av, corr_ref(a, u), rtol=2e-5, atol=1e-4)
    want = gamma_ref(c, corr_ref(a, u), mask, ck, h)
    got, want = np.asarray(gammas), np.asarray(want)
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-5)


def test_first_lars_step_equalizes_correlations():
    """After stepping by the min finite γ from gstep_model, the entering
    column's |corr| equals the selected column's |corr| — eq. (5)."""
    a, b, _ = _problem(42)
    m, n = a.shape
    c0 = corr_ref(a, b)
    j0 = int(jnp.argmax(jnp.abs(c0)))
    # Initial direction: the single selected column, signed.
    sgn = jnp.sign(c0[j0])
    u = a[:, j0] * sgn  # unit norm since columns are normalized
    ck = jnp.abs(c0[j0])
    h = jnp.float32(1.0)  # (s^T G^{-1} s)^{-1/2} = 1/ck for a single col
    # For one selected column: h = 1/ck, direction u as above.
    h = 1.0 / ck
    mask = jnp.zeros((n,), jnp.float32).at[j0].set(1.0)
    av, gammas = model.gstep_model(a, u, c0, mask, ck, jnp.float32(h))
    g = np.asarray(gammas)
    jstar = int(np.argmin(g))
    gamma = float(g[jstar])
    y1 = gamma * u
    c1 = corr_ref(a, b - y1)
    np.testing.assert_allclose(
        abs(float(c1[jstar])), abs(float(c1[j0])), rtol=5e-3, atol=5e-4
    )
    # And no other column exceeds the new max (LARS invariant).
    cmax = abs(float(c1[j0]))
    assert float(jnp.max(jnp.abs(c1))) <= cmax * (1.0 + 5e-3)


def test_shapes_for_covers_both_ops():
    shapes = model.shapes_for(128, 64)
    assert set(shapes) == {"corr", "gstep"}
    assert shapes["corr"][0].shape == (128, 64)
    assert shapes["gstep"][2].shape == (64,)
