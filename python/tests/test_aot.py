"""AOT bridge: lowered HLO text is well-formed and numerically faithful.

Executes the lowered XlaComputation back through the local CPU client —
the same artifact bytes the Rust runtime consumes.
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import aot, model


def _lowered_corr(m=128, n=64):
    shapes = model.shapes_for(m, n)
    return jax.jit(model.corr_model).lower(*shapes["corr"])


def test_hlo_text_well_formed():
    text = aot.to_hlo_text(_lowered_corr())
    assert "ENTRY" in text
    assert "f32[128,64]" in text.replace(" ", "")


def test_lowered_module_numerically_faithful():
    """The exact lowered module (same bytes the artifact holds) computes
    Aᵀr: execute the AOT-compiled executable and compare to numpy. The
    text-parse half of the roundtrip is covered by the Rust integration
    test (tests/runtime_parity.rs), which loads the artifact files."""
    m, n = 128, 64
    lowered = _lowered_corr(m, n)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and len(text) > 100
    exe = lowered.compile()
    rng = np.random.default_rng(0)
    a = rng.normal(size=(m, n)).astype(np.float32)
    r = rng.normal(size=(m,)).astype(np.float32)
    (got,) = exe(jnp.asarray(a), jnp.asarray(r))
    np.testing.assert_allclose(np.asarray(got), a.T @ r, rtol=2e-5, atol=1e-4)


def test_bucket_lowering_all(tmp_path=None):
    # Lower the smallest bucket end to end (others are shape-identical).
    m, n, tiles = aot.BUCKETS[0]
    texts = aot.lower_bucket(m, n, tiles)
    assert set(texts) == {"corr", "gstep"}
    for text in texts.values():
        assert "ENTRY" in text


def test_manifest_written():
    with tempfile.TemporaryDirectory() as d:
        import sys
        import unittest.mock as mock

        argv = ["aot", "--out-dir", d]
        with mock.patch.object(sys, "argv", argv):
            aot.main()
        assert os.path.exists(os.path.join(d, "manifest.tsv"))
        assert os.path.exists(os.path.join(d, "manifest.json"))
        lines = [
            l
            for l in open(os.path.join(d, "manifest.tsv")).read().splitlines()
            if l and not l.startswith("#")
        ]
        assert len(lines) == 2 * len(aot.BUCKETS)
        for line in lines:
            op, m, n, fname = line.split("\t")
            assert op in ("corr", "gstep")
            assert os.path.exists(os.path.join(d, fname))
