"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and value regimes; fixed seeds keep the suite
deterministic. Tolerances are f32-scale (the kernels are f32; the Rust
native path is f64 — parity between those is asserted on the Rust side).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import corr, corr_tiles, gamma_candidates, gram_block
from compile.kernels.ref import corr_ref, gamma_ref, gram_ref

jax.config.update("jax_enable_x64", False)


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------- corr


@settings(max_examples=12, deadline=None)
@given(
    mt=st.integers(min_value=1, max_value=4),
    nt=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_corr_matches_ref_over_shapes(mt, nt, seed):
    m, n = 128 * mt, 64 * nt
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, n))
    r = _rand(rng, (m,))
    got = corr(a, r)
    want = corr_ref(a, r)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4 * np.sqrt(m))


@pytest.mark.parametrize("tn", [32, 64])
def test_corr_alternate_tiles(tn):
    rng = np.random.default_rng(7)
    m, n = 256, 96 if tn == 32 else 128
    a = _rand(rng, (m, n))
    r = _rand(rng, (m,))
    np.testing.assert_allclose(corr(a, r, tn=tn), corr_ref(a, r), rtol=2e-5, atol=1e-3)


def test_corr_zero_residual_gives_zero():
    a = jnp.ones((128, 64), jnp.float32)
    r = jnp.zeros((128,), jnp.float32)
    assert float(jnp.max(jnp.abs(corr(a, r)))) == 0.0


def test_corr_rejects_untileable_shapes():
    with pytest.raises(ValueError):
        corr_tiles(100, 64)
    with pytest.raises(ValueError):
        corr_tiles(128, 65)


def test_corr_grid_shape():
    assert corr_tiles(256, 128) == (2, 2)
    assert corr_tiles(128, 64) == (1, 1)


# --------------------------------------------------------------- gamma


@settings(max_examples=12, deadline=None)
@given(
    nt=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31),
    ck=st.floats(min_value=0.05, max_value=3.0),
    h=st.floats(min_value=0.05, max_value=3.0),
)
def test_gamma_matches_ref(nt, seed, ck, h):
    n = 64 * nt
    rng = np.random.default_rng(seed)
    c = _rand(rng, (n,))
    a = _rand(rng, (n,))
    mask = (rng.random(n) < 0.2).astype(np.float32)
    ckj = jnp.float32(ck)
    hj = jnp.float32(h)
    got = gamma_candidates(c, a, jnp.asarray(mask), ckj, hj)
    want = gamma_ref(c, a, jnp.asarray(mask), ckj, hj)
    got, want = np.asarray(got), np.asarray(want)
    assert (np.isfinite(got) == np.isfinite(want)).all()
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=3e-5, atol=1e-5)


def test_gamma_masked_columns_are_inf():
    n = 64
    c = jnp.full((n,), 0.5, jnp.float32)
    a = jnp.full((n,), 0.1, jnp.float32)
    mask = jnp.ones((n,), jnp.float32)
    g = gamma_candidates(c, a, mask, jnp.float32(1.0), jnp.float32(1.0))
    assert bool(jnp.all(jnp.isinf(g)))


def test_gamma_candidates_positive_and_capped():
    rng = np.random.default_rng(3)
    n = 128
    c = _rand(rng, (n,))
    a = _rand(rng, (n,))
    mask = jnp.zeros((n,), jnp.float32)
    h = jnp.float32(0.8)
    g = np.asarray(gamma_candidates(c, a, mask, jnp.float32(1.2), h))
    fin = np.isfinite(g)
    assert (g[fin] > 0).all()
    assert (g[fin] <= (1.0 / 0.8) * (1.0 + 1e-5)).all()


def test_gamma_solves_equation():
    # For finite candidates, ck(1-gh) == |c_j - g a_j|.
    rng = np.random.default_rng(4)
    n = 64
    c = _rand(rng, (n,), scale=0.5)
    a = _rand(rng, (n,))
    ck, h = jnp.float32(1.0), jnp.float32(1.0)
    g = np.asarray(gamma_candidates(c, a, jnp.zeros((n,), jnp.float32), ck, h))
    c, a = np.asarray(c), np.asarray(a)
    fin = np.isfinite(g)
    lhs = 1.0 * (1.0 - g[fin] * 1.0)
    rhs = np.abs(c[fin] - g[fin] * a[fin])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------- gram


@settings(max_examples=10, deadline=None)
@given(
    mt=st.integers(min_value=1, max_value=4),
    k=st.integers(min_value=1, max_value=12),
    b=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_gram_matches_ref(mt, k, b, seed):
    m = 128 * mt
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k))
    y = _rand(rng, (m, b))
    np.testing.assert_allclose(
        gram_block(x, y), gram_ref(x, y), rtol=2e-5, atol=2e-4 * np.sqrt(m)
    )


def test_gram_symmetric_when_same_input():
    rng = np.random.default_rng(5)
    x = _rand(rng, (256, 6))
    g = np.asarray(gram_block(x, x))
    np.testing.assert_allclose(g, g.T, rtol=1e-6, atol=1e-6)
    assert (np.diag(g) > 0).all()


def test_gram_rejects_mismatched_rows():
    x = jnp.zeros((128, 2), jnp.float32)
    y = jnp.zeros((256, 2), jnp.float32)
    with pytest.raises(ValueError):
        gram_block(x, y)
