"""Fused update kernel vs plain jnp (Algorithm 2 steps 17-19)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.update import update_correlations, update_response


@settings(max_examples=10, deadline=None)
@given(
    mt=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
    gamma=st.floats(min_value=0.0, max_value=2.0),
)
def test_update_response_matches_jnp(mt, seed, gamma):
    m = 256 * mt
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.normal(size=m).astype(np.float32))
    u = jnp.asarray(rng.normal(size=m).astype(np.float32))
    b = jnp.asarray(rng.normal(size=m).astype(np.float32))
    g = jnp.float32(gamma)
    ynew, rnew = update_response(y, u, b, g)
    np.testing.assert_allclose(ynew, y + g * u, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(rnew, b - (y + g * u), rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    nt=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_update_correlations_masked_branches(nt, seed):
    n = 256 * nt
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=n).astype(np.float32))
    a = jnp.asarray(rng.normal(size=n).astype(np.float32))
    mask = jnp.asarray((rng.random(n) < 0.3).astype(np.float32))
    gamma = jnp.float32(0.7)
    shrink = jnp.float32(0.4)
    got = update_correlations(c, a, mask, gamma, shrink)
    want = jnp.where(mask > 0.5, c * shrink, c - gamma * a)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_zero_gamma_is_identity():
    m = 256
    y = jnp.arange(m, dtype=jnp.float32)
    u = jnp.ones((m,), jnp.float32)
    b = jnp.full((m,), 5.0, jnp.float32)
    ynew, rnew = update_response(y, u, b, jnp.float32(0.0))
    np.testing.assert_allclose(ynew, y)
    np.testing.assert_allclose(rnew, b - y)
