"""Pure-jnp oracles for every Pallas kernel (the build-time correctness
contract: pytest + hypothesis assert kernel ≡ oracle over shapes/dtypes).
"""

import jax
import jax.numpy as jnp


def corr_ref(a: jax.Array, r: jax.Array) -> jax.Array:
    """``c = Aᵀ r``."""
    return a.T @ r


def gram_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """``G = Xᵀ Y``."""
    return x.T @ y


def gamma_ref(
    c: jax.Array, a: jax.Array, mask: jax.Array, ck: jax.Array, h: jax.Array
) -> jax.Array:
    """min⁺ of the two γ roots, +inf where masked/invalid/over 1/h."""
    big = jnp.asarray(jnp.inf, dtype=c.dtype)
    g1 = (ck - c) / (ck * h - a)
    g2 = (ck + c) / (ck * h + a)

    def pos(x):
        return jnp.where(jnp.isfinite(x) & (x > 0.0), x, big)

    g = jnp.minimum(pos(g1), pos(g2))
    g = jnp.where(g <= (1.0 / h) * (1.0 + 1e-6), g, big)
    return jnp.where(mask > 0.5, big, g)


def lars_iteration_ref(a, b, selected, y):
    """One full LARS iteration in jnp (dense, selected as index array):
    returns (gamma, chosen column, new y). Used by model tests to check
    the composed L2 graph preserves algorithm semantics."""
    m, n = a.shape
    r = b - y
    c = a.T @ r
    asel = a[:, selected]
    g = asel.T @ asel
    s = c[selected]
    q = jnp.linalg.solve(g, s)
    h = 1.0 / jnp.sqrt(s @ q)
    u = asel @ (q * h)
    av = a.T @ u
    ck = jnp.min(jnp.abs(s))
    mask = jnp.zeros((n,), a.dtype).at[selected].set(1.0)
    gammas = gamma_ref(c, av, mask, ck, h)
    j = jnp.argmin(gammas)
    gamma = gammas[j]
    return gamma, j, y + gamma * u
