"""Correlation kernel ``c = Aᵀ r`` — the paper's arithmetic hot spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper
distributes rows of A over MPI ranks and tree-reduces partial Aᵀr
products. On a TPU-shaped target the same blocking becomes a BlockSpec
grid: A is tiled (TM × TN) into VMEM, each grid step accumulates a
partial ``A_tileᵀ · r_tile`` into the output tile — the HBM↔VMEM
schedule plays the role of the row partition, and the MXU executes the
tile product. Grid order puts the reduction dimension (row tiles)
innermost so the output tile stays resident across the accumulation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. TM×TN f32 = 128·64·4 B = 32 KiB per A-tile; with
# the r tile (512 B) and the TN-float accumulator this fits comfortably
# in a 16 MiB VMEM budget with room for double buffering.
TM = 128
TN = 64


def _corr_kernel(a_ref, r_ref, o_ref):
    """One grid step: o[jn] += A[im, jn]ᵀ · r[im]."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (TM, TN)ᵀ · (TM,) → (TN,): an MXU-shaped contraction on real TPUs.
    o_ref[...] += a_ref[...].T @ r_ref[...]


def corr_tiles(m: int, n: int, tm: int = TM, tn: int = TN) -> tuple[int, int]:
    """Grid shape for an (m, n) problem; shapes must tile evenly."""
    if m % tm or n % tn:
        raise ValueError(f"shape ({m}, {n}) not divisible by tiles ({tm}, {tn})")
    return (n // tn, m // tm)


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def corr(a: jax.Array, r: jax.Array, *, tm: int = TM, tn: int = TN) -> jax.Array:
    """``c = Aᵀ r`` via the tiled Pallas kernel (interpret mode)."""
    m, n = a.shape
    grid = corr_tiles(m, n, tm, tn)
    return pl.pallas_call(
        _corr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tn), lambda jn, im: (im, jn)),
            pl.BlockSpec((tm,), lambda jn, im: (im,)),
        ],
        out_specs=pl.BlockSpec((tn,), lambda jn, im: (jn,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, r)
