"""Gram-block kernel ``G = Xᵀ Y`` (Algorithm 2 steps 4/20).

X = A_I (m × k), Y = A_B (m × b): a skinny matmul reduced over rows.
Tiled over the row dimension only (k and b are tiny — at most t and b),
accumulating the (k × b) block in VMEM — the same shape the paper
reduces across MPI ranks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TM = 128


def _gram_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...].T @ y_ref[...]


@functools.partial(jax.jit, static_argnames=("tm",))
def gram_block(x: jax.Array, y: jax.Array, *, tm: int = TM) -> jax.Array:
    """``Xᵀ Y`` via a row-tiled Pallas kernel (interpret mode)."""
    m, k = x.shape
    m2, b = y.shape
    if m != m2:
        raise ValueError(f"row mismatch {m} vs {m2}")
    if m % tm:
        raise ValueError(f"m = {m} not divisible by tile {tm}")
    return pl.pallas_call(
        _gram_kernel,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, k), lambda im: (im, 0)),
            pl.BlockSpec((tm, b), lambda im: (im, 0)),
        ],
        out_specs=pl.BlockSpec((k, b), lambda im: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, b), x.dtype),
        interpret=True,
    )(x, y)
