"""Step-size candidate kernel (Algorithm 2, step 12).

For every non-selected column j compute the paper's two candidate roots

    g1 = (ck − c_j) / (ck·h − a_j)      g2 = (ck + c_j) / (ck·h + a_j)

and keep ``min⁺`` (the smallest strictly positive finite root, capped at
the full least-squares step 1/h). Selected / padded columns are masked
to +inf so downstream ``min^b`` selection ignores them.

Bandwidth-bound elementwise work — a natural VPU kernel fused over the
same TN tiles the correlation kernel produces.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TN = 64
_BIG = float("inf")  # plain Python literal: Pallas kernels cannot capture arrays


def _gamma_kernel(c_ref, a_ref, mask_ref, s_ref, o_ref):
    ck = s_ref[0]
    h = s_ref[1]
    c = c_ref[...]
    a = a_ref[...]
    g1 = (ck - c) / (ck * h - a)
    g2 = (ck + c) / (ck * h + a)

    def minpos(x, y):
        xo = jnp.where(jnp.isfinite(x) & (x > 0.0), x, _BIG)
        yo = jnp.where(jnp.isfinite(y) & (y > 0.0), y, _BIG)
        return jnp.minimum(xo, yo)

    g = minpos(g1, g2)
    gmax = 1.0 / h
    g = jnp.where(g <= gmax * (1.0 + 1e-6), g, _BIG)
    o_ref[...] = jnp.where(mask_ref[...] > 0.5, _BIG, g)


@functools.partial(jax.jit, static_argnames=("tn",))
def gamma_candidates(
    c: jax.Array,
    a: jax.Array,
    mask: jax.Array,
    ck: jax.Array,
    h: jax.Array,
    *,
    tn: int = TN,
) -> jax.Array:
    """γ candidates per column; `mask` is 1.0 for selected/padded columns.

    `ck`/`h` are passed stacked as a (2,)-vector so the kernel reads them
    from one scalar-prefetch-style ref.
    """
    (n,) = c.shape
    if n % tn:
        raise ValueError(f"n = {n} not divisible by tile {tn}")
    scalars = jnp.stack([ck.astype(c.dtype), h.astype(c.dtype)])
    return pl.pallas_call(
        _gamma_kernel,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((tn,), lambda j: (j,)),
            pl.BlockSpec((tn,), lambda j: (j,)),
            pl.BlockSpec((tn,), lambda j: (j,)),
            pl.BlockSpec((2,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((tn,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), c.dtype),
        interpret=True,
    )(c, a, mask, scalars)
