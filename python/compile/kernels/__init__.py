"""L1 — Pallas kernels for the LARS hot spots.

Every kernel here runs under ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is the correctness
path and real-TPU performance is *estimated* (VMEM footprint + MXU
utilization) in DESIGN.md / EXPERIMENTS.md §Perf.
"""

from .correlation import corr, corr_tiles
from .gamma import gamma_candidates
from .gram import gram_block

__all__ = ["corr", "corr_tiles", "gamma_candidates", "gram_block"]
