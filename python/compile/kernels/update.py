"""Fused response/correlation update kernel (Algorithm 2, steps 17-19).

    y ← y + γ·u ;  r ← b − y ;  c_j ← c_j·(1−γh) if selected else c_j − γ·a_j

Pure elementwise/VPU work over length-m and length-n tiles; fusing the
three updates removes two extra HBM passes over the m-vectors — the
same reasoning the paper uses to keep step 17 communication-free.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TM = 256


def _update_m_kernel(y_ref, u_ref, b_ref, s_ref, oy_ref, or_ref):
    gamma = s_ref[0]
    y = y_ref[...] + gamma * u_ref[...]
    oy_ref[...] = y
    or_ref[...] = b_ref[...] - y


def _update_c_kernel(c_ref, a_ref, mask_ref, s_ref, oc_ref):
    gamma = s_ref[0]
    shrink = s_ref[1]
    c = c_ref[...]
    oc_ref[...] = jnp.where(mask_ref[...] > 0.5, c * shrink, c - gamma * a_ref[...])


@functools.partial(jax.jit, static_argnames=("tm",))
def update_response(y, u, b, gamma, *, tm: int = TM):
    """Returns ``(y + γu, b − (y + γu))``."""
    (m,) = y.shape
    if m % tm:
        raise ValueError(f"m = {m} not divisible by tile {tm}")
    scalars = jnp.stack([gamma.astype(y.dtype)])
    return pl.pallas_call(
        _update_m_kernel,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm,), lambda i: (i,)),
            pl.BlockSpec((tm,), lambda i: (i,)),
            pl.BlockSpec((tm,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tm,), lambda i: (i,)),
            pl.BlockSpec((tm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), y.dtype),
            jax.ShapeDtypeStruct((m,), y.dtype),
        ],
        interpret=True,
    )(y, u, b, scalars)


@functools.partial(jax.jit, static_argnames=("tn",))
def update_correlations(c, a, mask, gamma, shrink, *, tn: int = TM):
    """Step 18: masked two-branch correlation update."""
    (n,) = c.shape
    if n % tn:
        raise ValueError(f"n = {n} not divisible by tile {tn}")
    scalars = jnp.stack([gamma.astype(c.dtype), shrink.astype(c.dtype)])
    return pl.pallas_call(
        _update_c_kernel,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), c.dtype),
        interpret=True,
    )(c, a, mask, scalars)
