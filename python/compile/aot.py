"""AOT bridge: lower the L2 models to HLO **text** artifacts.

HLO text (not ``.serialize()``): jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which the runtime's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts`` (idempotent;
`make artifacts` wraps it). Writes one ``<op>_<m>x<n>.hlo.txt`` per
bucket plus ``manifest.tsv`` (consumed by the Rust runtime) and
``manifest.json`` (for humans).
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# Bucket shapes compiled ahead of time. Chosen to cover the dense
# datasets/examples (year_like is 16384×90 → padded to ×96).
#
# Tile choice (EXPERIMENTS.md §Perf, L1 iteration 1): interpret-mode
# Pallas lowers the grid to an XLA while-loop with dynamic slices, so on
# the CPU execution path *fewer, larger* tiles win — the 16384×96 bucket
# went 424 ms → single-digit ms by collapsing the 384-step grid to ≤ 8
# steps. The TPU-oriented tiling (TM = 128, TN = 64, sized for ~16 MiB
# VMEM with double buffering) is retained as the kernels' defaults and
# in the roofline estimate; these overrides are per-artifact schedule
# choices, not kernel changes.
BUCKETS = [
    # (m, n, corr tile overrides)
    (128, 64, {}),
    (512, 256, {"tm": 512, "tn": 256}),
    (2048, 512, {"tm": 1024, "tn": 512}),
    (16384, 96, {"tm": 4096, "tn": 96}),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(m: int, n: int, tiles: dict) -> dict[str, str]:
    """Lower both models at one bucket; returns op → HLO text."""
    from .kernels.correlation import TM, TN, corr
    from .kernels.gamma import gamma_candidates

    shapes = model.shapes_for(m, n)
    tm = tiles.get("tm", TM)
    tn = tiles.get("tn", TN)
    # γ tile: one block per bucket (pure elementwise; no reuse to exploit).
    gtn = n

    def corr_fn(a, r):
        return (corr(a, r, tm=tm, tn=tn),)

    def gstep_fn(a, u, c, mask, ck, h):
        av = corr(a, u, tm=tm, tn=tn)
        return (av, gamma_candidates(c, av, mask, ck, h, tn=gtn))

    out = {}
    out["corr"] = to_hlo_text(jax.jit(corr_fn).lower(*shapes["corr"]))
    out["gstep"] = to_hlo_text(jax.jit(gstep_fn).lower(*shapes["gstep"]))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true", help="rebuild even if up to date")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    tsv_lines = []
    json_entries = []
    for m, n, tiles in BUCKETS:
        for op, text in lower_bucket(m, n, tiles).items():
            fname = f"{op}_{m}x{n}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            tsv_lines.append(f"{op}\t{m}\t{n}\t{fname}")
            json_entries.append({"op": op, "m": m, "n": n, "file": fname})
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("# op\tm\tn\tfile — see rust/src/runtime/artifacts.rs\n")
        f.write("\n".join(tsv_lines) + "\n")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": json_entries, "dtype": "f32"}, f, indent=2)
    print(f"manifest: {len(tsv_lines)} artifacts in {args.out_dir}")


if __name__ == "__main__":
    main()
