"""L1 performance model: VMEM footprint + MXU utilization estimates.

``interpret=True`` timings are CPU-numpy and say nothing about TPU
performance, so — per DESIGN.md §8 — real-TPU behaviour is *estimated*
from the BlockSpec schedule:

* VMEM footprint per grid step (A tile + r tile + accumulator, double
  buffered) must fit the ~16 MiB budget;
* arithmetic intensity (flops per HBM byte) decides whether the kernel
  is MXU-bound or HBM-bound; Aᵀr is a rank-1-output contraction, so it
  is bandwidth-bound and the target is HBM-roofline fraction, not MXU
  peak.

Usage: ``python -m compile.roofline``  (also imported by tests).
"""

from dataclasses import dataclass

# TPU-v4-ish single-core numbers (order-of-magnitude model, not a spec).
HBM_GBPS = 1200.0  # HBM bandwidth, GB/s
MXU_TFLOPS_F32 = 70.0  # effective f32 throughput via MXU passes
VMEM_BYTES = 16 * 2**20


@dataclass
class KernelEstimate:
    name: str
    m: int
    n: int
    tm: int
    tn: int
    vmem_per_step: int
    vmem_double_buffered: int
    flops: float
    hbm_bytes: float
    intensity: float  # flops / HBM byte
    bound: str
    t_hbm_us: float
    t_mxu_us: float
    t_roofline_us: float
    mxu_utilization_at_roofline: float

    def fits_vmem(self) -> bool:
        return self.vmem_double_buffered <= VMEM_BYTES


def corr_estimate(m: int, n: int, tm: int, tn: int, dtype_bytes: int = 4) -> KernelEstimate:
    """Roofline estimate for the tiled ``c = Aᵀr`` kernel."""
    a_tile = tm * tn * dtype_bytes
    r_tile = tm * dtype_bytes
    acc = tn * dtype_bytes
    per_step = a_tile + r_tile + acc
    flops = 2.0 * m * n
    # A is streamed once; r is re-read once per column tile; c written once.
    hbm = (m * n + m * (n // tn) + n) * dtype_bytes
    intensity = flops / hbm
    t_hbm = hbm / (HBM_GBPS * 1e9) * 1e6
    t_mxu = flops / (MXU_TFLOPS_F32 * 1e12) * 1e6
    t_roof = max(t_hbm, t_mxu)
    return KernelEstimate(
        name="corr",
        m=m,
        n=n,
        tm=tm,
        tn=tn,
        vmem_per_step=per_step,
        vmem_double_buffered=2 * per_step,
        flops=flops,
        hbm_bytes=hbm,
        intensity=intensity,
        bound="HBM" if t_hbm >= t_mxu else "MXU",
        t_hbm_us=t_hbm,
        t_mxu_us=t_mxu,
        t_roofline_us=t_roof,
        mxu_utilization_at_roofline=t_mxu / t_roof,
    )


def report(tm: int = 128, tn: int = 64) -> str:
    from .aot import BUCKETS

    lines = [
        f"# corr kernel roofline (TPU tiling TM={tm}, TN={tn}; "
        f"HBM {HBM_GBPS:.0f} GB/s, MXU {MXU_TFLOPS_F32:.0f} Tflop/s f32)",
        f"{'bucket':>12} {'VMEM(2x)':>10} {'fits':>5} {'intensity':>10} "
        f"{'bound':>6} {'t_roof(us)':>11} {'MXU util':>9}",
    ]
    for m, n, _ in BUCKETS:
        e = corr_estimate(m, n, tm, min(tn, n))
        lines.append(
            f"{f'{m}x{n}':>12} {e.vmem_double_buffered / 2**10:>9.0f}K "
            f"{str(e.fits_vmem()):>5} {e.intensity:>10.2f} {e.bound:>6} "
            f"{e.t_roofline_us:>11.2f} {e.mxu_utilization_at_roofline:>8.1%}"
        )
    lines.append(
        "Aᵀr is bandwidth-bound (intensity ≈ 0.5 flop/B): the efficiency "
        "target is the HBM roofline, matching the paper's matvec-bound "
        "cost model (Table 1's tmn/(bP) term)."
    )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
