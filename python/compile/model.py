"""L2 — the per-iteration LARS compute graphs, composed from the L1
Pallas kernels and lowered once by :mod:`compile.aot`.

Two entry points are AOT-compiled (one executable per bucket shape):

* ``corr_model`` — Algorithm 2 step 2/11: ``c = Aᵀ r``.
* ``gstep_model`` — the fused steps 11–12: given the direction ``u``,
  compute ``a = Aᵀ u`` with the Pallas correlation kernel, then the γ
  candidates with the Pallas elementwise kernel, in one XLA program (no
  host round-trip between the two hot loops).

Everything returns tuples — the AOT bridge lowers with
``return_tuple=True`` and the Rust side unwraps with ``to_tupleN``.
"""

import jax
import jax.numpy as jnp

from .kernels import corr, gamma_candidates


def corr_model(a: jax.Array, r: jax.Array):
    """``(c,) = (Aᵀ r,)``."""
    return (corr(a, r),)


def gstep_model(
    a: jax.Array,
    u: jax.Array,
    c: jax.Array,
    mask: jax.Array,
    ck: jax.Array,
    h: jax.Array,
):
    """Fused direction-correlation + γ-candidate computation.

    Returns ``(av, gammas)`` where ``av = Aᵀu`` and ``gammas[j]`` is the
    paper's min⁺ step-size candidate (+inf for selected/padded columns).
    """
    av = corr(a, u)
    gammas = gamma_candidates(c, av, mask, ck, h)
    return (av, gammas)


def shapes_for(m: int, n: int, dtype=jnp.float32):
    """Example arguments for AOT-lowering the two models at (m, n)."""
    f = jax.ShapeDtypeStruct
    scalar = f((), dtype)
    return {
        "corr": (f((m, n), dtype), f((m,), dtype)),
        "gstep": (
            f((m, n), dtype),
            f((m,), dtype),
            f((n,), dtype),
            f((n,), dtype),
            scalar,
            scalar,
        ),
    }
