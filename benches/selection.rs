//! Model-selection benchmark: in-sample Cp ranking and k-fold CV
//! selection wall time at 1→N pool threads, with the acceptance gate
//! baked in — the CV-selected step (and every score bit) must be
//! identical across thread counts, or the bench exits nonzero. This is
//! how `scripts/ci.sh` fails the build on a selection-determinism
//! regression while recording the perf trajectory.
//!
//! Run: `cargo bench --bench selection` (human table)
//!      `cargo bench --bench selection -- --json` (the records ci.sh
//!      writes to BENCH_select.json; schema per record:
//!      {bench, threads, wall_ms, speedup})

use calars::data::datasets;
use calars::fit::{Algorithm, FitSpec, Fitter, SnapshotObserver};
use calars::metrics::{bench, black_box, fmt_secs};
use calars::par::{self, ThreadPool};
use calars::select::{self, Criterion, SelectSpec, Selection};

struct Record {
    bench: &'static str,
    threads: usize,
    wall_ms: f64,
    speedup: f64,
}

/// Comparable identity of a selection: the chosen step plus every
/// score's bit pattern.
fn signature(sel: &Selection) -> Vec<u64> {
    let mut sig = vec![sel.best_step as u64];
    sig.extend(sel.scores.iter().map(|s| s.score.to_bits()));
    sig
}

fn main() {
    let json = std::env::args().any(|a| a == "--json");
    let cores = par::detected_cores();
    let mut counts: Vec<usize> = vec![1, 2, 4];
    if cores > 4 {
        counts.push(cores);
    }
    counts.dedup();
    let pools: Vec<ThreadPool> =
        counts.iter().map(|&t| ThreadPool::new(t, par::DEFAULT_MIN_CHUNK)).collect();
    if !json {
        println!("# model selection ({cores} cores detected; threads ∈ {counts:?})\n");
    }

    let ds = datasets::tiny(7);
    let fit = FitSpec::new(Algorithm::Lars).t(16);
    let sel = SelectSpec::new(Criterion::Cv).k(5).seed(1);
    let mut records: Vec<Record> = Vec::new();
    let mut diverged = false;

    // ── In-sample ranking (Cp over a stored path) ──
    let mut obs = SnapshotObserver::new();
    fit.fit(&ds.a, &ds.b, &mut obs).expect("fit");
    let snap = obs.into_snapshot().expect("snapshot");
    let m = ds.a.nrows();
    let cp = select::rank_steps(&snap, m, Criterion::Cp).expect("cp ranks");
    let timing = bench(2, 50, || {
        black_box(select::rank_steps(&snap, m, Criterion::Cp).expect("cp ranks"))
    });
    records.push(Record {
        bench: "select_cp_tiny_t16",
        threads: 1,
        wall_ms: timing.best * 1e3,
        speedup: 1.0,
    });
    if !json {
        println!("## select_cp_tiny_t16");
        println!("  step {} in {}\n", cp.best_step, fmt_secs(timing.best));
    }

    // ── k-fold CV selection, thread-count sweep + divergence gate ──
    let mut base: Option<(Vec<u64>, f64)> = None;
    for (pool, &threads) in pools.iter().zip(&counts) {
        let (sig, wall) = par::with_pool(pool, || {
            let first = select::cross_validate(&ds.a, &ds.b, &fit, &sel).expect("cv");
            let timing = bench(1, 3, || {
                black_box(select::cross_validate(&ds.a, &ds.b, &fit, &sel).expect("cv"))
            });
            (signature(&first), timing.best)
        });
        match &base {
            None => {
                records.push(Record {
                    bench: "select_cv5_tiny_t16",
                    threads,
                    wall_ms: wall * 1e3,
                    speedup: 1.0,
                });
                if !json {
                    println!("## select_cv5_tiny_t16");
                    println!("  threads={threads}  {:>10}  (baseline)", fmt_secs(wall));
                }
                base = Some((sig, wall));
            }
            Some((base_sig, base_wall)) => {
                if &sig != base_sig {
                    eprintln!(
                        "DIVERGENCE: CV selection differs between threads=1 and \
                         threads={threads}"
                    );
                    diverged = true;
                }
                let speedup = base_wall / wall.max(1e-12);
                records.push(Record {
                    bench: "select_cv5_tiny_t16",
                    threads,
                    wall_ms: wall * 1e3,
                    speedup,
                });
                if !json {
                    println!(
                        "  threads={threads}  {:>10}  speedup {speedup:.2}x",
                        fmt_secs(wall)
                    );
                }
            }
        }
    }

    if json {
        let body: Vec<String> = records
            .iter()
            .map(|r| {
                format!(
                    "{{\"bench\":\"{}\",\"threads\":{},\"wall_ms\":{:.3},\"speedup\":{:.3}}}",
                    r.bench, r.threads, r.wall_ms, r.speedup
                )
            })
            .collect();
        println!("[{}]", body.join(",\n "));
    } else {
        println!();
    }

    if diverged {
        eprintln!("CV selection diverged across thread counts — failing the bench");
        std::process::exit(1);
    }
}
