//! Regenerate every table and figure of the paper's evaluation
//! (the bench-shaped entry point; `calars exp <id>` is the CLI one).
//!
//! Run: `cargo bench --bench tables_figures`            (CI-sized sweeps)
//!      `cargo bench --bench tables_figures -- --full`  (paper-scale sweeps;
//!      equivalently `calars suite`, which is the canonical full run)

use calars::config::SweepConfig;
use calars::experiments;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let quick = !argv.iter().any(|a| a == "--full");
    let sweep = if quick { SweepConfig::quick() } else { SweepConfig::default() };

    for id in experiments::ALL_IDS {
        let t0 = std::time::Instant::now();
        match experiments::run_by_id(id, &sweep, quick) {
            Ok(report) => {
                println!("{report}");
                eprintln!("[{id}: {:.1}s]", t0.elapsed().as_secs_f64());
            }
            Err(e) => eprintln!("[{id} FAILED: {e}]"),
        }
        println!();
    }
}
