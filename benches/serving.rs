//! Serving-layer benchmark: spin up the HTTP server in-process, fit a
//! model, then drive closed-loop load at several concurrency/batch
//! shapes and report throughput + latency percentiles.
//!
//! Run: `cargo bench --bench serving`

use calars::serve::{
    run_load, spawn_server, FitRequest, LoadOptions, Selector, ServeClient, ServeOptions,
};

fn main() {
    println!("# serving benchmarks (in-process server, loopback TCP)\n");
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        batch_window_us: 200,
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr_string();
    println!("server on {addr}");

    let mut client = ServeClient::connect(&addr).expect("connect");
    let fit = FitRequest { dataset: "tiny".into(), t: 16, ..Default::default() };
    let model = client.fit(&fit, true).expect("fit");
    let dim = client.model_dim(model).expect("dim");
    println!("model {model}: dataset=tiny t=16 n={dim}\n");

    for (concurrency, rows, requests) in
        [(1usize, 1usize, 2000usize), (4, 1, 4000), (4, 16, 2000), (16, 16, 2000)]
    {
        println!("## concurrency={concurrency} rows/request={rows} requests={requests}");
        let report = run_load(
            &addr,
            &LoadOptions {
                requests,
                concurrency,
                rows,
                model,
                selector: Selector::Step(16),
                dim,
                seed: 7,
            },
        )
        .expect("load run");
        println!("{}\n", report.render());
    }

    let (_, stats) = client.request("GET", "/stats", "").expect("stats");
    println!("## final /stats\n{stats}");
    server.stop();
}
