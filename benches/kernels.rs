//! Kernel-engine microbenchmarks on the perf-gate shape (2000×4000
//! dense): the blocked `calars::kern` kernels vs the textbook scalar
//! `kern::reference` loops (the gated records) **and** vs the
//! row-streaming loops the crate shipped pre-kern
//! (`reference::{at_r,gram_block}_streamed` — the `streamed_*`
//! records, ungated: they track the honest old-code → kern delta),
//! plus the fused equiangular step and a serve-level warm-refit
//! measurement through the GramCache.
//!
//! Doubles as the CI divergence gate: every kern result is compared
//! against its reference and the bench exits nonzero if
//! `max |Δ| > 1e-9` (scripts/ci.sh records the JSON as
//! `BENCH_kernels.json`; schema per record:
//! `{bench, threads, wall_ms, speedup, isa}` where `speedup` is
//! old-scalar / kern wall time, or cold / warm for the refit record,
//! or scalar-backend / vector-backend wall time for the per-ISA
//! records).
//!
//! The per-ISA section re-times the hot kernels under
//! `kern::simd::with_backend` — once forced to the scalar backend
//! (`…_scalar` records, speedup 1.0 by definition) and once under the
//! widest detected vector backend (`…_<isa>` records, speedup =
//! scalar / vector wall time). scripts/ci.sh gates the vector records
//! at ≥ 2× on at_r and gram_block.
//!
//! Run: `cargo bench --bench kernels` (human table)
//!      `cargo bench --bench kernels -- --json`

use calars::fit::{Algorithm, FitSpec};
use calars::kern::reference;
use calars::kern::simd::{self, KernBackend};
use calars::linalg::{Cholesky, DenseMatrix};
use calars::metrics::{bench, black_box, fmt_secs};
use calars::par::{self, ThreadPool};
use calars::rng::Pcg64;
use calars::serve::{FitJob, FitQueue, GramCache, JobState, ModelRegistry};
use std::sync::Arc;
use std::time::Duration;

const GATE: f64 = 1e-9;

struct Record {
    bench: String,
    threads: usize,
    wall_ms: f64,
    speedup: f64,
    isa: &'static str,
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

fn main() {
    let json = std::env::args().any(|a| a == "--json");
    let mut records: Vec<Record> = Vec::new();
    let mut worst_delta = 0.0_f64;
    let note = |records: &mut Vec<Record>,
                    bench_name: &'static str,
                    kern_ms: f64,
                    ref_ms: f64,
                    delta: f64| {
        if !json {
            println!(
                "{bench_name:<34} kern {:>10}  scalar {:>10}  speedup {:>6.2}x  max|Δ| {delta:.2e}",
                fmt_secs(kern_ms / 1e3),
                fmt_secs(ref_ms / 1e3),
                ref_ms / kern_ms.max(1e-12)
            );
        }
        records.push(Record {
            bench: bench_name.to_string(),
            threads: 1,
            wall_ms: kern_ms,
            speedup: ref_ms / kern_ms.max(1e-12),
            isa: simd::current().name(),
        });
    };

    if !json {
        println!("# kernel engine: kern vs scalar reference (single thread)\n");
    }

    // The acceptance shape: 2000×4000 dense. All kernel comparisons run
    // on a 1-thread pool so the records measure per-core kernel
    // quality, not parallel fan-out (benches/parallel_scaling.rs owns
    // that trajectory).
    let (m, n) = (2000usize, 4000usize);
    let mut rng = Pcg64::new(1);
    let a = DenseMatrix::from_fn(m, n, |_, _| rng.normal());
    let data = a.data().to_vec();
    let r: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let ii: Vec<usize> = (0..64).collect();
    let jj: Vec<usize> = (64..128).collect();
    let w: Vec<f64> = ii.iter().map(|&k| (k as f64 * 0.05).sin() + 0.1).collect();

    let pool1 = ThreadPool::new(1, par::DEFAULT_MIN_CHUNK);
    par::with_pool(&pool1, || {
        // ── Aᵀr ──
        let mut kern_out = vec![0.0; n];
        a.at_r(&r, &mut kern_out);
        let mut ref_out = vec![0.0; n];
        reference::at_r(&data, m, n, &r, &mut ref_out);
        worst_delta = worst_delta.max(max_abs_diff(&kern_out, &ref_out));
        let sk = bench(1, 5, || {
            a.at_r(black_box(&r), &mut kern_out);
            kern_out[0]
        });
        let sr = bench(1, 3, || {
            reference::at_r(black_box(&data), m, n, &r, &mut ref_out);
            ref_out[0]
        });
        note(&mut records, "at_r_2000x4000", sk.best * 1e3, sr.best * 1e3, max_abs_diff(&kern_out, &ref_out));
        // Ungated: same kern timing vs the pre-kern row-streaming loop.
        let mut streamed_out = vec![0.0; n];
        reference::at_r_streamed(&data, m, n, &r, &mut streamed_out);
        worst_delta = worst_delta.max(max_abs_diff(&kern_out, &streamed_out));
        let ss = bench(1, 5, || {
            reference::at_r_streamed(black_box(&data), m, n, &r, &mut streamed_out);
            streamed_out[0]
        });
        note(
            &mut records,
            "streamed_at_r_2000x4000",
            sk.best * 1e3,
            ss.best * 1e3,
            max_abs_diff(&kern_out, &streamed_out),
        );

        // ── Gram block 64×64 ──
        let kern_g = a.gram_block(&ii, &jj);
        let ref_g = reference::gram_block(&data, m, n, &ii, &jj);
        worst_delta = worst_delta.max(max_abs_diff(kern_g.data(), &ref_g));
        let delta_g = max_abs_diff(kern_g.data(), &ref_g);
        let sk = bench(1, 5, || black_box(a.gram_block(&ii, &jj)).get(0, 0));
        let sr = bench(1, 2, || {
            black_box(reference::gram_block(&data, m, n, &ii, &jj))[0]
        });
        note(&mut records, "gram_block_2000x4000_64x64", sk.best * 1e3, sr.best * 1e3, delta_g);
        // Ungated: vs the pre-kern hoisted-rj rank-1 streaming Gram.
        let streamed_g = reference::gram_block_streamed(&data, m, n, &ii, &jj);
        worst_delta = worst_delta.max(max_abs_diff(kern_g.data(), &streamed_g));
        let delta_sg = max_abs_diff(kern_g.data(), &streamed_g);
        let ss = bench(1, 5, || {
            black_box(reference::gram_block_streamed(&data, m, n, &ii, &jj))[0]
        });
        note(
            &mut records,
            "streamed_gram_block_2000x4000_64x64",
            sk.best * 1e3,
            ss.best * 1e3,
            delta_sg,
        );

        // ── gemv_cols |I|=64 ──
        let mut kern_u = vec![0.0; m];
        a.gemv_cols(&ii, &w, &mut kern_u);
        let mut ref_u = vec![0.0; m];
        reference::gemv_cols(&data, m, n, &ii, &w, &mut ref_u);
        worst_delta = worst_delta.max(max_abs_diff(&kern_u, &ref_u));
        let delta_u = max_abs_diff(&kern_u, &ref_u);
        let sk = bench(1, 5, || {
            a.gemv_cols(black_box(&ii), &w, &mut kern_u);
            kern_u[0]
        });
        let sr = bench(1, 5, || {
            reference::gemv_cols(black_box(&data), m, n, &ii, &w, &mut ref_u);
            ref_u[0]
        });
        note(&mut records, "gemv_cols_2000x4000_64", sk.best * 1e3, sr.best * 1e3, delta_u);

        // ── fused equiangular step vs two scalar passes ──
        let mut fu = vec![0.0; m];
        let mut fav = vec![0.0; n];
        a.gemv_cols_at_r(&ii, &w, &mut fu, &mut fav);
        let mut ru = vec![0.0; m];
        reference::gemv_cols(&data, m, n, &ii, &w, &mut ru);
        let mut rav = vec![0.0; n];
        reference::at_r(&data, m, n, &ru, &mut rav);
        worst_delta = worst_delta.max(max_abs_diff(&fu, &ru));
        worst_delta = worst_delta.max(max_abs_diff(&fav, &rav));
        let delta_f = max_abs_diff(&fav, &rav);
        let sk = bench(1, 5, || {
            a.gemv_cols_at_r(black_box(&ii), &w, &mut fu, &mut fav);
            fav[0]
        });
        let sr = bench(1, 2, || {
            reference::gemv_cols(black_box(&data), m, n, &ii, &w, &mut ru);
            reference::at_r(&data, m, n, &ru, &mut rav);
            rav[0]
        });
        note(&mut records, "fused_step_2000x4000_64", sk.best * 1e3, sr.best * 1e3, delta_f);

        // ── Cholesky panel append (kern dot recurrences) ──
        let mut rng2 = Pcg64::new(3);
        let base = DenseMatrix::from_fn(96, 64, |_, _| rng2.normal());
        let all: Vec<usize> = (0..64).collect();
        let mut g = base.gram_block(&all, &all);
        for i in 0..64 {
            g.set(i, i, g.get(i, i) + 0.1);
        }
        let g56 = DenseMatrix::from_fn(56, 56, |i, j| g.get(i, j));
        let gib = DenseMatrix::from_fn(56, 8, |i, j| g.get(i, 56 + j));
        let gbb = DenseMatrix::from_fn(8, 8, |i, j| g.get(56 + i, 56 + j));
        let c56 = Cholesky::factor(&g56).unwrap();
        let push_rows = |ch: &mut Cholesky| {
            for rr in 0..8 {
                let mut grow: Vec<f64> = (0..56).map(|i| gib.get(i, rr)).collect();
                for j in 0..=rr {
                    grow.push(gbb.get(rr, j));
                }
                ch.push_row(&grow).unwrap();
            }
        };
        // Panel vs row-by-row must agree (bit-identical by contract);
        // feed the measured factor difference through the gate.
        let mut blocked = c56.clone();
        blocked.append_block(&gib, &gbb).unwrap();
        let mut rowwise = c56.clone();
        push_rows(&mut rowwise);
        let mut delta_c = 0.0_f64;
        for i in 0..blocked.dim() {
            for j in 0..=i {
                delta_c = delta_c.max((blocked.get(i, j) - rowwise.get(i, j)).abs());
            }
        }
        worst_delta = worst_delta.max(delta_c);
        let sk = bench(2, 50, || {
            let mut ch = c56.clone();
            ch.append_block(black_box(&gib), &gbb).unwrap();
            ch.dim()
        });
        let sr = bench(2, 50, || {
            let mut ch = c56.clone();
            push_rows(black_box(&mut ch));
            ch.dim()
        });
        note(&mut records, "cholesky_append_56p8", sk.best * 1e3, sr.best * 1e3, delta_c);
    });

    // ── per-ISA backend records ──
    // Re-time the three hot kernels under a forced-scalar backend and
    // under the widest detected vector backend. The pool is built
    // *inside* with_backend so it captures the forced backend (workers
    // would otherwise disagree with the bench thread). Outputs are
    // checked against kern::reference at the 1e-9 gate per backend.
    if !json {
        println!("\n# kernel engine: SIMD backend vs forced-scalar backend\n");
    }
    let detected = KernBackend::detect();
    let backends: Vec<KernBackend> = if detected == KernBackend::Scalar {
        vec![KernBackend::Scalar]
    } else {
        vec![KernBackend::Scalar, detected]
    };
    // (at_r_ms, gram_ms, fused_ms, worst backend-vs-reference |Δ|)
    let measure = |backend: KernBackend| -> (f64, f64, f64, f64) {
        simd::with_backend(backend, || {
            let pool = ThreadPool::new(1, par::DEFAULT_MIN_CHUNK);
            par::with_pool(&pool, || {
                let mut delta = 0.0_f64;
                let mut out = vec![0.0; n];
                a.at_r(&r, &mut out);
                let mut ref_out = vec![0.0; n];
                reference::at_r(&data, m, n, &r, &mut ref_out);
                delta = delta.max(max_abs_diff(&out, &ref_out));
                let s_at_r = bench(1, 5, || {
                    a.at_r(black_box(&r), &mut out);
                    out[0]
                });
                let g = a.gram_block(&ii, &jj);
                let ref_g = reference::gram_block(&data, m, n, &ii, &jj);
                delta = delta.max(max_abs_diff(g.data(), &ref_g));
                let s_gram = bench(1, 5, || black_box(a.gram_block(&ii, &jj)).get(0, 0));
                let mut u = vec![0.0; m];
                let mut av = vec![0.0; n];
                a.gemv_cols_at_r(&ii, &w, &mut u, &mut av);
                let mut ref_u = vec![0.0; m];
                reference::gemv_cols(&data, m, n, &ii, &w, &mut ref_u);
                let mut ref_av = vec![0.0; n];
                reference::at_r(&data, m, n, &ref_u, &mut ref_av);
                delta = delta.max(max_abs_diff(&u, &ref_u));
                delta = delta.max(max_abs_diff(&av, &ref_av));
                let s_fused = bench(1, 5, || {
                    a.gemv_cols_at_r(black_box(&ii), &w, &mut u, &mut av);
                    av[0]
                });
                (s_at_r.best * 1e3, s_gram.best * 1e3, s_fused.best * 1e3, delta)
            })
        })
    };
    let mut scalar_ms = (0.0_f64, 0.0_f64, 0.0_f64);
    for backend in backends {
        let (at_r_ms, gram_ms, fused_ms, delta) = measure(backend);
        worst_delta = worst_delta.max(delta);
        if backend == KernBackend::Scalar {
            scalar_ms = (at_r_ms, gram_ms, fused_ms);
        }
        let isa = backend.name();
        for (base, ms, base_ms) in [
            ("at_r_2000x4000", at_r_ms, scalar_ms.0),
            ("gram_block_2000x4000_64x64", gram_ms, scalar_ms.1),
            ("fused_step_2000x4000_64", fused_ms, scalar_ms.2),
        ] {
            let speedup = base_ms / ms.max(1e-12);
            if !json {
                println!(
                    "{:<34} {isa:>7} {:>10}  vs scalar {:>6.2}x  max|Δ| {delta:.2e}",
                    format!("{base}_{isa}"),
                    fmt_secs(ms / 1e3),
                    speedup
                );
            }
            records.push(Record {
                bench: format!("{base}_{isa}"),
                threads: 1,
                wall_ms: ms,
                speedup,
                isa,
            });
        }
    }

    // ── serve warm-refit through the GramCache ──
    // Cold: fresh registry + fresh cache. Warm: fresh registry (so the
    // warm-start snapshot shortcut cannot answer) but the SAME cache —
    // the refit skips dataset regeneration and hits every Gram panel
    // of the repeated selection prefix.
    let fit_wall = |cache: &Arc<GramCache>| -> f64 {
        let q = FitQueue::with_gram_cache(Arc::new(ModelRegistry::new(4)), 1, Arc::clone(cache));
        let job = q.submit(FitJob {
            dataset: "year".into(),
            spec: FitSpec::new(Algorithm::Lars).t(24),
            ..Default::default()
        });
        match q.wait(job, Duration::from_secs(600)) {
            Some(JobState::Done { wall_secs, .. }) => wall_secs,
            other => panic!("warm-refit bench fit failed: {other:?}"),
        }
    };
    let cache = Arc::new(GramCache::default());
    let cold = fit_wall(&cache);
    let warm = fit_wall(&cache);
    let refit_stats = cache.stats();
    assert!(refit_stats.panel_hits > 0, "warm refit recorded no panel hits");
    if !json {
        println!(
            "{:<34} warm {:>10}  cold {:>10}  speedup {:>6.2}x  (panel hits {})",
            "serve_warm_refit_year_t24",
            fmt_secs(warm),
            fmt_secs(cold),
            cold / warm.max(1e-12),
            refit_stats.panel_hits
        );
    }
    records.push(Record {
        bench: "serve_warm_refit_year_t24".to_string(),
        threads: 1,
        wall_ms: warm * 1e3,
        speedup: cold / warm.max(1e-12),
        isa: simd::current().name(),
    });

    if json {
        let body: Vec<String> = records
            .iter()
            .map(|r| {
                format!(
                    "{{\"bench\":\"{}\",\"threads\":{},\"wall_ms\":{:.3},\"speedup\":{:.3},\"isa\":\"{}\"}}",
                    r.bench, r.threads, r.wall_ms, r.speedup, r.isa
                )
            })
            .collect();
        println!("[{}]", body.join(",\n "));
    } else {
        println!("\nmax kern-vs-reference |Δ| = {worst_delta:.3e} (gate {GATE:.0e})");
    }

    if worst_delta > GATE {
        eprintln!(
            "kernel divergence: max |Δ| {worst_delta:.3e} exceeds the {GATE:.0e} gate — failing"
        );
        std::process::exit(1);
    }
}
