//! Microbenchmarks for the linalg substrate (criterion is unavailable
//! offline; `calars::metrics::bench` provides warmup + robust summary).
//!
//! Run: `cargo bench --bench kernels`

use calars::data::datasets;
use calars::linalg::{Cholesky, DenseMatrix, Matrix};
use calars::metrics::{bench, black_box, fmt_secs};
use calars::rng::Pcg64;

fn report(name: &str, flops: u64, s: calars::metrics::TimingSummary) {
    let gflops = flops as f64 / s.best / 1e9;
    println!(
        "{name:<34} best {:>10}  median {:>10}  {:>7.2} Gflop/s",
        fmt_secs(s.best),
        fmt_secs(s.median),
        gflops
    );
}

fn main() {
    println!("# kernel microbenchmarks\n");

    // Dense Aᵀr — the paper's hot spot (year_like shape).
    let year = datasets::year_like(1);
    let mut c = vec![0.0; year.a.ncols()];
    let s = bench(2, 10, || {
        year.a.at_r(black_box(&year.b), &mut c);
        c[0]
    });
    report("dense at_r 16384x90", year.a.at_r_flops(), s);

    // Sparse Aᵀr (sector_like shape).
    let sector = datasets::sector_like(1);
    let mut cs = vec![0.0; sector.a.ncols()];
    let s = bench(2, 10, || {
        sector.a.at_r(black_box(&sector.b), &mut cs);
        cs[0]
    });
    report("sparse at_r sector", sector.a.at_r_flops(), s);

    // Wide sparse Aᵀr (e2006_tfidf_like shape).
    let wide = datasets::e2006_tfidf_like(1);
    let mut cw = vec![0.0; wide.a.ncols()];
    let s = bench(2, 6, || {
        wide.a.at_r(black_box(&wide.b), &mut cw);
        cw[0]
    });
    report("sparse at_r e2006_tfidf", wide.a.at_r_flops(), s);

    // Direction application A_I w at |I| = 60.
    let cols: Vec<usize> = (0..60).collect();
    let w = vec![0.1; 60];
    let mut u = vec![0.0; year.a.nrows()];
    let s = bench(2, 10, || {
        year.a.gemv_cols(black_box(&cols), &w, &mut u);
        u[0]
    });
    report("dense gemv_cols |I|=60", year.a.gemv_cols_flops(&cols), s);

    // Gram block A_Iᵀ A_B (60 × 8).
    let bcols: Vec<usize> = (60..68).collect();
    let s = bench(2, 10, || black_box(year.a.gram_block(&cols, &bcols)).get(0, 0));
    report("dense gram_block 60x8", year.a.gram_block_flops(&cols, &bcols), s);

    // Sparse gram block.
    let scols: Vec<usize> = (0..60).collect();
    let sbcols: Vec<usize> = (60..68).collect();
    let s = bench(2, 10, || black_box(sector.a.gram_block(&scols, &sbcols)).get(0, 0));
    report("sparse gram_block 60x8", sector.a.gram_block_flops(&scols, &sbcols), s);

    // Cholesky: full factor vs incremental append at dim 60.
    let mut rng = Pcg64::new(3);
    let base = DenseMatrix::from_fn(80, 60, |_, _| rng.normal());
    let all: Vec<usize> = (0..60).collect();
    let mut g = Matrix::Dense(base).gram_block(&all, &all);
    for i in 0..60 {
        g.set(i, i, g.get(i, i) + 0.1);
    }
    let s = bench(2, 20, || black_box(Cholesky::factor(&g).unwrap()).dim());
    report("cholesky factor dim=60", 60u64.pow(3) / 3, s);

    let g52 = DenseMatrix::from_fn(52, 52, |i, j| g.get(i, j));
    let gib = DenseMatrix::from_fn(52, 8, |i, j| g.get(i, 52 + j));
    let gbb = DenseMatrix::from_fn(8, 8, |i, j| g.get(52 + i, 52 + j));
    let c52 = Cholesky::factor(&g52).unwrap();
    let s = bench(2, 50, || {
        let mut ch = c52.clone();
        ch.append_block(black_box(&gib), &gbb).unwrap();
        ch.dim()
    });
    report("cholesky append 52+8", 8 * 52 * 52, s);

    // Triangular solve at dim 60.
    let full = Cholesky::factor(&g).unwrap();
    let rhs: Vec<f64> = (0..60).map(|i| (i as f64).sin()).collect();
    let s = bench(2, 100, || black_box(full.solve(&rhs))[0]);
    report("cholesky solve dim=60", 2 * 60 * 60, s);

    // Selection: top-b of |c| over n = 150k.
    let mut rng = Pcg64::new(4);
    let big: Vec<f64> = (0..150_000).map(|_| rng.normal()).collect();
    let s = bench(2, 20, || {
        calars::linalg::select::argmax_b_by(big.len(), 38, |i| black_box(big[i]).abs()).len()
    });
    report("introselect top-38 of 150k", 150_000, s);
}
