//! Batched multi-response fitting benchmark: one design matrix,
//! `--k` LARS models, batched lockstep (`FitSpec::fit_batch`) vs the
//! same k fits run sequentially — with the acceptance gates baked in:
//!
//! * a batch of ONE must be bit-identical to the single-response
//!   `FitSpec::fit` (lars and lasso), and
//! * the batched result must be bit-identical across pool thread
//!   counts 1/2/4, and
//! * the batched path must beat k-sequential by ≥2× at k=64,
//!
//! or the bench exits nonzero. `scripts/ci.sh` runs it with `--json`
//! and captures stdout as BENCH_batch.json (schema per record:
//! {bench, threads, wall_ms, speedup}).
//!
//! Run: `cargo bench --bench batch` (human table)
//!      `cargo bench --bench batch -- --json [--k N] [--m N] [--n N] [--t N]`

use calars::data::synthetic::SyntheticSpec;
use calars::data::{datasets, Dataset};
use calars::fit::{Algorithm, FitResult, FitSpec, Fitter, NoopObserver};
use calars::metrics::{bench, black_box, fmt_secs};
use calars::par::{self, ThreadPool};
use calars::rng::Pcg64;

const GATE_SPEEDUP: f64 = 2.0;

struct Record {
    bench: String,
    threads: usize,
    wall_ms: f64,
    speedup: f64,
}

/// Parse `--name N` from the raw arg list, insisting on a positive
/// value: a zero-sized batch or matrix is a usage error, not a bench.
fn positive_arg(args: &[String], name: &str, default: usize) -> usize {
    let Some(pos) = args.iter().position(|a| a == name) else {
        return default;
    };
    let value = args
        .get(pos + 1)
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(0);
    if value == 0 {
        eprintln!("usage: cargo bench --bench batch -- [--json] [--k N] [--m N] [--n N] [--t N]");
        let got = args.get(pos + 1).map_or("", |v| v.as_str());
        eprintln!("  {name} must be a positive integer (got '{got}')");
        std::process::exit(2);
    }
    value
}

fn responses(ds: &Dataset, k: usize, seed: u64) -> Vec<Vec<f64>> {
    let m = ds.a.nrows();
    let mut rng = Pcg64::new(seed);
    (0..k)
        .map(|i| {
            if i == 0 {
                ds.b.clone()
            } else {
                (0..m).map(|_| rng.normal()).collect()
            }
        })
        .collect()
}

/// Comparable identity of a fit: every output field that the lockstep
/// core produces, with the floats as raw bit patterns.
fn signature(fit: &FitResult) -> Vec<u64> {
    let out = &fit.output;
    let mut sig: Vec<u64> = vec![out.selected.len() as u64, out.cols_at_iter.len() as u64];
    sig.extend(out.selected.iter().map(|&c| c as u64));
    sig.extend(out.cols_at_iter.iter().map(|&c| c as u64));
    sig.extend(out.residual_norms.iter().map(|r| r.to_bits()));
    sig.extend(out.y.iter().map(|y| y.to_bits()));
    sig
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let json = argv.iter().any(|a| a == "--json");
    let k = positive_arg(&argv, "--k", 64);
    let m = positive_arg(&argv, "--m", 1024);
    let n = positive_arg(&argv, "--n", 2048);
    let t = positive_arg(&argv, "--t", 8);

    let spec = FitSpec::new(Algorithm::Lars).t(t);
    let lasso = FitSpec::new(Algorithm::LassoLars { lambda_min: 1e-6 }).t(t);
    let mut records: Vec<Record> = Vec::new();
    let mut failed = false;

    // ── Gate 1: a batch of one is the single-response fit, bitwise ──
    let tiny = datasets::tiny(7);
    for (label, s) in [("lars", &spec), ("lasso", &lasso)] {
        let solo = s.fit(&tiny.a, &tiny.b, &mut NoopObserver).expect("solo fit");
        let batch = s.fit_batch(&tiny.a, std::slice::from_ref(&tiny.b)).expect("k=1 batch");
        if signature(&batch.fits[0]) != signature(&solo) {
            eprintln!("DIVERGENCE: k=1 {label} batch differs from FitSpec::fit");
            failed = true;
        }
    }

    // ── Gate 2: batched output is thread-count invariant ──
    let panel = responses(&tiny, 5, 99);
    let mut base_sig: Option<Vec<Vec<u64>>> = None;
    for threads in [1usize, 2, 4] {
        let pool = ThreadPool::new(threads, 64);
        let sigs = par::with_pool(&pool, || {
            let batch = spec.fit_batch(&tiny.a, &panel).expect("batch fit");
            batch.fits.iter().map(signature).collect::<Vec<_>>()
        });
        match &base_sig {
            None => base_sig = Some(sigs),
            Some(base) => {
                if &sigs != base {
                    eprintln!("DIVERGENCE: batch output differs at threads={threads}");
                    failed = true;
                }
            }
        }
    }

    // ── Timing: batched lockstep vs k sequential fits ──
    let ds = Dataset::from_synthetic(
        "batch_bench",
        &SyntheticSpec { m, n, density: 1.0, col_skew: 0.0, k_true: 2 * t, noise: 0.05 },
        42,
    );
    let panel = responses(&ds, k, 1234);
    if !json {
        println!("# batched multi-response fitting (m={m} n={n} k={k} t={t})\n");
    }

    let batch_timing = bench(1, 3, || black_box(spec.fit_batch(&ds.a, &panel).expect("batch")));
    let seq_timing = bench(1, 2, || {
        panel
            .iter()
            .map(|b| black_box(spec.fit(&ds.a, b, &mut NoopObserver).expect("solo")))
            .count()
    });
    let speedup = seq_timing.best / batch_timing.best.max(1e-12);
    records.push(Record {
        bench: format!("batch_seq_baseline_k{k}"),
        threads: par::threads(),
        wall_ms: seq_timing.best * 1e3,
        speedup: 1.0,
    });
    records.push(Record {
        bench: format!("batch_lars_k{k}"),
        threads: par::threads(),
        wall_ms: batch_timing.best * 1e3,
        speedup,
    });
    if !json {
        println!("## batch_lars_k{k}");
        println!("  k-sequential {:>10}", fmt_secs(seq_timing.best));
        println!("  batched      {:>10}  speedup {speedup:.2}x (gate ≥{GATE_SPEEDUP:.1}x)\n");
    }

    if json {
        let body: Vec<String> = records
            .iter()
            .map(|r| {
                format!(
                    "{{\"bench\":\"{}\",\"threads\":{},\"wall_ms\":{:.3},\"speedup\":{:.3}}}",
                    r.bench, r.threads, r.wall_ms, r.speedup
                )
            })
            .collect();
        println!("[{}]", body.join(",\n "));
    }

    if speedup < GATE_SPEEDUP {
        eprintln!("batched fitting speedup {speedup:.2}x is below the {GATE_SPEEDUP:.1}x gate");
        failed = true;
    }
    if failed {
        eprintln!("batch bench gates failed");
        std::process::exit(1);
    }
}
