//! Parallel-scaling benchmark: 1→N-thread speedup of the hot kernels
//! and an end-to-end bLARS fit on the paper's synthetic workloads,
//! with bit-identity verification between thread counts baked in —
//! any divergence between parallel and serial output exits nonzero,
//! which is how `scripts/ci.sh` fails the build on a determinism
//! regression.
//!
//! Run: `cargo bench --bench parallel_scaling` (human table)
//!      `cargo bench --bench parallel_scaling -- --json` (the
//!      machine-readable records ci.sh writes to BENCH_parallel.json;
//!      schema per record: {bench, threads, wall_ms, speedup})

use calars::data::datasets;
use calars::fit::NoopObserver;
use calars::lars::serial::{self, LarsOptions};
use calars::linalg::DenseMatrix;
use calars::metrics::{bench, black_box, fmt_secs};
use calars::par::{self, ThreadPool};
use calars::rng::Pcg64;

struct Record {
    bench: &'static str,
    threads: usize,
    wall_ms: f64,
    speedup: f64,
}

/// One workload: produces a comparable output signature (f64 bit
/// patterns) and a best-of-N wall time under the given pool.
struct Outcome {
    signature: Vec<u64>,
    wall_secs: f64,
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn measure(pool: &ThreadPool, iters: usize, mut f: impl FnMut() -> Vec<f64>) -> Outcome {
    par::with_pool(pool, || {
        let signature = bits(&f());
        let timing = bench(1, iters, || black_box(f()));
        Outcome { signature, wall_secs: timing.best }
    })
}

fn main() {
    let json = std::env::args().any(|a| a == "--json");
    let cores = par::detected_cores();
    let mut counts: Vec<usize> = vec![1, 2, 4];
    if cores > 4 {
        counts.push(cores);
    }
    counts.dedup();
    let pools: Vec<ThreadPool> =
        counts.iter().map(|&t| ThreadPool::new(t, par::DEFAULT_MIN_CHUNK)).collect();
    if !json {
        println!("# parallel scaling ({cores} cores detected; threads ∈ {counts:?})\n");
    }

    // Workloads span the paper's regimes: tall-dense Aᵀr (year), sparse
    // Aᵀr (sector), dense Gram assembly, the serving batch GEMV shape,
    // and an end-to-end serial bLARS fit (γ-search + panel updates).
    let year = datasets::year_like(1);
    let sector = datasets::sector_like(1);
    let mut rng = Pcg64::new(5);
    let batch = DenseMatrix::from_fn(2048, 512, |_, _| rng.normal());
    let coefs: Vec<f64> = (0..512).map(|j| (j as f64 * 0.01).sin()).collect();
    let gram_ii: Vec<usize> = (0..60).collect();
    let gram_jj: Vec<usize> = (30..90).collect();
    // End-to-end fit through the serial bLARS core (the same
    // `fit_observed` the estimator API dispatches to, minus the
    // simulated-cluster bookkeeping, so the record measures kernel
    // scaling only and keeps its historical name/trajectory).
    let blars_opts = LarsOptions { t: 24, b: 4, ..Default::default() };

    let mut records: Vec<Record> = Vec::new();
    let mut diverged = false;
    type Workload<'a> = (&'static str, usize, Box<dyn FnMut() -> Vec<f64> + 'a>);
    let workloads: Vec<Workload> = vec![
        (
            "dense_at_r_year",
            10,
            Box::new(|| {
                let mut c = vec![0.0; year.a.ncols()];
                year.a.at_r(&year.b, &mut c);
                c
            }),
        ),
        (
            "sparse_at_r_sector",
            10,
            Box::new(|| {
                let mut c = vec![0.0; sector.a.ncols()];
                sector.a.at_r(&sector.b, &mut c);
                c
            }),
        ),
        (
            "dense_gram_60x60_year",
            8,
            Box::new(|| year.a.gram_block(&gram_ii, &gram_jj).data().to_vec()),
        ),
        (
            "serve_batch_gemv_2048x512",
            10,
            Box::new(|| {
                let mut y = vec![0.0; batch.nrows()];
                batch.gemv(&coefs, &mut y);
                y
            }),
        ),
        (
            "blars_serial_year_t24_b4",
            3,
            Box::new(|| {
                let out = serial::fit_observed(&year.a, &year.b, &blars_opts, &mut NoopObserver)
                    .expect("fit");
                let mut sig: Vec<f64> = out.selected.iter().map(|&j| j as f64).collect();
                sig.extend_from_slice(&out.residual_norms);
                sig
            }),
        ),
    ];

    for (name, iters, mut f) in workloads {
        let base = measure(&pools[0], iters, &mut f);
        records.push(Record {
            bench: name,
            threads: counts[0],
            wall_ms: base.wall_secs * 1e3,
            speedup: 1.0,
        });
        if !json {
            println!("## {name}");
            println!("  threads=1  {:>10}  (baseline)", fmt_secs(base.wall_secs));
        }
        for (pool, &threads) in pools.iter().zip(&counts).skip(1) {
            let run = measure(pool, iters, &mut f);
            if run.signature != base.signature {
                eprintln!("DIVERGENCE: {name} differs between threads=1 and threads={threads}");
                diverged = true;
            }
            let speedup = base.wall_secs / run.wall_secs.max(1e-12);
            if !json {
                println!(
                    "  threads={threads}  {:>10}  speedup {speedup:.2}x",
                    fmt_secs(run.wall_secs)
                );
            }
            records.push(Record { bench: name, threads, wall_ms: run.wall_secs * 1e3, speedup });
        }
        if !json {
            println!();
        }
    }

    if json {
        let body: Vec<String> = records
            .iter()
            .map(|r| {
                format!(
                    "{{\"bench\":\"{}\",\"threads\":{},\"wall_ms\":{:.3},\"speedup\":{:.3}}}",
                    r.bench, r.threads, r.wall_ms, r.speedup
                )
            })
            .collect();
        println!("[{}]", body.join(",\n "));
    } else {
        let best = records
            .iter()
            .filter(|r| r.threads > 1)
            .map(|r| r.speedup)
            .fold(0.0_f64, f64::max);
        println!("best multi-thread speedup: {best:.2}x");
    }

    if diverged {
        eprintln!("parallel output diverged from serial — failing the bench");
        std::process::exit(1);
    }
}
