//! Ablation benchmarks for the design choices DESIGN.md calls out.
//!
//! 1. **Hardware regime** — the α-β parameters decide who wins: on a
//!    slow network the communication-avoiding blocking pays off much
//!    more (the paper's motivating premise, §1).
//! 2. **Incremental Cholesky vs refactorization** — Alg 2 steps 20-23
//!    vs recomputing the factor each iteration.
//! 3. **Column partition policy** — nnz-balanced (paper §10) vs random
//!    partitions: load imbalance and its simulated-time cost.
//! 4. **Correlation update vs recompute** — Alg 2 step 18's O(n) update
//!    vs a fresh Aᵀr per iteration (what a naive implementation does).
//!
//! Run: `cargo bench --bench ablations`

use calars::cluster::HwParams;
use calars::data::{datasets, partition};
use calars::fit::{Algorithm, FitSpec};
use calars::linalg::{Cholesky, DenseMatrix, Matrix};
use calars::metrics::{bench, black_box, fmt_secs};
use calars::rng::Pcg64;

fn main() {
    println!("# ablation benchmarks\n");
    hw_regimes();
    cholesky_incremental();
    partition_policy();
    corr_update_vs_recompute();
}

fn hw_regimes() {
    println!("## 1. hardware regime (sector_like, t=40, P=16)");
    let ds = datasets::sector_like(1);
    let t = 40;
    for (name, hw) in [
        ("fast network (NVLink-ish)", HwParams::fast_network()),
        ("default (10GbE-ish)", HwParams::default()),
        ("slow network (WAN-ish)", HwParams::slow_network()),
    ] {
        let sim = |b: usize| {
            FitSpec::new(Algorithm::Blars { b })
                .t(t)
                .ranks(16)
                .hw(hw)
                .run(&ds.a, &ds.b)
                .expect("fit")
                .sim
                .expect("cluster telemetry")
                .sim_time
        };
        let s1 = sim(1);
        let s8 = sim(8);
        println!(
            "  {name:<28} LARS {:>10}  bLARS(b=8) {:>10}  blocking gain {:.2}x",
            fmt_secs(s1),
            fmt_secs(s8),
            s1 / s8
        );
    }
    println!("  → the slower the network, the bigger the win from blocking.\n");
}

fn cholesky_incremental() {
    println!("## 2. Cholesky: incremental append vs refactorization (t=60, b=4)");
    let mut rng = Pcg64::new(2);
    let base = DenseMatrix::from_fn(100, 60, |_, _| rng.normal());
    let all: Vec<usize> = (0..60).collect();
    let mut g = Matrix::Dense(base).gram_block(&all, &all);
    for i in 0..60 {
        g.set(i, i, g.get(i, i) + 0.1);
    }
    // Simulate a t=60, b=4 run: 15 extensions.
    let s_inc = bench(1, 10, || {
        let g4 = DenseMatrix::from_fn(4, 4, |i, j| g.get(i, j));
        let mut chol = Cholesky::factor(&g4).unwrap();
        for step in 1..15 {
            let k = step * 4;
            let gib = DenseMatrix::from_fn(k, 4, |i, j| g.get(i, k + j));
            let gbb = DenseMatrix::from_fn(4, 4, |i, j| g.get(k + i, k + j));
            chol.append_block(black_box(&gib), &gbb).unwrap();
        }
        chol.dim()
    });
    let s_re = bench(1, 10, || {
        let mut dim = 0;
        for step in 1..=15 {
            let k = step * 4;
            let gk = DenseMatrix::from_fn(k, k, |i, j| g.get(i, j));
            dim = Cholesky::factor(black_box(&gk)).unwrap().dim();
        }
        dim
    });
    println!(
        "  incremental {:>10}   refactor-each-step {:>10}   gain {:.1}x\n",
        fmt_secs(s_inc.best),
        fmt_secs(s_re.best),
        s_re.best / s_inc.best
    );
}

fn partition_policy() {
    println!("## 3. column partition policy (e2006_tfidf_like, T-bLARS P=16 b=4, t=30)");
    let ds = datasets::e2006_tfidf_like(1);
    let t = 30;
    let balanced = partition::balanced_col_partition(&ds.a, 16);
    let mut rng = Pcg64::new(3);
    let random = partition::random_col_partition(ds.a.ncols(), 16, &mut rng);
    // partition_seed mirrors the explicit constructions above: None =
    // the same nnz-balanced partition, Some(3) = the same Pcg64(3)
    // random partition the imbalance is computed for.
    for (name, parts, seed) in
        [("nnz-balanced", &balanced, None), ("random", &random, Some(3u64))]
    {
        let imb = partition::partition_imbalance(&ds.a, parts);
        let sim = FitSpec::new(Algorithm::TBlars { b: 4, parts: 16 })
            .t(t)
            .partition_seed(seed)
            .run(&ds.a, &ds.b)
            .expect("fit")
            .sim
            .expect("cluster telemetry");
        println!(
            "  {name:<14} imbalance {imb:.3}   sim time {:>10}",
            fmt_secs(sim.sim_time)
        );
    }
    println!("  → balancing by nnz keeps the leaf superstep critical path tight.\n");
}

fn corr_update_vs_recompute() {
    println!("## 4. correlation update (step 18) vs fresh Aᵀr per iteration");
    let ds = datasets::e2006_tfidf_like(1);
    let n = ds.a.ncols();
    let mut c = vec![0.0; n];
    // Fresh recompute.
    let s_re = bench(1, 5, || {
        ds.a.at_r(black_box(&ds.b), &mut c);
        c[0]
    });
    // In-place update (what Alg 2 does): O(n).
    let av = vec![0.5; n];
    let mut cc = vec![1.0; n];
    let s_up = bench(1, 5, || {
        for j in 0..n {
            cc[j] -= 0.01 * av[j];
        }
        cc[0]
    });
    println!(
        "  recompute {:>10}   update {:>10}   gain {:.0}x (the nnz/n ratio)\n",
        fmt_secs(s_re.best),
        fmt_secs(s_up.best),
        s_re.best / s_up.best
    );
}
