//! End-to-end algorithm benchmarks: wallclock of each method per
//! dataset at representative (P, b), plus XLA-vs-native kernel timing.
//!
//! Run: `cargo bench --bench lars_end_to_end`

use calars::cluster::{ExecMode, HwParams, SimCluster};
use calars::data::{datasets, partition};
use calars::lars::blars::{blars, BlarsOptions};
use calars::lars::serial::{lars, LarsOptions};
use calars::lars::tblars::{tblars, TblarsOptions};
use calars::linalg::Matrix;
use calars::metrics::{bench, fmt_secs};
use calars::runtime::{default_artifacts_dir, XlaRuntime};

fn main() {
    println!("# end-to-end algorithm benchmarks\n");
    let t = 40;

    for ds in [datasets::sector_like(1), datasets::year_like(1), datasets::e2006_tfidf_like(1)] {
        let t = t.min(ds.a.nrows().min(ds.a.ncols()) / 2);
        println!("## {} (t = {t})", ds.name);

        let s = bench(1, 3, || {
            lars(&ds.a, &ds.b, &LarsOptions { t, ..Default::default() }).selected.len()
        });
        println!("  serial LARS           best {:>10}", fmt_secs(s.best));

        for (p, b) in [(8usize, 1usize), (8, 4)] {
            let s = bench(1, 3, || {
                let mut c = SimCluster::new(p, HwParams::default(), ExecMode::Sequential);
                blars(&ds.a, &ds.b, &BlarsOptions { t, b, ..Default::default() }, &mut c)
                    .selected
                    .len()
            });
            println!("  bLARS   P={p} b={b}       best {:>10}", fmt_secs(s.best));
        }
        for (p, b) in [(8usize, 4usize)] {
            let parts = partition::balanced_col_partition(&ds.a, p);
            let s = bench(1, 3, || {
                let mut c = SimCluster::new(p, HwParams::default(), ExecMode::Sequential);
                tblars(&ds.a, &ds.b, &parts, &TblarsOptions { t, b, ..Default::default() }, &mut c)
                    .selected
                    .len()
            });
            println!("  T-bLARS P={p} b={b}       best {:>10}", fmt_secs(s.best));
        }
        println!();
    }

    // XLA vs native correlation kernel (the runtime hot path).
    match XlaRuntime::load(&default_artifacts_dir()) {
        Ok(rt) => {
            let year = datasets::year_like(1);
            let Matrix::Dense(dense) = &year.a else { unreachable!() };
            let session = rt.prepare_corr(dense.nrows(), dense.ncols(), dense.data()).unwrap();
            let s = bench(2, 10, || session.corr(&year.b).unwrap()[0]);
            println!("## runtime corr (16384x90, bucket 16384x96)");
            println!("  XLA/PJRT              best {:>10}", fmt_secs(s.best));
            let mut c = vec![0.0; year.a.ncols()];
            let s = bench(2, 10, || {
                year.a.at_r(&year.b, &mut c);
                c[0]
            });
            println!("  native f64            best {:>10}", fmt_secs(s.best));
        }
        Err(e) => println!("## runtime corr: skipped ({e})"),
    }
}
