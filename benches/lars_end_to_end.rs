//! End-to-end algorithm benchmarks: wallclock of each method per
//! dataset at representative (P, b) — all through the unified
//! `calars::fit` estimator API — plus XLA-vs-native kernel timing.
//!
//! Run: `cargo bench --bench lars_end_to_end`

use calars::data::datasets;
use calars::fit::{Algorithm, FitSpec};
use calars::linalg::Matrix;
use calars::metrics::{bench, fmt_secs};
use calars::runtime::{default_artifacts_dir, XlaRuntime};

fn main() {
    println!("# end-to-end algorithm benchmarks\n");
    let t = 40;

    for ds in [datasets::sector_like(1), datasets::year_like(1), datasets::e2006_tfidf_like(1)] {
        let t = t.min(ds.a.nrows().min(ds.a.ncols()) / 2);
        println!("## {} (t = {t})", ds.name);

        let lars_spec = FitSpec::new(Algorithm::Lars).t(t);
        let s = bench(1, 3, || {
            lars_spec.run(&ds.a, &ds.b).expect("fit").output.selected.len()
        });
        println!("  serial LARS           best {:>10}", fmt_secs(s.best));

        for (p, b) in [(8usize, 1usize), (8, 4)] {
            let spec = FitSpec::new(Algorithm::Blars { b }).t(t).ranks(p);
            let s = bench(1, 3, || {
                spec.run(&ds.a, &ds.b).expect("fit").output.selected.len()
            });
            println!("  bLARS   P={p} b={b}       best {:>10}", fmt_secs(s.best));
        }
        for (p, b) in [(8usize, 4usize)] {
            let spec = FitSpec::new(Algorithm::TBlars { b, parts: p }).t(t);
            let s = bench(1, 3, || {
                spec.run(&ds.a, &ds.b).expect("fit").output.selected.len()
            });
            println!("  T-bLARS P={p} b={b}       best {:>10}", fmt_secs(s.best));
        }
        println!();
    }

    // XLA vs native correlation kernel (the runtime hot path).
    match XlaRuntime::load(&default_artifacts_dir()) {
        Ok(rt) => {
            let year = datasets::year_like(1);
            let Matrix::Dense(dense) = &year.a else { unreachable!() };
            let session = rt.prepare_corr(dense.nrows(), dense.ncols(), dense.data()).unwrap();
            let s = bench(2, 10, || session.corr(&year.b).unwrap()[0]);
            println!("## runtime corr (16384x90, bucket 16384x96)");
            println!("  XLA/PJRT              best {:>10}", fmt_secs(s.best));
            let mut c = vec![0.0; year.a.ncols()];
            let s = bench(2, 10, || {
                year.a.at_r(&year.b, &mut c);
                c[0]
            });
            println!("  native f64            best {:>10}", fmt_secs(s.best));
        }
        Err(e) => println!("## runtime corr: skipped ({e})"),
    }
}
