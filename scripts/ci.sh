#!/usr/bin/env bash
# CI for calars: format check, release build, test suite, the
# calars-audit static-analysis pass (determinism / panic-safety /
# unsafe-budget / zero-dep contracts, warnings denied), rustdoc with
# warnings denied, all five examples built AND executed, perf stage
# (parallel-scaling + batched-fitting benches + serving smoke, all in
# JSON mode, recorded as BENCH_parallel.json / BENCH_batch.json /
# BENCH_serving.json), a live
# serve → fit → predict → shutdown smoke cycle, and an observability
# stage that benches serving with tracing off vs on and gates the p50
# overhead at ≤ 5% (BENCH_obs.json) — README §CI.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== fmt =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "rustfmt unavailable — skipping format check"
fi

echo "== build (release) =="
cargo build --release

echo "== tests =="
cargo test -q

echo "== audit (determinism / panic-safety / unsafe-budget / zero-dep gates) =="
# calars-audit walks rust/src, rust/tests and benches with the in-tree
# lexer + rule engine; --deny-warnings also fails on stale allow
# markers, so every suppression in the tree stays load-bearing.
target/release/calars audit --deny-warnings

echo "== docs (rustdoc warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== examples (build + run all five) =="
cargo build --release --examples
for ex in quickstart lasso_path compressed_sensing wide_selection end_to_end; do
    echo "-- example: $ex"
    cargo run --release --quiet --example "$ex" >/dev/null
done

BIN=target/release/calars

# Require the perf-schema keys in a bench JSON file. An empty file is
# its own loud failure: a bench stage that silently produced no records
# must never read as "gate passed".
check_bench_json() {
    local file=$1
    if [ ! -s "$file" ]; then
        echo "$file is empty — the bench stage produced no JSON records"; exit 1
    fi
    for key in '"bench"' '"threads"' '"wall_ms"' '"speedup"'; do
        grep -q "$key" "$file" || { echo "$file missing $key:"; cat "$file"; exit 1; }
    done
    echo "$file OK"
}

echo "== perf: machine shape =="
"$BIN" info --json

echo "== perf: kernel engine (kern vs scalar reference, SIMD vs scalar backend) =="
# The bench compares every blocked kern kernel against kern::reference
# and exits nonzero if max |Δ| exceeds 1e-9 — the numerics gate — while
# the JSON records the old-scalar → kern speedup trajectory plus the
# per-ISA backend records (`…_scalar` / `…_<isa>`).
cargo bench --bench kernels -- --json > BENCH_kernels.json
check_bench_json BENCH_kernels.json
# Perf gate 1: the hot kernels must beat the scalar reference by ≥ 1.5×
# on the 2000×4000 problems. Exact record names (closing quote included)
# so the per-ISA `…_scalar` / `…_avx2` records don't dilute this gate.
awk '
/"bench":"at_r_2000x4000"/ || /"bench":"gram_block_2000x4000_64x64"/ {
    if (match($0, /"speedup":[0-9.]+/)) {
        s = substr($0, RSTART + 10, RLENGTH - 10) + 0
        if (s < 1.5) { printf "kernel speedup gate: %s < 1.5x\n", s; bad = 1 }
        found += 1
    }
}
END {
    if (found < 2) { print "kernel speedup gate: records missing"; exit 1 }
    exit bad
}' BENCH_kernels.json
# Perf gate 2: on a host with a vector ISA, the SIMD backend must beat
# the forced-scalar backend by ≥ 2× on at_r and gram_block. Zero
# matching records means the host detected no vector ISA (scalar-only):
# the gate passes vacuously — the bench itself still recorded the
# `…_scalar` rows, so the stage cannot go dark.
awk '
/"bench":"(at_r_2000x4000|gram_block_2000x4000_64x64)_(avx2|avx512|neon)"/ {
    if (match($0, /"speedup":[0-9.]+/)) {
        s = substr($0, RSTART + 10, RLENGTH - 10) + 0
        if (s < 2.0) { printf "simd backend speedup gate: %s < 2.0x\n", s; bad = 1 }
        found += 1
    }
}
END {
    if (found > 0) { printf "simd backend gate: %d vector record(s) checked\n", found }
    else { print "simd backend gate: no vector ISA detected — scalar-only host, gate passes" }
    exit bad
}' BENCH_kernels.json

echo "== perf: parallel scaling =="
# The bench itself verifies parallel output is bit-identical to serial
# and exits nonzero on divergence, so this line both records the perf
# trajectory and gates determinism.
cargo bench --bench parallel_scaling -- --json > BENCH_parallel.json
check_bench_json BENCH_parallel.json

echo "== perf: model selection =="
# The selection bench runs k-fold CV under thread pools of 1/2/4 (and
# all cores) and exits nonzero unless the CV-selected step — and every
# score bit — is identical at every thread count: the model-selection
# determinism gate.
cargo bench --bench selection -- --json > BENCH_select.json
check_bench_json BENCH_select.json

echo "== perf: batched multi-response fitting =="
# The batch bench self-gates bit-identity (k=1 batch vs single fit,
# plus thread-count invariance) and exits nonzero on divergence; the
# awk gate below enforces the shared-work payoff: batched lockstep must
# beat k sequential fits by ≥ 2× at k=64.
cargo bench --bench batch -- --json > BENCH_batch.json
check_bench_json BENCH_batch.json
awk '
/"bench":"batch_lars_k64"/ {
    if (match($0, /"speedup":[0-9.]+/)) {
        s = substr($0, RSTART + 10, RLENGTH - 10) + 0
        if (s < 2.0) { printf "batch speedup gate: %s < 2.0x\n", s; bad = 1 }
        found += 1
    }
}
END {
    if (found < 1) { print "batch speedup gate: batch_lars_k64 record missing"; exit 1 }
    exit bad
}' BENCH_batch.json

echo "== serving smoke + perf =="
PORT="${CALARS_SMOKE_PORT:-17878}"
LOG="$(mktemp)"
"$BIN" serve --port "$PORT" --oneshot --prefit tiny >"$LOG" 2>&1 &
SERVER_PID=$!
BENCH_PID=""
# Reap BOTH the server and any still-running bench client on exit, so a
# hung bench-serve can never leak the smoke server (or itself).
trap 'kill "$SERVER_PID" 2>/dev/null || true
      [ -n "$BENCH_PID" ] && kill "$BENCH_PID" 2>/dev/null || true' EXIT

# Wait for the listener (prefit runs before accept).
for _ in $(seq 1 100); do
    if grep -q "listening on" "$LOG"; then break; fi
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "server died during startup:"; cat "$LOG"; exit 1
    fi
    sleep 0.1
done
grep -q "listening on" "$LOG" || { echo "server never started:"; cat "$LOG"; exit 1; }

# One full request/response cycle through the batched prediction path,
# recorded as a JSON perf record, then ask the --oneshot server to
# exit. The client runs in the background under a hard 120s deadline —
# coreutils timeout when available, a pure-bash watchdog otherwise —
# so a hang fails CI instead of wedging it.
SMOKE_CMD=("$BIN" bench-serve --addr "127.0.0.1:$PORT" --requests 50 \
           --concurrency 4 --rows 4 --json --shutdown)
WATCHDOG_PID=""
if command -v timeout >/dev/null 2>&1; then
    timeout 120 "${SMOKE_CMD[@]}" > BENCH_serving.json &
    BENCH_PID=$!
else
    "${SMOKE_CMD[@]}" > BENCH_serving.json &
    BENCH_PID=$!
    ( sleep 120; kill "$BENCH_PID" 2>/dev/null ) &
    WATCHDOG_PID=$!
fi
if ! wait "$BENCH_PID"; then
    echo "bench-serve failed or timed out"; cat BENCH_serving.json; exit 1
fi
BENCH_PID=""
[ -n "$WATCHDOG_PID" ] && kill "$WATCHDOG_PID" 2>/dev/null || true
check_bench_json BENCH_serving.json

# Bounded wait for the --oneshot server to exit after /shutdown (an
# unbounded `wait` here could hang CI on a shutdown bug).
for _ in $(seq 1 100); do
    kill -0 "$SERVER_PID" 2>/dev/null || break
    sleep 0.1
done
if kill -0 "$SERVER_PID" 2>/dev/null; then
    echo "server did not exit after shutdown"; exit 1
fi
if ! wait "$SERVER_PID"; then
    echo "server exited nonzero:"; cat "$LOG"; exit 1
fi
trap - EXIT

echo "== perf: observability overhead (tracing off vs on) =="
# Two identical bench-serve runs against fresh --oneshot servers, one
# with CALARS_TRACE=off and one with tracing on (the default). The
# recorded p50 ratio gates the calars::obs promise: spans + metrics
# cost ≤ 5% at the median. A 0.5 ms absolute floor on both sides keeps
# sub-millisecond scheduler jitter from failing the gate spuriously on
# a fast machine.
OBS_PORT=$((PORT + 1))
for MODE in off on; do
    LOG="$(mktemp)"
    CALARS_TRACE="$MODE" "$BIN" serve --port "$OBS_PORT" --oneshot --prefit tiny >"$LOG" 2>&1 &
    SERVER_PID=$!
    BENCH_PID=""
    trap 'kill "$SERVER_PID" 2>/dev/null || true
          [ -n "$BENCH_PID" ] && kill "$BENCH_PID" 2>/dev/null || true' EXIT
    for _ in $(seq 1 100); do
        if grep -q "listening on" "$LOG"; then break; fi
        if ! kill -0 "$SERVER_PID" 2>/dev/null; then
            echo "obs server (trace=$MODE) died during startup:"; cat "$LOG"; exit 1
        fi
        sleep 0.1
    done
    grep -q "listening on" "$LOG" || { echo "obs server (trace=$MODE) never started:"; cat "$LOG"; exit 1; }
    OBS_CMD=("$BIN" bench-serve --addr "127.0.0.1:$OBS_PORT" --requests 200 \
             --concurrency 4 --rows 4 --json --shutdown)
    if command -v timeout >/dev/null 2>&1; then
        timeout 120 "${OBS_CMD[@]}" > "BENCH_obs_$MODE.json" &
        BENCH_PID=$!
    else
        "${OBS_CMD[@]}" > "BENCH_obs_$MODE.json" &
        BENCH_PID=$!
    fi
    if ! wait "$BENCH_PID"; then
        echo "obs bench (trace=$MODE) failed or timed out"; cat "BENCH_obs_$MODE.json"; exit 1
    fi
    BENCH_PID=""
    for _ in $(seq 1 100); do
        kill -0 "$SERVER_PID" 2>/dev/null || break
        sleep 0.1
    done
    kill "$SERVER_PID" 2>/dev/null || true
    wait "$SERVER_PID" 2>/dev/null || true
    trap - EXIT
done

p50_of() { awk 'match($0, /"p50_ms":[0-9.]+/)  { print substr($0, RSTART + 9,  RLENGTH - 9);  exit }' "$1"; }
P50_OFF=$(p50_of BENCH_obs_off.json)
P50_ON=$(p50_of BENCH_obs_on.json)
WALL_ON=$(awk 'match($0, /"wall_ms":[0-9.]+/) { print substr($0, RSTART + 10, RLENGTH - 10); exit }' BENCH_obs_on.json)
OBS_THREADS=$(awk 'match($0, /"threads":[0-9]+/) { print substr($0, RSTART + 10, RLENGTH - 10); exit }' BENCH_obs_on.json)
if [ -z "$P50_OFF" ] || [ -z "$P50_ON" ]; then
    echo "obs bench records lack a finite p50_ms (all requests errored?):"
    cat BENCH_obs_off.json BENCH_obs_on.json
    exit 1
fi
# speedup = off/on (≥ ~0.95 when the ≤5% overhead promise holds);
# overhead_ratio = on/off is the gated quantity.
RATIO=$(awk -v off="$P50_OFF" -v on="$P50_ON" 'BEGIN { printf "%.4f", (on + 0.5) / (off + 0.5) }')
OBS_SPEEDUP=$(awk -v off="$P50_OFF" -v on="$P50_ON" 'BEGIN { printf "%.4f", (off + 0.5) / (on + 0.5) }')
printf '{"bench":"serve_trace_overhead","threads":%s,"wall_ms":%s,"speedup":%s,"p50_off_ms":%s,"p50_on_ms":%s,"overhead_ratio":%s}\n' \
    "${OBS_THREADS:-0}" "${WALL_ON:-0}" "$OBS_SPEEDUP" "$P50_OFF" "$P50_ON" "$RATIO" > BENCH_obs.json
check_bench_json BENCH_obs.json
echo "obs overhead: p50 ${P50_OFF}ms (off) vs ${P50_ON}ms (on) — ratio $RATIO"
awk -v r="$RATIO" 'BEGIN {
    if (r > 1.05) { printf "obs overhead gate: p50 on/off ratio %.4f > 1.05\n", r; exit 1 }
}'

echo "== ci OK =="
