#!/usr/bin/env bash
# CI for calars: format check, release build, test suite, then a live
# serve → fit → predict → shutdown smoke cycle (README §CI).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== fmt =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "rustfmt unavailable — skipping format check"
fi

echo "== build (release) =="
cargo build --release

echo "== tests =="
cargo test -q

echo "== serving smoke =="
BIN=target/release/calars
PORT="${CALARS_SMOKE_PORT:-17878}"
LOG="$(mktemp)"
"$BIN" serve --port "$PORT" --oneshot --prefit tiny >"$LOG" 2>&1 &
SERVER_PID=$!
trap 'kill "$SERVER_PID" 2>/dev/null || true' EXIT

# Wait for the listener (prefit runs before accept).
for _ in $(seq 1 100); do
    if grep -q "listening on" "$LOG"; then break; fi
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "server died during startup:"; cat "$LOG"; exit 1
    fi
    sleep 0.1
done
grep -q "listening on" "$LOG" || { echo "server never started:"; cat "$LOG"; exit 1; }

# One full request/response cycle through the batched prediction path,
# then ask the --oneshot server to exit.
"$BIN" bench-serve --addr "127.0.0.1:$PORT" --requests 50 --concurrency 4 --rows 4 --shutdown

wait "$SERVER_PID"
trap - EXIT
echo "== ci OK =="
