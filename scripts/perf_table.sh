#!/usr/bin/env bash
# Render BENCH_kernels.json (scripts/ci.sh perf stage, or
# `cargo bench --bench kernels -- --json`) as the README's markdown
# perf table.
#
# Usage: scripts/perf_table.sh [BENCH_kernels.json]
set -euo pipefail
FILE="${1:-BENCH_kernels.json}"
[ -f "$FILE" ] || { echo "usage: $0 [BENCH_kernels.json]" >&2; exit 1; }

echo "| bench | kern wall (ms) | speedup vs scalar |"
echo "|---|---:|---:|"
awk '
/"bench":/ {
    name = ""; wall = ""; sp = ""
    if (match($0, /"bench":"[^"]+"/))    name = substr($0, RSTART + 9, RLENGTH - 10)
    if (match($0, /"wall_ms":[0-9.]+/))  wall = substr($0, RSTART + 10, RLENGTH - 10)
    if (match($0, /"speedup":[0-9.]+/))  sp   = substr($0, RSTART + 10, RLENGTH - 10)
    if (name != "") printf "| `%s` | %.3f | %.2fx |\n", name, wall, sp
}' "$FILE"
