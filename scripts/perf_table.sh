#!/usr/bin/env bash
# Render bench JSON records (scripts/ci.sh perf stages, or any
# `cargo bench --bench <kernels|selection|parallel_scaling> -- --json`
# output) as the README's markdown perf table — one pass over every
# file, so the README table regenerates from all BENCH_*.json at once.
#
# Records carrying an "isa" field (the per-backend kernel records) get
# a populated backend column; `…_<isa>` rows read as kernel × ISA with
# speedup-vs-forced-scalar. Records without the field render "-".
#
# Usage: scripts/perf_table.sh [BENCH_*.json ...]
#        (no args: every BENCH_*.json in the working directory)
set -euo pipefail

FILES=("$@")
if [ ${#FILES[@]} -eq 0 ]; then
    for f in BENCH_kernels.json BENCH_select.json BENCH_batch.json BENCH_parallel.json BENCH_serving.json BENCH_obs.json; do
        [ -f "$f" ] && FILES+=("$f")
    done
fi
[ ${#FILES[@]} -gt 0 ] || { echo "usage: $0 [BENCH_*.json ...]" >&2; exit 1; }

echo "| source | bench | isa | threads | wall (ms) | speedup |"
echo "|---|---|---|---:|---:|---:|"
for FILE in "${FILES[@]}"; do
    [ -f "$FILE" ] || { echo "missing $FILE" >&2; exit 1; }
    awk -v src="$(basename "$FILE" .json | sed 's/^BENCH_//')" '
/"bench":/ {
    n = split($0, parts, /\},[ \t]*/)
    for (i = 1; i <= n; i++) {
        rec = parts[i]
        name = ""; thr = ""; wall = ""; sp = ""; isa = ""
        if (match(rec, /"bench":"[^"]+"/))   name = substr(rec, RSTART + 9, RLENGTH - 10)
        if (match(rec, /"threads":[0-9]+/))  thr  = substr(rec, RSTART + 10, RLENGTH - 10)
        if (match(rec, /"wall_ms":[0-9.]+/)) wall = substr(rec, RSTART + 10, RLENGTH - 10)
        if (match(rec, /"speedup":[0-9.]+/)) sp   = substr(rec, RSTART + 10, RLENGTH - 10)
        if (match(rec, /"isa":"[^"]+"/))     isa  = substr(rec, RSTART + 7, RLENGTH - 8)
        if (thr == "") thr = "-"
        if (isa == "") isa = "-"
        # json_f64 emits null for NaN/inf (e.g. a fully-errored bench
        # run): surface it as n/a, never as a plausible-looking 0.000.
        wallout = (wall == "") ? "n/a" : sprintf("%.3f", wall)
        spout   = (sp == "")   ? "n/a" : sprintf("%.2fx", sp)
        if (name != "")
            printf "| %s | `%s` | %s | %s | %s | %s |\n", src, name, isa, thr, wallout, spout
    }
}' "$FILE"
done
