//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Exercises every layer in one run (recorded in EXPERIMENTS.md):
//!
//! 1. **Runtime bridge** — load the AOT artifacts (JAX+Pallas → HLO
//!    text), execute the `corr` and `gstep` kernels via PJRT, verify
//!    parity against the native f64 kernels on the year-like dataset.
//!    Skipped gracefully when the artifacts are absent (CI runs this
//!    example without `make artifacts`).
//! 2. **Coordinator** — run the paper's three algorithms on all four
//!    scaled datasets through the `calars::fit` estimator API,
//!    reporting quality (residual, precision) and the simulated
//!    parallel cost (time, words, messages).
//! 3. **Headline check** — reproduce the paper's §10 summary numbers:
//!    bLARS speedup at (P=4, b≈38) and T-bLARS quality at (P=64, b=2)
//!    on the n ≫ m dataset.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use calars::data::datasets;
use calars::fit::{Algorithm, FitSpec, SimReport};
use calars::lars::quality::precision;
use calars::lars::LarsOutput;
use calars::linalg::Matrix;
use calars::metrics::{fmt_count, fmt_secs};
use calars::runtime::{default_artifacts_dir, XlaRuntime};

/// Layer 1+2: only runs when the AOT artifacts exist.
fn runtime_bridge() {
    println!("=== Layer 1+2: AOT artifacts via PJRT ===");
    let rt = match XlaRuntime::load(&default_artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            println!("artifacts unavailable ({e}); skipping the runtime layer");
            println!("(run `make artifacts` to exercise the PJRT path)");
            return;
        }
    };
    println!("platform: {}, artifacts: {}", rt.platform(), rt.manifest().len());

    let year = datasets::year_like(42);
    let Matrix::Dense(dense) = &year.a else { unreachable!() };
    let t0 = std::time::Instant::now();
    let session = rt
        .prepare_corr(dense.nrows(), dense.ncols(), dense.data())
        .expect("year_like must fit the 16384x96 bucket");
    println!(
        "prepared corr session for {}x{} (bucket {:?}) in {}",
        dense.nrows(),
        dense.ncols(),
        session.bucket(),
        fmt_secs(t0.elapsed().as_secs_f64())
    );
    let t0 = std::time::Instant::now();
    let c_xla = session.corr(&year.b).expect("XLA corr");
    let xla_dt = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let mut c_nat = vec![0.0; year.a.ncols()];
    year.a.at_r(&year.b, &mut c_nat);
    let nat_dt = t0.elapsed().as_secs_f64();
    let scale = c_nat.iter().fold(1.0_f64, |a, &x| a.max(x.abs()));
    let err = c_xla
        .iter()
        .zip(&c_nat)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0_f64, f64::max);
    println!(
        "corr parity on year_like: max err {err:.2e} (scale {scale:.1}); xla {} vs native {}",
        fmt_secs(xla_dt),
        fmt_secs(nat_dt)
    );
    assert!(err < 1e-3 * scale, "XLA/native divergence");

    // Fused gstep (Aᵀu + γ candidates) — a full Alg-2 inner step offloaded.
    let gsession = rt
        .prepare_gstep(dense.nrows(), dense.ncols(), dense.data())
        .expect("gstep bucket");
    let j0 = c_nat
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.abs().total_cmp(&b.1.abs()))
        .map(|(j, _)| j)
        .unwrap();
    let mut u = vec![0.0; dense.nrows()];
    year.a.gemv_cols(&[j0], &[c_nat[j0].signum()], &mut u);
    let ck = c_nat[j0].abs();
    let mut mask = vec![false; year.a.ncols()];
    mask[j0] = true;
    let t0 = std::time::Instant::now();
    let (_av, gammas) = gsession.gstep(&u, &c_nat, &mask, ck, 1.0 / ck).expect("gstep");
    let jstar = gammas
        .iter()
        .enumerate()
        .filter(|(_, g)| g.is_finite())
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(j, _)| j)
        .unwrap();
    println!(
        "gstep on year_like: entering column {jstar} at γ = {:.4} ({})",
        gammas[jstar],
        fmt_secs(t0.elapsed().as_secs_f64())
    );
}

fn fit_sim(spec: FitSpec, ds: &calars::data::Dataset) -> (LarsOutput, SimReport) {
    let result = spec.run(&ds.a, &ds.b).expect("valid spec");
    let sim = result.sim.expect("cluster fitters report telemetry");
    (result.output, sim)
}

fn main() {
    runtime_bridge();

    println!("\n=== Layer 3: coordinator on the full paper suite ===");
    let t = 60;
    println!(
        "{:<22} {:<14} {:>9} {:>10} {:>10} {:>9} {:>8}",
        "dataset", "method", "precision", "residual", "sim time", "words", "msgs"
    );
    for ds in datasets::paper_suite(42) {
        let t = t.min(ds.a.nrows().min(ds.a.ncols()) / 2);
        let reference = FitSpec::new(Algorithm::Lars)
            .t(t)
            .run(&ds.a, &ds.b)
            .expect("fit")
            .output;
        let rows = vec![
            (
                "bLARS P=16 b=4".to_string(),
                fit_sim(FitSpec::new(Algorithm::Blars { b: 4 }).t(t).ranks(16), &ds),
            ),
            (
                "T-bLARS P=16 b=4".to_string(),
                fit_sim(FitSpec::new(Algorithm::TBlars { b: 4, parts: 16 }).t(t), &ds),
            ),
        ];
        for (name, (out, sim)) in rows {
            println!(
                "{:<22} {:<14} {:>9.2} {:>10.4} {:>10} {:>9} {:>8}",
                ds.name,
                name,
                precision(&out.selected, &reference.selected),
                out.residual_norms.last().unwrap(),
                fmt_secs(sim.sim_time),
                fmt_count(sim.counters.words),
                fmt_count(sim.counters.msgs)
            );
        }
    }

    println!("\n=== Headline checks (paper §10.2, e2006_log1p regime) ===");
    let ds = datasets::e2006_log1p_like(42);
    let t = 60;
    let reference = FitSpec::new(Algorithm::Lars)
        .t(t)
        .run(&ds.a, &ds.b)
        .expect("fit")
        .output;

    // Baseline: parallel LARS (P=1, b=1).
    let (_, base_sim) = fit_sim(FitSpec::new(Algorithm::Blars { b: 1 }).t(t).ranks(1), &ds);
    let base = base_sim.sim_time;

    // Paper: bLARS (P=4, b=38) ⇒ big speedup, low precision.
    let (o1, sim1) = fit_sim(FitSpec::new(Algorithm::Blars { b: 38 }).t(t).ranks(4), &ds);
    println!(
        "bLARS   P=4  b=38: speedup {:>5.1}x  precision {:.2}   (paper: ~27x, ~0.30)",
        base / sim1.sim_time,
        precision(&o1.selected, &reference.selected)
    );

    // Paper: T-bLARS (P=64, b=2) ⇒ ~4x speedup at 100% precision.
    let (o2, sim2) = fit_sim(FitSpec::new(Algorithm::TBlars { b: 2, parts: 64 }).t(t), &ds);
    println!(
        "T-bLARS P=64 b=2 : speedup {:>5.1}x  precision {:.2}   (paper: ~4x, 1.00)",
        base / sim2.sim_time,
        precision(&o2.selected, &reference.selected)
    );

    println!("\nend_to_end OK");
}
