//! Compressed-sensing recovery with parallel bLARS (paper §1/§2: the
//! signal-processing motivation [4]).
//!
//! Recover a k-sparse signal x from m ≪ n random measurements b = Ax:
//! the classic underdetermined regime where greedy path algorithms
//! shine. Compares LARS, bLARS (several b), OMP and LASSO-CD on
//! recovery quality and (simulated) parallel cost — every fitter
//! through the one `calars::fit` estimator call path.
//!
//! ```bash
//! cargo run --release --example compressed_sensing
//! ```

use calars::baselines::lasso_cd::{lambda_max, lasso_cd};
use calars::data::synthetic::{generate, SyntheticSpec};
use calars::fit::{Algorithm, FitSpec};
use calars::lars::quality::recall;
use calars::metrics::fmt_secs;

fn main() {
    // 4x underdetermined: n = 4m, k-sparse ground truth.
    let spec = SyntheticSpec {
        m: 256,
        n: 1024,
        density: 1.0, // dense Gaussian sensing matrix
        col_skew: 0.0,
        k_true: 20,
        noise: 0.01,
    };
    let s = generate(&spec, 7);
    let truth = &s.true_support;
    let t = 20;
    println!("compressed sensing: m={} n={} k={}", spec.m, spec.n, spec.k_true);
    println!("{:-<72}", "");

    // Serial LARS.
    let la = FitSpec::new(Algorithm::Lars).t(t).run(&s.a, &s.b).expect("fit");
    println!(
        "LARS       : recall {:.2}  residual {:.4}",
        recall(&la.output.selected, truth),
        la.output.residual_norms.last().unwrap()
    );

    // Parallel bLARS across block sizes: same recovery, b-fold fewer
    // synchronizations (the paper's headline trade).
    for b in [1usize, 2, 4, 10] {
        let result = FitSpec::new(Algorithm::Blars { b })
            .t(t)
            .ranks(8)
            .run(&s.a, &s.b)
            .expect("fit");
        let sim = result.sim.as_ref().expect("cluster telemetry");
        println!(
            "bLARS b={b:<3}: recall {:.2}  residual {:.4}  sim {}  msgs {}",
            recall(&result.output.selected, truth),
            result.output.residual_norms.last().unwrap(),
            fmt_secs(sim.sim_time),
            sim.counters.msgs
        );
    }

    // Baselines, same call path.
    let om = FitSpec::new(Algorithm::Omp).t(t).run(&s.a, &s.b).expect("fit");
    println!(
        "OMP        : recall {:.2}  residual {:.4}",
        recall(&om.output.selected, truth),
        om.output.residual_norms.last().unwrap()
    );
    let lam = lambda_max(&s.a, &s.b) * 0.1;
    let lc = lasso_cd(&s.a, &s.b, lam, 500, 1e-10);
    println!(
        "LASSO-CD   : recall {:.2}  residual {:.4}  support {} (λ = 0.1·λmax)",
        recall(&lc.support, truth),
        lc.residual_norm,
        lc.support.len()
    );
    println!("{:-<72}", "");
    println!("note: bLARS trades a little selection fidelity for b-fold fewer");
    println!("messages — Table 2's claim, visible in the msgs column above.");
}
