//! The LASSO-path equivalence (paper §2; Efron et al., Theorem 1).
//!
//! LARS with the drop modification traces the *exact* ℓ1-regularization
//! path; this example computes it through the `calars::fit` estimator
//! API (`Algorithm::LassoLars`) on a correlated design (drops do
//! happen) and cross-checks interior solutions against the
//! coordinate-descent LASSO solver — two entirely different algorithms
//! agreeing to 1e-5.
//!
//! ```bash
//! cargo run --release --example lasso_path
//! ```

use calars::baselines::lasso_cd::{lambda_max, lasso_cd};
use calars::data::synthetic::{generate, SyntheticSpec};
use calars::fit::{Algorithm, FitSpec};
use calars::linalg::norm_inf;

fn main() {
    let s = generate(
        &SyntheticSpec { m: 120, n: 80, density: 1.0, col_skew: 0.0, k_true: 10, noise: 0.1 },
        2024,
    );
    let result = FitSpec::new(Algorithm::LassoLars { lambda_min: 1e-8 })
        .t(30)
        .run(&s.a, &s.b)
        .expect("valid spec");
    let path = result.lasso.as_ref().expect("LassoLars reports the exact path");
    println!(
        "LASSO path: {} breakpoints, {} drop events (stop: {:?})",
        path.breakpoints.len(),
        path.drops,
        result.output.stop
    );
    println!("{:>12} {:>9} {:>12}", "lambda", "support", "residual");
    for bp in path.breakpoints.iter().step_by(3) {
        println!("{:>12.5} {:>9} {:>12.5}", bp.lambda, bp.support.len(), bp.residual_norm);
    }

    // Cross-check interior solutions against coordinate descent.
    let lmax = lambda_max(&s.a, &s.b);
    println!("\ncross-check vs coordinate descent:");
    for frac in [0.5, 0.25, 0.1, 0.05] {
        let lambda = lmax * frac;
        let Some(x_path) = path.solution_at(lambda) else {
            println!("  λ = {lambda:.4}: outside computed path");
            continue;
        };
        let cd = lasso_cd(&s.a, &s.b, lambda, 5000, 1e-12);
        let err = x_path
            .iter()
            .zip(&cd.x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0_f64, f64::max);
        println!(
            "  λ = {lambda:8.4}: ‖x_LARS − x_CD‖∞ = {err:.2e}  (‖x‖∞ = {:.3}, support {})",
            norm_inf(&x_path),
            cd.support.len()
        );
        assert!(err < 1e-4, "path disagrees with CD at λ = {lambda}");
    }
    println!("\nTheorem 1 (Efron et al.) reproduced: the modified-LARS path IS the LASSO path.");
}
