//! Feature selection on very wide data with T-bLARS (the paper's §10
//! E2006 regime: n ≫ m, column-partitioned).
//!
//! A genomics/text-like scenario: tens of thousands of sparse features,
//! few samples, feature selection must run distributed because no
//! single node holds all columns. Shows the tournament's quality
//! (vs. LARS ground truth) and the communication profile as P grows —
//! both through the `calars::fit` estimator API.
//!
//! ```bash
//! cargo run --release --example wide_selection
//! ```

use calars::data::datasets;
use calars::fit::{Algorithm, FitSpec};
use calars::lars::quality::precision;
use calars::metrics::{fmt_count, fmt_secs};

fn main() {
    let ds = datasets::e2006_tfidf_like(42);
    let t = 40;
    println!(
        "wide selection: {} — m={} n={} nnz={}",
        ds.name,
        ds.a.nrows(),
        ds.a.ncols(),
        fmt_count(ds.a.nnz() as u64)
    );

    println!("running serial LARS reference (t = {t})...");
    let reference = FitSpec::new(Algorithm::Lars)
        .t(t)
        .run(&ds.a, &ds.b)
        .expect("fit")
        .output;

    println!("{:-<78}", "");
    println!(
        "{:<18} {:>9} {:>10} {:>10} {:>10} {:>8}",
        "config", "precision", "residual", "sim time", "words", "msgs"
    );
    for (p, b) in [(1usize, 2usize), (4, 2), (16, 2), (64, 2), (16, 8), (64, 8)] {
        let result = FitSpec::new(Algorithm::TBlars { b, parts: p })
            .t(t)
            .run(&ds.a, &ds.b)
            .expect("fit");
        let sim = result.sim.as_ref().expect("cluster telemetry");
        println!(
            "{:<18} {:>9.2} {:>10.4} {:>10} {:>10} {:>8}",
            format!("T-bLARS P={p} b={b}"),
            precision(&result.output.selected, &reference.selected),
            result.output.residual_norms.last().unwrap(),
            fmt_secs(sim.sim_time),
            fmt_count(sim.counters.words),
            fmt_count(sim.counters.msgs)
        );
    }
    println!("{:-<78}", "");
    println!("T-bLARS words scale with m (not n): the tournament ships b·m-word");
    println!("column payloads up the tree instead of n-word correlation vectors —");
    println!("why the paper recommends it exactly in this n >> m regime.");
}
