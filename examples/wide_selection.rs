//! Feature selection on very wide data with T-bLARS (the paper's §10
//! E2006 regime: n ≫ m, column-partitioned).
//!
//! A genomics/text-like scenario: tens of thousands of sparse features,
//! few samples, feature selection must run distributed because no
//! single node holds all columns. Shows the tournament's quality
//! (vs. LARS ground truth) and the communication profile as P grows.
//!
//! ```bash
//! cargo run --release --example wide_selection
//! ```

use calars::cluster::{ExecMode, HwParams, SimCluster};
use calars::data::{datasets, partition};
use calars::lars::quality::precision;
use calars::lars::serial::{lars, LarsOptions};
use calars::lars::tblars::{tblars, TblarsOptions};
use calars::metrics::{fmt_count, fmt_secs};

fn main() {
    let ds = datasets::e2006_tfidf_like(42);
    let t = 40;
    println!(
        "wide selection: {} — m={} n={} nnz={}",
        ds.name,
        ds.a.nrows(),
        ds.a.ncols(),
        fmt_count(ds.a.nnz() as u64)
    );

    println!("running serial LARS reference (t = {t})...");
    let reference = lars(&ds.a, &ds.b, &LarsOptions { t, ..Default::default() });

    println!("{:-<78}", "");
    println!(
        "{:<18} {:>9} {:>10} {:>10} {:>10} {:>8}",
        "config", "precision", "residual", "sim time", "words", "msgs"
    );
    for (p, b) in [(1usize, 2usize), (4, 2), (16, 2), (64, 2), (16, 8), (64, 8)] {
        let parts = partition::balanced_col_partition(&ds.a, p);
        let mut cluster = SimCluster::new(p, HwParams::default(), ExecMode::Sequential);
        let out =
            tblars(&ds.a, &ds.b, &parts, &TblarsOptions { t, b, ..Default::default() }, &mut cluster);
        let c = cluster.counters();
        println!(
            "{:<18} {:>9.2} {:>10.4} {:>10} {:>10} {:>8}",
            format!("T-bLARS P={p} b={b}"),
            precision(&out.selected, &reference.selected),
            out.residual_norms.last().unwrap(),
            fmt_secs(cluster.sim_time()),
            fmt_count(c.words),
            fmt_count(c.msgs)
        );
    }
    println!("{:-<78}", "");
    println!("T-bLARS words scale with m (not n): the tournament ships b·m-word");
    println!("column payloads up the tree instead of n-word correlation vectors —");
    println!("why the paper recommends it exactly in this n >> m regime.");
}
