//! Quickstart: fit a sparse linear model through the unified
//! `calars::fit` estimator API in a few lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use calars::data::datasets;
use calars::fit::{Algorithm, FitSpec};
use calars::lars::path::{ls_coefficients, residual_norm};
use calars::lars::quality::recall;

fn main() {
    // A small synthetic regression problem: 120 samples, 300 features,
    // 12 of which actually generate the response.
    let ds = datasets::tiny(42);
    println!(
        "problem: m={} n={} planted support size={}",
        ds.a.nrows(),
        ds.a.ncols(),
        ds.true_support.as_ref().unwrap().len()
    );

    // One estimator call path for the whole family: build a FitSpec,
    // run it. Invalid specs return typed errors instead of panicking.
    let result = FitSpec::new(Algorithm::Lars)
        .t(12)
        .run(&ds.a, &ds.b)
        .expect("valid spec");
    let out = &result.output;
    println!("selected (in order): {:?}", out.selected);
    println!("stopped because: {:?}", out.stop);
    println!(
        "residual: {:.4} -> {:.4}",
        out.residual_norms.first().unwrap(),
        out.residual_norms.last().unwrap()
    );

    // Recover least-squares coefficients on the selected support.
    let coefs = ls_coefficients(&ds.a, &out.selected, &ds.b).expect("full-rank support");
    let rn = residual_norm(&ds.a, &out.selected, &coefs, &ds.b);
    println!("LS refit residual on support: {rn:.4}");

    // How much of the planted truth did we find?
    let truth = ds.true_support.as_ref().unwrap();
    println!("recall vs planted support: {:.2}", recall(&out.selected, truth));

    // Switching algorithms is switching the spec — same call, same
    // result shape. bLARS with blocks of 4 on 8 simulated ranks:
    let blars = FitSpec::new(Algorithm::Blars { b: 4 })
        .t(12)
        .ranks(8)
        .run(&ds.a, &ds.b)
        .expect("valid spec");
    let sim = blars.sim.as_ref().expect("cluster fitters report telemetry");
    println!(
        "bLARS b=4 P=8: recall {:.2}, {} simulated messages",
        recall(&blars.output.selected, truth),
        sim.counters.msgs
    );
}
