//! Quickstart: fit a sparse linear model with LARS in a few lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use calars::data::datasets;
use calars::lars::path::{ls_coefficients, residual_norm};
use calars::lars::quality::recall;
use calars::lars::serial::{lars, LarsOptions};

fn main() {
    // A small synthetic regression problem: 120 samples, 300 features,
    // 12 of which actually generate the response.
    let ds = datasets::tiny(42);
    println!(
        "problem: m={} n={} planted support size={}",
        ds.a.nrows(),
        ds.a.ncols(),
        ds.true_support.as_ref().unwrap().len()
    );

    // Run LARS for 12 columns.
    let out = lars(&ds.a, &ds.b, &LarsOptions { t: 12, ..Default::default() });
    println!("selected (in order): {:?}", out.selected);
    println!(
        "residual: {:.4} -> {:.4}",
        out.residual_norms.first().unwrap(),
        out.residual_norms.last().unwrap()
    );

    // Recover least-squares coefficients on the selected support.
    let coefs = ls_coefficients(&ds.a, &out.selected, &ds.b).expect("full-rank support");
    let rn = residual_norm(&ds.a, &out.selected, &coefs, &ds.b);
    println!("LS refit residual on support: {rn:.4}");

    // How much of the planted truth did we find?
    let truth = ds.true_support.as_ref().unwrap();
    println!("recall vs planted support: {:.2}", recall(&out.selected, truth));
}
