//! The `calars::select` model-selection subsystem end to end:
//!
//! * **Acceptance criterion**: the CV-selected step — and every score
//!   bit — is identical across pool thread counts {1, 2, 4};
//! * CV runs for every member of the fitter family through the one
//!   `FitSpec` call path;
//! * in-sample criteria and CV agree on the order of magnitude of the
//!   planted support;
//! * fold construction drops columns whose mass is held out (the fit
//!   API rejects zero columns) and maps them back correctly.

use calars::data::{datasets, partition};
use calars::fit::{Algorithm, FitSpec, Fitter, SnapshotObserver};
use calars::linalg::{DenseMatrix, Matrix};
use calars::par::{self, ThreadPool};
use calars::select::{self, Criterion, SelectSpec};
use std::sync::Mutex;

#[test]
fn cv_selection_is_bit_identical_across_thread_counts() {
    let d = datasets::tiny(11);
    let fit = FitSpec::new(Algorithm::Lars).t(16);
    let sel = SelectSpec::new(Criterion::Cv).k(5).seed(3);
    let mut baseline: Option<calars::select::Selection> = None;
    for threads in [1usize, 2, 4] {
        let pool = ThreadPool::new(threads, par::DEFAULT_MIN_CHUNK);
        let s =
            par::with_pool(&pool, || select::cross_validate(&d.a, &d.b, &fit, &sel).unwrap());
        match &baseline {
            None => baseline = Some(s),
            Some(b) => {
                assert_eq!(s.best_step, b.best_step, "threads={threads}");
                assert_eq!(s.scores.len(), b.scores.len(), "threads={threads}");
                for (x, y) in s.scores.iter().zip(&b.scores) {
                    assert_eq!(
                        x.score.to_bits(),
                        y.score.to_bits(),
                        "threads={threads} step {}",
                        x.step
                    );
                }
            }
        }
    }
}

#[test]
fn cv_runs_across_the_fitter_family() {
    let d = datasets::tiny_dense(2);
    let sel = SelectSpec::new(Criterion::Cv).k(4).seed(1);
    for algorithm in [
        Algorithm::Lars,
        Algorithm::Blars { b: 2 },
        Algorithm::TBlars { b: 2, parts: 2 },
        Algorithm::LassoLars { lambda_min: 1e-8 },
        Algorithm::ForwardSelection,
        Algorithm::Omp,
    ] {
        let fit = FitSpec::new(algorithm).t(8).ranks(2);
        let s = select::cross_validate(&d.a, &d.b, &fit, &sel)
            .unwrap_or_else(|e| panic!("{algorithm:?}: {e:#}"));
        assert!(!s.scores.is_empty(), "{algorithm:?}");
        assert!(s.best_step < s.scores.len(), "{algorithm:?}");
        assert!(
            s.best_step > 0,
            "{algorithm:?}: the planted signal must beat the empty model"
        );
    }
}

#[test]
fn select_model_agrees_with_the_planted_support_scale() {
    // tiny_dense plants 10 true features in a 150×60 design with weak
    // noise; every criterion should serve a non-trivial model and none
    // should insist on the full 20-step path.
    let d = datasets::tiny_dense(5);
    let fit = FitSpec::new(Algorithm::Lars).t(20);
    for criterion in [Criterion::Cp, Criterion::Aic, Criterion::Bic, Criterion::Cv] {
        let sel = SelectSpec::new(criterion).k(5).seed(2);
        let (result, snap, selection) =
            select::select_model(&d.a, &d.b, &fit, &sel).unwrap();
        assert_eq!(result.output.selected.len(), 20);
        assert!(selection.best_step >= 5, "{criterion:?}: {}", selection.best_step);
        assert!(selection.best_step < snap.len());
    }
}

#[test]
fn in_sample_ranking_matches_fit_time_metadata_path() {
    // rank_steps over a SnapshotObserver capture is exactly what the
    // serve queue precomputes into the model metadata.
    let d = datasets::tiny(4);
    let fit = FitSpec::new(Algorithm::Lars).t(12);
    let mut obs = SnapshotObserver::new();
    fit.fit(&d.a, &d.b, &mut obs).unwrap();
    let snap = obs.into_snapshot().unwrap();
    let a = select::rank_steps(&snap, d.a.nrows(), Criterion::Bic).unwrap();
    let b = select::rank_steps(&snap, d.a.nrows(), Criterion::Bic).unwrap();
    assert_eq!(a, b, "ranking is deterministic");
    assert_eq!(a.scores.len(), snap.len());
}

#[test]
fn cv_drops_columns_whose_mass_is_held_out_and_maps_them_back() {
    // Column 2 is nonzero ONLY on fold 0's rows: fold 0's training
    // shard must drop it (its training norm is 0 — the fit API rejects
    // zero columns), and every other fold must keep it.
    let m = 20usize;
    let k = 4usize;
    let seed = 9u64;
    let folds = partition::cv_folds(m, k, seed);
    let fold0 = folds[0].clone();
    let a = Matrix::Dense(DenseMatrix::from_fn(m, 5, |i, j| {
        if j == 2 {
            if fold0.contains(&i) {
                1.0
            } else {
                0.0
            }
        } else {
            // Pseudo-random full-rank filler (a sinusoid here would
            // make every column a combination of sin/cos of one
            // frequency and trip the rank-deficiency path instead).
            ((i * 31 + j * 17 + 3) % 23) as f64 / 10.0 - 1.0
        }
    }));
    let b: Vec<f64> = (0..m).map(|i| ((i * 5 + 1) as f64).cos()).collect();
    let fit = FitSpec::new(Algorithm::Lars).t(3);
    let sel = SelectSpec::new(Criterion::Cv).k(k).seed(seed);
    let kept_log: Mutex<Vec<(usize, Vec<usize>)>> = Mutex::new(Vec::new());
    let s = select::cross_validate_with(&a, &b, &fit, &sel, |ctx, fit_spec| {
        kept_log.lock().unwrap().push((ctx.fold, ctx.kept.to_vec()));
        assert_eq!(ctx.kept.len(), ctx.norms.len());
        select::fit_fold_snapshot(ctx, fit_spec)
    })
    .unwrap();
    assert!(s.best_step < s.scores.len());
    let log = kept_log.into_inner().unwrap();
    assert_eq!(log.len(), k);
    for (fold, kept) in &log {
        if *fold == 0 {
            assert!(!kept.contains(&2), "fold 0 must drop the held-out-only column");
            assert_eq!(kept.len(), 4);
        } else {
            assert!(kept.contains(&2), "fold {fold} keeps column 2");
            assert_eq!(kept.len(), 5);
        }
    }
}
