//! Property-based tests (via the in-repo `proptest_lite` harness) over
//! the algorithmic invariants the paper proves or relies on.
//!
//! Uses the deprecated free-function shims deliberately — they
//! delegate to the `calars::fit` cores (bit-identity proven in
//! `tests/fit.rs`), so these double as shim regression coverage.
#![allow(deprecated)]

use calars::cluster::{ExecMode, HwParams, SimCluster};
use calars::data::synthetic::{generate, Synthetic, SyntheticSpec};
use calars::lars::blars::{blars, BlarsOptions};
use calars::lars::serial::{blars_serial, lars, LarsOptions};
use calars::lars::steplars::step_lars;
use calars::linalg::{Cholesky, DenseMatrix};
use calars::proptest_lite::{check, Config};
use calars::rng::Pcg64;

fn random_problem(rng: &mut Pcg64, size: usize) -> Synthetic {
    let m = 30 + size * 6;
    let n = 20 + size * 8;
    let spec = SyntheticSpec {
        m,
        n,
        density: if rng.uniform() < 0.5 { 1.0 } else { 0.3 },
        col_skew: rng.uniform_range(0.0, 1.2),
        k_true: 3 + size / 2,
        noise: rng.uniform_range(0.0, 0.1),
    };
    generate(&spec, rng.next_u64())
}

#[test]
fn prop_lars_residuals_monotone() {
    check(
        Config { cases: 24, seed: 0xA11CE },
        random_problem,
        |s| {
            let t = 8.min(s.a.ncols() / 2).max(2);
            let out = lars(&s.a, &s.b, &LarsOptions { t, ..Default::default() });
            for w in out.residual_norms.windows(2) {
                if w[1] > w[0] + 1e-9 {
                    return Err(format!("residual increased {} -> {}", w[0], w[1]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lars_selected_unique_and_in_range() {
    check(
        Config { cases: 24, seed: 0xB0B },
        random_problem,
        |s| {
            let t = 10.min(s.a.ncols() / 2).max(2);
            let out = lars(&s.a, &s.b, &LarsOptions { t, ..Default::default() });
            let mut sel = out.selected.clone();
            sel.sort_unstable();
            let len = sel.len();
            sel.dedup();
            if sel.len() != len {
                return Err("duplicate selections".into());
            }
            if sel.iter().any(|&j| j >= s.a.ncols()) {
                return Err("selection out of range".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blars_b1_equals_lars() {
    check(
        Config { cases: 16, seed: 0xC0FFEE },
        random_problem,
        |s| {
            let t = 8.min(s.a.ncols() / 2).max(2);
            let l = lars(&s.a, &s.b, &LarsOptions { t, ..Default::default() });
            let b = blars_serial(&s.a, &s.b, &LarsOptions { t, b: 1, ..Default::default() });
            if l.selected != b.selected {
                return Err(format!("selections differ: {:?} vs {:?}", l.selected, b.selected));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_blars_selection_independent_of_p() {
    check(
        Config { cases: 12, seed: 0xDEAD },
        random_problem,
        |s| {
            let t = 8.min(s.a.ncols() / 2).max(2);
            let run = |p: usize| {
                let mut c = SimCluster::new(p, HwParams::default(), ExecMode::Sequential);
                blars(&s.a, &s.b, &BlarsOptions { t, b: 2, ..Default::default() }, &mut c).selected
            };
            let s1 = run(1);
            let s4 = run(4);
            if s1 != s4 {
                return Err(format!("P changed selection: {s1:?} vs {s4:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_steplars_gamma_in_bounds() {
    check(
        Config { cases: 64, seed: 0xFACE },
        |rng, _| {
            (
                rng.uniform_range(1e-6, 3.0),  // ck
                rng.uniform_range(1e-3, 5.0),  // h
                rng.normal() * 2.0,            // cj
                rng.normal() * 2.0,            // aj
            )
        },
        |&(ck, h, cj, aj)| {
            let g = step_lars(ck, h, cj, aj).gamma();
            if !(g.is_finite() && (0.0..=1.0 / h + 1e-9).contains(&g)) {
                return Err(format!("γ = {g} out of [0, 1/h]"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_steplars_crossing_solves_equation() {
    use calars::lars::steplars::StepKind;
    check(
        Config { cases: 128, seed: 0xFEED },
        |rng, _| {
            (
                rng.uniform_range(0.1, 2.0),
                rng.uniform_range(0.1, 2.0),
                rng.normal(),
                rng.normal(),
            )
        },
        |&(ck, h, cj, aj)| {
            if let StepKind::Crossing(g) = step_lars(ck, h, cj, aj) {
                if g < 1.0 / h - 1e-9 {
                    let lhs = ck * (1.0 - g * h);
                    let rhs = (cj - g * aj).abs();
                    if (lhs - rhs).abs() > 1e-7 * lhs.abs().max(1.0) {
                        return Err(format!("eq(5) violated: {lhs} vs {rhs} at γ={g}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cholesky_append_equals_full_factor() {
    check(
        Config { cases: 32, seed: 0x10_AD },
        |rng, size| {
            let n = 2 + size.min(12);
            let split = 1 + rng.below(n - 1);
            let m = n + 4;
            let a = DenseMatrix::from_fn(m, n, |_, _| rng.normal());
            (a, split)
        },
        |(a, split)| {
            let n = a.ncols();
            let all: Vec<usize> = (0..n).collect();
            let mut g = a.gram_block(&all, &all);
            for i in 0..n {
                g.set(i, i, g.get(i, i) + 0.05);
            }
            let full = Cholesky::factor(&g).map_err(|e| e.to_string())?;
            let k = *split;
            let gk = DenseMatrix::from_fn(k, k, |i, j| g.get(i, j));
            let mut inc = Cholesky::factor(&gk).map_err(|e| e.to_string())?;
            let gib = DenseMatrix::from_fn(k, n - k, |i, j| g.get(i, k + j));
            let gbb = DenseMatrix::from_fn(n - k, n - k, |i, j| g.get(k + i, k + j));
            inc.append_block(&gib, &gbb).map_err(|e| e.to_string())?;
            for i in 0..n {
                for j in 0..=i {
                    let d = (inc.get(i, j) - full.get(i, j)).abs();
                    if d > 1e-8 {
                        return Err(format!("factor mismatch at ({i},{j}): {d}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lars_maximal_correlation_invariant() {
    // No unselected column may strictly dominate the selected set's
    // maximum absolute correlation (LARS's defining property).
    check(
        Config { cases: 16, seed: 0x1A25 },
        random_problem,
        |s| {
            let t = 6.min(s.a.ncols() / 2).max(2);
            let out = lars(&s.a, &s.b, &LarsOptions { t, ..Default::default() });
            let r: Vec<f64> =
                s.b.iter().zip(&out.y).map(|(bi, yi)| bi - yi).collect();
            let mut c = vec![0.0; s.a.ncols()];
            s.a.at_r(&r, &mut c);
            let cmax_sel =
                out.selected.iter().map(|&j| c[j].abs()).fold(0.0_f64, f64::max);
            for j in 0..s.a.ncols() {
                if !out.selected.contains(&j) && c[j].abs() > cmax_sel * (1.0 + 1e-6) + 1e-9 {
                    return Err(format!(
                        "col {j} dominates: |c|={} vs selected max {cmax_sel}",
                        c[j].abs()
                    ));
                }
            }
            Ok(())
        },
    );
}
