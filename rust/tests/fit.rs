//! The unified `calars::fit` estimator API, end to end:
//!
//! * **Shim equivalence** (acceptance criterion): for every member of
//!   the fitter family, the deprecated free-function shim and the new
//!   `FitSpec`/`Fitter::fit` path produce **bit-identical** outputs;
//! * **Observer semantics** — snapshot capture, early stop, metrics
//!   collection, multi-observer composition;
//! * **StopReason reporting** — each fitter driven deliberately into
//!   `Saturated`, `PoolExhausted`, and `RankDeficient` terminal states
//!   and reporting them in `FitResult` instead of panicking;
//! * **Typed errors** — invalid specs and inputs come back as
//!   `ErrorKind::InvalidSpec`, never as a panic.
#![allow(deprecated)] // the whole point: shims vs the new API

use calars::cluster::{ExecMode, HwParams, SimCluster};
use calars::data::synthetic::{generate, Synthetic, SyntheticSpec};
use calars::data::{datasets, partition};
use calars::error::ErrorKind;
use calars::fit::{
    Algorithm, EarlyStop, FitSpec, Fitter, MetricsSink, MultiObserver, ProgressObserver,
    SnapshotObserver,
};
use calars::lars::blars::{blars, BlarsOptions};
use calars::lars::lasso_lars::lasso_path;
use calars::lars::path::PathSnapshot;
use calars::lars::serial::{lars, LarsOptions};
use calars::lars::tblars::{tblars, TblarsOptions};
use calars::lars::{LarsOutput, StopReason};
use calars::linalg::{DenseMatrix, Matrix};
use calars::proptest_lite::{check, Config};
use calars::rng::Pcg64;

fn random_problem(rng: &mut Pcg64, size: usize) -> Synthetic {
    let m = 30 + size * 6;
    let n = 20 + size * 8;
    let spec = SyntheticSpec {
        m,
        n,
        density: if rng.uniform() < 0.5 { 1.0 } else { 0.3 },
        col_skew: rng.uniform_range(0.0, 1.2),
        k_true: 3 + size / 2,
        noise: rng.uniform_range(0.0, 0.1),
    };
    generate(&spec, rng.next_u64())
}

fn bit_identical(old: &LarsOutput, new: &LarsOutput) -> Result<(), String> {
    if old.selected != new.selected {
        return Err(format!("selected differ: {:?} vs {:?}", old.selected, new.selected));
    }
    if old.cols_at_iter != new.cols_at_iter {
        return Err("cols_at_iter differ".into());
    }
    if old.stop != new.stop {
        return Err(format!("stop reasons differ: {:?} vs {:?}", old.stop, new.stop));
    }
    if old.residual_norms.len() != new.residual_norms.len() {
        return Err("residual trace length differs".into());
    }
    for (i, (a, b)) in old.residual_norms.iter().zip(&new.residual_norms).enumerate() {
        if a.to_bits() != b.to_bits() {
            return Err(format!("residual[{i}] bits differ: {a:?} vs {b:?}"));
        }
    }
    if old.y.len() != new.y.len() {
        return Err("y length differs".into());
    }
    for (i, (a, b)) in old.y.iter().zip(&new.y).enumerate() {
        if a.to_bits() != b.to_bits() {
            return Err(format!("y[{i}] bits differ: {a:?} vs {b:?}"));
        }
    }
    Ok(())
}

// ── Shim ≡ new API, bit for bit, per algorithm ──────────────────────

#[test]
fn prop_lars_shim_equals_fit_api() {
    check(Config { cases: 18, seed: 0xF17_A }, random_problem, |s| {
        let t = 8.min(s.a.ncols() / 2).max(2);
        let old = lars(&s.a, &s.b, &LarsOptions { t, ..Default::default() });
        let new = FitSpec::new(Algorithm::Lars)
            .t(t)
            .run(&s.a, &s.b)
            .map_err(|e| format!("fit failed: {e:#}"))?;
        bit_identical(&old, &new.output)
    });
}

#[test]
fn prop_blars_shim_equals_fit_api() {
    check(Config { cases: 14, seed: 0xF17_B }, random_problem, |s| {
        let t = 9.min(s.a.ncols() / 2).max(3);
        let mut cluster = SimCluster::new(4, HwParams::default(), ExecMode::Sequential);
        let old = blars(&s.a, &s.b, &BlarsOptions { t, b: 3, ..Default::default() }, &mut cluster);
        let new = FitSpec::new(Algorithm::Blars { b: 3 })
            .t(t)
            .ranks(4)
            .run(&s.a, &s.b)
            .map_err(|e| format!("fit failed: {e:#}"))?;
        if new.sim.is_none() {
            return Err("bLARS must report cluster telemetry".into());
        }
        bit_identical(&old, &new.output)
    });
}

#[test]
fn prop_tblars_shim_equals_fit_api() {
    check(Config { cases: 10, seed: 0xF17_C }, random_problem, |s| {
        let t = 8.min(s.a.ncols() / 2).max(2);
        let parts = partition::balanced_col_partition(&s.a, 4);
        let mut cluster = SimCluster::new(4, HwParams::default(), ExecMode::Sequential);
        let old =
            tblars(&s.a, &s.b, &parts, &TblarsOptions { t, b: 2, ..Default::default() }, &mut cluster);
        let new = FitSpec::new(Algorithm::TBlars { b: 2, parts: 4 })
            .t(t)
            .run(&s.a, &s.b)
            .map_err(|e| format!("fit failed: {e:#}"))?;
        bit_identical(&old, &new.output)
    });
}

#[test]
fn prop_lasso_shim_equals_fit_api() {
    check(Config { cases: 14, seed: 0xF17_D }, random_problem, |s| {
        let t = 8.min(s.a.ncols() / 2).max(2);
        let old = lasso_path(&s.a, &s.b, t, 1e-6);
        // The shim fixes the historical tol = 1e-10; match it so the
        // comparison is bit-for-bit by construction.
        let new = FitSpec::new(Algorithm::LassoLars { lambda_min: 1e-6 })
            .t(t)
            .tol(1e-10)
            .run(&s.a, &s.b)
            .map_err(|e| format!("fit failed: {e:#}"))?;
        let path = new.lasso.as_ref().ok_or("missing lasso path")?;
        if old.drops != path.drops {
            return Err(format!("drop counts differ: {} vs {}", old.drops, path.drops));
        }
        if old.breakpoints.len() != path.breakpoints.len() {
            return Err("breakpoint counts differ".into());
        }
        for (i, (a, b)) in old.breakpoints.iter().zip(&path.breakpoints).enumerate() {
            if a.lambda.to_bits() != b.lambda.to_bits() {
                return Err(format!("λ[{i}] bits differ"));
            }
            if a.support != b.support {
                return Err(format!("support[{i}] differs"));
            }
            for (x, y) in a.x.iter().zip(&b.x) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("x[{i}] bits differ"));
                }
            }
            if a.residual_norm.to_bits() != b.residual_norm.to_bits() {
                return Err(format!("residual[{i}] bits differ"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_baseline_shims_equal_fit_api() {
    use calars::baselines::forward_selection::forward_selection;
    use calars::baselines::omp::omp;
    check(Config { cases: 14, seed: 0xF17_E }, random_problem, |s| {
        let t = 6.min(s.a.ncols() / 2).max(2);

        let old = forward_selection(&s.a, &s.b, t);
        let new = FitSpec::new(Algorithm::ForwardSelection)
            .t(t)
            .run(&s.a, &s.b)
            .map_err(|e| format!("fs fit failed: {e:#}"))?;
        if old.selected != new.output.selected {
            return Err("fs selections differ".into());
        }
        for (a, b) in old.residual_norms.iter().zip(&new.output.residual_norms) {
            if a.to_bits() != b.to_bits() {
                return Err("fs residual bits differ".into());
            }
        }
        let coefs = new.coefs.as_ref().ok_or("fs must report coefficients")?;
        for (a, b) in old.coefs.iter().zip(coefs) {
            if a.to_bits() != b.to_bits() {
                return Err("fs coef bits differ".into());
            }
        }

        let old = omp(&s.a, &s.b, t);
        let new = FitSpec::new(Algorithm::Omp)
            .t(t)
            .run(&s.a, &s.b)
            .map_err(|e| format!("omp fit failed: {e:#}"))?;
        if old.selected != new.output.selected {
            return Err("omp selections differ".into());
        }
        for (a, b) in old.residual_norms.iter().zip(&new.output.residual_norms) {
            if a.to_bits() != b.to_bits() {
                return Err("omp residual bits differ".into());
            }
        }
        Ok(())
    });
}

// ── Observer semantics ──────────────────────────────────────────────

#[test]
fn snapshot_observer_matches_from_fit() {
    let d = datasets::tiny(1);
    let mut obs = SnapshotObserver::new();
    let result = FitSpec::new(Algorithm::Lars).t(8).fit(&d.a, &d.b, &mut obs).unwrap();
    let snap = obs.into_snapshot().expect("snapshot captured");
    let direct = PathSnapshot::from_fit(&d.a, &d.b, &result.output.selected);
    assert_eq!(snap, direct, "observer snapshot must equal the direct computation");
    assert_eq!(snap.max_support(), 8);
}

#[test]
fn early_stop_caps_iterations() {
    let d = datasets::tiny(2);
    let mut stopper = EarlyStop::after_iterations(3);
    let result = FitSpec::new(Algorithm::Lars).t(15).fit(&d.a, &d.b, &mut stopper).unwrap();
    assert_eq!(result.output.stop, StopReason::EarlyStopped);
    assert!(
        result.output.selected.len() < 15,
        "early stop must end before the target: {} columns",
        result.output.selected.len()
    );
}

#[test]
fn early_stop_at_residual_target() {
    let d = datasets::tiny(3);
    // ‖b‖ shrinks along the path; a loose target triggers quickly.
    let full = FitSpec::new(Algorithm::Lars).t(15).run(&d.a, &d.b).unwrap();
    let target = full.output.residual_norms[0] * 0.9;
    let mut stopper = EarlyStop::at_residual(target);
    let result = FitSpec::new(Algorithm::Lars).t(15).fit(&d.a, &d.b, &mut stopper).unwrap();
    assert!(
        *result.output.residual_norms.last().unwrap() <= target,
        "stop must fire at or below the residual target"
    );
    assert!(result.output.selected.len() <= full.output.selected.len());
}

#[test]
fn early_stop_works_across_the_family() {
    let d = datasets::tiny(4);
    for algorithm in [
        Algorithm::Blars { b: 2 },
        Algorithm::TBlars { b: 2, parts: 2 },
        Algorithm::LassoLars { lambda_min: 1e-9 },
        Algorithm::ForwardSelection,
        Algorithm::Omp,
    ] {
        let mut stopper = EarlyStop::after_iterations(2);
        let result = FitSpec::new(algorithm)
            .t(12)
            .ranks(2)
            .fit(&d.a, &d.b, &mut stopper)
            .unwrap_or_else(|e| panic!("{algorithm:?}: {e:#}"));
        assert_eq!(
            result.output.stop,
            StopReason::EarlyStopped,
            "{algorithm:?} must honor the observer"
        );
        assert!(
            result.output.selected.len() < 12,
            "{algorithm:?} stopped late: {}",
            result.output.selected.len()
        );
    }
}

#[test]
fn metrics_sink_collects_the_iteration_trace() {
    let d = datasets::tiny(5);
    let mut sink = MetricsSink::new();
    let result = FitSpec::new(Algorithm::Blars { b: 3 })
        .t(12)
        .ranks(4)
        .fit(&d.a, &d.b, &mut sink)
        .unwrap();
    assert!(sink.iterations > 0);
    assert_eq!(sink.residual_norms.len(), sink.iterations);
    assert_eq!(sink.gammas.len(), sink.iterations);
    assert_eq!(sink.support_sizes.len(), sink.iterations);
    for w in sink.support_sizes.windows(2) {
        assert!(w[1] >= w[0], "support must grow monotonically");
    }
    assert_eq!(sink.stop, Some(result.output.stop));
    assert!(sink.wall_secs >= 0.0);
    assert_eq!(*sink.support_sizes.last().unwrap(), result.output.selected.len());
}

#[test]
fn multi_observer_composes() {
    let d = datasets::tiny(6);
    let mut snap = SnapshotObserver::new();
    let mut sink = MetricsSink::new();
    let mut progress = ProgressObserver::every(1000); // quiet
    let result = {
        let mut multi = MultiObserver::new()
            .with(&mut snap)
            .with(&mut sink)
            .with(&mut progress);
        FitSpec::new(Algorithm::Lars).t(6).fit(&d.a, &d.b, &mut multi).unwrap()
    };
    assert!(snap.snapshot().is_some(), "snapshot observer ran");
    assert!(sink.iterations > 0, "metrics observer ran");
    assert_eq!(result.output.selected.len(), 6);
}

#[test]
fn multi_observer_any_stop_wins() {
    let d = datasets::tiny(7);
    let mut sink = MetricsSink::new();
    let mut stopper = EarlyStop::after_iterations(2);
    let result = {
        let mut multi = MultiObserver::new().with(&mut sink).with(&mut stopper);
        FitSpec::new(Algorithm::Lars).t(15).fit(&d.a, &d.b, &mut multi).unwrap()
    };
    assert_eq!(result.output.stop, StopReason::EarlyStopped);
    assert!(sink.iterations >= 2, "other observers still see every event");
}

/// Satellite: T-bLARS observer events carry NaN for γ/λ (the
/// tournament has no scalar step per outer iteration). The metrics
/// export must serialize them as `null` — a bare `NaN` token is
/// invalid JSON and used to corrupt any document embedding the trace.
#[test]
fn metrics_sink_serializes_nan_gamma_lambda_as_null() {
    let d = datasets::tiny(15);
    let mut sink = MetricsSink::new();
    FitSpec::new(Algorithm::TBlars { b: 2, parts: 4 })
        .t(6)
        .fit(&d.a, &d.b, &mut sink)
        .unwrap();
    assert!(sink.iterations > 0);
    assert!(sink.gammas.iter().all(|g| g.is_nan()), "T-bLARS γ events are NaN");
    assert!(sink.lambdas.iter().all(|l| l.is_nan()), "T-bLARS λ events are NaN");
    let json = sink.to_json();
    assert!(json.contains("\"gammas\":[null"), "{json}");
    assert!(json.contains("\"lambdas\":[null"), "{json}");
    for bad in ["NaN", "nan", "inf"] {
        assert!(!json.contains(bad), "invalid JSON token {bad:?} in {json}");
    }
    // Finite fields still serialize as numbers.
    assert!(json.contains("\"residual_norms\":["), "{json}");
    assert!(!json.contains("\"residual_norms\":[null"), "{json}");
    // ±∞ is also null, not `inf`.
    let mut inf_sink = MetricsSink::new();
    inf_sink.gammas.push(f64::INFINITY);
    inf_sink.lambdas.push(f64::NEG_INFINITY);
    let json = inf_sink.to_json();
    assert!(json.contains("\"gammas\":[null]"), "{json}");
    assert!(json.contains("\"lambdas\":[null]"), "{json}");
}

// ── StopReason reporting (satellite) ────────────────────────────────

/// A 16×6 design whose first two columns are an exact duplicate pair
/// with *exactly* unit norm: entries ±0.25 over 16 rows, so every Gram
/// entry the pair touches is 1.0 bit-exactly and the duplicate's
/// Cholesky pivot cancels to exactly 0.0 — the rank-deficiency
/// exclusion is deterministic, not at the mercy of last-ulp rounding.
/// The response loads every independent column (0, 2, 3, 4, 5) so a
/// fit must walk the whole pool before it can stop.
fn duplicated_design() -> (Matrix, Vec<f64>) {
    let m = 16usize;
    let col_pair = |i: usize| if i % 4 == 0 { -0.25 } else { 0.25 };
    let col_other = |i: usize, j: usize| ((i * 7 + j * 13) as f64).sin() * 0.3;
    let d = DenseMatrix::from_fn(m, 6, |i, j| match j {
        0 | 1 => col_pair(i),
        _ => col_other(i, j),
    });
    let b: Vec<f64> = (0..m)
        .map(|i| {
            3.0 * col_pair(i)
                + 0.9 * col_other(i, 2)
                + 0.7 * col_other(i, 3)
                + 0.5 * col_other(i, 4)
                + 0.4 * col_other(i, 5)
        })
        .collect();
    (Matrix::Dense(d), b)
}

#[test]
fn saturated_reported_on_zero_response() {
    let d = datasets::tiny_dense(10);
    let zero = vec![0.0; d.a.nrows()];
    let lars = FitSpec::new(Algorithm::Lars).t(5).run(&d.a, &zero).unwrap();
    assert_eq!(lars.output.stop, StopReason::Saturated);
    assert!(lars.output.selected.is_empty());
    let blars = FitSpec::new(Algorithm::Blars { b: 2 }).t(5).ranks(2).run(&d.a, &zero).unwrap();
    assert_eq!(blars.output.stop, StopReason::Saturated);
}

#[test]
fn rank_deficient_reported_when_duplicates_block_the_target() {
    // 6 columns, one an exact duplicate ⇒ only 5 independent. Asking
    // for all 6 must end with RankDeficient (not a panic, not a lie).
    let (a, b) = duplicated_design();
    let result = FitSpec::new(Algorithm::Lars).t(6).run(&a, &b).unwrap();
    assert_eq!(result.output.stop, StopReason::RankDeficient, "{:?}", result.output);
    assert_eq!(result.output.selected.len(), 5, "all independent columns selected");

    // bLARS with b = 2 hits the duplicate in its *initial* block (the
    // pair carries the top-2 correlations) and excludes it there.
    let result = FitSpec::new(Algorithm::Blars { b: 2 }).t(6).ranks(2).run(&a, &b).unwrap();
    assert_eq!(result.output.stop, StopReason::RankDeficient, "{:?}", result.output);
    assert_eq!(result.output.selected.len(), 5, "{:?}", result.output.selected);
}

#[test]
fn rank_deficient_reported_by_lasso_on_duplicate_activation() {
    // Exact duplicates share |correlation| at every λ, so both activate
    // at λmax together and the active Gram is singular immediately.
    let (a, b) = duplicated_design();
    let result = FitSpec::new(Algorithm::LassoLars { lambda_min: 1e-9 }).t(6).run(&a, &b).unwrap();
    assert_eq!(result.output.stop, StopReason::RankDeficient, "{:?}", result.output.stop);
}

#[test]
fn pool_exhausted_reported_by_tblars() {
    // Ask the tournament for more columns than the duplicated design
    // can supply: once every leaf's pool holds only duplicates of the
    // selected model, every nomination round comes back empty.
    let (a, b) = duplicated_design();
    let result = FitSpec::new(Algorithm::TBlars { b: 2, parts: 2 }).t(6).run(&a, &b).unwrap();
    assert_eq!(result.output.stop, StopReason::PoolExhausted, "{:?}", result.output);
    assert!(result.output.selected.len() <= 5);
}

#[test]
fn target_reached_is_the_happy_path_for_every_algorithm() {
    let d = datasets::tiny(8);
    for algorithm in [
        Algorithm::Lars,
        Algorithm::Blars { b: 2 },
        Algorithm::TBlars { b: 2, parts: 4 },
        Algorithm::ForwardSelection,
        Algorithm::Omp,
    ] {
        let result = FitSpec::new(algorithm)
            .t(6)
            .ranks(4)
            .run(&d.a, &d.b)
            .unwrap_or_else(|e| panic!("{algorithm:?}: {e:#}"));
        assert_eq!(
            result.output.stop,
            StopReason::TargetReached,
            "{algorithm:?} on an easy problem"
        );
        assert_eq!(result.output.selected.len(), 6, "{algorithm:?}");
    }
}

// ── Typed errors ────────────────────────────────────────────────────

#[test]
fn invalid_inputs_return_typed_errors_not_panics() {
    let d = datasets::tiny(9);
    let short = vec![0.0; d.a.nrows() - 1];
    for algorithm in [
        Algorithm::Lars,
        Algorithm::Blars { b: 2 },
        Algorithm::TBlars { b: 2, parts: 2 },
        Algorithm::LassoLars { lambda_min: 1e-6 },
        Algorithm::ForwardSelection,
        Algorithm::Omp,
    ] {
        let err = FitSpec::new(algorithm).t(4).run(&d.a, &short).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec, "{algorithm:?}: {err:#}");
    }
    // Bad knobs are caught before any arithmetic.
    let err = FitSpec::new(Algorithm::Blars { b: 0 }).t(4).run(&d.a, &d.b).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidSpec);
    let err = FitSpec::new(Algorithm::Lars).t(0).run(&d.a, &d.b).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidSpec);
}

/// Every member of the family, for the degenerate-input battery.
fn family() -> [Algorithm; 6] {
    [
        Algorithm::Lars,
        Algorithm::Blars { b: 2 },
        Algorithm::TBlars { b: 2, parts: 2 },
        Algorithm::LassoLars { lambda_min: 1e-6 },
        Algorithm::ForwardSelection,
        Algorithm::Omp,
    ]
}

/// Satellite: degenerate inputs return typed errors across the whole
/// family — never a panic. An all-zero (or non-finite) column used to
/// reach the tournament comparators as an incomparable NaN and abort
/// the process.
#[test]
fn all_zero_column_is_rejected_across_the_family() {
    let base = datasets::tiny_dense(11);
    let n = base.a.ncols();
    let zeroed = match &base.a {
        Matrix::Dense(d) => {
            Matrix::Dense(DenseMatrix::from_fn(d.nrows(), n, |i, j| {
                if j == 3 {
                    0.0
                } else {
                    d.get(i, j)
                }
            }))
        }
        Matrix::Sparse(_) => unreachable!("tiny_dense is dense"),
    };
    for algorithm in family() {
        let err = FitSpec::new(algorithm).t(4).ranks(2).run(&zeroed, &base.b).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec, "{algorithm:?}: {err:#}");
        assert!(format!("{err:#}").contains("column 3"), "{algorithm:?}: {err:#}");
    }
}

#[test]
fn non_finite_response_is_rejected_across_the_family() {
    let d = datasets::tiny_dense(12);
    for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
        let mut b = d.b.clone();
        b[7] = bad;
        for algorithm in family() {
            let err = FitSpec::new(algorithm).t(4).ranks(2).run(&d.a, &b).unwrap_err();
            assert_eq!(err.kind(), ErrorKind::InvalidSpec, "{algorithm:?} b[7]={bad}: {err:#}");
        }
    }
}

#[test]
fn non_finite_matrix_value_is_rejected() {
    let d = datasets::tiny_dense(13);
    let poisoned = match &d.a {
        Matrix::Dense(m) => Matrix::Dense(DenseMatrix::from_fn(m.nrows(), m.ncols(), |i, j| {
            if i == 0 && j == 5 {
                f64::NAN
            } else {
                m.get(i, j)
            }
        })),
        Matrix::Sparse(_) => unreachable!(),
    };
    let err = FitSpec::new(Algorithm::Lars).t(4).run(&poisoned, &d.b).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidSpec, "{err:#}");
}

#[test]
fn fewer_than_two_rows_is_rejected() {
    let a = Matrix::Dense(DenseMatrix::from_fn(1, 3, |_, j| (j + 1) as f64));
    let b = vec![1.0];
    for algorithm in family() {
        let err = FitSpec::new(algorithm).t(1).ranks(2).run(&a, &b).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec, "{algorithm:?}: {err:#}");
    }
}

#[test]
fn empty_partition_is_rejected_by_tblars() {
    use calars::fit::NoopObserver;
    use calars::lars::tblars::fit_observed;
    let d = datasets::tiny(14);
    let mut cluster = SimCluster::new(2, HwParams::default(), ExecMode::Sequential);
    let empty = vec![Vec::new(), Vec::new()];
    let err = fit_observed(
        &d.a,
        &d.b,
        &empty,
        &TblarsOptions::default(),
        &mut cluster,
        &mut NoopObserver,
    )
    .unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidSpec, "{err:#}");
}

#[test]
fn stop_reason_words_round_trip() {
    for stop in [
        StopReason::TargetReached,
        StopReason::PoolExhausted,
        StopReason::Saturated,
        StopReason::RankDeficient,
        StopReason::EarlyStopped,
    ] {
        assert_eq!(StopReason::from_word(stop.word()), Some(stop));
    }
    assert_eq!(StopReason::from_word("nope"), None);
}
