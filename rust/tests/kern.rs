//! The kernel engine's contract, end to end:
//!
//! * blocked kern kernels vs the scalar `kern::reference` over awkward
//!   shapes (dimensions not multiples of the unroll width, zero/one
//!   columns, single rows);
//! * bit-identity across thread counts {1, 2, 4} with the kern kernels
//!   as the only implementation (regression guard for the canonical
//!   summation order being anchored at fixed chunk boundaries);
//! * the fused equiangular step against its two-pass decomposition,
//!   dense and sparse.

use calars::kern::{self, reference};
use calars::linalg::{CscMatrix, DenseMatrix, Matrix};
use calars::par::{self, ThreadPool};
use calars::rng::Pcg64;

fn dense(m: usize, n: usize, seed: u64) -> DenseMatrix {
    let mut rng = Pcg64::new(seed);
    DenseMatrix::from_fn(m, n, |_, _| rng.normal())
}

fn close(a: f64, b: f64, label: &str) {
    assert!(
        (a - b).abs() <= 1e-10 * (1.0 + b.abs()),
        "{label}: {a} vs {b}"
    );
}

#[test]
fn dense_kernels_match_reference_over_awkward_shapes() {
    // Shapes straddle the unroll width 4 in both dimensions, plus the
    // degenerate edges the blocking must not trip over.
    for &(m, n) in &[
        (1usize, 1usize),
        (1, 7),
        (2, 3),
        (3, 4),
        (4, 4),
        (5, 5),
        (6, 1),
        (7, 9),
        (8, 0),
        (0, 6),
        (9, 8),
        (13, 5),
        (33, 17),
    ] {
        let a = dense(m, n, (m * 101 + n + 1) as u64);
        let data = a.data().to_vec();
        let mut rng = Pcg64::new(7);
        let r: Vec<f64> = (0..m).map(|_| rng.normal()).collect();

        let mut got = vec![0.0; n];
        a.at_r(&r, &mut got);
        let mut want = vec![0.0; n];
        reference::at_r(&data, m, n, &r, &mut want);
        for (j, (g, w)) in got.iter().zip(&want).enumerate() {
            close(*g, *w, &format!("at_r ({m},{n}) col {j}"));
        }

        let norms = a.col_norms();
        let want = reference::col_sq_norms(&data, m, n);
        for (j, (g, w)) in norms.iter().zip(&want).enumerate() {
            close(*g, w.sqrt(), &format!("col_norms ({m},{n}) col {j}"));
        }

        if n == 0 {
            continue;
        }
        let cols: Vec<usize> = (0..n).step_by(2).collect();
        let w: Vec<f64> = cols.iter().map(|&j| (j as f64 * 0.3).sin() + 0.1).collect();
        let mut got = vec![0.0; m];
        a.gemv_cols(&cols, &w, &mut got);
        let mut want = vec![0.0; m];
        reference::gemv_cols(&data, m, n, &cols, &w, &mut want);
        for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
            close(*g, *ww, &format!("gemv_cols ({m},{n}) row {i}"));
        }

        let jj: Vec<usize> = (0..n).collect();
        let got = a.gram_block(&cols, &jj);
        let want = reference::gram_block(&data, m, n, &cols, &jj);
        for (g, w) in got.data().iter().zip(&want) {
            close(*g, *w, &format!("gram_block ({m},{n})"));
        }
    }
}

#[test]
fn sparse_kernels_match_dense_counterparts() {
    let mut rng = Pcg64::new(11);
    let m = 37;
    let n = 23;
    let cols: Vec<Vec<(usize, f64)>> = (0..n)
        .map(|_| {
            (0..m)
                .filter(|_| rng.uniform() < 0.3)
                .map(|i| (i, rng.normal()))
                .collect()
        })
        .collect();
    let sp = CscMatrix::from_columns(m, cols);
    let de = sp.to_dense();
    let r: Vec<f64> = (0..m).map(|i| (i as f64 * 0.7).cos()).collect();
    let (mut cs, mut cd) = (vec![0.0; n], vec![0.0; n]);
    sp.at_r(&r, &mut cs);
    de.at_r(&r, &mut cd);
    for (j, (a, b)) in cs.iter().zip(&cd).enumerate() {
        close(*a, *b, &format!("sparse at_r col {j}"));
    }
    let sel: Vec<usize> = (0..n).step_by(3).collect();
    let w: Vec<f64> = sel.iter().map(|&j| j as f64 * 0.1 - 0.4).collect();
    let (mut us, mut ud) = (vec![0.0; m], vec![0.0; m]);
    sp.gemv_cols(&sel, &w, &mut us);
    de.gemv_cols(&sel, &w, &mut ud);
    for (a, b) in us.iter().zip(&ud) {
        close(*a, *b, "sparse gemv_cols");
    }
    let gs = sp.gram_block(&sel, &sel);
    let gd = de.gram_block(&sel, &sel);
    for (a, b) in gs.data().iter().zip(gd.data()) {
        close(*a, *b, "sparse gram_block");
    }
    for (a, b) in sp.col_norms().iter().zip(de.col_norms()) {
        close(*a, b, "sparse col_norms");
    }
}

#[test]
fn fused_step_matches_two_pass_both_storages() {
    let de = dense(41, 13, 3);
    let cols = [0usize, 1, 5, 9, 12];
    let w = [1.0, -0.5, 0.25, 2.0, 0.125];
    for a in [Matrix::Dense(de.clone()), Matrix::Sparse(CscMatrix::from_dense(&de))] {
        let mut u = vec![0.0; 41];
        let mut av = vec![0.0; 13];
        a.fused_step(&cols, &w, &mut u, &mut av);
        let mut u2 = vec![0.0; 41];
        a.gemv_cols(&cols, &w, &mut u2);
        let mut av2 = vec![0.0; 13];
        a.at_r(&u2, &mut av2);
        for (x, y) in u.iter().zip(&u2) {
            close(*x, *y, "fused u");
        }
        for (x, y) in av.iter().zip(&av2) {
            close(*x, *y, "fused av");
        }
    }
}

#[test]
fn kern_kernels_bit_identical_across_thread_counts() {
    // Small grain forces many chunks; every chunked reduction must be
    // a pure function of the data, never of the thread count.
    let a = dense(513, 29, 9); // rows not a multiple of 4 or the grain
    let mut rng = Pcg64::new(10);
    let r: Vec<f64> = (0..513).map(|_| rng.normal()).collect();
    let cols: Vec<usize> = (0..29).step_by(2).collect();
    let w: Vec<f64> = cols.iter().map(|&j| (j as f64 * 0.21).sin()).collect();
    let run = |threads: usize| {
        let pool = ThreadPool::new(threads, 96);
        par::with_pool(&pool, || {
            let mut c = vec![0.0; 29];
            a.at_r(&r, &mut c);
            let g = a.gram_block(&cols, &cols);
            let mut u = vec![0.0; 513];
            let mut av = vec![0.0; 29];
            a.gemv_cols_at_r(&cols, &w, &mut u, &mut av);
            let mut b = a.clone();
            let norms = b.normalize_columns_with_norms();
            (c, g.data().to_vec(), u, av, norms)
        })
    };
    let base = run(1);
    for threads in [2usize, 4] {
        let got = run(threads);
        let pairs: [(&[f64], &[f64]); 5] = [
            (&base.0, &got.0),
            (&base.1, &got.1),
            (&base.2, &got.2),
            (&base.3, &got.3),
            (&base.4, &got.4),
        ];
        for (which, (b, g)) in pairs.iter().enumerate() {
            for (x, y) in b.iter().zip(g.iter()) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "kernel {which} diverged at threads={threads}"
                );
            }
        }
    }
}

#[test]
fn reference_gram_is_symmetric_sanity() {
    let a = dense(19, 6, 21);
    let all: Vec<usize> = (0..6).collect();
    let g = reference::gram_block(a.data(), 19, 6, &all, &all);
    for i in 0..6 {
        for j in 0..6 {
            close(g[i * 6 + j], g[j * 6 + i], "reference gram symmetry");
        }
    }
}

#[test]
fn unroll_width_is_the_documented_contract() {
    assert_eq!(kern::UNROLL, 4);
}
