//! Integration tests for `calars::obs`: the tracing-never-changes-
//! numerics contract (bit-identity with tracing on vs off, across
//! algorithms and thread counts) and the serving layer's metrics/trace
//! endpoints under concurrent load (valid Prometheus framing, monotone
//! counters, every echoed trace_id resolving at `/trace/<id>` or being
//! honestly evicted).

use calars::data::datasets;
use calars::fit::{Algorithm, FitSpec, Fitter, TraceObserver};
use calars::par::ThreadPool;
use calars::serve::{spawn_server, FitRequest, PredictRequest, Selector, ServeClient, ServeOptions};
use std::sync::Mutex;

/// Both tests toggle (or depend on) the process-global tracing flag
/// and the shared sink; serialize them so the test harness's thread
/// parallelism can't interleave the toggles.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    match GATE.lock() {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    }
}

/// Satellite: property — tracing is passive. For every algorithm in
/// the family and every thread-pool size, a traced fit returns the
/// same bits as an untraced one.
#[test]
fn tracing_on_off_is_bit_identical_across_family_and_threads() {
    let _g = gate();
    let ds = datasets::by_name("tiny", 42).expect("tiny exists");
    let specs = [
        FitSpec::new(Algorithm::Lars).t(8),
        FitSpec::new(Algorithm::Blars { b: 2 }).t(8).ranks(4),
        FitSpec::new(Algorithm::TBlars { b: 2, parts: 4 }).t(8),
        FitSpec::new(Algorithm::LassoLars { lambda_min: 1e-8 }).t(8),
    ];
    for threads in [1usize, 2, 4] {
        let pool = ThreadPool::new(threads, 64);
        calars::par::with_pool(&pool, || {
            for spec in &specs {
                calars::obs::set_enabled(false);
                let off = spec.run(&ds.a, &ds.b).expect("untraced fit succeeds");
                calars::obs::set_enabled(true);
                let mut tracer = TraceObserver::new();
                let on = spec.fit(&ds.a, &ds.b, &mut tracer).expect("traced fit succeeds");
                calars::obs::flush_thread();

                let what = format!("{} @ {threads} threads", spec.encode());
                assert_eq!(off.output.selected, on.output.selected, "{what}: selection");
                assert_eq!(
                    off.output.y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    on.output.y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{what}: fitted response"
                );
                assert_eq!(
                    off.output.residual_norms.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    on.output.residual_norms.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{what}: residual trace"
                );
                match (&off.coefs, &on.coefs) {
                    (Some(a), Some(b)) => assert_eq!(
                        a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{what}: coefficients"
                    ),
                    (None, None) => {}
                    other => panic!("{what}: coefs presence differs: {other:?}"),
                }
                // And the traced run actually recorded phase spans —
                // the equality above must not be vacuous.
                let spans = calars::obs::sink()
                    .get(tracer.trace_id())
                    .expect("traced fit left spans in the sink");
                assert!(
                    spans.iter().any(|s| s.phase.is_some()),
                    "{what}: no phase spans recorded"
                );
            }
        });
    }
    // Leave the flag the way an env-less process starts: enabled.
    calars::obs::set_enabled(true);
}

// ── a small Prometheus 0.0.4 text parser for the scrape test ────────

#[derive(Debug, Default)]
struct Family {
    kind: String,
    /// (labels-inside-braces, value) per sample line.
    samples: Vec<(String, f64)>,
}

/// Parse Prometheus text exposition strictly enough to catch framing
/// bugs: every sample must belong to a family introduced by exactly
/// one `# TYPE` line, and every value must parse as f64.
fn parse_prometheus(text: &str) -> std::collections::BTreeMap<String, Family> {
    let mut out: std::collections::BTreeMap<String, Family> = Default::default();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("TYPE line has a name").to_string();
            let kind = it.next().expect("TYPE line has a kind").to_string();
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind.as_str()),
                "unknown kind in {line:?}"
            );
            let prev = out.insert(name.clone(), Family { kind, samples: Vec::new() });
            assert!(prev.is_none(), "duplicate # TYPE for {name}");
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP
        }
        let (ident, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line {line:?}"));
        let value: f64 = value.parse().unwrap_or_else(|e| panic!("bad value in {line:?}: {e}"));
        let (name, labels) = match ident.split_once('{') {
            Some((n, l)) => (n.to_string(), l.trim_end_matches('}').to_string()),
            None => (ident.to_string(), String::new()),
        };
        // Histogram samples attach to their family's base name.
        let base = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .filter(|b| out.contains_key(*b) && out[*b].kind == "histogram")
            .unwrap_or(&name)
            .to_string();
        let fam = out
            .get_mut(&base)
            .unwrap_or_else(|| panic!("sample {name} has no # TYPE family"));
        fam.samples.push((format!("{name}|{labels}"), value));
    }
    out
}

fn counter_sum(fams: &std::collections::BTreeMap<String, Family>, name: &str) -> f64 {
    let f = fams.get(name).unwrap_or_else(|| panic!("{name} missing"));
    assert_eq!(f.kind, "counter", "{name}");
    f.samples.iter().map(|(_, v)| v).sum()
}

/// Pull the `"trace_id":"…"` echo out of a JSON response body.
fn trace_id_of(body: &str) -> String {
    let at = body.find("\"trace_id\":\"").unwrap_or_else(|| panic!("no trace_id in {body}"));
    let rest = &body[at + "\"trace_id\":\"".len()..];
    rest[..rest.find('"').unwrap()].to_string()
}

/// Satellite: hammer `/fit` + `/predict` from several connections
/// while scraping `/metrics`, then check the scrape parses as valid
/// Prometheus text, counters are monotone between two scrapes,
/// histograms are internally consistent, and every trace_id handed out
/// resolves at `/trace/<id>` (or the sink honestly reports eviction).
#[test]
fn metrics_and_traces_under_concurrent_load() {
    let _g = gate();
    calars::obs::set_enabled(true);
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        batch_window_us: 100,
        slow_ms: 0, // disabled: test latencies are noise
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr_string();

    // One model up front so /predict has a target.
    let mut client = ServeClient::connect(&addr).unwrap();
    let model = client
        .fit(&FitRequest { dataset: "tiny".into(), t: 6, ..Default::default() }, true)
        .unwrap();
    let dim = client.model_dim(model).unwrap();

    let (_, first) = client.request("GET", "/metrics", "").unwrap();
    let before = parse_prometheus(&first);

    // Four worker connections interleaving fits and predictions, each
    // collecting the trace ids echoed back.
    let mut joins = Vec::new();
    for w in 0..4u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || -> Vec<String> {
            let mut c = ServeClient::connect(&addr).unwrap();
            let mut ids = Vec::new();
            for i in 0..6usize {
                if i % 3 == 0 {
                    let fit = FitRequest {
                        dataset: "tiny".into(),
                        t: 4 + (w as usize % 3),
                        ..Default::default()
                    };
                    let (status, body) = c.request("POST", "/fit?wait=1", &fit.encode()).unwrap();
                    assert_eq!(status, 200, "{body}");
                    ids.push(trace_id_of(&body));
                } else {
                    let rows = vec![vec![0.25 * (w as f64) + i as f64; dim]];
                    let req = PredictRequest { model, selector: Selector::Step(4), rows };
                    let (status, body) = c.predict(&req).unwrap();
                    assert_eq!(status, 200, "{body}");
                    ids.push(trace_id_of(&body));
                }
                if i == 3 {
                    // Scrapes interleave with the load.
                    let (status, text) = c.request("GET", "/metrics", "").unwrap();
                    assert_eq!(status, 200);
                    parse_prometheus(&text); // must stay well-framed mid-load
                }
            }
            ids
        }));
    }
    let ids: Vec<String> = joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
    assert_eq!(ids.len(), 24);

    let (_, second) = client.request("GET", "/metrics", "").unwrap();
    let after = parse_prometheus(&second);

    // Counters are monotone across scrapes and account for the load.
    for name in [
        "calars_http_requests_total",
        "calars_engine_queries_total",
        "calars_fit_jobs_total",
    ] {
        assert!(
            counter_sum(&after, name) >= counter_sum(&before, name),
            "{name} went backwards"
        );
    }
    assert!(
        counter_sum(&after, "calars_http_requests_total")
            >= counter_sum(&before, "calars_http_requests_total") + 24.0,
        "the load's requests must be counted"
    );

    // Histogram consistency: cumulative buckets, +Inf == _count.
    let hist = after
        .get("calars_http_request_seconds")
        .expect("request latency histogram exported");
    assert_eq!(hist.kind, "histogram");
    let mut by_route: std::collections::BTreeMap<String, Vec<(f64, f64)>> = Default::default();
    let mut counts: std::collections::BTreeMap<String, f64> = Default::default();
    for (key, v) in &hist.samples {
        let (name, labels) = key.split_once('|').unwrap();
        let route = labels
            .split(',')
            .find(|kv| kv.starts_with("route="))
            .unwrap_or("route=?")
            .to_string();
        if name.ends_with("_bucket") {
            let le = labels.split("le=\"").nth(1).unwrap().trim_end_matches('"');
            let le = if le == "+Inf" { f64::INFINITY } else { le.parse().unwrap() };
            by_route.entry(route).or_default().push((le, *v));
        } else if name.ends_with("_count") {
            counts.insert(route, *v);
        }
    }
    assert!(!by_route.is_empty(), "no latency buckets in {second}");
    for (route, mut buckets) in by_route {
        buckets.sort_by(|a, b| a.0.total_cmp(&b.0));
        for w in buckets.windows(2) {
            assert!(w[0].1 <= w[1].1, "{route}: buckets not cumulative");
        }
        let inf = buckets.last().unwrap();
        assert!(inf.0.is_infinite(), "{route}: no +Inf bucket");
        assert_eq!(inf.1, counts[&route], "{route}: +Inf bucket != _count");
    }

    // The queue-wait histogram exists once fits have flowed through.
    assert_eq!(
        after.get("calars_fit_queue_wait_seconds").map(|f| f.kind.as_str()),
        Some("histogram"),
        "queue wait histogram exported"
    );

    // Every echoed trace id resolves to a chrome-trace document — or
    // the sink honestly reports evictions.
    let mut resolved = 0usize;
    for id in &ids {
        let (status, body) = client.request("GET", &format!("/trace/{id}"), "").unwrap();
        if status == 200 {
            assert!(body.contains("\"traceEvents\":["), "{body}");
            resolved += 1;
        } else {
            assert_eq!(status, 404, "{body}");
            assert!(
                calars::obs::sink().stats().evicted > 0,
                "404 for trace {id} without any reported eviction"
            );
        }
    }
    assert!(resolved > 0, "at least some traces must resolve");
    // A real (non-warm-reused) fit's trace must carry the fit-phase
    // spans, not just HTTP timing. t=10 is deeper than every stored
    // path (the load fits at most t=6), so this fit cannot warm-reuse.
    let deep = FitRequest { dataset: "tiny".into(), t: 10, ..Default::default() };
    let (status, body) = client.request("POST", "/fit?wait=1", &deep.encode()).unwrap();
    assert_eq!(status, 200, "{body}");
    let fit_trace = trace_id_of(&body);
    let (status, body) = client.request("GET", &format!("/trace/{fit_trace}"), "").unwrap();
    assert_eq!(status, 200, "a just-recorded trace must resolve: {body}");
    for needle in ["\"cat\":\"Corr\"", "\"cat\":\"Update\"", "queue_wait"] {
        assert!(body.contains(needle), "fit trace lacks {needle}: {body}");
    }
    assert!(
        body.contains("gram_panel_hit") || body.contains("gram_panel_miss"),
        "fit trace lacks Gram panel-store markers: {body}"
    );

    // Bad ids answer 4xx without wedging the connection.
    let (status, _) = client.request("GET", "/trace/zzzz", "").unwrap();
    assert_eq!(status, 400);
    let (status, _) = client.request("GET", "/healthz", "").unwrap();
    assert_eq!(status, 200);

    server.stop();
}

/// `/stats` and `/metrics` agree within one scrape pair on settled
/// counters (no in-flight work): the same snapshot feeds both.
#[test]
fn stats_and_metrics_agree_when_idle() {
    let _g = gate();
    calars::obs::set_enabled(true);
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        slow_ms: 0,
        ..Default::default()
    })
    .expect("server starts");
    let mut client = ServeClient::connect(&server.addr_string()).unwrap();
    client
        .fit(&FitRequest { dataset: "tiny".into(), t: 4, ..Default::default() }, true)
        .unwrap();

    let (_, stats) = client.request("GET", "/stats", "").unwrap();
    let (_, metrics) = client.request("GET", "/metrics", "").unwrap();
    let fams = parse_prometheus(&metrics);

    let grab = |key: &str| -> f64 {
        let needle = format!("\"{key}\":");
        let at = stats.find(&needle).unwrap_or_else(|| panic!("{key} missing in {stats}"))
            + needle.len();
        let rest = &stats[at..];
        let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
        rest[..end].parse().unwrap()
    };
    // Fit jobs are settled (the wait=1 fit completed before the
    // scrapes), so the queue counters cannot move between the two
    // requests' snapshots.
    let submitted = fams
        .get("calars_fit_jobs_total")
        .expect("fit jobs family")
        .samples
        .iter()
        .find(|(k, _)| k.contains("state=\"submitted\""))
        .map(|(_, v)| *v)
        .unwrap();
    assert_eq!(submitted, grab("submitted"), "stats vs metrics: submitted");
    assert_eq!(
        counter_sum(&fams, "calars_registry_inserted_total"),
        grab("inserted"),
        "stats vs metrics: registry inserts"
    );
    server.stop();
}
