//! Integration contract for `calars::batch` — batched multi-response
//! fitting:
//!
//! * **k=1 ≡ single fit, bitwise**, for every batching-capable
//!   algorithm (lockstep lars/lasso and the fallback family), across
//!   `CALARS_THREADS ∈ {1,2,4}` — property-tested over random
//!   dense/sparse problems;
//! * **thread-count invariance** of whole batches, under both the
//!   forced-scalar and the detected SIMD kernel backend;
//! * fallback algorithms match their sequential fits;
//! * typed errors for degenerate panels.

use calars::data::synthetic::{generate, SyntheticSpec};
use calars::data::{datasets, Dataset};
use calars::fit::{Algorithm, FitResult, FitSpec, Fitter, NoopObserver};
use calars::kern::simd::{self, KernBackend};
use calars::par::{self, ThreadPool};
use calars::proptest_lite::{check, Config};
use calars::rng::Pcg64;

/// The algorithms `fit_batch` accepts, with batch-safe knobs.
fn batch_specs(t: usize) -> Vec<(&'static str, FitSpec)> {
    vec![
        ("lars", FitSpec::new(Algorithm::Lars).t(t)),
        ("lasso", FitSpec::new(Algorithm::LassoLars { lambda_min: 1e-6 }).t(t)),
        ("omp", FitSpec::new(Algorithm::Omp).t(t)),
        ("fs", FitSpec::new(Algorithm::ForwardSelection).t(t)),
        ("blars", FitSpec::new(Algorithm::Blars { b: 2 }).t(t).ranks(2)),
    ]
}

/// Every output field as raw bits, so equality means bit-identity.
fn signature(fit: &FitResult) -> Vec<u64> {
    let out = &fit.output;
    let mut sig: Vec<u64> = vec![
        out.selected.len() as u64,
        out.cols_at_iter.len() as u64,
        out.stop as u64,
    ];
    sig.extend(out.selected.iter().map(|&c| c as u64));
    sig.extend(out.cols_at_iter.iter().map(|&c| c as u64));
    sig.extend(out.residual_norms.iter().map(|r| r.to_bits()));
    sig.extend(out.y.iter().map(|y| y.to_bits()));
    if let Some(path) = &fit.lasso {
        sig.push(path.drops as u64);
        for bp in &path.breakpoints {
            sig.push(bp.lambda.to_bits());
            sig.extend(bp.support.iter().map(|&c| c as u64));
        }
    }
    sig
}

fn responses(ds: &Dataset, k: usize, seed: u64) -> Vec<Vec<f64>> {
    let m = ds.a.nrows();
    let mut rng = Pcg64::new(seed);
    (0..k)
        .map(|i| {
            if i == 0 {
                ds.b.clone()
            } else {
                (0..m).map(|_| rng.normal()).collect()
            }
        })
        .collect()
}

#[test]
fn prop_k1_batch_is_bit_identical_to_single_fit_at_any_thread_count() {
    check(
        Config { cases: 8, seed: 0xBA7C4 },
        |rng, size| {
            let spec = SyntheticSpec {
                m: 40 + size * 15,
                n: 30 + size * 10,
                density: if rng.uniform() < 0.5 { 1.0 } else { 0.3 },
                col_skew: rng.uniform_range(0.0, 1.0),
                k_true: 4 + size / 2,
                noise: rng.uniform_range(0.0, 0.05),
            };
            generate(&spec, rng.next_u64())
        },
        |s| {
            let t = 6.min(s.a.ncols() / 3).max(2);
            for (label, spec) in batch_specs(t) {
                let solo = spec
                    .fit(&s.a, &s.b, &mut NoopObserver)
                    .map_err(|e| format!("{label}: solo fit failed: {e:#}"))?;
                for threads in [1usize, 2, 4] {
                    // Small grain forces multi-chunk execution even at
                    // this size.
                    let pool = ThreadPool::new(threads, 256);
                    let batch = par::with_pool(&pool, || {
                        spec.fit_batch(&s.a, std::slice::from_ref(&s.b))
                    })
                    .map_err(|e| format!("{label}: batch fit failed: {e:#}"))?;
                    if signature(&batch.fits[0]) != signature(&solo) {
                        return Err(format!(
                            "{label}: k=1 batch diverged from single fit at \
                             threads={threads}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn whole_batches_are_thread_count_invariant() {
    // Runs once under the forced-scalar kernel backend and once under
    // the widest detected vector backend: the thread-invariance
    // contract must hold under every ISA (pools constructed *inside*
    // with_backend so their workers capture the forced backend).
    let ds = datasets::tiny(21);
    let panel = responses(&ds, 6, 77);
    let mut backends = vec![KernBackend::Scalar];
    if KernBackend::detect() != KernBackend::Scalar {
        backends.push(KernBackend::detect());
    }
    for backend in backends {
        for (label, spec) in batch_specs(5) {
            let mut base: Option<Vec<Vec<u64>>> = None;
            for threads in [1usize, 2, 4] {
                let sigs = simd::with_backend(backend, || {
                    let pool = ThreadPool::new(threads, 256);
                    par::with_pool(&pool, || {
                        let batch = spec.fit_batch(&ds.a, &panel).expect(label);
                        batch.fits.iter().map(signature).collect::<Vec<_>>()
                    })
                });
                match &base {
                    None => base = Some(sigs),
                    Some(b) => assert_eq!(
                        &sigs,
                        b,
                        "{label}: diverged at threads={threads} under {}",
                        backend.name()
                    ),
                }
            }
        }
    }
}

#[test]
fn fallback_algorithms_match_their_sequential_fits() {
    // No lockstep core for omp/fs/blars — the batch must still return
    // exactly what k independent fits would.
    let ds = datasets::tiny_dense(3);
    let panel = responses(&ds, 4, 11);
    for (label, spec) in batch_specs(5) {
        let batch = spec.fit_batch(&ds.a, &panel).expect(label);
        assert_eq!(batch.fits.len(), panel.len(), "{label}");
        for (i, b) in panel.iter().enumerate() {
            let solo = spec.fit(&ds.a, b, &mut NoopObserver).expect(label);
            assert_eq!(
                signature(&batch.fits[i]),
                signature(&solo),
                "{label}: response {i} diverged from its sequential fit"
            );
        }
        assert_eq!(batch.shared.responses, panel.len(), "{label}");
    }
}

#[test]
fn degenerate_panels_answer_typed_errors() {
    let ds = datasets::tiny(5);
    let spec = FitSpec::new(Algorithm::Lars).t(4);
    let empty: Vec<Vec<f64>> = Vec::new();
    assert!(spec.fit_batch(&ds.a, &empty).is_err(), "empty panel");

    let short = vec![ds.b.clone(), vec![1.0; ds.a.nrows() - 1]];
    let err = spec.fit_batch(&ds.a, &short).unwrap_err();
    assert!(err.root().contains("response 1"), "wrong-length row names the response: {err:#}");

    let mut poisoned = vec![ds.b.clone(), ds.b.clone()];
    poisoned[1][0] = f64::NAN;
    let err = spec.fit_batch(&ds.a, &poisoned).unwrap_err();
    assert!(err.root().contains("response 1"), "NaN row names the response: {err:#}");
}
