//! Cross-module integration tests: the paper's qualitative claims as
//! executable assertions, plus failure injection.
//!
//! Uses the deprecated free-function shims deliberately — they
//! delegate to the `calars::fit` cores (bit-identity proven in
//! `tests/fit.rs`), so these double as shim regression coverage.
#![allow(deprecated)]

use calars::baselines::forward_selection::forward_selection;
use calars::cluster::{ExecMode, HwParams, SimCluster};
use calars::data::synthetic::{generate, SyntheticSpec};
use calars::data::{datasets, partition};
use calars::lars::blars::{blars, BlarsOptions};
use calars::lars::quality::precision;
use calars::lars::serial::{lars, LarsOptions};
use calars::lars::tblars::{tblars, TblarsOptions};
use calars::lars::StopReason;
use calars::linalg::{DenseMatrix, Matrix};

fn cluster(p: usize) -> SimCluster {
    SimCluster::new(p, HwParams::default(), ExecMode::Sequential)
}

// ── §10.1 claims ────────────────────────────────────────────────────

#[test]
fn blars_b1_precision_is_one_everywhere() {
    for seed in [1u64, 2, 3] {
        let d = datasets::tiny(seed);
        let reference = lars(&d.a, &d.b, &LarsOptions { t: 15, ..Default::default() });
        for p in [1usize, 4, 8] {
            let mut c = cluster(p);
            let out = blars(&d.a, &d.b, &BlarsOptions { t: 15, b: 1, ..Default::default() }, &mut c);
            assert_eq!(
                precision(&out.selected, &reference.selected),
                1.0,
                "seed {seed} P {p}"
            );
        }
    }
}

#[test]
fn blars_precision_degrades_with_b() {
    // Paper Fig. 4: precision of bLARS drops as b increases.
    let d = datasets::sector_like(4);
    let t = 40;
    let reference = lars(&d.a, &d.b, &LarsOptions { t, ..Default::default() });
    let prec = |b: usize| {
        let mut c = cluster(1);
        let out = blars(&d.a, &d.b, &BlarsOptions { t, b, ..Default::default() }, &mut c);
        precision(&out.selected, &reference.selected)
    };
    let p1 = prec(1);
    let p8 = prec(8);
    let p20 = prec(20);
    assert_eq!(p1, 1.0);
    assert!(p8 <= p1 + 1e-12);
    assert!(p20 <= p8 + 0.15, "precision should broadly decrease: p8={p8} p20={p20}");
}

#[test]
fn tblars_residual_tracks_lars() {
    // Paper Fig. 3: T-bLARS residual ≈ LARS residual for all (P, b).
    let d = datasets::tiny(5);
    let t = 18;
    let reference = lars(&d.a, &d.b, &LarsOptions { t, ..Default::default() });
    let r_ref = *reference.residual_norms.last().unwrap();
    for (p, b) in [(2usize, 2usize), (4, 3), (8, 2)] {
        let parts = partition::balanced_col_partition(&d.a, p);
        let mut c = cluster(p);
        let out = tblars(&d.a, &d.b, &parts, &TblarsOptions { t, b, ..Default::default() }, &mut c);
        let r_tb = *out.residual_norms.last().unwrap();
        assert!(
            r_tb <= r_ref * 1.35 + 1e-9,
            "P={p} b={b}: T-bLARS residual {r_tb} vs LARS {r_ref}"
        );
    }
}

#[test]
fn blars_residual_degrades_gracefully_with_b() {
    // The bLARS y-estimate itself lags LARS at equal column count
    // (coarser steps — visible in the paper's Fig. 3 as curves above
    // LARS). The fair support-quality measure is the LS refit on the
    // selected columns, which should stay within a modest factor.
    use calars::lars::path::{ls_coefficients, residual_norm};
    let d = datasets::tiny(6);
    let t = 18;
    let refit = |b: usize| {
        let mut c = cluster(1);
        let out = blars(&d.a, &d.b, &BlarsOptions { t, b, ..Default::default() }, &mut c);
        let coefs = ls_coefficients(&d.a, &out.selected, &d.b).expect("full rank");
        residual_norm(&d.a, &out.selected, &coefs, &d.b)
    };
    let norm_b = calars::linalg::norm2(&d.b);
    let r1 = refit(1);
    let r6 = refit(6);
    // b=1 ≡ LARS: near-exact recovery. b=6 trades fidelity (paper Fig. 3:
    // curves sit above LARS) but must still explain most of the signal.
    assert!(r1 <= 0.1 * norm_b, "b=1 should nearly fit: {r1} vs ‖b‖={norm_b}");
    assert!(
        r6 <= 0.4 * norm_b,
        "b=6 refit residual {r6} vs ‖b‖={norm_b} — support quality collapsed"
    );
    assert!(r6 >= r1 - 1e-12, "larger b should not fit better at equal t");
}

// ── Table 2 scaling claims ──────────────────────────────────────────

#[test]
fn blars_words_scale_with_n_tblars_with_m() {
    // Two datasets with swapped aspect ratios; same t, b, P.
    let wide = generate(
        &SyntheticSpec { m: 60, n: 600, density: 0.2, col_skew: 0.5, k_true: 10, noise: 0.02 },
        7,
    );
    let tall = generate(
        &SyntheticSpec { m: 600, n: 60, density: 0.2, col_skew: 0.5, k_true: 10, noise: 0.02 },
        7,
    );
    let (t, b, p) = (12, 2, 4);

    let words = |a: &Matrix, bv: &[f64], tb: bool| {
        let mut c = cluster(p);
        if tb {
            let parts = partition::balanced_col_partition(a, p);
            tblars(a, bv, &parts, &TblarsOptions { t, b, ..Default::default() }, &mut c);
        } else {
            blars(a, bv, &BlarsOptions { t, b, ..Default::default() }, &mut c);
        }
        c.counters().words as f64
    };

    // bLARS: words ∝ n → wide costs ≈ 10x tall.
    let bl_ratio = words(&wide.a, &wide.b, false) / words(&tall.a, &tall.b, false);
    assert!(bl_ratio > 3.0, "bLARS words should grow with n (ratio {bl_ratio})");
    // T-bLARS: words ∝ m → tall costs more than wide.
    let tb_ratio = words(&tall.a, &tall.b, true) / words(&wide.a, &wide.b, true);
    assert!(tb_ratio > 3.0, "T-bLARS words should grow with m (ratio {tb_ratio})");
}

#[test]
fn latency_reduction_factor_b_both_methods() {
    let d = datasets::tiny(8);
    let t = 24;
    let msgs_blars = |b: usize| {
        let mut c = cluster(8);
        blars(&d.a, &d.b, &BlarsOptions { t, b, ..Default::default() }, &mut c);
        c.counters().msgs as f64
    };
    let msgs_tblars = |b: usize| {
        let parts = partition::balanced_col_partition(&d.a, 8);
        let mut c = cluster(8);
        tblars(&d.a, &d.b, &parts, &TblarsOptions { t, b, ..Default::default() }, &mut c);
        c.counters().msgs as f64
    };
    let fns: [&dyn Fn(usize) -> f64; 2] = [&msgs_blars, &msgs_tblars];
    for f in fns {
        let m1 = f(1);
        let m4 = f(4);
        let ratio = m1 / m4;
        assert!(
            ratio > 2.0,
            "messages should drop ~b-fold (got {m1} -> {m4}, ratio {ratio:.2})"
        );
    }
}

// ── Baseline cross-checks ───────────────────────────────────────────

#[test]
fn lars_and_forward_selection_agree_on_strong_signal() {
    let s = generate(
        &SyntheticSpec { m: 120, n: 60, density: 1.0, col_skew: 0.0, k_true: 5, noise: 0.0 },
        9,
    );
    let la = lars(&s.a, &s.b, &LarsOptions { t: 5, ..Default::default() });
    let fs = forward_selection(&s.a, &s.b, 5);
    assert_eq!(la.selected_sorted(), {
        let mut f = fs.selected.clone();
        f.sort_unstable();
        f
    });
    assert_eq!(la.selected_sorted(), s.true_support);
}

// ── Failure injection ───────────────────────────────────────────────

#[test]
fn duplicate_columns_dont_crash_lars() {
    // Two identical columns: Gram is singular the moment both enter.
    let mut d = DenseMatrix::from_fn(40, 10, |i, j| ((i * 7 + j * 13) as f64).sin());
    for i in 0..40 {
        let v = d.get(i, 3);
        d.set(i, 7, v); // col 7 := col 3
    }
    d.normalize_columns();
    let a = Matrix::Dense(d);
    let b: Vec<f64> = (0..40).map(|i| ((i * 3) as f64).cos()).collect();
    let out = lars(&a, &b, &LarsOptions { t: 9, ..Default::default() });
    // Must terminate cleanly — either completing or reporting rank issues.
    assert!(
        matches!(out.stop, StopReason::RankDeficient | StopReason::TargetReached | StopReason::Saturated),
        "{:?}",
        out.stop
    );
    assert!(out.selected.len() <= 9);
}

#[test]
fn duplicate_columns_dont_crash_tblars() {
    let mut d = DenseMatrix::from_fn(40, 16, |i, j| ((i * 5 + j * 11) as f64).sin());
    for i in 0..40 {
        let v = d.get(i, 2);
        d.set(i, 9, v);
    }
    d.normalize_columns();
    let a = Matrix::Dense(d);
    let b: Vec<f64> = (0..40).map(|i| ((i * 3) as f64).cos()).collect();
    let parts = partition::balanced_col_partition(&a, 4);
    let mut c = cluster(4);
    let out = tblars(&a, &b, &parts, &TblarsOptions { t: 10, b: 2, ..Default::default() }, &mut c);
    assert!(out.selected.len() <= 10);
    // No duplicates in the selection.
    let mut s = out.selected.clone();
    s.sort_unstable();
    s.dedup();
    assert_eq!(s.len(), out.selected.len());
}

#[test]
fn zero_response_saturates_immediately() {
    let d = datasets::tiny_dense(10);
    let zero = vec![0.0; d.a.nrows()];
    let out = lars(&d.a, &zero, &LarsOptions { t: 5, ..Default::default() });
    assert_eq!(out.stop, StopReason::Saturated);
    assert!(out.selected.is_empty());
    let mut c = cluster(2);
    let out = blars(&d.a, &zero, &BlarsOptions { t: 5, b: 2, ..Default::default() }, &mut c);
    assert_eq!(out.stop, StopReason::Saturated);
}

#[test]
fn t_larger_than_pool_stops_cleanly() {
    let s = generate(
        &SyntheticSpec { m: 50, n: 8, density: 1.0, col_skew: 0.0, k_true: 3, noise: 0.01 },
        11,
    );
    let out = lars(&s.a, &s.b, &LarsOptions { t: 100, ..Default::default() });
    assert!(out.selected.len() <= 8);
    let parts = partition::balanced_col_partition(&s.a, 2);
    let mut c = cluster(2);
    let out = tblars(&s.a, &s.b, &parts, &TblarsOptions { t: 100, b: 3, ..Default::default() }, &mut c);
    assert!(out.selected.len() <= 8);
}

#[test]
fn experiments_quick_suite_runs() {
    // Every table/figure driver must at least execute in quick mode.
    let sweep = calars::config::SweepConfig::quick();
    for id in calars::experiments::ALL_IDS {
        let report = calars::experiments::run_by_id(id, &sweep, true)
            .unwrap_or_else(|e| panic!("{id} failed: {e}"));
        assert!(report.len() > 100, "{id} produced a suspiciously short report");
    }
}
