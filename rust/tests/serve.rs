//! Integration tests for the L4 serving subsystem: protocol round
//! trips, registry semantics, the engine's bit-exactness contract
//! (property-tested), persistence, and the HTTP front end end to end.

use calars::data::synthetic::{generate, SyntheticSpec};
use calars::fit::{Algorithm, FitSpec, Fitter, SnapshotObserver};
use calars::lars::path::{densify, ls_coefficients, PathSnapshot};
use calars::linalg::{dot, Matrix};
use calars::proptest_lite::{check, Config};
use calars::rng::Pcg64;
use calars::select::Criterion;
use calars::serve::{
    run_load, spawn_server, BatchFitRequest, FitRequest, LoadOptions, ModelMeta, ModelRegistry,
    PredictRequest, PredictionEngine, Query, SelectRequest, Selector, ServeClient, ServeOptions,
};
use std::sync::Arc;
use std::time::Duration;

/// Snapshot a LARS fit through the estimator API (what the old
/// `lars_with_snapshot` entry point did, now via `SnapshotObserver`).
fn lars_snapshot(a: &Matrix, b: &[f64], t: usize) -> PathSnapshot {
    let mut obs = SnapshotObserver::new();
    FitSpec::new(Algorithm::Lars).t(t).fit(a, b, &mut obs).expect("fit succeeds");
    obs.into_snapshot().expect("snapshot captured")
}

fn problem(rng: &mut Pcg64, size: usize) -> (calars::data::synthetic::Synthetic, usize) {
    let m = 30 + size * 5;
    let n = 15 + size * 4;
    let spec = SyntheticSpec {
        m,
        n,
        density: if rng.uniform() < 0.5 { 1.0 } else { 0.4 },
        col_skew: rng.uniform_range(0.0, 1.0),
        k_true: 3 + size / 4,
        noise: rng.uniform_range(0.0, 0.1),
    };
    let t = 2 + size.min(8);
    (generate(&spec, rng.next_u64()), t)
}

/// The acceptance-criteria property: a prediction served from a stored
/// path at any breakpoint is bit-identical to evaluating the fitter's
/// returned coefficients at the same step.
#[test]
fn prop_served_predictions_bit_identical_to_direct_eval() {
    check(
        Config { cases: 24, seed: 0x5E21E },
        |rng, size| {
            let (s, t) = problem(rng, size);
            let queries: Vec<Vec<f64>> = (0..3)
                .map(|_| (0..s.a.ncols()).map(|_| rng.normal()).collect())
                .collect();
            (s, t, queries)
        },
        |(s, t, queries)| {
            let snap = lars_snapshot(&s.a, &s.b, *t);
            let registry = Arc::new(ModelRegistry::new(4));
            let id = registry.insert(ModelMeta::named("prop"), snap.clone());
            let engine = PredictionEngine::new(registry, 32);
            for step in 0..snap.len() {
                // Direct evaluation: an independent LS solve on the
                // step's support, densified, dotted with the query.
                let support = &snap.steps[step].support;
                let direct = if support.is_empty() {
                    vec![0.0; s.a.ncols()]
                } else {
                    let coefs = ls_coefficients(&s.a, support, &s.b)
                        .ok_or("rank-deficient prefix in test problem")?;
                    densify(s.a.ncols(), support, &coefs)
                };
                for x in queries {
                    let served = engine
                        .predict(&Query { model: id, selector: Selector::Step(step), x: x.clone() })
                        .map_err(|e| format!("predict failed: {e:#}"))?;
                    let expect = dot(x, &direct);
                    if served.to_bits() != expect.to_bits() {
                        return Err(format!(
                            "step {step}: served {served:?} != direct {expect:?}"
                        ));
                    }
                    // And at the exact stored λ, identical again.
                    let lam = snap.steps[step].lambda;
                    let via_lambda = engine
                        .predict(&Query {
                            model: id,
                            selector: Selector::Lambda(lam),
                            x: x.clone(),
                        })
                        .map_err(|e| format!("lambda predict failed: {e:#}"))?;
                    if via_lambda.to_bits() != expect.to_bits() {
                        // Duplicate λ values select the first matching
                        // breakpoint; only require bit-equality when this
                        // step is the first with its λ.
                        let first = snap
                            .steps
                            .iter()
                            .position(|st| st.lambda == lam)
                            .unwrap();
                        if first == step {
                            return Err(format!(
                                "λ={lam}: served {via_lambda:?} != direct {expect:?}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_predict_request_round_trips_exactly() {
    check(
        Config { cases: 48, seed: 0xB0D1 },
        |rng, size| {
            let rows = (0..1 + size / 8)
                .map(|_| {
                    (0..1 + size)
                        .map(|_| rng.normal() * 10f64.powi((rng.below(9) as i32) - 4))
                        .collect::<Vec<f64>>()
                })
                .collect::<Vec<_>>();
            let selector = if rng.uniform() < 0.5 {
                Selector::Step(rng.below(100))
            } else {
                Selector::Lambda(rng.uniform() * 3.0)
            };
            PredictRequest { model: rng.next_u64(), selector, rows }
        },
        |req| {
            let back = PredictRequest::parse(&req.encode())
                .map_err(|e| format!("parse failed: {e:#}"))?;
            if &back == req {
                Ok(())
            } else {
                Err(format!("round trip changed the request: {back:?} vs {req:?}"))
            }
        },
    );
}

#[test]
fn registry_persistence_round_trip_preserves_predictions() {
    let s = generate(
        &SyntheticSpec { m: 60, n: 30, density: 1.0, col_skew: 0.3, k_true: 5, noise: 0.02 },
        77,
    );
    let snap = lars_snapshot(&s.a, &s.b, 8);
    let registry = Arc::new(ModelRegistry::new(8));
    let mut meta = ModelMeta::named("persisted");
    meta.dataset = "synthetic-77".into();
    let id = registry.insert(meta, snap);

    let dir = std::env::temp_dir().join(format!("calars-serve-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    assert_eq!(registry.save_dir(&dir).unwrap(), 1);
    let reloaded = Arc::new(ModelRegistry::load_dir(&dir, 8).unwrap());
    std::fs::remove_dir_all(&dir).ok();

    let rec_a = registry.get(id).unwrap();
    let rec_b = reloaded.get(id).unwrap();
    assert_eq!(rec_a.snapshot, rec_b.snapshot, "snapshot survives disk bit-exactly");
    assert_eq!(rec_a.meta, rec_b.meta);

    let e1 = PredictionEngine::new(registry, 8);
    let e2 = PredictionEngine::new(reloaded, 8);
    let mut rng = Pcg64::new(5);
    for step in [0usize, 3, 8] {
        let x: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let q = Query { model: id, selector: Selector::Step(step), x };
        assert_eq!(
            e1.predict(&q).unwrap().to_bits(),
            e2.predict(&q).unwrap().to_bits(),
            "reloaded registry serves identical bits"
        );
    }
}

#[test]
fn http_end_to_end_fit_predict_models_stats() {
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        batch_window_us: 100,
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr_string();
    let mut client = ServeClient::connect(&addr).unwrap();

    // Health first.
    let (status, body) = client.request("GET", "/healthz", "").unwrap();
    assert_eq!(status, 200, "{body}");

    // Fit a model synchronously.
    let fit = FitRequest { dataset: "tiny".into(), t: 8, ..Default::default() };
    let model = client.fit(&fit, true).unwrap();
    let dim = client.model_dim(model).unwrap();
    assert!(dim > 0);

    // Server-side predictions must match a local fit of the same
    // deterministic dataset, bit for bit (f64 Display round-trips).
    let ds = calars::data::datasets::by_name("tiny", 42).unwrap();
    let snap = lars_snapshot(&ds.a, &ds.b, 8);
    assert_eq!(dim, ds.a.ncols());
    let mut rng = Pcg64::new(9);
    let rows: Vec<Vec<f64>> = (0..5).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect();
    let req = PredictRequest { model, selector: Selector::Step(8), rows: rows.clone() };
    let (status, body) = client.predict(&req).unwrap();
    assert_eq!(status, 200, "{body}");
    let served: Vec<f64> = body
        .split_once('[')
        .unwrap()
        .1
        .trim_end_matches(|c| c == '}' || c == ']')
        .split(',')
        .map(|t| t.parse().unwrap())
        .collect();
    let dense = snap.dense_coefs(8).unwrap();
    assert_eq!(served.len(), rows.len());
    for (x, y) in rows.iter().zip(&served) {
        assert_eq!(y.to_bits(), dot(x, &dense).to_bits(), "HTTP round trip is exact");
    }

    // Error paths are per-request, connection stays usable.
    let bad = PredictRequest { model: 999, selector: Selector::Step(0), rows: rows.clone() };
    let (status, _) = client.predict(&bad).unwrap();
    assert_eq!(status, 400);
    let (status, _) = client.request("GET", "/nope", "").unwrap();
    assert_eq!(status, 404);

    // Listings and counters.
    let (status, body) = client.request("GET", "/models", "").unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("\"dataset\":\"tiny\""), "{body}");
    let (status, body) = client.request("GET", "/stats", "").unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("\"engine\""), "{body}");
    assert!(body.contains("\"queries\""), "{body}");

    // A second, smaller fit of the same family is warm-reused.
    let fit2 = FitRequest { dataset: "tiny".into(), t: 4, ..Default::default() };
    let model2 = client.fit(&fit2, true).unwrap();
    assert_eq!(model2, model, "covering path reused instead of refitting");

    server.stop();
}

/// Scan a `/stats` body for `"key":<u64>` inside a named section
/// (several sections repeat counter names, e.g. `gram_cache` and
/// `cv_cache`).
fn section_u64(body: &str, section: &str, key: &str) -> u64 {
    let marker = format!("\"{section}\":{{");
    let at = body
        .find(&marker)
        .unwrap_or_else(|| panic!("section {section} missing in {body}"));
    stats_u64(&body[at..], key)
}

/// Scan a `/stats` body for `"key":<u64>`.
fn stats_u64(body: &str, key: &str) -> u64 {
    let needle = format!("\"{key}\":");
    let at = body.find(&needle).unwrap_or_else(|| panic!("{key} missing in {body}")) + needle.len();
    let rest = &body[at..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().unwrap()
}

#[test]
fn gram_cache_counters_surface_through_stats_on_warm_refit() {
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        fit_workers: 1, // strict fit ordering: second fit sees the first's panels
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr_string();
    let mut client = ServeClient::connect(&addr).unwrap();

    // First fit: dataset registered, panels materialized (all misses).
    let fit = FitRequest { dataset: "tiny".into(), t: 4, ..Default::default() };
    client.fit(&fit, true).unwrap();
    let (status, body) = client.request("GET", "/stats", "").unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"gram_cache\""), "{body}");
    assert_eq!(stats_u64(&body, "datasets"), 1, "{body}");
    let first_hits = stats_u64(&body, "panel_hits");
    assert!(stats_u64(&body, "panels") > 0, "first fit must cache panels: {body}");

    // The /datasets listing exposes the cached entry with its
    // column-norm summary (the training scale for raw features).
    let (status, body) = client.request("GET", "/datasets", "").unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"name\":\"tiny\""), "{body}");
    assert!(body.contains("\"norms\""), "{body}");
    assert_eq!(stats_u64(&body, "count"), 300, "tiny has 300 columns: {body}");

    // Deeper refit of the same family: warm-start snapshot too short,
    // so the fit reruns — dataset load is skipped and the repeated
    // selection prefix hits the cached panels.
    let deeper = FitRequest { dataset: "tiny".into(), t: 8, ..Default::default() };
    client.fit(&deeper, true).unwrap();
    let (_, body) = client.request("GET", "/stats", "").unwrap();
    assert_eq!(stats_u64(&body, "dataset_hits"), 1, "{body}");
    assert!(
        stats_u64(&body, "panel_hits") > first_hits,
        "warm refit must hit cached Gram panels: {body}"
    );

    server.stop();
}

#[test]
fn http_load_generator_round_trip() {
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        batch_window_us: 100,
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr_string();

    let mut client = ServeClient::connect(&addr).unwrap();
    let model = client
        .fit(&FitRequest { dataset: "tiny".into(), t: 6, ..Default::default() }, true)
        .unwrap();
    let dim = client.model_dim(model).unwrap();

    let report = run_load(
        &addr,
        &LoadOptions {
            requests: 40,
            concurrency: 4,
            rows: 3,
            model,
            selector: Selector::Step(6),
            dim,
            seed: 1,
        },
    )
    .unwrap();
    assert_eq!(report.errors, 0, "no request may fail");
    assert_eq!(report.requests, 40);
    assert_eq!(report.rows, 120);
    assert!(report.request_throughput > 0.0);
    assert!(report.latency.p99 >= report.latency.p50);

    // The batcher must have grouped at least some concurrent rows.
    let (_, stats) = client.request("GET", "/stats", "").unwrap();
    assert!(stats.contains("\"batches\""), "{stats}");

    server.stop();
}

#[test]
fn oneshot_shutdown_contract() {
    // Servers spawned in-process always honor /shutdown (that is how
    // ServerHandle::stop works); exercise the client-visible side.
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr_string();
    let mut client = ServeClient::connect(&addr).unwrap();
    client.shutdown().expect("shutdown accepted");
    drop(client);
    server.stop(); // returns promptly: the accept loop already exited

    // The port stops answering shortly after.
    std::thread::sleep(Duration::from_millis(50));
    let mut alive = false;
    if let Ok(mut c) = ServeClient::connect(&addr) {
        if c.request("GET", "/healthz", "").is_ok() {
            alive = true;
        }
    }
    assert!(!alive, "server must stop accepting after shutdown");
}

#[test]
fn lambda_interpolation_matches_manual_linear_blend() {
    let s = generate(
        &SyntheticSpec { m: 70, n: 25, density: 1.0, col_skew: 0.0, k_true: 4, noise: 0.05 },
        31,
    );
    let snap = lars_snapshot(&s.a, &s.b, 6);
    let registry = Arc::new(ModelRegistry::new(4));
    let id = registry.insert(ModelMeta::named("interp"), snap.clone());
    let engine = PredictionEngine::new(registry, 16);

    // Midpoint of a segment with distinct λ endpoints.
    let seg = snap
        .steps
        .windows(2)
        .position(|w| w[0].lambda > w[1].lambda)
        .expect("a non-degenerate segment exists");
    let (hi, lo) = (&snap.steps[seg], &snap.steps[seg + 1]);
    let lam = 0.5 * (hi.lambda + lo.lambda);
    let t = (hi.lambda - lam) / (hi.lambda - lo.lambda);
    let a = densify(snap.n, &hi.support, &hi.coefs);
    let b = densify(snap.n, &lo.support, &lo.coefs);
    let blend: Vec<f64> = a.iter().zip(&b).map(|(ai, bi)| ai + t * (bi - ai)).collect();

    let mut rng = Pcg64::new(3);
    let x: Vec<f64> = (0..snap.n).map(|_| rng.normal()).collect();
    let served = engine
        .predict(&Query { model: id, selector: Selector::Lambda(lam), x: x.clone() })
        .unwrap();
    assert_eq!(served.to_bits(), dot(&x, &blend).to_bits());
}

/// Snapshot sanity on a second algorithm: the snapshot observer works
/// for the parallel fitters too.
#[test]
fn blars_snapshot_hook_serves() {
    let ds = calars::data::datasets::by_name("tiny", 7).unwrap();
    let mut obs = SnapshotObserver::new();
    let result = FitSpec::new(Algorithm::Blars { b: 2 })
        .t(8)
        .ranks(4)
        .fit(&ds.a, &ds.b, &mut obs)
        .expect("fit succeeds");
    let out = &result.output;
    let snap = obs.into_snapshot().expect("snapshot captured");
    assert_eq!(snap.max_support(), out.selected.len());
    let registry = Arc::new(ModelRegistry::new(2));
    let id = registry.insert(ModelMeta::named("blars"), snap);
    let engine = PredictionEngine::new(registry, 8);
    let x = vec![0.5; ds.a.ncols()];
    assert!(engine
        .predict(&Query { model: id, selector: Selector::Step(4), x })
        .unwrap()
        .is_finite());
}

/// The LASSO path serves its exact breakpoints: the snapshot observer
/// preserves λ breakpoints for `Algorithm::LassoLars` fits.
#[test]
fn lasso_snapshot_serves_exact_breakpoints() {
    let s = generate(
        &SyntheticSpec { m: 60, n: 20, density: 1.0, col_skew: 0.0, k_true: 4, noise: 0.05 },
        13,
    );
    let mut obs = SnapshotObserver::new();
    let result = FitSpec::new(Algorithm::LassoLars { lambda_min: 1e-8 })
        .t(8)
        .fit(&s.a, &s.b, &mut obs)
        .expect("fit succeeds");
    let path = result.lasso.as_ref().expect("lasso path present");
    let snap = obs.into_snapshot().expect("snapshot captured");
    assert_eq!(snap, PathSnapshot::from_lasso(s.a.ncols(), path));
    let registry = Arc::new(ModelRegistry::new(2));
    let id = registry.insert(ModelMeta::named("lasso"), snap);
    let engine = PredictionEngine::new(registry, 8);
    let mut rng = Pcg64::new(11);
    let x: Vec<f64> = (0..s.a.ncols()).map(|_| rng.normal()).collect();
    for (k, bp) in path.breakpoints.iter().enumerate() {
        let served = engine
            .predict(&Query { model: id, selector: Selector::Step(k), x: x.clone() })
            .unwrap();
        assert_eq!(served.to_bits(), dot(&x, &bp.x).to_bits());
    }
}

/// Tentpole: `POST /select` chooses a path step by an in-sample
/// criterion, records it in the model metadata, and the `auto`
/// prediction selector serves exactly that step's bits.
#[test]
fn select_endpoint_in_sample_and_auto_selector() {
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr_string();
    let mut client = ServeClient::connect(&addr).unwrap();
    let model = client
        .fit(&FitRequest { dataset: "tiny".into(), t: 8, ..Default::default() }, true)
        .unwrap();
    let dim = client.model_dim(model).unwrap();

    // /select with cp answers the chosen step plus the score trace.
    let step = client
        .select(&SelectRequest { model, criterion: Criterion::Cp, k: 5, seed: 0 })
        .unwrap() as usize;
    assert!(step <= 8, "chosen step {step} must lie on the stored path");
    let (status, body) = client
        .request("POST", "/select", &format!("model {model}\ncriterion cp\n"))
        .unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"scores\":[{"), "{body}");

    // The selection token surfaces in /models (precomputed at fit
    // time and refreshed by /select).
    let (_, body) = client.request("GET", "/models", "").unwrap();
    assert!(body.contains(&format!("cp={step}")), "{body}");
    assert!(body.contains("\"rows\":120"), "tiny has 120 rows: {body}");

    // `auto cp` predictions are bit-identical to the chosen step.
    let mut rng = Pcg64::new(21);
    let rows: Vec<Vec<f64>> = (0..3).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect();
    let grab = |body: &str| -> Vec<f64> {
        body.split_once('[')
            .unwrap()
            .1
            .trim_end_matches(|c| c == '}' || c == ']')
            .split(',')
            .map(|t| t.parse().unwrap())
            .collect()
    };
    let (status, via_auto) = client
        .predict(&PredictRequest {
            model,
            selector: Selector::Auto(Criterion::Cp),
            rows: rows.clone(),
        })
        .unwrap();
    assert_eq!(status, 200, "{via_auto}");
    let (status, via_step) = client
        .predict(&PredictRequest { model, selector: Selector::Step(step), rows: rows.clone() })
        .unwrap();
    assert_eq!(status, 200, "{via_step}");
    for (a, b) in grab(&via_auto).iter().zip(&grab(&via_step)) {
        assert_eq!(a.to_bits(), b.to_bits(), "auto must serve the criterion's step exactly");
    }

    // `auto cv` cannot resolve lazily: typed 4xx/5xx, connection lives.
    let (status, body) = client
        .predict(&PredictRequest {
            model,
            selector: Selector::Auto(Criterion::Cv),
            rows: rows.clone(),
        })
        .unwrap();
    assert!(status >= 400, "{body}");
    let (status, _) = client.request("GET", "/healthz", "").unwrap();
    assert_eq!(status, 200);
    server.stop();
}

/// Tentpole acceptance: CV selection through `/select` — fold fits run
/// through the GramCache (per-fold entries), repeats answer from the
/// cached selection token, and a deeper family refit's CV demonstrably
/// hits the cached fold Gram panels.
#[test]
fn select_endpoint_cv_reuses_gram_cache_across_refits() {
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        fit_workers: 1,
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr_string();
    let mut client = ServeClient::connect(&addr).unwrap();

    let m1 = client
        .fit(&FitRequest { dataset: "tiny".into(), t: 4, ..Default::default() }, true)
        .unwrap();
    let req = SelectRequest { model: m1, criterion: Criterion::Cv, k: 4, seed: 1 };
    let step1 = client.select(&req).unwrap();
    let (_, stats) = client.request("GET", "/stats", "").unwrap();
    // Fold shards live in the dedicated cv_cache, NOT the main
    // GramCache (they must never evict real datasets).
    assert_eq!(section_u64(&stats, "gram_cache", "datasets"), 1, "{stats}");
    assert_eq!(section_u64(&stats, "cv_cache", "datasets"), 4, "4 fold entries: {stats}");
    let cv_hits_first = section_u64(&stats, "cv_cache", "panel_hits");

    // Identical repeat: answered from the cached selection token.
    let (status, body) = client.request("POST", "/select", &req.encode()).unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"cached\":true"), "{body}");
    assert!(body.contains(&format!("\"step\":{step1}")), "{body}");

    // Deeper refit of the same family: its CV fold fits repeat the
    // fold selection prefixes, which must now hit the cached panels.
    let m2 = client
        .fit(&FitRequest { dataset: "tiny".into(), t: 8, ..Default::default() }, true)
        .unwrap();
    assert_ne!(m1, m2, "deeper fit is a new model");
    let req2 = SelectRequest { model: m2, criterion: Criterion::Cv, k: 4, seed: 1 };
    let _ = client.select(&req2).unwrap();
    let (_, stats) = client.request("GET", "/stats", "").unwrap();
    assert!(
        section_u64(&stats, "cv_cache", "panel_hits") > cv_hits_first,
        "deeper CV must reuse fold Gram panels: {stats}"
    );
    assert_eq!(
        section_u64(&stats, "cv_cache", "datasets"),
        4,
        "fold entries reused, not duplicated: {stats}"
    );

    // The CV token lands in the model metadata.
    let (_, models) = client.request("GET", "/models", "").unwrap();
    assert!(models.contains("cv4.1="), "{models}");
    server.stop();
}

/// Satellite: a T-bLARS model (whose observer events carry NaN γ/λ)
/// must never leak a bare `NaN`/`inf` token into the JSON endpoints.
#[test]
fn tblars_model_emits_valid_json_everywhere() {
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr_string();
    let mut client = ServeClient::connect(&addr).unwrap();
    let fit = FitRequest {
        dataset: "tiny".into(),
        algo: "tblars".into(),
        t: 6,
        b: 2,
        p: 4,
        ..Default::default()
    };
    client.fit(&fit, true).unwrap();
    for path in ["/models", "/stats", "/datasets"] {
        let (status, body) = client.request("GET", path, "").unwrap();
        assert_eq!(status, 200, "{path}: {body}");
        for bad in ["NaN", "nan,", ":inf", "-inf"] {
            assert!(!body.contains(bad), "{path} leaked {bad:?}: {body}");
        }
    }
    server.stop();
}

/// Satellite: a malformed `/fit` body answers HTTP 4xx and keeps the
/// connection alive — never a panic or a dropped connection.
#[test]
fn malformed_fit_body_returns_4xx_not_dropped_connection() {
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr_string();
    let mut client = ServeClient::connect(&addr).unwrap();

    for (body, what) in [
        ("bogus_key 1\n", "unknown key"),
        ("t notanumber\n", "non-numeric t"),
        ("algo frobnicate\n", "unknown algorithm"),
        ("t 0\n", "zero t (InvalidSpec)"),
        ("algo blars\nb 0\n", "zero block size (InvalidSpec)"),
    ] {
        let (status, resp) = client.request("POST", "/fit", body).unwrap();
        assert!(
            (400..500).contains(&status),
            "{what}: expected 4xx, got {status} ({resp})"
        );
        assert!(resp.contains("error"), "{what}: body should explain: {resp}");
    }

    // The same connection still serves valid requests afterwards.
    let (status, _) = client.request("GET", "/healthz", "").unwrap();
    assert_eq!(status, 200, "connection must survive the bad requests");
    server.stop();
}

/// Satellite: `/models` exposes the algorithm, the full FitSpec, and
/// the stop reason from the registry metadata.
#[test]
fn models_listing_reports_spec_and_stop_reason() {
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr_string();
    let mut client = ServeClient::connect(&addr).unwrap();
    let fit = FitRequest { dataset: "tiny".into(), t: 6, ..Default::default() };
    client.fit(&fit, true).unwrap();
    let (status, body) = client.request("GET", "/models", "").unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("\"algo\":\"lars\""), "{body}");
    assert!(body.contains("\"stop\":\"target_reached\""), "{body}");
    assert!(body.contains("\"spec\":\"algo=lars t=6"), "{body}");
    assert!(body.contains("\"seed\":42"), "{body}");
    server.stop();
}

/// Bulk `POST /fit` end to end: a body with `y` rows fits the whole
/// response panel in one lockstep batch, registers every model in one
/// registry transaction, and answers with the ids, the shared-work
/// ledger, and a trace id.
#[test]
fn http_bulk_fit_registers_panel_models() {
    let server = spawn_server(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr_string();
    let mut client = ServeClient::connect(&addr).unwrap();

    let ds = calars::data::datasets::by_name("tiny", 42).unwrap();
    let mut rng = Pcg64::new(31);
    let responses: Vec<Vec<f64>> = (0..3)
        .map(|i| {
            if i == 0 {
                ds.b.clone()
            } else {
                (0..ds.a.nrows()).map(|_| rng.normal()).collect()
            }
        })
        .collect();
    let base =
        FitRequest { name: "panel".into(), dataset: "tiny".into(), t: 6, ..Default::default() };
    let req = BatchFitRequest {
        base,
        names: vec!["west".into(), "east".into(), "north".into()],
        responses,
    };
    let (status, body) = client.request("POST", "/fit", &req.encode()).unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"trace_id\":\""), "every JSON response echoes a trace id: {body}");
    assert!(body.contains("\"count\":3"), "{body}");
    assert!(body.contains("\"shared\":{\"responses\":3"), "{body}");
    assert!(body.contains("\"passes_saved\":"), "{body}");

    // All three models are listed, named, and flagged as batch-fitted
    // (the response fingerprint in the stored spec keeps them out of
    // ordinary warm-start families).
    let (status, models) = client.request("GET", "/models", "").unwrap();
    assert_eq!(status, 200);
    for name in ["west", "east", "north"] {
        assert!(models.contains(&format!("\"name\":\"{name}\"")), "{models}");
    }
    assert!(models.contains(" batch="), "{models}");

    // An ordinary /fit of the same family must run (or warm-reuse) a
    // dataset-response fit — never answer from a batch model.
    let fit = FitRequest { dataset: "tiny".into(), t: 6, ..Default::default() };
    let model = client.fit(&fit, true).unwrap();
    let (_, stats) = client.request("GET", "/stats", "").unwrap();
    assert_eq!(
        section_u64(&stats, "registry", "warm_reused"),
        0,
        "plain fit must not be warm-answered by a batch model: {stats}"
    );
    assert!(model > 0);

    // Malformed bulk bodies answer 4xx and keep the connection alive.
    let (status, resp) = client.request("POST", "/fit", "y 1 2\ny 3\n").unwrap();
    assert!((400..500).contains(&status), "ragged panel: {status} ({resp})");
    let (status, _) = client.request("GET", "/healthz", "").unwrap();
    assert_eq!(status, 200);
    server.stop();
}
