//! NaN/∞ totality regressions for every `partial_cmp(..).unwrap()`
//! site replaced by `total_cmp` (the PANIC-on-NaN class the audit's
//! DET-CMP rule now bans outright).
//!
//! Each test feeds non-finite values through a touched comparator path
//! and asserts it neither panics nor loses determinism: degenerate
//! inputs must surface as ordinary values, typed errors, or clean stop
//! reasons — never as an abort.

use calars::baselines::omp;
use calars::baselines::stagewise::stagewise;
use calars::fit::observers::NoopObserver;
use calars::lars::StopReason;
use calars::linalg::select::{argmax_b_by, max_b_abs};
use calars::linalg::{DenseMatrix, Matrix};
use calars::metrics::{LatencyStats, TimingSummary};

/// A small well-conditioned design plus a response we can poison.
fn toy(m: usize, n: usize) -> (Matrix, Vec<f64>) {
    // Deterministic, full-rank-ish: shifted cosines plus a diagonal
    // kick so no column is degenerate.
    let d = DenseMatrix::from_fn(m, n, |i, j| {
        ((i * n + j) as f64 * 0.7311).cos() + if i % n == j { 1.5 } else { 0.0 }
    });
    let b: Vec<f64> = (0..m).map(|i| (i as f64 * 0.19).sin() + 1.0).collect();
    (Matrix::Dense(d), b)
}

#[test]
fn timing_summary_orders_nan_samples_without_panicking() {
    // Before the total_cmp fix this sort_by panicked on NaN.
    let s = TimingSummary::from_samples(vec![3.0, f64::NAN, 1.0, f64::INFINITY, 2.0]);
    assert_eq!(s.best, 1.0, "finite minimum survives NaN neighbours");
    // total_cmp orders NaN above +inf, so the worst slot is NaN.
    assert!(s.worst.is_nan());
}

#[test]
fn latency_stats_order_nan_samples_without_panicking() {
    let s = LatencyStats::from_samples(vec![0.2, f64::NAN, 0.1, f64::NEG_INFINITY]);
    assert_eq!(s.count, 4);
    // -inf sorts first under the total order; percentiles stay defined.
    assert_eq!(s.p50, 0.1);
}

#[test]
fn timing_summary_is_deterministic_across_nan_permutations() {
    // total_cmp is a total order: any permutation of the same multiset
    // must sort to the same vector, so best/median agree bit-for-bit.
    let a = TimingSummary::from_samples(vec![f64::NAN, 2.0, 1.0, 3.0]);
    let b = TimingSummary::from_samples(vec![3.0, 1.0, f64::NAN, 2.0]);
    assert_eq!(a.best.to_bits(), b.best.to_bits());
    assert_eq!(a.median.to_bits(), b.median.to_bits());
}

#[test]
fn argselect_handles_nan_and_infinite_keys() {
    // linalg::select's partial_cmp(..).unwrap_or(Equal) comparator is
    // now total_cmp: NaN keys order deterministically instead of
    // corrupting the partition.
    let v = [1.0, f64::NAN, 5.0, f64::INFINITY, -2.0, 3.0];
    let top2 = argmax_b_by(v.len(), 2, |i| v[i]);
    assert_eq!(top2.len(), 2);
    // NaN sorts above +inf under totalOrder, so it wins the argmax —
    // deterministically — and +inf takes the second slot.
    assert!(top2.contains(&1), "NaN key is ordered, not dropped: {top2:?}");
    assert!(top2.contains(&3), "+inf is the second-largest key: {top2:?}");
    // And the same keys again give the same answer.
    assert_eq!(top2, argmax_b_by(v.len(), 2, |i| v[i]));
    // max_b_abs must also survive (|NaN| is NaN).
    let _ = max_b_abs(&v, 3);
}

#[test]
fn omp_with_nan_response_stops_cleanly() {
    let (a, mut b) = toy(12, 6);
    b[3] = f64::NAN;
    // check_fit_inputs screens tol but not b, so the NaN reaches the
    // correlation argmax. Under the old partial_cmp comparator that
    // argmax panicked; under total_cmp the NaN keys order and the run
    // completes (or errors) — and does so identically every time.
    let r1 = omp::fit_observed(&a, &b, 4, 1e-12, &mut NoopObserver);
    let r2 = omp::fit_observed(&a, &b, 4, 1e-12, &mut NoopObserver);
    match (r1, r2) {
        (Ok((o1, _)), Ok((o2, _))) => {
            assert_eq!(o1.selected, o2.selected, "NaN pick must be deterministic");
            assert_eq!(o1.stop, o2.stop);
        }
        (Err(_), Err(_)) => {} // a typed error is equally acceptable — just no panic
        _ => panic!("two identical NaN fits disagreed on Ok vs Err"),
    }
}

#[test]
fn forward_selection_with_infinite_response_does_not_panic() {
    let (a, mut b) = toy(12, 6);
    b[0] = f64::INFINITY;
    let result = calars::baselines::forward_selection::fit_observed(
        &a,
        &b,
        4,
        1e-12,
        &mut NoopObserver,
    );
    // Either outcome is fine; the regression is the absent panic.
    let _ = result;
}

#[test]
fn stagewise_with_nan_response_terminates_without_panic() {
    let (a, mut b) = toy(10, 5);
    b[2] = f64::NAN;
    // Stagewise has no Cholesky to catch the poison; it must simply
    // run its (bounded) steps without the comparator aborting.
    let out = stagewise(&a, &b, 0.01, 50, 1e-9);
    assert!(out.steps <= 50);
}

#[test]
fn baselines_still_agree_on_finite_inputs() {
    // The total_cmp swap must not change behaviour on finite data:
    // for distinct finite keys total_cmp and partial_cmp coincide.
    let (a, b) = toy(16, 8);
    let (out, _) = omp::fit_observed(&a, &b, 4, 1e-12, &mut NoopObserver).expect("finite fit");
    assert_eq!(out.selected.len(), 4);
    assert_eq!(out.stop, StopReason::TargetReached);
    let again = omp::fit_observed(&a, &b, 4, 1e-12, &mut NoopObserver).expect("finite fit");
    assert_eq!(out.selected, again.0.selected);
}
