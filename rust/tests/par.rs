//! The shared-memory execution layer's contract, end to end:
//!
//! * pool mechanics — ordered results, panic propagation, nested
//!   fork-join, the threads=1 inline path;
//! * the determinism guarantee the rest of the crate builds on —
//!   **bit-identical fitter outputs under `CALARS_THREADS ∈ {1,2,4}`**
//!   for LARS, bLARS (serial + cluster) and T-bLARS, dense and sparse,
//!   via `par::with_pool` so all three thread counts run in one
//!   process.
//!
//! The deprecated free-function shims are used deliberately here: they
//! delegate to the same `calars::fit` cores (bit-identity is proven in
//! `tests/fit.rs`), and exercising them keeps the shims covered.
#![allow(deprecated)]

use calars::cluster::{ExecMode, HwParams, SimCluster};
use calars::data::{datasets, partition};
use calars::lars::blars::{blars, BlarsOptions};
use calars::lars::serial::{blars_serial, lars, LarsOptions};
use calars::lars::tblars::{tblars, TblarsOptions};
use calars::lars::LarsOutput;
use calars::par::{self, ThreadPool};
use calars::proptest_lite::{check, Config};

fn pool(threads: usize) -> ThreadPool {
    ThreadPool::new(threads, par::DEFAULT_MIN_CHUNK)
}

// ── Pool mechanics ──────────────────────────────────────────────────

#[test]
fn results_come_back_in_task_order() {
    let p = pool(4);
    let out = p.run(
        (0..100)
            .map(|i| {
                move || {
                    // Stagger finish times so scheduling order ≠ task order.
                    if i % 7 == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                    i * i
                }
            })
            .collect::<Vec<_>>(),
    );
    assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
}

#[test]
fn threads1_executes_inline_on_caller() {
    let p = pool(1);
    assert!(p.is_inline());
    let caller = std::thread::current().id();
    let ids = p.run((0..8).map(|_| move || std::thread::current().id()).collect::<Vec<_>>());
    assert!(ids.iter().all(|&id| id == caller), "threads=1 must never leave the caller");
}

#[test]
fn worker_panic_propagates_and_pool_survives() {
    let p = pool(2);
    let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        p.run(
            (0..8)
                .map(|i| {
                    move || {
                        if i == 5 {
                            panic!("worker task {i} failed");
                        }
                        i
                    }
                })
                .collect::<Vec<_>>(),
        )
    }));
    assert!(attempt.is_err(), "the join must re-raise the task panic");
    // The pool keeps serving after a task panic.
    let out = p.run((0..8).map(|i| move || i + 1).collect::<Vec<_>>());
    assert_eq!(out, (1..9).collect::<Vec<_>>());
}

#[test]
fn nested_fork_join_runs_inline_without_deadlock() {
    let p = pool(4);
    let pref = &p;
    let out = p.run(
        (0..8)
            .map(|i| {
                move || {
                    // A task forking again must not wait on its own pool.
                    let inner =
                        pref.run((0..16).map(|j| move || i * 100 + j).collect::<Vec<_>>());
                    inner.iter().sum::<usize>()
                }
            })
            .collect::<Vec<_>>(),
    );
    for (i, &s) in out.iter().enumerate() {
        assert_eq!(s, (0..16).map(|j| i * 100 + j).sum::<usize>());
    }
}

#[test]
fn with_pool_scopes_kernel_execution() {
    let p = pool(3);
    let (inside, inside_chunk) = par::with_pool(&p, || (par::threads(), par::min_chunk()));
    assert_eq!(inside, 3);
    assert_eq!(inside_chunk, par::DEFAULT_MIN_CHUNK);
}

// ── Cross-fitter determinism: CALARS_THREADS ∈ {1, 2, 4} ───────────

fn assert_bit_identical(a: &LarsOutput, b: &LarsOutput, label: &str) {
    assert_eq!(a.selected, b.selected, "{label}: selection changed");
    assert_eq!(a.stop, b.stop, "{label}: stop reason changed");
    assert_eq!(
        a.residual_norms.len(),
        b.residual_norms.len(),
        "{label}: path length changed"
    );
    for (x, y) in a.residual_norms.iter().zip(&b.residual_norms) {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: residual bits changed");
    }
    for (x, y) in a.y.iter().zip(&b.y) {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: fitted-response bits changed");
    }
}

/// Run `f` under pools of 1, 2 and 4 threads (same grain) and demand
/// bit-identical outputs.
fn identical_under_thread_counts(label: &str, f: impl Fn() -> LarsOutput) {
    let base = par::with_pool(&pool(1), &f);
    for threads in [2usize, 4] {
        let out = par::with_pool(&pool(threads), &f);
        assert_bit_identical(&base, &out, &format!("{label} threads={threads}"));
    }
}

#[test]
fn lars_bit_identical_across_thread_counts_dense() {
    // year_like is tall-dense: at_r really splits into many chunks.
    let d = datasets::year_like(3);
    identical_under_thread_counts("lars/year", || {
        lars(&d.a, &d.b, &LarsOptions { t: 16, ..Default::default() })
    });
}

#[test]
fn blars_serial_bit_identical_across_thread_counts_sparse() {
    let d = datasets::sector_like(4);
    identical_under_thread_counts("blars_serial/sector", || {
        blars_serial(&d.a, &d.b, &LarsOptions { t: 20, b: 4, ..Default::default() })
    });
}

#[test]
fn cluster_blars_bit_identical_across_thread_counts() {
    let d = datasets::tiny(5);
    for mode in [ExecMode::Sequential, ExecMode::Threaded] {
        identical_under_thread_counts("blars/cluster", || {
            let mut cluster = SimCluster::new(4, HwParams::default(), mode);
            blars(&d.a, &d.b, &BlarsOptions { t: 12, b: 3, ..Default::default() }, &mut cluster)
        });
    }
}

#[test]
fn tblars_bit_identical_across_thread_counts() {
    let d = datasets::tiny(6);
    let parts = partition::balanced_col_partition(&d.a, 4);
    for mode in [ExecMode::Sequential, ExecMode::Threaded] {
        identical_under_thread_counts("tblars", || {
            let mut cluster = SimCluster::new(4, HwParams::default(), mode);
            tblars(
                &d.a,
                &d.b,
                &parts,
                &TblarsOptions { t: 10, b: 2, ..Default::default() },
                &mut cluster,
            )
        });
    }
}

#[test]
fn prop_random_problems_thread_count_invariant() {
    // Property form over random dense/sparse problems: the whole fit
    // (selection, residual path, fitted response) is a pure function
    // of the data — never of the thread count.
    use calars::data::synthetic::{generate, SyntheticSpec};
    check(
        Config { cases: 10, seed: 0x9A7A11E1 },
        |rng, size| {
            let spec = SyntheticSpec {
                m: 40 + size * 20,
                n: 30 + size * 10,
                density: if rng.uniform() < 0.5 { 1.0 } else { 0.25 },
                col_skew: rng.uniform_range(0.0, 1.0),
                k_true: 4 + size / 3,
                noise: rng.uniform_range(0.0, 0.05),
            };
            generate(&spec, rng.next_u64())
        },
        |s| {
            let t = 8.min(s.a.ncols() / 2).max(2);
            // Small grain forces multi-chunk execution even at this size.
            let run = |threads: usize| {
                let p = ThreadPool::new(threads, 256);
                par::with_pool(&p, || {
                    lars(&s.a, &s.b, &LarsOptions { t, ..Default::default() })
                })
            };
            let base = run(1);
            for threads in [2usize, 4] {
                let out = run(threads);
                if base.selected != out.selected {
                    return Err(format!("selection diverged at threads={threads}"));
                }
                for (x, y) in base.y.iter().zip(&out.y) {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("y bits diverged at threads={threads}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn serving_batch_bit_identical_under_pool() {
    // The engine's exactness contract must survive pool execution: a
    // batched predict equals the unbatched one bit for bit, at any
    // thread count.
    use calars::fit::{Algorithm, FitSpec, Fitter, SnapshotObserver};
    use calars::serve::{ModelMeta, ModelRegistry, PredictionEngine, Query, Selector};
    use std::sync::Arc;

    let d = datasets::tiny_dense(8);
    let mut snap_obs = SnapshotObserver::new();
    FitSpec::new(Algorithm::Lars).t(8).fit(&d.a, &d.b, &mut snap_obs).expect("fit");
    let snap = snap_obs.into_snapshot().expect("snapshot captured");
    let n = d.a.ncols();
    let registry = Arc::new(ModelRegistry::new(4));
    let id = registry.insert(ModelMeta::named("par-test"), snap);
    let engine = PredictionEngine::new(registry, 16);
    let queries: Vec<Query> = (0..64)
        .map(|i| Query {
            model: id,
            selector: if i % 2 == 0 { Selector::Step(4) } else { Selector::Step(8) },
            x: (0..n).map(|j| ((i * j) as f64 * 0.01).sin()).collect(),
        })
        .collect();
    let run = |threads: usize| {
        let p = pool(threads);
        par::with_pool(&p, || {
            engine
                .predict_batch(&queries)
                .into_iter()
                .map(|r| r.unwrap())
                .collect::<Vec<f64>>()
        })
    };
    let base = run(1);
    for (q, &batched) in queries.iter().zip(&base) {
        let single = engine.predict(q).unwrap();
        assert_eq!(single.to_bits(), batched.to_bits(), "batch vs single mismatch");
    }
    for threads in [2usize, 4] {
        let got = run(threads);
        for (x, y) in base.iter().zip(&got) {
            assert_eq!(x.to_bits(), y.to_bits(), "threads={threads} changed a served bit");
        }
    }
}
