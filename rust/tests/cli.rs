//! CLI smoke tests: the launcher binary end to end.

use std::process::Command;

fn calars(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_calars"))
        .args(args)
        .output()
        .expect("binary runs")
}

#[test]
fn no_args_prints_usage() {
    let out = calars(&[]);
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("USAGE"));
    assert!(s.contains("calars run"));
}

#[test]
fn run_lars_tiny() {
    let out = calars(&["run", "--algo", "lars", "--dataset", "tiny", "--t", "8"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("selected 8 columns"), "{s}");
    assert!(s.contains("TargetReached"));
}

#[test]
fn run_blars_reports_cluster_stats() {
    let out = calars(&[
        "run", "--algo", "blars", "--dataset", "tiny", "--t", "8", "--b", "2", "--p", "4",
    ]);
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("simulated time"));
    assert!(s.contains("breakdown:"));
}

#[test]
fn run_tblars_threaded_mode() {
    let out = calars(&[
        "run", "--algo", "tblars", "--dataset", "tiny", "--t", "6", "--b", "2", "--p", "4",
        "--threads",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("selected 6 columns"));
}

#[test]
fn run_lasso_reports_path() {
    let out = calars(&[
        "run", "--algo", "lasso", "--dataset", "tiny", "--t", "8", "--lambda-min", "1e-6",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("lasso path:"), "{s}");
    assert!(s.contains("breakpoints"), "{s}");
}

#[test]
fn run_omp_baseline_through_fit_api() {
    let out = calars(&["run", "--algo", "omp", "--dataset", "tiny", "--t", "6"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("selected 6 columns"), "{s}");
}

#[test]
fn select_cv_picks_a_step_deterministically() {
    let args = [
        "select", "--dataset", "tiny", "--t", "16", "--criterion", "cv", "--k", "4",
        "--cv-seed", "1",
    ];
    let out = calars(&args);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(s.contains("criterion cv"), "{s}");
    assert!(s.contains("<- best"), "{s}");
    assert!(s.contains("serve step"), "{s}");
    // Same invocation under a different thread count: identical stdout
    // (the acceptance criterion's CLI face).
    let out2 = Command::new(env!("CARGO_BIN_EXE_calars"))
        .args(args)
        .env("CALARS_THREADS", "2")
        .output()
        .expect("binary runs");
    assert!(out2.status.success());
    let s2 = String::from_utf8_lossy(&out2.stdout).to_string();
    // Strip the timing lines (wall time legitimately varies).
    let strip = |s: &str| {
        s.lines()
            .filter(|l| !l.contains("in ") && !l.contains("total"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(strip(&s), strip(&s2), "CV selection must not depend on thread count");
}

#[test]
fn select_in_sample_criterion_reports_scores() {
    let out = calars(&["select", "--dataset", "tiny", "--t", "10", "--criterion", "bic"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("criterion bic"), "{s}");
    assert!(s.contains("df"), "{s}");
}

#[test]
fn select_unknown_criterion_fails_cleanly() {
    let out = calars(&["select", "--dataset", "tiny", "--criterion", "r2"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown criterion"));
}

#[test]
fn run_unknown_algo_fails_cleanly() {
    let out = calars(&["run", "--algo", "ridge", "--dataset", "tiny"]);
    assert!(!out.status.success());
    let s = String::from_utf8_lossy(&out.stderr);
    assert!(s.contains("unknown algorithm"), "{s}");
}

#[test]
fn run_progress_flag_emits_iteration_lines() {
    let out = calars(&[
        "run", "--algo", "lars", "--dataset", "tiny", "--t", "5", "--progress",
    ]);
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stderr);
    assert!(s.contains("[fit]"), "progress lines go to stderr: {s}");
}

#[test]
fn exp_table3_quick() {
    let out = calars(&["exp", "table3", "--quick"]);
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("Table 3"));
    assert!(s.contains("sector_like"));
}

#[test]
fn unknown_command_fails() {
    let out = calars(&["frobnicate"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn unknown_dataset_fails() {
    let out = calars(&["run", "--dataset", "nope"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown dataset"));
}

#[test]
fn unknown_experiment_fails() {
    let out = calars(&["exp", "fig99"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown experiment"));
}

#[test]
fn info_lists_datasets() {
    let out = calars(&["info"]);
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("dataset registry"));
    assert!(s.contains("e2006_log1p_like"));
    assert!(s.contains("parallel execution:"), "{s}");
}

#[test]
fn info_json_reports_machine_shape() {
    let out = calars(&["info", "--json"]);
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    for key in
        ["\"version\"", "\"cores\"", "\"threads\"", "\"min_chunk\"", "\"isa\"", "\"features\""]
    {
        assert!(s.contains(key), "missing {key} in {s}");
    }
}

#[test]
fn par_flags_accepted_and_deterministic() {
    let run = |threads: &str| {
        let out = calars(&[
            "run", "--algo", "lars", "--dataset", "tiny", "--t", "8", "--par-threads", threads,
        ]);
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout)
            .lines()
            .find(|l| l.starts_with("first 10 selections"))
            .expect("selection line")
            .to_string()
    };
    let s1 = run("1");
    assert_eq!(s1, run("2"), "thread count changed the selection");
    assert_eq!(s1, run("4"), "thread count changed the selection");
}

#[test]
fn bad_par_flags_fail() {
    let out = calars(&["info", "--par-min-chunk", "0"]);
    assert!(!out.status.success());
    let out = calars(&["info", "--par-threads", "lots"]);
    assert!(!out.status.success());
}
