//! Integration contract for `calars::kern::simd` — runtime-dispatched
//! ISA backends under the determinism contract:
//!
//! * every available backend matches the blocked-scalar canonical
//!   order on awkward shapes (empty, single-element, lengths that are
//!   not multiples of any lane width) — bit-identical except the
//!   documented AVX-512 `dot`/`sq_norm` pair, which is 1e-9-gated
//!   against `kern::reference`;
//! * the cross-backend matrix: for any two available backends, all
//!   kernels agree bitwise except `dot`/`sq_norm` when one side is a
//!   divergent backend, where agreement is ≤ 1e-9 relative;
//! * thread-count invariance holds under every backend;
//! * pools capture the constructing thread's backend;
//! * the `CALARS_ISA` / `--isa` knob on the binary: forced scalar
//!   fallback is honored and reported, unknown or unsupported names
//!   are hard errors.

use calars::kern::reference;
use calars::kern::simd::{self, KernBackend};
use calars::linalg::DenseMatrix;
use calars::par::{self, ThreadPool};
use calars::rng::Pcg64;
use std::process::Command;

fn randvec(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::new(seed);
    (0..len).map(|_| rng.normal()).collect()
}

/// Relative agreement at the kernel divergence gate.
fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * b.abs().max(1.0)
}

/// Every dispatched kernel's output for one `(m, n)` panel shape,
/// computed under one forced backend.
struct KernelRun {
    dot: f64,
    sq_norm: f64,
    axpy: Vec<f64>,
    dot_idx: f64,
    sparse_dot: f64,
    scatter: Vec<f64>,
    at_r: Vec<f64>,
    col_norms: Vec<f64>,
    gram: Vec<f64>,
    cols_dot: Vec<f64>,
    fused_u: Vec<f64>,
    fused_av: Vec<f64>,
    multi_at_r: Vec<Vec<f64>>,
    multi_us: Vec<Vec<f64>>,
    multi_avs: Vec<Vec<f64>>,
}

fn run_kernels(backend: KernBackend, m: usize, n: usize, seed: u64) -> KernelRun {
    simd::with_backend(backend, || {
        let data = randvec(m * n, seed);
        let r = randvec(m, seed + 1);
        let x = randvec(m * n + 3, seed + 2);
        let y0 = randvec(m * n + 3, seed + 3);
        // Column subset with a deliberately ragged size.
        let cols: Vec<usize> = (0..n).step_by(3).collect();
        let w = randvec(cols.len(), seed + 4);
        // Sparse column: strided row indices (empty when m == 0).
        let srows: Vec<u32> = (0..m as u32).step_by(2).collect();
        let svals = randvec(srows.len(), seed + 5);

        let dot = simd::dot(&x, &y0);
        let sq_norm = simd::sq_norm(&x);
        let mut axpy = y0.clone();
        simd::axpy(0.37, &x, &mut axpy);
        let dot_idx = if m > 0 { simd::dot_idx(&data[..n], &cols, &w) } else { 0.0 };
        let sparse_dot = simd::sparse_dot(&srows, &svals, &r);
        let mut scatter = vec![0.0; m];
        simd::scatter_axpy(1.5, &srows, &svals, &mut scatter);
        let mut at_r = vec![0.0; n];
        simd::at_r_panel(&data, n, &r, &mut at_r);
        let mut col_norms = vec![0.0; n];
        simd::col_sq_norms_panel(&data, n, &mut col_norms);
        let ii: Vec<usize> = (0..n).step_by(2).collect();
        let jj: Vec<usize> = (0..n).collect();
        let mut gram = vec![0.0; ii.len() * jj.len()];
        let mut pi = vec![0.0; 4 * ii.len()];
        let mut pj = vec![0.0; 4 * jj.len()];
        simd::gram_panel(&data, n, &ii, &jj, &mut pi, &mut pj, &mut gram);
        let mut cols_dot = vec![0.0; cols.len()];
        simd::cols_dot_panel(&data, n, &cols, &r, &mut cols_dot);
        let mut fused_u = vec![0.0; m];
        let mut fused_av = vec![0.0; n];
        simd::fused_step_panel(&data, n, &cols, &w, &mut fused_u, &mut fused_av);

        let k = 3;
        let rs_own: Vec<Vec<f64>> = (0..k).map(|i| randvec(m, seed + 10 + i as u64)).collect();
        let rs: Vec<&[f64]> = rs_own.iter().map(|v| v.as_slice()).collect();
        let mut multi_at_r = vec![vec![0.0; n]; k];
        {
            let mut accs: Vec<&mut [f64]> =
                multi_at_r.iter_mut().map(|v| v.as_mut_slice()).collect();
            simd::at_r_multi_panel(&data, n, &rs, &mut accs);
        }
        let cols_own: Vec<Vec<usize>> =
            (0..k).map(|i| ((i % n.max(1)).min(n)..n).step_by(2).collect()).collect();
        let ws_own: Vec<Vec<f64>> = cols_own
            .iter()
            .enumerate()
            .map(|(i, c)| randvec(c.len(), seed + 20 + i as u64))
            .collect();
        let mcols: Vec<&[usize]> = cols_own.iter().map(|v| v.as_slice()).collect();
        let ws: Vec<&[f64]> = ws_own.iter().map(|v| v.as_slice()).collect();
        let mut multi_us = vec![vec![0.0; m]; k];
        let mut multi_avs = vec![vec![0.0; n]; k];
        {
            let mut u_sl: Vec<&mut [f64]> =
                multi_us.iter_mut().map(|v| v.as_mut_slice()).collect();
            let mut av_sl: Vec<&mut [f64]> =
                multi_avs.iter_mut().map(|v| v.as_mut_slice()).collect();
            simd::fused_step_multi_panel(&data, n, &mcols, &ws, &mut u_sl, &mut av_sl);
        }

        KernelRun {
            dot,
            sq_norm,
            axpy,
            dot_idx,
            sparse_dot,
            scatter,
            at_r,
            col_norms,
            gram,
            cols_dot,
            fused_u,
            fused_av,
            multi_at_r,
            multi_us,
            multi_avs,
        }
    })
}

fn assert_bits(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

const SHAPES: &[(usize, usize)] =
    &[(0, 5), (1, 5), (2, 3), (3, 7), (4, 4), (5, 0), (5, 1), (7, 8), (13, 9), (16, 16), (23, 11)];

#[test]
fn every_available_backend_matches_the_scalar_canonical_order() {
    for backend in KernBackend::available() {
        for (si, &(m, n)) in SHAPES.iter().enumerate() {
            let seed = 1 + si as u64 * 100;
            let got = run_kernels(backend, m, n, seed);
            let want = run_kernels(KernBackend::Scalar, m, n, seed);
            let ctx = format!("{} ({m},{n})", backend.name());
            if backend.bit_identical_to_scalar() {
                assert_eq!(got.dot.to_bits(), want.dot.to_bits(), "{ctx}: dot");
                assert_eq!(got.sq_norm.to_bits(), want.sq_norm.to_bits(), "{ctx}: sq_norm");
            } else {
                assert!(close(got.dot, want.dot), "{ctx}: dot {} vs {}", got.dot, want.dot);
                assert!(
                    close(got.sq_norm, want.sq_norm),
                    "{ctx}: sq_norm {} vs {}",
                    got.sq_norm,
                    want.sq_norm
                );
            }
            // Every other kernel is bit-identical on every backend.
            assert_bits(&got.axpy, &want.axpy, &format!("{ctx}: axpy"));
            assert_eq!(got.dot_idx.to_bits(), want.dot_idx.to_bits(), "{ctx}: dot_idx");
            assert_eq!(got.sparse_dot.to_bits(), want.sparse_dot.to_bits(), "{ctx}: sparse_dot");
            assert_bits(&got.scatter, &want.scatter, &format!("{ctx}: scatter_axpy"));
            assert_bits(&got.at_r, &want.at_r, &format!("{ctx}: at_r_panel"));
            assert_bits(&got.col_norms, &want.col_norms, &format!("{ctx}: col_sq_norms_panel"));
            assert_bits(&got.gram, &want.gram, &format!("{ctx}: gram_panel"));
            assert_bits(&got.cols_dot, &want.cols_dot, &format!("{ctx}: cols_dot_panel"));
            assert_bits(&got.fused_u, &want.fused_u, &format!("{ctx}: fused_step u"));
            assert_bits(&got.fused_av, &want.fused_av, &format!("{ctx}: fused_step av"));
            for k in 0..got.multi_at_r.len() {
                assert_bits(
                    &got.multi_at_r[k],
                    &want.multi_at_r[k],
                    &format!("{ctx}: at_r_multi[{k}]"),
                );
                assert_bits(&got.multi_us[k], &want.multi_us[k], &format!("{ctx}: multi u[{k}]"));
                assert_bits(
                    &got.multi_avs[k],
                    &want.multi_avs[k],
                    &format!("{ctx}: multi av[{k}]"),
                );
            }
        }
    }
}

#[test]
fn every_available_backend_stays_within_the_reference_gate() {
    // Against the naive one-accumulator mathematical definition the
    // blocked order legitimately differs in rounding — the contract is
    // the 1e-9 relative gate, for every backend.
    for backend in KernBackend::available() {
        for (si, &(m, n)) in SHAPES.iter().enumerate() {
            let seed = 1 + si as u64 * 100;
            let got = run_kernels(backend, m, n, seed);
            let data = randvec(m * n, seed);
            let r = randvec(m, seed + 1);
            let ctx = format!("{} ({m},{n})", backend.name());
            let mut want = vec![0.0; n];
            reference::at_r(&data, m, n, &r, &mut want);
            for (j, (a, b)) in got.at_r.iter().zip(&want).enumerate() {
                assert!(close(*a, *b), "{ctx}: at_r col {j}: {a} vs {b}");
            }
            let norms = reference::col_sq_norms(&data, m, n);
            for (a, b) in got.col_norms.iter().zip(&norms) {
                assert!(close(*a, *b), "{ctx}: col_sq_norms {a} vs {b}");
            }
            let ii: Vec<usize> = (0..n).step_by(2).collect();
            let jj: Vec<usize> = (0..n).collect();
            let gram = reference::gram_block(&data, m, n, &ii, &jj);
            for (a, b) in got.gram.iter().zip(&gram) {
                assert!(close(*a, *b), "{ctx}: gram {a} vs {b}");
            }
            let x = randvec(m * n + 3, seed + 2);
            let y = randvec(m * n + 3, seed + 3);
            assert!(close(got.dot, reference::dot(&x, &y)), "{ctx}: dot");
            assert!(close(got.sq_norm, reference::sq_norm(&x)), "{ctx}: sq_norm");
        }
    }
}

#[test]
fn cross_backend_matrix_has_the_documented_divergence_classes() {
    let avail = KernBackend::available();
    let x = randvec(1001, 42);
    let y = randvec(1001, 43);
    let runs: Vec<(KernBackend, f64, f64)> = avail
        .iter()
        .map(|&b| simd::with_backend(b, || (b, simd::dot(&x, &y), simd::sq_norm(&x))))
        .collect();
    for (i, &(ba, dot_a, sq_a)) in runs.iter().enumerate() {
        for &(bb, dot_b, sq_b) in runs.iter().skip(i + 1) {
            let pair = format!("{} vs {}", ba.name(), bb.name());
            if ba.bit_identical_to_scalar() && bb.bit_identical_to_scalar() {
                assert_eq!(dot_a.to_bits(), dot_b.to_bits(), "{pair}: dot");
                assert_eq!(sq_a.to_bits(), sq_b.to_bits(), "{pair}: sq_norm");
            } else {
                assert!(close(dot_a, dot_b), "{pair}: dot {dot_a} vs {dot_b}");
                assert!(close(sq_a, sq_b), "{pair}: sq_norm {sq_a} vs {sq_b}");
            }
        }
    }
}

#[test]
fn thread_invariance_holds_under_every_backend() {
    let (m, n) = (97, 61);
    let mut rng = Pcg64::new(9);
    let a = DenseMatrix::from_fn(m, n, |_, _| rng.normal());
    let r = randvec(m, 10);
    let ii: Vec<usize> = (0..n).step_by(2).collect();
    let jj: Vec<usize> = (1..n).step_by(3).collect();
    for backend in KernBackend::available() {
        let mut base: Option<(Vec<u64>, Vec<u64>)> = None;
        for threads in [1usize, 2, 4] {
            let sig = simd::with_backend(backend, || {
                // Small grain so every thread count actually chunks.
                let pool = ThreadPool::new(threads, 64);
                par::with_pool(&pool, || {
                    let mut out = vec![0.0; n];
                    a.at_r(&r, &mut out);
                    let g = a.gram_block(&ii, &jj);
                    (
                        out.iter().map(|v| v.to_bits()).collect::<Vec<u64>>(),
                        g.data().iter().map(|v| v.to_bits()).collect::<Vec<u64>>(),
                    )
                })
            });
            match &base {
                None => base = Some(sig),
                Some(b) => assert_eq!(
                    &sig,
                    b,
                    "{}: diverged at threads={threads}",
                    backend.name()
                ),
            }
        }
    }
}

#[test]
fn pools_capture_the_backend_at_construction() {
    // The pool is built inside a forced-scalar scope but *used* after
    // the scope exits: workers must still dispatch to scalar, because
    // the backend was captured when the pool was constructed.
    let pool = simd::with_backend(KernBackend::Scalar, || ThreadPool::new(2, 1));
    assert_eq!(pool.backend(), KernBackend::Scalar);
    let seen = pool.run((0..8).map(|_| || simd::current()).collect::<Vec<_>>());
    assert!(
        seen.iter().all(|&b| b == KernBackend::Scalar),
        "workers saw {seen:?}, expected the captured scalar backend"
    );
}

fn calars() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_calars"));
    c.env_remove("CALARS_ISA");
    c
}

#[test]
fn calars_isa_scalar_forces_the_fallback_backend() {
    let out = calars()
        .args(["info", "--json"])
        .env("CALARS_ISA", "scalar")
        .output()
        .expect("binary runs");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("\"isa\":\"scalar\""), "{s}");
}

#[test]
fn isa_flag_beats_detection_and_is_reported() {
    let out = calars().args(["info", "--json", "--isa", "scalar"]).output().expect("binary runs");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("\"isa\":\"scalar\""), "{s}");

    // Without any knob, the reported backend is the detected one.
    let out = calars().args(["info", "--json"]).output().expect("binary runs");
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    let want = format!("\"isa\":\"{}\"", KernBackend::detect().name());
    assert!(s.contains(&want), "expected {want} in {s}");
}

#[test]
fn forced_scalar_fit_matches_the_detected_backend_fit() {
    // End to end through the binary: a fit must succeed under every
    // backend, and when the detected backend is in the bit-identical
    // class (everything but AVX-512, whose divergent `dot` feeds the
    // Cholesky recurrences) the selections must match forced-scalar
    // exactly.
    let run = |isa: Option<&str>| {
        let mut cmd = calars();
        cmd.args(["run", "--algo", "lars", "--dataset", "tiny", "--t", "8"]);
        if let Some(v) = isa {
            cmd.args(["--isa", v]);
        }
        let out = cmd.output().expect("binary runs");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout)
            .lines()
            .find(|l| l.starts_with("first 10 selections"))
            .expect("selection line")
            .to_string()
    };
    let detected = run(None);
    let scalar = run(Some("scalar"));
    if KernBackend::detect().bit_identical_to_scalar() {
        assert_eq!(scalar, detected, "bit-identical backend changed the selection");
    }
}

#[test]
fn invalid_or_unsupported_isa_is_a_hard_error() {
    let out = calars().args(["info", "--isa", "sse9"]).output().expect("binary runs");
    assert!(!out.status.success(), "unknown --isa must fail");
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown kernel backend"));

    let out =
        calars().args(["info"]).env("CALARS_ISA", "bogus").output().expect("binary runs");
    assert!(!out.status.success(), "unknown CALARS_ISA must fail on the binary");
    assert!(String::from_utf8_lossy(&out.stderr).contains("CALARS_ISA"));

    // Some backend is always unsupported on any one host (NEON on
    // x86_64, the AVX family on aarch64).
    if let Some(b) = KernBackend::ALL.into_iter().find(|b| !b.supported()) {
        let out = calars().args(["info", "--isa", b.name()]).output().expect("binary runs");
        assert!(!out.status.success(), "unsupported --isa {} must fail", b.name());
        assert!(String::from_utf8_lossy(&out.stderr).contains("not supported on this host"));
    }
}
