//! Integration: the AOT (JAX+Pallas → HLO text → PJRT) path computes
//! the same numbers as the native f64 kernels, within f32 tolerance.
//!
//! Requires `make artifacts` to have run (the Makefile orders this);
//! the suite fails with a clear message otherwise. The whole file is
//! compiled only with the `pjrt` cargo feature — without it there is no
//! XLA client to test against.
#![cfg(feature = "pjrt")]
#![allow(deprecated)] // exercises the legacy shims alongside the runtime

use calars::data::datasets;
use calars::linalg::Matrix;
use calars::runtime::{default_artifacts_dir, CorrEngine, KernelOp, XlaRuntime};

fn runtime() -> XlaRuntime {
    let dir = default_artifacts_dir();
    XlaRuntime::load(&dir).expect(
        "artifacts missing — run `make artifacts` before `cargo test` \
         (the Makefile test target does this)",
    )
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn manifest_has_both_ops() {
    let rt = runtime();
    assert!(rt.manifest().len() >= 2);
    assert!(rt.manifest().bucket_for(KernelOp::Corr, 64, 32).is_some());
    assert!(rt.manifest().bucket_for(KernelOp::GammaStep, 64, 32).is_some());
}

#[test]
fn corr_parity_exact_bucket() {
    let rt = runtime();
    let d = datasets::by_name("tiny_dense", 7).unwrap();
    let Matrix::Dense(dense) = &d.a else { panic!("tiny_dense must be dense") };
    let (m, n) = (dense.nrows(), dense.ncols());
    let session = rt.prepare_corr(m, n, dense.data()).unwrap();
    let c_xla = session.corr(&d.b).unwrap();
    let mut c_native = vec![0.0; n];
    d.a.at_r(&d.b, &mut c_native);
    let scale = c_native.iter().fold(1.0_f64, |a, &x| a.max(x.abs()));
    let err = max_abs_diff(&c_xla, &c_native);
    assert!(err < 1e-4 * scale * (m as f64).sqrt(), "corr parity err = {err}");
}

#[test]
fn corr_parity_padded_bucket() {
    // A shape that fits no bucket exactly: padding must not change c.
    let rt = runtime();
    let d = datasets::by_name("tiny_dense", 8).unwrap();
    let Matrix::Dense(dense) = &d.a else { panic!() };
    // Take an odd sub-shape.
    let sub = dense.row_slice(0, 100);
    let session = rt.prepare_corr(100, sub.ncols(), sub.data()).unwrap();
    let (bm, bn) = session.bucket();
    assert!(bm >= 100 && bn >= sub.ncols());
    assert!(bm > 100 || bn > sub.ncols(), "expected a padded bucket");
    let r = &d.b[..100];
    let c_xla = session.corr(r).unwrap();
    let mut c_native = vec![0.0; sub.ncols()];
    sub.at_r(r, &mut c_native);
    let err = max_abs_diff(&c_xla, &c_native);
    assert!(err < 1e-3, "padded corr err = {err}");
}

#[test]
fn gstep_parity_with_native_gamma() {
    let rt = runtime();
    let d = datasets::by_name("tiny_dense", 9).unwrap();
    let Matrix::Dense(dense) = &d.a else { panic!() };
    let (m, n) = (dense.nrows(), dense.ncols());

    // Build a plausible iteration state: select the top column, form u.
    let mut c = vec![0.0; n];
    d.a.at_r(&d.b, &mut c);
    let j0 = (0..n).max_by(|&i, &j| c[i].abs().total_cmp(&c[j].abs())).unwrap();
    let mut u = vec![0.0; m];
    d.a.gemv_cols(&[j0], &[c[j0].signum()], &mut u);
    let ck = c[j0].abs();
    let h = 1.0 / ck;
    let mut mask = vec![false; n];
    mask[j0] = true;

    let session = rt.prepare_gstep(m, n, dense.data()).unwrap();
    let (av_xla, gam_xla) = session.gstep(&u, &c, &mask, ck, h).unwrap();

    // Native av.
    let mut av = vec![0.0; n];
    d.a.at_r(&u, &mut av);
    assert!(max_abs_diff(&av_xla, &av) < 1e-3, "av parity");

    // Native gamma candidates (same min+ rule the kernel implements).
    for j in 0..n {
        if mask[j] {
            assert!(gam_xla[j].is_infinite(), "masked col {j} must be inf");
            continue;
        }
        let g1 = (ck - c[j]) / (ck * h - av[j]);
        let g2 = (ck + c[j]) / (ck * h + av[j]);
        let want = calars::linalg::select::min_positive2(g1, g2)
            .filter(|g| *g <= (1.0 / h) * (1.0 + 1e-6));
        match want {
            Some(w) => {
                assert!(
                    gam_xla[j].is_finite() && (gam_xla[j] - w).abs() < 1e-3 * w.max(1.0),
                    "gamma[{j}] = {} want {w}",
                    gam_xla[j]
                );
            }
            None => assert!(
                gam_xla[j].is_infinite() || gam_xla[j] > 1.0 / h,
                "gamma[{j}] should be invalid, got {}",
                gam_xla[j]
            ),
        }
    }
}

#[test]
fn corr_engine_prefers_xla_for_dense() {
    let rt = runtime();
    let d = datasets::by_name("tiny_dense", 10).unwrap();
    let eng = CorrEngine::new(&d.a, Some(&rt));
    assert_eq!(eng.backend(), calars::runtime::hybrid::Backend::Xla);
    let c_xla = eng.corr(&d.b).unwrap();
    let nat = CorrEngine::native(&d.a);
    let c_nat = nat.corr(&d.b).unwrap();
    assert!(max_abs_diff(&c_xla, &c_nat) < 1e-3);
}

#[test]
fn corr_engine_native_for_sparse() {
    let rt = runtime();
    let d = datasets::by_name("tiny", 11).unwrap();
    let eng = CorrEngine::new(&d.a, Some(&rt));
    assert_eq!(eng.backend(), calars::runtime::hybrid::Backend::Native);
}

#[test]
fn accelerated_blars_on_xla_engine_matches_reference_quality() {
    use calars::lars::accelerated::{blars_accelerated, AccelOptions};
    use calars::lars::path::{ls_coefficients, residual_norm};
    use calars::lars::serial::{blars_serial, LarsOptions};

    let rt = runtime();
    let d = datasets::by_name("tiny_dense", 13).unwrap();
    let engine = CorrEngine::new(&d.a, Some(&rt));
    assert_eq!(engine.backend(), calars::runtime::hybrid::Backend::Xla);

    let acc = blars_accelerated(
        &d.a,
        &d.b,
        &engine,
        &AccelOptions { t: 10, b: 2, ..Default::default() },
    )
    .unwrap();
    let reference = blars_serial(&d.a, &d.b, &LarsOptions { t: 10, b: 2, ..Default::default() });

    // f32 vs f64 may reorder near-ties; require equal-quality supports.
    let refit = |sel: &[usize]| {
        let coefs = ls_coefficients(&d.a, sel, &d.b).expect("full rank");
        residual_norm(&d.a, sel, &coefs, &d.b)
    };
    let (ra, rr) = (refit(&acc.selected), refit(&reference.selected));
    assert!(
        (ra - rr).abs() <= 0.05 * rr.max(1e-6) + 1e-6,
        "XLA-path support quality {ra} vs reference {rr}"
    );
    assert_eq!(acc.selected.len(), reference.selected.len());
}

#[test]
fn repeated_execution_is_stable() {
    // Device-resident A: repeated calls must return identical results.
    let rt = runtime();
    let d = datasets::by_name("tiny_dense", 12).unwrap();
    let Matrix::Dense(dense) = &d.a else { panic!() };
    let session = rt.prepare_corr(dense.nrows(), dense.ncols(), dense.data()).unwrap();
    let c1 = session.corr(&d.b).unwrap();
    let c2 = session.corr(&d.b).unwrap();
    assert_eq!(c1, c2);
}
