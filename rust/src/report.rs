//! ASCII tables and series printers matching the paper's rows/curves.
//!
//! Every experiment driver renders through these so the console output
//! (and `EXPERIMENTS.md`) has a uniform, diffable shape.

/// A simple left-aligned ASCII table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        let sep = {
            let mut line = String::from("|");
            for w in &widths {
                line.push_str(&format!("{}|", "-".repeat(w + 2)));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Render an (x, y) series as aligned columns — the textual stand-in for
/// the paper's line plots.
pub fn series(title: &str, xlabel: &str, ylabel: &str, points: &[(f64, f64)]) -> String {
    let mut t = Table::new(&[xlabel, ylabel]);
    for &(x, y) in points {
        t.row(&[trim_float(x), format!("{y:.6}")]);
    }
    format!("## {title}\n{}", t.render())
}

/// An ASCII bar chart (log-ish scaled to the max), for speedup figures.
pub fn bar_chart(title: &str, bars: &[(String, f64)], width: usize) -> String {
    let max = bars.iter().map(|(_, v)| *v).fold(f64::MIN_POSITIVE, f64::max);
    let label_w = bars.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("## {title}\n");
    for (label, v) in bars {
        let n = ((v / max) * width as f64).round().max(0.0) as usize;
        out.push_str(&format!("{label:<label_w$} | {} {v:.3}\n", "#".repeat(n)));
    }
    out
}

fn trim_float(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "unaligned:\n{s}");
        assert!(s.contains("longer"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn series_contains_points() {
        let s = series("resid", "cols", "l2", &[(1.0, 0.5), (2.0, 0.25)]);
        assert!(s.contains("resid"));
        assert!(s.contains("0.500000"));
        assert!(s.contains("| 1 "));
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart("speedup", &[("P=1".into(), 1.0), ("P=4".into(), 4.0)], 10);
        assert!(s.contains("##########"));
    }
}
