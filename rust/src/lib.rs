//! # calars — Communication-Avoiding Least Angle Regression
//!
//! A production-shaped reproduction of *"Parallel and Communication
//! Avoiding Least Angle Regression"* (Das, Demmel, Fountoulakis, Grigori,
//! Mahoney, Yang; 2019/2020).
//!
//! The crate is organized as three layers (see `DESIGN.md`):
//!
//! * **L3 — the coordinator** (this crate): the paper's parallel
//!   algorithms ([`lars::serial`], [`lars::blars`], [`lars::tblars`])
//!   scheduled over a simulated message-passing cluster
//!   ([`cluster`]) with an α-β-γ communication cost model, plus the
//!   substrate the paper depends on: dense/sparse linear algebra
//!   ([`linalg`]), dataset generators matching the paper's Table 3
//!   ([`data`]), baselines ([`baselines`]), metrics and experiment
//!   drivers ([`experiments`]) regenerating every table and figure.
//! * **L2/L1 — JAX + Pallas** (build-time Python under `python/`):
//!   the per-iteration compute graph and its Pallas hot-spot kernels,
//!   AOT-lowered to HLO text artifacts.
//! * **Runtime bridge** ([`runtime`]): loads the artifacts via the PJRT
//!   CPU client and executes them from the Rust request path; Python is
//!   never on the request path. Gated behind the off-by-default `pjrt`
//!   cargo feature — without it every call site degrades to the native
//!   f64 kernels.
//! * **Shared-memory execution** ([`par`]): a zero-dependency
//!   persistent thread pool with fixed-grain chunking. Every hot
//!   kernel (dense/sparse `Aᵀr`, GEMV, Gram blocks, Cholesky panel
//!   updates, cluster supersteps, the serving engine's batched GEMV)
//!   forks onto it; results are bit-identical across `CALARS_THREADS`
//!   settings by construction.
//! * **L4 — serving** ([`serve`]): the production front end. A
//!   versioned [`serve::ModelRegistry`] snapshots fitted LARS/bLARS/
//!   T-bLARS regularization paths (in memory and on disk), a batched
//!   [`serve::PredictionEngine`] evaluates any stored path at an
//!   arbitrary step or λ, a [`serve::FitQueue`] worker pool runs fit
//!   jobs asynchronously, and a zero-dependency HTTP/1.1 server
//!   (`calars serve`) exposes `/fit`, `/predict`, `/models`, `/stats`.
//!   `calars bench-serve` is the closed-loop load generator.
//!
//! ## Quickstart
//!
//! ```no_run
//! use calars::data::datasets;
//! use calars::lars::serial::{lars, LarsOptions};
//!
//! let ds = datasets::sector_like(42);
//! let out = lars(&ds.a, &ds.b, &LarsOptions { t: 20, ..Default::default() });
//! println!("selected columns: {:?}", out.selected);
//! ```
//!
//! ## Serving quickstart
//!
//! ```no_run
//! use calars::data::datasets;
//! use calars::lars::serial::lars_with_snapshot;
//! use calars::lars::serial::LarsOptions;
//! use calars::serve::{ModelMeta, ModelRegistry, PredictionEngine, Query, Selector};
//! use std::sync::Arc;
//!
//! let ds = datasets::tiny(42);
//! let (_, snap) = lars_with_snapshot(&ds.a, &ds.b, &LarsOptions { t: 8, ..Default::default() });
//! let registry = Arc::new(ModelRegistry::new(16));
//! let id = registry.insert(ModelMeta::named("tiny-lars"), snap);
//! let engine = PredictionEngine::new(registry, 64);
//! let x = vec![0.0; ds.a.ncols()];
//! let yhat = engine.predict(&Query { model: id, selector: Selector::Step(4), x }).unwrap();
//! println!("prediction: {yhat}");
//! ```

pub mod baselines;
pub mod cluster;
pub mod config;
pub mod data;
pub mod error;
pub mod experiments;
pub mod lars;
pub mod linalg;
pub mod metrics;
pub mod par;
pub mod proptest_lite;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serve;

/// Crate-wide result alias.
pub type Result<T> = crate::error::Result<T>;

/// Library version (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
