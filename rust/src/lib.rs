//! # calars — Communication-Avoiding Least Angle Regression
//!
//! A production-shaped reproduction of *"Parallel and Communication
//! Avoiding Least Angle Regression"* (Das, Demmel, Fountoulakis, Grigori,
//! Mahoney, Yang; 2019/2020).
//!
//! The crate is organized as layers (see `DESIGN.md`):
//!
//! * **The estimator API** ([`fit`]): the single entry point for the
//!   whole fitter family. A [`fit::FitSpec`] (a validated, serializable
//!   [`fit::Algorithm`] + shared knobs) implements [`fit::Fitter`],
//!   whose `fit(a, b, observer)` call covers serial LARS, bLARS,
//!   T-bLARS, LASSO-LARS, and the greedy baselines with one signature.
//!   Cross-cutting behaviors compose as [`fit::FitObserver`]s
//!   ([`fit::SnapshotObserver`], [`fit::ProgressObserver`],
//!   [`fit::EarlyStop`], [`fit::MetricsSink`]); invalid inputs return
//!   typed errors ([`error::ErrorKind`]) instead of panicking.
//! * **Batched multi-response fitting** ([`batch`]):
//!   [`fit::FitSpec::fit_batch`] fits one design matrix against a
//!   whole response panel in lockstep — the initial `AᵀR`, the fused
//!   direction pass, and the γ scans of each joint iteration are
//!   batched across models ([`kern`] panel kernels), Gram panels and
//!   column norms are shared through [`kern::cache`], and a batch of
//!   one is bit-identical to the single-response fit. Backs the bulk
//!   `POST /fit` serve path and `calars batch`.
//! * **L3 — the coordinator**: the paper's parallel algorithms
//!   ([`lars::serial`], [`lars::blars`], [`lars::tblars`]) scheduled
//!   over a simulated message-passing cluster ([`cluster`]) with an
//!   α-β-γ communication cost model, plus the substrate the paper
//!   depends on: dense/sparse linear algebra ([`linalg`]), dataset
//!   generators matching the paper's Table 3 ([`data`]), baselines
//!   ([`baselines`]), metrics and experiment drivers ([`experiments`])
//!   regenerating every table and figure.
//! * **L2/L1 — JAX + Pallas** (build-time Python under `python/`):
//!   the per-iteration compute graph and its Pallas hot-spot kernels,
//!   AOT-lowered to HLO text artifacts.
//! * **Runtime bridge** ([`runtime`]): loads the artifacts via the PJRT
//!   CPU client and executes them from the Rust request path; Python is
//!   never on the request path. Gated behind the off-by-default `pjrt`
//!   cargo feature — without it every call site degrades to the native
//!   f64 kernels.
//! * **Shared-memory execution** ([`par`]): a zero-dependency
//!   persistent thread pool with fixed-grain chunking. Every hot
//!   kernel (dense/sparse `Aᵀr`, GEMV, Gram blocks, Cholesky panel
//!   updates, cluster supersteps, the serving engine's batched GEMV)
//!   forks onto it; results are bit-identical across `CALARS_THREADS`
//!   settings by construction.
//! * **Kernel engine** ([`kern`]): the register-blocked, unrolled
//!   compute kernels those hot paths run — multi-accumulator
//!   reductions, 4-row fused streaming sweeps, a packed 4×4 Gram
//!   micro-GEMM, and fused paired traversals (`gemv_cols`+`at_r`,
//!   normalize-with-norms) — each with one canonical summation order
//!   shared by the serial and chunked-parallel paths, tolerance-gated
//!   against the scalar [`kern::reference`]. [`kern::cache`] is the
//!   cross-fit Gram/norm panel store the serving layer binds around
//!   fits.
//! * **Model selection** ([`select`]): choosing *which* model on a
//!   fitted path to serve — Mallows' Cp / AIC / BIC per stored step
//!   (df = active-set size) and seeded k-fold cross-validation whose
//!   fold fits fan out on the [`par`] pool; the chosen step is
//!   bit-identical at any thread count. Drives `calars select`, the
//!   serving layer's `POST /select`, and the `Selector::Auto`
//!   prediction selector.
//! * **L4 — serving** ([`serve`]): the production front end. A
//!   versioned [`serve::ModelRegistry`] snapshots fitted regularization
//!   paths (in memory and on disk), a batched
//!   [`serve::PredictionEngine`] evaluates any stored path at an
//!   arbitrary step or λ, a [`serve::FitQueue`] worker pool runs
//!   [`serve::FitJob`]s asynchronously through the estimator API, and a
//!   zero-dependency HTTP/1.1 server (`calars serve`) exposes `/fit`,
//!   `/predict`, `/select`, `/models`, `/datasets`, `/stats`.
//!   `calars bench-serve` is the closed-loop load generator.
//! * **Observability** ([`obs`]): end-to-end tracing spans (per-request
//!   `trace_id`, fit phases on the same taxonomy as the SimCluster
//!   tracer, queue wait, Gram-cache hits) drained into a bounded
//!   [`obs::TraceSink`], plus a typed counter/gauge/histogram registry
//!   behind `GET /metrics` (Prometheus text) and `GET /trace/<id>`
//!   (chrome://tracing JSON). `calars trace` pretty-prints one fit's
//!   span tree. Tracing is passive — fits are bit-identical with it on
//!   or off — and `CALARS_TRACE=off` reduces every probe to one atomic
//!   load.
//!
//! ## Quickstart
//!
//! ```no_run
//! use calars::data::datasets;
//! use calars::fit::{Algorithm, FitSpec};
//!
//! let ds = datasets::sector_like(42);
//! let result = FitSpec::new(Algorithm::Lars)
//!     .t(20)
//!     .run(&ds.a, &ds.b)
//!     .expect("valid spec");
//! println!("selected columns: {:?}", result.output.selected);
//! println!("stopped because: {:?}", result.output.stop);
//! ```
//!
//! Every family member goes through the same call — switch algorithms
//! by switching the [`fit::Algorithm`]:
//!
//! ```no_run
//! use calars::data::datasets;
//! use calars::fit::{Algorithm, FitSpec};
//!
//! let ds = datasets::sector_like(42);
//! let blars = FitSpec::new(Algorithm::Blars { b: 4 }).t(60).ranks(16);
//! let result = blars.run(&ds.a, &ds.b).expect("valid spec");
//! let sim = result.sim.as_ref().expect("cluster fitters report telemetry");
//! println!("simulated seconds: {:.3}, messages: {}", sim.sim_time, sim.counters.msgs);
//! ```
//!
//! ## Serving quickstart
//!
//! ```no_run
//! use calars::data::datasets;
//! use calars::fit::{Algorithm, FitSpec, Fitter, SnapshotObserver};
//! use calars::serve::{ModelMeta, ModelRegistry, PredictionEngine, Query, Selector};
//! use std::sync::Arc;
//!
//! let ds = datasets::tiny(42);
//! let mut snap = SnapshotObserver::new();
//! FitSpec::new(Algorithm::Lars)
//!     .t(8)
//!     .fit(&ds.a, &ds.b, &mut snap)
//!     .expect("fit succeeds");
//! let registry = Arc::new(ModelRegistry::new(16));
//! let id = registry.insert(ModelMeta::named("tiny-lars"), snap.into_snapshot().unwrap());
//! let engine = PredictionEngine::new(registry, 64);
//! let x = vec![0.0; ds.a.ncols()];
//! let yhat = engine.predict(&Query { model: id, selector: Selector::Step(4), x }).unwrap();
//! println!("prediction: {yhat}");
//! ```
//!
//! ## Legacy entry points
//!
//! The original free functions (`lars::serial::lars`,
//! `lars::serial::blars_serial`, `lars::blars::blars`,
//! `lars::tblars::tblars`, `lars::lasso_lars::lasso_path`,
//! `baselines::forward_selection::forward_selection`,
//! `baselines::omp::omp`) remain as `#[deprecated]` shims that delegate
//! to the estimator API and produce bit-identical outputs
//! (property-tested in `tests/fit.rs`). Migrate by constructing the
//! matching [`fit::FitSpec`]; the shims panic on invalid input exactly
//! like their old `assert!`s, whereas the new API returns typed errors.

pub mod baselines;
pub mod batch;
pub mod cluster;
pub mod config;
pub mod data;
pub mod error;
pub mod experiments;
pub mod fit;
pub mod kern;
pub mod lars;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod par;
pub mod proptest_lite;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod select;
pub mod serve;

/// Crate-wide result alias.
pub type Result<T> = crate::error::Result<T>;

/// Library version (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
