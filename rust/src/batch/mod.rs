//! `calars::batch` — multi-response fitting: one design matrix,
//! thousands of LARS models.
//!
//! Panel studies, multi-target screening, and per-gene/per-pixel
//! regressions all fit the same `m × n` design against many response
//! vectors. Fitting them one [`FitSpec::fit`] call at a time repeats
//! the expensive part `k` times: every iteration of every model
//! streams the full matrix once for the fused `u = A_I w` / `a = Aᵀu`
//! step, and once more up front for the initial correlations
//! `c = Aᵀb`. Those streams are memory-bound — the arithmetic per
//! matrix element is tiny — so `k` sequential fits pay `k` full
//! traversals of `A` per joint iteration while the cache line holding
//! each element is hot enough to serve many models at once.
//!
//! [`FitSpec::fit_batch`] fixes that by fitting the responses in
//! **lockstep**: all models advance through the same iteration
//! together, and each per-model matrix pass is replaced by one
//! *batched panel pass* over `A` that serves every still-active model
//! from the same streamed rows ([`crate::kern::at_r_multi_panel`] /
//! [`crate::kern::fused_step_multi_panel`]). Per-model bookkeeping —
//! Cholesky updates, γ selection, coefficient updates — stays exactly
//! the serial code path, so each model's mathematics is unchanged.
//!
//! # What is shared
//!
//! * **Matrix passes**: the initial `AᵀR` over the whole response
//!   panel and one fused direction pass per joint iteration, instead
//!   of `k` of each ([`SharedWork::batched_passes`] vs
//!   [`SharedWork::sequential_passes`]).
//! * **Column norms**: the degenerate-column screen runs once per
//!   batch, not once per response, and records its norms in the
//!   batch's panel store for any fallback fits to reuse.
//! * **Gram panels**: per-model Gram blocks go through
//!   [`crate::kern::cache::PanelStore`] — the serve layer's bound
//!   store when one is installed, a batch-local store otherwise — so
//!   models that select overlapping column sets reuse each other's
//!   panels ([`SharedWork::gram_panel_hits`]).
//! * **γ-candidate scans**: the per-model scans of one joint
//!   iteration run under a single fork-join over the column range
//!   (every chunk walks [`crate::kern::gamma_scan_range`], the same
//!   loop body the serial scan uses).
//!
//! # Scheduling and determinism
//!
//! Responses are fitted in fixed chunks of [`RESPONSE_CHUNK`] models,
//! scheduled across the [`crate::par`] pool with
//! [`crate::par::run_tasks`] and recombined in ascending response
//! order. The chunk size is a constant — never derived from the
//! thread count — and the batched kernels chunk rows by the same
//! grain formulas on any pool, so a batch's output is **bit-identical
//! across `CALARS_THREADS`** (the `tests/batch.rs` property tests
//! pin this for pools of 1, 2, and 4 workers).
//!
//! Two bit-level contracts, verified by `tests/batch.rs`:
//!
//! * a batch of one response is bit-identical to the single-response
//!   [`FitSpec::fit`] for every algorithm (at `k = 1` the panel
//!   kernels degenerate to the single-response kernels, same grain
//!   and same summation order);
//! * any batch is bit-identical to itself across thread counts.
//!
//! A batch with `k > 1` is *not* promised bit-identical to `k`
//! separate fits: the batched row panels accumulate each model's
//! partial sums under a row grain derived from the joint panel cost,
//! which splits chunk boundaries differently than a solo fit. Each
//! model still runs the identical per-iteration mathematics, so the
//! results agree to kernel rounding (and selections virtually always
//! match exactly).
//!
//! # Which algorithms batch
//!
//! [`Algorithm::Lars`] and [`Algorithm::LassoLars`] run the lockstep
//! cores below. The simulated-cluster fitters (`Blars`, `TBlars`) and
//! the greedy baselines (`ForwardSelection`, `Omp`) fall back to
//! sequential per-response [`FitSpec::fit`] calls inside the same
//! response-chunk scheduling — they still share the panel store and
//! the column-norm screen, just not the matrix passes.
//!
//! ```no_run
//! use calars::data::datasets;
//! use calars::fit::{Algorithm, FitSpec};
//!
//! let ds = datasets::tiny(42);
//! let responses: Vec<Vec<f64>> = (0..64).map(|_| ds.b.clone()).collect();
//! let batch = FitSpec::new(Algorithm::Lars).t(8).fit_batch(&ds.a, &responses).unwrap();
//! assert_eq!(batch.fits.len(), 64);
//! println!("shared passes saved: {}", batch.shared.passes_saved());
//! ```

use crate::error::{Error, Result};
use crate::fit::{Algorithm, FitResult, FitSpec, Fitter, NoopObserver};
use crate::kern;
use crate::kern::cache::PanelStore;
use crate::lars::lasso_lars::{Breakpoint, LassoFit, LassoPath};
use crate::lars::{LarsOutput, StopReason};
use crate::linalg::select::{argmax_b_by, argmin_b_by, min_positive2};
use crate::linalg::{dot, norm2, Cholesky, DenseMatrix, Matrix};
use crate::obs::{phase_span, Phase};
use crate::par;
use std::sync::Arc;
use std::time::Instant;

/// Responses fitted per lockstep chunk. A constant (never derived
/// from the thread count) so the chunk decomposition — and therefore
/// every batched panel shape — is a pure function of the batch size.
/// Eight keeps the per-chunk working set (eight residual/correlation
/// panels) inside L2 while amortizing each streamed row of `A` across
/// eight models.
pub const RESPONSE_CHUNK: usize = 8;

/// Upper bound on the number of responses per batch.
pub const MAX_BATCH: usize = 1 << 20;

/// Byte bound for the batch-local Gram panel store used when the
/// caller has not bound one (CLI / bench batches).
const BATCH_PANEL_BYTES: usize = 32 << 20;

/// Shared-work accounting for one batch: what the lockstep cores
/// amortized across the responses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SharedWork {
    /// Responses fitted in this batch.
    pub responses: usize,
    /// Gram-panel cache hits recorded while the batch ran (cross-model
    /// panel reuse; counted on the serve layer's bound store when one
    /// is installed, on the batch-local store otherwise).
    pub gram_panel_hits: u64,
    /// Gram-panel cache misses recorded while the batch ran.
    pub gram_panel_misses: u64,
    /// Full passes over `A` the lockstep cores actually executed
    /// (one batched `AᵀR` plus one batched fused step per joint
    /// iteration, each serving every still-active model).
    pub batched_passes: u64,
    /// Full passes over `A` that independent single-response fits
    /// would have executed for the same per-model work.
    pub sequential_passes: u64,
}

impl SharedWork {
    /// Matrix passes the batch avoided relative to sequential fitting.
    pub fn passes_saved(&self) -> u64 {
        self.sequential_passes.saturating_sub(self.batched_passes)
    }
}

/// What [`FitSpec::fit_batch`] returns: one [`FitResult`] per
/// response (same order as the input panel) plus the batch-level
/// shared-work accounting and wall time.
#[derive(Clone, Debug)]
pub struct BatchFitResult {
    /// Per-response results, aligned with the input response order.
    pub fits: Vec<FitResult>,
    /// What the batch amortized across the responses.
    pub shared: SharedWork,
    /// Wall-clock seconds for the whole batch (the per-response
    /// `wall_secs` inside [`Self::fits`] are the amortized per-model
    /// share of their chunk).
    pub wall_secs: f64,
}

/// Matrix-pass counters threaded through the lockstep cores.
#[derive(Clone, Copy, Debug, Default)]
struct PassCounts {
    batched: u64,
    sequential_equiv: u64,
}

impl FitSpec {
    /// Fit every response in `responses` against `a` under this spec,
    /// sharing matrix passes, column norms, and Gram panels across the
    /// batch (see the [module docs](self) for what is shared and the
    /// bit-identity contracts). Results come back in input order; the
    /// first invalid response fails the whole batch with a typed
    /// [`crate::error::ErrorKind::InvalidSpec`] error before any
    /// fitting starts.
    pub fn fit_batch(&self, a: &Matrix, responses: &[Vec<f64>]) -> Result<BatchFitResult> {
        self.validate()?;
        let m = a.nrows();
        let n = a.ncols();
        if m < 2 || n == 0 {
            return Err(Error::invalid_spec(format!(
                "matrix must have at least 2 rows and 1 column (got {m}×{n})"
            )));
        }
        if responses.is_empty() {
            return Err(Error::invalid_spec("batch must contain at least one response"));
        }
        if responses.len() > MAX_BATCH {
            return Err(Error::invalid_spec(format!(
                "batch holds {} responses (max {})",
                responses.len(),
                MAX_BATCH
            )));
        }
        for (k, b) in responses.iter().enumerate() {
            if b.len() != m {
                return Err(Error::invalid_spec(format!(
                    "response {k}: length {} does not match the matrix row count {m}",
                    b.len()
                )));
            }
            if let Some(i) = b.iter().position(|v| !v.is_finite()) {
                return Err(Error::invalid_spec(format!(
                    "response {k} contains a non-finite value at row {i} ({})",
                    b[i]
                )));
            }
        }

        // One panel store for the whole batch: the serve layer's bound
        // store when one is installed for this shape, a batch-local
        // store otherwise. Either way the store carries the dataset's
        // column norms, so the degenerate-column screen runs once per
        // batch and fallback fits skip their own O(nnz) sweep.
        let store = match kern::cache::bound_for((m, n)) {
            Some(s) => s,
            None => Arc::new(PanelStore::new((m, n), BATCH_PANEL_BYTES)),
        };
        if store.norms().is_none() {
            store.set_norms(Arc::new(a.col_norms()));
        }
        let col_norms = match store.norms() {
            Some(norms) if norms.len() == n => norms,
            _ => Arc::new(a.col_norms()),
        };
        if let Some(j) = col_norms.iter().position(|v| !v.is_finite() || *v == 0.0) {
            return Err(Error::invalid_spec(format!(
                "column {j} is degenerate (norm {}): all-zero or non-finite \
                 columns cannot enter a LARS path",
                col_norms[j]
            )));
        }

        let before = store.counters();
        // audit: allow(DET-TIME) -- wall_secs metadata only: the clock value never reaches numerics or control flow
        let t0 = Instant::now();
        let batch_span = crate::obs::span("batch_fit");

        // Fixed response chunks (pure in the batch size), scheduled on
        // the pool and recombined in ascending response order.
        let k_total = responses.len();
        let ranges: Vec<(usize, usize)> = (0..k_total)
            .step_by(RESPONSE_CHUNK)
            .map(|lo| (lo, (lo + RESPONSE_CHUNK).min(k_total)))
            .collect();
        let tasks: Vec<_> = ranges
            .iter()
            .map(|&(lo, hi)| {
                let store = Arc::clone(&store);
                move || {
                    // Pool workers carry no ambient store binding;
                    // rebind the batch's store so every chunk shares
                    // one panel cache (values are deterministic, so
                    // the cache never changes bits — only work).
                    kern::cache::with_store(&store, || fit_chunk(self, a, &responses[lo..hi]))
                }
            })
            .collect();
        let chunk_results = par::run_tasks(tasks);
        drop(batch_span);

        let mut fits = Vec::with_capacity(k_total);
        let mut passes = PassCounts::default();
        for (chunk, p) in chunk_results {
            passes.batched += p.batched;
            passes.sequential_equiv += p.sequential_equiv;
            for r in chunk {
                fits.push(r?);
            }
        }
        let after = store.counters();
        let shared = SharedWork {
            responses: k_total,
            gram_panel_hits: after.hits.saturating_sub(before.hits),
            gram_panel_misses: after.misses.saturating_sub(before.misses),
            batched_passes: passes.batched,
            sequential_passes: passes.sequential_equiv,
        };
        Ok(BatchFitResult { fits, shared, wall_secs: t0.elapsed().as_secs_f64() })
    }
}

/// Fit one response chunk: lockstep for the batching-capable
/// algorithms, sequential per-response [`Fitter::fit`] otherwise.
fn fit_chunk(
    spec: &FitSpec,
    a: &Matrix,
    responses: &[Vec<f64>],
) -> (Vec<Result<FitResult>>, PassCounts) {
    let mut passes = PassCounts::default();
    // audit: allow(DET-TIME) -- per-chunk wall_secs metadata only: the clock value never reaches numerics or control flow
    let t0 = Instant::now();
    let results: Vec<Result<FitResult>> = match spec.algorithm {
        Algorithm::Lars => {
            let outs = lars_lockstep(a, responses, spec.t, spec.tol, &mut passes);
            let wall = t0.elapsed().as_secs_f64() / responses.len().max(1) as f64;
            outs.into_iter()
                .map(|output| {
                    Ok(FitResult { output, coefs: None, lasso: None, sim: None, wall_secs: wall })
                })
                .collect()
        }
        Algorithm::LassoLars { lambda_min } => {
            let fits = lasso_lockstep(a, responses, spec.t, lambda_min, spec.tol, &mut passes);
            let wall = t0.elapsed().as_secs_f64() / responses.len().max(1) as f64;
            fits.into_iter()
                .map(|fit| {
                    Ok(FitResult {
                        output: fit.out,
                        coefs: None,
                        lasso: Some(fit.path),
                        sim: None,
                        wall_secs: wall,
                    })
                })
                .collect()
        }
        _ => responses.iter().map(|b| spec.fit(a, b, &mut NoopObserver)).collect(),
    };
    (results, passes)
}

/// Per-model state for the lockstep LARS core — exactly the locals of
/// `lars::serial::fit_observed` (with `b = 1`), lifted into a struct
/// so the batched passes can borrow each model's panels disjointly.
struct LarsSt {
    b: Vec<f64>,
    y: Vec<f64>,
    r: Vec<f64>,
    c: Vec<f64>,
    u: Vec<f64>,
    av: Vec<f64>,
    residual_norms: Vec<f64>,
    cols_at_iter: Vec<usize>,
    in_model: Vec<bool>,
    selected: Vec<usize>,
    rank_excluded: usize,
    chol: Cholesky,
    ck: f64,
    s: Vec<f64>,
    q: Vec<f64>,
    w: Vec<f64>,
    h: f64,
    gamma_full: f64,
    stepping: bool,
    done: Option<StopReason>,
}

impl LarsSt {
    fn new(b: &[f64], m: usize, n: usize) -> Self {
        LarsSt {
            b: b.to_vec(),
            y: vec![0.0; m],
            r: b.to_vec(),
            c: vec![0.0; n],
            u: vec![0.0; m],
            av: vec![0.0; n],
            residual_norms: Vec::new(),
            cols_at_iter: Vec::new(),
            in_model: vec![false; n],
            selected: Vec::new(),
            rank_excluded: 0,
            chol: Cholesky::empty(),
            ck: 0.0,
            s: Vec::new(),
            q: Vec::new(),
            w: Vec::new(),
            h: 0.0,
            gamma_full: 0.0,
            stepping: false,
            done: None,
        }
    }

    fn finish(&mut self, stop: StopReason) {
        self.done = Some(stop);
        self.stepping = false;
    }
}

/// Lockstep LARS (`b = 1`): every model runs the per-iteration
/// mathematics of `lars::serial::fit_observed` unchanged, while the
/// initial correlations, the fused direction pass, and the γ scans
/// of one joint iteration are batched across the still-active models.
fn lars_lockstep(
    a: &Matrix,
    responses: &[Vec<f64>],
    t_req: usize,
    tol: f64,
    passes: &mut PassCounts,
) -> Vec<LarsOutput> {
    let m = a.nrows();
    let n = a.ncols();
    let t = t_req.min(m.min(n));
    let mut sts: Vec<LarsSt> = responses.iter().map(|b| LarsSt::new(b, m, n)).collect();

    // Batched initial correlations: C = AᵀR over the whole panel.
    {
        let mut sp = phase_span(Phase::Corr);
        sp.flops(2 * (sts.len() as u64) * (m as u64) * (n as u64));
        let mut rs: Vec<&[f64]> = Vec::with_capacity(sts.len());
        let mut cs: Vec<&mut [f64]> = Vec::with_capacity(sts.len());
        for st in sts.iter_mut() {
            let LarsSt { r, c, .. } = st;
            rs.push(r);
            cs.push(c);
        }
        a.at_r_multi(&rs, &mut cs);
    }
    passes.batched += 1;
    passes.sequential_equiv += sts.len() as u64;

    // Per-model initial block selection + Cholesky seed (serial
    // steps 3-5, one model at a time).
    for st in sts.iter_mut() {
        st.residual_norms.push(norm2(&st.r));
        st.cols_at_iter.push(0);
        let b0 = 1usize.min(t.max(1));
        let sel_span = phase_span(Phase::Select);
        let mut block = argmax_b_by(n, b0, |j| st.c[j].abs());
        block.sort_unstable();
        drop(sel_span);
        if block.iter().all(|&j| st.c[j].abs() <= tol) {
            st.finish(StopReason::Saturated);
            continue;
        }
        let g0 = {
            let mut sp = phase_span(Phase::Gram);
            sp.flops(2 * (m as u64) * (block.len() as u64) * (block.len() as u64));
            a.gram_block(&block, &block)
        };
        let chol_span = phase_span(Phase::Cholesky);
        let admitted = st.chol.append_block_graceful(&DenseMatrix::zeros(0, block.len()), &g0);
        drop(chol_span);
        st.rank_excluded += block.len() - admitted.len();
        for &row in &admitted {
            st.selected.push(block[row]);
        }
        for &j in &block {
            st.in_model[j] = true;
        }
        if st.selected.is_empty() {
            st.finish(StopReason::RankDeficient);
            continue;
        }
        st.ck = st.selected.iter().map(|&j| st.c[j].abs()).fold(f64::INFINITY, f64::min);
    }

    loop {
        // Per-model stop checks + equiangular solve (serial steps 7-8).
        let mut stepping = 0usize;
        for st in sts.iter_mut() {
            st.stepping = false;
            if st.done.is_some() {
                continue;
            }
            if st.selected.len() >= t {
                st.finish(StopReason::TargetReached);
                continue;
            }
            if st.ck <= tol {
                st.finish(StopReason::Saturated);
                continue;
            }
            let solve_span = phase_span(Phase::Solve);
            let sq = {
                let LarsSt { s, q, chol, selected, c, .. } = &mut *st;
                s.clear();
                s.extend(selected.iter().map(|&j| c[j]));
                chol.solve_into(s, q);
                dot(s, q)
            };
            drop(solve_span);
            if !(sq.is_finite() && sq > 0.0) {
                st.finish(StopReason::RankDeficient);
                continue;
            }
            let h = 1.0 / sq.sqrt();
            {
                let LarsSt { q, w, .. } = &mut *st;
                w.clear();
                w.extend(q.iter().map(|qi| qi * h));
            }
            st.h = h;
            st.gamma_full = 1.0 / h;
            st.stepping = true;
            stepping += 1;
        }
        if stepping == 0 {
            break;
        }

        // Batched fused step (serial steps 10-11): one pass over `A`
        // serves every stepping model.
        {
            let mut sp = phase_span(Phase::DirApply);
            let sel_sum: u64 =
                sts.iter().filter(|st| st.stepping).map(|st| st.selected.len() as u64).sum();
            sp.flops(2 * (m as u64) * (sel_sum + stepping as u64 * n as u64));
            let mut cols: Vec<&[usize]> = Vec::with_capacity(stepping);
            let mut ws: Vec<&[f64]> = Vec::with_capacity(stepping);
            let mut us: Vec<&mut [f64]> = Vec::with_capacity(stepping);
            let mut avs: Vec<&mut [f64]> = Vec::with_capacity(stepping);
            for st in sts.iter_mut().filter(|st| st.stepping) {
                let LarsSt { selected, w, u, av, .. } = st;
                cols.push(selected);
                ws.push(w);
                us.push(u);
                avs.push(av);
            }
            a.fused_step_multi(&cols, &ws, &mut us, &mut avs);
        }
        passes.batched += 1;
        passes.sequential_equiv += stepping as u64;

        // Batched γ scans (serial step 12): one fork-join over the
        // column range; each chunk walks `kern::gamma_scan_range` for
        // every stepping model, and chunk results concatenate in
        // ascending order — per model this is bit- and order-identical
        // to the serial `gamma_candidates` scan.
        let gamma_span = phase_span(Phase::GammaStep);
        let cands: Vec<Vec<(usize, f64)>> = {
            let scans: Vec<(&[bool], &[f64], &[f64], f64, f64, f64)> = sts
                .iter()
                .filter(|st| st.stepping)
                .map(|st| {
                    (
                        st.in_model.as_slice(),
                        st.c.as_slice(),
                        st.av.as_slice(),
                        st.ck,
                        st.h,
                        st.gamma_full,
                    )
                })
                .collect();
            let per_chunk = par::map_chunks(n, par::min_chunk(), |lo, hi| {
                scans
                    .iter()
                    .map(|&(in_model, c, av, ck, h, gf)| {
                        let mut loc: Vec<(usize, f64)> = Vec::new();
                        kern::gamma_scan_range(lo, hi, in_model, c, av, ck, h, gf, &mut loc);
                        loc
                    })
                    .collect::<Vec<_>>()
            });
            let mut cands: Vec<Vec<(usize, f64)>> = vec![Vec::new(); scans.len()];
            for chunk in per_chunk {
                for (mi, loc) in chunk.into_iter().enumerate() {
                    cands[mi].extend(loc);
                }
            }
            cands
        };
        drop(gamma_span);

        // Per-model γ pick, update, and Cholesky extension (serial
        // steps 13-23, verbatim).
        let mut ci = 0usize;
        for st in sts.iter_mut().filter(|st| st.stepping) {
            let cand = &cands[ci];
            ci += 1;
            let remaining = t - st.selected.len();
            let bsz = 1usize.min(remaining);
            let (gamma, new_block): (f64, Vec<usize>) = if cand.len() >= bsz && bsz > 0 {
                let picks = argmin_b_by(cand.len(), bsz, |i| cand[i].1);
                let gamma = picks.iter().map(|&i| cand[i].1).fold(0.0_f64, f64::max);
                let mut block: Vec<usize> = picks.iter().map(|&i| cand[i].0).collect();
                block.sort_unstable();
                (gamma, block)
            } else {
                let mut block: Vec<usize> = cand.iter().map(|&(j, _)| j).collect();
                block.sort_unstable();
                (st.gamma_full, block)
            };

            let mut update_span = phase_span(Phase::Update);
            update_span.flops(4 * m as u64 + 2 * n as u64);
            let h = st.h;
            let shrink = 1.0 - gamma * h;
            {
                let LarsSt { b, y, r, u, c, av, in_model, .. } = &mut *st;
                for i in 0..m {
                    y[i] += gamma * u[i];
                    r[i] = b[i] - y[i];
                }
                for j in 0..n {
                    if in_model[j] {
                        c[j] *= shrink;
                    } else {
                        c[j] -= gamma * av[j];
                    }
                }
            }
            st.ck *= shrink;
            st.residual_norms.push(norm2(&st.r));
            drop(update_span);

            let hit_full_step = new_block.is_empty() || gamma >= st.gamma_full * (1.0 - 1e-12);

            if !new_block.is_empty() {
                let (gib, gbb) = {
                    let mut sp = phase_span(Phase::Gram);
                    let k = st.selected.len() as u64;
                    let bn = new_block.len() as u64;
                    sp.flops(2 * (m as u64) * bn * (k + bn));
                    (a.gram_block(&st.selected, &new_block), a.gram_block(&new_block, &new_block))
                };
                let chol_span = phase_span(Phase::Cholesky);
                let admitted = st.chol.append_block_graceful(&gib, &gbb);
                drop(chol_span);
                st.rank_excluded += new_block.len() - admitted.len();
                for &row in &admitted {
                    st.selected.push(new_block[row]);
                }
                for &j in &new_block {
                    st.in_model[j] = true;
                }
                let refreshed =
                    st.selected.iter().map(|&j| st.c[j].abs()).fold(f64::INFINITY, f64::min);
                st.ck = refreshed.max(st.ck);
            }
            st.cols_at_iter.push(st.selected.len());

            if hit_full_step {
                let reason = if st.rank_excluded > 0
                    && st.selected.len() < t
                    && st.selected.len() + st.rank_excluded >= t
                {
                    StopReason::RankDeficient
                } else {
                    StopReason::Saturated
                };
                st.finish(reason);
            }
        }
    }

    sts.into_iter()
        .map(|mut st| {
            if st.cols_at_iter.last().copied() != Some(st.selected.len()) {
                st.cols_at_iter.push(st.selected.len());
            }
            LarsOutput {
                selected: st.selected,
                residual_norms: st.residual_norms,
                cols_at_iter: st.cols_at_iter,
                y: st.y,
                stop: st.done.unwrap_or(StopReason::Saturated),
            }
        })
        .collect()
}

/// Per-model state for the lockstep LASSO-LARS core — the locals of
/// `lars::lasso_lars::fit_observed`, lifted into a struct.
struct LassoSt {
    b: Vec<f64>,
    x: Vec<f64>,
    active: Vec<usize>,
    order: Vec<usize>,
    order_at_last_bp: Vec<usize>,
    breakpoints: Vec<Breakpoint>,
    drops: usize,
    r: Vec<f64>,
    c: Vec<f64>,
    u: Vec<f64>,
    av: Vec<f64>,
    w: Vec<f64>,
    ck: f64,
    h: f64,
    gamma_full: f64,
    stepping: bool,
    done: Option<StopReason>,
}

impl LassoSt {
    fn new(b: &[f64], m: usize, n: usize) -> Self {
        LassoSt {
            b: b.to_vec(),
            x: vec![0.0; n],
            active: Vec::new(),
            order: Vec::new(),
            order_at_last_bp: Vec::new(),
            breakpoints: Vec::new(),
            drops: 0,
            r: b.to_vec(),
            c: vec![0.0; n],
            u: vec![0.0; m],
            av: vec![0.0; n],
            w: Vec::new(),
            ck: 0.0,
            h: 0.0,
            gamma_full: 0.0,
            stepping: false,
            done: None,
        }
    }

    fn finish(&mut self, stop: StopReason) {
        self.done = Some(stop);
        self.stepping = false;
    }
}

/// Lockstep LASSO-LARS: every model runs the per-event mathematics of
/// `lars::lasso_lars::fit_observed` unchanged (fresh correlations and
/// a from-scratch Gram factorization per breakpoint event — it is the
/// reference implementation), with the per-event `AᵀR` and the fused
/// direction pass batched across the still-running models.
fn lasso_lockstep(
    a: &Matrix,
    responses: &[Vec<f64>],
    t_req: usize,
    lambda_min: f64,
    tol: f64,
    passes: &mut PassCounts,
) -> Vec<LassoFit> {
    let m = a.nrows();
    let n = a.ncols();
    let max_active = t_req.min(m.min(n));
    let max_events = 8 * max_active + 16;
    let mut sts: Vec<LassoSt> = responses.iter().map(|b| LassoSt::new(b, m, n)).collect();

    for _event in 0..max_events {
        // Batched fresh correlations for every still-running model.
        {
            let mut running = 0usize;
            let mut rs: Vec<&[f64]> = Vec::with_capacity(sts.len());
            let mut cs: Vec<&mut [f64]> = Vec::with_capacity(sts.len());
            for st in sts.iter_mut() {
                if st.done.is_some() {
                    continue;
                }
                running += 1;
                let LassoSt { r, c, .. } = st;
                rs.push(r);
                cs.push(c);
            }
            if running == 0 {
                break;
            }
            let mut sp = phase_span(Phase::Corr);
            sp.flops(2 * (running as u64) * (m as u64) * (n as u64));
            a.at_r_multi(&rs, &mut cs);
            passes.batched += 1;
            passes.sequential_equiv += running as u64;
        }

        // Per-model activation + equiangular solve (reference
        // implementation, one model at a time).
        let mut stepping = 0usize;
        for st in sts.iter_mut() {
            st.stepping = false;
            if st.done.is_some() {
                continue;
            }
            let ck = st.c.iter().fold(0.0_f64, |mx, &v| mx.max(v.abs()));
            st.ck = ck;
            if ck <= lambda_min.max(tol) {
                st.finish(StopReason::Saturated);
                continue;
            }
            if st.breakpoints.is_empty() {
                st.breakpoints.push(Breakpoint {
                    lambda: ck,
                    support: Vec::new(),
                    x: st.x.clone(),
                    residual_norm: norm2(&st.r),
                });
            }
            {
                let LassoSt { active, order, c, .. } = &mut *st;
                for j in 0..n {
                    if !active.contains(&j) && c[j].abs() >= ck * (1.0 - 1e-9) {
                        active.push(j);
                        order.push(j);
                    }
                }
                active.sort_unstable();
            }
            if st.active.len() > max_active {
                st.finish(StopReason::TargetReached);
                continue;
            }
            let s: Vec<f64> = st.active.iter().map(|&j| st.c[j]).collect();
            let g = {
                let mut sp = phase_span(Phase::Gram);
                let k = st.active.len() as u64;
                sp.flops(2 * (m as u64) * k * k);
                a.gram_block(&st.active, &st.active)
            };
            let chol_span = phase_span(Phase::Cholesky);
            let factored = Cholesky::factor(&g);
            drop(chol_span);
            let Ok(chol) = factored else {
                st.finish(StopReason::RankDeficient);
                continue;
            };
            let q = chol.solve(&s);
            let sq: f64 = s.iter().zip(&q).map(|(si, qi)| si * qi).sum();
            if !(sq.is_finite() && sq > 0.0) {
                st.finish(StopReason::RankDeficient);
                continue;
            }
            let h = 1.0 / sq.sqrt();
            st.w = q.iter().map(|qi| qi * h).collect();
            st.h = h;
            st.gamma_full = 1.0 / h;
            st.stepping = true;
            stepping += 1;
        }
        if stepping == 0 {
            continue;
        }

        // Batched fused step across the stepping models.
        {
            let mut sp = phase_span(Phase::DirApply);
            let sel_sum: u64 =
                sts.iter().filter(|st| st.stepping).map(|st| st.active.len() as u64).sum();
            sp.flops(2 * (m as u64) * (sel_sum + stepping as u64 * n as u64));
            let mut cols: Vec<&[usize]> = Vec::with_capacity(stepping);
            let mut ws: Vec<&[f64]> = Vec::with_capacity(stepping);
            let mut us: Vec<&mut [f64]> = Vec::with_capacity(stepping);
            let mut avs: Vec<&mut [f64]> = Vec::with_capacity(stepping);
            for st in sts.iter_mut().filter(|st| st.stepping) {
                let LassoSt { active, w, u, av, .. } = st;
                cols.push(active);
                ws.push(w);
                us.push(u);
                avs.push(av);
            }
            a.fused_step_multi(&cols, &ws, &mut us, &mut avs);
        }
        passes.batched += 1;
        passes.sequential_equiv += stepping as u64;

        // Per-model γ scans, step, drop handling, and breakpoint
        // recording (reference implementation, verbatim).
        for st in sts.iter_mut().filter(|st| st.stepping) {
            let ck = st.ck;
            let h = st.h;
            let gamma_full = st.gamma_full;
            let gamma_span = phase_span(Phase::GammaStep);
            let (gamma_add, gamma_drop, drop_pos) = {
                let LassoSt { active, c, av, w, x, .. } = &mut *st;
                let mut gamma_add = gamma_full;
                for j in 0..n {
                    if active.binary_search(&j).is_ok() {
                        continue;
                    }
                    let g1 = (ck - c[j]) / (ck * h - av[j]);
                    let g2 = (ck + c[j]) / (ck * h + av[j]);
                    if let Some(g) = min_positive2(g1, g2) {
                        if g < gamma_add {
                            gamma_add = g;
                        }
                    }
                }
                let mut gamma_drop = f64::INFINITY;
                let mut drop_pos: Option<usize> = None;
                for (k, &j) in active.iter().enumerate() {
                    if w[k] != 0.0 {
                        let g = -x[j] / w[k];
                        if g > tol && g < gamma_drop {
                            gamma_drop = g;
                            drop_pos = Some(k);
                        }
                    }
                }
                (gamma_add, gamma_drop, drop_pos)
            };
            let gamma = gamma_add.min(gamma_drop);
            drop(gamma_span);

            let update_span = phase_span(Phase::Update);
            {
                let LassoSt { active, w, x, r, u, .. } = &mut *st;
                for (k, &j) in active.iter().enumerate() {
                    x[j] += gamma * w[k];
                }
                for i in 0..m {
                    r[i] -= gamma * u[i];
                }
            }
            if gamma_drop < gamma_add {
                // audit: allow(PANIC-REACH) -- gamma_drop < gamma_add implies drop_pos was set: gamma_drop starts at +inf and is only lowered together with drop_pos
                let kpos = drop_pos.unwrap();
                let LassoSt { active, x, order, drops, .. } = &mut *st;
                let j = active.remove(kpos);
                x[j] = 0.0;
                if let Some(pos) = order.iter().position(|&v| v == j) {
                    order.remove(pos);
                }
                *drops += 1;
            }
            let lambda = ck * (1.0 - gamma * h);
            {
                let LassoSt { breakpoints, active, x, r, order, order_at_last_bp, .. } =
                    &mut *st;
                breakpoints.push(Breakpoint {
                    lambda: lambda.max(0.0),
                    support: active.clone(),
                    x: x.clone(),
                    residual_norm: norm2(r),
                });
                order_at_last_bp.clone_from(order);
            }
            drop(update_span);

            if gamma >= gamma_full * (1.0 - 1e-12) {
                st.finish(StopReason::Saturated);
            }
        }
    }

    sts.into_iter()
        .map(|st| {
            let stop = st.done.unwrap_or(StopReason::PoolExhausted);
            let (residual_norms, cols_at_iter) = if st.breakpoints.is_empty() {
                (vec![norm2(&st.b)], vec![0usize])
            } else {
                (
                    st.breakpoints.iter().map(|bp| bp.residual_norm).collect(),
                    st.breakpoints.iter().map(|bp| bp.support.len()).collect(),
                )
            };
            let y: Vec<f64> = st.b.iter().zip(&st.r).map(|(bi, ri)| bi - ri).collect();
            let out = LarsOutput {
                selected: st.order_at_last_bp,
                residual_norms,
                cols_at_iter,
                y,
                stop,
            };
            LassoFit { out, path: LassoPath { breakpoints: st.breakpoints, drops: st.drops } }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::rng::Pcg64;

    fn responses(ds: &datasets::Dataset, k: usize, seed: u64) -> Vec<Vec<f64>> {
        let m = ds.a.nrows();
        let mut rng = Pcg64::new(seed);
        (0..k)
            .map(|i| {
                if i == 0 {
                    ds.b.clone()
                } else {
                    (0..m).map(|_| rng.normal()).collect()
                }
            })
            .collect()
    }

    fn assert_fit_bits_equal(batch: &FitResult, solo: &FitResult, what: &str) {
        assert_eq!(batch.output.selected, solo.output.selected, "{what}: selected");
        assert_eq!(batch.output.cols_at_iter, solo.output.cols_at_iter, "{what}: cols");
        assert_eq!(batch.output.stop, solo.output.stop, "{what}: stop");
        assert_eq!(
            batch.output.residual_norms.len(),
            solo.output.residual_norms.len(),
            "{what}: residual count"
        );
        for (x, y) in batch.output.residual_norms.iter().zip(&solo.output.residual_norms) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: residual bits");
        }
        for (x, y) in batch.output.y.iter().zip(&solo.output.y) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: y bits");
        }
    }

    #[test]
    fn k1_batch_bit_identical_to_single_fit() {
        let ds = datasets::tiny(11);
        for spec in [
            FitSpec::new(Algorithm::Lars).t(10),
            FitSpec::new(Algorithm::LassoLars { lambda_min: 1e-6 }).t(10),
            FitSpec::new(Algorithm::Omp).t(6),
        ] {
            let solo = spec.run(&ds.a, &ds.b).unwrap();
            let batch = spec.fit_batch(&ds.a, &[ds.b.clone()]).unwrap();
            assert_eq!(batch.fits.len(), 1);
            assert_eq!(batch.shared.responses, 1);
            assert_fit_bits_equal(&batch.fits[0], &solo, spec.algorithm.name());
        }
    }

    #[test]
    fn lasso_batch_paths_match_single_fits_bitwise_at_k1() {
        let ds = datasets::tiny_dense(12);
        let spec = FitSpec::new(Algorithm::LassoLars { lambda_min: 1e-6 }).t(8);
        let solo = spec.run(&ds.a, &ds.b).unwrap();
        let batch = spec.fit_batch(&ds.a, &[ds.b.clone()]).unwrap();
        let sp = solo.lasso.as_ref().unwrap();
        let bp = batch.fits[0].lasso.as_ref().unwrap();
        assert_eq!(sp.breakpoints.len(), bp.breakpoints.len());
        assert_eq!(sp.drops, bp.drops);
        for (x, y) in sp.breakpoints.iter().zip(&bp.breakpoints) {
            assert_eq!(x.lambda.to_bits(), y.lambda.to_bits());
            assert_eq!(x.support, y.support);
        }
    }

    #[test]
    fn batch_is_deterministic_across_thread_counts() {
        let ds = datasets::tiny_dense(13);
        let rs = responses(&ds, 5, 99);
        let spec = FitSpec::new(Algorithm::Lars).t(8);
        let reference = par::with_pool(&par::ThreadPool::new(1, 64), || {
            spec.fit_batch(&ds.a, &rs).unwrap()
        });
        for threads in [2usize, 4] {
            let got = par::with_pool(&par::ThreadPool::new(threads, 64), || {
                spec.fit_batch(&ds.a, &rs).unwrap()
            });
            for (b, r) in got.fits.iter().zip(&reference.fits) {
                assert_fit_bits_equal(b, r, &format!("threads={threads}"));
            }
        }
    }

    #[test]
    fn fallback_algorithms_match_sequential_fits() {
        let ds = datasets::tiny(14);
        let rs = responses(&ds, 3, 7);
        for spec in [
            FitSpec::new(Algorithm::Blars { b: 2 }).t(8).ranks(4),
            FitSpec::new(Algorithm::ForwardSelection).t(5),
        ] {
            let batch = spec.fit_batch(&ds.a, &rs).unwrap();
            for (b, resp) in batch.fits.iter().zip(&rs) {
                let solo = spec.run(&ds.a, resp).unwrap();
                assert_fit_bits_equal(b, &solo, spec.algorithm.name());
            }
        }
    }

    #[test]
    fn shared_work_counts_batched_passes() {
        let ds = datasets::tiny_dense(15);
        let rs = responses(&ds, 6, 3);
        let batch = FitSpec::new(Algorithm::Lars).t(6).fit_batch(&ds.a, &rs).unwrap();
        assert_eq!(batch.shared.responses, 6);
        assert!(batch.shared.batched_passes > 0);
        assert!(batch.shared.sequential_passes >= batch.shared.batched_passes);
        assert!(batch.shared.passes_saved() > 0, "6 models must share passes");
    }

    #[test]
    fn invalid_batches_are_rejected_with_typed_errors() {
        use crate::error::ErrorKind;
        let ds = datasets::tiny(16);
        let spec = FitSpec::new(Algorithm::Lars).t(4);
        let empty: Vec<Vec<f64>> = Vec::new();
        assert_eq!(
            spec.fit_batch(&ds.a, &empty).unwrap_err().kind(),
            ErrorKind::InvalidSpec
        );
        let short = vec![vec![0.0; ds.a.nrows() - 1]];
        assert_eq!(
            spec.fit_batch(&ds.a, &short).unwrap_err().kind(),
            ErrorKind::InvalidSpec
        );
        let mut bad = responses(&ds, 2, 1);
        bad[1][0] = f64::NAN;
        let err = spec.fit_batch(&ds.a, &bad).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
        assert!(err.root().contains("response 1"), "{err:#}");
    }

    #[test]
    fn large_batch_spans_many_chunks() {
        let ds = datasets::tiny_dense(17);
        let k = 2 * RESPONSE_CHUNK + 3;
        let rs = responses(&ds, k, 21);
        let batch = FitSpec::new(Algorithm::Lars).t(5).fit_batch(&ds.a, &rs).unwrap();
        assert_eq!(batch.fits.len(), k);
        for fit in &batch.fits {
            assert!(!fit.output.selected.is_empty());
        }
    }
}
