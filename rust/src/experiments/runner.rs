//! Shared machinery for experiment drivers: run one (algorithm,
//! dataset, P, b) cell and collect everything the figures need.

use crate::cluster::{CommCounters, ExecMode, HwParams, SimCluster, Tracer};
use crate::data::{partition, Dataset};
use crate::lars::blars::{blars, BlarsOptions};
use crate::lars::serial::{lars, LarsOptions};
use crate::lars::tblars::{tblars, TblarsOptions};
use crate::lars::LarsOutput;
use crate::rng::Pcg64;

/// Everything one parallel run produces.
pub struct RunResult {
    pub out: LarsOutput,
    /// Simulated seconds (critical path under the α-β model).
    pub sim_time: f64,
    pub counters: CommCounters,
    /// Figure 7/8 categories: [mat products, step size, comm, wait, other].
    pub categories: [f64; 5],
    pub tracer: Tracer,
}

/// Serial LARS reference (ground truth for precision metrics).
pub fn run_lars_ref(ds: &Dataset, t: usize) -> LarsOutput {
    lars(&ds.a, &ds.b, &LarsOptions { t, ..Default::default() })
}

/// One parallel bLARS cell.
pub fn run_blars(ds: &Dataset, t: usize, b: usize, p: usize, hw: HwParams) -> RunResult {
    let mut cluster = SimCluster::new(p, hw, ExecMode::Sequential);
    let out = blars(&ds.a, &ds.b, &BlarsOptions { t, b, ..Default::default() }, &mut cluster);
    collect(out, &cluster)
}

/// One T-bLARS cell. `partition_seed = None` uses the nnz-balanced
/// partition (the paper's default); `Some(seed)` uses a uniformly random
/// partition (Figure 5).
pub fn run_tblars(
    ds: &Dataset,
    t: usize,
    b: usize,
    p: usize,
    hw: HwParams,
    partition_seed: Option<u64>,
) -> RunResult {
    let parts = match partition_seed {
        None => partition::balanced_col_partition(&ds.a, p),
        Some(seed) => {
            let mut rng = Pcg64::new(seed);
            partition::random_col_partition(ds.a.ncols(), p, &mut rng)
        }
    };
    let mut cluster = SimCluster::new(p, hw, ExecMode::Sequential);
    let out = tblars(&ds.a, &ds.b, &parts, &TblarsOptions { t, b, ..Default::default() }, &mut cluster);
    collect(out, &cluster)
}

fn collect(out: LarsOutput, cluster: &SimCluster) -> RunResult {
    RunResult {
        out,
        sim_time: cluster.sim_time(),
        counters: cluster.counters(),
        categories: cluster.tracer().by_category(),
        tracer: cluster.tracer().clone(),
    }
}

/// Pick a target `t` that fits the dataset.
pub fn effective_t(ds: &Dataset, t: usize) -> usize {
    t.min(ds.a.nrows().min(ds.a.ncols()) / 2).max(4)
}
