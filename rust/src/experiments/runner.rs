//! Shared machinery for experiment drivers: run one (algorithm,
//! dataset, P, b) cell through the [`crate::fit`] estimator API and
//! collect everything the figures need.

use crate::cluster::{CommCounters, HwParams, Tracer};
use crate::data::Dataset;
use crate::fit::{Algorithm, FitResult, FitSpec};
use crate::lars::LarsOutput;

/// Everything one parallel run produces.
pub struct RunResult {
    pub out: LarsOutput,
    /// Simulated seconds (critical path under the α-β model).
    pub sim_time: f64,
    pub counters: CommCounters,
    /// Figure 7/8 categories: [mat products, step size, comm, wait, other].
    pub categories: [f64; 5],
    pub tracer: Tracer,
}

/// Serial LARS reference (ground truth for precision metrics).
pub fn run_lars_ref(ds: &Dataset, t: usize) -> LarsOutput {
    FitSpec::new(Algorithm::Lars)
        .t(t)
        .run(&ds.a, &ds.b)
        .expect("valid LARS spec")
        .output
}

/// One parallel bLARS cell.
pub fn run_blars(ds: &Dataset, t: usize, b: usize, p: usize, hw: HwParams) -> RunResult {
    let result = FitSpec::new(Algorithm::Blars { b })
        .t(t)
        .ranks(p)
        .hw(hw)
        .run(&ds.a, &ds.b)
        .expect("valid bLARS spec");
    collect(result)
}

/// One T-bLARS cell. `partition_seed = None` uses the nnz-balanced
/// partition (the paper's default); `Some(seed)` uses a uniformly random
/// partition (Figure 5).
pub fn run_tblars(
    ds: &Dataset,
    t: usize,
    b: usize,
    p: usize,
    hw: HwParams,
    partition_seed: Option<u64>,
) -> RunResult {
    let result = FitSpec::new(Algorithm::TBlars { b, parts: p })
        .t(t)
        .hw(hw)
        .partition_seed(partition_seed)
        .run(&ds.a, &ds.b)
        .expect("valid T-bLARS spec");
    collect(result)
}

fn collect(result: FitResult) -> RunResult {
    let sim = result.sim.expect("cluster fitters report sim telemetry");
    RunResult {
        out: result.output,
        sim_time: sim.sim_time,
        counters: sim.counters,
        categories: sim.categories,
        tracer: sim.tracer,
    }
}

/// Pick a target `t` that fits the dataset.
pub fn effective_t(ds: &Dataset, t: usize) -> usize {
    t.min(ds.a.nrows().min(ds.a.ncols()) / 2).max(4)
}
