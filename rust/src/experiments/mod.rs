//! Experiment drivers — one per table/figure of the paper's evaluation
//! (see DESIGN.md §5 for the index).
//!
//! Every driver takes a [`crate::config::SweepConfig`]-derived setup,
//! runs the relevant sweep on the scaled paper datasets, and returns a
//! rendered report (the console/EXPERIMENTS.md artifact). The CLI
//! (`calars exp <id>`) and the `tables_figures` bench both dispatch
//! through [`run_by_id`].

pub mod fig2;
pub mod runner;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig78;
pub mod table1;
pub mod table2;
pub mod table3;

use crate::config::SweepConfig;
use crate::error::{bail, Result};

/// All experiment ids in paper order.
pub const ALL_IDS: [&str; 10] =
    ["table1", "table2", "table3", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8"];

/// Dispatch an experiment by id; returns the rendered report.
pub fn run_by_id(id: &str, sweep: &SweepConfig, quick: bool) -> Result<String> {
    match id {
        "table1" => Ok(table1::run(sweep, quick)),
        "table2" => Ok(table2::run(sweep, quick)),
        "table3" => Ok(table3::run(sweep)),
        "fig2" => Ok(fig2::run(sweep)),
        "fig3" => Ok(fig3::run(sweep, quick)),
        "fig4" => Ok(fig4::run(sweep, quick)),
        "fig5" => Ok(fig5::run(sweep, quick)),
        "fig6" => Ok(fig6::run(sweep, quick)),
        "fig7" => Ok(fig78::run_fig7(sweep, quick)),
        "fig8" => Ok(fig78::run_fig8(sweep, quick)),
        other => bail!("unknown experiment '{other}' (one of {:?})", ALL_IDS),
    }
}

/// Datasets used by an experiment sweep: the full paper suite, or the
/// two fastest under `--quick`.
pub(crate) fn sweep_datasets(seed: u64, quick: bool) -> Vec<crate::data::Dataset> {
    use crate::data::datasets;
    if quick {
        vec![datasets::tiny(seed), datasets::tiny_dense(seed)]
    } else {
        datasets::paper_suite(seed)
    }
}
