//! Figure 4 — precision in column selection vs block size `b`.
//!
//! Treats serial LARS's `t` selections as ground truth; reports
//! `|method ∩ LARS| / |method|` for bLARS (P-independent) and T-bLARS
//! (per P, nnz-balanced partition). Expected shape (paper §10.1):
//! bLARS precision drops steadily as `b` grows; T-bLARS stays higher
//! and often *recovers* at large `b` (more candidates reach non-leaf
//! rounds).

use super::runner::{effective_t, run_blars, run_lars_ref, run_tblars};
use super::sweep_datasets;
use crate::cluster::HwParams;
use crate::config::SweepConfig;
use crate::lars::quality::precision;
use crate::report::Table;

pub fn run(sweep: &SweepConfig, quick: bool) -> String {
    let hw = HwParams::default();
    let b_values: Vec<usize> =
        if quick { vec![1, 2, 4] } else { sweep.b_values.clone() };
    let p_values: Vec<usize> = if quick { vec![2, 4] } else { vec![4, 16, 64, 128] };
    let mut out = String::from("# Figure 4 — precision in column selection vs b\n");

    for ds in sweep_datasets(sweep.seed, quick) {
        let t = effective_t(&ds, sweep.t);
        let reference = run_lars_ref(&ds, t);
        out.push_str(&format!("\n## {} (t = {t})\n", ds.name));

        let mut headers: Vec<String> = vec!["b".into(), "bLARS".into()];
        headers.extend(p_values.iter().map(|p| format!("T-bLARS P={p}")));
        let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&headers_ref);

        for &b in &b_values {
            let mut row = vec![b.to_string()];
            let rb = run_blars(&ds, t, b, 1, hw);
            row.push(format!("{:.2}", precision(&rb.out.selected, &reference.selected)));
            for &p in &p_values {
                let rt = run_tblars(&ds, t, b, p, hw, None);
                row.push(format!("{:.2}", precision(&rt.out.selected, &reference.selected)));
            }
            table.row(&row);
        }
        out.push_str(&table.render());
    }
    out.push_str(
        "\nShape check (paper Fig. 4): b=1 ⇒ precision 1.00 for bLARS; \
         precision decreases with b; T-bLARS generally above bLARS.\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_has_unit_precision_at_b1() {
        let s = run(&SweepConfig::quick(), true);
        // the first data row is b=1 and bLARS must be exactly LARS
        let row = s.lines().find(|l| l.starts_with("| 1 ")).expect("b=1 row");
        assert!(row.contains("1.00"), "b=1 row: {row}");
    }
}
