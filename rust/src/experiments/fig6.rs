//! Figure 6 — total speedup vs. serial LARS (P = 1, b = 1).
//!
//! Speedup = simulated time of parallel LARS at (P=1, b=1) divided by
//! the simulated time of the method at (P, b). Simulated time = measured
//! per-rank compute critical path + α-β-modeled communication (see
//! `cluster`), exactly the quantity the paper's Table 2 predicts.
//!
//! Expected shape (paper §10.2): bLARS speedups are large and grow with
//! both P and b; T-bLARS speedups are modest except on n ≫ m data
//! (e2006_log1p), where the tournament avoids the wide reductions.

use super::runner::{effective_t, run_blars, run_tblars};
use super::sweep_datasets;
use crate::cluster::HwParams;
use crate::config::SweepConfig;
use crate::report::Table;

pub fn run(sweep: &SweepConfig, quick: bool) -> String {
    let hw = HwParams::default();
    let b_values: Vec<usize> =
        if quick { vec![1, 2, 4] } else { vec![1, 2, 4, 8, 15, 38] };
    let p_values: Vec<usize> = if quick { vec![1, 4] } else { vec![1, 4, 16, 64] };
    let mut out = String::from("# Figure 6 — total speedup over parallel LARS (P=1, b=1)\n");

    for ds in sweep_datasets(sweep.seed, quick) {
        let t = effective_t(&ds, sweep.t);
        let base = run_blars(&ds, t, 1, 1, hw).sim_time;
        out.push_str(&format!("\n## {} (t = {t}, baseline {:.4}s simulated)\n", ds.name, base));

        for (algo, f) in [
            ("bLARS", true),
            ("T-bLARS", false),
        ] {
            let mut headers: Vec<String> = vec!["P \\ b".into()];
            headers.extend(b_values.iter().map(|b| format!("b={b}")));
            let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
            let mut table = Table::new(&headers_ref);
            for &p in &p_values {
                let mut row = vec![format!("P={p}")];
                for &b in &b_values {
                    let st = if f {
                        run_blars(&ds, t, b, p, hw).sim_time
                    } else {
                        run_tblars(&ds, t, b, p, hw, None).sim_time
                    };
                    row.push(format!("{:.2}x", base / st));
                }
                table.row(&row);
            }
            out.push_str(&format!("\n### {algo}\n{}", table.render()));
        }
    }
    out.push_str(
        "\nShape check (paper Fig. 6): bLARS speedup grows with P and b; \
         T-bLARS speedup is best on n >> m (e2006_log1p_like).\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_renders_speedups() {
        let s = run(&SweepConfig::quick(), true);
        assert!(s.contains("bLARS"));
        assert!(s.contains("T-bLARS"));
        assert!(s.contains('x'));
    }
}
