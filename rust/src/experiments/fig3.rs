//! Figure 3 — ℓ2 norm of the residual vs. number of selected columns.
//!
//! Per dataset: the LARS curve, bLARS curves per block size `b`
//! (P does not affect bLARS quality), and T-bLARS curves for a (P, b)
//! subset. Expected shape (paper §10.1): T-bLARS tracks LARS nearly
//! identically; bLARS residuals grow with `b`.

use super::runner::{effective_t, run_blars, run_lars_ref, run_tblars};
use super::sweep_datasets;
use crate::cluster::HwParams;
use crate::config::SweepConfig;
use crate::report::Table;

/// Sample a residual curve at every `step` columns.
fn curve_samples(cols: &[usize], resid: &[f64], step: usize) -> Vec<(usize, f64)> {
    let mut out = Vec::new();
    let mut next = 0usize;
    for (c, r) in cols.iter().zip(resid) {
        if *c >= next {
            out.push((*c, *r));
            next = c + step;
        }
    }
    out
}

pub fn run(sweep: &SweepConfig, quick: bool) -> String {
    let hw = HwParams::default();
    let mut out = String::from("# Figure 3 — residual ℓ2 vs columns selected\n");
    let b_values: Vec<usize> =
        if quick { vec![1, 2, 4] } else { sweep.b_values.iter().copied().take(6).collect() };
    let tb_p = if quick { 4 } else { 16 };

    for ds in sweep_datasets(sweep.seed, quick) {
        let t = effective_t(&ds, sweep.t);
        let step = (t / 10).max(1);
        out.push_str(&format!("\n## {} (t = {t})\n", ds.name));
        let reference = run_lars_ref(&ds, t);
        let mut table = Table::new(&["curve", "samples (cols:resid)"]);
        let fmt = |samples: Vec<(usize, f64)>| {
            samples
                .iter()
                .map(|(c, r)| format!("{c}:{r:.4}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        table.row(&[
            "LARS".into(),
            fmt(curve_samples(&reference.cols_at_iter, &reference.residual_norms, step)),
        ]);
        for &b in &b_values {
            let r = run_blars(&ds, t, b, 1, hw);
            table.row(&[
                format!("bLARS b={b}"),
                fmt(curve_samples(&r.out.cols_at_iter, &r.out.residual_norms, step)),
            ]);
        }
        for &b in &b_values {
            let r = run_tblars(&ds, t, b, tb_p, hw, None);
            table.row(&[
                format!("T-bLARS P={tb_p} b={b}"),
                fmt(curve_samples(&r.out.cols_at_iter, &r.out.residual_norms, step)),
            ]);
        }
        out.push_str(&table.render());

        // Shape check: final residuals.
        let rl = *reference.residual_norms.last().unwrap();
        let rb = run_blars(&ds, t, *b_values.last().unwrap(), 1, hw);
        let rt = run_tblars(&ds, t, *b_values.last().unwrap(), tb_p, hw, None);
        out.push_str(&format!(
            "final residual — LARS {rl:.4} | bLARS(b={}) {:.4} | T-bLARS(b={}) {:.4}\n",
            b_values.last().unwrap(),
            rb.out.residual_norms.last().unwrap(),
            b_values.last().unwrap(),
            rt.out.residual_norms.last().unwrap(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_renders() {
        let s = run(&SweepConfig::quick(), true);
        assert!(s.contains("LARS"));
        assert!(s.contains("bLARS b=2"));
        assert!(s.contains("T-bLARS"));
    }

    #[test]
    fn curve_sampling_subsamples() {
        let cols = vec![0, 1, 2, 3, 4, 5, 6];
        let resid = vec![7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0];
        let s = curve_samples(&cols, &resid, 3);
        assert_eq!(s, vec![(0, 7.0), (3, 4.0), (6, 1.0)]);
    }
}
