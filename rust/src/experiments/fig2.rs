//! Figure 2 — sparsity patterns and per-column/row nonzero
//! distributions of the sparse datasets.
//!
//! The paper draws 128-bin histograms; a console reproduction uses 16
//! coarse bins plus summary skew statistics (max/mean ratio), which is
//! what the figure is demonstrating: the text datasets' heavy-tailed
//! column distributions that motivate nnz-balanced partitioning.

use crate::config::SweepConfig;
use crate::data::datasets;
use crate::report::Table;

fn histogram(counts: &[usize], bins: usize) -> Vec<usize> {
    let max = *counts.iter().max().unwrap_or(&1) as f64;
    let mut hist = vec![0usize; bins];
    for &c in counts {
        let i = (((c as f64) / (max + 1.0)) * bins as f64) as usize;
        hist[i.min(bins - 1)] += 1;
    }
    hist
}

pub fn run(sweep: &SweepConfig) -> String {
    let mut out = String::from("# Figure 2 — sparsity structure of the sparse datasets\n");
    for ds in [
        datasets::sector_like(sweep.seed),
        datasets::e2006_log1p_like(sweep.seed),
        datasets::e2006_tfidf_like(sweep.seed),
    ] {
        let col = ds.a.col_nnz_counts();
        let mean = col.iter().sum::<usize>() as f64 / col.len() as f64;
        let max = *col.iter().max().unwrap() as f64;
        let hist = histogram(&col, 16);
        out.push_str(&format!(
            "\n## {} — per-column nnz: mean {:.1}, max {:.0}, max/mean {:.1}\n",
            ds.name,
            mean,
            max,
            max / mean
        ));
        let mut t = Table::new(&["bin", "columns"]);
        for (i, h) in hist.iter().enumerate() {
            t.row(&[format!("{i}"), h.to_string()]);
        }
        out.push_str(&t.render());
    }
    out.push_str(
        "\nShape check (paper Fig. 2): histograms are heavy-tailed — most \
         columns hold few nonzeros, a small set holds many.\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_bins_sum_to_total() {
        let counts = vec![1usize, 2, 3, 100, 1, 1];
        let h = histogram(&counts, 4);
        assert_eq!(h.iter().sum::<usize>(), counts.len());
    }

    #[test]
    fn report_shows_heavy_tail() {
        let s = run(&SweepConfig { seed: 3, ..SweepConfig::quick() });
        assert!(s.contains("sector_like"));
        assert!(s.contains("max/mean"));
    }
}
