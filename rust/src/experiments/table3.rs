//! Table 3 — dataset properties (m, n, nnz(A)/mn).
//!
//! Prints the scaled synthetic substitutes side by side with the
//! paper's original values so the aspect-ratio/density match is
//! auditable.

use crate::config::SweepConfig;
use crate::data::datasets;
use crate::report::Table;

/// Paper's Table 3 values (original scale) for comparison.
const PAPER: [(&str, usize, usize, f64); 4] = [
    ("sector", 6412, 55197, 0.003),
    ("YearPredictionMSD", 463715, 90, 1.00),
    ("E2006_log1p", 16087, 4272227, 0.001),
    ("E2006_tfidf", 16087, 150360, 0.008),
];

pub fn run(sweep: &SweepConfig) -> String {
    let suite = datasets::paper_suite(sweep.seed);
    let mut t = Table::new(&[
        "dataset (ours)",
        "m",
        "n",
        "nnz/mn",
        "nnz/col",
        "paper dataset",
        "paper m",
        "paper n",
        "paper nnz/mn",
        "paper nnz/col",
    ]);
    for (ds, (pname, pm, pn, pd)) in suite.iter().zip(PAPER.iter()) {
        let s = ds.stats();
        t.row(&[
            s.name.clone(),
            s.m.to_string(),
            s.n.to_string(),
            format!("{:.4}", s.density),
            format!("{:.1}", s.nnz as f64 / s.n as f64),
            pname.to_string(),
            pm.to_string(),
            pn.to_string(),
            format!("{pd:.3}"),
            format!("{:.1}", pd * *pm as f64),
        ]);
    }
    format!(
        "# Table 3 — dataset properties (scaled substitutes)\n{}\
         \nScaling rule: m and n are reduced ~10x; density is raised so the\n\
         per-column nnz (the geometry that drives selection behaviour)\n\
         matches the paper's full-scale datasets. See DESIGN.md §3.\n",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_four() {
        let s = run(&SweepConfig { seed: 1, ..SweepConfig::quick() });
        assert!(s.contains("sector_like"));
        assert!(s.contains("year_like"));
        assert!(s.contains("e2006_log1p_like"));
        assert!(s.contains("e2006_tfidf_like"));
        assert!(s.contains("E2006_tfidf"));
    }
}
