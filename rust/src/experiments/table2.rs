//! Table 2 — asymptotic cost comparison: LARS vs bLARS vs T-bLARS.
//!
//! Measures total F/W/L for the three methods on each dataset at a
//! fixed (P, b) and checks the table's qualitative claims:
//!
//! * bLARS cuts all three costs by ≈ b relative to LARS;
//! * both block methods have the same latency scaling `(t/b)·log P`;
//! * bLARS words scale with **n**, T-bLARS words with **m** — so on
//!   n ≫ m data T-bLARS moves far fewer words.

use super::runner::{effective_t, run_blars, run_tblars};
use super::sweep_datasets;
use crate::cluster::HwParams;
use crate::config::SweepConfig;
use crate::metrics::fmt_count;
use crate::report::Table;

pub fn run(sweep: &SweepConfig, quick: bool) -> String {
    let hw = HwParams::default();
    let p = if quick { 4 } else { 16 };
    let b = if quick { 2 } else { 4 };
    let mut out = format!("# Table 2 — asymptotic cost comparison (P = {p}, b = {b})\n");

    for ds in sweep_datasets(sweep.seed, quick) {
        let t = effective_t(&ds, sweep.t);
        out.push_str(&format!(
            "\n## {} (m = {}, n = {}, t = {t})\n",
            ds.name,
            ds.a.nrows(),
            ds.a.ncols()
        ));
        let lars = run_blars(&ds, t, 1, p, hw);
        let bl = run_blars(&ds, t, b, p, hw);
        let tb = run_tblars(&ds, t, b, p, hw, None);

        let mut table =
            Table::new(&["method", "F (flops)", "W (words)", "L (msgs)", "sim time (s)"]);
        for (name, r) in [("LARS (b=1)", &lars), ("bLARS", &bl), ("T-bLARS", &tb)] {
            table.row(&[
                name.into(),
                fmt_count(r.counters.flops),
                fmt_count(r.counters.words),
                fmt_count(r.counters.msgs),
                format!("{:.4}", r.sim_time),
            ]);
        }
        out.push_str(&table.render());

        // Qualitative claims.
        let wr = lars.counters.words as f64 / bl.counters.words.max(1) as f64;
        let lr = lars.counters.msgs as f64 / bl.counters.msgs.max(1) as f64;
        out.push_str(&format!(
            "claims: W(LARS)/W(bLARS) = {wr:.1} (≈ b = {b}); \
             L(LARS)/L(bLARS) = {lr:.1} (≈ b = {b}); \
             W(T-bLARS)/W(bLARS) = {:.2} (small iff n >> m)\n",
            tb.counters.words as f64 / bl.counters.words.max(1) as f64,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_shows_blars_savings() {
        let s = run(&SweepConfig::quick(), true);
        assert!(s.contains("bLARS"));
        assert!(s.contains("T-bLARS"));
        assert!(s.contains("claims:"));
    }
}
