//! Figure 5 — effect of random column partitions on T-bLARS precision.
//!
//! Fix P = 128 (scaled down under `--quick`), run T-bLARS on 10
//! uniformly random column partitions per `b`, report min/mean/max
//! precision vs serial LARS. Expected shape (paper §10.1): spread is
//! visible but T-bLARS stays above bLARS in most cells.

use super::runner::{effective_t, run_blars, run_lars_ref, run_tblars};
use super::sweep_datasets;
use crate::cluster::HwParams;
use crate::config::SweepConfig;
use crate::lars::quality::{min_mean_max, precision};
use crate::report::Table;

pub fn run(sweep: &SweepConfig, quick: bool) -> String {
    let hw = HwParams::default();
    let p = if quick { 8 } else { 128 };
    let n_partitions = if quick { 3 } else { 10 };
    // Representative b subset (the paper sweeps 2..38; the sequential
    // simulator pays all 128 ranks' work on one core, so the full cross
    // product is reserved for `fig4`).
    let b_values: Vec<usize> = if quick { vec![1, 2, 4] } else { vec![2, 5, 15, 38] };
    let mut out =
        format!("# Figure 5 — T-bLARS precision over {n_partitions} random partitions (P = {p})\n");

    for ds in sweep_datasets(sweep.seed, quick) {
        let t = effective_t(&ds, sweep.t);
        let reference = run_lars_ref(&ds, t);
        out.push_str(&format!("\n## {} (t = {t})\n", ds.name));
        let mut table =
            Table::new(&["b", "min", "mean", "max", "balanced", "bLARS (ref)"]);
        for &b in &b_values {
            let precisions: Vec<f64> = (0..n_partitions)
                .map(|i| {
                    let r = run_tblars(&ds, t, b, p, hw, Some(sweep.seed ^ (i as u64 + 1)));
                    precision(&r.out.selected, &reference.selected)
                })
                .collect();
            let s = min_mean_max(&precisions);
            let balanced = {
                let r = run_tblars(&ds, t, b, p, hw, None);
                precision(&r.out.selected, &reference.selected)
            };
            let blars_ref = {
                let r = run_blars(&ds, t, b, 1, hw);
                precision(&r.out.selected, &reference.selected)
            };
            table.row(&[
                b.to_string(),
                format!("{:.2}", s.min),
                format!("{:.2}", s.mean),
                format!("{:.2}", s.max),
                format!("{balanced:.2}"),
                format!("{blars_ref:.2}"),
            ]);
        }
        out.push_str(&table.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_renders_bars() {
        let s = run(&SweepConfig::quick(), true);
        assert!(s.contains("min"));
        assert!(s.contains("balanced"));
        assert!(s.contains("## tiny"));
    }
}
