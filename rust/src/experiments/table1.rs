//! Table 1 — per-step running-time costs of parallel bLARS.
//!
//! The tracer already attributes measured flops/words/messages to each
//! algorithm phase (= the step groups of Table 1). This driver renders
//! the per-phase measurements for a (P, b) cell and then verifies the
//! table's *scaling claims*: F, W and L all drop by ≈ b when b grows
//! (the `tmn/(bP)`, `(tn/b)·logP` and `(t/b)·logP` leading terms), and
//! the words/messages grow by ≈ log P.

use super::runner::{effective_t, run_blars};
use crate::cluster::{HwParams, Phase};
use crate::config::SweepConfig;
use crate::data::datasets;
use crate::metrics::fmt_count;
use crate::report::Table;

/// Leading-order Table 1 totals (t ≫ b assumed).
pub fn model_totals(t: f64, m: f64, n: f64, p: f64, b: f64) -> (f64, f64, f64) {
    let logp = (p.max(2.0)).log2();
    let f = t * m * n / (b * p) + t * n / b + t * t * m / p + t * t * t;
    let w = (t * n / b) * logp + t * t * logp;
    let l = (t / b) * logp;
    (f, w, l)
}

pub fn run(sweep: &SweepConfig, quick: bool) -> String {
    let ds = if quick { datasets::tiny(sweep.seed) } else { datasets::sector_like(sweep.seed) };
    let t = effective_t(&ds, sweep.t);
    let hw = HwParams::default();
    let p = if quick { 4 } else { 16 };
    let mut out = format!(
        "# Table 1 — per-step costs of parallel bLARS ({}, t = {t}, P = {p})\n",
        ds.name
    );

    // Per-phase measured table at b = 4.
    let b = 4;
    let r = run_blars(&ds, t, b, p, hw);
    let mut table = Table::new(&["step group (phase)", "F (flops)", "W (words)", "L (msgs)"]);
    for phase in Phase::ALL {
        let s = r.tracer.get(phase);
        if s.flops == 0 && s.words == 0 && s.msgs == 0 {
            continue;
        }
        table.row(&[
            format!("{phase:?}"),
            fmt_count(s.flops),
            fmt_count(s.words),
            fmt_count(s.msgs),
        ]);
    }
    let totals = r.counters;
    table.row(&[
        "TOTAL".into(),
        fmt_count(totals.flops),
        fmt_count(totals.words),
        fmt_count(totals.msgs),
    ]);
    out.push_str(&table.render());

    // Scaling verification: measured(b)/measured(1) vs model.
    let (m_, n_) = (ds.a.nrows() as f64, ds.a.ncols() as f64);
    let mut scale = Table::new(&[
        "b",
        "F meas",
        "F model",
        "W meas",
        "W model",
        "L meas",
        "L model",
    ]);
    let base = run_blars(&ds, t, 1, p, hw).counters;
    let (f1, w1, l1) = model_totals(t as f64, m_, n_, p as f64, 1.0);
    for &b in &[1usize, 2, 4, 8] {
        let c = run_blars(&ds, t, b, p, hw).counters;
        let (fm, wm, lm) = model_totals(t as f64, m_, n_, p as f64, b as f64);
        scale.row(&[
            b.to_string(),
            format!("{:.2}", c.flops as f64 / base.flops as f64),
            format!("{:.2}", fm / f1),
            format!("{:.2}", c.words as f64 / base.words as f64),
            format!("{:.2}", wm / w1),
            format!("{:.2}", c.msgs as f64 / base.msgs as f64),
            format!("{:.2}", lm / l1),
        ]);
    }
    out.push_str(&format!(
        "\n## Scaling vs b (ratios to b = 1; model = Table 1 leading terms)\n{}",
        scale.render()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_scales_inverse_b() {
        let (f1, w1, l1) = model_totals(60.0, 1e4, 1e5, 16.0, 1.0);
        let (f4, w4, l4) = model_totals(60.0, 1e4, 1e5, 16.0, 4.0);
        assert!(f4 < f1 && w4 < w1 && l4 < l1);
        assert!((l1 / l4 - 4.0).abs() < 1e-9, "L scales exactly 1/b");
    }

    #[test]
    fn quick_run_renders() {
        let s = run(&SweepConfig::quick(), true);
        assert!(s.contains("TOTAL"));
        assert!(s.contains("Scaling vs b"));
    }
}
