//! Figures 7 & 8 — running-time breakdowns.
//!
//! Figure 7 fixes b = 1 and varies P; Figure 8 fixes P (= 128 in the
//! paper) and varies b. Each cell decomposes simulated time into the
//! paper's categories: matrix products, step-size γ, communication,
//! wait (T-bLARS serial tournament), other.
//!
//! Expected shape (paper §10.2): matvec time falls with P and b for
//! both methods; bLARS communication share is larger on n ≫ m data;
//! T-bLARS wait dominates on everything except the widest dataset;
//! communication of both methods falls as b grows.

use super::runner::{effective_t, run_blars, run_tblars, RunResult};
use super::sweep_datasets;
use crate::cluster::HwParams;
use crate::config::SweepConfig;
use crate::report::Table;

fn breakdown_row(label: String, r: &RunResult) -> Vec<String> {
    let total: f64 = r.categories.iter().sum::<f64>().max(1e-12);
    let pct = |x: f64| format!("{:.0}%", 100.0 * x / total);
    vec![
        label,
        format!("{:.4}", r.sim_time),
        pct(r.categories[0]),
        pct(r.categories[1]),
        pct(r.categories[2]),
        pct(r.categories[3]),
        pct(r.categories[4]),
    ]
}

const HEADERS: [&str; 7] =
    ["config", "sim time (s)", "matprod", "gamma", "comm", "wait", "other"];

fn render(
    title: &str,
    sweep: &SweepConfig,
    quick: bool,
    cells: impl Fn(&crate::data::Dataset, usize) -> Vec<(String, RunResult)>,
) -> String {
    let mut out = format!("# {title}\n");
    for ds in sweep_datasets(sweep.seed, quick) {
        let t = effective_t(&ds, sweep.t);
        out.push_str(&format!("\n## {} (t = {t})\n", ds.name));
        let mut table = Table::new(&HEADERS);
        for (label, r) in cells(&ds, t) {
            table.row(&breakdown_row(label, &r));
        }
        out.push_str(&table.render());
    }
    out
}

pub fn run_fig7(sweep: &SweepConfig, quick: bool) -> String {
    let hw = HwParams::default();
    let p_values: Vec<usize> = if quick { vec![1, 4] } else { vec![1, 4, 16, 64, 128] };
    render(
        "Figure 7 — runtime breakdown, b = 1, varying P",
        sweep,
        quick,
        |ds, t| {
            let mut cells = Vec::new();
            for &p in &p_values {
                cells.push((format!("bLARS P={p}"), run_blars(ds, t, 1, p, hw)));
            }
            for &p in &p_values {
                cells.push((format!("T-bLARS P={p}"), run_tblars(ds, t, 1, p, hw, None)));
            }
            cells
        },
    )
}

pub fn run_fig8(sweep: &SweepConfig, quick: bool) -> String {
    let hw = HwParams::default();
    let p = if quick { 4 } else { 128 };
    let b_values: Vec<usize> = if quick { vec![1, 2, 4] } else { sweep.b_values.clone() };
    render(
        &format!("Figure 8 — runtime breakdown, P = {p}, varying b"),
        sweep,
        quick,
        |ds, t| {
            let mut cells = Vec::new();
            for &b in &b_values {
                cells.push((format!("bLARS b={b}"), run_blars(ds, t, b, p, hw)));
            }
            for &b in &b_values {
                cells.push((format!("T-bLARS b={b}"), run_tblars(ds, t, b, p, hw, None)));
            }
            cells
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_quick_renders() {
        let s = run_fig7(&SweepConfig::quick(), true);
        assert!(s.contains("matprod"));
        assert!(s.contains("bLARS P=4"));
        assert!(s.contains("T-bLARS P=4"));
    }

    #[test]
    fn fig8_quick_renders() {
        let s = run_fig8(&SweepConfig::quick(), true);
        assert!(s.contains("b=2"));
        assert!(s.contains("wait"));
    }
}
