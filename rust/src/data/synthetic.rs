//! Synthetic regression problem generators.
//!
//! The paper's datasets (LIBSVM `sector`, `YearPredictionMSD`,
//! `E2006_log1p`, `E2006_tfidf`) are not redistributable inside this
//! environment, so we generate matched substitutes: same aspect ratio
//! and density (Table 3), and for the sparse ones the same *skewed*
//! per-column nonzero distribution (Figure 2) via a log-normal column
//! nnz law. A planted `k`-sparse ground truth makes precision/recovery
//! experiments meaningful.

use crate::linalg::{CscMatrix, DenseMatrix, Matrix};
use crate::rng::Pcg64;

/// Parameters for a synthetic problem.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub m: usize,
    pub n: usize,
    /// Target nnz(A)/(m·n). `1.0` ⇒ dense storage.
    pub density: f64,
    /// Log-normal σ for per-column nnz (0 ⇒ uniform columns). Matches
    /// Figure 2's heavy-tailed histograms when ≈ 1.0–1.5.
    pub col_skew: f64,
    /// Number of planted true features.
    pub k_true: usize,
    /// Relative noise level σ‖Ax‖/√m added to the response.
    pub noise: f64,
}

/// Generated problem: design matrix (unit-norm columns), response, the
/// planted support (sorted), and the pre-normalization column norms
/// (a by-product of the fused normalize pass — the serving layer's
/// GramCache stores them per dataset).
#[derive(Clone, Debug)]
pub struct Synthetic {
    pub a: Matrix,
    pub b: Vec<f64>,
    pub true_support: Vec<usize>,
    pub col_norms: Vec<f64>,
}

/// Generate a problem from a spec, deterministically in `seed`.
pub fn generate(spec: &SyntheticSpec, seed: u64) -> Synthetic {
    let mut rng = Pcg64::new(seed);
    let mut a: Matrix = if spec.density >= 0.999 {
        Matrix::Dense(dense_design(spec.m, spec.n, &mut rng))
    } else {
        Matrix::Sparse(sparse_design(spec, &mut rng))
    };
    // Fused normalize: one norm sweep + one scaling pass, keeping the
    // pre-normalization norms instead of recomputing them later.
    let col_norms = a.normalize_columns_with_norms();

    // Planted sparse model: support sampled uniformly, coefficients with
    // random signs and magnitudes bounded away from zero so every true
    // feature carries signal.
    let mut support = rng.sample_indices(spec.n, spec.k_true.min(spec.n));
    support.sort_unstable();
    let coefs: Vec<f64> = (0..support.len())
        .map(|_| {
            let mag = 1.0 + 2.0 * rng.uniform();
            if rng.uniform() < 0.5 {
                -mag
            } else {
                mag
            }
        })
        .collect();

    let mut b = vec![0.0; spec.m];
    a.gemv_cols(&support, &coefs, &mut b);

    if spec.noise > 0.0 {
        let signal = crate::linalg::norm2(&b);
        let scale = spec.noise * signal / (spec.m as f64).sqrt();
        for bi in b.iter_mut() {
            *bi += scale * rng.normal();
        }
    }

    Synthetic { a, b, true_support: support, col_norms }
}

fn dense_design(m: usize, n: usize, rng: &mut Pcg64) -> DenseMatrix {
    DenseMatrix::from_fn(m, n, |_, _| rng.normal())
}

/// Sparse design with a log-normal per-column nnz distribution rescaled
/// to hit the target density, mimicking Figure 2's text-data skew.
fn sparse_design(spec: &SyntheticSpec, rng: &mut Pcg64) -> CscMatrix {
    let target_nnz = (spec.density * spec.m as f64 * spec.n as f64).round().max(spec.n as f64);
    // Draw raw per-column weights, rescale to the target total.
    let raw: Vec<f64> = (0..spec.n)
        .map(|_| if spec.col_skew > 0.0 { rng.lognormal(0.0, spec.col_skew) } else { 1.0 })
        .collect();
    let total: f64 = raw.iter().sum();
    let mut cols = Vec::with_capacity(spec.n);
    for w in raw {
        let mut k = ((w / total) * target_nnz).round() as usize;
        // ≥ 2 entries per column: unit-normalized single-entry columns
        // are exact ± duplicates of each other (and of basis vectors),
        // which makes the Gram matrix singular by construction — real
        // text features are distinct. (≥ 1 keeps the unit-norm
        // assumption when m == 1.)
        k = k.clamp(2.min(spec.m), spec.m);
        let rows = rng.sample_indices(spec.m, k);
        let col: Vec<(usize, f64)> = rows
            .into_iter()
            .map(|r| {
                let v = loop {
                    let v = rng.normal();
                    if v != 0.0 {
                        break v;
                    }
                };
                (r, v)
            })
            .collect();
        cols.push(col);
    }
    CscMatrix::from_columns(spec.m, cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SyntheticSpec {
        SyntheticSpec { m: 200, n: 400, density: 0.02, col_skew: 1.0, k_true: 10, noise: 0.01 }
    }

    #[test]
    fn deterministic() {
        let a = generate(&spec(), 5);
        let b = generate(&spec(), 5);
        assert_eq!(a.true_support, b.true_support);
        assert_eq!(a.b, b.b);
    }

    #[test]
    fn seeds_differ() {
        let a = generate(&spec(), 1);
        let b = generate(&spec(), 2);
        assert_ne!(a.b, b.b);
    }

    #[test]
    fn columns_unit_norm() {
        let s = generate(&spec(), 3);
        for j in 0..40 {
            assert!((s.a.col_norm(j) - 1.0).abs() < 1e-10, "col {j}");
        }
    }

    #[test]
    fn density_near_target() {
        let s = generate(&spec(), 4);
        let density = s.a.nnz() as f64 / (200.0 * 400.0);
        assert!(
            (density - 0.02).abs() < 0.01,
            "density {density} too far from 0.02"
        );
    }

    #[test]
    fn dense_when_density_one() {
        let s = generate(
            &SyntheticSpec { m: 30, n: 10, density: 1.0, col_skew: 0.0, k_true: 3, noise: 0.0 },
            7,
        );
        assert!(!s.a.is_sparse());
    }

    #[test]
    fn skew_creates_spread() {
        let s = generate(
            &SyntheticSpec { m: 500, n: 300, density: 0.05, col_skew: 1.5, k_true: 5, noise: 0.0 },
            8,
        );
        let counts = s.a.col_nnz_counts();
        let max = *counts.iter().max().unwrap() as f64;
        let mean = counts.iter().sum::<usize>() as f64 / counts.len() as f64;
        assert!(max > 3.0 * mean, "expected heavy tail: max={max} mean={mean}");
    }

    #[test]
    fn noiseless_response_in_span() {
        let s = generate(
            &SyntheticSpec { m: 50, n: 30, density: 1.0, col_skew: 0.0, k_true: 4, noise: 0.0 },
            9,
        );
        // b must be a combination of exactly the support columns: residual
        // after projecting onto support is ~0. Cheap check: correlations of
        // non-support columns are strictly below the max.
        assert_eq!(s.true_support.len(), 4);
        let mut c = vec![0.0; 30];
        s.a.at_r(&s.b, &mut c);
        let max_on_support =
            s.true_support.iter().map(|&j| c[j].abs()).fold(0.0f64, f64::max);
        assert!(max_on_support > 0.0);
    }
}
