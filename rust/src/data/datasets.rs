//! Dataset registry: the paper's Table 3 datasets as synthetic
//! substitutes (scaled ~1/10 linearly; see DESIGN.md §3), plus stats
//! used to regenerate Table 3 and Figure 2.

use super::synthetic::{generate, SyntheticSpec};
use crate::linalg::Matrix;

/// A named regression problem.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub a: Matrix,
    pub b: Vec<f64>,
    /// Planted support for synthetic data (None for loaded files).
    pub true_support: Option<Vec<usize>>,
    /// Pre-normalization column norms (by-product of the fused
    /// normalize pass; the serving layer caches them per dataset).
    pub col_norms: Vec<f64>,
}

impl Dataset {
    pub fn from_synthetic(name: &str, spec: &SyntheticSpec, seed: u64) -> Self {
        let s = generate(spec, seed);
        Dataset {
            name: name.to_string(),
            a: s.a,
            b: s.b,
            true_support: Some(s.true_support),
            col_norms: s.col_norms,
        }
    }

    /// Table 3 row for this dataset.
    pub fn stats(&self) -> DatasetStats {
        let m = self.a.nrows();
        let n = self.a.ncols();
        let nnz = self.a.nnz();
        DatasetStats {
            name: self.name.clone(),
            m,
            n,
            density: nnz as f64 / (m as f64 * n as f64),
            nnz,
        }
    }
}

/// Table 3 row.
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetStats {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub density: f64,
    pub nnz: usize,
}

/// `sector`-like: sparse text data, m < n, skewed columns.
/// Paper: m=6412, n=55197, nnz/mn=0.003 (≈19 nnz/column).
/// Scaled m=641, n=5520 with density raised ×10 to **preserve the
/// per-column nnz geometry** (19/column) — the quantity that drives
/// selection behaviour; see DESIGN.md §3.
pub fn sector_like(seed: u64) -> Dataset {
    Dataset::from_synthetic(
        "sector_like",
        &SyntheticSpec { m: 641, n: 5520, density: 0.03, col_skew: 1.3, k_true: 75, noise: 0.02 },
        seed,
    )
}

/// `YearPredictionMSD`-like: tall dense data, m ≫ n.
/// Paper: m=463715, n=90, dense → scaled m=16384, n=90.
pub fn year_like(seed: u64) -> Dataset {
    Dataset::from_synthetic(
        "year_like",
        &SyntheticSpec { m: 16384, n: 90, density: 1.0, col_skew: 0.0, k_true: 40, noise: 0.05 },
        seed,
    )
}

/// `E2006_log1p`-like: extremely wide sparse data, n ≫ m.
/// Paper: m=16087, n=4272227, nnz/mn=0.001 (≈16 nnz/column).
/// Scaled m=1608, n=42722; density ×10 preserves nnz/column ≈ 16.
pub fn e2006_log1p_like(seed: u64) -> Dataset {
    Dataset::from_synthetic(
        "e2006_log1p_like",
        &SyntheticSpec {
            m: 1608,
            n: 42722,
            density: 0.01,
            col_skew: 1.5,
            k_true: 75,
            noise: 0.02,
        },
        seed,
    )
}

/// `E2006_tfidf`-like: wide sparse data.
/// Paper: m=16087, n=150360, nnz/mn=0.008 (≈129 nnz/column).
/// Scaled m=1608, n=15036; density ×10 preserves nnz/column ≈ 129.
pub fn e2006_tfidf_like(seed: u64) -> Dataset {
    Dataset::from_synthetic(
        "e2006_tfidf_like",
        &SyntheticSpec {
            m: 1608,
            n: 15036,
            density: 0.08,
            col_skew: 1.3,
            k_true: 75,
            noise: 0.02,
        },
        seed,
    )
}

/// Small fast dataset for tests/examples/CI.
pub fn tiny(seed: u64) -> Dataset {
    Dataset::from_synthetic(
        "tiny",
        &SyntheticSpec { m: 120, n: 300, density: 0.15, col_skew: 0.8, k_true: 12, noise: 0.01 },
        seed,
    )
}

/// Small dense dataset for tests.
pub fn tiny_dense(seed: u64) -> Dataset {
    Dataset::from_synthetic(
        "tiny_dense",
        &SyntheticSpec { m: 150, n: 60, density: 1.0, col_skew: 0.0, k_true: 10, noise: 0.01 },
        seed,
    )
}

/// All four paper datasets (scaled), in Table 3 order.
pub fn paper_suite(seed: u64) -> Vec<Dataset> {
    vec![sector_like(seed), year_like(seed), e2006_log1p_like(seed), e2006_tfidf_like(seed)]
}

/// Look a dataset up by name (CLI entry point).
pub fn by_name(name: &str, seed: u64) -> Option<Dataset> {
    match name {
        "sector" | "sector_like" => Some(sector_like(seed)),
        "year" | "year_like" => Some(year_like(seed)),
        "e2006_log1p" | "e2006_log1p_like" => Some(e2006_log1p_like(seed)),
        "e2006_tfidf" | "e2006_tfidf_like" => Some(e2006_tfidf_like(seed)),
        "tiny" => Some(tiny(seed)),
        "tiny_dense" => Some(tiny_dense(seed)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_shapes() {
        let d = tiny(1);
        assert_eq!(d.a.nrows(), 120);
        assert_eq!(d.a.ncols(), 300);
        assert_eq!(d.b.len(), 120);
        assert!(d.a.is_sparse());
    }

    #[test]
    fn sector_like_matches_table3_shape() {
        let d = sector_like(1);
        let s = d.stats();
        assert_eq!(s.m, 641);
        assert_eq!(s.n, 5520);
        // Scaled geometry: nnz per column matches the paper's full-scale
        // dataset (0.003 × 6412 ≈ 19), not the raw density.
        let nnz_per_col = s.nnz as f64 / s.n as f64;
        assert!((nnz_per_col - 19.2).abs() < 6.0, "nnz/col={nnz_per_col}");
    }

    #[test]
    fn year_like_is_dense() {
        let d = year_like(1);
        assert!(!d.a.is_sparse());
        assert_eq!(d.a.ncols(), 90);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("tiny", 0).is_some());
        assert!(by_name("sector", 0).is_some());
        assert!(by_name("nope", 0).is_none());
    }

    #[test]
    fn stats_consistent() {
        let d = tiny(2);
        let s = d.stats();
        assert_eq!(s.nnz, d.a.nnz());
        assert!((s.density - s.nnz as f64 / (s.m * s.n) as f64).abs() < 1e-12);
    }
}
