//! Dataset substrate: synthetic generators matched to the paper's
//! Table 3 / Figure 2, a LIBSVM-format parser (used when the real files
//! are present), and the row/column partitioners the two algorithms
//! need.

pub mod datasets;
pub mod libsvm;
pub mod partition;
pub mod synthetic;

pub use datasets::{Dataset, DatasetStats};
