//! Data partitioners.
//!
//! bLARS assumes **row-partitioned** data (each rank holds `m/P` rows,
//! Alg. 2); T-bLARS assumes **column-partitioned** data (each rank holds
//! `n/P` columns, §8). For sparse, column-unbalanced matrices the paper
//! balances by nnz (§10: "we distribute the columns ... so that the
//! partitioned columns at each processor have roughly the same number of
//! nonzeros"); Figure 5 additionally studies *random* column partitions.

use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Contiguous row ranges, one per rank; sizes differ by ≤ 1.
pub fn row_ranges(m: usize, p: usize) -> Vec<(usize, usize)> {
    assert!(p >= 1);
    let base = m / p;
    let extra = m % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for r in 0..p {
        let len = base + usize::from(r < extra);
        out.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, m);
    out
}

/// Row shards of a matrix, one per rank.
pub fn row_shards(a: &Matrix, p: usize) -> Vec<Matrix> {
    row_ranges(a.nrows(), p).into_iter().map(|(r0, r1)| a.row_slice(r0, r1)).collect()
}

/// Deterministic k-fold row partition for cross-validated model
/// selection ([`crate::select`]): the row indices are permuted by
/// `seed` (Fisher-Yates over [`Pcg64`]) and split into `k` near-equal
/// chunks via [`row_ranges`]. Each fold's held-out row list comes back
/// **sorted ascending** (what [`crate::linalg::Matrix::row_subset`]
/// expects), so together the folds are a disjoint cover of `0..m`.
/// `seed` changes the assignment, never the fold sizes.
pub fn cv_folds(m: usize, k: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!((1..=m).contains(&k), "need 1 ≤ k ≤ m (got k={k}, m={m})");
    let mut idx: Vec<usize> = (0..m).collect();
    let mut rng = Pcg64::new(seed);
    rng.shuffle(&mut idx);
    row_ranges(m, k)
        .into_iter()
        .map(|(a, b)| {
            let mut fold = idx[a..b].to_vec();
            fold.sort_unstable();
            fold
        })
        .collect()
}

/// nnz-balanced column partition: greedy LPT (largest column first into
/// the lightest bin). Returns `p` column-index lists, each sorted.
pub fn balanced_col_partition(a: &Matrix, p: usize) -> Vec<Vec<usize>> {
    assert!(p >= 1);
    let counts = a.col_nnz_counts();
    let mut order: Vec<usize> = (0..counts.len()).collect();
    order.sort_unstable_by(|&i, &j| counts[j].cmp(&counts[i]).then(i.cmp(&j)));
    let mut bins: Vec<Vec<usize>> = vec![Vec::new(); p];
    let mut loads = vec![0usize; p];
    for j in order {
        // Lightest bin (ties → lowest rank).
        // audit: allow(PANIC-REACH) -- p >= 1 is asserted at entry, so the bin range is never empty
        let r = (0..p).min_by_key(|&r| (loads[r], r)).unwrap();
        bins[r].push(j);
        loads[r] += counts[j].max(1);
    }
    for bin in &mut bins {
        bin.sort_unstable();
    }
    bins
}

/// Uniformly random column partition into `p` near-equal parts
/// (Figure 5's 10-random-partition study).
pub fn random_col_partition(n: usize, p: usize, rng: &mut Pcg64) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let ranges = row_ranges(n, p); // reuse the near-equal splitter
    let mut out: Vec<Vec<usize>> = ranges
        .into_iter()
        .map(|(a, b)| {
            let mut part = idx[a..b].to_vec();
            part.sort_unstable();
            part
        })
        .collect();
    // Keep deterministic rank order.
    out.shrink_to_fit();
    out
}

/// Imbalance factor of a partition: max bin nnz / mean bin nnz.
pub fn partition_imbalance(a: &Matrix, parts: &[Vec<usize>]) -> f64 {
    let counts = a.col_nnz_counts();
    let loads: Vec<usize> =
        parts.iter().map(|p| p.iter().map(|&j| counts[j]).sum::<usize>()).collect();
    let max = *loads.iter().max().unwrap_or(&0) as f64;
    let mean = loads.iter().sum::<usize>() as f64 / loads.len().max(1) as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    #[test]
    fn row_ranges_cover() {
        for (m, p) in [(10, 3), (7, 7), (100, 8), (5, 1)] {
            let r = row_ranges(m, p);
            assert_eq!(r.len(), p);
            assert_eq!(r[0].0, 0);
            assert_eq!(r[p - 1].1, m);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            let sizes: Vec<usize> = r.iter().map(|(a, b)| b - a).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    fn row_shards_preserve_at_r() {
        let d = datasets::tiny(3);
        let p = 4;
        let shards = row_shards(&d.a, p);
        let ranges = row_ranges(d.a.nrows(), p);
        let n = d.a.ncols();
        let mut whole = vec![0.0; n];
        d.a.at_r(&d.b, &mut whole);
        let mut sum = vec![0.0; n];
        for (shard, (r0, r1)) in shards.iter().zip(&ranges) {
            let mut part = vec![0.0; n];
            shard.at_r(&d.b[*r0..*r1], &mut part);
            for (s, x) in sum.iter_mut().zip(&part) {
                *s += x;
            }
        }
        for (a, b) in whole.iter().zip(&sum) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn cv_folds_cover_disjointly_and_depend_on_seed() {
        for (m, k) in [(10usize, 3usize), (120, 5), (7, 7), (9, 1)] {
            let folds = cv_folds(m, k, 42);
            assert_eq!(folds.len(), k);
            let mut all: Vec<usize> = folds.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..m).collect::<Vec<_>>(), "m={m} k={k}");
            let sizes: Vec<usize> = folds.iter().map(|f| f.len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "near-equal folds: {sizes:?}");
            for f in &folds {
                assert!(f.windows(2).all(|w| w[0] < w[1]), "folds are sorted");
            }
        }
        assert_eq!(cv_folds(50, 5, 7), cv_folds(50, 5, 7), "deterministic in seed");
        assert_ne!(cv_folds(50, 5, 7), cv_folds(50, 5, 8), "seed changes assignment");
    }

    #[test]
    fn balanced_partition_covers_all_columns() {
        let d = datasets::tiny(4);
        let parts = balanced_col_partition(&d.a, 8);
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..d.a.ncols()).collect::<Vec<_>>());
    }

    #[test]
    fn balanced_beats_random_on_skewed_data() {
        let d = datasets::sector_like(5);
        let balanced = balanced_col_partition(&d.a, 16);
        let mut rng = Pcg64::new(0);
        let random = random_col_partition(d.a.ncols(), 16, &mut rng);
        let ib = partition_imbalance(&d.a, &balanced);
        let ir = partition_imbalance(&d.a, &random);
        assert!(ib <= ir + 1e-9, "balanced {ib} vs random {ir}");
        assert!(ib < 1.05, "LPT should be near-perfect, got {ib}");
    }

    #[test]
    fn random_partition_is_partition() {
        let mut rng = Pcg64::new(1);
        let parts = random_col_partition(101, 4, &mut rng);
        assert_eq!(parts.len(), 4);
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..101).collect::<Vec<_>>());
    }

    #[test]
    fn random_partitions_differ_by_seed() {
        let mut r1 = Pcg64::new(1);
        let mut r2 = Pcg64::new(2);
        let p1 = random_col_partition(50, 2, &mut r1);
        let p2 = random_col_partition(50, 2, &mut r2);
        assert_ne!(p1, p2);
    }
}
