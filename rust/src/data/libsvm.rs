//! LIBSVM regression format parser.
//!
//! The paper's datasets come from the LIBSVM collection [7]. When the
//! real files are available (`<label> <idx>:<val> ...` per line,
//! 1-based feature indices), this loader produces the same [`Dataset`]
//! the synthetic registry does, so every experiment driver can run on
//! real data unmodified.

use super::datasets::Dataset;
use crate::linalg::{CscMatrix, Matrix};
use crate::error::{bail, Context, Result};
use std::io::BufRead;

/// Parse LIBSVM text from a reader. `n_hint` pre-sizes the feature
/// count; the actual count is `max(n_hint, max feature index)`.
pub fn parse<R: BufRead>(reader: R, name: &str, n_hint: usize) -> Result<Dataset> {
    let mut labels = Vec::new();
    // (row, col, val) triplets; converted to CSC at the end.
    let mut triplets: Vec<(usize, usize, f64)> = Vec::new();
    let mut max_col = 0usize;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("read error")?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f64 = parts
            .next()
            .with_context(|| format!("line {}: missing label", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let row = labels.len();
        labels.push(label);
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad token '{tok}'", lineno + 1))?;
            let idx: usize =
                idx.parse().with_context(|| format!("line {}: bad index", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: LIBSVM indices are 1-based, got 0", lineno + 1);
            }
            let val: f64 =
                val.parse().with_context(|| format!("line {}: bad value", lineno + 1))?;
            let col = idx - 1;
            max_col = max_col.max(col + 1);
            triplets.push((row, col, val));
        }
    }
    if labels.is_empty() {
        bail!("empty LIBSVM file");
    }

    let m = labels.len();
    let n = max_col.max(n_hint);
    let mut cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    for (r, c, v) in triplets {
        cols[c].push((r, v));
    }
    let mut a = Matrix::Sparse(CscMatrix::from_columns(m, cols));
    let col_norms = a.normalize_columns_with_norms();
    Ok(Dataset { name: name.to_string(), a, b: labels, true_support: None, col_norms })
}

/// Load from a file path.
pub fn load(path: &std::path::Path, name: &str) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    parse(std::io::BufReader::new(f), name, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let txt = "1.5 1:2.0 3:1.0\n-0.5 2:4.0\n# comment\n2.0 1:1.0 2:1.0 3:1.0\n";
        let ds = parse(std::io::Cursor::new(txt), "t", 0).unwrap();
        assert_eq!(ds.a.nrows(), 3);
        assert_eq!(ds.a.ncols(), 3);
        assert_eq!(ds.b, vec![1.5, -0.5, 2.0]);
        // Columns are normalized.
        for j in 0..3 {
            assert!((ds.a.col_norm(j) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn respects_n_hint() {
        let ds = parse(std::io::Cursor::new("1.0 1:1.0\n"), "t", 10).unwrap();
        assert_eq!(ds.a.ncols(), 10);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse(std::io::Cursor::new("1.0 0:1.0\n"), "t", 0).is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(parse(std::io::Cursor::new(""), "t", 0).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse(std::io::Cursor::new("abc 1:1.0\n"), "t", 0).is_err());
        assert!(parse(std::io::Cursor::new("1.0 x\n"), "t", 0).is_err());
    }
}
