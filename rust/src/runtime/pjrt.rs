//! PJRT runtime: compile HLO-text artifacts once, execute them with
//! device-resident operands from the Rust hot path.
//!
//! Interchange is HLO **text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that the crate's XLA (xla_extension 0.5.1)
//! rejects; the text parser reassigns ids and round-trips cleanly. The
//! AOT side lowers with `return_tuple=True`, so every result is a tuple
//! (unwrapped here with `to_tuple1`/`to_tuple2`).
//!
//! The whole module is gated behind the off-by-default `pjrt` cargo
//! feature: the `xla` crate (xla_extension bindings) is not available
//! in offline builds. Without the feature the same public types exist
//! as stubs whose constructors fail cleanly, so every call site — the
//! hybrid [`super::hybrid::CorrEngine`], `calars info`, the benches —
//! compiles unchanged and degrades to the native f64 kernels. Enabling
//! the feature requires adding the `xla` dependency to `rust/Cargo.toml`
//! (see DESIGN.md §7).

#[cfg(feature = "pjrt")]
mod imp {
    use crate::error::{anyhow, Context, Result};
    use crate::runtime::artifacts::{ArtifactManifest, KernelKey, KernelOp};
    use std::cell::RefCell;
    use std::collections::BTreeMap;
    use std::path::Path;
    use std::rc::Rc;

    /// The XLA runtime: PJRT CPU client + lazily compiled executables.
    ///
    /// Not `Send` (PJRT handles are `Rc`-shared): construct one per
    /// coordinator thread. The request path never touches Python.
    pub struct XlaRuntime {
        client: xla::PjRtClient,
        manifest: ArtifactManifest,
        cache: RefCell<BTreeMap<KernelKey, Rc<xla::PjRtLoadedExecutable>>>,
    }

    impl XlaRuntime {
        /// Load the manifest from `dir` and start a PJRT CPU client.
        pub fn load(dir: &Path) -> Result<Self> {
            let manifest = ArtifactManifest::load(dir)?;
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(XlaRuntime { client, manifest, cache: RefCell::new(BTreeMap::new()) })
        }

        /// The manifest in use.
        pub fn manifest(&self) -> &ArtifactManifest {
            &self.manifest
        }

        /// PJRT platform name (for logs).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch the cached) executable for a bucket.
        fn executable(&self, key: KernelKey) -> Result<Rc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = self.cache.borrow().get(&key) {
                return Ok(exe.clone());
            }
            let path = self
                .manifest
                .path(&key)
                .ok_or_else(|| anyhow!("no artifact for {:?} {}x{}", key.op, key.m, key.n))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = Rc::new(self.client.compile(&comp).context("XLA compile")?);
            self.cache.borrow_mut().insert(key, exe.clone());
            Ok(exe)
        }

        /// Prepare a correlation kernel session for an `m × n` dense matrix
        /// given in row-major f64: pads to the nearest bucket, uploads A to
        /// the device **once**, returns a session executing `c = Aᵀr`.
        pub fn prepare_corr(
            &self,
            m: usize,
            n: usize,
            a_row_major: &[f64],
        ) -> Result<CorrSession<'_>> {
            assert_eq!(a_row_major.len(), m * n);
            let bucket = self
                .manifest
                .bucket_for(KernelOp::Corr, m, n)
                .ok_or_else(|| anyhow!("no corr bucket fits {m}x{n}"))?;
            let exe = self.executable(bucket)?;
            // Zero-pad into the bucket (padding rows/cols contribute 0 to Aᵀr).
            let mut a32 = vec![0.0f32; bucket.m * bucket.n];
            for i in 0..m {
                let src = &a_row_major[i * n..(i + 1) * n];
                let dst = &mut a32[i * bucket.n..i * bucket.n + n];
                for (d, s) in dst.iter_mut().zip(src) {
                    *d = *s as f32;
                }
            }
            let a_buf = self
                .client
                .buffer_from_host_buffer::<f32>(&a32, &[bucket.m, bucket.n], None)
                .context("upload A")?;
            Ok(CorrSession { rt: self, exe, a_buf, bucket, m, n })
        }

        /// Prepare the fused gstep kernel (Aᵀu + γ candidates) for an
        /// `m × n` dense matrix: pads/uploads A once, returns a session.
        pub fn prepare_gstep(
            &self,
            m: usize,
            n: usize,
            a_row_major: &[f64],
        ) -> Result<GstepSession<'_>> {
            assert_eq!(a_row_major.len(), m * n);
            let bucket = self
                .manifest
                .bucket_for(KernelOp::GammaStep, m, n)
                .ok_or_else(|| anyhow!("no gstep bucket fits {m}x{n}"))?;
            let exe = self.executable(bucket)?;
            let mut a32 = vec![0.0f32; bucket.m * bucket.n];
            for i in 0..m {
                let src = &a_row_major[i * n..(i + 1) * n];
                let dst = &mut a32[i * bucket.n..i * bucket.n + n];
                for (d, s) in dst.iter_mut().zip(src) {
                    *d = *s as f32;
                }
            }
            let a_buf = self
                .client
                .buffer_from_host_buffer::<f32>(&a32, &[bucket.m, bucket.n], None)
                .context("upload A")?;
            Ok(GstepSession { rt: self, exe, a_buf, bucket, m, n })
        }
    }

    /// A prepared fused gstep kernel (Algorithm 2 steps 11-12 in one XLA
    /// program): `a = Aᵀu` and the per-column γ candidates, masked.
    pub struct GstepSession<'rt> {
        rt: &'rt XlaRuntime,
        exe: Rc<xla::PjRtLoadedExecutable>,
        a_buf: xla::PjRtBuffer,
        bucket: KernelKey,
        m: usize,
        n: usize,
    }

    impl GstepSession<'_> {
        /// Problem shape (unpadded).
        pub fn shape(&self) -> (usize, usize) {
            (self.m, self.n)
        }

        /// Execute: returns `(a, gammas)`, each length n. `mask[j] = true`
        /// for selected columns (padded columns are masked internally).
        pub fn gstep(
            &self,
            u: &[f64],
            c: &[f64],
            mask: &[bool],
            ck: f64,
            h: f64,
        ) -> Result<(Vec<f64>, Vec<f64>)> {
            assert_eq!(u.len(), self.m);
            assert_eq!(c.len(), self.n);
            assert_eq!(mask.len(), self.n);
            let up = |v: &[f64], len: usize, pad: f32| -> Vec<f32> {
                let mut out = vec![pad; len];
                for (d, s) in out.iter_mut().zip(v) {
                    *d = *s as f32;
                }
                out
            };
            let u32v = up(u, self.bucket.m, 0.0);
            let c32 = up(c, self.bucket.n, 0.0);
            let mut m32 = vec![1.0f32; self.bucket.n]; // pad columns masked
            for (d, &s) in m32.iter_mut().zip(mask) {
                *d = if s { 1.0 } else { 0.0 };
            }
            let cl = &self.rt.client;
            let u_buf = cl.buffer_from_host_buffer::<f32>(&u32v, &[self.bucket.m], None)?;
            let c_buf = cl.buffer_from_host_buffer::<f32>(&c32, &[self.bucket.n], None)?;
            let m_buf = cl.buffer_from_host_buffer::<f32>(&m32, &[self.bucket.n], None)?;
            let ck_buf = cl.buffer_from_host_buffer::<f32>(&[ck as f32], &[], None)?;
            let h_buf = cl.buffer_from_host_buffer::<f32>(&[h as f32], &[], None)?;
            let result = self
                .exe
                .execute_b(&[&self.a_buf, &u_buf, &c_buf, &m_buf, &ck_buf, &h_buf])
                .context("execute gstep")?;
            let lit = result[0][0].to_literal_sync()?;
            let (av, gam) = lit.to_tuple2().context("unwrap tuple2")?;
            let av32: Vec<f32> = av.to_vec()?;
            let gam32: Vec<f32> = gam.to_vec()?;
            Ok((
                av32[..self.n].iter().map(|&v| v as f64).collect(),
                gam32[..self.n].iter().map(|&v| v as f64).collect(),
            ))
        }
    }

    /// A prepared `c = Aᵀr` kernel: A is device-resident; each call uploads
    /// only `r` (the per-iteration hot path of Algorithm 2 steps 2/11).
    pub struct CorrSession<'rt> {
        rt: &'rt XlaRuntime,
        exe: Rc<xla::PjRtLoadedExecutable>,
        a_buf: xla::PjRtBuffer,
        bucket: KernelKey,
        m: usize,
        n: usize,
    }

    impl CorrSession<'_> {
        /// Problem shape (unpadded).
        pub fn shape(&self) -> (usize, usize) {
            (self.m, self.n)
        }

        /// Bucket shape actually executed.
        pub fn bucket(&self) -> (usize, usize) {
            (self.bucket.m, self.bucket.n)
        }

        /// Execute `c = Aᵀ r` for a length-`m` f64 vector; returns length-`n`
        /// f64 (computed in f32 — see DESIGN.md §7 for the tolerance story).
        pub fn corr(&self, r: &[f64]) -> Result<Vec<f64>> {
            assert_eq!(r.len(), self.m);
            let mut r32 = vec![0.0f32; self.bucket.m];
            for (d, s) in r32.iter_mut().zip(r) {
                *d = *s as f32;
            }
            let r_buf = self
                .rt
                .client
                .buffer_from_host_buffer::<f32>(&r32, &[self.bucket.m], None)
                .context("upload r")?;
            let result = self.exe.execute_b(&[&self.a_buf, &r_buf]).context("execute corr")?;
            let lit = result[0][0].to_literal_sync().context("fetch result")?;
            let lit = lit.to_tuple1().context("unwrap tuple")?;
            let out32: Vec<f32> = lit.to_vec().context("to_vec")?;
            Ok(out32[..self.n].iter().map(|&v| v as f64).collect())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use crate::error::{bail, Result};
    use crate::runtime::artifacts::ArtifactManifest;
    use std::path::Path;

    const DISABLED: &str = "calars was built without the `pjrt` cargo feature; \
         XLA artifacts cannot be executed (rebuild with `--features pjrt` and \
         the `xla` dependency — see DESIGN.md §7). Native f64 kernels remain \
         fully functional";

    /// Stub runtime for builds without the `pjrt` feature. [`Self::load`]
    /// always fails, so call sites take their native fallback path; the
    /// remaining methods exist only to keep those call sites type-checking
    /// and are unreachable in practice.
    pub struct XlaRuntime {
        manifest: ArtifactManifest,
    }

    impl XlaRuntime {
        /// Always fails: the PJRT client is compiled out.
        pub fn load(_dir: &Path) -> Result<Self> {
            bail!("{DISABLED}")
        }

        pub fn manifest(&self) -> &ArtifactManifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "disabled".to_string()
        }

        pub fn prepare_corr(
            &self,
            _m: usize,
            _n: usize,
            _a_row_major: &[f64],
        ) -> Result<CorrSession<'_>> {
            bail!("{DISABLED}")
        }

        pub fn prepare_gstep(
            &self,
            _m: usize,
            _n: usize,
            _a_row_major: &[f64],
        ) -> Result<GstepSession<'_>> {
            bail!("{DISABLED}")
        }
    }

    /// Stub session (never constructed; see [`XlaRuntime`]).
    pub struct CorrSession<'rt> {
        _rt: &'rt XlaRuntime,
    }

    impl CorrSession<'_> {
        pub fn shape(&self) -> (usize, usize) {
            (0, 0)
        }

        pub fn bucket(&self) -> (usize, usize) {
            (0, 0)
        }

        pub fn corr(&self, _r: &[f64]) -> Result<Vec<f64>> {
            bail!("{DISABLED}")
        }
    }

    /// Stub session (never constructed; see [`XlaRuntime`]).
    pub struct GstepSession<'rt> {
        _rt: &'rt XlaRuntime,
    }

    impl GstepSession<'_> {
        pub fn shape(&self) -> (usize, usize) {
            (0, 0)
        }

        pub fn gstep(
            &self,
            _u: &[f64],
            _c: &[f64],
            _mask: &[bool],
            _ck: f64,
            _h: f64,
        ) -> Result<(Vec<f64>, Vec<f64>)> {
            bail!("{DISABLED}")
        }
    }
}

#[cfg(feature = "pjrt")]
pub use imp::{CorrSession, GstepSession, XlaRuntime};
#[cfg(not(feature = "pjrt"))]
pub use stub::{CorrSession, GstepSession, XlaRuntime};
