//! Hybrid kernel dispatch: XLA artifact if one fits, native Rust
//! otherwise.
//!
//! The coordinator asks for a [`CorrEngine`] per matrix; dense matrices
//! whose shape fits a compiled bucket get the AOT Pallas/XLA path
//! (f32), everything else (sparse storage, oversize shapes, missing
//! artifacts) gets the native f64 kernels. Parity between the two paths
//! is enforced by `tests/runtime_parity.rs`.

use super::pjrt::{CorrSession, XlaRuntime};
use crate::linalg::Matrix;
use crate::error::Result;

/// Which backend a [`CorrEngine`] ended up on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Native,
    Xla,
}

/// A per-matrix correlation engine: computes `c = Aᵀr` repeatedly.
pub enum CorrEngine<'rt> {
    /// Native f64 kernels on the matrix itself.
    Native { a: Matrix },
    /// Device-resident XLA session (dense f32).
    Xla { session: CorrSession<'rt>, n: usize },
}

impl<'rt> CorrEngine<'rt> {
    /// Build an engine for `a`, preferring the XLA path when
    /// `runtime` is available, the matrix is dense, and a bucket fits.
    pub fn new(a: &Matrix, runtime: Option<&'rt XlaRuntime>) -> Self {
        if let (Some(rt), Matrix::Dense(d)) = (runtime, a) {
            if let Ok(session) = rt.prepare_corr(d.nrows(), d.ncols(), d.data()) {
                return CorrEngine::Xla { session, n: d.ncols() };
            }
        }
        CorrEngine::Native { a: a.clone() }
    }

    /// Force the native path (used by parity tests and benchmarks).
    pub fn native(a: &Matrix) -> Self {
        CorrEngine::Native { a: a.clone() }
    }

    pub fn backend(&self) -> Backend {
        match self {
            CorrEngine::Native { .. } => Backend::Native,
            CorrEngine::Xla { .. } => Backend::Xla,
        }
    }

    /// `c = Aᵀ r`.
    pub fn corr(&self, r: &[f64]) -> Result<Vec<f64>> {
        match self {
            CorrEngine::Native { a } => {
                let mut c = vec![0.0; a.ncols()];
                a.at_r(r, &mut c);
                Ok(c)
            }
            CorrEngine::Xla { session, .. } => session.corr(r),
        }
    }

    /// Output dimension.
    pub fn ncols(&self) -> usize {
        match self {
            CorrEngine::Native { a } => a.ncols(),
            CorrEngine::Xla { n, .. } => *n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    #[test]
    fn native_engine_matches_matrix_kernel() {
        let d = datasets::tiny(1);
        let eng = CorrEngine::native(&d.a);
        assert_eq!(eng.backend(), Backend::Native);
        let c1 = eng.corr(&d.b).unwrap();
        let mut c2 = vec![0.0; d.a.ncols()];
        d.a.at_r(&d.b, &mut c2);
        assert_eq!(c1, c2);
    }

    #[test]
    fn falls_back_without_runtime() {
        let d = datasets::tiny_dense(2);
        let eng = CorrEngine::new(&d.a, None);
        assert_eq!(eng.backend(), Backend::Native);
    }

    #[test]
    fn sparse_always_native() {
        let d = datasets::tiny(3);
        // Even with a runtime the sparse matrix goes native; passing None
        // here since the runtime needs artifacts on disk.
        let eng = CorrEngine::new(&d.a, None);
        assert_eq!(eng.backend(), Backend::Native);
        assert_eq!(eng.ncols(), d.a.ncols());
    }
}
