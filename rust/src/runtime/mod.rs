//! Runtime bridge: load AOT-compiled HLO artifacts via the PJRT CPU
//! client and execute them from the Rust request path.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`);
//! after that the Rust binary is self-contained: it reads
//! `artifacts/manifest.tsv`, compiles each HLO text module once with
//! the PJRT CPU client, and dispatches kernel calls by padding operands
//! to the nearest compiled bucket shape.
//!
//! The PJRT client itself (the `xla` crate) is gated behind the
//! off-by-default `pjrt` cargo feature so offline builds need no
//! external dependencies; without it [`XlaRuntime::load`] fails cleanly
//! and every consumer falls back to the native f64 kernels.

pub mod artifacts;
pub mod hybrid;
pub mod pjrt;

pub use artifacts::{ArtifactManifest, KernelKey, KernelOp};
pub use hybrid::CorrEngine;
pub use pjrt::XlaRuntime;

/// Default artifacts directory, relative to the repo root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("CALARS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
