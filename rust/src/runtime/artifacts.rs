//! Artifact manifest: which AOT-compiled kernels exist, at which
//! (bucket) shapes, and where their HLO text lives.
//!
//! `python/compile/aot.py` writes `manifest.tsv` with one line per
//! artifact: `op \t m \t n \t filename`. (There is also a
//! `manifest.json` for humans; the TSV exists because the offline crate
//! set has no JSON parser and hand-rolling one for a fixed schema is
//! worse than a fixed-column format.)

use crate::error::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Operations the AOT pipeline compiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum KernelOp {
    /// `corr(A[m,n], r[m]) -> c[n]` — the Aᵀr hot spot (Pallas kernel).
    Corr,
    /// `gstep(A, u, c, ck, h) -> (a[n], gamma[n])` — fused direction
    /// correlation + γ-candidate computation (Alg 2 steps 11-12).
    GammaStep,
}

impl KernelOp {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "corr" => Ok(KernelOp::Corr),
            "gstep" => Ok(KernelOp::GammaStep),
            other => bail!("unknown kernel op '{other}'"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelOp::Corr => "corr",
            KernelOp::GammaStep => "gstep",
        }
    }
}

/// A compiled artifact's identity: op + bucket shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct KernelKey {
    pub op: KernelOp,
    pub m: usize,
    pub n: usize,
}

/// Parsed manifest: key → HLO text path.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    entries: BTreeMap<KernelKey, PathBuf>,
    dir: PathBuf,
}

impl ArtifactManifest {
    /// Load `manifest.tsv` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut f = line.split('\t');
            let op = KernelOp::parse(f.next().context("missing op")?)?;
            let m: usize = f
                .next()
                .with_context(|| format!("line {}: missing m", lineno + 1))?
                .parse()
                .context("bad m")?;
            let n: usize = f
                .next()
                .with_context(|| format!("line {}: missing n", lineno + 1))?
                .parse()
                .context("bad n")?;
            let file = f.next().with_context(|| format!("line {}: missing file", lineno + 1))?;
            entries.insert(KernelKey { op, m, n }, dir.join(file));
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(ArtifactManifest { entries, dir: dir.to_path_buf() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn keys(&self) -> impl Iterator<Item = &KernelKey> {
        self.entries.keys()
    }

    pub fn path(&self, key: &KernelKey) -> Option<&Path> {
        self.entries.get(key).map(|p| p.as_path())
    }

    /// Smallest bucket of `op` that fits an (m, n) problem: minimizes
    /// padded area among buckets with `bucket.m ≥ m` and `bucket.n ≥ n`.
    pub fn bucket_for(&self, op: KernelOp, m: usize, n: usize) -> Option<KernelKey> {
        self.entries
            .keys()
            .filter(|k| k.op == op && k.m >= m && k.n >= n)
            .min_by_key(|k| k.m * k.n)
            .copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> ArtifactManifest {
        let text = "corr\t128\t64\tcorr_128x64.hlo.txt\n\
                    corr\t512\t256\tcorr_512x256.hlo.txt\n\
                    gstep\t128\t64\tgstep_128x64.hlo.txt\n";
        ArtifactManifest::parse(text, Path::new("/tmp/arts")).unwrap()
    }

    #[test]
    fn parses_entries() {
        let m = manifest();
        assert_eq!(m.len(), 3);
        let key = KernelKey { op: KernelOp::Corr, m: 128, n: 64 };
        assert_eq!(
            m.path(&key).unwrap(),
            Path::new("/tmp/arts/corr_128x64.hlo.txt")
        );
    }

    #[test]
    fn bucket_selection_smallest_fit() {
        let m = manifest();
        let b = m.bucket_for(KernelOp::Corr, 100, 60).unwrap();
        assert_eq!((b.m, b.n), (128, 64));
        let b2 = m.bucket_for(KernelOp::Corr, 200, 60).unwrap();
        assert_eq!((b2.m, b2.n), (512, 256));
        assert!(m.bucket_for(KernelOp::Corr, 1000, 10).is_none());
        assert!(m.bucket_for(KernelOp::GammaStep, 512, 10).is_none());
    }

    #[test]
    fn exact_fit_is_exact() {
        let m = manifest();
        let b = m.bucket_for(KernelOp::Corr, 128, 64).unwrap();
        assert_eq!((b.m, b.n), (128, 64));
    }

    #[test]
    fn rejects_empty_and_garbage() {
        assert!(ArtifactManifest::parse("", Path::new("/x")).is_err());
        assert!(ArtifactManifest::parse("bogus\t1\t2\tf", Path::new("/x")).is_err());
        assert!(ArtifactManifest::parse("corr\tx\t2\tf", Path::new("/x")).is_err());
    }

    #[test]
    fn comments_skipped() {
        let m = ArtifactManifest::parse(
            "# comment\ncorr\t8\t8\tf.hlo.txt\n",
            Path::new("/x"),
        )
        .unwrap();
        assert_eq!(m.len(), 1);
    }
}
