//! Minimal property-based testing harness.
//!
//! The offline environment carries no `proptest`/`quickcheck`, so the
//! crate ships its own: a deterministic-seeded case generator with
//! failure reporting (the seed + case index that failed, so a failure
//! reproduces exactly). Shrinking is approximated by retrying the
//! failing property on "smaller" variants supplied by the caller's
//! generator (sizes are drawn small-biased).

use crate::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xCA1A25 }
    }
}

/// Run `prop` over `cases` generated inputs. `gen` receives an RNG and a
/// size hint that grows with the case index (small cases first — cheap
/// shrinking by construction). Panics with the reproducing seed on the
/// first failure.
pub fn check<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Pcg64, usize) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        // Size ramps from 1 to ~32 over the run.
        let size = 1 + (case * 32) / cfg.cases.max(1);
        let mut rng = Pcg64::new(cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{} (seed {:#x}, size {size}):\n  {msg}\n  input: {input:?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Convenience: generate a random vector of length `len` with entries
/// from `f`.
pub fn vec_of(rng: &mut Pcg64, len: usize, mut f: impl FnMut(&mut Pcg64) -> f64) -> Vec<f64> {
    (0..len).map(|_| f(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            Config { cases: 10, seed: 1 },
            |rng, size| vec_of(rng, size, |r| r.normal()),
            |v| {
                count += 1;
                if v.iter().all(|x| x.is_finite()) {
                    Ok(())
                } else {
                    Err("non-finite".into())
                }
            },
        );
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_context() {
        check(
            Config { cases: 20, seed: 2 },
            |rng, _| rng.below(100),
            |&x| if x < 1000 { Err(format!("x={x}")) } else { Ok(()) },
        );
    }

    #[test]
    fn deterministic_inputs_per_seed() {
        let mut first: Vec<usize> = Vec::new();
        check(
            Config { cases: 5, seed: 3 },
            |rng, _| rng.below(1_000_000),
            |&x| {
                first.push(x);
                Ok(())
            },
        );
        let mut second: Vec<usize> = Vec::new();
        check(
            Config { cases: 5, seed: 3 },
            |rng, _| rng.below(1_000_000),
            |&x| {
                second.push(x);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }

    #[test]
    fn sizes_ramp_up() {
        let mut sizes = Vec::new();
        check(
            Config { cases: 32, seed: 4 },
            |_, size| size,
            |&s| {
                sizes.push(s);
                Ok(())
            },
        );
        assert!(sizes[0] <= sizes[sizes.len() - 1]);
        assert!(*sizes.last().unwrap() >= 16);
    }
}
