//! The α-β-γ running-time model (paper §7.1).
//!
//! `T = γ·F + α·L + β·W` where F = flops, L = messages, W = words.
//! Defaults are calibrated to commodity-cluster ratios (InfiniBand-ish
//! latency, 10GbE-ish bandwidth, ~1 Gflop/s/core sustained f64), giving
//! α/γ ≈ 10³ and β/γ ≈ 4 — the "communication is much more expensive
//! than a flop" regime the paper targets.

/// Hardware parameters for the cost model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HwParams {
    /// Seconds per message (latency).
    pub alpha: f64,
    /// Seconds per 8-byte word (inverse bandwidth).
    pub beta: f64,
    /// Seconds per floating-point operation.
    pub gamma: f64,
}

impl Default for HwParams {
    fn default() -> Self {
        HwParams {
            alpha: 1.0e-6, // 1 µs MPI latency
            beta: 4.0e-9,  // 8 B / (2 GB/s) per word
            gamma: 1.0e-9, // 1 Gflop/s sustained per core
        }
    }
}

impl HwParams {
    /// A "slow network" variant (WAN-ish): stresses the
    /// communication-avoiding advantage (used by ablation benches).
    pub fn slow_network() -> Self {
        HwParams { alpha: 1.0e-4, beta: 8.0e-8, gamma: 1.0e-9 }
    }

    /// A "fast network" variant (NVLink-ish): shrinks the advantage.
    pub fn fast_network() -> Self {
        HwParams { alpha: 1.0e-7, beta: 5.0e-10, gamma: 1.0e-9 }
    }
}

/// Aggregate counters (F, W, L in the paper's notation).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommCounters {
    /// Arithmetic operations F.
    pub flops: u64,
    /// Words moved W.
    pub words: u64,
    /// Messages sent L.
    pub msgs: u64,
}

impl CommCounters {
    pub fn add(&mut self, other: CommCounters) {
        self.flops += other.flops;
        self.words += other.words;
        self.msgs += other.msgs;
    }

    /// Modeled time under `hw`: γF + αL + βW.
    pub fn model_time(&self, hw: &HwParams) -> f64 {
        hw.gamma * self.flops as f64 + hw.alpha * self.msgs as f64 + hw.beta * self.words as f64
    }
}

/// Cost model bound to fixed hardware parameters.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    hw: HwParams,
}

impl CostModel {
    pub fn new(hw: HwParams) -> Self {
        CostModel { hw }
    }

    pub fn hw(&self) -> HwParams {
        self.hw
    }

    /// Time for one point-to-point message of `words` words.
    pub fn msg_time(&self, words: usize) -> f64 {
        self.hw.alpha + self.hw.beta * words as f64
    }

    /// Critical-path time of a binary-tree collective (reduce or bcast)
    /// over `p` ranks moving `words` words per level: `log₂p · (α + βW)`.
    pub fn collective_time(&self, p: usize, words: usize) -> f64 {
        let levels = (p.max(1)).trailing_zeros() as f64;
        levels * self.msg_time(words)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_time_linear() {
        let hw = HwParams { alpha: 1.0, beta: 0.1, gamma: 0.01 };
        let c = CommCounters { flops: 100, words: 10, msgs: 2 };
        assert!((c.model_time(&hw) - (0.01 * 100.0 + 1.0 * 2.0 + 0.1 * 10.0)).abs() < 1e-12);
    }

    #[test]
    fn counters_add() {
        let mut a = CommCounters { flops: 1, words: 2, msgs: 3 };
        a.add(CommCounters { flops: 10, words: 20, msgs: 30 });
        assert_eq!(a, CommCounters { flops: 11, words: 22, msgs: 33 });
    }

    #[test]
    fn collective_scales_with_log_p() {
        let m = CostModel::new(HwParams::default());
        let t8 = m.collective_time(8, 100);
        let t2 = m.collective_time(2, 100);
        assert!((t8 / t2 - 3.0).abs() < 1e-9);
        assert_eq!(m.collective_time(1, 100), 0.0);
    }

    #[test]
    fn latency_dominates_small_messages() {
        let hw = HwParams::default();
        let m = CostModel::new(hw);
        // 1-word message ≈ α
        assert!((m.msg_time(1) - hw.alpha) / hw.alpha < 0.01);
    }
}
