//! Phase-level breakdown tracing (regenerates Figures 7–8).
//!
//! Every superstep / collective / master computation is attributed to a
//! [`Phase`]; the tracer accumulates simulated time, flops, words and
//! messages per phase. The figure drivers then group phases into the
//! paper's breakdown categories: matrix products, step-size γ,
//! communication, wait, other.

use super::cost::CommCounters;

/// Algorithm phases, labeled after the steps of Algorithms 1–4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Initialization (Alg 2 step 1).
    Init,
    /// Correlation products `Aᵀr` / `Aᵀu` (steps 2, 11).
    Corr,
    /// Top-b selection / argmin (steps 3, 13–14).
    Select,
    /// Gram block products (steps 4, 20).
    Gram,
    /// Cholesky factor/extend (steps 5, 21–23).
    Cholesky,
    /// Master triangular solves (steps 7–8).
    Solve,
    /// Direction application `A_I w` (step 10).
    DirApply,
    /// Step-size γ computation (step 12 / Procedure 1).
    GammaStep,
    /// Response / correlation updates (steps 17–19).
    Update,
    /// Broadcasts (steps 9, 16 / Alg 3 step 12).
    Bcast,
    /// Reductions (steps 2, 4, 11, 20).
    Reduce,
    /// Tournament-tree point-to-point exchange (Alg 3 step 9).
    TreeExchange,
    /// Modeled wait for serial tournament levels (§10.2).
    Wait,
    /// Anything else.
    Other,
}

/// The paper's Figure 7/8 breakdown categories.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Category {
    MatProducts,
    StepSize,
    Communication,
    Wait,
    Other,
}

impl Phase {
    /// Map a phase to its breakdown category.
    pub fn category(self) -> Category {
        match self {
            Phase::Corr | Phase::Gram | Phase::DirApply => Category::MatProducts,
            Phase::GammaStep => Category::StepSize,
            Phase::Bcast | Phase::Reduce | Phase::TreeExchange => Category::Communication,
            Phase::Wait => Category::Wait,
            _ => Category::Other,
        }
    }

    /// Short stable label, shared by the simulation reports and the
    /// real-hardware span names in [`crate::obs`] so that simulated and
    /// measured traces line up phase-for-phase.
    pub fn label(self) -> &'static str {
        match self {
            Phase::Init => "Init",
            Phase::Corr => "Corr",
            Phase::Select => "Select",
            Phase::Gram => "Gram",
            Phase::Cholesky => "Cholesky",
            Phase::Solve => "Solve",
            Phase::DirApply => "DirApply",
            Phase::GammaStep => "GammaStep",
            Phase::Update => "Update",
            Phase::Bcast => "Bcast",
            Phase::Reduce => "Reduce",
            Phase::TreeExchange => "TreeExchange",
            Phase::Wait => "Wait",
            Phase::Other => "Other",
        }
    }

    /// All phases (for iteration/reporting).
    pub const ALL: [Phase; 14] = [
        Phase::Init,
        Phase::Corr,
        Phase::Select,
        Phase::Gram,
        Phase::Cholesky,
        Phase::Solve,
        Phase::DirApply,
        Phase::GammaStep,
        Phase::Update,
        Phase::Bcast,
        Phase::Reduce,
        Phase::TreeExchange,
        Phase::Wait,
        Phase::Other,
    ];
}

/// Accumulated statistics for one phase.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseStats {
    /// Simulated seconds attributed to the phase.
    pub time: f64,
    pub flops: u64,
    pub words: u64,
    pub msgs: u64,
}

/// Per-phase accumulator.
#[derive(Clone, Debug, Default)]
pub struct Tracer {
    stats: [PhaseStats; Phase::ALL.len()],
}

impl Tracer {
    pub fn new() -> Self {
        Tracer::default()
    }

    #[inline]
    fn idx(phase: Phase) -> usize {
        // audit: allow(PANIC-REACH) -- Phase::ALL enumerates every variant (pinned by the phase-coverage test), so position() is always Some
        Phase::ALL.iter().position(|&p| p == phase).unwrap()
    }

    pub fn add_time(&mut self, phase: Phase, dt: f64) {
        self.stats[Self::idx(phase)].time += dt;
    }

    pub fn add_flops(&mut self, phase: Phase, flops: u64) {
        self.stats[Self::idx(phase)].flops += flops;
    }

    pub fn add_comm(&mut self, phase: Phase, dt: f64, words: u64, msgs: u64) {
        let s = &mut self.stats[Self::idx(phase)];
        s.time += dt;
        s.words += words;
        s.msgs += msgs;
    }

    pub fn add_words_only(&mut self, phase: Phase, words: u64) {
        self.stats[Self::idx(phase)].words += words;
    }

    pub fn get(&self, phase: Phase) -> PhaseStats {
        self.stats[Self::idx(phase)]
    }

    /// Totals across phases.
    pub fn totals(&self) -> CommCounters {
        let mut c = CommCounters::default();
        for s in &self.stats {
            c.flops += s.flops;
            c.words += s.words;
            c.msgs += s.msgs;
        }
        c
    }

    /// Total simulated time across phases.
    pub fn total_time(&self) -> f64 {
        self.stats.iter().map(|s| s.time).sum()
    }

    /// Aggregate by Figure 7/8 category: returns
    /// (mat_products, step_size, communication, wait, other) seconds.
    pub fn by_category(&self) -> [f64; 5] {
        let mut out = [0.0; 5];
        for (i, phase) in Phase::ALL.iter().enumerate() {
            let slot = match phase.category() {
                Category::MatProducts => 0,
                Category::StepSize => 1,
                Category::Communication => 2,
                Category::Wait => 3,
                Category::Other => 4,
            };
            out[slot] += self.stats[i].time;
        }
        out
    }

    /// Zero all time components, keeping counters (used when absorbing
    /// off-critical-path work into an aggregate).
    pub fn zero_times(&mut self) {
        for s in self.stats.iter_mut() {
            s.time = 0.0;
        }
    }

    /// Element-wise critical path of several tracers: per-phase maximum
    /// time and flops (the slowest rank defines the superstep), summed
    /// words/msgs (traffic volume).
    pub fn critical_path(tracers: &[Tracer]) -> Tracer {
        let mut out = Tracer::new();
        for t in tracers {
            for (o, s) in out.stats.iter_mut().zip(&t.stats) {
                o.time = o.time.max(s.time);
                o.flops = o.flops.max(s.flops);
                o.words += s.words;
                o.msgs += s.msgs;
            }
        }
        out
    }

    /// Merge another tracer into this one.
    pub fn merge(&mut self, other: &Tracer) {
        for (a, b) in self.stats.iter_mut().zip(&other.stats) {
            a.time += b.time;
            a.flops += b.flops;
            a.words += b.words;
            a.msgs += b.msgs;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_per_phase() {
        let mut t = Tracer::new();
        t.add_time(Phase::Corr, 0.5);
        t.add_flops(Phase::Corr, 42);
        t.add_comm(Phase::Reduce, 0.1, 10, 2);
        assert_eq!(t.get(Phase::Corr).flops, 42);
        assert!((t.get(Phase::Corr).time - 0.5).abs() < 1e-15);
        assert_eq!(t.get(Phase::Reduce).msgs, 2);
        let totals = t.totals();
        assert_eq!(totals.flops, 42);
        assert_eq!(totals.words, 10);
        assert!((t.total_time() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn category_mapping() {
        assert_eq!(Phase::Corr.category(), Category::MatProducts);
        assert_eq!(Phase::GammaStep.category(), Category::StepSize);
        assert_eq!(Phase::Reduce.category(), Category::Communication);
        assert_eq!(Phase::Wait.category(), Category::Wait);
        assert_eq!(Phase::Cholesky.category(), Category::Other);
    }

    #[test]
    fn by_category_sums() {
        let mut t = Tracer::new();
        t.add_time(Phase::Corr, 1.0);
        t.add_time(Phase::Gram, 2.0);
        t.add_time(Phase::GammaStep, 3.0);
        t.add_time(Phase::Wait, 4.0);
        let cats = t.by_category();
        assert!((cats[0] - 3.0).abs() < 1e-15);
        assert!((cats[1] - 3.0).abs() < 1e-15);
        assert!((cats[3] - 4.0).abs() < 1e-15);
    }

    #[test]
    fn critical_path_takes_max_time_sum_words() {
        let mut a = Tracer::new();
        let mut b = Tracer::new();
        a.add_time(Phase::Corr, 1.0);
        a.add_flops(Phase::Corr, 100);
        a.add_comm(Phase::Reduce, 0.0, 10, 1);
        b.add_time(Phase::Corr, 3.0);
        b.add_flops(Phase::Corr, 50);
        b.add_comm(Phase::Reduce, 0.0, 20, 2);
        let cp = Tracer::critical_path(&[a, b]);
        assert!((cp.get(Phase::Corr).time - 3.0).abs() < 1e-15);
        assert_eq!(cp.get(Phase::Corr).flops, 100);
        assert_eq!(cp.get(Phase::Reduce).words, 30);
        assert_eq!(cp.get(Phase::Reduce).msgs, 3);
    }

    #[test]
    fn zero_times_keeps_counters() {
        let mut t = Tracer::new();
        t.add_comm(Phase::Bcast, 5.0, 7, 2);
        t.zero_times();
        assert_eq!(t.get(Phase::Bcast).words, 7);
        assert_eq!(t.total_time(), 0.0);
    }

    #[test]
    fn labels_unique_and_cover_all() {
        let mut seen: Vec<&str> = Vec::new();
        for p in Phase::ALL {
            let l = p.label();
            assert!(!l.is_empty());
            assert!(!seen.contains(&l), "duplicate label {l}");
            seen.push(l);
        }
        assert_eq!(seen.len(), Phase::ALL.len());
    }

    #[test]
    fn merge_adds() {
        let mut a = Tracer::new();
        let mut b = Tracer::new();
        a.add_flops(Phase::Corr, 10);
        b.add_flops(Phase::Corr, 5);
        a.merge(&b);
        assert_eq!(a.get(Phase::Corr).flops, 15);
    }
}
