//! Simulated message-passing cluster with α-β-γ cost accounting.
//!
//! The paper evaluates on an MPI cluster; this environment has no
//! network, so the distributed runtime is *simulated*: `P` logical
//! ranks execute the same superstep program (sequentially, or in
//! parallel on the [`crate::par`] shared-memory pool under
//! [`ExecMode::Threaded`]), and every collective routes through a cost
//! accountant
//! that charges **α per message, β per word and γ per flop** — exactly
//! the model the paper's §7.1 analysis uses. Simulated time is
//!
//! ```text
//! T = Σ_supersteps max_rank(measured compute) + Σ_collectives (α·L + β·W)
//! ```
//!
//! so computation constants are *measured* (real wallclock of real
//! kernels on real shards) while communication is *modeled* (the only
//! part this hardware cannot produce). See `DESIGN.md` §3 for why this
//! preserves the paper's observable behaviour.

pub mod collectives;
pub mod cost;
pub mod topology;
pub mod tracer;

pub use cost::{CommCounters, CostModel, HwParams};
pub use tracer::{Phase, PhaseStats, Tracer};

use std::time::Instant;

/// Execution strategy for rank compute within a superstep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Ranks run one after another; per-rank wallclock is measured and the
    /// *maximum* is charged to the simulated clock (BSP critical path).
    Sequential,
    /// Ranks run as fork-join tasks on the [`crate::par`] pool — real
    /// shared-memory parallelism across ranks (sized by
    /// `CALARS_THREADS`), degrading to inline execution on a
    /// single-thread pool. Outputs are identical to `Sequential`; only
    /// the measured wallclock (and therefore the simulated clock)
    /// changes, exactly as the α-β-γ model intends: computation is
    /// measured, communication stays modeled.
    Threaded,
}

/// The simulated cluster: logical ranks + cost accounting + phase tracer.
pub struct SimCluster {
    p: usize,
    mode: ExecMode,
    cost: CostModel,
    /// Simulated elapsed seconds (critical path).
    clock: f64,
    tracer: Tracer,
}

impl SimCluster {
    /// `p` must be a power of two ≥ 1 (binary-tree collectives).
    pub fn new(p: usize, hw: HwParams, mode: ExecMode) -> Self {
        assert!(p >= 1 && p.is_power_of_two(), "P must be a power of two, got {p}");
        SimCluster { p, mode, cost: CostModel::new(hw), clock: 0.0, tracer: Tracer::new() }
    }

    /// Number of ranks.
    pub fn nranks(&self) -> usize {
        self.p
    }

    /// Execution strategy for rank compute.
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Tree depth `log₂ P`.
    pub fn levels(&self) -> u32 {
        self.p.trailing_zeros()
    }

    /// Simulated elapsed time in seconds.
    pub fn sim_time(&self) -> f64 {
        self.clock
    }

    /// Aggregated communication counters.
    pub fn counters(&self) -> CommCounters {
        self.tracer.totals()
    }

    /// Phase-level breakdown (Figures 7–8).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Reset clock/counters, keep topology.
    pub fn reset(&mut self) {
        self.clock = 0.0;
        self.tracer = Tracer::new();
    }

    /// Hardware parameters in use.
    pub fn hw(&self) -> HwParams {
        self.cost.hw()
    }

    /// Run `f(rank, &mut state[rank])` on every rank as one superstep,
    /// charging `max_rank(wallclock)` to the simulated clock under
    /// `phase`. Returns the per-rank outputs.
    pub fn superstep<R: Send, T: Send>(
        &mut self,
        phase: Phase,
        states: &mut [R],
        f: impl Fn(usize, &mut R) -> T + Sync,
    ) -> Vec<T> {
        assert_eq!(states.len(), self.p);
        // Wall-clock observability span for the whole superstep (all
        // ranks), opened on the driving thread where the ambient trace
        // is bound; the simulated clock below still charges only the
        // per-rank maximum.
        let _span = crate::obs::phase_span(phase);
        let (outs, max_dt) = match self.mode {
            ExecMode::Sequential => {
                let mut outs = Vec::with_capacity(self.p);
                let mut max_dt = 0.0f64;
                for (rank, st) in states.iter_mut().enumerate() {
                    let t0 = Instant::now();
                    outs.push(f(rank, st));
                    max_dt = max_dt.max(t0.elapsed().as_secs_f64());
                }
                (outs, max_dt)
            }
            ExecMode::Threaded => {
                // Ranks fork onto the persistent pool instead of raw
                // thread::scope: workers are reused across supersteps,
                // and rank count beyond the pool size queues instead of
                // oversubscribing the machine.
                let fref = &f;
                let tasks: Vec<_> = states
                    .iter_mut()
                    .enumerate()
                    .map(|(rank, st)| {
                        move || {
                            let t0 = Instant::now();
                            let out = fref(rank, st);
                            (out, t0.elapsed().as_secs_f64())
                        }
                    })
                    .collect();
                let pairs = crate::par::run_tasks(tasks);
                let max_dt = pairs.iter().map(|(_, d)| *d).fold(0.0f64, f64::max);
                (pairs.into_iter().map(|(o, _)| o).collect(), max_dt)
            }
        };
        self.clock += max_dt;
        self.tracer.add_time(phase, max_dt);
        outs
    }

    /// Master-only (rank 0) compute, measured and charged under `phase`.
    pub fn master<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let _span = crate::obs::phase_span(phase);
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        self.clock += dt;
        self.tracer.add_time(phase, dt);
        out
    }

    /// Charge `flops` floating-point operations to `phase` (bookkeeping
    /// for Table 1/2 verification; time comes from measurement, not γ).
    pub fn charge_flops(&mut self, phase: Phase, flops: u64) {
        self.tracer.add_flops(phase, flops);
    }

    /// Binary-tree reduction of per-rank vectors to the master:
    /// charges `log₂P` messages and `words·log₂P` words (the paper's
    /// convention for Table 1), advances the clock by the modeled comm
    /// time, and returns the combined (summed) vector.
    pub fn reduce_sum(&mut self, phase: Phase, contribs: Vec<Vec<f64>>) -> Vec<f64> {
        assert_eq!(contribs.len(), self.p);
        let words = contribs.first().map(|v| v.len()).unwrap_or(0);
        let out = collectives::tree_sum(contribs);
        self.charge_collective(phase, words);
        out
    }

    /// Broadcast `words` words from master to all ranks (cost only; data
    /// movement is the caller's business since memory is shared here).
    pub fn broadcast(&mut self, phase: Phase, words: usize) {
        self.charge_collective(phase, words);
    }

    /// Point-to-point sends at one tournament-tree level: each of the
    /// `pairs` sends `words_per_msg` words to its parent (T-bLARS Alg. 3
    /// step 9). One level = 1 message of `words_per_msg` on the critical
    /// path; counters record the per-level totals.
    pub fn tree_level_exchange(&mut self, phase: Phase, pairs: usize, words_per_msg: usize) {
        if pairs == 0 {
            return;
        }
        let dt = self.cost.msg_time(words_per_msg);
        self.clock += dt;
        self.tracer.add_comm(phase, dt, words_per_msg as u64, 1);
        // Off-critical-path traffic still counted as words (volume), not time.
        if pairs > 1 {
            self.tracer.add_words_only(phase, ((pairs - 1) * words_per_msg) as u64);
        }
    }

    /// Advance the simulated clock by an explicitly modeled wait
    /// (T-bLARS serial-tournament wait, §10.2).
    pub fn charge_wait(&mut self, dt: f64) {
        self.clock += dt;
        self.tracer.add_time(Phase::Wait, dt);
    }

    /// Absorb an externally measured tracer (e.g. an mLARS call's
    /// per-phase compute) into this cluster's clock and tracer. The
    /// tracer's total time lands on the critical path.
    pub fn absorb(&mut self, t: &Tracer) {
        self.clock += t.total_time();
        self.tracer.merge(t);
    }

    /// Absorb only the counters (flops/words/msgs) of a tracer without
    /// advancing the clock (volume accounting off the critical path).
    pub fn absorb_counters(&mut self, t: &Tracer) {
        let mut zeroed = t.clone();
        zeroed.zero_times();
        self.tracer.merge(&zeroed);
    }

    fn charge_collective(&mut self, phase: Phase, words: usize) {
        if self.p == 1 {
            return; // no communication on a single rank
        }
        let levels = self.levels() as u64;
        let dt = self.cost.collective_time(self.p, words);
        self.clock += dt;
        self.tracer.add_comm(phase, dt, words as u64 * levels, levels);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(p: usize) -> SimCluster {
        SimCluster::new(p, HwParams::default(), ExecMode::Sequential)
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two() {
        let _ = cluster(3);
    }

    #[test]
    fn superstep_runs_all_ranks() {
        let mut c = cluster(4);
        let mut states = vec![0u64; 4];
        let outs = c.superstep(Phase::Other, &mut states, |rank, s| {
            *s = rank as u64 + 1;
            rank
        });
        assert_eq!(outs, vec![0, 1, 2, 3]);
        assert_eq!(states, vec![1, 2, 3, 4]);
        assert!(c.sim_time() > 0.0);
    }

    #[test]
    fn threaded_matches_sequential() {
        let mut seq = SimCluster::new(4, HwParams::default(), ExecMode::Sequential);
        let mut thr = SimCluster::new(4, HwParams::default(), ExecMode::Threaded);
        let mut s1 = vec![0.0f64; 4];
        let mut s2 = vec![0.0f64; 4];
        let f = |rank: usize, s: &mut f64| {
            *s = (rank as f64 + 1.0).sqrt();
            *s
        };
        let o1 = seq.superstep(Phase::Other, &mut s1, f);
        let o2 = thr.superstep(Phase::Other, &mut s2, f);
        assert_eq!(o1, o2);
        assert_eq!(s1, s2);
    }

    #[test]
    fn reduce_sum_combines() {
        let mut c = cluster(4);
        let contribs = vec![vec![1.0, 2.0]; 4];
        let out = c.reduce_sum(Phase::Corr, contribs);
        assert_eq!(out, vec![4.0, 8.0]);
        let t = c.counters();
        assert_eq!(t.msgs, 2); // log2(4)
        assert_eq!(t.words, 2 * 2); // words * log2(P)
    }

    #[test]
    fn single_rank_no_comm() {
        let mut c = cluster(1);
        let out = c.reduce_sum(Phase::Corr, vec![vec![3.0]]);
        assert_eq!(out, vec![3.0]);
        assert_eq!(c.counters().msgs, 0);
        assert_eq!(c.counters().words, 0);
        c.broadcast(Phase::Bcast, 100);
        assert_eq!(c.counters().msgs, 0);
    }

    #[test]
    fn broadcast_charges_model() {
        let mut c = cluster(8);
        c.broadcast(Phase::Bcast, 10);
        let t = c.counters();
        assert_eq!(t.msgs, 3);
        assert_eq!(t.words, 30);
        assert!(c.sim_time() > 0.0);
    }

    #[test]
    fn flop_charges_accumulate() {
        let mut c = cluster(2);
        c.charge_flops(Phase::Corr, 100);
        c.charge_flops(Phase::Corr, 50);
        assert_eq!(c.counters().flops, 150);
        assert_eq!(c.tracer().get(Phase::Corr).flops, 150);
    }

    #[test]
    fn absorb_advances_clock_and_counters() {
        let mut c = cluster(2);
        let mut t = Tracer::new();
        t.add_time(Phase::Corr, 0.25);
        t.add_flops(Phase::Corr, 99);
        c.absorb(&t);
        assert!((c.sim_time() - 0.25).abs() < 1e-12);
        assert_eq!(c.counters().flops, 99);
    }

    #[test]
    fn absorb_counters_leaves_clock() {
        let mut c = cluster(2);
        let mut t = Tracer::new();
        t.add_time(Phase::Corr, 0.25);
        t.add_flops(Phase::Corr, 99);
        c.absorb_counters(&t);
        assert_eq!(c.sim_time(), 0.0);
        assert_eq!(c.counters().flops, 99);
    }

    #[test]
    fn tree_level_exchange_counts_volume() {
        let mut c = cluster(8);
        c.tree_level_exchange(Phase::TreeExchange, 4, 100);
        let s = c.tracer().get(Phase::TreeExchange);
        assert_eq!(s.msgs, 1); // critical path: one message per level
        assert_eq!(s.words, 400); // total traffic volume
        c.tree_level_exchange(Phase::TreeExchange, 0, 100); // no-op
        assert_eq!(c.tracer().get(Phase::TreeExchange).msgs, 1);
    }

    #[test]
    fn reset_clears() {
        let mut c = cluster(2);
        c.broadcast(Phase::Bcast, 5);
        c.reset();
        assert_eq!(c.sim_time(), 0.0);
        assert_eq!(c.counters().msgs, 0);
    }
}
