//! Data-combining halves of the collectives.
//!
//! The cost halves live in [`super::SimCluster`]; these helpers perform
//! the actual combining the way a binary-tree MPI reduction would, so
//! floating-point summation order matches a real tree reduction (which
//! matters for bitwise reproducibility across P).

/// Binary-tree sum of per-rank vectors: pairwise combine adjacent ranks
/// level by level, exactly like an MPI binomial-tree reduce. Returns the
/// root's vector.
pub fn tree_sum(mut contribs: Vec<Vec<f64>>) -> Vec<f64> {
    assert!(!contribs.is_empty());
    let p = contribs.len();
    assert!(p.is_power_of_two(), "tree_sum requires power-of-two ranks");
    let mut stride = 1;
    while stride < p {
        let mut i = 0;
        while i + stride < p {
            // Split so we can borrow two disjoint elements.
            let (left, right) = contribs.split_at_mut(i + stride);
            let dst = &mut left[i];
            let src = &right[0];
            debug_assert_eq!(dst.len(), src.len());
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                *d += s;
            }
            i += stride * 2;
        }
        stride *= 2;
    }
    std::mem::take(&mut contribs[0])
}

/// Binary-tree max-abs merge (used by distributed top-b pre-filtering):
/// keeps per-index maximum absolute value.
pub fn tree_max_abs(mut contribs: Vec<Vec<f64>>) -> Vec<f64> {
    assert!(!contribs.is_empty());
    let p = contribs.len();
    assert!(p.is_power_of_two());
    let mut stride = 1;
    while stride < p {
        let mut i = 0;
        while i + stride < p {
            let (left, right) = contribs.split_at_mut(i + stride);
            let dst = &mut left[i];
            let src = &right[0];
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                if s.abs() > d.abs() {
                    *d = *s;
                }
            }
            i += stride * 2;
        }
        stride *= 2;
    }
    std::mem::take(&mut contribs[0])
}

/// Gather per-rank index lists into one (order: rank-major), the data
/// half of an MPI gather.
pub fn gather_indices(contribs: Vec<Vec<usize>>) -> Vec<usize> {
    let mut out = Vec::new();
    for c in contribs {
        out.extend(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_sum_matches_serial() {
        let contribs: Vec<Vec<f64>> =
            (0..8).map(|r| (0..5).map(|i| (r * 5 + i) as f64).collect()).collect();
        let tree = tree_sum(contribs.clone());
        for i in 0..5 {
            let serial: f64 = contribs.iter().map(|c| c[i]).sum();
            assert!((tree[i] - serial).abs() < 1e-12);
        }
    }

    #[test]
    fn tree_sum_single_rank() {
        let out = tree_sum(vec![vec![1.0, 2.0]]);
        assert_eq!(out, vec![1.0, 2.0]);
    }

    #[test]
    fn tree_max_abs_keeps_largest_magnitude() {
        let out = tree_max_abs(vec![
            vec![1.0, -5.0],
            vec![-3.0, 2.0],
            vec![2.0, 0.0],
            vec![-1.0, 4.0],
        ]);
        assert_eq!(out, vec![-3.0, -5.0]);
    }

    #[test]
    fn gather_preserves_rank_order() {
        let out = gather_indices(vec![vec![3, 1], vec![], vec![7]]);
        assert_eq!(out, vec![3, 1, 7]);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn tree_sum_rejects_non_pow2() {
        let _ = tree_sum(vec![vec![0.0]; 3]);
    }
}
