//! Binary tournament tree over ranks (T-bLARS, Algorithm 3 / Figure 1).
//!
//! Level 0 holds all `P` leaf ranks; each higher level halves the node
//! count by pairing adjacent nodes until a single root remains. The node
//! at `(level, i)` is hosted by the lowest rank among its leaves
//! (rank `i · 2^level`), matching a binomial reduction tree.

/// A binary tournament tree over `p` ranks (`p` a power of two).
#[derive(Clone, Copy, Debug)]
pub struct TournamentTree {
    p: usize,
}

impl TournamentTree {
    pub fn new(p: usize) -> Self {
        assert!(p >= 1 && p.is_power_of_two(), "P must be a power of two");
        TournamentTree { p }
    }

    /// Number of leaf ranks.
    pub fn nranks(&self) -> usize {
        self.p
    }

    /// Number of levels above the leaves (`log₂ P`).
    pub fn levels(&self) -> usize {
        self.p.trailing_zeros() as usize
    }

    /// Number of internal nodes at `level` (1-based above leaves):
    /// `P / 2^level`.
    pub fn nodes_at(&self, level: usize) -> usize {
        assert!(level <= self.levels());
        self.p >> level
    }

    /// The hosting rank of node `i` at `level`.
    pub fn host(&self, level: usize, i: usize) -> usize {
        assert!(i < self.nodes_at(level));
        i << level
    }

    /// Children (as node indices at `level - 1`) of node `i` at `level`.
    pub fn children(&self, level: usize, i: usize) -> (usize, usize) {
        assert!(level >= 1);
        (2 * i, 2 * i + 1)
    }

    /// Leaf ranks covered by node `i` at `level`.
    pub fn leaves(&self, level: usize, i: usize) -> std::ops::Range<usize> {
        let span = 1 << level;
        i * span..(i + 1) * span
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_and_counts() {
        let t = TournamentTree::new(8);
        assert_eq!(t.levels(), 3);
        assert_eq!(t.nodes_at(0), 8);
        assert_eq!(t.nodes_at(1), 4);
        assert_eq!(t.nodes_at(3), 1);
    }

    #[test]
    fn hosts_are_lowest_leaf() {
        let t = TournamentTree::new(8);
        assert_eq!(t.host(1, 0), 0);
        assert_eq!(t.host(1, 3), 6);
        assert_eq!(t.host(3, 0), 0); // root hosted at rank 0
    }

    #[test]
    fn children_partition_leaves() {
        let t = TournamentTree::new(8);
        for level in 1..=t.levels() {
            for i in 0..t.nodes_at(level) {
                let (l, r) = t.children(level, i);
                let pl = t.leaves(level - 1, l);
                let pr = t.leaves(level - 1, r);
                let me = t.leaves(level, i);
                assert_eq!(pl.start, me.start);
                assert_eq!(pr.end, me.end);
                assert_eq!(pl.end, pr.start);
            }
        }
    }

    #[test]
    fn single_rank_tree() {
        let t = TournamentTree::new(1);
        assert_eq!(t.levels(), 0);
        assert_eq!(t.nodes_at(0), 1);
        assert_eq!(t.leaves(0, 0), 0..1);
    }
}
