//! Serial (b)LARS — the reference implementation.
//!
//! Implements the per-iteration mathematics of Algorithm 2 without any
//! parallel machinery. With `b = 1` this **is** Algorithm 1 (LARS): the
//! bLARS direction `u = A_I (A_Iᵀ A_I)⁻¹ [c]_I h` equals the LARS
//! equiangular direction whenever all selected correlations share the
//! maximal magnitude, which `b = 1` maintains inductively (§7: "if we
//! set b = 1 then bLARS reduces to LARS").
//!
//! The paper's quality experiments (Figures 3–5) treat this
//! implementation's selections as ground truth.
//!
//! Entry points: [`fit_observed`] is the fallible, observer-carrying
//! core the [`crate::fit`] estimator API dispatches to
//! (`Algorithm::Lars`); the legacy free functions [`lars`] and
//! [`blars_serial`] remain as thin deprecated shims that panic on
//! invalid input the way their `assert!`s used to.

use super::{LarsOutput, StopReason};
use crate::cluster::tracer::Phase;
use crate::error::{Error, Result};
use crate::fit::observers::{FitEvent, FitObserver, NoopObserver, ObserverControl};
use crate::linalg::select::{argmax_b_by, argmin_b_by};
use crate::linalg::{dot, norm2, Cholesky, DenseMatrix, Matrix};
use crate::obs::phase_span;
use crate::par;

/// γ-candidate scan over the complement of the model (Algorithm 2 step
/// 12), chunked on the pool. Each chunk runs
/// [`crate::kern::gamma_scan_range`] — the same per-`j` arithmetic the
/// batched multi-response scan in [`crate::batch`] walks — and chunk
/// results concatenate in ascending chunk order, so both the candidate
/// order and every f64 operation match the serial scan exactly — on
/// any thread count.
pub(crate) fn gamma_candidates(
    n: usize,
    in_model: &[bool],
    c: &[f64],
    av: &[f64],
    ck: f64,
    h: f64,
    gamma_full: f64,
) -> Vec<(usize, f64)> {
    let chunks = par::map_chunks(n, par::min_chunk(), |lo, hi| {
        let mut loc: Vec<(usize, f64)> = Vec::new();
        crate::kern::gamma_scan_range(lo, hi, in_model, c, av, ck, h, gamma_full, &mut loc);
        loc
    });
    chunks.concat()
}

/// Options for a serial run.
#[derive(Clone, Debug)]
pub struct LarsOptions {
    /// Target number of columns (the paper's `t`).
    pub t: usize,
    /// Block size (`b = 1` ⇒ plain LARS).
    pub b: usize,
    /// Numerical floor under which the maximum correlation counts as 0.
    pub tol: f64,
}

impl Default for LarsOptions {
    fn default() -> Self {
        LarsOptions { t: 10, b: 1, tol: 1e-12 }
    }
}

/// Plain LARS (Algorithm 1): serial bLARS with `b = 1`.
#[deprecated(
    since = "0.4.0",
    note = "use calars::fit::FitSpec::new(Algorithm::Lars) — this shim panics on invalid input"
)]
pub fn lars(a: &Matrix, b_vec: &[f64], opts: &LarsOptions) -> LarsOutput {
    let o = LarsOptions { b: 1, ..opts.clone() };
    fit_observed(a, b_vec, &o, &mut NoopObserver).expect("invalid LARS input")
}

/// Serial bLARS (the mathematics of Algorithm 2 on one rank).
#[deprecated(
    since = "0.4.0",
    note = "use calars::fit::FitSpec::new(Algorithm::Blars { b }) — this shim panics on invalid input"
)]
pub fn blars_serial(a: &Matrix, b_vec: &[f64], opts: &LarsOptions) -> LarsOutput {
    fit_observed(a, b_vec, opts, &mut NoopObserver).expect("invalid bLARS input")
}

/// Serial bLARS core: validated inputs, per-iteration
/// [`FitObserver`] events, typed errors instead of `assert!`s. This is
/// what `calars::fit`'s `Algorithm::Lars` runs (with `b = 1`).
pub fn fit_observed(
    a: &Matrix,
    b_vec: &[f64],
    opts: &LarsOptions,
    obs: &mut dyn FitObserver,
) -> Result<LarsOutput> {
    let m = a.nrows();
    let n = a.ncols();
    super::check_fit_inputs(a, b_vec, opts.tol)?;
    if opts.b < 1 {
        return Err(Error::invalid_spec("block size must be ≥ 1"));
    }
    let t = opts.t.min(m.min(n));

    // State (Alg 2 step 1-2): y = 0, r = b, c = Aᵀr.
    let mut y = vec![0.0; m];
    let mut r = b_vec.to_vec();
    let mut c = vec![0.0; n];
    {
        // Phase spans mirror the SimCluster taxonomy on real hardware;
        // flop counts are coarse dense-equivalent estimates.
        let mut sp = phase_span(Phase::Corr);
        sp.flops(2 * (m as u64) * (n as u64));
        a.at_r(&r, &mut c);
    }
    let mut u = vec![0.0; m];
    let mut av = vec![0.0; n]; // a_k = Aᵀu

    let mut residual_norms = vec![norm2(&r)];
    let mut cols_at_iter = vec![0usize];

    // In/out bitmap + ordered selection.
    let mut in_model = vec![false; n];
    let mut selected: Vec<usize> = Vec::new();
    // Columns permanently excluded as rank-deficient duplicates; when
    // the run ends short of `t` because of them, the stop reason is
    // RankDeficient rather than Saturated.
    let mut rank_excluded = 0usize;

    // Step 3: pick the initial block of (up to) b columns.
    let b0 = opts.b.min(t.max(1));
    let sel_span = phase_span(Phase::Select);
    let mut block = argmax_b_by(n, b0, |j| c[j].abs());
    block.sort_unstable();
    drop(sel_span);
    // Reject numerically dead starts.
    if block.iter().all(|&j| c[j].abs() <= opts.tol) {
        return Ok(LarsOutput {
            selected,
            residual_norms,
            cols_at_iter,
            y,
            stop: StopReason::Saturated,
        });
    }
    // Steps 4-5: Gram of the initial block + Cholesky via the chunked
    // panel update, with graceful exclusion of duplicate columns
    // (§5.2; a rank-deficient block degrades to one-at-a-time
    // admission inside `append_block_graceful`).
    let mut chol = Cholesky::empty();
    {
        let g0 = {
            let mut sp = phase_span(Phase::Gram);
            sp.flops(2 * (m as u64) * (block.len() as u64) * (block.len() as u64));
            a.gram_block(&block, &block)
        };
        let chol_span = phase_span(Phase::Cholesky);
        let admitted = chol.append_block_graceful(&DenseMatrix::zeros(0, block.len()), &g0);
        drop(chol_span);
        rank_excluded += block.len() - admitted.len();
        for &row in &admitted {
            selected.push(block[row]);
        }
        for &j in &block {
            in_model[j] = true;
        }
    }
    if selected.is_empty() {
        return Ok(LarsOutput {
            selected,
            residual_norms,
            cols_at_iter,
            y,
            stop: StopReason::RankDeficient,
        });
    }

    // `c_k` scalar: the b-th largest |c| among the *selected* block —
    // which by construction of the selection is the paper's max^b|c|.
    let mut ck = selected.iter().map(|&j| c[j].abs()).fold(f64::INFINITY, f64::min);

    // Event 0: the initial block is in the model.
    let initial_stop = obs.on_iteration(&FitEvent {
        iter: 0,
        selected: &selected,
        gamma: 0.0,
        residual_norm: residual_norms[0],
        lambda: ck,
    });
    if initial_stop == ObserverControl::Stop {
        cols_at_iter.push(selected.len());
        return Ok(LarsOutput {
            selected,
            residual_norms,
            cols_at_iter,
            y,
            stop: StopReason::EarlyStopped,
        });
    }

    // Scratch buffers reused across iterations (the per-step s/q/w
    // allocations used to dominate small-problem fit latency).
    let mut s = Vec::with_capacity(t);
    let mut q = Vec::with_capacity(t);
    let mut w = Vec::with_capacity(t);

    let mut iter = 0usize;
    let stop = loop {
        if selected.len() >= t {
            break StopReason::TargetReached;
        }
        if ck <= opts.tol {
            break StopReason::Saturated;
        }

        // Steps 7-8: s = [c]_I ; q = (LLᵀ)⁻¹ s ; h = (sᵀq)^{-1/2} ; w = q·h.
        let solve_span = phase_span(Phase::Solve);
        s.clear();
        s.extend(selected.iter().map(|&j| c[j]));
        chol.solve_into(&s, &mut q);
        drop(solve_span);
        let sq = dot(&s, &q);
        if !(sq.is_finite() && sq > 0.0) {
            // sᵀG⁻¹s ≤ 0 with s ≠ 0: the factor has gone numerically
            // indefinite — a rank problem, not saturation.
            break StopReason::RankDeficient;
        }
        let h = 1.0 / sq.sqrt();
        w.clear();
        w.extend(q.iter().map(|qi| qi * h));

        // Steps 10-11 fused: u = A_I w and a = Aᵀu in one pass over A
        // (dense storage; CSC takes the two-pass form inside).
        {
            let mut sp = phase_span(Phase::DirApply);
            sp.flops(2 * (m as u64) * (selected.len() as u64 + n as u64));
            a.fused_step(&selected, &w, &mut u, &mut av);
        }

        // Step 12: γ_j candidates over the complement (pool-chunked).
        // Valid candidates lie in (0, 1/h]: beyond 1/h the selected
        // correlations have crossed zero (least-squares point reached).
        let gamma_full = 1.0 / h;
        let gamma_span = phase_span(Phase::GammaStep);
        let cand = gamma_candidates(n, &in_model, &c, &av, ck, h, gamma_full);

        let remaining = t - selected.len();
        let bsz = opts.b.min(remaining);
        let (gamma, new_block): (f64, Vec<usize>) = if cand.len() >= bsz && bsz > 0 {
            // Steps 13-14: b-th smallest γ and its b indices.
            let picks = argmin_b_by(cand.len(), bsz, |i| cand[i].1);
            let gamma = picks.iter().map(|&i| cand[i].1).fold(0.0_f64, f64::max);
            let mut block: Vec<usize> = picks.iter().map(|&i| cand[i].0).collect();
            block.sort_unstable();
            (gamma, block)
        } else {
            // Not enough catch-up candidates: take the full least-squares
            // step with whatever candidates exist, then stop.
            let mut block: Vec<usize> = cand.iter().map(|&(j, _)| j).collect();
            block.sort_unstable();
            (gamma_full, block)
        };
        drop(gamma_span);

        // Step 17: y ← y + γu ; r = b − y.
        let mut update_span = phase_span(Phase::Update);
        update_span.flops(4 * m as u64 + 2 * n as u64);
        for i in 0..m {
            y[i] += gamma * u[i];
            r[i] = b_vec[i] - y[i];
        }

        // Steps 18-19: correlation updates (no fresh Aᵀr needed).
        let shrink = 1.0 - gamma * h;
        for j in 0..n {
            if in_model[j] {
                c[j] *= shrink;
            } else {
                c[j] -= gamma * av[j];
            }
        }
        ck *= shrink;

        let rnorm = norm2(&r);
        residual_norms.push(rnorm);
        drop(update_span);

        let hit_full_step = new_block.is_empty() || gamma >= gamma_full * (1.0 - 1e-12);

        if !new_block.is_empty() {
            // Steps 20-23: extend the Cholesky factor by the new block
            // through the chunked panel update (parallel forward
            // solves, bit-identical to sequential push_rows); a column
            // collinear with the model is permanently excluded rather
            // than aborting the run (§5.2, via append_block_graceful).
            let (gib, gbb) = {
                let mut sp = phase_span(Phase::Gram);
                let k = selected.len() as u64;
                let bn = new_block.len() as u64;
                sp.flops(2 * (m as u64) * bn * (k + bn));
                (a.gram_block(&selected, &new_block), a.gram_block(&new_block, &new_block))
            };
            let chol_span = phase_span(Phase::Cholesky);
            let admitted = chol.append_block_graceful(&gib, &gbb);
            drop(chol_span);
            rank_excluded += new_block.len() - admitted.len();
            for &row in &admitted {
                selected.push(new_block[row]);
            }
            for &j in &new_block {
                in_model[j] = true;
            }
            // New scalar c_k: per step 19 the paper tracks c_k(1−γh); the
            // entering block has |c_j| ≥ that value by construction, so the
            // b-th largest among selected equals the tracked scalar. Refresh
            // from the block for numerical hygiene.
            ck = selected.iter().map(|&j| c[j].abs()).fold(f64::INFINITY, f64::min).max(ck);
        }
        cols_at_iter.push(selected.len());

        iter += 1;
        let observer_stop = obs.on_iteration(&FitEvent {
            iter,
            selected: &selected,
            gamma,
            residual_norm: rnorm,
            lambda: ck,
        }) == ObserverControl::Stop;

        if hit_full_step {
            // Attribute the shortfall honestly: RankDeficient only when
            // the excluded duplicates are what stand between the
            // selection and the target (with them the target was
            // reachable); a saturation the exclusions cannot explain
            // stays Saturated.
            let reason = if rank_excluded > 0
                && selected.len() < t
                && selected.len() + rank_excluded >= t
            {
                StopReason::RankDeficient
            } else {
                StopReason::Saturated
            };
            break reason;
        }
        if observer_stop {
            break StopReason::EarlyStopped;
        }
    };
    if cols_at_iter.last().copied() != Some(selected.len()) {
        cols_at_iter.push(selected.len());
    }

    Ok(LarsOutput { selected, residual_norms, cols_at_iter, y, stop })
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shims double as regression coverage

    use super::*;
    use crate::data::datasets;
    use crate::linalg::DenseMatrix;

    fn corr_after(a: &Matrix, b: &[f64], y: &[f64]) -> Vec<f64> {
        let r: Vec<f64> = b.iter().zip(y).map(|(bi, yi)| bi - yi).collect();
        let mut c = vec![0.0; a.ncols()];
        a.at_r(&r, &mut c);
        c
    }

    #[test]
    fn selects_requested_columns() {
        let d = datasets::tiny(1);
        let out = lars(&d.a, &d.b, &LarsOptions { t: 15, ..Default::default() });
        assert_eq!(out.selected.len(), 15);
        assert_eq!(out.stop, StopReason::TargetReached);
        // No duplicates
        let mut s = out.selected.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 15);
    }

    #[test]
    fn residuals_strictly_decrease() {
        let d = datasets::tiny(2);
        let out = lars(&d.a, &d.b, &LarsOptions { t: 20, ..Default::default() });
        for w in out.residual_norms.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "residual increased: {} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn lars_equal_correlation_invariant() {
        // After each iteration, all selected columns share the maximal
        // absolute correlation (the defining LARS property).
        let d = datasets::tiny_dense(3);
        for t in [2usize, 5, 10] {
            let out = lars(&d.a, &d.b, &LarsOptions { t, ..Default::default() });
            let c = corr_after(&d.a, &d.b, &out.y);
            let sel_abs: Vec<f64> = out.selected.iter().map(|&j| c[j].abs()).collect();
            let cmax = sel_abs.iter().fold(0.0_f64, |a, &x| a.max(x));
            for (&j, &v) in out.selected.iter().zip(&sel_abs) {
                assert!(
                    (v - cmax).abs() < 1e-6 * cmax.max(1e-12),
                    "col {j}: |corr| {v} != cmax {cmax}"
                );
            }
            // And it is maximal over the complement.
            for j in 0..d.a.ncols() {
                if !out.selected.contains(&j) {
                    assert!(c[j].abs() <= cmax * (1.0 + 1e-8), "non-selected col {j} dominates");
                }
            }
        }
    }

    #[test]
    fn blars_maximal_correlation_invariant() {
        // bLARS relaxation: no non-selected column may exceed the b-th
        // largest selected absolute correlation (§3).
        let d = datasets::tiny(4);
        let out = blars_serial(&d.a, &d.b, &LarsOptions { t: 12, b: 4, ..Default::default() });
        let c = corr_after(&d.a, &d.b, &out.y);
        let min_sel =
            out.selected.iter().map(|&j| c[j].abs()).fold(f64::INFINITY, f64::min);
        for j in 0..d.a.ncols() {
            if !out.selected.contains(&j) {
                assert!(
                    c[j].abs() <= min_sel + 1e-6,
                    "col {j} |c|={} exceeds weakest selected {min_sel}",
                    c[j].abs()
                );
            }
        }
    }

    #[test]
    fn recovers_planted_support_noiseless() {
        use crate::data::synthetic::{generate, SyntheticSpec};
        let s = generate(
            &SyntheticSpec { m: 80, n: 40, density: 1.0, col_skew: 0.0, k_true: 5, noise: 0.0 },
            11,
        );
        let out = lars(&s.a, &s.b, &LarsOptions { t: 5, ..Default::default() });
        let mut got = out.selected.clone();
        got.sort_unstable();
        assert_eq!(got, s.true_support, "LARS should find the planted support first");
    }

    #[test]
    fn blars_b1_equals_lars() {
        let d = datasets::tiny(5);
        let l = lars(&d.a, &d.b, &LarsOptions { t: 10, ..Default::default() });
        let bl = blars_serial(&d.a, &d.b, &LarsOptions { t: 10, b: 1, ..Default::default() });
        assert_eq!(l.selected, bl.selected);
        for (x, y) in l.residual_norms.iter().zip(&bl.residual_norms) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn block_sizes_advance_by_b() {
        let d = datasets::tiny(6);
        let out = blars_serial(&d.a, &d.b, &LarsOptions { t: 12, b: 3, ..Default::default() });
        assert_eq!(out.cols_at_iter.first(), Some(&0));
        // After the first iteration the initial block of b is in, then +b each.
        for w in out.cols_at_iter.windows(2) {
            assert!(w[1] - w[0] <= 3 + 3); // initial block may merge with first step
        }
        assert_eq!(out.selected.len(), 12);
    }

    #[test]
    fn saturates_on_exact_fit() {
        // b exactly in the span of 2 columns, t asks for more than needed.
        let a = Matrix::Dense({
            let mut m = DenseMatrix::from_vec(
                4,
                3,
                vec![1., 0., 0.3, 0., 1., 0.3, 0., 0., 0.9, 0., 0., 0.1],
            );
            m.normalize_columns();
            m
        });
        let b = vec![2.0, 3.0, 0.0, 0.0]; // span of cols 0,1
        let out = lars(&a, &b, &LarsOptions { t: 3, ..Default::default() });
        let last = *out.residual_norms.last().unwrap();
        assert!(
            out.stop == StopReason::Saturated || last < 1e-8,
            "stop={:?} last residual={last}",
            out.stop
        );
    }

    #[test]
    fn updated_correlations_match_recomputed() {
        // Steps 18-19 update c in place; verify against a fresh Aᵀr.
        let d = datasets::tiny_dense(7);
        let out = lars(&d.a, &d.b, &LarsOptions { t: 8, ..Default::default() });
        let c = corr_after(&d.a, &d.b, &out.y);
        // The invariant-based test recomputes; here just sanity-check scale.
        let cmax = c.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
        assert!(cmax.is_finite());
        assert!(!out.selected.is_empty());
    }

    #[test]
    fn hand_computed_orthogonal_case() {
        // Orthonormal design (identity): LARS on b = [3, 1, 0] is fully
        // analytic. Iter 1: select col 0, γ = 2, y = [2,0,0], ‖r‖ = √2.
        // Iter 2: select col 1, full step γ = √2 along (e1+e2)/√2,
        // y = [3,1,0], residual 0 (saturated).
        let a = Matrix::Dense(DenseMatrix::from_vec(
            3,
            3,
            vec![1., 0., 0., 0., 1., 0., 0., 0., 1.],
        ));
        let b = vec![3.0, 1.0, 0.0];
        let out = lars(&a, &b, &LarsOptions { t: 3, ..Default::default() });
        assert_eq!(&out.selected[..2], &[0, 1]);
        assert!((out.residual_norms[0] - 10f64.sqrt()).abs() < 1e-12);
        assert!((out.residual_norms[1] - 2f64.sqrt()).abs() < 1e-9);
        assert!(out.residual_norms.last().unwrap() < &1e-9);
        assert!((out.y[0] - 3.0).abs() < 1e-9);
        assert!((out.y[1] - 1.0).abs() < 1e-9);
        assert!(out.y[2].abs() < 1e-9);
    }

    #[test]
    fn t_clamped_to_min_mn() {
        let d = datasets::tiny_dense(8); // m=150, n=60
        let out = lars(&d.a, &d.b, &LarsOptions { t: 500, ..Default::default() });
        assert!(out.selected.len() <= 60);
    }

    #[test]
    fn fit_observed_rejects_bad_inputs_without_panicking() {
        use crate::error::ErrorKind;
        let d = datasets::tiny(9);
        let short = vec![0.0; d.a.nrows() - 1];
        let err = fit_observed(&d.a, &short, &LarsOptions::default(), &mut NoopObserver)
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
        let err = fit_observed(
            &d.a,
            &d.b,
            &LarsOptions { b: 0, ..Default::default() },
            &mut NoopObserver,
        )
        .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
    }
}
