//! Solution-quality metrics (paper §10.1).
//!
//! Two metrics: the ℓ2 residual-vs-columns curve (Figure 3) comes for
//! free from [`super::LarsOutput`]; the second is *precision in column
//! selection* — treating plain LARS's selections as ground truth, the
//! fraction of a method's selected columns that LARS also selected
//! (Figures 4–5).

/// Precision of `candidate` against `reference`:
/// `|candidate ∩ reference| / |candidate|`. Returns 1.0 for an empty
/// candidate set (vacuous precision).
pub fn precision(candidate: &[usize], reference: &[usize]) -> f64 {
    if candidate.is_empty() {
        return 1.0;
    }
    let mut refset: Vec<usize> = reference.to_vec();
    refset.sort_unstable();
    let hits = candidate.iter().filter(|j| refset.binary_search(j).is_ok()).count();
    hits as f64 / candidate.len() as f64
}

/// Recall against a known support (synthetic ground truth):
/// `|candidate ∩ truth| / |truth|`.
pub fn recall(candidate: &[usize], truth: &[usize]) -> f64 {
    if truth.is_empty() {
        return 1.0;
    }
    let mut cset: Vec<usize> = candidate.to_vec();
    cset.sort_unstable();
    let hits = truth.iter().filter(|j| cset.binary_search(j).is_ok()).count();
    hits as f64 / truth.len() as f64
}

/// Summary statistics over repeated runs (Figure 5's min/mean/max bars).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MinMeanMax {
    pub min: f64,
    pub mean: f64,
    pub max: f64,
}

/// Compute min/mean/max of a non-empty sample.
pub fn min_mean_max(xs: &[f64]) -> MinMeanMax {
    assert!(!xs.is_empty());
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    // audit: allow(DET-SUM) -- serial left-to-right iterator sum over reporting samples: fixed order, diagnostics only (never feeds a fit)
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    MinMeanMax { min, mean, max }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_basic() {
        assert_eq!(precision(&[1, 2, 3, 4], &[2, 4, 6, 8]), 0.5);
        assert_eq!(precision(&[1, 2], &[1, 2, 3]), 1.0);
        assert_eq!(precision(&[9], &[1, 2]), 0.0);
        assert_eq!(precision(&[], &[1]), 1.0);
    }

    #[test]
    fn recall_basic() {
        assert_eq!(recall(&[1, 2, 3], &[2, 3, 4, 5]), 0.5);
        assert_eq!(recall(&[], &[]), 1.0);
        assert_eq!(recall(&[1], &[]), 1.0);
    }

    #[test]
    fn min_mean_max_works() {
        let s = min_mean_max(&[0.2, 0.8, 0.5]);
        assert_eq!(s.min, 0.2);
        assert_eq!(s.max, 0.8);
        assert!((s.mean - 0.5).abs() < 1e-12);
    }

    #[test]
    fn order_independent() {
        assert_eq!(precision(&[3, 1, 2], &[2, 1]), precision(&[1, 2, 3], &[1, 2]));
    }
}
