//! LARS with the LASSO modification (Efron et al. [17], Theorem 1;
//! referenced in the paper's §2: "a certain version of LARS produces a
//! sequence of solutions equivalent to the solution path x(λ)").
//!
//! Identical to LARS except that an active coefficient hitting zero is
//! *dropped* from the active set before any new column enters; the
//! resulting breakpoints trace the exact ℓ1-regularization path, with
//! λ equal to the common absolute correlation at each breakpoint.
//!
//! This is the reference implementation (fresh `Aᵀr` per step, Gram
//! refactorization on drops) — it anchors correctness of both the
//! fast LARS implementations and the coordinate-descent baseline:
//! between consecutive breakpoints the path is linear in λ, so any
//! interior LASSO solution is checkable against `baselines::lasso_cd`.
//!
//! Entry points: [`fit_observed`] is the fallible, observer-carrying
//! core the [`crate::fit`] estimator API dispatches to
//! (`Algorithm::LassoLars`); the legacy free function [`lasso_path`]
//! remains as a thin deprecated shim.

use super::{LarsOutput, StopReason};
use crate::cluster::tracer::Phase;
use crate::error::{Error, Result};
use crate::fit::observers::{FitEvent, FitObserver, NoopObserver, ObserverControl};
use crate::linalg::{norm2, Cholesky, Matrix};
use crate::obs::phase_span;

/// One breakpoint of the LASSO path.
#[derive(Clone, Debug)]
pub struct Breakpoint {
    /// Regularization level: the common |correlation| of active columns.
    pub lambda: f64,
    /// Active set (ascending).
    pub support: Vec<usize>,
    /// Dense coefficient vector (length n).
    pub x: Vec<f64>,
    /// ‖b − Ax‖₂ at the breakpoint.
    pub residual_norm: f64,
}

/// The piecewise-linear LASSO path.
#[derive(Clone, Debug)]
pub struct LassoPath {
    pub breakpoints: Vec<Breakpoint>,
    /// Number of drop events encountered (0 ⇒ plain LARS ≡ LASSO here).
    pub drops: usize,
}

impl LassoPath {
    /// Interpolate the solution at regularization `lambda` (the path is
    /// linear in λ between breakpoints). `None` outside the computed
    /// range.
    pub fn solution_at(&self, lambda: f64) -> Option<Vec<f64>> {
        let bps = &self.breakpoints;
        if bps.is_empty() || lambda > bps[0].lambda {
            return None;
        }
        for w in bps.windows(2) {
            let (hi, lo) = (&w[0], &w[1]);
            if lambda <= hi.lambda && lambda >= lo.lambda {
                let span = (hi.lambda - lo.lambda).max(1e-300);
                let t = (hi.lambda - lambda) / span;
                return Some(
                    hi.x.iter().zip(&lo.x).map(|(a, b)| a + t * (b - a)).collect(),
                );
            }
        }
        None
    }
}

/// What the LASSO-LARS core returns: the exact path plus the unified
/// family-shaped output (selection order = activation order of the
/// final active set, residuals per breakpoint).
pub struct LassoFit {
    pub out: LarsOutput,
    pub path: LassoPath,
}

/// Trace the LASSO path until `max_active` columns are active, λ falls
/// below `lambda_min`, or the path saturates. Uses the reference
/// implementation's historical numerical floor (`tol = 1e-10`).
#[deprecated(
    since = "0.4.0",
    note = "use calars::fit::FitSpec::new(Algorithm::LassoLars { lambda_min }).t(max_active) — this shim panics on invalid input"
)]
pub fn lasso_path(a: &Matrix, b: &[f64], max_active: usize, lambda_min: f64) -> LassoPath {
    fit_observed(a, b, max_active, lambda_min, 1e-10, &mut NoopObserver)
        .expect("invalid LASSO input")
        .path
}

/// LASSO-LARS core: validated inputs, per-breakpoint [`FitObserver`]
/// events, typed errors, and a [`StopReason`] — `RankDeficient` when a
/// Gram factorization fails (simultaneously activated duplicate
/// columns), `TargetReached` at `max_active`, `Saturated` at the λ
/// floor or the least-squares point, `PoolExhausted` if the cycling
/// guard trips. `tol` is the spec's shared numerical floor: it guards
/// both the correlation level (`λ ≤ max(lambda_min, tol)` saturates)
/// and the drop-event detection.
pub fn fit_observed(
    a: &Matrix,
    b: &[f64],
    max_active: usize,
    lambda_min: f64,
    tol: f64,
    obs: &mut dyn FitObserver,
) -> Result<LassoFit> {
    let m = a.nrows();
    let n = a.ncols();
    super::check_fit_inputs(a, b, tol)?;
    if !lambda_min.is_finite() || lambda_min < 0.0 {
        return Err(Error::invalid_spec(format!(
            "lambda_min must be finite and ≥ 0 (got {lambda_min})"
        )));
    }

    let mut x = vec![0.0; n];
    let mut active: Vec<usize> = Vec::new();
    // Activation order (drops remove their column); `order_at_last_bp`
    // freezes it at the last *recorded* breakpoint so the family
    // output's `selected` always matches the stored path even when a
    // stop fires mid-event, after activation but before the step.
    let mut order: Vec<usize> = Vec::new();
    let mut order_at_last_bp: Vec<usize> = Vec::new();
    let mut breakpoints: Vec<Breakpoint> = Vec::new();
    let mut drops = 0usize;
    let mut r = b.to_vec();
    let mut c = vec![0.0; n];
    // Per-event scratch reused across the path (u/av were fresh
    // length-m/n allocations every breakpoint event).
    let mut u = vec![0.0; m];
    let mut av = vec![0.0; n];
    let max_active = max_active.min(m.min(n));

    // Guard against pathological cycling (paper assumes general position).
    let max_events = 8 * max_active + 16;

    let mut stop = StopReason::PoolExhausted; // if the event guard trips
    let mut iter = 0usize;
    for _event in 0..max_events {
        // Fresh correlations (reference implementation). Coarser phase
        // spans than the serial core: one Corr + one Gram/Cholesky per
        // breakpoint event.
        {
            let mut sp = phase_span(Phase::Corr);
            sp.flops(2 * (m as u64) * (n as u64));
            a.at_r(&r, &mut c);
        }
        let ck = c.iter().fold(0.0_f64, |mx, &v| mx.max(v.abs()));
        if ck <= lambda_min.max(tol) {
            stop = StopReason::Saturated;
            break;
        }
        if breakpoints.is_empty() {
            breakpoints.push(Breakpoint {
                lambda: ck,
                support: Vec::new(),
                x: x.clone(),
                residual_norm: norm2(&r),
            });
        }

        // Activate every column at the current correlation level.
        for j in 0..n {
            if !active.contains(&j) && c[j].abs() >= ck * (1.0 - 1e-9) {
                active.push(j);
                order.push(j);
            }
        }
        active.sort_unstable();
        if active.len() > max_active {
            stop = StopReason::TargetReached;
            break;
        }

        // Direction: w = h · G⁻¹ c_A (all |c_A| = ck ⇒ LARS equiangular).
        let s: Vec<f64> = active.iter().map(|&j| c[j]).collect();
        let g = {
            let mut sp = phase_span(Phase::Gram);
            let k = active.len() as u64;
            sp.flops(2 * (m as u64) * k * k);
            a.gram_block(&active, &active)
        };
        let chol_span = phase_span(Phase::Cholesky);
        let factored = Cholesky::factor(&g);
        drop(chol_span);
        let Ok(chol) = factored else {
            stop = StopReason::RankDeficient;
            break;
        };
        let q = chol.solve(&s);
        let sq: f64 = s.iter().zip(&q).map(|(a, b)| a * b).sum();
        if !(sq.is_finite() && sq > 0.0) {
            stop = StopReason::RankDeficient;
            break;
        }
        let h = 1.0 / sq.sqrt();
        let w: Vec<f64> = q.iter().map(|qi| qi * h).collect();

        // u = A_A w ; av = Aᵀu — fused single pass (dense storage).
        {
            let mut sp = phase_span(Phase::DirApply);
            sp.flops(2 * (m as u64) * (active.len() as u64 + n as u64));
            a.fused_step(&active, &w, &mut u, &mut av);
        }

        // Standard LARS entering step.
        let gamma_full = 1.0 / h;
        let gamma_span = phase_span(Phase::GammaStep);
        let mut gamma_add = gamma_full;
        for j in 0..n {
            if active.binary_search(&j).is_ok() {
                continue;
            }
            let g1 = (ck - c[j]) / (ck * h - av[j]);
            let g2 = (ck + c[j]) / (ck * h + av[j]);
            if let Some(g) = crate::linalg::select::min_positive2(g1, g2) {
                if g < gamma_add {
                    gamma_add = g;
                }
            }
        }

        // LASSO modification: first active coefficient to cross zero.
        let mut gamma_drop = f64::INFINITY;
        let mut drop_pos: Option<usize> = None;
        for (k, &j) in active.iter().enumerate() {
            if w[k] != 0.0 {
                let g = -x[j] / w[k];
                if g > tol && g < gamma_drop {
                    gamma_drop = g;
                    drop_pos = Some(k);
                }
            }
        }

        let gamma = gamma_add.min(gamma_drop);
        drop(gamma_span);
        let update_span = phase_span(Phase::Update);
        // Step coefficients and residual.
        for (k, &j) in active.iter().enumerate() {
            x[j] += gamma * w[k];
        }
        for i in 0..m {
            r[i] -= gamma * u[i];
        }

        if gamma_drop < gamma_add {
            // Drop event: zero the crossing coefficient exactly.
            // audit: allow(PANIC-REACH) -- gamma_drop < gamma_add implies drop_pos was set: gamma_drop starts at +inf and is only lowered together with drop_pos
            let k = drop_pos.unwrap();
            let j = active.remove(k);
            x[j] = 0.0;
            if let Some(pos) = order.iter().position(|&v| v == j) {
                order.remove(pos);
            }
            drops += 1;
        }

        let bp_lambda = (ck * (1.0 - gamma * h)).max(0.0);
        let bp_rnorm = norm2(&r);
        breakpoints.push(Breakpoint {
            lambda: bp_lambda,
            support: active.clone(),
            x: x.clone(),
            residual_norm: bp_rnorm,
        });
        order_at_last_bp.clone_from(&order);
        drop(update_span);

        let observer_stop = obs.on_iteration(&FitEvent {
            iter,
            selected: &order,
            gamma,
            residual_norm: bp_rnorm,
            lambda: bp_lambda,
        }) == ObserverControl::Stop;
        iter += 1;

        if gamma >= gamma_full * (1.0 - 1e-12) {
            stop = StopReason::Saturated;
            break; // least-squares point reached
        }
        if observer_stop {
            stop = StopReason::EarlyStopped;
            break;
        }
    }

    // Family-shaped output: one entry per stored breakpoint.
    let (residual_norms, cols_at_iter) = if breakpoints.is_empty() {
        (vec![norm2(b)], vec![0usize])
    } else {
        (
            breakpoints.iter().map(|bp| bp.residual_norm).collect(),
            breakpoints.iter().map(|bp| bp.support.len()).collect(),
        )
    };
    let y: Vec<f64> = b.iter().zip(&r).map(|(bi, ri)| bi - ri).collect();
    let out = LarsOutput { selected: order_at_last_bp, residual_norms, cols_at_iter, y, stop };
    Ok(LassoFit { out, path: LassoPath { breakpoints, drops } })
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shim doubles as regression coverage

    use super::*;
    use crate::baselines::lasso_cd::{lambda_max, lasso_cd};
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn problem(seed: u64) -> crate::data::synthetic::Synthetic {
        generate(
            &SyntheticSpec { m: 80, n: 40, density: 1.0, col_skew: 0.0, k_true: 6, noise: 0.05 },
            seed,
        )
    }

    #[test]
    fn lambdas_strictly_decrease() {
        let s = problem(1);
        let path = lasso_path(&s.a, &s.b, 15, 1e-6);
        assert!(path.breakpoints.len() >= 3);
        for w in path.breakpoints.windows(2) {
            assert!(w[1].lambda <= w[0].lambda + 1e-9);
        }
    }

    #[test]
    fn first_lambda_is_lambda_max() {
        let s = problem(2);
        let path = lasso_path(&s.a, &s.b, 10, 1e-6);
        let lmax = lambda_max(&s.a, &s.b);
        assert!((path.breakpoints[0].lambda - lmax).abs() < 1e-9 * lmax);
    }

    #[test]
    fn matches_coordinate_descent_at_interior_lambda() {
        // Theorem 1 (Efron et al.): the LARS-LASSO path solves the LASSO
        // at every λ. Cross-check against the CD solver.
        for seed in [3u64, 4, 5] {
            let s = problem(seed);
            let path = lasso_path(&s.a, &s.b, 20, 1e-8);
            let lmax = lambda_max(&s.a, &s.b);
            for frac in [0.6, 0.3, 0.1] {
                let lambda = lmax * frac;
                let Some(x_path) = path.solution_at(lambda) else { continue };
                let cd = lasso_cd(&s.a, &s.b, lambda, 5000, 1e-12);
                assert!(cd.converged);
                let err = x_path
                    .iter()
                    .zip(&cd.x)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0_f64, f64::max);
                assert!(
                    err < 1e-5,
                    "seed {seed} λ={lambda:.4}: path vs CD max err {err:.2e}"
                );
            }
        }
    }

    #[test]
    fn residuals_decrease_along_path() {
        let s = problem(6);
        let path = lasso_path(&s.a, &s.b, 15, 1e-6);
        for w in path.breakpoints.windows(2) {
            assert!(w[1].residual_norm <= w[0].residual_norm + 1e-9);
        }
    }

    #[test]
    fn solution_at_endpoints_and_outside() {
        let s = problem(7);
        let path = lasso_path(&s.a, &s.b, 10, 1e-6);
        let lmax = path.breakpoints[0].lambda;
        assert!(path.solution_at(lmax * 1.1).is_none());
        let x = path.solution_at(lmax * 0.999).unwrap();
        // Just below λmax the solution is barely nonzero.
        assert!(crate::linalg::norm_inf(&x) < 0.1);
    }

    #[test]
    fn agrees_with_plain_lars_when_no_drops() {
        use crate::lars::serial::{lars, LarsOptions};
        let s = problem(8);
        let path = lasso_path(&s.a, &s.b, 8, 1e-6);
        if path.drops == 0 {
            let la = lars(&s.a, &s.b, &LarsOptions { t: 8, ..Default::default() });
            let last = path.breakpoints.last().unwrap();
            // Same active set as the LARS selection (order-insensitive).
            let mut lsel = la.selected.clone();
            lsel.sort_unstable();
            let overlap = crate::lars::quality::precision(&last.support, &lsel);
            assert!(overlap >= 0.9, "overlap {overlap}");
        }
    }

    #[test]
    fn family_output_mirrors_the_path() {
        let s = problem(9);
        let fit = fit_observed(&s.a, &s.b, 10, 1e-6, 1e-10, &mut NoopObserver).unwrap();
        assert_eq!(fit.out.residual_norms.len(), fit.path.breakpoints.len());
        assert_eq!(fit.out.cols_at_iter.len(), fit.path.breakpoints.len());
        // Final selection = the last recorded breakpoint's support
        // (order-insensitive).
        let mut sel = fit.out.selected.clone();
        sel.sort_unstable();
        assert_eq!(sel, fit.path.breakpoints.last().unwrap().support);
        // Residual trace mirrors the breakpoints exactly.
        for (rn, bp) in fit.out.residual_norms.iter().zip(&fit.path.breakpoints) {
            assert_eq!(rn.to_bits(), bp.residual_norm.to_bits());
        }
        assert!(matches!(
            fit.out.stop,
            StopReason::TargetReached | StopReason::Saturated
        ));
    }
}
