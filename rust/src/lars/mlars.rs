//! Algorithm 4 — Modified Least Angle Regression (mLARS).
//!
//! One tournament node's local solver: starting from the globally
//! selected set `Ĩ₀` (with its Cholesky factor) and a candidate pool
//! `Ĩ_v`, select `b` more columns one at a time, LARS-style, using
//! [stepLARS](super::steplars) to survive the broken invariant
//! (a pool column may out-correlate every selected column — impossible
//! in plain LARS, routine here because the node only sees a slice of
//! the data).
//!
//! All arithmetic phases are measured into a private [`Tracer`] so
//! T-bLARS can assemble critical-path timings and the Figure 7/8
//! breakdowns.
//
// audit: allow(DET-TIME, file) -- every Instant::now here feeds the Tracer's phase timings only; no clock value ever reaches the numerics or control flow

use super::steplars::{step_lars, StepKind};
use crate::cluster::{Phase, Tracer};
use crate::linalg::{dot, Cholesky, Matrix};
use std::time::Instant;

/// Result of one mLARS call.
#[derive(Clone, Debug)]
pub struct MlarsOutput {
    /// Updated response estimate (length m).
    pub y: Vec<f64>,
    /// Full selected set: `Ĩ₀` followed by the new columns, in order.
    pub selected: Vec<usize>,
    /// The newly selected columns `B`, in selection order.
    pub new_cols: Vec<usize>,
    /// Cholesky factor over `selected` (same order).
    pub chol: Cholesky,
    /// Measured per-phase compute (no communication happens inside).
    pub tracer: Tracer,
}

/// Run mLARS.
///
/// * `a` — the global matrix (a node accesses only columns in
///   `i0 ∪ pool`; cost accounting charges exactly those);
/// * `b_vec` — the response;
/// * `y_tilde` — current global response estimate `ỹ`;
/// * `i0` — globally selected columns (ordered), with factor `chol0`;
/// * `pool` — this node's candidate columns (`Ĩ_v \ Ĩ₀`);
/// * `budget` — number of new columns `b` to select;
/// * `tol` — numerical floor.
#[allow(clippy::too_many_arguments)] // mirrors Algorithm 4's parameter list
pub fn mlars(
    a: &Matrix,
    b_vec: &[f64],
    y_tilde: &[f64],
    i0: &[usize],
    pool: &[usize],
    chol0: &Cholesky,
    budget: usize,
    tol: f64,
) -> MlarsOutput {
    let m = a.nrows();
    assert_eq!(b_vec.len(), m);
    assert_eq!(y_tilde.len(), m);
    assert_eq!(chol0.dim(), i0.len());

    let mut tracer = Tracer::new();
    let mut y = y_tilde.to_vec();
    let mut selected: Vec<usize> = i0.to_vec();
    let mut chol = chol0.clone();
    let mut new_cols: Vec<usize> = Vec::new();

    // ── Steps 3-4: r = b − ỹ ; c over I₀ ∪ Ĩ_v. ──
    let t0 = Instant::now();
    let r: Vec<f64> = b_vec.iter().zip(&y).map(|(bi, yi)| bi - yi).collect();
    let mut c_sel = vec![0.0; selected.len()];
    a.cols_dot(&selected, &r, &mut c_sel);
    // O(pool + |I₀|) membership filter (a linear `contains` scan per pool
    // element costs pool·|I₀| — measurable at leaf scale; §Perf L3 note).
    let mut in_sel = vec![false; a.ncols()];
    for &j in &selected {
        in_sel[j] = true;
    }
    let mut pool: Vec<usize> = pool.iter().copied().filter(|&j| !in_sel[j]).collect();
    let mut c_pool = vec![0.0; pool.len()];
    a.cols_dot(&pool, &r, &mut c_pool);
    tracer.add_time(Phase::Corr, t0.elapsed().as_secs_f64());
    tracer.add_flops(Phase::Corr, a.gemv_cols_flops(&selected) + a.gemv_cols_flops(&pool));

    // A NaN/∞ correlation (a degenerate shard column or a poisoned
    // response estimate) would corrupt every comparison below; bail
    // out with no nominations so the tournament driver reports a typed
    // stop instead of the whole T-bLARS fit panicking.
    if c_sel.iter().chain(c_pool.iter()).any(|v| !v.is_finite()) {
        return MlarsOutput { y, selected, new_cols, chol, tracer };
    }

    // ── Step 5 (+6-8): c_k over the selected set; bootstrap if empty. ──
    let mut ck = c_sel.iter().fold(0.0_f64, |mx, &v| mx.max(v.abs()));
    if selected.is_empty() {
        if pool.is_empty() {
            return MlarsOutput { y, selected, new_cols, chol, tracer };
        }
        let t0 = Instant::now();
        let (imax, _) = c_pool
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.abs().total_cmp(&y.1.abs()))
            // audit: allow(PANIC-REACH) -- pool is non-empty here (checked just above), so the max exists
            .unwrap();
        let j = pool.swap_remove(imax);
        let cj = c_pool.swap_remove(imax);
        // L₀ = (A_jᵀA_j)^{1/2} — columns are unit-norm but compute it.
        let gjj = a.gram_block(&[j], &[j]).get(0, 0);
        if chol.push_row(&[gjj]).is_err() {
            return MlarsOutput { y, selected, new_cols, chol, tracer };
        }
        selected.push(j);
        new_cols.push(j);
        c_sel.push(cj);
        ck = cj.abs();
        tracer.add_time(Phase::Select, t0.elapsed().as_secs_f64());
    }

    let target = i0.len() + budget;
    let mut u = vec![0.0; m];
    // Scratch reused across iterations (q/w/a_pool/steps/grow used to
    // reallocate every step — measurable at leaf scale, where mLARS
    // runs once per tournament node per outer iteration).
    let mut q: Vec<f64> = Vec::new();
    let mut w: Vec<f64> = Vec::new();
    let mut a_pool: Vec<f64> = Vec::new();
    let mut steps: Vec<StepKind> = Vec::new();
    let mut grow: Vec<f64> = Vec::new();

    // ── Main loop (steps 9-28). ──
    while selected.len() < target && !pool.is_empty() {
        if ck <= tol {
            break;
        }

        // Steps 10-13: s, q, h, w.
        let t0 = Instant::now();
        chol.solve_into(&c_sel, &mut q);
        let sq = dot(&c_sel, &q);
        if !(sq.is_finite() && sq > 0.0) {
            break;
        }
        let h = 1.0 / sq.sqrt();
        w.clear();
        w.extend(q.iter().map(|qi| qi * h));
        tracer.add_time(Phase::Solve, t0.elapsed().as_secs_f64());
        tracer.add_flops(Phase::Solve, (selected.len() * selected.len()) as u64);

        // Step 14: u = A_I w.
        let t0 = Instant::now();
        a.gemv_cols(&selected, &w, &mut u);
        tracer.add_time(Phase::DirApply, t0.elapsed().as_secs_f64());
        tracer.add_flops(Phase::DirApply, a.gemv_cols_flops(&selected));

        // Step 15: a over the pool.
        let t0 = Instant::now();
        a_pool.clear();
        a_pool.resize(pool.len(), 0.0);
        a.cols_dot(&pool, &u, &mut a_pool);
        tracer.add_time(Phase::Corr, t0.elapsed().as_secs_f64());
        tracer.add_flops(Phase::Corr, a.gemv_cols_flops(&pool));

        // Steps 16-18: stepLARS per pool column; pick γ_k and the entrant.
        let t0 = Instant::now();
        steps.clear();
        steps.extend(
            pool.iter()
                .zip(&c_pool)
                .zip(&a_pool)
                .map(|((_, &cj), &aj)| step_lars(ck, h, cj, aj)),
        );
        let any_zero = steps.iter().any(|s| s.gamma() == 0.0);
        let (gamma, entrant_pos) = if any_zero {
            // Step 17/18 (zero branch): γ_k = 0; force-add the zero-γ
            // column with the largest |c|.
            let pos = (0..pool.len())
                .filter(|&i| steps[i].gamma() == 0.0)
                .max_by(|&x, &y| c_pool[x].abs().total_cmp(&c_pool[y].abs()))
                // audit: allow(PANIC-REACH) -- this branch runs only when a zero-gamma step exists, so the filtered max exists
                .unwrap();
            (0.0, pos)
        } else {
            let pos = (0..pool.len())
                .min_by(|&x, &y| steps[x].gamma().total_cmp(&steps[y].gamma()))
                // audit: allow(PANIC-REACH) -- the main loop runs only while pool is non-empty, so the min exists
                .unwrap();
            (steps[pos].gamma(), pos)
        };
        tracer.add_time(Phase::GammaStep, t0.elapsed().as_secs_f64());
        tracer.add_flops(Phase::GammaStep, 6 * pool.len() as u64);

        // Step 19: y ← y + γu.
        let t0 = Instant::now();
        if gamma != 0.0 {
            for i in 0..m {
                y[i] += gamma * u[i];
            }
        }
        // Step 20: correlation updates.
        let shrink = 1.0 - gamma * h;
        for v in c_sel.iter_mut() {
            *v *= shrink;
        }
        for (v, &aj) in c_pool.iter_mut().zip(&a_pool) {
            *v -= gamma * aj;
        }
        tracer.add_time(Phase::Update, t0.elapsed().as_secs_f64());
        tracer.add_flops(Phase::Update, (m + pool.len()) as u64);

        // Steps 21 + 23-26: admit the entrant, extend the factor.
        let t0 = Instant::now();
        let j = pool[entrant_pos];
        let grow_head = a.gram_block(&selected, &[j]);
        let gjj = a.gram_block(&[j], &[j]).get(0, 0);
        grow.clear();
        grow.extend((0..selected.len()).map(|i| grow_head.get(i, 0)));
        grow.push(gjj);
        tracer.add_flops(Phase::Gram, a.gram_block_flops(&selected, &[j]) + 2);
        if chol.push_row(&grow).is_ok() {
            pool.swap_remove(entrant_pos);
            let cj = c_pool.swap_remove(entrant_pos);
            selected.push(j);
            new_cols.push(j);
            c_sel.push(cj);
        } else {
            // Near-duplicate of an already selected column: drop it from
            // the pool and continue (the paper's §5.2 independence
            // assumption rules this out; we degrade gracefully).
            pool.swap_remove(entrant_pos);
            c_pool.swap_remove(entrant_pos);
        }
        tracer.add_time(Phase::Cholesky, t0.elapsed().as_secs_f64());

        // Step 22: refresh c_k over the (updated) selected correlations.
        ck = c_sel.iter().fold(0.0_f64, |mx, &v| mx.max(v.abs()));
    }

    MlarsOutput { y, selected, new_cols, chol, tracer }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // compares against the legacy serial shim

    use super::*;
    use crate::data::datasets;
    use crate::lars::serial::{lars, LarsOptions};
    use crate::linalg::norm2;

    #[test]
    fn from_scratch_matches_lars_on_full_pool() {
        // With Ĩ₀ = ∅ and the pool = all columns, mLARS is plain LARS.
        let d = datasets::tiny_dense(1);
        let n = d.a.ncols();
        let m = d.a.nrows();
        let reference = lars(&d.a, &d.b, &LarsOptions { t: 8, ..Default::default() });
        let out = mlars(
            &d.a,
            &d.b,
            &vec![0.0; m],
            &[],
            &(0..n).collect::<Vec<_>>(),
            &Cholesky::empty(),
            8,
            1e-12,
        );
        assert_eq!(out.selected, reference.selected);
        assert_eq!(out.new_cols.len(), 8);
    }

    #[test]
    fn respects_budget() {
        let d = datasets::tiny(2);
        let pool: Vec<usize> = (0..100).collect();
        let out = mlars(
            &d.a,
            &d.b,
            &vec![0.0; d.a.nrows()],
            &[],
            &pool,
            &Cholesky::empty(),
            5,
            1e-12,
        );
        assert_eq!(out.new_cols.len(), 5);
        assert!(out.new_cols.iter().all(|j| pool.contains(j)));
    }

    #[test]
    fn extends_existing_selection() {
        let d = datasets::tiny_dense(3);
        // Run LARS for 4 columns, then ask mLARS to continue with 3 more
        // from the full pool — result must equal 7-column LARS.
        let ref7 = lars(&d.a, &d.b, &LarsOptions { t: 7, ..Default::default() });
        let ref4 = lars(&d.a, &d.b, &LarsOptions { t: 4, ..Default::default() });
        let chol4 = Cholesky::factor(&d.a.gram_block(&ref4.selected, &ref4.selected)).unwrap();
        let pool: Vec<usize> = (0..d.a.ncols()).collect();
        let out = mlars(&d.a, &d.b, &ref4.y, &ref4.selected, &pool, &chol4, 3, 1e-12);
        assert_eq!(out.selected, ref7.selected);
        // Response estimate should be close to the 7-column LARS estimate.
        let dy: f64 = out
            .y
            .iter()
            .zip(&ref7.y)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(dy < 1e-8 * norm2(&ref7.y).max(1.0), "dy={dy}");
    }

    #[test]
    fn handles_violating_pool() {
        // Give mLARS a selected set that is NOT maximal: Ĩ₀ chosen as the
        // *least* correlated columns, so the pool violates the LARS
        // invariant. mLARS must still produce the requested budget.
        let d = datasets::tiny_dense(4);
        let m = d.a.nrows();
        let n = d.a.ncols();
        let mut c = vec![0.0; n];
        d.a.at_r(&d.b, &mut c);
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&i, &j| c[i].abs().total_cmp(&c[j].abs()));
        let weak: Vec<usize> = order[..3].to_vec();
        let chol = Cholesky::factor(&d.a.gram_block(&weak, &weak)).unwrap();
        let pool: Vec<usize> = order[3..].to_vec();
        let out = mlars(&d.a, &d.b, &vec![0.0; m], &weak, &pool, &chol, 4, 1e-12);
        assert_eq!(out.new_cols.len(), 4, "budget not met under violation");
        assert_eq!(out.selected.len(), 7);
        assert_eq!(out.chol.dim(), 7);
    }

    #[test]
    fn nan_response_estimate_does_not_panic() {
        // Regression: these inputs used to abort the whole T-bLARS fit
        // at a `partial_cmp(..).unwrap()` in the bootstrap `max_by`
        // (a NaN correlation is incomparable). The node must instead
        // nominate nothing, so the tournament driver reports a typed
        // stop reason.
        let d = datasets::tiny_dense(8);
        let m = d.a.nrows();
        let mut y = vec![0.0; m];
        y[0] = f64::NAN;
        let pool: Vec<usize> = (0..d.a.ncols()).collect();
        let out = mlars(&d.a, &d.b, &y, &[], &pool, &Cholesky::empty(), 3, 1e-12);
        assert!(out.new_cols.is_empty(), "degenerate node must nominate nothing");
        // Same guard when a selected set already exists.
        let ref2 = lars(&d.a, &d.b, &LarsOptions { t: 2, ..Default::default() });
        let chol = Cholesky::factor(&d.a.gram_block(&ref2.selected, &ref2.selected)).unwrap();
        let out = mlars(&d.a, &d.b, &y, &ref2.selected, &pool, &chol, 2, 1e-12);
        assert!(out.new_cols.is_empty());
    }

    #[test]
    fn empty_pool_returns_immediately() {
        let d = datasets::tiny_dense(5);
        let m = d.a.nrows();
        let out = mlars(&d.a, &d.b, &vec![0.0; m], &[], &[], &Cholesky::empty(), 3, 1e-12);
        assert!(out.new_cols.is_empty());
        assert!(out.selected.is_empty());
    }

    #[test]
    fn pool_overlapping_selected_is_filtered() {
        let d = datasets::tiny_dense(6);
        let ref2 = lars(&d.a, &d.b, &LarsOptions { t: 2, ..Default::default() });
        let chol = Cholesky::factor(&d.a.gram_block(&ref2.selected, &ref2.selected)).unwrap();
        let pool: Vec<usize> = (0..d.a.ncols()).collect(); // includes selected
        let out = mlars(&d.a, &d.b, &ref2.y, &ref2.selected, &pool, &chol, 2, 1e-12);
        // New columns must not duplicate Ĩ₀.
        for j in &out.new_cols {
            assert!(!ref2.selected.contains(j));
        }
        assert_eq!(out.selected.len(), 4);
    }

    #[test]
    fn tracer_records_compute() {
        let d = datasets::tiny(7);
        let pool: Vec<usize> = (0..d.a.ncols()).collect();
        let out = mlars(
            &d.a,
            &d.b,
            &vec![0.0; d.a.nrows()],
            &[],
            &pool,
            &Cholesky::empty(),
            4,
            1e-12,
        );
        let totals = out.tracer.totals();
        assert!(totals.flops > 0);
        assert!(out.tracer.total_time() > 0.0);
        assert_eq!(totals.msgs, 0, "mLARS itself must not communicate");
    }
}
