//! Procedure 1 — step-size computation for modified LARS (stepLARS).
//!
//! Inside T-bLARS a node runs LARS on columns that may violate the basic
//! LARS invariant: a not-yet-selected column `j` can have
//! `|c_j| > c_k` (larger absolute correlation than the current known
//! maximum). Equation (5) then may lack a non-negative solution. This
//! procedure reproduces the paper's case analysis exactly, returning a
//! γ ≥ 0 (γ = 0 signals "cannot step — force-add the violator").

use crate::linalg::select::min_positive2;

/// Outcome of the step-size computation for one candidate column.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepKind {
    /// Normal LARS crossing (eq. (5) has a positive solution).
    Crossing(f64),
    /// No crossing, but both curves decrease — step to the full
    /// least-squares point `γ = 1/h` (Procedure 1, step 12).
    FullStep(f64),
    /// Violation cannot be resolved: stepping would worsen it; γ = 0 and
    /// the violator must be force-added (Procedure 1, step 14).
    Blocked,
}

impl StepKind {
    /// The γ value this outcome steps by.
    pub fn gamma(self) -> f64 {
        match self {
            StepKind::Crossing(g) | StepKind::FullStep(g) => g,
            StepKind::Blocked => 0.0,
        }
    }
}

/// Procedure 1. Inputs are the scalars for one candidate column `j`:
/// current maximum correlation `ck` (over *selected* columns), the
/// direction normalizer `h`, and the column's correlation `cj = [c_k]_j`
/// and direction-correlation `aj = [a_k]_j`.
pub fn step_lars(ck: f64, h: f64, cj: f64, aj: f64) -> StepKind {
    debug_assert!(ck >= 0.0 && h > 0.0);
    let same_sign = cj * aj > 0.0;

    if ck >= cj.abs() {
        // ── No violation (Procedure 1, steps 2-7) ──
        if same_sign {
            // Step 4: at least one positive solution; take min⁺.
            let g1 = (ck - cj) / (ck * h - aj);
            let g2 = (ck + cj) / (ck * h + aj);
            match min_positive2(g1, g2) {
                Some(g) => StepKind::Crossing(g.min(1.0 / h)),
                // Degenerate (cj = ±ck with matching slope): no strictly
                // positive crossing before the LS point.
                None => StepKind::FullStep(1.0 / h),
            }
        } else {
            // Step 6: exactly one positive solution.
            let g = (ck - cj.abs()) / (ck * h + aj.abs());
            if g > 0.0 && g.is_finite() {
                StepKind::Crossing(g.min(1.0 / h))
            } else {
                // cj.abs() == ck boundary: the column is already level.
                StepKind::Crossing(0.0)
            }
        }
    } else {
        // ── Violation: |c_j| > c_k (Procedure 1, steps 8-15) ──
        if same_sign && cj.abs() * h <= aj.abs() {
            // Step 10: the violator's correlation falls fast enough that
            // the curves still cross at γ = (ck − |cj|)/(ck·h − |aj|) > 0.
            let g = (ck - cj.abs()) / (ck * h - aj.abs());
            if g > 0.0 && g.is_finite() {
                StepKind::Crossing(g.min(1.0 / h))
            } else {
                StepKind::Blocked
            }
        } else if same_sign {
            // Step 12: both decrease, no crossing — step to the maximum.
            StepKind::FullStep(1.0 / h)
        } else {
            // Step 14: |c_j − γ a_j| increases while c_k(1−γh) decreases;
            // any γ > 0 makes the violation worse.
            StepKind::Blocked
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_same_sign_crossing() {
        // ck=1, h=1, cj=0.5, aj=0.2: g1=(1-0.5)/(1-0.2)=0.625, g2=(1.5)/(1.2)=1.25
        match step_lars(1.0, 1.0, 0.5, 0.2) {
            StepKind::Crossing(g) => assert!((g - 0.625).abs() < 1e-12),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn normal_opposite_sign_single_root() {
        // cj=-0.5, aj=0.2 (opposite): γ = (1-0.5)/(1+0.2)
        match step_lars(1.0, 1.0, -0.5, 0.2) {
            StepKind::Crossing(g) => assert!((g - 0.5 / 1.2).abs() < 1e-12),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn crossing_verifies_equation() {
        // Check returned γ satisfies ck(1−γh) = |cj − γ·aj|.
        for (ck, h, cj, aj) in [
            (1.0, 0.7, 0.3, 0.5),
            (2.0, 0.4, -1.5, 0.9),
            (1.0, 1.0, 0.8, -0.6),
            (0.9, 1.2, -0.2, -0.4),
        ] {
            if let StepKind::Crossing(g) = step_lars(ck, h, cj, aj) {
                let lhs = ck * (1.0 - g * h);
                let rhs = (cj - g * aj).abs();
                assert!(
                    (lhs - rhs).abs() < 1e-9,
                    "γ={g} does not solve eq.(5): {lhs} vs {rhs} for {ck},{h},{cj},{aj}"
                );
            }
        }
    }

    #[test]
    fn violation_fast_decay_crosses() {
        // |cj|=1.5 > ck=1, same sign, |cj|·h=1.5·1 ≤ |aj|=2 ⇒ crossing at
        // (1−1.5)/(1−2) = 0.5.
        match step_lars(1.0, 1.0, 1.5, 2.0) {
            StepKind::Crossing(g) => assert!((g - 0.5).abs() < 1e-12),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn violation_slow_decay_full_step() {
        // |cj|=1.5 > ck=1, same sign, |cj|·h=1.5 > |aj|=0.5 ⇒ γ = 1/h.
        match step_lars(1.0, 2.0, 1.5, 0.5) {
            StepKind::FullStep(g) => assert!((g - 0.5).abs() < 1e-12),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn violation_opposite_sign_blocked() {
        // |cj| > ck with opposite signs: stepping increases |c_j − γa_j|.
        assert_eq!(step_lars(1.0, 1.0, 1.5, -0.3), StepKind::Blocked);
        assert_eq!(step_lars(1.0, 1.0, -1.5, 0.3), StepKind::Blocked);
    }

    #[test]
    fn gamma_never_negative_never_exceeds_full() {
        let mut rng = crate::rng::Pcg64::new(42);
        for _ in 0..10_000 {
            let ck = rng.uniform_range(1e-6, 2.0);
            let h = rng.uniform_range(1e-3, 3.0);
            let cj = rng.normal();
            let aj = rng.normal();
            let g = step_lars(ck, h, cj, aj).gamma();
            assert!(g >= 0.0, "negative γ for {ck},{h},{cj},{aj}");
            assert!(g <= 1.0 / h + 1e-12, "γ={g} exceeds 1/h for {ck},{h},{cj},{aj}");
            assert!(g.is_finite());
        }
    }

    #[test]
    fn zero_aj_handled() {
        // aj = 0: correlation of j is constant; crossing at (ck−|cj|)/(ck·h).
        match step_lars(1.0, 1.0, 0.5, 0.0) {
            StepKind::Crossing(g) => assert!((g - 0.5).abs() < 1e-12),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn level_column_steps_zero() {
        // |cj| == ck exactly: already level; γ = 0 crossing.
        let g = step_lars(1.0, 1.0, -1.0, 0.4).gamma();
        assert_eq!(g, 0.0);
    }
}
