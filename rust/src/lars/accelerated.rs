//! XLA-accelerated (b)LARS — the runtime bridge integrated into the
//! algorithm as a first-class feature.
//!
//! Single-node (b)LARS whose two hot products (Algorithm 2 steps 2/11:
//! `c = Aᵀr`, `a = Aᵀu`) execute through a [`CorrEngine`] — the AOT
//! Pallas/XLA artifact when one fits the matrix, the native f64 kernels
//! otherwise. Selection logic, Cholesky extension and γ computation are
//! shared with the rest of the crate.
//!
//! Numerics: the XLA path computes in f32 (DESIGN.md §7). Selections
//! can therefore differ from the f64 reference when correlations are
//! within f32 noise of each other; the parity test accepts either the
//! identical path or an equal-quality one (checked via the LS refit).

use super::{LarsOutput, StopReason};
use crate::linalg::select::{argmax_b_by, argmin_b_by, min_positive2};
use crate::linalg::{dot, norm2, Cholesky, Matrix};
use crate::runtime::CorrEngine;
use crate::error::Result;

/// Options (mirrors [`super::serial::LarsOptions`]).
#[derive(Clone, Debug)]
pub struct AccelOptions {
    pub t: usize,
    pub b: usize,
    pub tol: f64,
}

impl Default for AccelOptions {
    fn default() -> Self {
        AccelOptions { t: 10, b: 1, tol: 1e-9 }
    }
}

/// Run (b)LARS with the correlation products dispatched to `engine`.
///
/// `a` is still used for the small Gram blocks and the direction
/// application (`A_I w` touches only `|I|` columns — not worth a device
/// round-trip at these sizes).
pub fn blars_accelerated(
    a: &Matrix,
    b_vec: &[f64],
    engine: &CorrEngine,
    opts: &AccelOptions,
) -> Result<LarsOutput> {
    let m = a.nrows();
    let n = a.ncols();
    assert_eq!(engine.ncols(), n, "engine/matrix mismatch");
    assert_eq!(b_vec.len(), m);
    let t = opts.t.min(m.min(n));

    let mut y = vec![0.0; m];
    let mut r = b_vec.to_vec();
    let mut u = vec![0.0; m];
    let mut c = engine.corr(&r)?;

    let mut residual_norms = vec![norm2(&r)];
    let mut cols_at_iter = vec![0usize];
    let mut in_model = vec![false; n];
    let mut selected: Vec<usize> = Vec::new();

    // Initial block.
    let b0 = opts.b.min(t.max(1));
    let mut block = argmax_b_by(n, b0, |j| c[j].abs());
    block.sort_unstable();
    if block.iter().all(|&j| c[j].abs() <= opts.tol) {
        return Ok(LarsOutput {
            selected,
            residual_norms,
            cols_at_iter,
            y,
            stop: StopReason::Saturated,
        });
    }
    let mut chol = Cholesky::empty();
    admit_block(a, &block, &mut chol, &mut selected, &mut in_model);
    if selected.is_empty() {
        return Ok(LarsOutput {
            selected,
            residual_norms,
            cols_at_iter,
            y,
            stop: StopReason::RankDeficient,
        });
    }
    let mut ck = selected.iter().map(|&j| c[j].abs()).fold(f64::INFINITY, f64::min);

    let stop = loop {
        if selected.len() >= t {
            break StopReason::TargetReached;
        }
        if ck <= opts.tol {
            break StopReason::Saturated;
        }

        let s: Vec<f64> = selected.iter().map(|&j| c[j]).collect();
        let q = chol.solve(&s);
        let sq = dot(&s, &q);
        if !(sq.is_finite() && sq > 0.0) {
            break StopReason::Saturated;
        }
        let h = 1.0 / sq.sqrt();
        let w: Vec<f64> = q.iter().map(|qi| qi * h).collect();
        a.gemv_cols(&selected, &w, &mut u);

        // The offloaded hot product: a = Aᵀu.
        let av = engine.corr(&u)?;

        let gamma_full = 1.0 / h;
        let mut cand: Vec<(usize, f64)> = Vec::new();
        for j in 0..n {
            if in_model[j] {
                continue;
            }
            let g1 = (ck - c[j]) / (ck * h - av[j]);
            let g2 = (ck + c[j]) / (ck * h + av[j]);
            if let Some(g) = min_positive2(g1, g2) {
                if g <= gamma_full * (1.0 + 1e-9) {
                    cand.push((j, g));
                }
            }
        }
        let remaining = t - selected.len();
        let bsz = opts.b.min(remaining);
        let (gamma, new_block) = if cand.len() >= bsz && bsz > 0 {
            let picks = argmin_b_by(cand.len(), bsz, |i| cand[i].1);
            let gamma = picks.iter().map(|&i| cand[i].1).fold(0.0_f64, f64::max);
            let mut blk: Vec<usize> = picks.iter().map(|&i| cand[i].0).collect();
            blk.sort_unstable();
            (gamma, blk)
        } else {
            let mut blk: Vec<usize> = cand.iter().map(|&(j, _)| j).collect();
            blk.sort_unstable();
            (gamma_full, blk)
        };

        for i in 0..m {
            y[i] += gamma * u[i];
            r[i] = b_vec[i] - y[i];
        }
        // f32-path hygiene: refresh correlations from the residual rather
        // than compounding in-place updates (one engine call per
        // iteration either way — same cost, tighter error).
        c = engine.corr(&r)?;
        residual_norms.push(norm2(&r));

        let hit_full = new_block.is_empty() || gamma >= gamma_full * (1.0 - 1e-12);
        if !new_block.is_empty() {
            admit_block(a, &new_block, &mut chol, &mut selected, &mut in_model);
        }
        cols_at_iter.push(selected.len());
        ck = selected.iter().map(|&j| c[j].abs()).fold(f64::INFINITY, f64::min);
        if hit_full {
            break StopReason::Saturated;
        }
    };
    if *cols_at_iter.last().unwrap() != selected.len() {
        cols_at_iter.push(selected.len());
    }

    Ok(LarsOutput { selected, residual_norms, cols_at_iter, y, stop })
}

/// Admit a block column-by-column (graceful on duplicates, §5.2).
fn admit_block(
    a: &Matrix,
    block: &[usize],
    chol: &mut Cholesky,
    selected: &mut Vec<usize>,
    in_model: &mut [bool],
) {
    for &j in block {
        let gi = a.gram_block(selected, &[j]);
        let gjj = a.gram_block(&[j], &[j]).get(0, 0);
        let mut grow: Vec<f64> = (0..selected.len()).map(|i| gi.get(i, 0)).collect();
        grow.push(gjj);
        if chol.push_row(&grow).is_ok() {
            selected.push(j);
        }
        in_model[j] = true;
    }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // compares against the legacy serial shim

    use super::*;
    use crate::data::datasets;
    use crate::lars::serial::{blars_serial, LarsOptions};

    #[test]
    fn native_engine_matches_serial_reference() {
        for seed in [1u64, 2, 3] {
            let d = datasets::tiny_dense(seed);
            let engine = CorrEngine::native(&d.a);
            let acc = blars_accelerated(
                &d.a,
                &d.b,
                &engine,
                &AccelOptions { t: 10, b: 2, ..Default::default() },
            )
            .unwrap();
            let reference =
                blars_serial(&d.a, &d.b, &LarsOptions { t: 10, b: 2, ..Default::default() });
            assert_eq!(acc.selected, reference.selected, "seed {seed}");
        }
    }

    #[test]
    fn native_engine_b1_is_lars() {
        let d = datasets::tiny(4);
        let engine = CorrEngine::native(&d.a);
        let acc = blars_accelerated(&d.a, &d.b, &engine, &AccelOptions { t: 8, b: 1, ..Default::default() })
            .unwrap();
        let reference = crate::lars::serial::lars(
            &d.a,
            &d.b,
            &LarsOptions { t: 8, ..Default::default() },
        );
        assert_eq!(acc.selected, reference.selected);
    }

    #[test]
    fn residuals_decrease() {
        let d = datasets::tiny_dense(5);
        let engine = CorrEngine::native(&d.a);
        let acc = blars_accelerated(&d.a, &d.b, &engine, &AccelOptions { t: 12, b: 3, ..Default::default() })
            .unwrap();
        for w in acc.residual_norms.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
    }
}
