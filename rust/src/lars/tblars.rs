//! Algorithm 3 — Tournament block LARS (T-bLARS).
//!
//! Column-partitioned data: each rank owns `~n/P` columns. Per outer
//! iteration every leaf runs [mLARS](super::mlars) on its local columns
//! to nominate `b` candidates; winners battle pairwise up a binary
//! reduction tree (Figure 1); the root's mLARS output becomes the new
//! global state, which is then broadcast (selected columns, `y`, and
//! the Cholesky extension — Alg 3 step 12).
//!
//! Cost accounting follows §8.1/§10.2: leaf compute is parallel
//! (critical path = slowest leaf, fine-grained phases), the `log P`
//! tournament levels are *serial* — their compute is charged to the
//! `Wait` category exactly like the paper's wait-time estimate — and
//! each level exchanges `b·m` words of column data.
//!
//! Entry points: [`fit_observed`] is the fallible, observer-carrying
//! core the [`crate::fit`] estimator API dispatches to
//! (`Algorithm::TBlars`); the legacy free function [`tblars`] remains
//! as a thin deprecated shim that panics on invalid input the way its
//! `assert!`s used to.

use super::mlars::{mlars, MlarsOutput};
use super::{LarsOutput, StopReason};
use crate::cluster::topology::TournamentTree;
use crate::cluster::{ExecMode, Phase, SimCluster, Tracer};
use crate::error::{Error, Result};
use crate::fit::observers::{FitEvent, FitObserver, NoopObserver, ObserverControl};
use crate::linalg::{norm2, Cholesky, Matrix};

/// Options for a T-bLARS run.
#[derive(Clone, Debug)]
pub struct TblarsOptions {
    /// Target number of columns `t`.
    pub t: usize,
    /// Columns nominated per node per outer iteration.
    pub b: usize,
    /// Numerical floor.
    pub tol: f64,
}

impl Default for TblarsOptions {
    fn default() -> Self {
        TblarsOptions { t: 10, b: 1, tol: 1e-12 }
    }
}

/// Run T-bLARS with a given column `partition` (one column-index list
/// per rank; see [`crate::data::partition`] for the balanced and random
/// partitioners the paper's §10 uses).
#[deprecated(
    since = "0.4.0",
    note = "use calars::fit::FitSpec::new(Algorithm::TBlars { b, parts }) — this shim panics on invalid input"
)]
pub fn tblars(
    a: &Matrix,
    b_vec: &[f64],
    partition: &[Vec<usize>],
    opts: &TblarsOptions,
    cluster: &mut SimCluster,
) -> LarsOutput {
    fit_observed(a, b_vec, partition, opts, cluster, &mut NoopObserver)
        .expect("invalid T-bLARS input")
}

/// T-bLARS core: validated inputs (including the partition), per-outer-
/// iteration [`FitObserver`] events, typed errors instead of
/// `assert!`s. Events carry `NaN` for γ and λ — the tournament has no
/// scalar step size per outer iteration.
pub fn fit_observed(
    a: &Matrix,
    b_vec: &[f64],
    partition: &[Vec<usize>],
    opts: &TblarsOptions,
    cluster: &mut SimCluster,
    obs: &mut dyn FitObserver,
) -> Result<LarsOutput> {
    let m = a.nrows();
    let n = a.ncols();
    super::check_fit_inputs(a, b_vec, opts.tol)?;
    if opts.b < 1 {
        return Err(Error::invalid_spec("block size must be ≥ 1"));
    }
    let p = cluster.nranks();
    if partition.len() != p {
        return Err(Error::invalid_spec(format!(
            "partition has {} buckets for {p} ranks",
            partition.len()
        )));
    }
    for bucket in partition {
        for &j in bucket {
            if j >= n {
                return Err(Error::invalid_spec(format!(
                    "partition references column {j}, but the matrix has {n} columns"
                )));
            }
        }
    }
    if partition.iter().all(|bucket| bucket.is_empty()) {
        return Err(Error::invalid_spec(
            "partition is empty — no rank owns any candidate column",
        ));
    }
    let tree = TournamentTree::new(p);
    let t = opts.t.min(m.min(n));

    // ── Step 1-2: global state. ──
    let mut y = vec![0.0; m];
    let mut selected: Vec<usize> = Vec::new();
    let mut chol = Cholesky::empty();
    let mut residual_norms = vec![norm2(b_vec)];
    let mut cols_at_iter = vec![0usize];
    // Residual scratch reused across outer iterations (was a fresh
    // length-m allocation per round).
    let mut r_buf = vec![0.0; m];

    let mut iter = 0usize;
    let stop = loop {
        if selected.len() >= t {
            break StopReason::TargetReached;
        }
        let budget = opts.b.min(t - selected.len());

        // ── Leaves (Alg 3 steps 5-6): parallel mLARS per rank. Under
        // ExecMode::Threaded the per-rank solves fork onto the
        // calars::par pool (mLARS is deterministic, so leaf outputs —
        // and therefore the fit — are identical either way; only the
        // measured wallclock changes). ──
        let leaf_outs: Vec<MlarsOutput> = if cluster.mode() == ExecMode::Threaded {
            let tasks: Vec<_> = partition
                .iter()
                .map(|pool| {
                    let (y_ref, sel_ref, chol_ref) = (&y, &selected, &chol);
                    move || mlars(a, b_vec, y_ref, sel_ref, pool, chol_ref, budget, opts.tol)
                })
                .collect();
            crate::par::run_tasks(tasks)
        } else {
            partition
                .iter()
                .map(|pool| mlars(a, b_vec, &y, &selected, pool, &chol, budget, opts.tol))
                .collect()
        };
        let leaf_tracers: Vec<Tracer> = leaf_outs.iter().map(|o| o.tracer.clone()).collect();
        cluster.absorb(&Tracer::critical_path(&leaf_tracers));

        let mut cands: Vec<Vec<usize>> = leaf_outs.iter().map(|o| o.new_cols.clone()).collect();
        if cands.iter().all(|c| c.is_empty()) {
            break StopReason::PoolExhausted;
        }

        // ── Tournament levels (steps 7-9), serialized on the tree. ──
        let mut root_out: Option<MlarsOutput> = None;
        if p == 1 {
            // Single rank: the leaf IS the root.
            root_out = leaf_outs.into_iter().next();
        } else {
            for level in 1..=tree.levels() {
                let nodes = tree.nodes_at(level);
                // Each right child ships ≤b columns of length m to its
                // parent's host (plus indices; dominated by b·m).
                cluster.tree_level_exchange(Phase::TreeExchange, nodes, budget * m);

                let mut next: Vec<Vec<usize>> = Vec::with_capacity(nodes);
                let mut node_tracers: Vec<Tracer> = Vec::with_capacity(nodes);
                let is_root_level = level == tree.levels();
                for i in 0..nodes {
                    let (lc, rc) = tree.children(level, i);
                    let mut merged = cands[lc].clone();
                    merged.extend(cands[rc].iter().copied());
                    let out = mlars(a, b_vec, &y, &selected, &merged, &chol, budget, opts.tol);
                    node_tracers.push(out.tracer.clone());
                    next.push(out.new_cols.clone());
                    if is_root_level {
                        root_out = Some(out);
                    }
                }
                // Non-leaf competitions are serialized across levels: while
                // one node computes, the rest of the machine waits. Charge
                // the level's critical path to Wait (the paper's §10.2
                // estimate), keeping flop counters in their phases.
                let cp = Tracer::critical_path(&node_tracers);
                cluster.charge_wait(cp.total_time());
                cluster.absorb_counters(&cp);
                cands = next;
            }
        }

        // ── Root update + broadcast (steps 10-12). ──
        let root =
            root_out.ok_or_else(|| Error::internal("tournament produced no root output"))?;
        let new_count = root.new_cols.len();
        y = root.y;
        let k_prev = selected.len();
        selected = root.selected;
        chol = root.chol;

        // Broadcast: the chosen columns' data (b·m), the new response
        // (m), and the newly appended Cholesky rows (b·(k+b)).
        let l_words = new_count * (k_prev + new_count);
        cluster.broadcast(Phase::Bcast, new_count * m + m + l_words);

        for ((ri, bi), yi) in r_buf.iter_mut().zip(b_vec).zip(&y) {
            *ri = bi - yi;
        }
        let rnorm = norm2(&r_buf);
        residual_norms.push(rnorm);
        cols_at_iter.push(selected.len());

        let observer_stop = obs.on_iteration(&FitEvent {
            iter,
            selected: &selected,
            gamma: f64::NAN,
            residual_norm: rnorm,
            lambda: f64::NAN,
        }) == ObserverControl::Stop;
        iter += 1;

        if new_count == 0 {
            break StopReason::Saturated;
        }
        if observer_stop {
            break StopReason::EarlyStopped;
        }
    };

    Ok(LarsOutput { selected, residual_norms, cols_at_iter, y, stop })
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shims double as regression coverage

    use super::*;
    use crate::cluster::{ExecMode, HwParams};
    use crate::data::{datasets, partition};
    use crate::lars::serial::{lars, LarsOptions};

    fn run(p: usize, b: usize, t: usize, seed: u64) -> (LarsOutput, SimCluster) {
        let d = datasets::tiny(seed);
        let parts = partition::balanced_col_partition(&d.a, p);
        let mut cluster = SimCluster::new(p, HwParams::default(), ExecMode::Sequential);
        let out = tblars(
            &d.a,
            &d.b,
            &parts,
            &TblarsOptions { t, b, ..Default::default() },
            &mut cluster,
        );
        (out, cluster)
    }

    #[test]
    fn p1_matches_lars_selection() {
        // With P=1 and b=1, every outer iteration runs mLARS on the full
        // pool for one column — selection order must equal plain LARS.
        let d = datasets::tiny(1);
        let reference = lars(&d.a, &d.b, &LarsOptions { t: 10, ..Default::default() });
        let (out, _) = run(1, 1, 10, 1);
        assert_eq!(out.selected, reference.selected);
    }

    #[test]
    fn reaches_target_multirank() {
        for p in [2usize, 4, 8] {
            let (out, _) = run(p, 2, 12, 2);
            assert_eq!(out.selected.len(), 12, "P={p}");
            assert_eq!(out.stop, StopReason::TargetReached);
            // No duplicates.
            let mut s = out.selected.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 12);
        }
    }

    #[test]
    fn residuals_nonincreasing() {
        let (out, _) = run(4, 3, 15, 3);
        for w in out.residual_norms.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "{} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn quality_close_to_lars() {
        // §10.1: T-bLARS residuals are nearly identical to LARS.
        let d = datasets::tiny(4);
        let reference = lars(&d.a, &d.b, &LarsOptions { t: 15, ..Default::default() });
        let parts = partition::balanced_col_partition(&d.a, 4);
        let mut cluster = SimCluster::new(4, HwParams::default(), ExecMode::Sequential);
        let out = tblars(
            &d.a,
            &d.b,
            &parts,
            &TblarsOptions { t: 15, b: 3, ..Default::default() },
            &mut cluster,
        );
        let r_ref = *reference.residual_norms.last().unwrap();
        let r_tb = *out.residual_norms.last().unwrap();
        assert!(
            r_tb <= r_ref * 1.25 + 1e-9,
            "T-bLARS residual {r_tb} much worse than LARS {r_ref}"
        );
    }

    #[test]
    fn threaded_leaves_match_sequential_bitwise() {
        let d = datasets::tiny(9);
        let parts = partition::balanced_col_partition(&d.a, 4);
        let opts = TblarsOptions { t: 10, b: 2, ..Default::default() };
        let mut c1 = SimCluster::new(4, HwParams::default(), ExecMode::Sequential);
        let mut c2 = SimCluster::new(4, HwParams::default(), ExecMode::Threaded);
        let o1 = tblars(&d.a, &d.b, &parts, &opts, &mut c1);
        let o2 = tblars(&d.a, &d.b, &parts, &opts, &mut c2);
        assert_eq!(o1.selected, o2.selected);
        for (x, y) in o1.y.iter().zip(&o2.y) {
            assert_eq!(x.to_bits(), y.to_bits(), "pool execution changed the fit");
        }
    }

    #[test]
    fn wait_time_recorded_for_multirank() {
        let (_, cluster) = run(8, 2, 10, 5);
        let wait = cluster.tracer().get(Phase::Wait).time;
        assert!(wait > 0.0, "tournament must record wait time");
        let cats = cluster.tracer().by_category();
        assert!(cats[3] > 0.0);
    }

    #[test]
    fn tree_exchange_words_scale_with_m() {
        let (_, cluster) = run(4, 2, 8, 6);
        let te = cluster.tracer().get(Phase::TreeExchange);
        assert!(te.words > 0);
        assert!(te.msgs > 0);
    }

    #[test]
    fn messages_scale_inverse_b() {
        // Table 2: L = (t/b)·2·log P.
        let (_, c1) = run(8, 1, 24, 7);
        let (_, c3) = run(8, 3, 24, 7);
        let m1 = c1.counters().msgs as f64;
        let m3 = c3.counters().msgs as f64;
        assert!(m3 < m1 / 2.0, "b=3 should cut messages: b1={m1} b3={m3}");
    }

    #[test]
    fn respects_partition_locality_at_leaves() {
        // Every selected column must come from some rank's partition.
        let d = datasets::tiny(8);
        let parts = partition::balanced_col_partition(&d.a, 4);
        let mut cluster = SimCluster::new(4, HwParams::default(), ExecMode::Sequential);
        let out = tblars(
            &d.a,
            &d.b,
            &parts,
            &TblarsOptions { t: 9, b: 3, ..Default::default() },
            &mut cluster,
        );
        let all: Vec<usize> = parts.iter().flatten().copied().collect();
        for j in &out.selected {
            assert!(all.contains(j));
        }
    }

    #[test]
    fn fit_observed_rejects_bad_partitions_without_panicking() {
        use crate::error::ErrorKind;
        use crate::fit::observers::NoopObserver;
        let d = datasets::tiny(10);
        let opts = TblarsOptions::default();
        // Wrong bucket count.
        let mut cluster = SimCluster::new(4, HwParams::default(), ExecMode::Sequential);
        let bad_count = vec![vec![0usize]; 3];
        let err = fit_observed(&d.a, &d.b, &bad_count, &opts, &mut cluster, &mut NoopObserver)
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
        // Out-of-range column index.
        let mut cluster = SimCluster::new(2, HwParams::default(), ExecMode::Sequential);
        let bad_index = vec![vec![0usize], vec![d.a.ncols() + 5]];
        let err = fit_observed(&d.a, &d.b, &bad_index, &opts, &mut cluster, &mut NoopObserver)
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
    }
}
