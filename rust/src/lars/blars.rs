//! Algorithm 2 — Parallel bLARS for row-partitioned data.
//!
//! The data matrix and every length-`m` vector are partitioned across
//! `P` ranks; the master (rank 0) holds length-`n` state (`c`, `a`), the
//! selection, and the Cholesky factor. Every step below is numbered
//! after Algorithm 2 and charged to the simulated cluster with the
//! paper's communication pattern (reductions for Aᵀ-products and Gram
//! blocks, broadcasts for `w` and γ).
//!
//! Selection results are *identical* to the serial core in
//! [`super::serial`] (the paper: "for bLARS, how rows are partitioned
//! among processors does not affect the columns selected") — enforced
//! by tests.
//!
//! Entry points: [`fit_observed`] is the fallible, observer-carrying
//! core the [`crate::fit`] estimator API dispatches to
//! (`Algorithm::Blars`); the legacy free function [`blars`] remains as
//! a thin deprecated shim that panics on invalid input the way its
//! `assert!`s used to.

use super::{LarsOutput, StopReason};
use crate::cluster::{Phase, SimCluster};
use crate::data::partition::row_ranges;
use crate::error::{Error, Result};
use crate::fit::observers::{FitEvent, FitObserver, NoopObserver, ObserverControl};
use crate::linalg::select::{argmax_b_by, argmin_b_by};
use crate::linalg::{dot, Cholesky, DenseMatrix, Matrix};

/// Options for a parallel bLARS run.
#[derive(Clone, Debug)]
pub struct BlarsOptions {
    /// Target number of columns `t`.
    pub t: usize,
    /// Block size `b` (`b = 1` ⇒ parallel LARS, §7: "we use parallel
    /// bLARS with b = 1 as parallel LARS").
    pub b: usize,
    /// Numerical floor for the maximum correlation.
    pub tol: f64,
}

impl Default for BlarsOptions {
    fn default() -> Self {
        BlarsOptions { t: 10, b: 1, tol: 1e-12 }
    }
}

/// Per-rank state: the row shard and the local slices of m-vectors.
struct RankState {
    /// This rank's rows of A.
    a: Matrix,
    /// Local slice of the response b.
    b: Vec<f64>,
    /// Local slices of y, r, u.
    y: Vec<f64>,
    r: Vec<f64>,
    u: Vec<f64>,
}

/// Run parallel bLARS on `cluster`.
#[deprecated(
    since = "0.4.0",
    note = "use calars::fit::FitSpec::new(Algorithm::Blars { b }).ranks(p) — this shim panics on invalid input"
)]
pub fn blars(a: &Matrix, b_vec: &[f64], opts: &BlarsOptions, cluster: &mut SimCluster) -> LarsOutput {
    fit_observed(a, b_vec, opts, cluster, &mut NoopObserver).expect("invalid bLARS input")
}

/// Parallel bLARS core: validated inputs, per-iteration
/// [`FitObserver`] events, typed errors instead of `assert!`s. The
/// matrix is row-sharded here (Alg 2's standing assumption); all cost
/// accounting lands in the cluster's tracer/clock.
pub fn fit_observed(
    a: &Matrix,
    b_vec: &[f64],
    opts: &BlarsOptions,
    cluster: &mut SimCluster,
    obs: &mut dyn FitObserver,
) -> Result<LarsOutput> {
    let m = a.nrows();
    let n = a.ncols();
    super::check_fit_inputs(a, b_vec, opts.tol)?;
    if opts.b < 1 {
        return Err(Error::invalid_spec("block size must be ≥ 1"));
    }
    let t = opts.t.min(m.min(n));
    let p = cluster.nranks();

    // ── Step 1: shard + initialize in parallel, no communication. ──
    let ranges = row_ranges(m, p);
    let mut ranks: Vec<RankState> = ranges
        .iter()
        .map(|&(r0, r1)| {
            let rows = r1 - r0;
            RankState {
                a: a.row_slice(r0, r1),
                b: b_vec[r0..r1].to_vec(),
                y: vec![0.0; rows],
                r: vec![0.0; rows],
                u: vec![0.0; rows],
            }
        })
        .collect();
    let init_flops: u64 = m as u64 / p.max(1) as u64;
    cluster.charge_flops(Phase::Init, init_flops);
    cluster.superstep(Phase::Init, &mut ranks, |_, st| {
        st.r.copy_from_slice(&st.b);
    });

    // ── Step 2: c = Aᵀr, local products + tree reduction to master. ──
    let at_r_flops: u64 = ranks.iter().map(|st| st.a.at_r_flops()).max().unwrap_or(0);
    cluster.charge_flops(Phase::Corr, at_r_flops);
    let contribs = cluster.superstep(Phase::Corr, &mut ranks, |_, st| {
        let mut c = vec![0.0; n];
        st.a.at_r(&st.r, &mut c);
        c
    });
    let mut c = cluster.reduce_sum(Phase::Reduce, contribs);

    // ── Step 3: master selects the initial block (introselect, O(n)). ──
    cluster.charge_flops(Phase::Select, n as u64);
    let b0 = opts.b.min(t.max(1));
    let mut selected = cluster.master(Phase::Select, || {
        let mut blk = argmax_b_by(n, b0, |j| c[j].abs());
        blk.sort_unstable();
        blk
    });
    let mut in_model = vec![false; n];
    for &j in &selected {
        in_model[j] = true;
    }
    let mut residual_norms = vec![crate::linalg::norm2(b_vec)];
    let mut cols_at_iter = vec![0usize];
    if selected.iter().all(|&j| c[j].abs() <= opts.tol) {
        return Ok(LarsOutput {
            selected: Vec::new(),
            residual_norms,
            cols_at_iter,
            y: vec![0.0; m],
            stop: StopReason::Saturated,
        });
    }

    // ── Step 4: G = A_Iᵀ A_I via local Gram blocks + reduction. ──
    let gram_flops = ranks.iter().map(|st| st.a.gram_block_flops(&selected, &selected)).max().unwrap_or(0);
    cluster.charge_flops(Phase::Gram, gram_flops);
    let gram_contribs = cluster.superstep(Phase::Gram, &mut ranks, |_, st| {
        st.a.gram_block(&selected, &selected).data().to_vec()
    });
    let g0 = cluster.reduce_sum(Phase::Reduce, gram_contribs);
    let block0 = std::mem::take(&mut selected);
    let g0 = DenseMatrix::from_vec(block0.len(), block0.len(), g0);

    // ── Step 5: Cholesky on the master via the chunked panel update;
    // duplicates inside the initial block are excluded, not fatal
    // (in_model[j] is already true for the whole block, set above). ──
    cluster.charge_flops(Phase::Cholesky, (b0 as u64).pow(3));
    let mut chol = Cholesky::empty();
    let mut rank_excluded = 0usize;
    cluster.master(Phase::Cholesky, || {
        let admitted = chol.append_block_graceful(&DenseMatrix::zeros(0, block0.len()), &g0);
        rank_excluded += block0.len() - admitted.len();
        for &row in &admitted {
            selected.push(block0[row]);
        }
    });
    if selected.is_empty() {
        return Ok(LarsOutput {
            selected,
            residual_norms,
            cols_at_iter,
            y: vec![0.0; m],
            stop: StopReason::RankDeficient,
        });
    }

    let mut ck = selected.iter().map(|&j| c[j].abs()).fold(f64::INFINITY, f64::min);
    let mut av = vec![0.0; n];

    // Event 0: the initial block is in the model.
    let initial_stop = obs.on_iteration(&FitEvent {
        iter: 0,
        selected: &selected,
        gamma: 0.0,
        residual_norm: residual_norms[0],
        lambda: ck,
    });
    if initial_stop == ObserverControl::Stop {
        cols_at_iter.push(selected.len());
        return Ok(LarsOutput {
            selected,
            residual_norms,
            cols_at_iter,
            y: vec![0.0; m],
            stop: StopReason::EarlyStopped,
        });
    }

    // ── Main loop (steps 6-25). ──
    // Master-side scratch reused across iterations (s/q reallocation
    // per step is pure overhead; w stays per-iteration because the
    // broadcast closures borrow it until the step ends).
    let mut s_buf: Vec<f64> = Vec::with_capacity(t);
    let mut q_buf: Vec<f64> = Vec::with_capacity(t);
    let mut iter = 0usize;
    let stop = loop {
        if selected.len() >= t {
            break StopReason::TargetReached;
        }
        if ck <= opts.tol {
            break StopReason::Saturated;
        }
        let k = selected.len();

        // Steps 7-8 (master): s, q = (LLᵀ)⁻¹s, h, w.
        cluster.charge_flops(Phase::Solve, (k * k) as u64 + 2 * k as u64);
        let (h, w) = {
            s_buf.clear();
            s_buf.extend(selected.iter().map(|&j| c[j]));
            let s = &s_buf;
            let q = &mut q_buf;
            let out = cluster.master(Phase::Solve, || {
                chol.solve_into(s, q);
                let sq = dot(s, q);
                if !(sq.is_finite() && sq > 0.0) {
                    return None;
                }
                let h = 1.0 / sq.sqrt();
                let w: Vec<f64> = q.iter().map(|qi| qi * h).collect();
                Some((h, w))
            });
            match out {
                Some(hw) => hw,
                // sᵀG⁻¹s ≤ 0 with s ≠ 0: numerically indefinite factor.
                None => break StopReason::RankDeficient,
            }
        };

        // Step 9: broadcast w (|I| words).
        cluster.broadcast(Phase::Bcast, w.len());

        // Step 10: u = A_I w in parallel, no communication.
        let dir_flops = ranks.iter().map(|st| st.a.gemv_cols_flops(&selected)).max().unwrap_or(0);
        cluster.charge_flops(Phase::DirApply, dir_flops);
        cluster.superstep(Phase::DirApply, &mut ranks, |_, st| {
            st.a.gemv_cols(&selected, &w, &mut st.u);
        });

        // Step 11: a = Aᵀu, local products + reduction.
        cluster.charge_flops(Phase::Corr, at_r_flops);
        let a_contribs = cluster.superstep(Phase::Corr, &mut ranks, |_, st| {
            let mut av_loc = vec![0.0; n];
            st.a.at_r(&st.u, &mut av_loc);
            av_loc
        });
        av = cluster.reduce_sum(Phase::Reduce, a_contribs);

        // Step 12 (master): γ_j candidates over the complement, chunked
        // on the pool (order and bits match the serial scan).
        cluster.charge_flops(Phase::GammaStep, (n - k) as u64 * 6);
        let gamma_full = 1.0 / h;
        let cand = cluster.master(Phase::GammaStep, || {
            super::serial::gamma_candidates(n, &in_model, &c, &av, ck, h, gamma_full)
        });

        // Steps 13-14 (master): b-th smallest γ + the b entering indices.
        let remaining = t - k;
        let bsz = opts.b.min(remaining);
        cluster.charge_flops(Phase::Select, cand.len() as u64);
        let (gamma, new_block) = cluster.master(Phase::Select, || {
            if cand.len() >= bsz && bsz > 0 {
                let picks = argmin_b_by(cand.len(), bsz, |i| cand[i].1);
                let gamma = picks.iter().map(|&i| cand[i].1).fold(0.0_f64, f64::max);
                let mut blk: Vec<usize> = picks.iter().map(|&i| cand[i].0).collect();
                blk.sort_unstable();
                (gamma, blk)
            } else {
                let mut blk: Vec<usize> = cand.iter().map(|&(j, _)| j).collect();
                blk.sort_unstable();
                (gamma_full, blk)
            }
        });

        // Steps 15-16: broadcast γ (1 word).
        cluster.broadcast(Phase::Bcast, 1);

        // Step 17: y ← y + γu, r = b − y in parallel, no communication.
        cluster.charge_flops(Phase::Update, 2 * (m / p) as u64);
        let local_sq = cluster.superstep(Phase::Update, &mut ranks, |_, st| {
            let mut sq = 0.0;
            for i in 0..st.y.len() {
                st.y[i] += gamma * st.u[i];
                st.r[i] = st.b[i] - st.y[i];
                sq += st.r[i] * st.r[i];
            }
            sq
        });
        // Quality instrumentation (not part of the algorithm's comm):
        // audit: allow(DET-SUM) -- serial combine of per-rank partials in ascending rank order: fixed order regardless of CALARS_THREADS
        let rnorm = local_sq.iter().sum::<f64>().sqrt();
        residual_norms.push(rnorm);

        // Steps 18-19 (master): in-place correlation updates.
        cluster.charge_flops(Phase::Update, n as u64);
        let shrink = 1.0 - gamma * h;
        cluster.master(Phase::Update, || {
            for j in 0..n {
                if in_model[j] {
                    c[j] *= shrink;
                } else {
                    c[j] -= gamma * av[j];
                }
            }
        });
        ck *= shrink;

        let hit_full_step = new_block.is_empty() || gamma >= gamma_full * (1.0 - 1e-12);

        if !new_block.is_empty() {
            // Step 20: A_Iᵀ A_B and A_Bᵀ A_B via local products + reduction.
            let gb_flops = ranks
                .iter()
                .map(|st| {
                    st.a.gram_block_flops(&selected, &new_block)
                        + st.a.gram_block_flops(&new_block, &new_block)
                })
                .max()
                .unwrap_or(0);
            cluster.charge_flops(Phase::Gram, gb_flops);
            let blk = new_block.clone();
            let sel = selected.clone();
            let packed = cluster.superstep(Phase::Gram, &mut ranks, |_, st| {
                let gib = st.a.gram_block(&sel, &blk);
                let gbb = st.a.gram_block(&blk, &blk);
                let mut v = gib.data().to_vec();
                v.extend_from_slice(gbb.data());
                v
            });
            let combined = cluster.reduce_sum(Phase::Reduce, packed);
            let (gib_flat, gbb_flat) = combined.split_at(k * new_block.len());
            let gib = DenseMatrix::from_vec(k, new_block.len(), gib_flat.to_vec());
            let gbb =
                DenseMatrix::from_vec(new_block.len(), new_block.len(), gbb_flat.to_vec());

            // Steps 21-23 (master): extend the Cholesky factor through
            // the chunked panel update (parallel forward solves, bit-
            // identical to sequential push_rows); a (near-)duplicate is
            // permanently excluded from the model rather than aborting
            // (§5.2, via append_block_graceful) — no extra
            // communication: both Gram blocks are already here.
            cluster.charge_flops(
                Phase::Cholesky,
                (new_block.len() * k * k + new_block.len().pow(3)) as u64,
            );
            cluster.master(Phase::Cholesky, || {
                let admitted = chol.append_block_graceful(&gib, &gbb);
                rank_excluded += new_block.len() - admitted.len();
                for &row in &admitted {
                    selected.push(new_block[row]);
                }
                for &j in &new_block {
                    in_model[j] = true;
                }
            });
            ck = selected.iter().map(|&j| c[j].abs()).fold(f64::INFINITY, f64::min).max(ck);
        }
        cols_at_iter.push(selected.len());

        iter += 1;
        let observer_stop = obs.on_iteration(&FitEvent {
            iter,
            selected: &selected,
            gamma,
            residual_norm: rnorm,
            lambda: ck,
        }) == ObserverControl::Stop;

        if hit_full_step {
            // Attribute the shortfall honestly: RankDeficient only when
            // the excluded duplicates are what stand between the
            // selection and the target (with them the target was
            // reachable); a saturation the exclusions cannot explain
            // stays Saturated.
            let reason = if rank_excluded > 0
                && selected.len() < t
                && selected.len() + rank_excluded >= t
            {
                StopReason::RankDeficient
            } else {
                StopReason::Saturated
            };
            break reason;
        }
        if observer_stop {
            break StopReason::EarlyStopped;
        }
    };
    if cols_at_iter.last().copied() != Some(selected.len()) {
        cols_at_iter.push(selected.len());
    }

    // Gather y (outside the algorithm's cost accounting — the paper's
    // algorithms return the distributed y as-is).
    let mut y = vec![0.0; m];
    for (st, &(r0, _)) in ranks.iter().zip(&ranges) {
        y[r0..r0 + st.y.len()].copy_from_slice(&st.y);
    }

    Ok(LarsOutput { selected, residual_norms, cols_at_iter, y, stop })
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shims double as regression coverage

    use super::*;
    use crate::cluster::{ExecMode, HwParams};
    use crate::data::datasets;
    use crate::lars::serial::{blars_serial, LarsOptions};

    fn run(p: usize, b: usize, t: usize, seed: u64) -> (LarsOutput, SimCluster) {
        let d = datasets::tiny(seed);
        let mut cluster = SimCluster::new(p, HwParams::default(), ExecMode::Sequential);
        let out = blars(
            &d.a,
            &d.b,
            &BlarsOptions { t, b, ..Default::default() },
            &mut cluster,
        );
        (out, cluster)
    }

    #[test]
    fn matches_serial_reference_p1() {
        let d = datasets::tiny(1);
        let serial = blars_serial(&d.a, &d.b, &LarsOptions { t: 12, b: 3, ..Default::default() });
        let (par, _) = run(1, 3, 12, 1);
        assert_eq!(par.selected, serial.selected);
    }

    #[test]
    fn row_partition_does_not_change_selection() {
        // §10.1: "how rows are partitioned among processors does not
        // affect the columns selected".
        let (p1, _) = run(1, 2, 10, 2);
        for p in [2usize, 4, 8] {
            let (pp, _) = run(p, 2, 10, 2);
            assert_eq!(pp.selected, p1.selected, "P={p} changed selection");
        }
    }

    #[test]
    fn threaded_mode_matches_sequential() {
        let d = datasets::tiny(3);
        let opts = BlarsOptions { t: 10, b: 2, ..Default::default() };
        let mut c1 = SimCluster::new(4, HwParams::default(), ExecMode::Sequential);
        let mut c2 = SimCluster::new(4, HwParams::default(), ExecMode::Threaded);
        let o1 = blars(&d.a, &d.b, &opts, &mut c1);
        let o2 = blars(&d.a, &d.b, &opts, &mut c2);
        assert_eq!(o1.selected, o2.selected);
    }

    #[test]
    fn communication_counted() {
        let (_, cluster) = run(4, 2, 10, 4);
        let c = cluster.counters();
        assert!(c.msgs > 0, "no messages counted");
        assert!(c.words > 0);
        assert!(c.flops > 0);
        assert!(cluster.sim_time() > 0.0);
    }

    #[test]
    fn larger_b_reduces_messages() {
        // Table 2: messages scale as (t/b)·log P.
        let (_, c1) = run(8, 1, 24, 5);
        let (_, c4) = run(8, 4, 24, 5);
        let m1 = c1.counters().msgs as f64;
        let m4 = c4.counters().msgs as f64;
        assert!(
            m4 < m1 / 2.0,
            "b=4 should cut messages ~4x: b1={m1} b4={m4}"
        );
    }

    #[test]
    fn residuals_decrease() {
        let (out, _) = run(4, 3, 15, 6);
        for w in out.residual_norms.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
    }

    #[test]
    fn reaches_target() {
        let (out, _) = run(2, 5, 20, 7);
        assert_eq!(out.selected.len(), 20);
        assert_eq!(out.stop, StopReason::TargetReached);
    }

    #[test]
    fn fit_observed_rejects_bad_inputs_without_panicking() {
        use crate::error::ErrorKind;
        use crate::fit::observers::NoopObserver;
        let d = datasets::tiny(8);
        let mut cluster = SimCluster::new(2, HwParams::default(), ExecMode::Sequential);
        let short = vec![0.0; d.a.nrows() - 1];
        let err = fit_observed(
            &d.a,
            &short,
            &BlarsOptions::default(),
            &mut cluster,
            &mut NoopObserver,
        )
        .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
    }
}
