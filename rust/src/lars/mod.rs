//! The LARS algorithm family (the paper's core contribution).
//!
//! * [`serial`] — Algorithm 1 / serial bLARS semantics (the reference
//!   implementation everything else is compared against);
//! * [`blars`] — Algorithm 2, parallel block LARS on row-partitioned
//!   data over the simulated cluster;
//! * [`steplars`] — Procedure 1, the guarded step-size computation;
//! * [`mlars`] — Algorithm 4, modified LARS on a column subset;
//! * [`tblars`] — Algorithm 3, tournament bLARS on column-partitioned
//!   data;
//! * [`lasso_lars`] — LARS with the LASSO modification (§2 / Efron
//!   Theorem 1: the exact ℓ1-regularization path);
//! * [`path`] — coefficient recovery along the selection path;
//! * [`quality`] — the paper's §10.1 quality metrics.

pub mod accelerated;
pub mod blars;
pub mod lasso_lars;
pub mod mlars;
pub mod path;
pub mod quality;
pub mod serial;
pub mod steplars;
pub mod tblars;

/// Shared input validation for every fitter core (`fit_observed`):
/// the response length must match the matrix row count and the
/// numerical floor must be finite. Kept in one place so the six cores
/// cannot drift; per-algorithm checks (block size, partitions, λ
/// floor) stay with their cores.
pub(crate) fn check_fit_inputs(
    a: &crate::linalg::Matrix,
    b_vec: &[f64],
    tol: f64,
) -> crate::error::Result<()> {
    if b_vec.len() != a.nrows() {
        return Err(crate::error::Error::invalid_spec(format!(
            "response length {} does not match the matrix row count {}",
            b_vec.len(),
            a.nrows()
        )));
    }
    if !tol.is_finite() {
        return Err(crate::error::Error::invalid_spec(format!(
            "tol must be finite (got {tol})"
        )));
    }
    Ok(())
}

/// Why a run stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Reached the target number of columns `t`.
    TargetReached,
    /// No candidate columns left.
    PoolExhausted,
    /// Residual (correlation) numerically zero — the model is saturated.
    Saturated,
    /// Gram matrix lost positive definiteness (near-duplicate columns).
    RankDeficient,
    /// A [`crate::fit::FitObserver`] asked the fit to stop early.
    EarlyStopped,
}

impl StopReason {
    /// Stable lower-case identifier (wire formats, `/models` JSON,
    /// registry metadata). Inverse of [`Self::from_word`].
    pub fn word(self) -> &'static str {
        match self {
            StopReason::TargetReached => "target_reached",
            StopReason::PoolExhausted => "pool_exhausted",
            StopReason::Saturated => "saturated",
            StopReason::RankDeficient => "rank_deficient",
            StopReason::EarlyStopped => "early_stopped",
        }
    }

    /// Parse a [`Self::word`] identifier back.
    pub fn from_word(s: &str) -> Option<StopReason> {
        match s {
            "target_reached" => Some(StopReason::TargetReached),
            "pool_exhausted" => Some(StopReason::PoolExhausted),
            "saturated" => Some(StopReason::Saturated),
            "rank_deficient" => Some(StopReason::RankDeficient),
            "early_stopped" => Some(StopReason::EarlyStopped),
            _ => None,
        }
    }
}

/// Common output of all LARS-family runs.
#[derive(Clone, Debug)]
pub struct LarsOutput {
    /// Selected column indices, in selection order.
    pub selected: Vec<usize>,
    /// ℓ2 norm of the residual after 0, 1, 2, … iterations
    /// (index 0 = ‖b‖; Figure 3's y-axis).
    pub residual_norms: Vec<f64>,
    /// Number of columns selected after each iteration (Figure 3's
    /// x-axis; for bLARS this advances by `b` per entry).
    pub cols_at_iter: Vec<usize>,
    /// Final response estimate `y` (length m).
    pub y: Vec<f64>,
    /// Why the run stopped.
    pub stop: StopReason,
}

impl LarsOutput {
    /// Selected set as a sorted vector (for set comparisons).
    pub fn selected_sorted(&self) -> Vec<usize> {
        let mut s = self.selected.clone();
        s.sort_unstable();
        s
    }
}
