//! Coefficient recovery along the selection path.
//!
//! LARS-family outputs are (selection order, response estimates); for
//! downstream use (examples, baselines comparison) we recover the
//! least-squares coefficients restricted to each prefix of the path —
//! the paper's §2 note that after k iterations one solves the smaller
//! ordinary regression problem on the selected columns.

use crate::lars::lasso_lars::LassoPath;
use crate::linalg::{norm2, norm_inf, Cholesky, Matrix};

/// Least-squares coefficients of `b ≈ A[:, support] x`:
/// `x = (A_Sᵀ A_S)⁻¹ A_Sᵀ b`.
pub fn ls_coefficients(a: &Matrix, support: &[usize], b: &[f64]) -> Option<Vec<f64>> {
    if support.is_empty() {
        return Some(Vec::new());
    }
    let g = a.gram_block(support, support);
    let chol = Cholesky::factor(&g).ok()?;
    let atb: Vec<f64> = support.iter().map(|&j| a.col_dot(j, b)).collect();
    Some(chol.solve(&atb))
}

/// Dense coefficient vector (length n) from a sparse support solution.
pub fn densify(n: usize, support: &[usize], coefs: &[f64]) -> Vec<f64> {
    assert_eq!(support.len(), coefs.len());
    let mut x = vec![0.0; n];
    for (&j, &v) in support.iter().zip(coefs) {
        x[j] = v;
    }
    x
}

/// Residual ‖A x − b‖₂ for a support/coefficient pair.
pub fn residual_norm(a: &Matrix, support: &[usize], coefs: &[f64], b: &[f64]) -> f64 {
    let mut ax = vec![0.0; a.nrows()];
    a.gemv_cols(support, coefs, &mut ax);
    // audit: allow(DET-SUM) -- serial left-to-right iterator sum: one fixed order by construction, kept as-is so recorded residual norms never change bits
    ax.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum::<f64>().sqrt()
}

/// The full solution path: LS coefficients for every prefix
/// `selected[..1], selected[..2], …` (the sequence of linear models the
/// paper's abstract highlights). Returns one (support, coefs) per step.
pub fn solution_path(
    a: &Matrix,
    selected: &[usize],
    b: &[f64],
) -> Vec<(Vec<usize>, Vec<f64>)> {
    let mut out = Vec::with_capacity(selected.len());
    for k in 1..=selected.len() {
        let support = selected[..k].to_vec();
        if let Some(coefs) = ls_coefficients(a, &support, b) {
            out.push((support, coefs));
        }
    }
    out
}

// ── Path snapshots (the serving layer's storage unit) ───────────────
//
// A fit is consumed as a *sequence of models* (the paper's abstract:
// LARS "generates a sequence of linear models"); the serving subsystem
// stores that sequence once and answers model-selection queries against
// it forever after. `PathSnapshot` is the compact, self-contained form:
// per step the active set, its LS coefficients, the regularization
// level λ (max absolute residual correlation) and the residual norm.

/// One stored breakpoint of a fitted path.
#[derive(Clone, Debug, PartialEq)]
pub struct PathStep {
    /// Regularization level: ‖Aᵀ(b − Ax)‖∞ at this step's solution.
    pub lambda: f64,
    /// Active columns, in selection order.
    pub support: Vec<usize>,
    /// Coefficients aligned with `support`.
    pub coefs: Vec<f64>,
    /// ‖b − Ax‖₂ at this step.
    pub residual_norm: f64,
}

/// A compact snapshot of an entire fitted regularization path.
///
/// `steps[0]` is always the empty model at λ_max = ‖Aᵀb‖∞; `lambda` is
/// non-increasing along `steps`, which makes piecewise-linear
/// interpolation in λ well defined (between breakpoints the LASSO path
/// is exactly linear in λ; for plain LARS/bLARS selection prefixes it
/// is the standard linear-in-λ approximation between stored models).
#[derive(Clone, Debug, PartialEq)]
pub struct PathSnapshot {
    /// Feature dimension (query vectors must have this length).
    pub n: usize,
    /// Breakpoints, λ non-increasing.
    pub steps: Vec<PathStep>,
}

impl PathSnapshot {
    /// Snapshot a LARS-family fit: LS coefficients for every prefix of
    /// the selection order (the paper's §2 note), λ from the residual
    /// correlations. Prefixes whose Gram block is numerically rank
    /// deficient are skipped.
    pub fn from_fit(a: &Matrix, b: &[f64], selected: &[usize]) -> Self {
        let m = a.nrows();
        let n = a.ncols();
        assert_eq!(b.len(), m);
        let mut c = vec![0.0; n];
        a.at_r(b, &mut c);
        let mut prev_lambda = norm_inf(&c);
        let mut steps = vec![PathStep {
            lambda: prev_lambda,
            support: Vec::new(),
            coefs: Vec::new(),
            residual_norm: norm2(b),
        }];
        let mut ax = vec![0.0; m];
        for k in 1..=selected.len() {
            let support = selected[..k].to_vec();
            let Some(coefs) = ls_coefficients(a, &support, b) else { continue };
            a.gemv_cols(&support, &coefs, &mut ax);
            let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, yi)| bi - yi).collect();
            a.at_r(&r, &mut c);
            // Enforce monotonicity so λ-interpolation stays well defined
            // even when a prefix LS solution is slightly out of order.
            let lambda = norm_inf(&c).min(prev_lambda);
            prev_lambda = lambda;
            steps.push(PathStep { lambda, support, coefs, residual_norm: norm2(&r) });
        }
        PathSnapshot { n, steps }
    }

    /// Snapshot an exact LASSO path (λ breakpoints are the path's own).
    pub fn from_lasso(n: usize, path: &LassoPath) -> Self {
        let steps = path
            .breakpoints
            .iter()
            .map(|bp| PathStep {
                lambda: bp.lambda,
                support: bp.support.clone(),
                coefs: bp.support.iter().map(|&j| bp.x[j]).collect(),
                residual_norm: bp.residual_norm,
            })
            .collect();
        PathSnapshot { n, steps }
    }

    /// Number of stored breakpoints (including the empty step 0).
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Largest model size stored (columns active at the final step).
    pub fn max_support(&self) -> usize {
        self.steps.iter().map(|s| s.support.len()).max().unwrap_or(0)
    }

    /// λ range covered: `(lambda_max, lambda_min)`.
    pub fn lambda_range(&self) -> (f64, f64) {
        let hi = self.steps.first().map_or(0.0, |s| s.lambda);
        let lo = self.steps.last().map_or(0.0, |s| s.lambda);
        (hi, lo)
    }

    /// Dense length-`n` coefficient vector at breakpoint `step`.
    pub fn dense_coefs(&self, step: usize) -> Option<Vec<f64>> {
        let s = self.steps.get(step)?;
        Some(densify(self.n, &s.support, &s.coefs))
    }

    /// Approximate in-memory footprint in bytes (registry accounting).
    pub fn approx_bytes(&self) -> usize {
        let per_step: usize = self
            .steps
            .iter()
            .map(|s| 16 + s.support.len() * 8 + s.coefs.len() * 8)
            .sum();
        std::mem::size_of::<Self>() + per_step
    }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // exercises the legacy shims

    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::lars::serial::{lars, LarsOptions};

    #[test]
    fn exact_recovery_noiseless() {
        let s = generate(
            &SyntheticSpec { m: 60, n: 30, density: 1.0, col_skew: 0.0, k_true: 4, noise: 0.0 },
            1,
        );
        let out = lars(&s.a, &s.b, &LarsOptions { t: 4, ..Default::default() });
        let coefs = ls_coefficients(&s.a, &out.selected, &s.b).unwrap();
        let rn = residual_norm(&s.a, &out.selected, &coefs, &s.b);
        assert!(rn < 1e-8, "residual {rn}");
    }

    #[test]
    fn path_residuals_decrease() {
        let s = generate(
            &SyntheticSpec { m: 80, n: 50, density: 1.0, col_skew: 0.0, k_true: 8, noise: 0.05 },
            2,
        );
        let out = lars(&s.a, &s.b, &LarsOptions { t: 10, ..Default::default() });
        let path = solution_path(&s.a, &out.selected, &s.b);
        let mut prev = f64::INFINITY;
        for (support, coefs) in &path {
            let rn = residual_norm(&s.a, support, coefs, &s.b);
            assert!(rn <= prev + 1e-9, "LS residual must shrink along the path");
            prev = rn;
        }
        assert_eq!(path.len(), 10);
    }

    #[test]
    fn densify_places_coefs() {
        let x = densify(5, &[1, 3], &[2.0, -1.0]);
        assert_eq!(x, vec![0.0, 2.0, 0.0, -1.0, 0.0]);
    }

    #[test]
    fn snapshot_covers_every_prefix_and_lambda_decreases() {
        let s = generate(
            &SyntheticSpec { m: 80, n: 50, density: 1.0, col_skew: 0.0, k_true: 8, noise: 0.05 },
            21,
        );
        let out = lars(&s.a, &s.b, &LarsOptions { t: 10, ..Default::default() });
        let snap = PathSnapshot::from_fit(&s.a, &s.b, &out.selected);
        assert_eq!(snap.len(), 11); // empty step + 10 prefixes
        assert_eq!(snap.n, 50);
        assert!(snap.steps[0].support.is_empty());
        for (k, st) in snap.steps.iter().enumerate() {
            assert_eq!(st.support.len(), k);
            assert_eq!(st.support, out.selected[..k]);
        }
        for w in snap.steps.windows(2) {
            assert!(w[1].lambda <= w[0].lambda);
            assert!(w[1].residual_norm <= w[0].residual_norm + 1e-9);
        }
    }

    #[test]
    fn snapshot_coefs_match_direct_ls() {
        let s = generate(
            &SyntheticSpec { m: 60, n: 30, density: 1.0, col_skew: 0.0, k_true: 5, noise: 0.0 },
            22,
        );
        let out = lars(&s.a, &s.b, &LarsOptions { t: 6, ..Default::default() });
        let snap = PathSnapshot::from_fit(&s.a, &s.b, &out.selected);
        for k in 1..=6usize {
            let direct = ls_coefficients(&s.a, &out.selected[..k], &s.b).unwrap();
            assert_eq!(snap.steps[k].coefs, direct, "prefix {k} must be bit-identical");
        }
    }

    #[test]
    fn snapshot_from_lasso_preserves_breakpoints() {
        use crate::lars::lasso_lars::lasso_path;
        let s = generate(
            &SyntheticSpec { m: 80, n: 40, density: 1.0, col_skew: 0.0, k_true: 6, noise: 0.05 },
            23,
        );
        let lp = lasso_path(&s.a, &s.b, 10, 1e-6);
        let snap = PathSnapshot::from_lasso(s.a.ncols(), &lp);
        assert_eq!(snap.len(), lp.breakpoints.len());
        for (st, bp) in snap.steps.iter().zip(&lp.breakpoints) {
            assert_eq!(st.lambda, bp.lambda);
            let dense = densify(snap.n, &st.support, &st.coefs);
            assert_eq!(dense, bp.x, "densified snapshot must equal the path's x");
        }
    }

    #[test]
    fn empty_support() {
        let s = generate(
            &SyntheticSpec { m: 10, n: 5, density: 1.0, col_skew: 0.0, k_true: 2, noise: 0.0 },
            3,
        );
        assert_eq!(ls_coefficients(&s.a, &[], &s.b), Some(vec![]));
    }
}
