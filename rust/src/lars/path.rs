//! Coefficient recovery along the selection path.
//!
//! LARS-family outputs are (selection order, response estimates); for
//! downstream use (examples, baselines comparison) we recover the
//! least-squares coefficients restricted to each prefix of the path —
//! the paper's §2 note that after k iterations one solves the smaller
//! ordinary regression problem on the selected columns.

use crate::linalg::{Cholesky, Matrix};

/// Least-squares coefficients of `b ≈ A[:, support] x`:
/// `x = (A_Sᵀ A_S)⁻¹ A_Sᵀ b`.
pub fn ls_coefficients(a: &Matrix, support: &[usize], b: &[f64]) -> Option<Vec<f64>> {
    if support.is_empty() {
        return Some(Vec::new());
    }
    let g = a.gram_block(support, support);
    let chol = Cholesky::factor(&g).ok()?;
    let atb: Vec<f64> = support.iter().map(|&j| a.col_dot(j, b)).collect();
    Some(chol.solve(&atb))
}

/// Dense coefficient vector (length n) from a sparse support solution.
pub fn densify(n: usize, support: &[usize], coefs: &[f64]) -> Vec<f64> {
    assert_eq!(support.len(), coefs.len());
    let mut x = vec![0.0; n];
    for (&j, &v) in support.iter().zip(coefs) {
        x[j] = v;
    }
    x
}

/// Residual ‖A x − b‖₂ for a support/coefficient pair.
pub fn residual_norm(a: &Matrix, support: &[usize], coefs: &[f64], b: &[f64]) -> f64 {
    let mut ax = vec![0.0; a.nrows()];
    a.gemv_cols(support, coefs, &mut ax);
    ax.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum::<f64>().sqrt()
}

/// The full solution path: LS coefficients for every prefix
/// `selected[..1], selected[..2], …` (the sequence of linear models the
/// paper's abstract highlights). Returns one (support, coefs) per step.
pub fn solution_path(
    a: &Matrix,
    selected: &[usize],
    b: &[f64],
) -> Vec<(Vec<usize>, Vec<f64>)> {
    let mut out = Vec::with_capacity(selected.len());
    for k in 1..=selected.len() {
        let support = selected[..k].to_vec();
        if let Some(coefs) = ls_coefficients(a, &support, b) {
            out.push((support, coefs));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::lars::serial::{lars, LarsOptions};

    #[test]
    fn exact_recovery_noiseless() {
        let s = generate(
            &SyntheticSpec { m: 60, n: 30, density: 1.0, col_skew: 0.0, k_true: 4, noise: 0.0 },
            1,
        );
        let out = lars(&s.a, &s.b, &LarsOptions { t: 4, ..Default::default() });
        let coefs = ls_coefficients(&s.a, &out.selected, &s.b).unwrap();
        let rn = residual_norm(&s.a, &out.selected, &coefs, &s.b);
        assert!(rn < 1e-8, "residual {rn}");
    }

    #[test]
    fn path_residuals_decrease() {
        let s = generate(
            &SyntheticSpec { m: 80, n: 50, density: 1.0, col_skew: 0.0, k_true: 8, noise: 0.05 },
            2,
        );
        let out = lars(&s.a, &s.b, &LarsOptions { t: 10, ..Default::default() });
        let path = solution_path(&s.a, &out.selected, &s.b);
        let mut prev = f64::INFINITY;
        for (support, coefs) in &path {
            let rn = residual_norm(&s.a, support, coefs, &s.b);
            assert!(rn <= prev + 1e-9, "LS residual must shrink along the path");
            prev = rn;
        }
        assert_eq!(path.len(), 10);
    }

    #[test]
    fn densify_places_coefs() {
        let x = densify(5, &[1, 3], &[2.0, -1.0]);
        assert_eq!(x, vec![0.0, 2.0, 0.0, -1.0, 0.0]);
    }

    #[test]
    fn empty_support() {
        let s = generate(
            &SyntheticSpec { m: 10, n: 5, density: 1.0, col_skew: 0.0, k_true: 2, noise: 0.0 },
            3,
        );
        assert_eq!(ls_coefficients(&s.a, &[], &s.b), Some(vec![]));
    }
}
