//! Run configuration: experiment sweeps, hardware parameters, CLI
//! option parsing (hand-rolled `key=value` / `--flag` parsing — the
//! environment is offline, no clap).

use crate::cluster::{ExecMode, HwParams};
use crate::error::{bail, Result};
pub use crate::par::ParConfig;

/// Which algorithm a run uses.
///
/// Legacy CLI-era enum kept for configuration compatibility; new code
/// should use [`crate::fit::Algorithm`], which carries the per-variant
/// knobs (block size, partitions, λ floor) and covers the baselines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    Lars,
    Blars,
    Tblars,
}

impl Algo {
    /// Canonical lower-case name (inverse of `FromStr`).
    pub fn name(self) -> &'static str {
        match self {
            Algo::Lars => "lars",
            Algo::Blars => "blars",
            Algo::Tblars => "tblars",
        }
    }
}

impl std::str::FromStr for Algo {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "lars" => Ok(Algo::Lars),
            "blars" => Ok(Algo::Blars),
            "tblars" | "t-blars" => Ok(Algo::Tblars),
            other => bail!("unknown algorithm '{other}' (lars|blars|tblars)"),
        }
    }
}

/// One fully specified run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub algo: Algo,
    pub dataset: String,
    /// Target selected columns.
    pub t: usize,
    /// Block size.
    pub b: usize,
    /// Simulated ranks (power of two).
    pub p: usize,
    pub seed: u64,
    pub hw: HwParams,
    pub mode: ExecMode,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algo: Algo::Lars,
            dataset: "tiny".into(),
            t: 20,
            b: 1,
            p: 1,
            seed: 42,
            hw: HwParams::default(),
            mode: ExecMode::Sequential,
        }
    }
}

/// The paper's sweep grids (scaled; §10 uses P up to 128, b up to 38,
/// t = 75 → we default to t = 60, same regimes).
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub t: usize,
    pub b_values: Vec<usize>,
    pub p_values: Vec<usize>,
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            t: 60,
            b_values: vec![1, 2, 3, 5, 8, 15, 25, 38],
            p_values: vec![1, 2, 4, 8, 16, 32, 64, 128],
            seed: 42,
        }
    }
}

impl SweepConfig {
    /// Reduced grid for quick runs / CI.
    pub fn quick() -> Self {
        SweepConfig {
            t: 24,
            b_values: vec![1, 2, 4, 8],
            p_values: vec![1, 4, 16],
            seed: 42,
        }
    }
}

/// Minimal argv parser: positional args plus `--key value` / `--key=value`
/// options and bare `--flag`s. Boolean flags must be listed in
/// [`BOOL_FLAGS`] so `--quick fig3` parses as flag + positional rather
/// than `quick = "fig3"`.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: Vec<(String, Option<String>)>,
}

/// Options that never take a value.
pub const BOOL_FLAGS: [&str; 9] =
    ["quick", "threads", "force", "verbose", "oneshot", "wait", "shutdown", "json", "progress"];

impl Args {
    pub fn parse(argv: &[String]) -> Self {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.push((k.to_string(), Some(v.to_string())));
                } else if !BOOL_FLAGS.contains(&stripped)
                    && i + 1 < argv.len()
                    && !argv[i + 1].starts_with("--")
                {
                    out.opts.push((stripped.to_string(), Some(argv[i + 1].clone())));
                    i += 1;
                } else {
                    out.opts.push((stripped.to_string(), None));
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.opts.iter().any(|(k, _)| k == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .and_then(|(_, v)| v.as_deref())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| crate::anyhow!("--{name}: {e}")),
        }
    }
}

/// `calars serve` configuration parsed from argv (the CLI face of
/// [`crate::serve::ServeOptions`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Bind address, `host:port`. `--port N` overrides the port part;
    /// port 0 picks an ephemeral port.
    pub addr: String,
    /// Fit worker threads (`--fit-workers`).
    pub fit_workers: usize,
    /// Batch accumulation window in µs (`--batch-window-us`).
    pub batch_window_us: u64,
    /// Registry capacity (`--capacity`).
    pub registry_capacity: usize,
    /// Coefficient cache capacity (`--cache`).
    pub cache_capacity: usize,
    /// `--oneshot`: honor POST /shutdown (scripted smoke runs).
    pub oneshot: bool,
    /// `--persist DIR`: load/save the registry from/to DIR.
    pub persist_dir: Option<String>,
    /// `--prefit DATASET`: fit this dataset before accepting traffic.
    pub prefit: Option<String>,
    /// `--slow-ms N`: requests slower than N ms land in the
    /// ring-buffered slow-request log.
    pub slow_ms: u64,
    /// Shared-memory execution (`--par-threads`, `--par-min-chunk`;
    /// `CALARS_THREADS` / `CALARS_MIN_CHUNK` env when the flags are
    /// absent). Carried here so whoever starts the server from a
    /// `ServeConfig` — the CLI's serve command does this — can install
    /// it via [`crate::par::configure`] before the first kernel runs;
    /// `configure` is a no-op once the global pool exists.
    pub par: ParConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        // Defer to the serving layer's defaults so the CLI and the
        // library can never drift apart.
        let d = crate::serve::ServeOptions::default();
        ServeConfig {
            addr: d.addr,
            fit_workers: d.fit_workers,
            batch_window_us: d.batch_window_us,
            registry_capacity: d.registry_capacity,
            cache_capacity: d.cache_capacity,
            oneshot: false,
            persist_dir: None,
            prefit: None,
            slow_ms: d.slow_ms,
            par: ParConfig::default(),
        }
    }
}

impl ServeConfig {
    pub fn from_args(args: &Args) -> Result<Self> {
        let d = ServeConfig::default();
        let mut addr = args.get("addr").unwrap_or(&d.addr).to_string();
        if let Some(port) = args.get("port") {
            let port: u16 = port.parse().map_err(|e| crate::anyhow!("--port: {e}"))?;
            let host = addr.rsplit_once(':').map(|(h, _)| h).unwrap_or("127.0.0.1");
            addr = format!("{host}:{port}");
        }
        Ok(ServeConfig {
            addr,
            fit_workers: args.get_parse("fit-workers", d.fit_workers)?,
            batch_window_us: args.get_parse("batch-window-us", d.batch_window_us)?,
            registry_capacity: args.get_parse("capacity", d.registry_capacity)?,
            cache_capacity: args.get_parse("cache", d.cache_capacity)?,
            oneshot: args.flag("oneshot"),
            persist_dir: args.get("persist").map(String::from),
            prefit: args.get("prefit").map(String::from),
            slow_ms: args.get_parse("slow-ms", d.slow_ms)?,
            par: par_config_from_args(args)?,
        })
    }
}

/// Resolve and install the kernel ISA backend: `--isa` beats the
/// `CALARS_ISA` environment variable beats runtime detection. Unknown
/// or unsupported names are hard errors here (the library's lazy path
/// merely warns); every subcommand calls this before the first kernel
/// runs so the choice is global and immutable for the process.
pub fn init_isa_from_args(args: &Args) -> Result<crate::kern::simd::KernBackend> {
    crate::kern::simd::init_from_cli(args.get("isa"))
}

/// Resolve the shared-memory execution config: environment first
/// (`CALARS_THREADS`, `CALARS_MIN_CHUNK`), CLI flags (`--par-threads`,
/// `--par-min-chunk`) override. Every subcommand applies the result to
/// the global pool before doing any work.
pub fn par_config_from_args(args: &Args) -> Result<ParConfig> {
    let env = ParConfig::from_env();
    Ok(ParConfig {
        threads: args.get_parse("par-threads", env.threads)?,
        min_chunk: {
            let c: usize = args.get_parse("par-min-chunk", env.min_chunk)?;
            if c == 0 {
                bail!("--par-min-chunk must be ≥ 1");
            }
            c
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_positional_and_opts() {
        let a = Args::parse(&argv("run --t 30 --b=4 --quick fig3"));
        assert_eq!(a.positional, vec!["run", "fig3"]);
        assert_eq!(a.get("t"), Some("30"));
        assert_eq!(a.get("b"), Some("4"));
        assert!(a.flag("quick"));
        assert!(!a.flag("nope"));
    }

    #[test]
    fn get_parse_defaults() {
        let a = Args::parse(&argv("x --t 7"));
        assert_eq!(a.get_parse::<usize>("t", 1).unwrap(), 7);
        assert_eq!(a.get_parse::<usize>("b", 3).unwrap(), 3);
        assert!(a.get_parse::<usize>("t", 1).is_ok());
    }

    #[test]
    fn bad_value_errors() {
        let a = Args::parse(&argv("x --t seven"));
        assert!(a.get_parse::<usize>("t", 1).is_err());
    }

    #[test]
    fn algo_from_str() {
        assert_eq!("lars".parse::<Algo>().unwrap(), Algo::Lars);
        assert_eq!("t-blars".parse::<Algo>().unwrap(), Algo::Tblars);
        assert!("zzz".parse::<Algo>().is_err());
    }

    #[test]
    fn last_option_wins() {
        let a = Args::parse(&argv("x --t 1 --t 2"));
        assert_eq!(a.get("t"), Some("2"));
    }

    #[test]
    fn algo_name_roundtrips() {
        for algo in [Algo::Lars, Algo::Blars, Algo::Tblars] {
            assert_eq!(algo.name().parse::<Algo>().unwrap(), algo);
        }
    }

    #[test]
    fn serve_config_defaults_and_overrides() {
        let d = ServeConfig::from_args(&Args::parse(&argv("serve"))).unwrap();
        assert_eq!(d, ServeConfig::default());
        let c = ServeConfig::from_args(&Args::parse(&argv(
            "serve --port 9000 --fit-workers 4 --capacity 8 --oneshot --prefit tiny",
        )))
        .unwrap();
        assert_eq!(c.addr, "127.0.0.1:9000");
        assert_eq!(c.fit_workers, 4);
        assert_eq!(c.registry_capacity, 8);
        assert!(c.oneshot);
        assert_eq!(c.prefit.as_deref(), Some("tiny"));
        assert_eq!(c.slow_ms, 500, "slow-ms keeps its default when absent");
        let c = ServeConfig::from_args(&Args::parse(&argv("serve --slow-ms 50"))).unwrap();
        assert_eq!(c.slow_ms, 50);
        let c = ServeConfig::from_args(&Args::parse(&argv("serve --addr 0.0.0.0:80 --port 81")))
            .unwrap();
        assert_eq!(c.addr, "0.0.0.0:81", "--port overrides the addr's port");
        assert!(ServeConfig::from_args(&Args::parse(&argv("serve --port zzz"))).is_err());
    }

    #[test]
    fn par_config_flags_override() {
        let c = par_config_from_args(&Args::parse(&argv(
            "serve --par-threads 3 --par-min-chunk 512",
        )))
        .unwrap();
        assert_eq!(c.threads, 3);
        assert_eq!(c.min_chunk, 512);
        assert!(c.resolved_threads() >= 3);
        assert!(par_config_from_args(&Args::parse(&argv("x --par-min-chunk 0"))).is_err());
        assert!(par_config_from_args(&Args::parse(&argv("x --par-threads four"))).is_err());
    }
}
