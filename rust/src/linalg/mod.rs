//! Dense and sparse linear-algebra substrate.
//!
//! The paper's algorithms reduce to a handful of kernels — `Aᵀr`
//! correlations, `A_I w` direction application, Gram blocks
//! `A_Iᵀ A_B`, and an incrementally extended Cholesky factorization —
//! implemented here for row-major dense matrices and CSC sparse
//! matrices, with a unified [`Matrix`] front end so the algorithms are
//! storage-agnostic.

pub mod cholesky;
pub mod dense;
pub mod matrix;
pub mod select;
pub mod sparse;

pub use cholesky::Cholesky;
pub use dense::DenseMatrix;
pub use matrix::Matrix;
pub use sparse::CscMatrix;

/// Dot product of two equally sized slices — the [`crate::kern`]
/// multi-accumulator kernel (canonical summation order).
pub use crate::kern::dot;

/// `y += alpha * x` — the [`crate::kern`] unrolled kernel
/// (element-wise, identical numerics to the naive loop).
pub use crate::kern::axpy;

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Infinity norm (max absolute value); 0 for empty input.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0_f64, |m, &v| m.max(v.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_basic() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert_eq!(norm_inf(&[-7.0, 2.0, 5.0]), 7.0);
        assert_eq!(norm_inf(&[]), 0.0);
    }
}
