//! Row-major dense matrices and the blocked kernels the LARS family
//! needs. The row-streaming kernels fork onto [`crate::par`] in
//! fixed-grain chunks and run each chunk through the register-blocked
//! [`crate::kern`] panels (4-row packs, multi-accumulator reductions,
//! 4×4 Gram micro-GEMM): disjoint-output sweeps (`gemv`, `gemv_cols`)
//! keep serial numerics exactly, and chunked reductions (`at_r`,
//! `gram_block`, column norms) combine per-chunk partials in ascending
//! chunk order so results are bit-identical across thread counts (the
//! kern canonical summation order is anchored at each fixed chunk
//! boundary).

use super::{axpy, dot};
use crate::kern;
use crate::par;

/// Row-major dense `m × n` matrix of `f64`.
///
/// Row-major is the natural layout for the paper's *row-partitioned*
/// bLARS: a rank's shard is a contiguous slice of `data`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    m: usize,
    n: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero matrix.
    pub fn zeros(m: usize, n: usize) -> Self {
        DenseMatrix { m, n, data: vec![0.0; m * n] }
    }

    /// From a row-major buffer.
    pub fn from_vec(m: usize, n: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), m * n, "buffer size mismatch");
        DenseMatrix { m, n, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(m: usize, n: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(m * n);
        for i in 0..m {
            for j in 0..n {
                data.push(f(i, j));
            }
        }
        DenseMatrix { m, n, data }
    }

    /// Stack equal-length rows into a matrix — the serving batcher's
    /// GEMV input (one row per concurrent query against a model).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        assert!(!rows.is_empty(), "from_rows needs at least one row");
        let n = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * n);
        for row in rows {
            assert_eq!(row.len(), n, "ragged rows");
            data.extend_from_slice(row);
        }
        DenseMatrix { m: rows.len(), n, data }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.m
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.n + j] = v;
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// Raw row-major buffer.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw buffer (crate-internal: lets the sparse Gram kernel
    /// fill disjoint output rows in parallel).
    #[inline]
    pub(crate) fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Rows per fork-join task for a row sweep touching `row_cost`
    /// elements per row. Pure in the shape + configured grain.
    #[inline]
    fn row_grain(&self, row_cost: usize) -> usize {
        par::grain_for(row_cost)
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.m).map(|i| self.get(i, j)).collect()
    }

    /// Contiguous row slice `[r0, r1)` as a new matrix (a rank's shard).
    pub fn row_slice(&self, r0: usize, r1: usize) -> DenseMatrix {
        assert!(r0 <= r1 && r1 <= self.m);
        DenseMatrix {
            m: r1 - r0,
            n: self.n,
            data: self.data[r0 * self.n..r1 * self.n].to_vec(),
        }
    }

    /// Arbitrary row gather as a new dense matrix (`rows` ascending —
    /// a cross-validation train/test shard; see
    /// [`crate::data::partition::cv_folds`]).
    pub fn row_subset(&self, rows: &[usize]) -> DenseMatrix {
        let mut data = Vec::with_capacity(rows.len() * self.n);
        for &i in rows {
            assert!(i < self.m, "row {i} out of range for {} rows", self.m);
            data.extend_from_slice(self.row(i));
        }
        DenseMatrix { m: rows.len(), n: self.n, data }
    }

    /// Column subset as a new dense `m × |cols|` matrix.
    pub fn col_subset(&self, cols: &[usize]) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.m, cols.len());
        for i in 0..self.m {
            let row = self.row(i);
            let orow = i * cols.len();
            for (k, &j) in cols.iter().enumerate() {
                out.data[orow + k] = row[j];
            }
        }
        out
    }

    /// `out = Aᵀ r` — the correlation kernel. Each fixed-grain row
    /// chunk runs [`kern::at_r_panel`] (4-row fused accumulation — ¼
    /// the accumulator traffic of an axpy-per-row sweep; dispatched to
    /// the active SIMD backend, see [`crate::kern::simd`]); partials
    /// combine in chunk order, so results are bit-identical across
    /// thread counts — and across backends, since the panel kernel's
    /// per-element reduction order is lane-width independent.
    pub fn at_r(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.m);
        assert_eq!(out.len(), self.n);
        let grain = self.row_grain(self.n);
        if self.m <= grain {
            out.fill(0.0);
            kern::at_r_panel(&self.data, self.n, r, out);
            return;
        }
        let n = self.n;
        let partials = par::map_chunks(self.m, grain, |lo, hi| {
            let mut acc = vec![0.0_f64; n];
            kern::at_r_panel(&self.data[lo * n..hi * n], n, &r[lo..hi], &mut acc);
            acc
        });
        // audit: allow(PANIC-REACH) -- map_chunks yields at least one partial for the m >= 1 rows any constructed matrix has
        let (first, rest) = partials.split_first().expect("m > grain implies chunks");
        out.copy_from_slice(first);
        for p in rest {
            axpy(1.0, p, out);
        }
    }

    /// `out = A[:, cols] · w` — apply a direction supported on `cols`.
    /// Per-row [`kern::dot_idx`] gather (four accumulators); output
    /// rows are disjoint, so the parallel form is bit-identical to the
    /// serial loop.
    pub fn gemv_cols(&self, cols: &[usize], w: &[f64], out: &mut [f64]) {
        assert_eq!(cols.len(), w.len());
        assert_eq!(out.len(), self.m);
        let grain = self.row_grain(cols.len());
        par::for_chunks_mut(out, grain, |lo, chunk| {
            for (k, o) in chunk.iter_mut().enumerate() {
                *o = kern::dot_idx(self.row(lo + k), cols, w);
            }
        });
    }

    /// Fused equiangular step: `u = A[:, cols]·w` **and** `av = Aᵀu`
    /// in one streaming pass over `A` (the fitters' steps 10–11 were
    /// two full sweeps; fusing halves the hot-path memory traffic).
    /// `u` chunks are disjoint and each `av` partial is built from its
    /// own chunk's `u` values, combined in chunk order — bit-identical
    /// across thread counts.
    pub fn gemv_cols_at_r(&self, cols: &[usize], w: &[f64], u: &mut [f64], av: &mut [f64]) {
        assert_eq!(cols.len(), w.len());
        assert_eq!(u.len(), self.m);
        assert_eq!(av.len(), self.n);
        let n = self.n;
        let grain = self.row_grain(cols.len() + n);
        if self.m <= grain {
            av.fill(0.0);
            kern::fused_step_panel(&self.data, n, cols, w, u, av);
            return;
        }
        // Split u at the same fixed chunk boundaries the reduction
        // uses so each task owns its rows of u.
        let ranges = par::chunk_ranges(self.m, grain);
        let mut tasks = Vec::with_capacity(ranges.len());
        let mut rest: &mut [f64] = u;
        for &(lo, hi) in &ranges {
            let (head, tail) = rest.split_at_mut(hi - lo);
            rest = tail;
            let rows = &self.data[lo * n..hi * n];
            tasks.push(move || {
                let mut acc = vec![0.0_f64; n];
                kern::fused_step_panel(rows, n, cols, w, head, &mut acc);
                acc
            });
        }
        let partials = par::run_tasks(tasks);
        // audit: allow(PANIC-REACH) -- one task per chunk was queued above, so run_tasks returns at least one partial
        let (first, sum_rest) = partials.split_first().expect("m > grain implies chunks");
        av.copy_from_slice(first);
        for p in sum_rest {
            axpy(1.0, p, av);
        }
    }

    /// Multi-response `outs[k] = Aᵀ rs[k]` — the batch correlation
    /// kernel. One streaming pass over `A` serves every response in
    /// the panel ([`kern::at_r_multi_panel`]); per-model numerics walk
    /// the exact [`Self::at_r`] summation order, and at `k = 1` the
    /// fixed grain reduces to `at_r`'s, so a one-response batch is
    /// bit-identical to the single-response kernel — and any batch is
    /// bit-identical across thread counts.
    pub fn at_r_multi(&self, rs: &[&[f64]], outs: &mut [&mut [f64]]) {
        assert_eq!(rs.len(), outs.len());
        let k = rs.len();
        if k == 0 {
            return;
        }
        for r in rs {
            assert_eq!(r.len(), self.m);
        }
        for o in outs.iter() {
            assert_eq!(o.len(), self.n);
        }
        let n = self.n;
        let grain = self.row_grain(k * n);
        if self.m <= grain {
            for o in outs.iter_mut() {
                o.fill(0.0);
            }
            kern::at_r_multi_panel(&self.data, n, rs, outs);
            return;
        }
        let partials = par::map_chunks(self.m, grain, |lo, hi| {
            let mut accs_own = vec![vec![0.0_f64; n]; k];
            let rs_chunk: Vec<&[f64]> = rs.iter().map(|r| &r[lo..hi]).collect();
            let mut accs: Vec<&mut [f64]> =
                accs_own.iter_mut().map(|v| v.as_mut_slice()).collect();
            kern::at_r_multi_panel(&self.data[lo * n..hi * n], n, &rs_chunk, &mut accs);
            accs_own
        });
        for (idx, o) in outs.iter_mut().enumerate() {
            o.copy_from_slice(&partials[0][idx]);
            for p in &partials[1..] {
                axpy(1.0, &p[idx], o);
            }
        }
    }

    /// Multi-response fused equiangular step: for every model `k`, one
    /// shared pass over `A` computes `us[k] = A[:, cols[k]]·ws[k]` and
    /// `avs[k] = Aᵀ us[k]` ([`kern::fused_step_multi_panel`]). The
    /// fixed grain accounts for the whole batch's per-row cost and
    /// reduces to [`Self::gemv_cols_at_r`]'s at `k = 1`, so a
    /// one-response batch is bit-identical to the single-response
    /// fused step; partials combine per model in ascending chunk
    /// order (thread-count independent bits).
    pub fn fused_step_multi(
        &self,
        cols: &[&[usize]],
        ws: &[&[f64]],
        us: &mut [&mut [f64]],
        avs: &mut [&mut [f64]],
    ) {
        let k = cols.len();
        assert_eq!(ws.len(), k);
        assert_eq!(us.len(), k);
        assert_eq!(avs.len(), k);
        if k == 0 {
            return;
        }
        for (c, w) in cols.iter().zip(ws) {
            assert_eq!(c.len(), w.len());
        }
        for (u, av) in us.iter().zip(avs.iter()) {
            assert_eq!(u.len(), self.m);
            assert_eq!(av.len(), self.n);
        }
        let n = self.n;
        let cost = cols.iter().map(|c| c.len()).sum::<usize>() + k * n;
        let grain = self.row_grain(cost);
        if self.m <= grain {
            for av in avs.iter_mut() {
                av.fill(0.0);
            }
            kern::fused_step_multi_panel(&self.data, n, cols, ws, us, avs);
            return;
        }
        // Split every model's u at the same fixed chunk boundaries so
        // each task owns its rows of every u.
        let ranges = par::chunk_ranges(self.m, grain);
        let mut rests: Vec<&mut [f64]> = Vec::with_capacity(k);
        for u in us.iter_mut() {
            rests.push(&mut **u);
        }
        let mut tasks = Vec::with_capacity(ranges.len());
        for &(lo, hi) in &ranges {
            let mut heads: Vec<&mut [f64]> = Vec::with_capacity(k);
            for slot in rests.iter_mut() {
                let (head, tail) = std::mem::take(slot).split_at_mut(hi - lo);
                *slot = tail;
                heads.push(head);
            }
            let rows = &self.data[lo * n..hi * n];
            tasks.push(move || {
                let mut heads = heads;
                let mut accs_own = vec![vec![0.0_f64; n]; k];
                let mut accs: Vec<&mut [f64]> =
                    accs_own.iter_mut().map(|v| v.as_mut_slice()).collect();
                kern::fused_step_multi_panel(rows, n, cols, ws, &mut heads, &mut accs);
                accs_own
            });
        }
        let partials = par::run_tasks(tasks);
        for (idx, av) in avs.iter_mut().enumerate() {
            av.copy_from_slice(&partials[0][idx]);
            for p in &partials[1..] {
                axpy(1.0, &p[idx], av);
            }
        }
    }

    /// Gram block `A[:, ii]ᵀ · A[:, jj]` as a dense `|ii| × |jj|` matrix.
    ///
    /// Streams A exactly once through [`kern::gram_panel`]: four rows'
    /// `ii`/`jj` values are packed into contiguous panels and the block
    /// accumulates in 4×4 register tiles (vectorized per backend, see
    /// [`crate::kern::simd`] — every backend keeps the tile's scalar
    /// reduction tree, so the block is backend-independent). Row chunks
    /// run on the pool with private blocks + scratch, combined in chunk
    /// order (fixed grain ⇒ thread-count independent bits).
    pub fn gram_block(&self, ii: &[usize], jj: &[usize]) -> DenseMatrix {
        let nb = jj.len();
        let na = ii.len();
        let n = self.n;
        let mut out = DenseMatrix::zeros(na, nb);
        if na == 0 || nb == 0 || self.m == 0 {
            return out;
        }
        let grain = self.row_grain(na * nb + nb);
        let partials = par::map_chunks(self.m, grain, |lo, hi| {
            let mut acc = vec![0.0_f64; na * nb];
            let mut pi = vec![0.0_f64; 4 * na];
            let mut pj = vec![0.0_f64; 4 * nb];
            kern::gram_panel(&self.data[lo * n..hi * n], n, ii, jj, &mut pi, &mut pj, &mut acc);
            acc
        });
        if let Some((first, rest)) = partials.split_first() {
            out.data.copy_from_slice(first);
            for p in rest {
                axpy(1.0, p, &mut out.data);
            }
        }
        out
    }

    /// Dot of column `j` with vector `r` of length `m`.
    pub fn col_dot(&self, j: usize, r: &[f64]) -> f64 {
        assert_eq!(r.len(), self.m);
        let mut s = 0.0;
        for i in 0..self.m {
            s += self.get(i, j) * r[i];
        }
        s
    }

    /// ℓ2 norm of column `j`.
    pub fn col_norm(&self, j: usize) -> f64 {
        // audit: allow(DET-SUM) -- serial ascending-row sum: one fixed order by construction, and the strided column access has no kern kernel to call
        (0..self.m).map(|i| self.get(i, j).powi(2)).sum::<f64>().sqrt()
    }

    /// Squared ℓ2 norms of every column in one row-streaming sweep
    /// through [`kern::col_sq_norms_panel`] (4-row fused), chunked on
    /// the pool (partials combined in chunk order).
    fn col_sq_norms(&self) -> Vec<f64> {
        let n = self.n;
        let mut norms = vec![0.0_f64; n];
        if n == 0 || self.m == 0 {
            return norms;
        }
        let grain = self.row_grain(n);
        let partials = par::map_chunks(self.m, grain, |lo, hi| {
            let mut acc = vec![0.0_f64; n];
            kern::col_sq_norms_panel(&self.data[lo * n..hi * n], n, &mut acc);
            acc
        });
        // audit: allow(PANIC-REACH) -- map_chunks yields at least one partial for the m >= 1 rows any constructed matrix has
        let (first, rest) = partials.split_first().expect("m > 0 implies chunks");
        norms.copy_from_slice(first);
        for p in rest {
            axpy(1.0, p, &mut norms);
        }
        norms
    }

    /// ℓ2 norms of all columns at once — the parallel form of a
    /// `col_norm` sweep (one streaming pass instead of `n` strided
    /// passes).
    pub fn col_norms(&self) -> Vec<f64> {
        self.col_sq_norms().into_iter().map(f64::sqrt).collect()
    }

    /// Normalize every column to unit ℓ2 norm (the paper's standing
    /// assumption, §5.2). Zero columns are left untouched.
    pub fn normalize_columns(&mut self) {
        let _ = self.normalize_columns_with_norms();
    }

    /// Fused normalize: one norm sweep + one scaling pass, **returning
    /// the pre-normalization column norms** (0.0 for zero columns) so
    /// callers that need both — dataset generation, the serving layer's
    /// norm cache — don't pay a separate `col_norms` sweep. Both passes
    /// run chunked on the pool; scaling mutates disjoint row chunks, so
    /// numerics are identical to the serial loop.
    pub fn normalize_columns_with_norms(&mut self) -> Vec<f64> {
        let n = self.n;
        if n == 0 || self.m == 0 {
            return vec![0.0; n];
        }
        let norms: Vec<f64> =
            self.col_sq_norms().into_iter().map(f64::sqrt).collect();
        let inv: Vec<f64> =
            norms.iter().map(|&nj| if nj > 0.0 { 1.0 / nj } else { 1.0 }).collect();
        let grain_rows = self.row_grain(n);
        par::for_chunks_mut(&mut self.data, grain_rows * n, |_, chunk| {
            for row in chunk.chunks_mut(n) {
                for (v, s) in row.iter_mut().zip(&inv) {
                    *v *= *s;
                }
            }
        });
        norms
    }

    /// Full matvec `out = A x`. Each output row is an independent
    /// [`dot`] — the serving layer's batched-prediction kernel — so
    /// the pool-parallel form is bit-identical to the serial loop
    /// (the engine's breakpoint exactness contract relies on this).
    pub fn gemv(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(out.len(), self.m);
        let grain = self.row_grain(self.n);
        par::for_chunks_mut(out, grain, |lo, chunk| {
            for (k, o) in chunk.iter_mut().enumerate() {
                *o = dot(self.row(lo + k), x);
            }
        });
    }

    /// Number of structurally nonzero entries (counts exact zeros out).
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DenseMatrix {
        // 3x2: [[1,2],[3,4],[5,6]]
        DenseMatrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.])
    }

    #[test]
    fn from_rows_matches_from_vec_and_gemv_is_per_row_dot() {
        let r0 = [1.0, 2.0];
        let r1 = [3.0, 4.0];
        let r2 = [5.0, 6.0];
        let a = DenseMatrix::from_rows(&[&r0, &r1, &r2]);
        assert_eq!(a, small());
        // Batched prediction invariant: gemv row i == dot(row i, x),
        // bit for bit (the serving layer's exactness contract).
        let x = [0.25, -1.5];
        let mut out = vec![0.0; 3];
        a.gemv(&x, &mut out);
        for (i, row) in [&r0[..], &r1[..], &r2[..]].iter().enumerate() {
            assert_eq!(out[i], dot(row, &x));
        }
    }

    #[test]
    fn at_r_matches_naive() {
        let a = small();
        let r = vec![1.0, -1.0, 2.0];
        let mut c = vec![0.0; 2];
        a.at_r(&r, &mut c);
        assert_eq!(c, vec![1. - 3. + 10., 2. - 4. + 12.]);
    }

    #[test]
    fn gemv_cols_subset() {
        let a = small();
        let mut out = vec![0.0; 3];
        a.gemv_cols(&[1], &[2.0], &mut out);
        assert_eq!(out, vec![4.0, 8.0, 12.0]);
    }

    #[test]
    fn gram_block_symmetry() {
        let a = small();
        let g = a.gram_block(&[0, 1], &[0, 1]);
        assert!((g.get(0, 1) - g.get(1, 0)).abs() < 1e-12);
        assert!((g.get(0, 0) - (1. + 9. + 25.)).abs() < 1e-12);
        assert!((g.get(0, 1) - (2. + 12. + 30.)).abs() < 1e-12);
    }

    #[test]
    fn row_slice_shard() {
        let a = small();
        let s = a.row_slice(1, 3);
        assert_eq!(s.nrows(), 2);
        assert_eq!(s.row(0), &[3., 4.]);
    }

    #[test]
    fn col_subset_extracts() {
        let a = small();
        let s = a.col_subset(&[1]);
        assert_eq!(s.ncols(), 1);
        assert_eq!(s.col(0), vec![2., 4., 6.]);
    }

    #[test]
    fn row_subset_gathers() {
        let a = small();
        let s = a.row_subset(&[0, 2]);
        assert_eq!(s.nrows(), 2);
        assert_eq!(s.row(0), &[1., 2.]);
        assert_eq!(s.row(1), &[5., 6.]);
        // A contiguous subset matches row_slice exactly.
        assert_eq!(a.row_subset(&[1, 2]), a.row_slice(1, 3));
        assert_eq!(a.row_subset(&[]).nrows(), 0);
    }

    #[test]
    fn normalize_columns_unit_norm() {
        let mut a = small();
        a.normalize_columns();
        for j in 0..2 {
            assert!((a.col_norm(j) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn gemv_matches_manual() {
        let a = small();
        let mut out = vec![0.0; 3];
        a.gemv(&[1.0, 1.0], &mut out);
        assert_eq!(out, vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn col_dot_and_norm() {
        let a = small();
        assert!((a.col_dot(0, &[1., 1., 1.]) - 9.0).abs() < 1e-12);
        assert!((a.col_norm(1) - (4.0f64 + 16.0 + 36.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn nnz_counts_nonzeros() {
        let a = DenseMatrix::from_vec(2, 2, vec![0., 1., 2., 0.]);
        assert_eq!(a.nnz(), 2);
    }

    #[test]
    fn col_norms_sweep_matches_per_column() {
        let a = small();
        let norms = a.col_norms();
        for (j, nj) in norms.iter().enumerate() {
            assert!((nj - a.col_norm(j)).abs() < 1e-12, "col {j}");
        }
        assert!(DenseMatrix::zeros(0, 3).col_norms().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn fused_step_matches_two_pass() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(42);
        let a = DenseMatrix::from_fn(37, 11, |_, _| rng.normal());
        let cols = [0usize, 2, 5, 7, 10];
        let w = [0.5, -1.5, 0.25, 1.0, -0.75];
        let mut u = vec![0.0; 37];
        let mut av = vec![0.0; 11];
        a.gemv_cols_at_r(&cols, &w, &mut u, &mut av);
        let mut u2 = vec![0.0; 37];
        a.gemv_cols(&cols, &w, &mut u2);
        let mut av2 = vec![0.0; 11];
        a.at_r(&u2, &mut av2);
        for (x, y) in u.iter().zip(&u2) {
            assert_eq!(x.to_bits(), y.to_bits(), "fused u must equal gemv_cols exactly");
        }
        for (x, y) in av.iter().zip(&av2) {
            assert!((x - y).abs() < 1e-10 * (1.0 + y.abs()), "fused av off: {x} vs {y}");
        }
    }

    #[test]
    fn fused_step_bit_identical_across_thread_counts() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(43);
        let a = DenseMatrix::from_fn(700, 30, |_, _| rng.normal());
        let cols: Vec<usize> = (0..12).collect();
        let w: Vec<f64> = (0..12).map(|k| (k as f64 * 0.2).cos()).collect();
        let run = |threads: usize| {
            let pool = crate::par::ThreadPool::new(threads, 64);
            crate::par::with_pool(&pool, || {
                let mut u = vec![0.0; 700];
                let mut av = vec![0.0; 30];
                a.gemv_cols_at_r(&cols, &w, &mut u, &mut av);
                (u, av)
            })
        };
        let base = run(1);
        for threads in [2usize, 4] {
            let got = run(threads);
            for (x, y) in base.0.iter().chain(&base.1).zip(got.0.iter().chain(&got.1)) {
                assert_eq!(x.to_bits(), y.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn multi_response_kernels_match_single_and_threads() {
        // 700×30 with a 64-unit grain forces the chunked paths. The
        // multi kernels promise (a) k=1 bit-identity to the
        // single-response kernels under the same pool, and (b)
        // bit-identity across thread counts at any k.
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(77);
        let a = DenseMatrix::from_fn(700, 30, |_, _| rng.normal());
        let rs_own: Vec<Vec<f64>> = (0..3)
            .map(|s| (0..700).map(|i| ((i + 31 * s) as f64 * 0.21).sin()).collect())
            .collect();
        let cols_own: Vec<Vec<usize>> = vec![(0..12).collect(), (5..17).collect(), vec![1, 3, 9]];
        let ws_own: Vec<Vec<f64>> = cols_own
            .iter()
            .map(|c| c.iter().map(|&j| (j as f64 * 0.2).cos()).collect())
            .collect();
        let run = |threads: usize, k: usize| {
            let pool = crate::par::ThreadPool::new(threads, 64);
            crate::par::with_pool(&pool, || {
                let rs: Vec<&[f64]> = rs_own[..k].iter().map(|v| v.as_slice()).collect();
                let mut cs = vec![vec![0.0; 30]; k];
                {
                    let mut outs: Vec<&mut [f64]> =
                        cs.iter_mut().map(|v| v.as_mut_slice()).collect();
                    a.at_r_multi(&rs, &mut outs);
                }
                let cols: Vec<&[usize]> = cols_own[..k].iter().map(|v| v.as_slice()).collect();
                let ws: Vec<&[f64]> = ws_own[..k].iter().map(|v| v.as_slice()).collect();
                let mut us = vec![vec![0.0; 700]; k];
                let mut avs = vec![vec![0.0; 30]; k];
                {
                    let mut u_sl: Vec<&mut [f64]> =
                        us.iter_mut().map(|v| v.as_mut_slice()).collect();
                    let mut av_sl: Vec<&mut [f64]> =
                        avs.iter_mut().map(|v| v.as_mut_slice()).collect();
                    a.fused_step_multi(&cols, &ws, &mut u_sl, &mut av_sl);
                }
                (cs, us, avs)
            })
        };
        // (a) k=1 batch ≡ single-response kernels, bit for bit.
        let (cs, us, avs) = run(2, 1);
        let pool = crate::par::ThreadPool::new(2, 64);
        let (c1, u1, av1) = crate::par::with_pool(&pool, || {
            let mut c = vec![0.0; 30];
            a.at_r(&rs_own[0], &mut c);
            let mut u = vec![0.0; 700];
            let mut av = vec![0.0; 30];
            a.gemv_cols_at_r(&cols_own[0], &ws_own[0], &mut u, &mut av);
            (c, u, av)
        });
        for (x, y) in cs[0].iter().zip(&c1).chain(us[0].iter().zip(&u1)).chain(avs[0].iter().zip(&av1)) {
            assert_eq!(x.to_bits(), y.to_bits(), "k=1 multi != single");
        }
        // (b) thread invariance at k=3.
        let base = run(1, 3);
        for threads in [2usize, 4] {
            let got = run(threads, 3);
            for i in 0..3 {
                for (x, y) in base
                    .0[i]
                    .iter()
                    .zip(&got.0[i])
                    .chain(base.1[i].iter().zip(&got.1[i]))
                    .chain(base.2[i].iter().zip(&got.2[i]))
                {
                    assert_eq!(x.to_bits(), y.to_bits(), "threads={threads} model {i}");
                }
            }
        }
    }

    #[test]
    fn normalize_with_norms_returns_prenormalization_norms() {
        let mut a = small();
        let expect: Vec<f64> = (0..2).map(|j| a.col_norm(j)).collect();
        let norms = a.normalize_columns_with_norms();
        for (x, y) in norms.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-12);
        }
        for j in 0..2 {
            assert!((a.col_norm(j) - 1.0).abs() < 1e-12);
        }
        // Zero columns report norm 0 and stay untouched.
        let mut z = DenseMatrix::zeros(3, 2);
        z.set(0, 0, 2.0);
        let norms = z.normalize_columns_with_norms();
        assert_eq!(norms[1], 0.0);
        assert_eq!(z.get(1, 1), 0.0);
        assert!((z.get(0, 0) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn kernels_bit_identical_across_thread_counts() {
        // 600×40 spans multiple fixed-grain chunks at the default
        // min_chunk, so the chunked-reduction paths really execute.
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(99);
        let a = DenseMatrix::from_fn(600, 40, |_, _| rng.normal());
        let r: Vec<f64> = (0..600).map(|i| (i as f64 * 0.3).cos()).collect();
        let run = |threads: usize| {
            let pool = crate::par::ThreadPool::new(threads, crate::par::DEFAULT_MIN_CHUNK);
            crate::par::with_pool(&pool, || {
                let mut c = vec![0.0; 40];
                a.at_r(&r, &mut c);
                let g = a.gram_block(&[0, 3, 7], &[1, 2, 4, 5]);
                let x = vec![0.5; 40];
                let mut y = vec![0.0; 600];
                a.gemv(&x, &mut y);
                (c, g.data().to_vec(), y, a.col_norms())
            })
        };
        let base = run(1);
        for threads in [2, 4] {
            let got = run(threads);
            let pairs =
                [(&base.0, &got.0), (&base.1, &got.1), (&base.2, &got.2), (&base.3, &got.3)];
            for (b, g) in pairs {
                for (x, y) in b.iter().zip(g.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "threads={threads}");
                }
            }
        }
    }
}
