//! Row-major dense matrices and the blocked kernels the LARS family needs.

use super::{axpy, dot};

/// Row-major dense `m × n` matrix of `f64`.
///
/// Row-major is the natural layout for the paper's *row-partitioned*
/// bLARS: a rank's shard is a contiguous slice of `data`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    m: usize,
    n: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero matrix.
    pub fn zeros(m: usize, n: usize) -> Self {
        DenseMatrix { m, n, data: vec![0.0; m * n] }
    }

    /// From a row-major buffer.
    pub fn from_vec(m: usize, n: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), m * n, "buffer size mismatch");
        DenseMatrix { m, n, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(m: usize, n: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(m * n);
        for i in 0..m {
            for j in 0..n {
                data.push(f(i, j));
            }
        }
        DenseMatrix { m, n, data }
    }

    /// Stack equal-length rows into a matrix — the serving batcher's
    /// GEMV input (one row per concurrent query against a model).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        assert!(!rows.is_empty(), "from_rows needs at least one row");
        let n = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * n);
        for row in rows {
            assert_eq!(row.len(), n, "ragged rows");
            data.extend_from_slice(row);
        }
        DenseMatrix { m: rows.len(), n, data }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.m
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.n + j] = v;
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// Raw row-major buffer.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.m).map(|i| self.get(i, j)).collect()
    }

    /// Contiguous row slice `[r0, r1)` as a new matrix (a rank's shard).
    pub fn row_slice(&self, r0: usize, r1: usize) -> DenseMatrix {
        assert!(r0 <= r1 && r1 <= self.m);
        DenseMatrix {
            m: r1 - r0,
            n: self.n,
            data: self.data[r0 * self.n..r1 * self.n].to_vec(),
        }
    }

    /// Column subset as a new dense `m × |cols|` matrix.
    pub fn col_subset(&self, cols: &[usize]) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.m, cols.len());
        for i in 0..self.m {
            let row = self.row(i);
            let orow = i * cols.len();
            for (k, &j) in cols.iter().enumerate() {
                out.data[orow + k] = row[j];
            }
        }
        out
    }

    /// `out = Aᵀ r` — the correlation kernel. Row-major friendly:
    /// accumulate `r_i * row_i` into `out` (axpy per row), which streams
    /// both `A` and `out` and vectorizes well.
    pub fn at_r(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.m);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for i in 0..self.m {
            let ri = r[i];
            if ri != 0.0 {
                axpy(ri, self.row(i), out);
            }
        }
    }

    /// `out = A[:, cols] · w` — apply a direction supported on `cols`.
    pub fn gemv_cols(&self, cols: &[usize], w: &[f64], out: &mut [f64]) {
        assert_eq!(cols.len(), w.len());
        assert_eq!(out.len(), self.m);
        for i in 0..self.m {
            let row = self.row(i);
            let mut s = 0.0;
            for (k, &j) in cols.iter().enumerate() {
                s += row[j] * w[k];
            }
            out[i] = s;
        }
    }

    /// Gram block `A[:, ii]ᵀ · A[:, jj]` as a dense `|ii| × |jj|` matrix.
    ///
    /// Streams A exactly once (rank-1 accumulation into the block). The
    /// `jj` values of each row are hoisted into a contiguous scratch
    /// buffer so the inner loop is a register-friendly `v · rj[b]` FMA
    /// chain rather than strided re-loads — 3-4x on tall matrices
    /// (EXPERIMENTS.md §Perf, L3 iteration 2).
    pub fn gram_block(&self, ii: &[usize], jj: &[usize]) -> DenseMatrix {
        let nb = jj.len();
        let mut out = DenseMatrix::zeros(ii.len(), nb);
        let mut rj = vec![0.0_f64; nb];
        for rix in 0..self.m {
            let row = self.row(rix);
            for (x, &j) in rj.iter_mut().zip(jj) {
                *x = row[j];
            }
            for (a, &i) in ii.iter().enumerate() {
                let v = row[i];
                if v != 0.0 {
                    let orow = &mut out.data[a * nb..(a + 1) * nb];
                    for (o, &x) in orow.iter_mut().zip(&rj) {
                        *o += v * x;
                    }
                }
            }
        }
        out
    }

    /// Dot of column `j` with vector `r` of length `m`.
    pub fn col_dot(&self, j: usize, r: &[f64]) -> f64 {
        assert_eq!(r.len(), self.m);
        let mut s = 0.0;
        for i in 0..self.m {
            s += self.get(i, j) * r[i];
        }
        s
    }

    /// ℓ2 norm of column `j`.
    pub fn col_norm(&self, j: usize) -> f64 {
        (0..self.m).map(|i| self.get(i, j).powi(2)).sum::<f64>().sqrt()
    }

    /// Normalize every column to unit ℓ2 norm (the paper's standing
    /// assumption, §5.2). Zero columns are left untouched.
    pub fn normalize_columns(&mut self) {
        let mut norms = vec![0.0_f64; self.n];
        for i in 0..self.m {
            let row = &self.data[i * self.n..(i + 1) * self.n];
            for j in 0..self.n {
                norms[j] += row[j] * row[j];
            }
        }
        for nj in norms.iter_mut() {
            *nj = if *nj > 0.0 { nj.sqrt() } else { 1.0 };
        }
        for i in 0..self.m {
            let row = &mut self.data[i * self.n..(i + 1) * self.n];
            for j in 0..self.n {
                row[j] /= norms[j];
            }
        }
    }

    /// Full matvec `out = A x`.
    pub fn gemv(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(out.len(), self.m);
        for i in 0..self.m {
            out[i] = dot(self.row(i), x);
        }
    }

    /// Number of structurally nonzero entries (counts exact zeros out).
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DenseMatrix {
        // 3x2: [[1,2],[3,4],[5,6]]
        DenseMatrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.])
    }

    #[test]
    fn from_rows_matches_from_vec_and_gemv_is_per_row_dot() {
        let r0 = [1.0, 2.0];
        let r1 = [3.0, 4.0];
        let r2 = [5.0, 6.0];
        let a = DenseMatrix::from_rows(&[&r0, &r1, &r2]);
        assert_eq!(a, small());
        // Batched prediction invariant: gemv row i == dot(row i, x),
        // bit for bit (the serving layer's exactness contract).
        let x = [0.25, -1.5];
        let mut out = vec![0.0; 3];
        a.gemv(&x, &mut out);
        for (i, row) in [&r0[..], &r1[..], &r2[..]].iter().enumerate() {
            assert_eq!(out[i], dot(row, &x));
        }
    }

    #[test]
    fn at_r_matches_naive() {
        let a = small();
        let r = vec![1.0, -1.0, 2.0];
        let mut c = vec![0.0; 2];
        a.at_r(&r, &mut c);
        assert_eq!(c, vec![1. - 3. + 10., 2. - 4. + 12.]);
    }

    #[test]
    fn gemv_cols_subset() {
        let a = small();
        let mut out = vec![0.0; 3];
        a.gemv_cols(&[1], &[2.0], &mut out);
        assert_eq!(out, vec![4.0, 8.0, 12.0]);
    }

    #[test]
    fn gram_block_symmetry() {
        let a = small();
        let g = a.gram_block(&[0, 1], &[0, 1]);
        assert!((g.get(0, 1) - g.get(1, 0)).abs() < 1e-12);
        assert!((g.get(0, 0) - (1. + 9. + 25.)).abs() < 1e-12);
        assert!((g.get(0, 1) - (2. + 12. + 30.)).abs() < 1e-12);
    }

    #[test]
    fn row_slice_shard() {
        let a = small();
        let s = a.row_slice(1, 3);
        assert_eq!(s.nrows(), 2);
        assert_eq!(s.row(0), &[3., 4.]);
    }

    #[test]
    fn col_subset_extracts() {
        let a = small();
        let s = a.col_subset(&[1]);
        assert_eq!(s.ncols(), 1);
        assert_eq!(s.col(0), vec![2., 4., 6.]);
    }

    #[test]
    fn normalize_columns_unit_norm() {
        let mut a = small();
        a.normalize_columns();
        for j in 0..2 {
            assert!((a.col_norm(j) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn gemv_matches_manual() {
        let a = small();
        let mut out = vec![0.0; 3];
        a.gemv(&[1.0, 1.0], &mut out);
        assert_eq!(out, vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn col_dot_and_norm() {
        let a = small();
        assert!((a.col_dot(0, &[1., 1., 1.]) - 9.0).abs() < 1e-12);
        assert!((a.col_norm(1) - (4.0f64 + 16.0 + 36.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn nnz_counts_nonzeros() {
        let a = DenseMatrix::from_vec(2, 2, vec![0., 1., 2., 0.]);
        assert_eq!(a.nnz(), 2);
    }
}
