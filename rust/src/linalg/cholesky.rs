//! Incrementally extended Cholesky factorization.
//!
//! LARS-family algorithms grow the Gram matrix `G_k = A_{I_k}ᵀ A_{I_k}`
//! by `b` columns per iteration. Refactorizing costs `O(|I|³)`; the
//! paper instead appends a `b`-row block to the existing factor
//! (Algorithm 2, steps 20–23):
//!
//! ```text
//! H   = L_k⁻¹ · (A_{I_k}ᵀ A_B)          (forward solves)
//! ΩΩᵀ = A_Bᵀ A_B − Hᵀ H                  (small b×b Cholesky)
//! L_{k+1} = [ L_k  0 ]
//!           [ Hᵀ   Ω ]
//! ```

use super::dense::DenseMatrix;
use crate::kern;
use std::fmt;

/// Errors from factorization (loss of positive-definiteness — in exact
/// arithmetic impossible under the paper's §5.2 full-rank assumption,
/// but finite precision and near-duplicate columns can trigger it).
/// Hand-rolled `Display`/`Error` impls: the crate builds offline with
/// zero dependencies, so no `thiserror`.
#[derive(Clone, Copy, Debug)]
pub enum CholeskyError {
    NotPositiveDefinite(usize, f64),
}

impl fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CholeskyError::NotPositiveDefinite(pivot, value) => {
                write!(f, "matrix not positive definite at pivot {pivot} (value {value:.3e})")
            }
        }
    }
}

impl std::error::Error for CholeskyError {}

/// Lower-triangular Cholesky factor stored packed row-major:
/// row `i` occupies `i+1` entries starting at `i(i+1)/2`.
#[derive(Clone, Debug, Default)]
pub struct Cholesky {
    dim: usize,
    /// Packed lower triangle, length `dim(dim+1)/2`.
    l: Vec<f64>,
}

#[inline]
fn row_start(i: usize) -> usize {
    i * (i + 1) / 2
}

impl Cholesky {
    /// Empty (0×0) factor — T-bLARS starts from this.
    pub fn empty() -> Self {
        Cholesky { dim: 0, l: Vec::new() }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// `L[i][j]`, `j <= i`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(j <= i && i < self.dim);
        self.l[row_start(i) + j]
    }

    /// Factor a dense symmetric positive-definite matrix.
    pub fn factor(g: &DenseMatrix) -> Result<Self, CholeskyError> {
        assert_eq!(g.nrows(), g.ncols());
        let n = g.nrows();
        let mut chol = Cholesky { dim: 0, l: Vec::with_capacity(row_start(n)) };
        for i in 0..n {
            let row: Vec<f64> = (0..=i).map(|j| g.get(i, j)).collect();
            chol.push_row(&row)?;
        }
        Ok(chol)
    }

    /// Append one row of the Gram matrix: `row = [G[i][0..=i]]` where
    /// `i == self.dim`. Computes the new factor row in place. The
    /// recurrence subtractions run through the [`crate::kern`]
    /// multi-accumulator dot (canonical order over the `[0, j)` row
    /// prefix) — the same arithmetic [`Self::solve_lower`] and
    /// [`Self::append_block`] use, which is what keeps the panel
    /// update bit-identical to sequential `push_row`s.
    pub fn push_row(&mut self, grow: &[f64]) -> Result<(), CholeskyError> {
        let i = self.dim;
        assert_eq!(grow.len(), i + 1);
        let start = row_start(i);
        self.l.resize(start + i + 1, 0.0);
        for j in 0..i {
            // l[i][j] = (g[i][j] − Σ_{k<j} l[i][k]·l[j][k]) / l[j][j]
            let js = row_start(j);
            let s = grow[j]
                - kern::dot(&self.l[start..start + j], &self.l[js..js + j]);
            self.l[start + j] = s / self.l[js + j];
        }
        let d = grow[i] - kern::sq_norm(&self.l[start..start + i]);
        if d <= 0.0 || !d.is_finite() {
            self.l.truncate(start);
            return Err(CholeskyError::NotPositiveDefinite(i, d));
        }
        self.l[start + i] = d.sqrt();
        self.dim = i + 1;
        Ok(())
    }

    /// Append a `b`-column block (Algorithm 2 steps 20–23) as a
    /// chunked panel update:
    ///
    /// * `gib` — `A_{I}ᵀ A_B`, shape `dim × b`;
    /// * `gbb` — `A_Bᵀ A_B`, shape `b × b` (full symmetric).
    ///
    /// The panel `H = L_k⁻¹·gib` is `b` *independent* forward solves,
    /// chunked over panel columns on the [`crate::par`] pool; the
    /// trailing `b × b` rows are then completed serially by running
    /// `push_row`'s own recurrence over the concatenated `[H | Ω]`
    /// prefixes (the first `k` entries of each new row are exactly the
    /// parallel solves, so no arithmetic repeats). Because the solve
    /// and the recurrence both subtract through the same
    /// [`crate::kern::dot`] canonical order over the `[0, j)` prefix,
    /// the result is bit-identical to `b` sequential `push_row`s — on
    /// any thread count. Unlike `push_row` loops, failure leaves the
    /// factor untouched (no partially appended rows).
    pub fn append_block(&mut self, gib: &DenseMatrix, gbb: &DenseMatrix) -> Result<(), CholeskyError> {
        let k = self.dim;
        let b = gbb.nrows();
        assert_eq!(gib.nrows(), k);
        assert_eq!(gib.ncols(), b);
        assert_eq!(gbb.ncols(), b);
        if b == 0 {
            return Ok(());
        }
        // Panel: H columns, each a forward solve against the existing
        // factor (cost ~k²/2 flops per column → chunk grain).
        let grain = crate::par::grain_for(k * k / 2 + 1);
        let h_cols: Vec<Vec<f64>> = crate::par::map_chunks(b, grain, |lo, hi| {
            (lo..hi)
                .map(|r| {
                    let mut col: Vec<f64> = (0..k).map(|i| gib.get(i, r)).collect();
                    self.solve_lower(&mut col);
                    col
                })
                .collect::<Vec<_>>()
        })
        .concat();
        // Complete each new packed row [ Hᵀ[r] | Ω[r] ] with push_row's
        // recurrence over the full prefix, buffered so failure leaves
        // the factor untouched.
        let mut new_rows: Vec<Vec<f64>> = Vec::with_capacity(b);
        for (r, h_col) in h_cols.into_iter().enumerate() {
            let mut row = h_col;
            row.reserve(r + 1);
            for (j, prev) in new_rows.iter().enumerate() {
                // l[k+r][k+j] = (g − Σ_{x<k+j} row[x]·prev[x]) / prev[k+j]
                let s = gbb.get(r, j) - kern::dot(&row[..k + j], &prev[..k + j]);
                row.push(s / prev[k + j]);
            }
            let d = gbb.get(r, r) - kern::sq_norm(&row[..k + r]);
            if d <= 0.0 || !d.is_finite() {
                // Report the pivot in full-factor coordinates, as the
                // row-by-row path would.
                return Err(CholeskyError::NotPositiveDefinite(k + r, d));
            }
            row.push(d.sqrt());
            new_rows.push(row);
        }
        self.l.reserve(b * k + row_start(b));
        for row in &new_rows {
            self.l.extend_from_slice(row);
        }
        self.dim = k + b;
        Ok(())
    }

    /// Append a block, gracefully excluding rows that break positive
    /// definiteness (the paper's §5.2 "minor modifications" for
    /// linearly dependent columns — duplicate columns are routine in
    /// real text data). Tries the fast chunked panel update first;
    /// only a rank-deficient block falls back to row-by-row greedy
    /// admission, whose arithmetic the panel path reproduces bit for
    /// bit on the rows both admit. Returns the block-row indices
    /// actually admitted, in order.
    pub fn append_block_graceful(&mut self, gib: &DenseMatrix, gbb: &DenseMatrix) -> Vec<usize> {
        if self.append_block(gib, gbb).is_ok() {
            return (0..gbb.nrows()).collect();
        }
        let k = self.dim;
        let b = gbb.nrows();
        let mut admitted: Vec<usize> = Vec::new();
        for r in 0..b {
            let mut grow: Vec<f64> = (0..k).map(|i| gib.get(i, r)).collect();
            for &ar in &admitted {
                grow.push(gbb.get(r, ar));
            }
            grow.push(gbb.get(r, r));
            if self.push_row(&grow).is_ok() {
                admitted.push(r);
            }
        }
        admitted
    }

    /// Forward substitution: solve `L x = rhs` in place. The prefix
    /// subtraction is the [`crate::kern::dot`] canonical order —
    /// identical arithmetic to [`Self::push_row`]'s off-diagonal
    /// recurrence (the block-append bit-identity relies on this).
    pub fn solve_lower(&self, rhs: &mut [f64]) {
        assert_eq!(rhs.len(), self.dim);
        for i in 0..self.dim {
            let start = row_start(i);
            let (prefix, tail) = rhs.split_at_mut(i);
            let s = tail[0] - kern::dot(&self.l[start..start + i], prefix);
            tail[0] = s / self.l[start + i];
        }
    }

    /// Back substitution: solve `Lᵀ x = rhs` in place.
    pub fn solve_upper(&self, rhs: &mut [f64]) {
        assert_eq!(rhs.len(), self.dim);
        for i in (0..self.dim).rev() {
            let mut s = rhs[i];
            for j in i + 1..self.dim {
                s -= self.l[row_start(j) + i] * rhs[j];
            }
            rhs[i] = s / self.l[row_start(i) + i];
        }
    }

    /// Solve `(L Lᵀ) x = s`, i.e. `G x = s` (Algorithm 2, step 7).
    pub fn solve(&self, s: &[f64]) -> Vec<f64> {
        let mut x = Vec::new();
        self.solve_into(s, &mut x);
        x
    }

    /// [`Self::solve`] into a caller-owned buffer — the fitters' inner
    /// loops call this every iteration, so reusing `x` eliminates a
    /// per-step heap allocation. `x` is cleared and refilled; the
    /// arithmetic is identical to [`Self::solve`].
    pub fn solve_into(&self, s: &[f64], x: &mut Vec<f64>) {
        x.clear();
        x.extend_from_slice(s);
        self.solve_lower(x);
        self.solve_upper(x);
    }

    /// Truncate back to the leading `dim0 × dim0` factor.
    ///
    /// mLARS calls inside T-bLARS extend a *copy* of the global factor;
    /// the root keeps only its own extension, so losing trailing rows is
    /// a cheap O(1) truncation thanks to packed row-major storage.
    pub fn truncate(&mut self, dim0: usize) {
        assert!(dim0 <= self.dim);
        self.l.truncate(row_start(dim0));
        self.dim = dim0;
    }

    /// Reconstruct `G = L Lᵀ` (tests).
    pub fn reconstruct(&self) -> DenseMatrix {
        let n = self.dim;
        DenseMatrix::from_fn(n, n, |i, j| {
            let (lo, hi) = if i < j { (i, j) } else { (j, i) };
            let (ls, hs) = (row_start(lo), row_start(hi));
            (0..=lo).map(|k| self.l[ls + k] * self.l[hs + k]).sum()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> DenseMatrix {
        let mut rng = Pcg64::new(seed);
        let b = DenseMatrix::from_fn(n + 3, n, |_, _| rng.normal());
        let mut g = b.gram_block(&(0..n).collect::<Vec<_>>(), &(0..n).collect::<Vec<_>>());
        for i in 0..n {
            g.set(i, i, g.get(i, i) + 0.1); // comfortably PD
        }
        g
    }

    #[test]
    fn factor_reconstructs() {
        let g = random_spd(8, 1);
        let c = Cholesky::factor(&g).unwrap();
        let r = c.reconstruct();
        for i in 0..8 {
            for j in 0..8 {
                assert!((r.get(i, j) - g.get(i, j)).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn solve_matches_direct() {
        let g = random_spd(6, 2);
        let c = Cholesky::factor(&g).unwrap();
        let s: Vec<f64> = (0..6).map(|i| (i as f64).cos()).collect();
        let x = c.solve(&s);
        // Check G x = s
        for i in 0..6 {
            let gi: f64 = (0..6).map(|j| g.get(i, j) * x[j]).sum();
            assert!((gi - s[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn append_block_matches_full_factor() {
        let n = 10;
        let b = 3;
        let g = random_spd(n, 3);
        let full = Cholesky::factor(&g).unwrap();

        // Factor the leading (n-b) block, then append the trailing b.
        let k = n - b;
        let gk = DenseMatrix::from_fn(k, k, |i, j| g.get(i, j));
        let mut inc = Cholesky::factor(&gk).unwrap();
        let gib = DenseMatrix::from_fn(k, b, |i, j| g.get(i, k + j));
        let gbb = DenseMatrix::from_fn(b, b, |i, j| g.get(k + i, k + j));
        inc.append_block(&gib, &gbb).unwrap();

        assert_eq!(inc.dim(), n);
        for i in 0..n {
            for j in 0..=i {
                assert!(
                    (inc.get(i, j) - full.get(i, j)).abs() < 1e-9,
                    "L mismatch at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn append_block_bit_identical_to_push_rows() {
        // The panel update reorders nothing: it must equal b sequential
        // push_rows bit for bit, on any thread count.
        let n = 14;
        let b = 5;
        let k = n - b;
        let g = random_spd(n, 11);
        let gib = DenseMatrix::from_fn(k, b, |i, j| g.get(i, k + j));
        let gbb = DenseMatrix::from_fn(b, b, |i, j| g.get(k + i, k + j));
        let gk = DenseMatrix::from_fn(k, k, |i, j| g.get(i, j));
        let base = Cholesky::factor(&gk).unwrap();

        let mut rowwise = base.clone();
        for r in 0..b {
            let mut grow: Vec<f64> = (0..k).map(|i| gib.get(i, r)).collect();
            for j in 0..=r {
                grow.push(gbb.get(r, j));
            }
            rowwise.push_row(&grow).unwrap();
        }

        for threads in [1usize, 2, 4] {
            let pool = crate::par::ThreadPool::new(threads, 1);
            let blocked = crate::par::with_pool(&pool, || {
                let mut c = base.clone();
                c.append_block(&gib, &gbb).unwrap();
                c
            });
            assert_eq!(blocked.dim(), rowwise.dim());
            for i in 0..n {
                for j in 0..=i {
                    assert_eq!(
                        blocked.get(i, j).to_bits(),
                        rowwise.get(i, j).to_bits(),
                        "threads={threads} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn append_block_graceful_excludes_dependent_rows() {
        // Exact small-integer arithmetic: the block's first row is a
        // perfect duplicate of the existing column (Schur pivot exactly
        // 0 ⇒ rejected), the second is orthogonal (admitted).
        let mut chol = Cholesky::factor(&DenseMatrix::from_vec(1, 1, vec![4.0])).unwrap();
        let gib = DenseMatrix::from_vec(1, 2, vec![4.0, 0.0]);
        let gbb = DenseMatrix::from_vec(2, 2, vec![4.0, 0.0, 0.0, 9.0]);
        let admitted = chol.append_block_graceful(&gib, &gbb);
        assert_eq!(admitted, vec![1]);
        assert_eq!(chol.dim(), 2);
        assert_eq!(chol.get(1, 1), 3.0);
        assert_eq!(chol.get(1, 0), 0.0);
        // A fully independent block takes the fast panel path whole.
        let gib2 = DenseMatrix::from_vec(2, 1, vec![0.0, 0.0]);
        let gbb2 = DenseMatrix::from_vec(1, 1, vec![16.0]);
        assert_eq!(chol.append_block_graceful(&gib2, &gbb2), vec![0]);
        assert_eq!(chol.dim(), 3);
    }

    #[test]
    fn truncate_recovers_prefix() {
        let g = random_spd(7, 4);
        let mut c = Cholesky::factor(&g).unwrap();
        let expect = {
            let g4 = DenseMatrix::from_fn(4, 4, |i, j| g.get(i, j));
            Cholesky::factor(&g4).unwrap()
        };
        c.truncate(4);
        assert_eq!(c.dim(), 4);
        for i in 0..4 {
            for j in 0..=i {
                assert!((c.get(i, j) - expect.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn not_pd_detected() {
        let g = DenseMatrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]); // rank 1
        match Cholesky::factor(&g) {
            Err(CholeskyError::NotPositiveDefinite(i, _)) => assert_eq!(i, 1),
            other => panic!("expected NotPositiveDefinite, got {other:?}"),
        }
    }

    #[test]
    fn empty_factor_usable() {
        let mut c = Cholesky::empty();
        assert_eq!(c.dim(), 0);
        c.push_row(&[4.0]).unwrap();
        assert!((c.get(0, 0) - 2.0).abs() < 1e-15);
    }

    #[test]
    fn solve_empty_ok() {
        let c = Cholesky::empty();
        assert!(c.solve(&[]).is_empty());
    }
}
