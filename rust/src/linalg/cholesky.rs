//! Incrementally extended Cholesky factorization.
//!
//! LARS-family algorithms grow the Gram matrix `G_k = A_{I_k}ᵀ A_{I_k}`
//! by `b` columns per iteration. Refactorizing costs `O(|I|³)`; the
//! paper instead appends a `b`-row block to the existing factor
//! (Algorithm 2, steps 20–23):
//!
//! ```text
//! H   = L_k⁻¹ · (A_{I_k}ᵀ A_B)          (forward solves)
//! ΩΩᵀ = A_Bᵀ A_B − Hᵀ H                  (small b×b Cholesky)
//! L_{k+1} = [ L_k  0 ]
//!           [ Hᵀ   Ω ]
//! ```

use super::dense::DenseMatrix;
use thiserror::Error;

/// Errors from factorization (loss of positive-definiteness — in exact
/// arithmetic impossible under the paper's §5.2 full-rank assumption,
/// but finite precision and near-duplicate columns can trigger it).
#[derive(Debug, Error)]
pub enum CholeskyError {
    #[error("matrix not positive definite at pivot {0} (value {1:.3e})")]
    NotPositiveDefinite(usize, f64),
}

/// Lower-triangular Cholesky factor stored packed row-major:
/// row `i` occupies `i+1` entries starting at `i(i+1)/2`.
#[derive(Clone, Debug, Default)]
pub struct Cholesky {
    dim: usize,
    /// Packed lower triangle, length `dim(dim+1)/2`.
    l: Vec<f64>,
}

#[inline]
fn row_start(i: usize) -> usize {
    i * (i + 1) / 2
}

impl Cholesky {
    /// Empty (0×0) factor — T-bLARS starts from this.
    pub fn empty() -> Self {
        Cholesky { dim: 0, l: Vec::new() }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// `L[i][j]`, `j <= i`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(j <= i && i < self.dim);
        self.l[row_start(i) + j]
    }

    /// Factor a dense symmetric positive-definite matrix.
    pub fn factor(g: &DenseMatrix) -> Result<Self, CholeskyError> {
        assert_eq!(g.nrows(), g.ncols());
        let n = g.nrows();
        let mut chol = Cholesky { dim: 0, l: Vec::with_capacity(row_start(n)) };
        for i in 0..n {
            let row: Vec<f64> = (0..=i).map(|j| g.get(i, j)).collect();
            chol.push_row(&row)?;
        }
        Ok(chol)
    }

    /// Append one row of the Gram matrix: `row = [G[i][0..=i]]` where
    /// `i == self.dim`. Computes the new factor row in place.
    pub fn push_row(&mut self, grow: &[f64]) -> Result<(), CholeskyError> {
        let i = self.dim;
        assert_eq!(grow.len(), i + 1);
        let start = row_start(i);
        self.l.resize(start + i + 1, 0.0);
        for j in 0..i {
            // l[i][j] = (g[i][j] − Σ_{k<j} l[i][k]·l[j][k]) / l[j][j]
            let js = row_start(j);
            let mut s = grow[j];
            for k in 0..j {
                s -= self.l[start + k] * self.l[js + k];
            }
            self.l[start + j] = s / self.l[js + j];
        }
        let mut d = grow[i];
        for k in 0..i {
            d -= self.l[start + k] * self.l[start + k];
        }
        if d <= 0.0 || !d.is_finite() {
            self.l.truncate(start);
            return Err(CholeskyError::NotPositiveDefinite(i, d));
        }
        self.l[start + i] = d.sqrt();
        self.dim = i + 1;
        Ok(())
    }

    /// Append a `b`-column block (Algorithm 2 steps 20–23).
    ///
    /// * `gib` — `A_{I}ᵀ A_B`, shape `dim × b`;
    /// * `gbb` — `A_Bᵀ A_B`, shape `b × b` (full symmetric).
    pub fn append_block(&mut self, gib: &DenseMatrix, gbb: &DenseMatrix) -> Result<(), CholeskyError> {
        let k = self.dim;
        let b = gbb.nrows();
        assert_eq!(gib.nrows(), k);
        assert_eq!(gib.ncols(), b);
        assert_eq!(gbb.ncols(), b);
        // Equivalent to b sequential push_rows but phrased at block level:
        // each new row r (0..b) of the extended Gram is
        //   [ gibᵀ[r][0..k] | gbb[r][0..=r] ].
        for r in 0..b {
            let mut grow = Vec::with_capacity(k + r + 1);
            for i in 0..k {
                grow.push(gib.get(i, r));
            }
            for j in 0..=r {
                grow.push(gbb.get(r, j));
            }
            self.push_row(&grow)?;
        }
        Ok(())
    }

    /// Forward substitution: solve `L x = rhs` in place.
    pub fn solve_lower(&self, rhs: &mut [f64]) {
        assert_eq!(rhs.len(), self.dim);
        for i in 0..self.dim {
            let start = row_start(i);
            let mut s = rhs[i];
            for j in 0..i {
                s -= self.l[start + j] * rhs[j];
            }
            rhs[i] = s / self.l[start + i];
        }
    }

    /// Back substitution: solve `Lᵀ x = rhs` in place.
    pub fn solve_upper(&self, rhs: &mut [f64]) {
        assert_eq!(rhs.len(), self.dim);
        for i in (0..self.dim).rev() {
            let mut s = rhs[i];
            for j in i + 1..self.dim {
                s -= self.l[row_start(j) + i] * rhs[j];
            }
            rhs[i] = s / self.l[row_start(i) + i];
        }
    }

    /// Solve `(L Lᵀ) x = s`, i.e. `G x = s` (Algorithm 2, step 7).
    pub fn solve(&self, s: &[f64]) -> Vec<f64> {
        let mut x = s.to_vec();
        self.solve_lower(&mut x);
        self.solve_upper(&mut x);
        x
    }

    /// Truncate back to the leading `dim0 × dim0` factor.
    ///
    /// mLARS calls inside T-bLARS extend a *copy* of the global factor;
    /// the root keeps only its own extension, so losing trailing rows is
    /// a cheap O(1) truncation thanks to packed row-major storage.
    pub fn truncate(&mut self, dim0: usize) {
        assert!(dim0 <= self.dim);
        self.l.truncate(row_start(dim0));
        self.dim = dim0;
    }

    /// Reconstruct `G = L Lᵀ` (tests).
    pub fn reconstruct(&self) -> DenseMatrix {
        let n = self.dim;
        DenseMatrix::from_fn(n, n, |i, j| {
            let (lo, hi) = if i < j { (i, j) } else { (j, i) };
            let (ls, hs) = (row_start(lo), row_start(hi));
            (0..=lo).map(|k| self.l[ls + k] * self.l[hs + k]).sum()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> DenseMatrix {
        let mut rng = Pcg64::new(seed);
        let b = DenseMatrix::from_fn(n + 3, n, |_, _| rng.normal());
        let mut g = b.gram_block(&(0..n).collect::<Vec<_>>(), &(0..n).collect::<Vec<_>>());
        for i in 0..n {
            g.set(i, i, g.get(i, i) + 0.1); // comfortably PD
        }
        g
    }

    #[test]
    fn factor_reconstructs() {
        let g = random_spd(8, 1);
        let c = Cholesky::factor(&g).unwrap();
        let r = c.reconstruct();
        for i in 0..8 {
            for j in 0..8 {
                assert!((r.get(i, j) - g.get(i, j)).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn solve_matches_direct() {
        let g = random_spd(6, 2);
        let c = Cholesky::factor(&g).unwrap();
        let s: Vec<f64> = (0..6).map(|i| (i as f64).cos()).collect();
        let x = c.solve(&s);
        // Check G x = s
        for i in 0..6 {
            let gi: f64 = (0..6).map(|j| g.get(i, j) * x[j]).sum();
            assert!((gi - s[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn append_block_matches_full_factor() {
        let n = 10;
        let b = 3;
        let g = random_spd(n, 3);
        let full = Cholesky::factor(&g).unwrap();

        // Factor the leading (n-b) block, then append the trailing b.
        let k = n - b;
        let gk = DenseMatrix::from_fn(k, k, |i, j| g.get(i, j));
        let mut inc = Cholesky::factor(&gk).unwrap();
        let gib = DenseMatrix::from_fn(k, b, |i, j| g.get(i, k + j));
        let gbb = DenseMatrix::from_fn(b, b, |i, j| g.get(k + i, k + j));
        inc.append_block(&gib, &gbb).unwrap();

        assert_eq!(inc.dim(), n);
        for i in 0..n {
            for j in 0..=i {
                assert!(
                    (inc.get(i, j) - full.get(i, j)).abs() < 1e-9,
                    "L mismatch at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn truncate_recovers_prefix() {
        let g = random_spd(7, 4);
        let mut c = Cholesky::factor(&g).unwrap();
        let expect = {
            let g4 = DenseMatrix::from_fn(4, 4, |i, j| g.get(i, j));
            Cholesky::factor(&g4).unwrap()
        };
        c.truncate(4);
        assert_eq!(c.dim(), 4);
        for i in 0..4 {
            for j in 0..=i {
                assert!((c.get(i, j) - expect.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn not_pd_detected() {
        let g = DenseMatrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]); // rank 1
        match Cholesky::factor(&g) {
            Err(CholeskyError::NotPositiveDefinite(i, _)) => assert_eq!(i, 1),
            other => panic!("expected NotPositiveDefinite, got {other:?}"),
        }
    }

    #[test]
    fn empty_factor_usable() {
        let mut c = Cholesky::empty();
        assert_eq!(c.dim(), 0);
        c.push_row(&[4.0]).unwrap();
        assert!((c.get(0, 0) - 2.0).abs() < 1e-15);
    }

    #[test]
    fn solve_empty_ok() {
        let c = Cholesky::empty();
        assert!(c.solve(&[]).is_empty());
    }
}
