//! Compressed-sparse-column matrices.
//!
//! CSC is the natural layout for LARS-family algorithms: correlations
//! `Aᵀr` are per-column dots, the direction `A_I w` accumulates selected
//! columns, and Gram blocks are column-column sparse dots. The paper's
//! T-bLARS column partition is a CSC column subset; bLARS's row
//! partition is a CSC row slice (both implemented below).

use super::dense::DenseMatrix;
use super::axpy;
use crate::kern;
use crate::par;

/// CSC sparse `m × n` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    m: usize,
    n: usize,
    /// Column pointers, length `n + 1`.
    colptr: Vec<usize>,
    /// Row indices, length nnz; sorted ascending within each column.
    rowidx: Vec<u32>,
    /// Values, parallel to `rowidx`.
    values: Vec<f64>,
}

impl CscMatrix {
    /// Build from per-column (row, value) triplet lists. Rows within a
    /// column need not be sorted; they are sorted here.
    pub fn from_columns(m: usize, cols: Vec<Vec<(usize, f64)>>) -> Self {
        let n = cols.len();
        let mut colptr = Vec::with_capacity(n + 1);
        let mut rowidx = Vec::new();
        let mut values = Vec::new();
        colptr.push(0);
        for mut col in cols {
            col.sort_unstable_by_key(|&(r, _)| r);
            for (r, v) in col {
                assert!(r < m, "row index out of bounds");
                if v != 0.0 {
                    rowidx.push(r as u32);
                    values.push(v);
                }
            }
            colptr.push(rowidx.len());
        }
        CscMatrix { m, n, colptr, rowidx, values }
    }

    /// Build from a dense matrix, dropping exact zeros.
    pub fn from_dense(a: &DenseMatrix) -> Self {
        let mut cols = vec![Vec::new(); a.ncols()];
        for i in 0..a.nrows() {
            for j in 0..a.ncols() {
                let v = a.get(i, j);
                if v != 0.0 {
                    cols[j].push((i, v));
                }
            }
        }
        CscMatrix::from_columns(a.nrows(), cols)
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.m
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// nnz of column `j`.
    #[inline]
    pub fn col_nnz(&self, j: usize) -> usize {
        self.colptr[j + 1] - self.colptr[j]
    }

    /// Borrow the (rows, values) of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.colptr[j], self.colptr[j + 1]);
        (&self.rowidx[s..e], &self.values[s..e])
    }

    /// Densify (tests / small blocks only).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.m, self.n);
        for j in 0..self.n {
            let (rows, vals) = self.col(j);
            for (&r, &v) in rows.iter().zip(vals) {
                out.set(r as usize, j, v);
            }
        }
        out
    }

    /// Columns per fork-join task, targeting ≈ `min_chunk` nonzeros per
    /// task. Pure in (shape, nnz, configured grain) — never in the
    /// thread count, so chunk boundaries are reproducible.
    pub(crate) fn col_grain(&self) -> usize {
        par::grain_for((self.nnz() / self.n.max(1)).max(1))
    }

    /// `out = Aᵀ r`: per-column [`kern::sparse_dot`] gather (four
    /// accumulators — the SIMD backends keep that exact reduction
    /// order, see [`crate::kern::simd`]). Each `out[j]` is independent,
    /// so the column-chunked parallel form is bit-identical to the
    /// serial loop.
    pub fn at_r(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.m);
        assert_eq!(out.len(), self.n);
        let grain = self.col_grain();
        par::for_chunks_mut(out, grain, |lo, chunk| {
            for (k, o) in chunk.iter_mut().enumerate() {
                let (rows, vals) = self.col(lo + k);
                *o = kern::sparse_dot(rows, vals, r);
            }
        });
    }

    /// `out = A[:, cols] · w`: scatter-accumulate selected columns.
    /// Column chunks scatter into private accumulators, combined in
    /// chunk order (fixed grain ⇒ thread-count independent bits). The
    /// parallel form only pays off when the selected nonzeros dominate
    /// the per-chunk `m`-length accumulator traffic, so the guard also
    /// requires that — it is pure in (matrix, |cols|, grain), never in
    /// the thread count.
    pub fn gemv_cols(&self, cols: &[usize], w: &[f64], out: &mut [f64]) {
        assert_eq!(cols.len(), w.len());
        assert_eq!(out.len(), self.m);
        let grain = self.col_grain();
        let est_sel_nnz = cols.len() * (self.nnz() / self.n.max(1)).max(1);
        if cols.len() <= grain || est_sel_nnz < 4 * self.m {
            out.fill(0.0);
            for (&wk, &j) in w.iter().zip(cols) {
                if wk == 0.0 {
                    continue;
                }
                let (rows, vals) = self.col(j);
                kern::scatter_axpy(wk, rows, vals, out);
            }
            return;
        }
        let partials = par::map_chunks(cols.len(), grain, |lo, hi| {
            let mut acc = vec![0.0_f64; self.m];
            for k in lo..hi {
                let wk = w[k];
                if wk == 0.0 {
                    continue;
                }
                let (rows, vals) = self.col(cols[k]);
                kern::scatter_axpy(wk, rows, vals, &mut acc);
            }
            acc
        });
        // audit: allow(PANIC-REACH) -- map_chunks yields at least one partial for the non-empty column set this path passes in
        let (first, rest) = partials.split_first().expect("cols > grain implies chunks");
        out.copy_from_slice(first);
        for p in rest {
            axpy(1.0, p, out);
        }
    }

    /// Sparse dot of columns `i` and `j` (sorted-merge).
    pub fn col_col_dot(&self, i: usize, j: usize) -> f64 {
        let (ri, vi) = self.col(i);
        let (rj, vj) = self.col(j);
        let (mut a, mut b, mut s) = (0usize, 0usize, 0.0);
        while a < ri.len() && b < rj.len() {
            match ri[a].cmp(&rj[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    s += vi[a] * vj[b];
                    a += 1;
                    b += 1;
                }
            }
        }
        s
    }

    /// Gram block `A[:, ii]ᵀ A[:, jj]` as dense `|ii| × |jj|`.
    ///
    /// Uses a scatter buffer per `ii` column: densify column `i` once,
    /// then each dot with a `jj` column is O(nnz(col j)). This beats the
    /// pairwise merge when `|jj|` is large. Output rows are disjoint, so
    /// `ii` chunks run on the pool (one scratch buffer per task) with
    /// numerics identical to the serial loop.
    pub fn gram_block(&self, ii: &[usize], jj: &[usize]) -> DenseMatrix {
        let nb = jj.len();
        let mut out = DenseMatrix::zeros(ii.len(), nb);
        if ii.is_empty() || nb == 0 {
            return out;
        }
        let jnnz: usize = jj.iter().map(|&j| self.col_nnz(j)).sum();
        let grain_rows = par::grain_for(jnnz.max(1));
        par::for_chunks_mut(out.data_mut(), grain_rows * nb, |off, chunk| {
            let mut scratch = vec![0.0_f64; self.m];
            for (step, orow) in chunk.chunks_mut(nb).enumerate() {
                let i = ii[off / nb + step];
                let (ri, vi) = self.col(i);
                for (&r, &v) in ri.iter().zip(vi) {
                    scratch[r as usize] = v;
                }
                for (o, &j) in orow.iter_mut().zip(jj) {
                    let (rj, vj) = self.col(j);
                    *o = kern::sparse_dot(rj, vj, &scratch);
                }
                for &r in ri {
                    scratch[r as usize] = 0.0;
                }
            }
        });
        out
    }

    /// Dot of column `j` with a dense length-`m` vector.
    pub fn col_dot(&self, j: usize, r: &[f64]) -> f64 {
        let (rows, vals) = self.col(j);
        kern::sparse_dot(rows, vals, r)
    }

    /// ℓ2 norm of column `j`.
    pub fn col_norm(&self, j: usize) -> f64 {
        let (_, vals) = self.col(j);
        kern::sq_norm(vals).sqrt()
    }

    /// ℓ2 norms of all columns — the pool-parallel form of a
    /// `col_norm` sweep. Per-column sums are untouched, so the result
    /// is bit-identical to the serial sweep.
    pub fn col_norms(&self) -> Vec<f64> {
        let chunks = par::map_chunks(self.n, self.col_grain(), |lo, hi| {
            (lo..hi).map(|j| self.col_norm(j)).collect::<Vec<_>>()
        });
        chunks.concat()
    }

    /// Scale every column to unit ℓ2 norm (zero columns untouched).
    pub fn normalize_columns(&mut self) {
        let _ = self.normalize_columns_with_norms();
    }

    /// Fused normalize: per-column norm + scale in one traversal of
    /// `values`, **returning the pre-normalization column norms** (0.0
    /// for empty columns). Column chunks mutate disjoint `values`
    /// ranges (chunk boundaries land on `colptr` entries) and each
    /// chunk returns its own norm slice concatenated in chunk order,
    /// so numerics match the serial loop on any thread count.
    pub fn normalize_columns_with_norms(&mut self) -> Vec<f64> {
        let ranges = par::chunk_ranges(self.n, self.col_grain());
        if ranges.len() <= 1 {
            let mut norms = Vec::with_capacity(self.n);
            for j in 0..self.n {
                let (s, e) = (self.colptr[j], self.colptr[j + 1]);
                let nrm = kern::sq_norm(&self.values[s..e]).sqrt();
                if nrm > 0.0 {
                    kern::scale(&mut self.values[s..e], 1.0 / nrm);
                }
                norms.push(nrm);
            }
            return norms;
        }
        let colptr = &self.colptr;
        let mut rest: &mut [f64] = &mut self.values;
        let mut base = 0usize;
        let mut tasks = Vec::with_capacity(ranges.len());
        for &(lo, hi) in &ranges {
            let end = colptr[hi];
            let (head, tail) = rest.split_at_mut(end - base);
            rest = tail;
            let start = base;
            tasks.push(move || {
                let mut local = Vec::with_capacity(hi - lo);
                for j in lo..hi {
                    let (s, e) = (colptr[j] - start, colptr[j + 1] - start);
                    let nrm = kern::sq_norm(&head[s..e]).sqrt();
                    if nrm > 0.0 {
                        kern::scale(&mut head[s..e], 1.0 / nrm);
                    }
                    local.push(nrm);
                }
                local
            });
            base = end;
        }
        par::run_tasks(tasks).concat()
    }

    /// Row slice `[r0, r1)` as a new CSC matrix (bLARS rank shard).
    pub fn row_slice(&self, r0: usize, r1: usize) -> CscMatrix {
        assert!(r0 <= r1 && r1 <= self.m);
        let mut colptr = Vec::with_capacity(self.n + 1);
        let mut rowidx = Vec::new();
        let mut values = Vec::new();
        colptr.push(0);
        for j in 0..self.n {
            let (rows, vals) = self.col(j);
            // rows sorted: binary search the window.
            let lo = rows.partition_point(|&r| (r as usize) < r0);
            let hi = rows.partition_point(|&r| (r as usize) < r1);
            for k in lo..hi {
                rowidx.push(rows[k] - r0 as u32);
                values.push(vals[k]);
            }
            colptr.push(rowidx.len());
        }
        CscMatrix { m: r1 - r0, n: self.n, colptr, rowidx, values }
    }

    /// Arbitrary row gather as a new CSC matrix. `rows` must be
    /// strictly ascending (a sorted cross-validation shard; see
    /// [`crate::data::partition::cv_folds`]); output row `i` is input
    /// row `rows[i]`.
    pub fn row_subset(&self, rows: &[usize]) -> CscMatrix {
        debug_assert!(rows.windows(2).all(|w| w[0] < w[1]), "rows must be strictly ascending");
        if let Some(&last) = rows.last() {
            assert!(last < self.m, "row {last} out of range for {} rows", self.m);
        }
        let mut colptr = Vec::with_capacity(self.n + 1);
        let mut rowidx: Vec<u32> = Vec::new();
        let mut values: Vec<f64> = Vec::new();
        colptr.push(0);
        for j in 0..self.n {
            let (rs, vs) = self.col(j);
            // Both index lists are sorted: merge-intersect them.
            let (mut a, mut b) = (0usize, 0usize);
            while a < rs.len() && b < rows.len() {
                let r = rs[a] as usize;
                if r == rows[b] {
                    rowidx.push(b as u32);
                    values.push(vs[a]);
                    a += 1;
                    b += 1;
                } else if r < rows[b] {
                    a += 1;
                } else {
                    b += 1;
                }
            }
            colptr.push(rowidx.len());
        }
        CscMatrix { m: rows.len(), n: self.n, colptr, rowidx, values }
    }

    /// Column subset as a new CSC matrix (T-bLARS rank shard).
    pub fn col_subset(&self, cols: &[usize]) -> CscMatrix {
        let mut colptr = Vec::with_capacity(cols.len() + 1);
        let mut rowidx = Vec::new();
        let mut values = Vec::new();
        colptr.push(0);
        for &j in cols {
            let (rows, vals) = self.col(j);
            rowidx.extend_from_slice(rows);
            values.extend_from_slice(vals);
            colptr.push(rowidx.len());
        }
        CscMatrix { m: self.m, n: cols.len(), colptr, rowidx, values }
    }

    /// Per-column nnz counts (Figure 2 histograms).
    pub fn col_nnz_counts(&self) -> Vec<usize> {
        (0..self.n).map(|j| self.col_nnz(j)).collect()
    }

    /// Per-row nnz counts.
    pub fn row_nnz_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.m];
        for &r in &self.rowidx {
            counts[r as usize] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CscMatrix {
        // [[1,0,2],[0,3,0],[4,0,5],[0,6,0]]  (4x3)
        CscMatrix::from_columns(
            4,
            vec![
                vec![(0, 1.0), (2, 4.0)],
                vec![(1, 3.0), (3, 6.0)],
                vec![(0, 2.0), (2, 5.0)],
            ],
        )
    }

    #[test]
    fn roundtrip_dense() {
        let a = sample();
        let d = a.to_dense();
        let a2 = CscMatrix::from_dense(&d);
        assert_eq!(a, a2);
    }

    #[test]
    fn at_r_matches_dense() {
        let a = sample();
        let d = a.to_dense();
        let r = vec![1.0, -2.0, 0.5, 3.0];
        let mut cs = vec![0.0; 3];
        let mut cd = vec![0.0; 3];
        a.at_r(&r, &mut cs);
        d.at_r(&r, &mut cd);
        for (x, y) in cs.iter().zip(&cd) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn gemv_cols_matches_dense() {
        let a = sample();
        let d = a.to_dense();
        let mut os = vec![0.0; 4];
        let mut od = vec![0.0; 4];
        a.gemv_cols(&[0, 2], &[1.5, -0.5], &mut os);
        d.gemv_cols(&[0, 2], &[1.5, -0.5], &mut od);
        assert_eq!(os, od);
    }

    #[test]
    fn gram_block_matches_dense() {
        let a = sample();
        let d = a.to_dense();
        let gs = a.gram_block(&[0, 1], &[0, 1, 2]);
        let gd = d.gram_block(&[0, 1], &[0, 1, 2]);
        for i in 0..2 {
            for j in 0..3 {
                assert!((gs.get(i, j) - gd.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn col_col_dot_merge() {
        let a = sample();
        assert!((a.col_col_dot(0, 2) - (1.0 * 2.0 + 4.0 * 5.0)).abs() < 1e-12);
        assert_eq!(a.col_col_dot(0, 1), 0.0);
    }

    #[test]
    fn row_slice_matches_dense() {
        let a = sample();
        let d = a.to_dense();
        let s = a.row_slice(1, 3);
        let sd = d.row_slice(1, 3);
        assert_eq!(s.to_dense(), sd);
    }

    #[test]
    fn col_subset_matches_dense() {
        let a = sample();
        let d = a.to_dense();
        let s = a.col_subset(&[2, 0]);
        let sd = d.col_subset(&[2, 0]);
        assert_eq!(s.to_dense(), sd);
    }

    #[test]
    fn row_subset_matches_dense() {
        let a = sample();
        let d = a.to_dense();
        let rows = [0usize, 2, 3];
        assert_eq!(a.row_subset(&rows).to_dense(), d.row_subset(&rows));
        // Contiguous gather equals row_slice.
        assert_eq!(a.row_subset(&[1, 2]).to_dense(), a.row_slice(1, 3).to_dense());
    }

    #[test]
    fn normalize_columns_unit() {
        let mut a = sample();
        a.normalize_columns();
        for j in 0..3 {
            assert!((a.col_norm(j) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn nnz_counts() {
        let a = sample();
        assert_eq!(a.nnz(), 6);
        assert_eq!(a.col_nnz_counts(), vec![2, 2, 2]);
        assert_eq!(a.row_nnz_counts(), vec![2, 1, 2, 1]);
    }

    #[test]
    fn zero_values_dropped() {
        let a = CscMatrix::from_columns(2, vec![vec![(0, 0.0), (1, 1.0)]]);
        assert_eq!(a.nnz(), 1);
    }

    #[test]
    fn col_norms_matches_per_column() {
        let a = sample();
        let norms = a.col_norms();
        for (j, nj) in norms.iter().enumerate() {
            assert!((nj - a.col_norm(j)).abs() < 1e-15, "col {j}");
        }
    }

    #[test]
    fn parallel_paths_bit_identical_across_thread_counts() {
        // A matrix wide enough that the column-chunked kernels split at
        // a small grain; results must not depend on the thread count.
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(7);
        let n = 400;
        let m = 50;
        let cols: Vec<Vec<(usize, f64)>> = (0..n)
            .map(|_| {
                (0..m).filter(|_| rng.uniform() < 0.2).map(|i| (i, rng.normal())).collect()
            })
            .collect();
        let a = CscMatrix::from_columns(m, cols);
        let r: Vec<f64> = (0..m).map(|i| (i as f64).sin()).collect();
        let sel: Vec<usize> = (0..n).step_by(3).collect();
        let w: Vec<f64> = sel.iter().map(|&j| (j as f64 * 0.01) - 0.5).collect();
        let run = |threads: usize| {
            // min_chunk 64 forces several chunks even at this size.
            let pool = crate::par::ThreadPool::new(threads, 64);
            crate::par::with_pool(&pool, || {
                let mut c = vec![0.0; n];
                a.at_r(&r, &mut c);
                let mut u = vec![0.0; m];
                a.gemv_cols(&sel, &w, &mut u);
                let g = a.gram_block(&sel[..20], &sel[..10]);
                let mut b = a.clone();
                b.normalize_columns();
                (c, u, g.data().to_vec(), b.col_norms())
            })
        };
        let base = run(1);
        for threads in [2, 4] {
            let got = run(threads);
            for (x, y) in base
                .0
                .iter()
                .chain(&base.1)
                .chain(&base.2)
                .chain(&base.3)
                .zip(got.0.iter().chain(&got.1).chain(&got.2).chain(&got.3))
            {
                assert_eq!(x.to_bits(), y.to_bits(), "threads={threads}");
            }
        }
    }
}
