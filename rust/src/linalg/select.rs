//! Selection primitives: `max^b`, `argmax^b`, `min^b`, `min⁺`.
//!
//! The paper charges these at O(n) via Introspective Selection [26];
//! we implement introselect (quickselect with median-of-three pivoting
//! and a heap-based fallback after too many bad partitions) plus the
//! small helpers the algorithms use.

/// Indices of the `b` largest values of `f(i)` over `0..n`, unordered.
/// If `n < b`, returns all indices (paper convention §5.1).
pub fn argmax_b_by<F: Fn(usize) -> f64>(n: usize, b: usize, f: F) -> Vec<usize> {
    argselect_b_keyed(n, b, f, false)
}

/// Indices of the `b` smallest values.
pub fn argmin_b_by<F: Fn(usize) -> f64>(n: usize, b: usize, f: F) -> Vec<usize> {
    argselect_b_keyed(n, b, f, true)
}

/// Materialize keys once, then introselect on (key, index) pairs —
/// evaluating `f` per *comparison* dominated the selection cost
/// (EXPERIMENTS.md §Perf, L3 iteration 3: ~9x on n = 150k).
fn argselect_b_keyed<F: Fn(usize) -> f64>(n: usize, b: usize, f: F, ascending: bool) -> Vec<usize> {
    if b >= n {
        return (0..n).collect();
    }
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (f(i), i)).collect();
    pairs.select_nth_unstable_by(b - 1, |a, c| {
        let ord = a.0.total_cmp(&c.0);
        if ascending {
            ord
        } else {
            ord.reverse()
        }
    });
    pairs[..b].iter().map(|&(_, i)| i).collect()
}

/// `b`-th largest absolute value of a slice (`max^b` in the paper);
/// `None` if empty. If the slice has fewer than `b` entries, `b` is
/// clamped to its length.
pub fn max_b_abs(v: &[f64], b: usize) -> Option<f64> {
    if v.is_empty() || b == 0 {
        return None;
    }
    let idx = argmax_b_by(v.len(), b, |i| v[i].abs());
    idx.iter().map(|&i| v[i].abs()).fold(None, |acc: Option<f64>, x| {
        Some(match acc {
            None => x,
            Some(a) => a.min(x),
        })
    })
}

/// Minimum positive value among the two candidates (paper's `min⁺` on a
/// 2-vector): returns `None` when neither is strictly positive & finite.
#[inline]
pub fn min_positive2(a: f64, b: f64) -> Option<f64> {
    let pa = a.is_finite() && a > 0.0;
    let pb = b.is_finite() && b > 0.0;
    match (pa, pb) {
        (true, true) => Some(a.min(b)),
        (true, false) => Some(a),
        (false, true) => Some(b),
        (false, false) => None,
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn check_topb(v: &[f64], b: usize) {
        let got = argmax_b_by(v.len(), b, |i| v[i]);
        assert_eq!(got.len(), b.min(v.len()));
        let mut sorted: Vec<f64> = v.to_vec();
        sorted.sort_by(|a, c| c.total_cmp(a));
        let thresh = sorted[b.min(v.len()) - 1];
        for &i in &got {
            assert!(v[i] >= thresh - 1e-12, "v[{i}]={} < thresh {}", v[i], thresh);
        }
        // No duplicates
        let mut g = got.clone();
        g.sort_unstable();
        g.dedup();
        assert_eq!(g.len(), b.min(v.len()));
    }

    #[test]
    fn top_b_random() {
        let mut rng = Pcg64::new(11);
        for n in [1usize, 2, 5, 17, 100, 501] {
            let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            for b in [1usize, 2, 3, n / 2 + 1, n] {
                let b = b.min(n).max(1);
                check_topb(&v, b);
            }
        }
    }

    #[test]
    fn top_b_with_ties() {
        let v = vec![1.0, 1.0, 1.0, 0.5, 1.0, 0.2];
        check_topb(&v, 2);
        check_topb(&v, 4);
    }

    #[test]
    fn b_exceeds_len() {
        let v = vec![3.0, 1.0];
        let got = argmax_b_by(v.len(), 10, |i| v[i]);
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn argmin_b() {
        let v = vec![5.0, -1.0, 3.0, 0.0, 7.0];
        let got = argmin_b_by(v.len(), 2, |i| v[i]);
        let mut g = got.clone();
        g.sort_unstable();
        assert_eq!(g, vec![1, 3]);
    }

    #[test]
    fn max_b_abs_values() {
        let v = vec![-5.0, 1.0, 4.0, -3.0];
        assert_eq!(max_b_abs(&v, 1), Some(5.0));
        assert_eq!(max_b_abs(&v, 2), Some(4.0));
        assert_eq!(max_b_abs(&v, 4), Some(1.0));
        assert_eq!(max_b_abs(&v, 10), Some(1.0)); // b clamped
        assert_eq!(max_b_abs(&[], 1), None);
    }

    #[test]
    fn min_positive2_cases() {
        assert_eq!(min_positive2(2.0, 3.0), Some(2.0));
        assert_eq!(min_positive2(-2.0, 3.0), Some(3.0));
        assert_eq!(min_positive2(-2.0, -3.0), None);
        assert_eq!(min_positive2(f64::INFINITY, 1.0), Some(1.0));
        assert_eq!(min_positive2(f64::NAN, 1.0), Some(1.0));
        assert_eq!(min_positive2(0.0, 0.0), None);
    }

    #[test]
    fn all_equal_input() {
        let v = vec![2.0; 9];
        check_topb(&v, 3);
    }
}
