//! Storage-agnostic matrix front end.
//!
//! The algorithms in [`crate::lars`] are written against this enum so a
//! single implementation serves both the dense (YearPredictionMSD-like)
//! and sparse (sector/E2006-like) regimes, mirroring the paper's §10
//! implementation note that leaf computations use sparse structures and
//! non-leaf computations dense ones.

use super::dense::DenseMatrix;
use super::sparse::CscMatrix;
use crate::kern;
use crate::par;
use std::sync::Arc;

/// Dense or CSC-sparse matrix with the unified kernel API used by the
/// LARS family.
#[derive(Clone, Debug)]
pub enum Matrix {
    Dense(DenseMatrix),
    Sparse(CscMatrix),
}

impl Matrix {
    pub fn nrows(&self) -> usize {
        match self {
            Matrix::Dense(a) => a.nrows(),
            Matrix::Sparse(a) => a.nrows(),
        }
    }

    pub fn ncols(&self) -> usize {
        match self {
            Matrix::Dense(a) => a.ncols(),
            Matrix::Sparse(a) => a.ncols(),
        }
    }

    /// Structural nonzeros (dense counts exact nonzero entries).
    pub fn nnz(&self) -> usize {
        match self {
            Matrix::Dense(a) => a.nnz(),
            Matrix::Sparse(a) => a.nnz(),
        }
    }

    /// True if backed by CSC storage.
    pub fn is_sparse(&self) -> bool {
        matches!(self, Matrix::Sparse(_))
    }

    /// `out = Aᵀ r` — the correlation kernel (the paper's hot spot).
    pub fn at_r(&self, r: &[f64], out: &mut [f64]) {
        match self {
            Matrix::Dense(a) => a.at_r(r, out),
            Matrix::Sparse(a) => a.at_r(r, out),
        }
    }

    /// `out = A[:, cols] · w`.
    pub fn gemv_cols(&self, cols: &[usize], w: &[f64], out: &mut [f64]) {
        match self {
            Matrix::Dense(a) => a.gemv_cols(cols, w, out),
            Matrix::Sparse(a) => a.gemv_cols(cols, w, out),
        }
    }

    /// Gram block `A[:, ii]ᵀ A[:, jj]` (dense output).
    ///
    /// When the serving layer has bound a [`crate::kern::cache`] panel
    /// store for this matrix's shape (see
    /// [`crate::kern::cache::with_store`]), previously materialized
    /// panels are returned from the cache and fresh ones are recorded
    /// — warm-started refits of a model family repeat exactly the same
    /// `(ii, jj)` keys, so they skip the dominant recomputation. The
    /// shape guard keeps shard-local products (bLARS row slices) out
    /// of the full-matrix store.
    pub fn gram_block(&self, ii: &[usize], jj: &[usize]) -> DenseMatrix {
        if let Some(store) = kern::cache::bound_for((self.nrows(), self.ncols())) {
            if let Some(panel) = store.lookup(ii, jj) {
                return DenseMatrix::from_vec(ii.len(), jj.len(), panel.as_ref().clone());
            }
            let out = self.gram_block_uncached(ii, jj);
            store.insert(ii, jj, Arc::new(out.data().to_vec()));
            return out;
        }
        self.gram_block_uncached(ii, jj)
    }

    fn gram_block_uncached(&self, ii: &[usize], jj: &[usize]) -> DenseMatrix {
        match self {
            Matrix::Dense(a) => a.gram_block(ii, jj),
            Matrix::Sparse(a) => a.gram_block(ii, jj),
        }
    }

    /// Fused equiangular step (Algorithm 2, steps 10–11): `u = A[:,
    /// cols]·w` and `av = Aᵀu`. Dense storage runs the single-pass
    /// [`DenseMatrix::gemv_cols_at_r`] kernel; CSC falls back to the
    /// two-pass form (the scatter `u` must complete before the
    /// per-column gather dots can start), so both storages return the
    /// same pair with their own canonical orders.
    pub fn fused_step(&self, cols: &[usize], w: &[f64], u: &mut [f64], av: &mut [f64]) {
        match self {
            Matrix::Dense(a) => a.gemv_cols_at_r(cols, w, u, av),
            Matrix::Sparse(a) => {
                a.gemv_cols(cols, w, u);
                a.at_r(u, av);
            }
        }
    }

    /// Multi-response correlation kernel: `outs[k] = Aᵀ rs[k]` for a
    /// whole residual panel. Dense storage streams `A` once for the
    /// batch ([`DenseMatrix::at_r_multi`] — the blocked panel GEMM the
    /// batch fitter leans on); CSC falls back to per-response [`Self::at_r`]
    /// sweeps (same results, the sparse gather order is already
    /// per-column). At `k = 1` both storages are bit-identical to the
    /// single-response kernel.
    pub fn at_r_multi(&self, rs: &[&[f64]], outs: &mut [&mut [f64]]) {
        match self {
            Matrix::Dense(a) => a.at_r_multi(rs, outs),
            Matrix::Sparse(a) => {
                for (r, out) in rs.iter().zip(outs.iter_mut()) {
                    a.at_r(r, out);
                }
            }
        }
    }

    /// Multi-response fused equiangular step: per model `k`,
    /// `us[k] = A[:, cols[k]]·ws[k]` and `avs[k] = Aᵀ us[k]`. Dense
    /// storage shares one pass over `A` across the batch
    /// ([`DenseMatrix::fused_step_multi`]); CSC falls back to
    /// per-model [`Self::fused_step`]. At `k = 1` both storages are
    /// bit-identical to the single-response fused step.
    pub fn fused_step_multi(
        &self,
        cols: &[&[usize]],
        ws: &[&[f64]],
        us: &mut [&mut [f64]],
        avs: &mut [&mut [f64]],
    ) {
        match self {
            Matrix::Dense(a) => a.fused_step_multi(cols, ws, us, avs),
            Matrix::Sparse(a) => {
                for k in 0..cols.len() {
                    a.gemv_cols(cols[k], ws[k], &mut *us[k]);
                    a.at_r(&*us[k], &mut *avs[k]);
                }
            }
        }
    }

    /// Dot of column `j` with `r`.
    pub fn col_dot(&self, j: usize, r: &[f64]) -> f64 {
        match self {
            Matrix::Dense(a) => a.col_dot(j, r),
            Matrix::Sparse(a) => a.col_dot(j, r),
        }
    }

    /// `out[k] = A[:, cols[k]]ᵀ r` for a set of columns at once.
    ///
    /// Dense: streams rows once (contiguous) instead of one strided
    /// pass per column — 3-5x on tall matrices (§Perf L3 iteration 5);
    /// row chunks run on the pool with partials combined in chunk
    /// order (bit-identical across thread counts, fixed grain).
    /// Sparse CSC: independent per-column gather dots, column-chunked.
    pub fn cols_dot(&self, cols: &[usize], r: &[f64], out: &mut [f64]) {
        assert_eq!(cols.len(), out.len());
        match self {
            Matrix::Dense(a) => {
                assert_eq!(r.len(), a.nrows());
                let n = a.ncols();
                let grain = par::grain_for(cols.len());
                if a.nrows() <= grain {
                    out.fill(0.0);
                    kern::cols_dot_panel(a.data(), n, cols, r, out);
                    return;
                }
                let partials = par::map_chunks(a.nrows(), grain, |lo, hi| {
                    let mut acc = vec![0.0_f64; cols.len()];
                    kern::cols_dot_panel(
                        &a.data()[lo * n..hi * n],
                        n,
                        cols,
                        &r[lo..hi],
                        &mut acc,
                    );
                    acc
                });
                let (first, rest) =
                    // audit: allow(PANIC-REACH) -- map_chunks yields at least one partial for a matrix with nrows >= 1
                    partials.split_first().expect("nrows > grain implies chunks");
                out.copy_from_slice(first);
                for p in rest {
                    super::axpy(1.0, p, out);
                }
            }
            Matrix::Sparse(a) => {
                let grain = a.col_grain();
                par::for_chunks_mut(out, grain, |lo, chunk| {
                    for (k, o) in chunk.iter_mut().enumerate() {
                        *o = a.col_dot(cols[lo + k], r);
                    }
                });
            }
        }
    }

    /// ℓ2 norm of column `j`.
    pub fn col_norm(&self, j: usize) -> f64 {
        match self {
            Matrix::Dense(a) => a.col_norm(j),
            Matrix::Sparse(a) => a.col_norm(j),
        }
    }

    /// ℓ2 norms of every column at once — the pool-parallel form of a
    /// `col_norm` sweep.
    pub fn col_norms(&self) -> Vec<f64> {
        match self {
            Matrix::Dense(a) => a.col_norms(),
            Matrix::Sparse(a) => a.col_norms(),
        }
    }

    /// Unit-normalize all columns (paper assumption §5.2).
    pub fn normalize_columns(&mut self) {
        let _ = self.normalize_columns_with_norms();
    }

    /// Fused normalize returning the pre-normalization column norms
    /// (one norm sweep + one scaling pass instead of the old
    /// `col_norms` + `normalize_columns` pair).
    pub fn normalize_columns_with_norms(&mut self) -> Vec<f64> {
        match self {
            Matrix::Dense(a) => a.normalize_columns_with_norms(),
            Matrix::Sparse(a) => a.normalize_columns_with_norms(),
        }
    }

    /// Row slice `[r0, r1)` — a bLARS rank shard.
    pub fn row_slice(&self, r0: usize, r1: usize) -> Matrix {
        match self {
            Matrix::Dense(a) => Matrix::Dense(a.row_slice(r0, r1)),
            Matrix::Sparse(a) => Matrix::Sparse(a.row_slice(r0, r1)),
        }
    }

    /// Arbitrary row gather (`rows` ascending) — a cross-validation
    /// train/test shard ([`crate::select`]).
    pub fn row_subset(&self, rows: &[usize]) -> Matrix {
        match self {
            Matrix::Dense(a) => Matrix::Dense(a.row_subset(rows)),
            Matrix::Sparse(a) => Matrix::Sparse(a.row_subset(rows)),
        }
    }

    /// Column subset — a T-bLARS rank shard.
    pub fn col_subset(&self, cols: &[usize]) -> Matrix {
        match self {
            Matrix::Dense(a) => Matrix::Dense(a.col_subset(cols)),
            Matrix::Sparse(a) => Matrix::Sparse(a.col_subset(cols)),
        }
    }

    /// Per-column nnz (Figure 2).
    pub fn col_nnz_counts(&self) -> Vec<usize> {
        match self {
            Matrix::Dense(a) => (0..a.ncols())
                .map(|j| (0..a.nrows()).filter(|&i| a.get(i, j) != 0.0).count())
                .collect(),
            Matrix::Sparse(a) => a.col_nnz_counts(),
        }
    }

    /// Flop count charged for one `Aᵀr` on this storage (2·nnz).
    pub fn at_r_flops(&self) -> u64 {
        2 * self.nnz() as u64
    }

    /// Flop count charged for `A[:, cols]·w`.
    pub fn gemv_cols_flops(&self, cols: &[usize]) -> u64 {
        match self {
            Matrix::Dense(a) => 2 * (a.nrows() * cols.len()) as u64,
            Matrix::Sparse(a) => 2 * cols.iter().map(|&j| a.col_nnz(j) as u64).sum::<u64>(),
        }
    }

    /// Flop count charged for a Gram block.
    pub fn gram_block_flops(&self, ii: &[usize], jj: &[usize]) -> u64 {
        match self {
            Matrix::Dense(a) => 2 * (a.nrows() * ii.len() * jj.len()) as u64,
            Matrix::Sparse(a) => {
                let jnnz: u64 = jj.iter().map(|&j| a.col_nnz(j) as u64).sum();
                2 * ii.len() as u64 * jnnz
            }
        }
    }
}

impl From<DenseMatrix> for Matrix {
    fn from(a: DenseMatrix) -> Self {
        Matrix::Dense(a)
    }
}

impl From<CscMatrix> for Matrix {
    fn from(a: CscMatrix) -> Self {
        Matrix::Sparse(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (Matrix, Matrix) {
        let d = DenseMatrix::from_vec(3, 3, vec![1., 0., 2., 0., 3., 0., 4., 0., 5.]);
        let s = CscMatrix::from_dense(&d);
        (Matrix::Dense(d), Matrix::Sparse(s))
    }

    #[test]
    fn parity_at_r() {
        let (d, s) = pair();
        let r = vec![1.0, 2.0, -1.0];
        let (mut cd, mut cs) = (vec![0.0; 3], vec![0.0; 3]);
        d.at_r(&r, &mut cd);
        s.at_r(&r, &mut cs);
        assert_eq!(cd, cs);
    }

    #[test]
    fn parity_gram() {
        let (d, s) = pair();
        let gd = d.gram_block(&[0, 2], &[1, 2]);
        let gs = s.gram_block(&[0, 2], &[1, 2]);
        assert_eq!(gd, gs);
    }

    #[test]
    fn parity_shards() {
        let (d, s) = pair();
        let rd = d.row_slice(1, 3);
        let rs = s.row_slice(1, 3);
        assert_eq!(rd.nrows(), 2);
        assert_eq!(rs.nrows(), 2);
        let r = vec![1.0, 1.0];
        let (mut cd, mut cs) = (vec![0.0; 3], vec![0.0; 3]);
        rd.at_r(&r, &mut cd);
        rs.at_r(&r, &mut cs);
        assert_eq!(cd, cs);
    }

    #[test]
    fn parity_col_norms() {
        let (d, s) = pair();
        for (x, y) in d.col_norms().iter().zip(s.col_norms()) {
            assert!((x - y).abs() < 1e-15);
        }
    }

    #[test]
    fn flop_accounting_positive() {
        let (d, s) = pair();
        assert!(d.at_r_flops() > 0);
        assert!(s.at_r_flops() > 0);
        assert_eq!(s.at_r_flops(), 2 * s.nnz() as u64);
    }
}
