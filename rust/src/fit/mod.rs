//! The unified estimator API — one validated, fallible, extensible
//! entry point for the whole fitter family.
//!
//! The paper presents LARS, bLARS, and T-bLARS as one algorithm family
//! producing the same kind of output (a sequence of linear models);
//! this module gives them — plus LASSO-LARS and the greedy baselines —
//! one shape:
//!
//! * [`FitSpec`] — a validated, serializable description of a fit: an
//!   [`Algorithm`] plus the shared knobs (`t`, `tol`, simulated ranks,
//!   execution mode, hardware cost model).
//! * [`Fitter`] — `fit(&self, a, b, &mut dyn FitObserver) ->
//!   Result<FitResult>`; [`FitSpec`] implements it, and
//!   [`FitSpec::run`] is the no-observer convenience.
//! * [`FitObserver`] — composable per-iteration hooks
//!   ([`SnapshotObserver`], [`ProgressObserver`], [`EarlyStop`],
//!   [`MetricsSink`], [`TraceObserver`], [`MultiObserver`]); see
//!   [`observers`].
//! * [`FitResult`] — the algorithm's [`LarsOutput`] unified with
//!   timing, the exact LASSO path when applicable, and the simulated
//!   cluster telemetry ([`SimReport`]) for the parallel fitters.
//!
//! Invalid inputs come back as typed
//! [`crate::error::ErrorKind::InvalidSpec`] errors instead of the
//! `assert!` panics the legacy free functions used, so the serving
//! front end can answer HTTP 400 instead of dropping connections.
//!
//! ```no_run
//! use calars::data::datasets;
//! use calars::fit::{Algorithm, FitSpec};
//!
//! let ds = datasets::tiny(42);
//! let result = FitSpec::new(Algorithm::Blars { b: 4 })
//!     .t(20)
//!     .ranks(8)
//!     .run(&ds.a, &ds.b)
//!     .expect("valid spec");
//! println!("selected {:?}, stop {:?}", result.output.selected, result.output.stop);
//! ```

pub mod observers;

pub use observers::{
    EarlyStop, FitEvent, FitObserver, MetricsSink, MultiObserver, NoopObserver,
    ObserverControl, ProgressObserver, SnapshotObserver, TraceObserver,
};

// Model selection rides alongside the estimator API: a fitted path is
// a sequence of models, and [`SelectSpec`] picks which one to serve
// (see [`crate::select`] for the criteria and the CV machinery).
pub use crate::select::{Criterion, SelectSpec, Selection, StepScore};

use crate::cluster::{CommCounters, ExecMode, HwParams, SimCluster, Tracer};
use crate::data::partition;
use crate::error::{Error, Result};
use crate::lars::blars::{self, BlarsOptions};
use crate::lars::lasso_lars::{self, LassoPath};
use crate::lars::path::PathSnapshot;
use crate::lars::serial::{self, LarsOptions};
use crate::lars::tblars::{self, TblarsOptions};
use crate::lars::{LarsOutput, StopReason};
use crate::linalg::Matrix;
use crate::rng::Pcg64;
use std::time::Instant;

/// Which member of the fitter family a [`FitSpec`] runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Algorithm {
    /// Serial LARS (Algorithm 1).
    Lars,
    /// Parallel block LARS on row-partitioned data (Algorithm 2);
    /// `b` columns enter per iteration. Ranks come from the spec's
    /// `ranks` knob.
    Blars { b: usize },
    /// Tournament block LARS on column-partitioned data (Algorithm 3);
    /// `parts` ranks each nominate `b` candidates per round.
    TBlars { b: usize, parts: usize },
    /// LARS with the LASSO modification — the exact ℓ1 path, traced
    /// until λ falls below `lambda_min` (or `t` columns are active).
    LassoLars { lambda_min: f64 },
    /// Classic greedy forward selection (baseline, paper §2).
    ForwardSelection,
    /// Orthogonal matching pursuit (baseline, paper §2).
    Omp,
}

impl Algorithm {
    /// Canonical lower-case name (inverse of [`Self::from_parts`]).
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Lars => "lars",
            Algorithm::Blars { .. } => "blars",
            Algorithm::TBlars { .. } => "tblars",
            Algorithm::LassoLars { .. } => "lasso",
            Algorithm::ForwardSelection => "fs",
            Algorithm::Omp => "omp",
        }
    }

    /// Block size (1 for the non-blocked members).
    pub fn block(&self) -> usize {
        match self {
            Algorithm::Blars { b } => *b,
            Algorithm::TBlars { b, .. } => *b,
            _ => 1,
        }
    }

    /// Build an algorithm from loosely-typed request parts — the wire
    /// format and the CLI carry `algo`, `b`, `p`, and `lambda_min`
    /// flat; each variant takes what it needs.
    pub fn from_parts(name: &str, b: usize, p: usize, lambda_min: f64) -> Result<Algorithm> {
        match name {
            "lars" => Ok(Algorithm::Lars),
            "blars" => Ok(Algorithm::Blars { b }),
            "tblars" | "t-blars" => Ok(Algorithm::TBlars { b, parts: p }),
            "lasso" | "lasso-lars" => Ok(Algorithm::LassoLars { lambda_min }),
            "fs" | "forward" => Ok(Algorithm::ForwardSelection),
            "omp" => Ok(Algorithm::Omp),
            other => Err(Error::invalid_spec(format!(
                "unknown algorithm '{other}' (lars|blars|tblars|lasso|fs|omp)"
            ))),
        }
    }
}

/// A validated, serializable fit specification: the [`Algorithm`] plus
/// the knobs every fitter shares. Construct with [`FitSpec::new`] and
/// the builder methods; [`FitSpec::validate`] runs automatically at
/// fit time (and at [`FitSpec::parse`] time).
#[derive(Clone, Debug, PartialEq)]
pub struct FitSpec {
    pub algorithm: Algorithm,
    /// Target number of selected columns (the paper's `t`; for
    /// LASSO-LARS the maximum active-set size).
    pub t: usize,
    /// Numerical floor under which the maximum correlation counts as 0.
    pub tol: f64,
    /// Simulated cluster ranks for [`Algorithm::Blars`] (rounded up to
    /// a power of two; T-bLARS takes its rank count from `parts`).
    pub ranks: usize,
    /// Execution mode for simulated-cluster supersteps (threaded mode
    /// runs rank compute on the [`crate::par`] pool; results are
    /// identical either way).
    pub mode: ExecMode,
    /// Hardware cost model for the simulated cluster (not part of the
    /// wire encoding; programmatic sweeps set it via the `hw` builder
    /// method).
    pub hw: HwParams,
    /// T-bLARS column partition: `None` = nnz-balanced (the paper's
    /// default), `Some(seed)` = uniformly random (Figure 5).
    pub partition_seed: Option<u64>,
}

impl FitSpec {
    /// Upper bound on `t` accepted by [`Self::validate`].
    pub const MAX_T: usize = 1 << 24;
    /// Upper bound on block sizes.
    pub const MAX_BLOCK: usize = 1 << 20;
    /// Upper bound on simulated ranks / partitions.
    pub const MAX_RANKS: usize = 1 << 16;

    /// A spec with the default knobs (`t = 16`, `tol = 1e-12`, one
    /// rank, sequential mode, default hardware).
    pub fn new(algorithm: Algorithm) -> Self {
        FitSpec {
            algorithm,
            t: 16,
            tol: 1e-12,
            ranks: 1,
            mode: ExecMode::Sequential,
            hw: HwParams::default(),
            partition_seed: None,
        }
    }

    /// Set the target number of selected columns.
    pub fn t(mut self, t: usize) -> Self {
        self.t = t;
        self
    }

    /// Set the numerical floor.
    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Set the simulated rank count (bLARS).
    pub fn ranks(mut self, ranks: usize) -> Self {
        self.ranks = ranks;
        self
    }

    /// Set the superstep execution mode.
    pub fn mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }

    /// Set the hardware cost model.
    pub fn hw(mut self, hw: HwParams) -> Self {
        self.hw = hw;
        self
    }

    /// Set the T-bLARS partition seed (`None` = nnz-balanced).
    pub fn partition_seed(mut self, seed: Option<u64>) -> Self {
        self.partition_seed = seed;
        self
    }

    /// Check every knob; returns a typed
    /// [`crate::error::ErrorKind::InvalidSpec`] error on the first
    /// violation.
    pub fn validate(&self) -> Result<()> {
        if self.t == 0 || self.t > Self::MAX_T {
            return Err(Error::invalid_spec(format!(
                "t must be in 1..={} (got {})",
                Self::MAX_T,
                self.t
            )));
        }
        if !self.tol.is_finite() || self.tol < 0.0 {
            return Err(Error::invalid_spec(format!(
                "tol must be finite and ≥ 0 (got {})",
                self.tol
            )));
        }
        if self.ranks == 0 || self.ranks > Self::MAX_RANKS {
            return Err(Error::invalid_spec(format!(
                "ranks must be in 1..={} (got {})",
                Self::MAX_RANKS,
                self.ranks
            )));
        }
        match self.algorithm {
            Algorithm::Blars { b } => {
                if b == 0 || b > Self::MAX_BLOCK {
                    return Err(Error::invalid_spec(format!(
                        "block size b must be in 1..={} (got {b})",
                        Self::MAX_BLOCK
                    )));
                }
            }
            Algorithm::TBlars { b, parts } => {
                if b == 0 || b > Self::MAX_BLOCK {
                    return Err(Error::invalid_spec(format!(
                        "block size b must be in 1..={} (got {b})",
                        Self::MAX_BLOCK
                    )));
                }
                if parts == 0 || parts > Self::MAX_RANKS {
                    return Err(Error::invalid_spec(format!(
                        "parts must be in 1..={} (got {parts})",
                        Self::MAX_RANKS
                    )));
                }
            }
            Algorithm::LassoLars { lambda_min } => {
                if !lambda_min.is_finite() || lambda_min < 0.0 {
                    return Err(Error::invalid_spec(format!(
                        "lambda_min must be finite and ≥ 0 (got {lambda_min})"
                    )));
                }
            }
            Algorithm::Lars | Algorithm::ForwardSelection | Algorithm::Omp => {}
        }
        Ok(())
    }

    /// Simulated ranks the fit actually uses (normalized to a power of
    /// two — the registry's family identity uses this too).
    pub fn effective_ranks(&self) -> usize {
        match self.algorithm {
            Algorithm::TBlars { parts, .. } => parts.max(1).next_power_of_two(),
            Algorithm::Blars { .. } => self.ranks.max(1).next_power_of_two(),
            _ => 1,
        }
    }

    /// Canonical single-line serialization (`key=value` tokens).
    /// Covers everything that affects the fitted model; `hw` is
    /// deliberately excluded (it only shapes simulated timings) and
    /// [`Self::parse`] restores it to the default.
    pub fn encode(&self) -> String {
        let mut s = format!("algo={} t={} tol={}", self.algorithm.name(), self.t, self.tol);
        match self.algorithm {
            Algorithm::Blars { b } => {
                s.push_str(&format!(" b={b} ranks={}", self.ranks));
            }
            Algorithm::TBlars { b, parts } => {
                s.push_str(&format!(" b={b} parts={parts}"));
            }
            Algorithm::LassoLars { lambda_min } => {
                s.push_str(&format!(" lambda_min={lambda_min}"));
            }
            Algorithm::Lars | Algorithm::ForwardSelection | Algorithm::Omp => {}
        }
        if self.mode == ExecMode::Threaded {
            s.push_str(" mode=threaded");
        }
        if let Some(seed) = self.partition_seed {
            s.push_str(&format!(" partition_seed={seed}"));
        }
        s
    }

    /// Parse [`Self::encode`]'s format back into a validated spec.
    /// Unknown keys are rejected; `tol` round-trips bit-exactly (f64
    /// `Display` is shortest-round-trippable).
    pub fn parse(text: &str) -> Result<FitSpec> {
        fn field<T: std::str::FromStr>(v: &str, what: &str) -> Result<T> {
            v.parse()
                .map_err(|_| Error::invalid_spec(format!("bad {what} value '{v}'")))
        }
        let mut algo_name: Option<String> = None;
        let mut t = 16usize;
        let mut tol = 1e-12f64;
        let mut b = 1usize;
        let mut parts = 1usize;
        let mut ranks = 1usize;
        let mut lambda_min = 1e-6f64;
        let mut mode = ExecMode::Sequential;
        let mut partition_seed: Option<u64> = None;
        for tok in text.split_whitespace() {
            let Some((k, v)) = tok.split_once('=') else {
                return Err(Error::invalid_spec(format!("bad spec token '{tok}'")));
            };
            match k {
                "algo" => algo_name = Some(v.to_string()),
                "t" => t = field(v, "t")?,
                "tol" => tol = field(v, "tol")?,
                "b" => b = field(v, "b")?,
                "parts" => parts = field(v, "parts")?,
                "ranks" => ranks = field(v, "ranks")?,
                "lambda_min" => lambda_min = field(v, "lambda_min")?,
                "partition_seed" => partition_seed = Some(field(v, "partition_seed")?),
                "mode" => {
                    mode = match v {
                        "sequential" => ExecMode::Sequential,
                        "threaded" => ExecMode::Threaded,
                        other => {
                            return Err(Error::invalid_spec(format!(
                                "unknown mode '{other}' (sequential|threaded)"
                            )))
                        }
                    }
                }
                other => {
                    return Err(Error::invalid_spec(format!("unknown spec key '{other}'")))
                }
            }
        }
        let name = algo_name.ok_or_else(|| Error::invalid_spec("spec is missing 'algo='"))?;
        let algorithm = Algorithm::from_parts(&name, b, parts, lambda_min)?;
        let spec = FitSpec {
            algorithm,
            t,
            tol,
            ranks,
            mode,
            hw: HwParams::default(),
            partition_seed,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Convenience: fit with no observer attached.
    pub fn run(&self, a: &Matrix, b: &[f64]) -> Result<FitResult> {
        self.fit(a, b, &mut NoopObserver)
    }
}

/// Simulated-cluster telemetry for the parallel fitters (what the
/// experiment drivers chart).
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Simulated seconds (critical path under the α-β-γ model).
    pub sim_time: f64,
    /// Aggregate F/W/L counters.
    pub counters: CommCounters,
    /// Figure 7/8 categories: [mat products, step size, comm, wait,
    /// other].
    pub categories: [f64; 5],
    /// Full per-phase trace.
    pub tracer: Tracer,
}

impl SimReport {
    fn from_cluster(cluster: &SimCluster) -> Self {
        SimReport {
            sim_time: cluster.sim_time(),
            counters: cluster.counters(),
            categories: cluster.tracer().by_category(),
            tracer: cluster.tracer().clone(),
        }
    }
}

/// What a [`Fitter::fit`] call returns: the algorithm output plus
/// timing and algorithm-specific extras, one shape for the whole
/// family.
#[derive(Clone, Debug)]
pub struct FitResult {
    /// Selection order, residual trace, response estimate, and
    /// [`StopReason`] (shared by every fitter).
    pub output: LarsOutput,
    /// Final coefficients aligned with `output.selected`, for the
    /// fitters that compute them natively (the baselines). LARS-family
    /// fits recover coefficients per prefix via
    /// [`crate::lars::path::ls_coefficients`] / [`Self::snapshot`].
    pub coefs: Option<Vec<f64>>,
    /// The exact ℓ1 path ([`Algorithm::LassoLars`] only).
    pub lasso: Option<LassoPath>,
    /// Simulated-cluster telemetry ([`Algorithm::Blars`] /
    /// [`Algorithm::TBlars`] only).
    pub sim: Option<SimReport>,
    /// Wall-clock seconds spent fitting.
    pub wall_secs: f64,
}

impl FitResult {
    fn from_output(output: LarsOutput) -> Self {
        FitResult { output, coefs: None, lasso: None, sim: None, wall_secs: 0.0 }
    }

    /// Why the fit stopped.
    pub fn stop(&self) -> StopReason {
        self.output.stop
    }

    /// The selected columns, in selection order.
    pub fn selected(&self) -> &[usize] {
        &self.output.selected
    }

    /// Snapshot of the fitted path — what [`SnapshotObserver`]
    /// captures: exact λ breakpoints for LASSO-LARS, per-prefix LS
    /// coefficients otherwise.
    pub fn snapshot(&self, a: &Matrix, b: &[f64]) -> PathSnapshot {
        match &self.lasso {
            Some(path) => PathSnapshot::from_lasso(a.ncols(), path),
            None => PathSnapshot::from_fit(a, b, &self.output.selected),
        }
    }
}

/// The one call path every consumer uses: serve, CLI, experiments,
/// benches, and examples all fit through this trait.
pub trait Fitter {
    /// Run the fit on `(a, b)`, streaming per-iteration events to
    /// `obs`. Invalid inputs return typed errors
    /// ([`crate::error::ErrorKind::InvalidSpec`]) instead of
    /// panicking.
    fn fit(&self, a: &Matrix, b: &[f64], obs: &mut dyn FitObserver) -> Result<FitResult>;
}

impl Fitter for FitSpec {
    fn fit(&self, a: &Matrix, b: &[f64], obs: &mut dyn FitObserver) -> Result<FitResult> {
        self.validate()?;
        if a.nrows() < 2 || a.ncols() == 0 {
            return Err(Error::invalid_spec(format!(
                "matrix must have at least 2 rows and 1 column (got {}×{})",
                a.nrows(),
                a.ncols()
            )));
        }
        if b.len() != a.nrows() {
            return Err(Error::invalid_spec(format!(
                "response length {} does not match the matrix row count {}",
                b.len(),
                a.nrows()
            )));
        }
        // Degenerate-input screen (one O(nnz) pass): a NaN/∞ anywhere
        // in the problem, or an all-zero column, poisons correlations
        // deep inside the fitter cores — tournament shards used to
        // *panic* on the resulting incomparable NaNs. Reject up front
        // with a typed error instead.
        if let Some(i) = b.iter().position(|v| !v.is_finite()) {
            return Err(Error::invalid_spec(format!(
                "response contains a non-finite value at row {i} ({})",
                b[i]
            )));
        }
        // When a panel store for this exact shape is bound (serve-layer
        // fits of cached datasets, CV fold fits) its recorded
        // pre-normalization norms already witness every column: a zero
        // norm means the column was zero before normalization left it
        // untouched, a non-finite norm means the column held a NaN/∞.
        // Checking them is O(n); only uncached matrices pay the O(nnz)
        // sweep.
        let cached_norms =
            crate::kern::cache::bound_for((a.nrows(), a.ncols())).and_then(|s| s.norms());
        let col_norms = match cached_norms {
            Some(norms) if norms.len() == a.ncols() => norms,
            _ => std::sync::Arc::new(a.col_norms()),
        };
        if let Some(j) = col_norms.iter().position(|v| !v.is_finite() || *v == 0.0) {
            return Err(Error::invalid_spec(format!(
                "column {j} is degenerate (norm {}): all-zero or non-finite \
                 columns cannot enter a LARS path",
                col_norms[j]
            )));
        }
        obs.on_start(a.nrows(), a.ncols(), self);
        let t0 = Instant::now();
        // Algorithm-level span: nests under the request/fit root span
        // when a trace is bound, encloses every phase span the fitter
        // cores emit. Inert (one atomic load) otherwise.
        let algo_span = crate::obs::span(self.algorithm.name());
        let mut result = match self.algorithm {
            Algorithm::Lars => {
                let opts = LarsOptions { t: self.t, b: 1, tol: self.tol };
                FitResult::from_output(serial::fit_observed(a, b, &opts, obs)?)
            }
            Algorithm::Blars { b: block } => {
                let p = self.effective_ranks();
                let mut cluster = SimCluster::new(p, self.hw, self.mode);
                let opts = BlarsOptions { t: self.t, b: block, tol: self.tol };
                let out = blars::fit_observed(a, b, &opts, &mut cluster, obs)?;
                let mut r = FitResult::from_output(out);
                r.sim = Some(SimReport::from_cluster(&cluster));
                r
            }
            Algorithm::TBlars { b: block, parts } => {
                let p = parts.max(1).next_power_of_two();
                let partition = match self.partition_seed {
                    None => partition::balanced_col_partition(a, p),
                    Some(seed) => {
                        let mut rng = Pcg64::new(seed);
                        partition::random_col_partition(a.ncols(), p, &mut rng)
                    }
                };
                let mut cluster = SimCluster::new(p, self.hw, self.mode);
                let opts = TblarsOptions { t: self.t, b: block, tol: self.tol };
                let out = tblars::fit_observed(a, b, &partition, &opts, &mut cluster, obs)?;
                let mut r = FitResult::from_output(out);
                r.sim = Some(SimReport::from_cluster(&cluster));
                r
            }
            Algorithm::LassoLars { lambda_min } => {
                let fit = lasso_lars::fit_observed(a, b, self.t, lambda_min, self.tol, obs)?;
                let mut r = FitResult::from_output(fit.out);
                r.lasso = Some(fit.path);
                r
            }
            Algorithm::ForwardSelection => {
                let (out, coefs) =
                    crate::baselines::forward_selection::fit_observed(a, b, self.t, self.tol, obs)?;
                let mut r = FitResult::from_output(out);
                r.coefs = Some(coefs);
                r
            }
            Algorithm::Omp => {
                let (out, coefs) = crate::baselines::omp::fit_observed(a, b, self.t, self.tol, obs)?;
                let mut r = FitResult::from_output(out);
                r.coefs = Some(coefs);
                r
            }
        };
        drop(algo_span);
        result.wall_secs = t0.elapsed().as_secs_f64();
        obs.on_complete(a, b, &result);
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::ErrorKind;

    #[test]
    fn spec_encode_parse_round_trip() {
        let specs = [
            FitSpec::new(Algorithm::Lars).t(8),
            FitSpec::new(Algorithm::Blars { b: 4 }).t(60).ranks(8),
            FitSpec::new(Algorithm::TBlars { b: 2, parts: 16 }).t(30).partition_seed(Some(7)),
            FitSpec::new(Algorithm::LassoLars { lambda_min: 1e-5 }).t(12).tol(1e-10),
            FitSpec::new(Algorithm::Omp).t(5),
            FitSpec::new(Algorithm::ForwardSelection).t(5).mode(ExecMode::Threaded),
        ];
        for spec in specs {
            let enc = spec.encode();
            let back = FitSpec::parse(&enc)
                .unwrap_or_else(|e| panic!("parse of '{enc}' failed: {e:#}"));
            assert_eq!(back, spec, "round trip changed the spec for '{enc}'");
            assert_eq!(back.encode(), enc, "canonical form must be a fixpoint");
        }
    }

    #[test]
    fn validate_rejects_bad_knobs_with_invalid_spec_kind() {
        let bad = [
            FitSpec::new(Algorithm::Lars).t(0),
            FitSpec::new(Algorithm::Lars).tol(f64::NAN),
            FitSpec::new(Algorithm::Lars).ranks(0),
            FitSpec::new(Algorithm::Blars { b: 0 }),
            FitSpec::new(Algorithm::TBlars { b: 1, parts: 0 }),
            FitSpec::new(Algorithm::TBlars { b: 1, parts: FitSpec::MAX_RANKS + 1 }),
            FitSpec::new(Algorithm::LassoLars { lambda_min: -1.0 }),
        ];
        for spec in bad {
            let err = spec.validate().expect_err("spec must be rejected");
            assert_eq!(err.kind(), ErrorKind::InvalidSpec, "{err:#}");
        }
        assert!(FitSpec::new(Algorithm::Lars).validate().is_ok());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FitSpec::parse("").is_err(), "missing algo");
        assert!(FitSpec::parse("algo=nope").is_err());
        assert!(FitSpec::parse("algo=lars bogus=1").is_err());
        assert!(FitSpec::parse("algo=lars t=zero").is_err());
        assert!(FitSpec::parse("algo=lars noequals").is_err());
        assert!(FitSpec::parse("algo=lars t=0").is_err(), "parse validates");
    }

    #[test]
    fn effective_ranks_normalizes() {
        assert_eq!(FitSpec::new(Algorithm::Lars).ranks(7).effective_ranks(), 1);
        assert_eq!(FitSpec::new(Algorithm::Blars { b: 1 }).ranks(5).effective_ranks(), 8);
        assert_eq!(
            FitSpec::new(Algorithm::TBlars { b: 1, parts: 3 }).effective_ranks(),
            4
        );
    }

    #[test]
    fn from_parts_covers_the_family() {
        assert_eq!(Algorithm::from_parts("lars", 1, 1, 0.0).unwrap(), Algorithm::Lars);
        assert_eq!(
            Algorithm::from_parts("blars", 3, 1, 0.0).unwrap(),
            Algorithm::Blars { b: 3 }
        );
        assert_eq!(
            Algorithm::from_parts("tblars", 2, 8, 0.0).unwrap(),
            Algorithm::TBlars { b: 2, parts: 8 }
        );
        assert_eq!(
            Algorithm::from_parts("lasso", 1, 1, 1e-4).unwrap(),
            Algorithm::LassoLars { lambda_min: 1e-4 }
        );
        assert_eq!(Algorithm::from_parts("omp", 1, 1, 0.0).unwrap(), Algorithm::Omp);
        assert_eq!(
            Algorithm::from_parts("fs", 1, 1, 0.0).unwrap(),
            Algorithm::ForwardSelection
        );
        let err = Algorithm::from_parts("ridge", 1, 1, 0.0).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
    }

    #[test]
    fn fit_rejects_mismatched_response_length() {
        let ds = crate::data::datasets::tiny(1);
        let short = vec![0.0; ds.a.nrows() - 1];
        let err = FitSpec::new(Algorithm::Lars).t(4).run(&ds.a, &short).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec, "{err:#}");
    }
}
