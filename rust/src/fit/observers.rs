//! Composable fit observers — the hook layer of the unified estimator
//! API.
//!
//! A [`FitObserver`] receives per-iteration callbacks from every fitter
//! behind [`super::Fitter::fit`] (serial LARS, bLARS, T-bLARS,
//! LASSO-LARS, and the baselines), carrying the active set, the step
//! size γ, the residual norm, and the current regularization level.
//! Cross-cutting behaviors — path snapshotting for the serving layer,
//! progress reporting, early stopping, metrics collection — compose as
//! observers instead of forking the fitter signatures (which is how the
//! repo grew four copy-pasted `*_with_snapshot` entry points before
//! this API existed).
//!
//! Observers are passive with respect to the arithmetic: emitting an
//! event never changes a bit of the fit. The only influence an observer
//! has is the [`ObserverControl::Stop`] return, which ends the run with
//! [`StopReason::EarlyStopped`].

use super::{FitResult, FitSpec};
use crate::lars::path::PathSnapshot;
use crate::lars::StopReason;
use crate::linalg::Matrix;

/// Returned by [`FitObserver::on_iteration`]: keep going or stop the
/// fit after this iteration (the fitter reports
/// [`StopReason::EarlyStopped`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObserverControl {
    Continue,
    Stop,
}

/// One per-iteration event. Fields that have no meaning for a given
/// algorithm are `f64::NAN` (T-bLARS has no scalar γ per outer
/// iteration; the greedy baselines have no γ at all).
#[derive(Clone, Debug)]
pub struct FitEvent<'a> {
    /// Event index, 0-based, monotonically increasing within a fit.
    pub iter: usize,
    /// Active set after this iteration, in selection order.
    pub selected: &'a [usize],
    /// Step size taken this iteration (NaN where undefined).
    pub gamma: f64,
    /// ‖r‖₂ after this iteration.
    pub residual_norm: f64,
    /// Current regularization level — the tracked maximal absolute
    /// correlation scale (NaN where undefined).
    pub lambda: f64,
}

/// Per-iteration hooks shared by every fitter behind the
/// [`super::Fitter`] trait. All methods have no-op defaults; implement
/// only what you need.
pub trait FitObserver {
    /// Called once before the fit starts.
    fn on_start(&mut self, _m: usize, _n: usize, _spec: &FitSpec) {}

    /// Called after each iteration; return [`ObserverControl::Stop`]
    /// to end the fit with [`StopReason::EarlyStopped`].
    fn on_iteration(&mut self, _event: &FitEvent<'_>) -> ObserverControl {
        ObserverControl::Continue
    }

    /// Called once after the fit completes, with the problem data and
    /// the final result (before the result is returned to the caller).
    fn on_complete(&mut self, _a: &Matrix, _b: &[f64], _result: &FitResult) {}
}

/// The do-nothing observer ([`FitSpec::run`] uses it).
pub struct NoopObserver;

impl FitObserver for NoopObserver {}

/// Captures a [`PathSnapshot`] of the fitted path for the serving
/// layer — the replacement for the deleted `*_with_snapshot` entry
/// points. For LASSO-LARS fits the snapshot preserves the exact λ
/// breakpoints; for selection fits it stores the LS coefficients of
/// every prefix, bit-identical to what `lars_with_snapshot` produced.
#[derive(Default)]
pub struct SnapshotObserver {
    snapshot: Option<PathSnapshot>,
}

impl SnapshotObserver {
    pub fn new() -> Self {
        SnapshotObserver { snapshot: None }
    }

    /// The captured snapshot, if the fit completed.
    pub fn snapshot(&self) -> Option<&PathSnapshot> {
        self.snapshot.as_ref()
    }

    /// Consume the observer, yielding the captured snapshot.
    pub fn into_snapshot(self) -> Option<PathSnapshot> {
        self.snapshot
    }
}

impl FitObserver for SnapshotObserver {
    fn on_complete(&mut self, a: &Matrix, b: &[f64], result: &FitResult) {
        self.snapshot = Some(result.snapshot(a, b));
    }
}

/// Prints a progress line to stderr every `every` iterations plus a
/// completion summary (`calars run --progress`).
pub struct ProgressObserver {
    every: usize,
    /// Progress lines emitted so far (inspectable in tests).
    pub emitted: usize,
}

impl ProgressObserver {
    /// Report every iteration.
    pub fn new() -> Self {
        Self::every(1)
    }

    /// Report every `every`-th iteration (≥ 1).
    pub fn every(every: usize) -> Self {
        ProgressObserver { every: every.max(1), emitted: 0 }
    }
}

impl Default for ProgressObserver {
    fn default() -> Self {
        Self::new()
    }
}

impl FitObserver for ProgressObserver {
    fn on_iteration(&mut self, ev: &FitEvent<'_>) -> ObserverControl {
        if ev.iter % self.every == 0 {
            eprintln!(
                "[fit] iter {:>4}  |I|={:<5}  γ={:<14.6e}  ‖r‖={:.6e}",
                ev.iter,
                ev.selected.len(),
                ev.gamma,
                ev.residual_norm
            );
            self.emitted += 1;
        }
        ObserverControl::Continue
    }

    fn on_complete(&mut self, _a: &Matrix, _b: &[f64], result: &FitResult) {
        eprintln!(
            "[fit] done: {} columns, stop={:?}, {:.3}s",
            result.output.selected.len(),
            result.output.stop,
            result.wall_secs
        );
    }
}

/// Stops a fit early: after a fixed number of iterations, when the
/// residual falls below a target, or when an iteration fails to shrink
/// the residual by a minimum relative amount. Unset criteria never
/// trigger.
#[derive(Clone, Debug, Default)]
pub struct EarlyStop {
    /// Stop after this many iterations (events).
    pub max_iterations: Option<usize>,
    /// Stop once ‖r‖₂ ≤ this value.
    pub target_residual: Option<f64>,
    /// Stop when an iteration shrinks ‖r‖₂ by less than this relative
    /// fraction (e.g. `0.01` = require ≥ 1% improvement per step).
    pub min_decrease: Option<f64>,
    last_residual: Option<f64>,
}

impl EarlyStop {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stop after `n` iterations.
    pub fn after_iterations(n: usize) -> Self {
        EarlyStop { max_iterations: Some(n), ..Self::default() }
    }

    /// Stop once the residual norm reaches `r`.
    pub fn at_residual(r: f64) -> Self {
        EarlyStop { target_residual: Some(r), ..Self::default() }
    }

    /// Stop when progress stalls below `min_decrease` relative
    /// improvement per iteration.
    pub fn when_stalled(min_decrease: f64) -> Self {
        EarlyStop { min_decrease: Some(min_decrease), ..Self::default() }
    }
}

impl FitObserver for EarlyStop {
    fn on_iteration(&mut self, ev: &FitEvent<'_>) -> ObserverControl {
        let mut stop = false;
        if let Some(n) = self.max_iterations {
            if ev.iter + 1 >= n {
                stop = true;
            }
        }
        if let Some(target) = self.target_residual {
            if ev.residual_norm <= target {
                stop = true;
            }
        }
        if let Some(min) = self.min_decrease {
            if let Some(prev) = self.last_residual {
                if prev.is_finite() && ev.residual_norm > prev * (1.0 - min) {
                    stop = true;
                }
            }
        }
        self.last_residual = Some(ev.residual_norm);
        if stop {
            ObserverControl::Stop
        } else {
            ObserverControl::Continue
        }
    }
}

/// Accumulates per-iteration metrics (γ trace, residual trace, support
/// growth) plus the final stop reason and wall time — the estimator
/// API's counterpart to the experiment drivers' ad-hoc collection.
#[derive(Clone, Debug, Default)]
pub struct MetricsSink {
    pub gammas: Vec<f64>,
    pub residual_norms: Vec<f64>,
    pub lambdas: Vec<f64>,
    pub support_sizes: Vec<usize>,
    pub iterations: usize,
    pub wall_secs: f64,
    pub stop: Option<StopReason>,
}

impl MetricsSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Machine-readable export of the collected trace.
    ///
    /// NaN/±∞ values serialize as `null`, never as bare `NaN`/`inf`
    /// tokens (which are invalid JSON): T-bLARS events legitimately
    /// carry NaN for γ and λ — the tournament has no scalar step size
    /// per outer iteration — and the greedy baselines carry NaN γ
    /// throughout. Regression-tested in `tests/fit.rs`.
    pub fn to_json(&self) -> String {
        let arr = |v: &[f64]| {
            v.iter().map(|&x| crate::metrics::json_f64(x)).collect::<Vec<_>>().join(",")
        };
        format!(
            "{{\"iterations\":{},\"wall_secs\":{},\"stop\":{},\
             \"gammas\":[{}],\"lambdas\":[{}],\"residual_norms\":[{}],\"support_sizes\":[{}]}}",
            self.iterations,
            crate::metrics::json_f64(self.wall_secs),
            match self.stop {
                Some(s) => format!("\"{}\"", s.word()),
                None => "null".to_string(),
            },
            arr(&self.gammas),
            arr(&self.lambdas),
            arr(&self.residual_norms),
            self.support_sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(","),
        )
    }
}

impl FitObserver for MetricsSink {
    fn on_iteration(&mut self, ev: &FitEvent<'_>) -> ObserverControl {
        self.iterations += 1;
        self.gammas.push(ev.gamma);
        self.residual_norms.push(ev.residual_norm);
        self.lambdas.push(ev.lambda);
        self.support_sizes.push(ev.selected.len());
        ObserverControl::Continue
    }

    fn on_complete(&mut self, _a: &Matrix, _b: &[f64], result: &FitResult) {
        self.wall_secs = result.wall_secs;
        self.stop = Some(result.output.stop);
    }
}

/// Binds an [`crate::obs`] trace to the fitting thread for the
/// duration of one fit and records a root `fit` span, so CLI and bench
/// fits produce the same span trees as served requests (the serving
/// queue binds the request's trace around the whole job instead).
///
/// Like every observer this is passive: it reads the clock and the
/// thread-local trace binding, never a bit of the fit.
pub struct TraceObserver {
    trace: u64,
    /// Previous thread binding, present only between `on_start` and
    /// `on_complete` (restored by `Drop` if the fit errors out).
    prev: Option<u64>,
    /// The root `fit` span, open for the duration of the fit so every
    /// phase span nests beneath it.
    guard: Option<crate::obs::SpanGuard>,
}

impl TraceObserver {
    /// Observe under a freshly minted trace id.
    pub fn new() -> Self {
        TraceObserver { trace: crate::obs::next_trace_id(), prev: None, guard: None }
    }

    /// Observe under an existing trace (e.g. a served request's id).
    pub fn for_trace(trace: u64) -> Self {
        TraceObserver { trace, prev: None, guard: None }
    }

    /// The trace id this observer records under — look spans up in
    /// [`crate::obs::sink`] after the fit.
    pub fn trace_id(&self) -> u64 {
        self.trace
    }

    fn detach(&mut self) {
        // Close the root span before releasing the binding so it is
        // flushed with everything else.
        self.guard = None;
        if let Some(prev) = self.prev.take() {
            crate::obs::uninstall_trace(prev);
        }
    }
}

impl Default for TraceObserver {
    fn default() -> Self {
        Self::new()
    }
}

impl FitObserver for TraceObserver {
    fn on_start(&mut self, _m: usize, _n: usize, _spec: &FitSpec) {
        self.prev = Some(crate::obs::install_trace(self.trace));
        self.guard = Some(crate::obs::span("fit"));
    }

    fn on_complete(&mut self, _a: &Matrix, _b: &[f64], _result: &FitResult) {
        self.detach();
    }
}

impl Drop for TraceObserver {
    fn drop(&mut self) {
        self.detach();
    }
}

/// Fans events out to several observers — the composition glue. The
/// fit stops if *any* member requests it; every member still sees every
/// event.
#[derive(Default)]
pub struct MultiObserver<'a> {
    observers: Vec<&'a mut dyn FitObserver>,
}

impl<'a> MultiObserver<'a> {
    pub fn new() -> Self {
        MultiObserver { observers: Vec::new() }
    }

    /// Add an observer (builder style).
    pub fn with(mut self, obs: &'a mut dyn FitObserver) -> Self {
        self.observers.push(obs);
        self
    }
}

impl FitObserver for MultiObserver<'_> {
    fn on_start(&mut self, m: usize, n: usize, spec: &FitSpec) {
        for o in self.observers.iter_mut() {
            o.on_start(m, n, spec);
        }
    }

    fn on_iteration(&mut self, ev: &FitEvent<'_>) -> ObserverControl {
        let mut ctl = ObserverControl::Continue;
        for o in self.observers.iter_mut() {
            if o.on_iteration(ev) == ObserverControl::Stop {
                ctl = ObserverControl::Stop;
            }
        }
        ctl
    }

    fn on_complete(&mut self, a: &Matrix, b: &[f64], result: &FitResult) {
        for o in self.observers.iter_mut() {
            o.on_complete(a, b, result);
        }
    }
}
