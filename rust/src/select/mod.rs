//! Path-wise model selection — choosing **which** model on a fitted
//! path to serve.
//!
//! The paper's algorithms produce a *sequence* of linear models (one
//! per path step) "without any compromise in solution quality"; the
//! discussion literature on LARS (Madigan & Ridgeway's discussion of
//! *Least Angle Regression*; see PAPERS.md) centers exactly on
//! path-wise selection: Cp-style in-sample criteria and out-of-sample
//! validation. This module implements both over the existing
//! [`PathSnapshot`] storage unit:
//!
//! * **In-sample criteria** ([`rank_steps`]): Mallows' Cp, AIC, and
//!   BIC computed per stored step from the step's residual norm with
//!   `df = |active set|` — the degrees-of-freedom identity that makes
//!   LARS-family paths special (Efron et al. §4).
//! * **k-fold cross-validation** ([`cross_validate`]): rows are split
//!   into `k` seeded folds ([`crate::data::partition::cv_folds`]), one
//!   path is fitted per training complement, and every step is scored
//!   by held-out mean squared error. Fold fits fan out on the
//!   [`crate::par`] pool and fold results combine in fixed fold order,
//!   so the selected step (and every score bit) is identical at any
//!   `CALARS_THREADS` setting.
//!
//! Fold fits renormalize the training columns (a row subset of a
//! unit-norm design is no longer unit-norm) and drop columns whose
//! mass lives entirely in the held-out fold — the [`crate::fit`] API
//! rejects all-zero columns by design. Held-out predictions are then
//! evaluated in the *raw* column scale (`coef / fold_norm`), so the
//! scores measure exactly what serving a refit model would deliver.
//!
//! The serving layer wires this through [`cross_validate_with`]: its
//! fold-fit hook binds each fold to a
//! [`crate::serve::GramCache`]-registered panel store, so repeated or
//! deeper selections of the same model family reuse the fold Gram
//! panels instead of recomputing them (see `serve::http`'s `/select`).

use crate::data::partition;
use crate::error::{Error, Result};
use crate::fit::{FitSpec, Fitter, SnapshotObserver};
use crate::lars::path::PathSnapshot;
use crate::linalg::Matrix;
use crate::par;

/// Which model-selection rule to apply along a fitted path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Criterion {
    /// Mallows' Cp: `RSS_k/σ̂² − m + 2·df_k`, σ̂² plugged in from the
    /// fullest stored model.
    Cp,
    /// Akaike: `m·ln(RSS_k/m) + 2·df_k`.
    Aic,
    /// Schwarz/Bayesian: `m·ln(RSS_k/m) + ln(m)·df_k`.
    Bic,
    /// k-fold cross-validated held-out MSE (needs the training data —
    /// see [`cross_validate`]; rejected by [`rank_steps`]).
    Cv,
}

impl Criterion {
    /// Stable lower-case identifier (wire formats, CLI, metadata
    /// tokens). Inverse of [`Self::from_name`].
    pub fn name(self) -> &'static str {
        match self {
            Criterion::Cp => "cp",
            Criterion::Aic => "aic",
            Criterion::Bic => "bic",
            Criterion::Cv => "cv",
        }
    }

    /// Parse a [`Self::name`] identifier.
    pub fn from_name(s: &str) -> Result<Criterion> {
        match s {
            "cp" => Ok(Criterion::Cp),
            "aic" => Ok(Criterion::Aic),
            "bic" => Ok(Criterion::Bic),
            "cv" => Ok(Criterion::Cv),
            other => Err(Error::invalid_spec(format!(
                "unknown criterion '{other}' (cp|aic|bic|cv)"
            ))),
        }
    }

    /// True for the criteria computable from a stored snapshot alone.
    pub fn is_in_sample(self) -> bool {
        !matches!(self, Criterion::Cv)
    }
}

/// A validated model-selection specification: the [`Criterion`] plus
/// the cross-validation knobs (`k` folds, fold-assignment `seed`) —
/// the selection-side sibling of [`FitSpec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SelectSpec {
    pub criterion: Criterion,
    /// Fold count for [`Criterion::Cv`] (ignored by the in-sample
    /// criteria).
    pub k: usize,
    /// Fold-assignment seed ([`partition::cv_folds`]).
    pub seed: u64,
}

impl SelectSpec {
    /// Upper bound on `k` accepted by [`Self::validate`]. Deliberately
    /// small: each fold is a near-full copy of the training problem
    /// (the serving layer caches k fold shards per CV selection), and
    /// statistical practice tops out near leave-some-out with tens of
    /// folds.
    pub const MAX_K: usize = 64;

    /// A spec with the default CV knobs (`k = 5`, `seed = 0`).
    pub fn new(criterion: Criterion) -> Self {
        SelectSpec { criterion, k: 5, seed: 0 }
    }

    /// Set the fold count.
    pub fn k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    /// Set the fold-assignment seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Check the knobs; typed
    /// [`crate::error::ErrorKind::InvalidSpec`] on violation.
    pub fn validate(&self) -> Result<()> {
        if self.criterion == Criterion::Cv && !(2..=Self::MAX_K).contains(&self.k) {
            return Err(Error::invalid_spec(format!(
                "cv fold count k must be in 2..={} (got {})",
                Self::MAX_K,
                self.k
            )));
        }
        Ok(())
    }

    /// The metadata token key this spec selects under — `"cp"`,
    /// `"aic"`, `"bic"`, or `"cv{k}.{seed}"` (CV results are keyed by
    /// their fold geometry; a different `k` or `seed` is a different
    /// selection).
    pub fn token_key(&self) -> String {
        match self.criterion {
            Criterion::Cv => format!("cv{}.{}", self.k, self.seed),
            c => c.name().to_string(),
        }
    }
}

/// One scored path step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepScore {
    /// Breakpoint index into the snapshot (0 = empty model).
    pub step: usize,
    /// Degrees of freedom charged: the step's active-set size
    /// (in-sample criteria) or the step index (CV).
    pub df: usize,
    /// Criterion value — smaller is better for every criterion.
    pub score: f64,
}

/// The result of ranking a path: the chosen step plus the full score
/// trace (what the CLI prints and `/select` returns).
#[derive(Clone, Debug, PartialEq)]
pub struct Selection {
    pub criterion: Criterion,
    /// The chosen breakpoint (argmin score; ties break toward the
    /// smaller — more regularized — step).
    pub best_step: usize,
    /// Per-step scores, ascending step order.
    pub scores: Vec<StepScore>,
    /// Fold count (0 for in-sample criteria).
    pub k: usize,
    /// Fold seed (0 for in-sample criteria).
    pub seed: u64,
}

/// Smallest non-NaN score, ties toward the smaller step.
fn best_step(scores: &[StepScore]) -> Result<usize> {
    let mut best: Option<(f64, usize)> = None;
    for sc in scores {
        if sc.score.is_nan() {
            continue;
        }
        let better = match best {
            None => true,
            Some((b, _)) => sc.score.total_cmp(&b) == std::cmp::Ordering::Less,
        };
        if better {
            best = Some((sc.score, sc.step));
        }
    }
    best.map(|(_, s)| s)
        .ok_or_else(|| Error::invalid_spec("every criterion score is NaN — degenerate path"))
}

/// Rank every stored step of a path by an **in-sample** criterion.
/// `m` is the number of training rows the path was fitted on (the
/// serving layer keeps it in the model metadata). [`Criterion::Cv`]
/// is rejected here — it needs the data, not just the path.
pub fn rank_steps(snap: &PathSnapshot, m: usize, criterion: Criterion) -> Result<Selection> {
    if criterion == Criterion::Cv {
        return Err(Error::invalid_spec(
            "cv needs the training data — use select::cross_validate",
        ));
    }
    if snap.is_empty() {
        return Err(Error::invalid_spec("cannot rank an empty path snapshot"));
    }
    if m == 0 {
        return Err(Error::invalid_spec(
            "training row count unknown (m = 0); refit to record it",
        ));
    }
    let mf = m as f64;
    let Some(last) = snap.steps.last() else {
        return Err(Error::internal("path snapshot has no steps; refit to record a path"));
    };
    let df_last = last.support.len();
    // Cp's plug-in noise estimate from the fullest stored model.
    let sigma2 = (last.residual_norm * last.residual_norm)
        / m.saturating_sub(df_last).max(1) as f64;
    if criterion == Criterion::Cp && !(sigma2.is_finite() && sigma2 > 0.0) {
        return Err(Error::invalid_spec(format!(
            "Cp is undefined on this path (σ̂² = {sigma2}); use aic, bic, or cv"
        )));
    }
    let scores: Vec<StepScore> = snap
        .steps
        .iter()
        .enumerate()
        .map(|(s, st)| {
            let df = st.support.len();
            let rss = st.residual_norm * st.residual_norm;
            let score = match criterion {
                Criterion::Cp => rss / sigma2 - mf + 2.0 * df as f64,
                Criterion::Aic => mf * (rss / mf).ln() + 2.0 * df as f64,
                Criterion::Bic => mf * (rss / mf).ln() + mf.ln() * df as f64,
                // audit: allow(PANIC-REACH) -- Cv is rejected at rank_steps entry, so this arm is genuinely unreachable
                Criterion::Cv => unreachable!("rejected above"),
            };
            StepScore { step: s, df, score }
        })
        .collect();
    let best = best_step(&scores)?;
    Ok(Selection { criterion, best_step: best, scores, k: 0, seed: 0 })
}

/// Everything a fold-fit hook sees for one fold: the renormalized
/// training shard plus the bookkeeping needed to map it back to the
/// full design. [`cross_validate_with`] owns the construction; the
/// hook only decides *how* to run the fit (the serving layer binds a
/// Gram panel store around it).
pub struct FoldFit<'a> {
    /// Fold index, `0..k`.
    pub fold: usize,
    /// Training design: rows = the fold's complement, columns = `kept`,
    /// renormalized to unit column norm.
    pub a: &'a Matrix,
    /// Training response rows.
    pub b: &'a [f64],
    /// Pre-renormalization column norms of the kept columns (divide
    /// fitted coefficients by these to predict in the raw scale).
    pub norms: &'a [f64],
    /// Kept column indices in full-design column space (columns whose
    /// mass survived the row split).
    pub kept: &'a [usize],
}

/// The default fold fit: run the spec through the estimator API with a
/// snapshot observer.
pub fn fit_fold_snapshot(ctx: &FoldFit<'_>, fit: &FitSpec) -> Result<PathSnapshot> {
    let mut obs = SnapshotObserver::new();
    fit.fit(ctx.a, ctx.b, &mut obs)?;
    obs.into_snapshot()
        .ok_or_else(|| Error::internal("fit returned Ok without completing a snapshot"))
}

/// k-fold cross-validation of a fit spec on `(a, b)` with the default
/// fold fit. See [`cross_validate_with`] for the mechanics.
pub fn cross_validate(
    a: &Matrix,
    b: &[f64],
    fit: &FitSpec,
    sel: &SelectSpec,
) -> Result<Selection> {
    cross_validate_with(a, b, fit, sel, fit_fold_snapshot)
}

/// k-fold cross-validation with a caller-supplied fold-fit hook.
///
/// Folds come from [`partition::cv_folds`]`(m, k, seed)`; per fold the
/// training complement is gathered ([`Matrix::row_subset`]), columns
/// that lost all their mass are dropped, the rest renormalize, and
/// `fold_fit` produces the fold's path. Every stored step is then
/// scored by held-out squared error in the raw column scale. Fold
/// tasks fork onto the [`crate::par`] pool; scores combine in fixed
/// fold order, so the result is bit-identical at any thread count.
///
/// The returned scores cover the step range every fold reached
/// (shorter fold paths truncate the comparison — scoring a step no
/// fold fitted would be meaningless).
pub fn cross_validate_with<F>(
    a: &Matrix,
    b: &[f64],
    fit: &FitSpec,
    sel: &SelectSpec,
    fold_fit: F,
) -> Result<Selection>
where
    F: Fn(&FoldFit<'_>, &FitSpec) -> Result<PathSnapshot> + Sync,
{
    fit.validate()?;
    sel.validate()?;
    if sel.criterion != Criterion::Cv {
        return Err(Error::invalid_spec(format!(
            "cross_validate needs Criterion::Cv (got {})",
            sel.criterion.name()
        )));
    }
    let m = a.nrows();
    if b.len() != m {
        return Err(Error::invalid_spec(format!(
            "response length {} does not match the matrix row count {m}",
            b.len()
        )));
    }
    if sel.k > m {
        return Err(Error::invalid_spec(format!(
            "cv fold count {} exceeds the row count {m}",
            sel.k
        )));
    }
    let folds = partition::cv_folds(m, sel.k, sel.seed);
    let hook = &fold_fit;
    let tasks: Vec<_> = folds
        .iter()
        .enumerate()
        .map(|(fi, test_rows)| {
            move || -> Result<Vec<f64>> {
                // Training complement (sorted by construction).
                let mut is_test = vec![false; m];
                for &r in test_rows.iter() {
                    is_test[r] = true;
                }
                let train_rows: Vec<usize> = (0..m).filter(|&r| !is_test[r]).collect();
                let mut a_train = a.row_subset(&train_rows);
                let b_train: Vec<f64> = train_rows.iter().map(|&r| b[r]).collect();
                // One fused pass: normalize AND collect the
                // pre-normalization norms (zero columns are left
                // untouched by the normalize kernel). Columns whose
                // nonzeros all fell into the held-out fold are
                // degenerate in the training shard; drop them (the fit
                // API rejects zero-norm columns by design). Per-column
                // scaling is independent of the other columns, so
                // normalizing before the subset is bit-identical to
                // normalizing after it.
                let pre = a_train.normalize_columns_with_norms();
                let kept: Vec<usize> =
                    (0..a_train.ncols()).filter(|&j| pre[j].is_finite() && pre[j] > 0.0).collect();
                let norms: Vec<f64> = if kept.len() < a_train.ncols() {
                    a_train = a_train.col_subset(&kept);
                    kept.iter().map(|&j| pre[j]).collect()
                } else {
                    pre
                };
                let ctx =
                    FoldFit { fold: fi, a: &a_train, b: &b_train, norms: &norms, kept: &kept };
                let snap = hook(&ctx, fit)?;
                // Held-out RSS per step, predicting in the raw scale.
                let a_test = a.row_subset(test_rows);
                let b_test: Vec<f64> = test_rows.iter().map(|&r| b[r]).collect();
                let mut yhat = vec![0.0; test_rows.len()];
                let mut rss = Vec::with_capacity(snap.len());
                for step in &snap.steps {
                    let support_full: Vec<usize> =
                        step.support.iter().map(|&j| kept[j]).collect();
                    let w: Vec<f64> = step
                        .support
                        .iter()
                        .zip(&step.coefs)
                        .map(|(&j, &c)| c / norms[j])
                        .collect();
                    a_test.gemv_cols(&support_full, &w, &mut yhat);
                    let r: f64 =
                        yhat.iter().zip(&b_test).map(|(p, q)| (p - q) * (p - q)).sum();
                    rss.push(r);
                }
                Ok(rss)
            }
        })
        .collect();
    let mut per_fold: Vec<Vec<f64>> = Vec::with_capacity(folds.len());
    for r in par::run_tasks(tasks) {
        per_fold.push(r?);
    }
    let nsteps = per_fold.iter().map(|v| v.len()).min().unwrap_or(0);
    if nsteps == 0 {
        return Err(Error::invalid_spec(
            "cross-validation produced no comparable path steps",
        ));
    }
    // Fixed fold-order summation keeps every score bit independent of
    // the pool's scheduling.
    let scores: Vec<StepScore> = (0..nsteps)
        .map(|s| {
            let mut rss = 0.0;
            for f in &per_fold {
                rss += f[s];
            }
            StepScore { step: s, df: s, score: rss / m as f64 }
        })
        .collect();
    let best = best_step(&scores)?;
    Ok(Selection {
        criterion: Criterion::Cv,
        best_step: best,
        scores,
        k: sel.k,
        seed: sel.seed,
    })
}

/// Fit the full path and choose its serving step in one call — what
/// `calars select` drives. Returns the full-data fit result, its
/// snapshot, and the selection.
pub fn select_model(
    a: &Matrix,
    b: &[f64],
    fit: &FitSpec,
    sel: &SelectSpec,
) -> Result<(crate::fit::FitResult, PathSnapshot, Selection)> {
    let mut obs = SnapshotObserver::new();
    let result = fit.fit(a, b, &mut obs)?;
    let snap = obs.into_snapshot().expect("on_complete fires when fit returns Ok");
    let mut selection = match sel.criterion {
        Criterion::Cv => cross_validate(a, b, fit, sel)?,
        c => rank_steps(&snap, a.nrows(), c)?,
    };
    // A CV-chosen step is served from the full-data path; clamp in
    // case the full path is shorter than every fold path.
    if selection.best_step >= snap.len() {
        selection.best_step = snap.len().saturating_sub(1);
    }
    Ok((result, snap, selection))
}

// ── selection metadata tokens ───────────────────────────────────────
//
// The serving layer records chosen steps in the model metadata as
// space-separated `key=step` tokens ("cp=4 aic=5 cv5.0=3"), where the
// key is `SelectSpec::token_key`. Kept here so the registry, the HTTP
// layer, and tests share one format.

/// Render one selection token (`"cp=4"`, `"cv5.7=3"`).
pub fn selection_token(key: &str, step: usize) -> String {
    format!("{key}={step}")
}

/// Find a selection token's step by key.
pub fn find_selection(selection: &str, key: &str) -> Option<usize> {
    selection.split_whitespace().find_map(|tok| {
        let (k, v) = tok.split_once('=')?;
        if k == key {
            v.parse().ok()
        } else {
            None
        }
    })
}

/// Insert or replace a token by key, preserving the others' order.
pub fn upsert_selection(selection: &str, key: &str, step: usize) -> String {
    let mut toks: Vec<String> = selection
        .split_whitespace()
        .filter(|tok| tok.split_once('=').map(|(k, _)| k) != Some(key))
        .map(str::to_string)
        .collect();
    toks.push(selection_token(key, step));
    toks.join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::error::ErrorKind;
    use crate::fit::Algorithm;
    use crate::lars::path::PathStep;

    fn toy_snapshot(rss: &[f64]) -> PathSnapshot {
        // Step s has support {0..s} and ‖r‖ = √rss[s].
        let steps = rss
            .iter()
            .enumerate()
            .map(|(s, &r)| PathStep {
                lambda: (rss.len() - s) as f64,
                support: (0..s).collect(),
                coefs: vec![1.0; s],
                residual_norm: r.sqrt(),
            })
            .collect();
        PathSnapshot { n: rss.len(), steps }
    }

    #[test]
    fn criteria_penalize_model_size() {
        // RSS barely improves after step 2: every criterion should
        // stop there rather than pay for more degrees of freedom.
        let snap = toy_snapshot(&[100.0, 20.0, 5.0, 4.999, 4.998, 4.997]);
        for c in [Criterion::Cp, Criterion::Aic, Criterion::Bic] {
            let sel = rank_steps(&snap, 100, c).unwrap();
            assert_eq!(sel.best_step, 2, "{c:?}: {:?}", sel.scores);
            assert_eq!(sel.scores.len(), 6);
            assert_eq!(sel.scores[3].df, 3);
        }
        // BIC's ln(m) penalty is at least AIC's (m ≥ 8 ⇒ ln m ≥ 2).
        let aic = rank_steps(&snap, 100, Criterion::Aic).unwrap();
        let bic = rank_steps(&snap, 100, Criterion::Bic).unwrap();
        assert!(bic.best_step <= aic.best_step);
    }

    #[test]
    fn rank_steps_rejects_degenerate_inputs() {
        let snap = toy_snapshot(&[10.0, 1.0]);
        assert_eq!(
            rank_steps(&snap, 10, Criterion::Cv).unwrap_err().kind(),
            ErrorKind::InvalidSpec
        );
        assert_eq!(rank_steps(&snap, 0, Criterion::Cp).unwrap_err().kind(), ErrorKind::InvalidSpec);
        let empty = PathSnapshot { n: 3, steps: Vec::new() };
        assert_eq!(
            rank_steps(&empty, 10, Criterion::Aic).unwrap_err().kind(),
            ErrorKind::InvalidSpec
        );
        // Saturated path (zero final residual): Cp undefined, AIC fine.
        let sat = toy_snapshot(&[10.0, 0.0]);
        assert_eq!(rank_steps(&sat, 10, Criterion::Cp).unwrap_err().kind(), ErrorKind::InvalidSpec);
        assert_eq!(rank_steps(&sat, 10, Criterion::Aic).unwrap().best_step, 1);
    }

    #[test]
    fn select_spec_validates_and_keys() {
        assert!(SelectSpec::new(Criterion::Cv).k(1).validate().is_err());
        assert!(SelectSpec::new(Criterion::Cv).k(2).validate().is_ok());
        assert!(SelectSpec::new(Criterion::Cp).k(1).validate().is_ok(), "k ignored off-CV");
        assert_eq!(SelectSpec::new(Criterion::Cv).k(5).seed(7).token_key(), "cv5.7");
        assert_eq!(SelectSpec::new(Criterion::Bic).token_key(), "bic");
        assert_eq!(Criterion::from_name("aic").unwrap(), Criterion::Aic);
        assert!(Criterion::from_name("r2").is_err());
    }

    #[test]
    fn cv_recovers_the_planted_support_size() {
        // tiny plants 12 true features; CV error should stop shrinking
        // near 12 selected columns, never pick the empty model, and be
        // fully deterministic.
        let d = datasets::tiny(3);
        let fit = FitSpec::new(Algorithm::Lars).t(20);
        let sel = SelectSpec::new(Criterion::Cv).k(5).seed(1);
        let s1 = cross_validate(&d.a, &d.b, &fit, &sel).unwrap();
        let s2 = cross_validate(&d.a, &d.b, &fit, &sel).unwrap();
        assert_eq!(s1, s2, "CV must be deterministic");
        assert!(s1.best_step >= 6, "planted k=12: best step {}", s1.best_step);
        assert!(s1.scores[0].score > s1.scores[s1.best_step].score);
        // The scores at the chosen step beat the saturated end or tie.
        let last = s1.scores.last().unwrap().score;
        assert!(s1.scores[s1.best_step].score <= last);
    }

    #[test]
    fn cv_rejects_bad_geometry() {
        let d = datasets::tiny_dense(1);
        let fit = FitSpec::new(Algorithm::Lars).t(4);
        let err = cross_validate(&d.a, &d.b, &fit, &SelectSpec::new(Criterion::Cv).k(1))
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
        let err = cross_validate(
            &d.a,
            &d.b,
            &fit,
            &SelectSpec::new(Criterion::Cv).k(d.a.nrows() + 1),
        )
        .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
        let err =
            cross_validate(&d.a, &d.b, &fit, &SelectSpec::new(Criterion::Cp)).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec, "cross_validate is CV-only");
    }

    #[test]
    fn selection_tokens_round_trip() {
        let s = upsert_selection("", "cp", 4);
        let s = upsert_selection(&s, "cv5.0", 3);
        assert_eq!(find_selection(&s, "cp"), Some(4));
        assert_eq!(find_selection(&s, "cv5.0"), Some(3));
        assert_eq!(find_selection(&s, "aic"), None);
        let s = upsert_selection(&s, "cp", 6);
        assert_eq!(find_selection(&s, "cp"), Some(6));
        assert_eq!(s.matches("cp=").count(), 1, "upsert replaces: {s}");
    }
}
