//! The persistent worker pool behind [`crate::par`].
//!
//! Workers are plain OS threads parked on a condvar over a shared
//! injector queue; a fork-join (`ThreadPool::run`) enqueues its tasks,
//! blocks on a latch until every task has finished, and only then
//! returns — which is what makes handing the workers *borrowed*
//! closures sound (see the `SAFETY` note in `run`).
//!
//! Scheduling never influences results: tasks carry their output slot
//! index, so `run` returns outputs in task order no matter which worker
//! finished first, and panics are captured per task and re-raised on
//! the calling thread after the join point.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased unit of queued work. The `'static` bound is a fiction
/// maintained by `run`, which cannot return before the job has executed.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// One task's output cell: filled by whichever worker ran the task.
type Slot<T> = Mutex<Option<std::thread::Result<T>>>;

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    work_cv: Condvar,
}

thread_local! {
    /// Set for the lifetime of a pool worker thread: nested fork-joins
    /// issued from inside a task execute inline instead of re-entering
    /// the queue (which could otherwise deadlock with every worker
    /// blocked on a child join).
    static IS_WORKER: Cell<bool> = const { Cell::new(false) };
    /// The owning pool's determinism grain, recorded per worker so a
    /// kernel running *inside* a task chunks by the same `min_chunk`
    /// it would use inline on the submitting thread — without this,
    /// nested kernels would silently pick up the global pool's grain
    /// and could break bit-identity across thread counts.
    static WORKER_MIN_CHUNK: Cell<usize> = const { Cell::new(0) };
    /// The owning pool's kernel ISA backend, mirrored per worker for
    /// the same reason as the grain: a kernel running inside a task
    /// must dispatch to the very backend the submitting thread resolved
    /// when it built the pool, or a `with_backend` scope on the caller
    /// could silently diverge from its own workers.
    static WORKER_BACKEND: Cell<Option<crate::kern::simd::KernBackend>> =
        const { Cell::new(None) };
}

/// Fork-join task counter in the global metrics registry, registered
/// once and cloned thereafter (the add itself is one relaxed atomic).
fn pool_tasks_counter() -> crate::obs::Counter {
    static C: std::sync::OnceLock<crate::obs::Counter> = std::sync::OnceLock::new();
    C.get_or_init(|| {
        crate::obs::global().counter(
            "calars_par_tasks_total",
            "",
            "Tasks enqueued on the shared-memory fork-join pool.",
        )
    })
    .clone()
}

/// The grain of the pool owning the current worker thread, if this is
/// one (used by [`crate::par::min_chunk`]).
pub(crate) fn worker_min_chunk() -> Option<usize> {
    if IS_WORKER.with(|w| w.get()) {
        Some(WORKER_MIN_CHUNK.with(|c| c.get()))
    } else {
        None
    }
}

/// The kernel backend of the pool owning the current worker thread, if
/// this is one (used by [`crate::kern::simd::current`]).
pub(crate) fn worker_backend() -> Option<crate::kern::simd::KernBackend> {
    WORKER_BACKEND.with(|b| b.get())
}

/// Countdown latch: `run` waits here until its last task completes.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { remaining: Mutex::new(n), cv: Condvar::new() }
    }

    // Poison recovery throughout: the latch count and the job queue
    // are plain data that stay consistent even if a panic unwinds
    // while a guard is held (task panics are caught inside the job
    // closure anyway), so a poisoned mutex carries no broken invariant
    // — recover the guard instead of cascading the panic.
    fn done(&self) {
        let mut g =
            self.remaining.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait_zero(&self) {
        let mut g =
            self.remaining.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        while *g > 0 {
            g = self.cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// A persistent fork-join pool. `threads == 1` spawns no workers at
/// all — every `run` degrades to an inline loop on the calling thread,
/// the same code path a worker uses for nested joins.
pub struct ThreadPool {
    threads: usize,
    min_chunk: usize,
    backend: crate::kern::simd::KernBackend,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Build a pool with `threads` workers (clamped to ≥ 1) and the
    /// given determinism grain (work units per task, see
    /// [`crate::par::chunk_ranges`]). The constructing thread's kernel
    /// backend ([`crate::kern::simd::current`]) is captured here and
    /// installed on every worker, so a pool built inside
    /// `simd::with_backend` runs its tasks under that backend too.
    pub fn new(threads: usize, min_chunk: usize) -> Self {
        let threads = threads.max(1);
        let backend = crate::kern::simd::current();
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
        });
        let min_chunk = min_chunk.max(1);
        let mut workers = Vec::new();
        if threads > 1 {
            for i in 0..threads {
                let sh = Arc::clone(&shared);
                let handle = std::thread::Builder::new()
                    .name(format!("calars-par-{i}"))
                    .spawn(move || worker_loop(sh, min_chunk, backend))
                    // audit: allow(PANIC-REACH) -- pool threads spawn once at first use, before any fit runs; a host that cannot spawn threads cannot serve
                    .expect("spawn pool worker");
                workers.push(handle);
            }
        }
        ThreadPool { threads, min_chunk, backend, shared, workers }
    }

    /// Configured parallelism (1 ⇒ pure inline execution).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Work units per task — the chunk grain shared by every kernel.
    pub fn min_chunk(&self) -> usize {
        self.min_chunk
    }

    /// The kernel ISA backend captured at construction — what every
    /// worker (and the inline path, barring a nested override)
    /// dispatches to.
    pub fn backend(&self) -> crate::kern::simd::KernBackend {
        self.backend
    }

    /// True when `run` would execute on the calling thread: a
    /// single-thread pool, or a nested join from inside a worker.
    pub fn is_inline(&self) -> bool {
        self.threads == 1 || IS_WORKER.with(|w| w.get())
    }

    /// Fork-join: execute every task (possibly concurrently) and return
    /// their results **in task order**. A panicking task does not kill
    /// the pool; the first captured panic (by task index) is re-raised
    /// here after all tasks have settled.
    pub fn run<T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        if tasks.len() <= 1 || self.is_inline() {
            return tasks.into_iter().map(|f| f()).collect();
        }
        pool_tasks_counter().add(tasks.len() as u64);
        let n = tasks.len();
        let slots: Vec<Slot<T>> = (0..n).map(|_| Mutex::new(None)).collect();
        let latch = Latch::new(n);
        {
            let mut state =
                self.shared.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            for (slot, task) in slots.iter().zip(tasks) {
                let latch_ref = &latch;
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let result = catch_unwind(AssertUnwindSafe(task));
                    *slot.lock().unwrap_or_else(std::sync::PoisonError::into_inner) =
                        Some(result);
                    latch_ref.done();
                });
                // SAFETY: `run` blocks on `latch` until every job queued
                // here has finished executing, so the borrows the job
                // captures (`task`'s environment, `slots`, `latch`)
                // strictly outlive its execution; erasing the lifetime
                // is therefore sound.
                let job: Job = unsafe { std::mem::transmute(job) };
                state.jobs.push_back(job);
            }
            self.shared.work_cv.notify_all();
        }
        latch.wait_zero();
        slots
            .into_iter()
            .map(|slot| {
                let cell = slot.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
                // audit: allow(PANIC-REACH) -- wait_zero() returns only after every queued job stored its result, so the slot is always Some
                match cell.expect("pool job completed without a result") {
                    Ok(v) => v,
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            })
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st =
                self.shared.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, min_chunk: usize, backend: crate::kern::simd::KernBackend) {
    IS_WORKER.with(|w| w.set(true));
    WORKER_MIN_CHUNK.with(|c| c.set(min_chunk));
    WORKER_BACKEND.with(|b| b.set(Some(backend)));
    loop {
        let job = {
            let mut st =
                shared.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = shared
                    .work_cv
                    .wait(st)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        job();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_results_under_contention() {
        let pool = ThreadPool::new(4, 1);
        let tasks: Vec<_> = (0..64).map(|i| move || i * 3).collect();
        let out = pool.run(tasks);
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool_spawns_no_workers() {
        let pool = ThreadPool::new(1, 1);
        assert!(pool.is_inline());
        assert_eq!(pool.workers.len(), 0);
        let caller = std::thread::current().id();
        let ids =
            pool.run((0..2).map(|_| move || std::thread::current().id()).collect::<Vec<_>>());
        assert!(ids.iter().all(|&id| id == caller));
    }

    #[test]
    fn borrowed_state_is_visible_to_tasks() {
        let pool = ThreadPool::new(3, 1);
        let data: Vec<u64> = (0..100).collect();
        let dref = &data;
        let halves = [(0usize, 50usize), (50, 100)];
        let sums = pool.run(
            halves
                .iter()
                .map(|&(lo, hi)| move || dref[lo..hi].iter().sum::<u64>())
                .collect::<Vec<_>>(),
        );
        assert_eq!(sums[0] + sums[1], data.iter().sum::<u64>());
    }

    #[test]
    fn workers_inherit_the_constructing_threads_backend() {
        use crate::kern::simd::{self, KernBackend};
        // Built inside a forced-scalar scope, the pool must run its
        // tasks under scalar even though the workers themselves never
        // entered `with_backend`.
        let pool = simd::with_backend(KernBackend::Scalar, || ThreadPool::new(2, 1));
        assert_eq!(pool.backend(), KernBackend::Scalar);
        let seen = pool.run((0..4).map(|_| || simd::current()).collect::<Vec<_>>());
        assert!(seen.iter().all(|&b| b == KernBackend::Scalar));
    }

    #[test]
    fn pool_survives_task_panic() {
        let pool = ThreadPool::new(2, 1);
        let attempt = catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<_> = (0..4)
                .map(|i| {
                    move || {
                        if i == 2 {
                            panic!("task {i} exploded");
                        }
                        i
                    }
                })
                .collect();
            pool.run(tasks)
        }));
        assert!(attempt.is_err(), "panic must propagate to the joiner");
        let out = pool.run((0..4).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out, vec![0, 1, 2, 3]);
    }
}
