//! `calars::par` — the crate's shared-memory execution layer.
//!
//! A zero-dependency, std-only persistent thread pool plus the chunked
//! fork-join helpers every hot kernel is written against. The paper's
//! speedups come from parallel `Aᵀr` products, Gram-block assembly and
//! equiangular solves; this module is the substrate that makes those
//! kernels actually run on all cores (L1 linalg, L2 fitters, L3
//! cluster supersteps and the L4 serving engine all funnel through it
//! — see DESIGN.md §"Shared-memory execution").
//!
//! ## Determinism contract
//!
//! Parallel results are **bit-identical to serial**. Two rules make
//! that hold:
//!
//! 1. **Fixed grain.** Work is split by [`chunk_ranges`], a pure
//!    function of `(len, grain)` where the grain comes from the
//!    workload shape and the configured `min_chunk` — never from the
//!    thread count. `CALARS_THREADS=1` and `=64` produce the *same*
//!    chunk decomposition; only who executes each chunk differs.
//! 2. **Fixed combine order.** Reductions compute one partial per
//!    chunk (each with the serial kernel's own inner loop) and combine
//!    the partials on the calling thread in ascending chunk order.
//!
//! Kernels whose parallel form writes disjoint outputs (`gemv`,
//! per-column sweeps) are bit-identical to the classic serial loop for
//! free; chunked reductions (`at_r`, `gram_block`, column norms) are
//! bit-identical across thread counts for a fixed `min_chunk`. The
//! registry's warm-start reuse and the serving engine's breakpoint
//! exactness contract both lean on this guarantee; it is enforced by
//! `rust/tests/par.rs` property tests over `CALARS_THREADS ∈ {1,2,4}`.
//!
//! ## Configuration
//!
//! The global pool is built lazily from [`ParConfig`]: `CALARS_THREADS`
//! (0/unset ⇒ one worker per detected core) and `CALARS_MIN_CHUNK`
//! override the defaults; `calars --par-threads N --par-min-chunk N`
//! set them from the CLI before first use. Tests and benches run
//! kernels against private pools via [`with_pool`] without touching
//! process-global state.

pub mod pool;

pub use pool::ThreadPool;

use std::cell::Cell;
use std::sync::OnceLock;

/// Default work units (≈ matrix elements touched) per fork-join task.
/// Big enough that a task amortizes queue+wake overhead; small enough
/// that the paper-scale workloads split into dozens of tasks.
pub const DEFAULT_MIN_CHUNK: usize = 16 * 1024;

/// Shared-memory execution configuration, threaded through
/// [`crate::config::ServeConfig`] and the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParConfig {
    /// Worker threads; 0 ⇒ one per detected core.
    pub threads: usize,
    /// Work units per task — the determinism grain. Changing it may
    /// move chunk boundaries (and thus last-bit rounding of chunked
    /// reductions); changing `threads` never does.
    pub min_chunk: usize,
}

impl Default for ParConfig {
    fn default() -> Self {
        ParConfig { threads: 0, min_chunk: DEFAULT_MIN_CHUNK }
    }
}

impl ParConfig {
    /// Read `CALARS_THREADS` / `CALARS_MIN_CHUNK` from the environment.
    /// Malformed values warn on stderr and fall back to the default
    /// (the CLI flag forms hard-error instead); `0` means "default"
    /// for both.
    pub fn from_env() -> Self {
        ParConfig {
            threads: env_usize("CALARS_THREADS", 0),
            min_chunk: match env_usize("CALARS_MIN_CHUNK", DEFAULT_MIN_CHUNK) {
                0 => DEFAULT_MIN_CHUNK,
                c => c,
            },
        }
    }

    /// The concrete worker count this config resolves to.
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            detected_cores()
        }
    }
}

fn env_usize(name: &str, default: usize) -> usize {
    match std::env::var(name) {
        Err(_) => default,
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(x) => x,
            Err(_) => {
                eprintln!("warning: ignoring unparseable {name}={v:?} (using {default})");
                default
            }
        },
    }
}

/// Detected hardware parallelism (≥ 1).
pub fn detected_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
static CONFIG: OnceLock<ParConfig> = OnceLock::new();

/// Install `cfg` as the global pool's configuration. Must run before
/// the first kernel executes (the CLI does this right after argv
/// parsing); returns `false` — and changes nothing — if the global
/// pool was already built.
pub fn configure(cfg: ParConfig) -> bool {
    if GLOBAL.get().is_some() {
        return false;
    }
    CONFIG.set(cfg).is_ok()
}

fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| {
        let cfg = CONFIG.get().copied().unwrap_or_else(ParConfig::from_env);
        ThreadPool::new(cfg.resolved_threads(), cfg.min_chunk)
    })
}

thread_local! {
    /// Per-thread pool override installed by [`with_pool`] (raw pointer
    /// because test pools are stack-allocated, not `'static`).
    static OVERRIDE: Cell<Option<*const ThreadPool>> = const { Cell::new(None) };
}

/// Run `f` with `pool` as the calling thread's current pool. Kernels
/// invoked inside `f` (on this thread) fork onto `pool` instead of the
/// global one — how the determinism property tests compare
/// `CALARS_THREADS ∈ {1, 2, 4}` inside a single process.
pub fn with_pool<R>(pool: &ThreadPool, f: impl FnOnce() -> R) -> R {
    struct Reset(Option<*const ThreadPool>);
    impl Drop for Reset {
        fn drop(&mut self) {
            OVERRIDE.with(|o| o.set(self.0));
        }
    }
    let prev = OVERRIDE.with(|o| o.replace(Some(pool as *const ThreadPool)));
    let _reset = Reset(prev);
    f()
}

fn with_current<R>(f: impl FnOnce(&ThreadPool) -> R) -> R {
    match OVERRIDE.with(|o| o.get()) {
        // SAFETY: the pointer was installed by `with_pool`, which holds
        // the pool borrowed for the whole scope and restores the
        // previous value on exit (including unwinds), so it is live.
        Some(p) => f(unsafe { &*p }),
        None => f(global()),
    }
}

/// True on a pool worker thread, where nested fork-joins always run
/// inline — checked by the helpers below *before* resolving a pool so
/// that kernels nested inside a private pool's tasks never construct
/// (and spawn the workers of) the untouched global pool.
fn on_worker() -> bool {
    pool::worker_min_chunk().is_some()
}

/// Worker-thread count of the current pool (1 on a worker thread:
/// nested joins are inline).
pub fn threads() -> usize {
    if on_worker() {
        return 1;
    }
    with_current(ThreadPool::threads)
}

/// Determinism grain (work units per task) of the current pool. On a
/// pool worker thread this is the *owning* pool's grain, so kernels
/// nested inside a task chunk exactly as they would inline on the
/// submitting thread.
pub fn min_chunk() -> usize {
    match pool::worker_min_chunk() {
        Some(mc) => mc,
        None => with_current(ThreadPool::min_chunk),
    }
}

/// Items per task for a sweep whose per-item cost is `item_cost` work
/// units: keeps ≈ `min_chunk()` units per task. Pure in the workload
/// shape and the configured grain — never in the thread count.
pub fn grain_for(item_cost: usize) -> usize {
    (min_chunk() / item_cost.max(1)).max(1)
}

/// Fixed-grain chunk decomposition of `0..len`: every chunk except the
/// last spans exactly `grain` items. Pure in `(len, grain)`, which is
/// what keeps chunked reductions bit-identical across thread counts.
pub fn chunk_ranges(len: usize, grain: usize) -> Vec<(usize, usize)> {
    let grain = grain.max(1);
    let mut out = Vec::with_capacity(len / grain + 1);
    let mut lo = 0;
    while lo < len {
        let hi = (lo + grain).min(len);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Apply `f` to every fixed-grain chunk of `0..len` (possibly in
/// parallel) and return the per-chunk results **in ascending chunk
/// order**. Combine them sequentially in that order and the final
/// result is independent of the thread count.
pub fn map_chunks<T, F>(len: usize, grain: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    let ranges = chunk_ranges(len, grain);
    if ranges.len() <= 1 || on_worker() {
        return ranges.into_iter().map(|(lo, hi)| f(lo, hi)).collect();
    }
    with_current(|pool| {
        let fr = &f;
        let tasks: Vec<_> = ranges.into_iter().map(|(lo, hi)| move || fr(lo, hi)).collect();
        pool.run(tasks)
    })
}

/// Split `data` at fixed-grain boundaries and run `f(chunk_start,
/// chunk)` over the disjoint pieces (possibly in parallel). Writes are
/// disjoint, so the result is bit-identical to the serial loop no
/// matter how the chunks are scheduled.
pub fn for_chunks_mut<T, F>(data: &mut [T], grain: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let ranges = chunk_ranges(data.len(), grain);
    if ranges.len() <= 1 || on_worker() {
        if !data.is_empty() {
            f(0, data);
        }
        return;
    }
    with_current(|pool| {
        if pool.is_inline() {
            for &(lo, hi) in &ranges {
                f(lo, &mut data[lo..hi]);
            }
            return;
        }
        let fr = &f;
        let mut tasks = Vec::with_capacity(ranges.len());
        let mut rest: &mut [T] = data;
        for &(lo, hi) in &ranges {
            let (head, tail) = rest.split_at_mut(hi - lo);
            rest = tail;
            tasks.push(move || fr(lo, head));
        }
        pool.run(tasks);
    })
}

/// Fork-join over arbitrary same-typed tasks on the current pool,
/// returning results in task order (the cluster's per-rank supersteps
/// and T-bLARS leaf solves use this directly).
pub fn run_tasks<T, F>(tasks: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    if tasks.len() <= 1 || on_worker() {
        return tasks.into_iter().map(|f| f()).collect();
    }
    with_current(|pool| pool.run(tasks))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_fixed_grain() {
        assert_eq!(chunk_ranges(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(chunk_ranges(8, 4), vec![(0, 4), (4, 8)]);
        assert_eq!(chunk_ranges(3, 4), vec![(0, 3)]);
        assert_eq!(chunk_ranges(0, 4), Vec::<(usize, usize)>::new());
        // grain 0 is clamped, not a division by zero
        assert_eq!(chunk_ranges(2, 0), vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn map_chunks_orders_results() {
        let pool = ThreadPool::new(4, 1);
        let out = with_pool(&pool, || map_chunks(100, 7, |lo, hi| (lo, hi)));
        assert_eq!(out, chunk_ranges(100, 7));
    }

    #[test]
    fn map_chunks_reduction_independent_of_threads() {
        let data: Vec<f64> = (0..10_000).map(|i| (i as f64 * 0.7).sin()).collect();
        let sum_with = |threads: usize| {
            let pool = ThreadPool::new(threads, 64);
            with_pool(&pool, || {
                let partials = map_chunks(data.len(), 64, |lo, hi| {
                    data[lo..hi].iter().sum::<f64>()
                });
                partials.iter().sum::<f64>()
            })
        };
        let s1 = sum_with(1);
        for threads in [2, 4, 8] {
            assert_eq!(s1.to_bits(), sum_with(threads).to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn for_chunks_mut_covers_every_element() {
        let pool = ThreadPool::new(4, 1);
        let mut data = vec![0u32; 1000];
        with_pool(&pool, || {
            for_chunks_mut(&mut data, 13, |start, chunk| {
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v = (start + k) as u32;
                }
            });
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn with_pool_overrides_and_restores() {
        let p2 = ThreadPool::new(2, 123);
        let outer = min_chunk();
        let inner = with_pool(&p2, || (threads(), min_chunk()));
        assert_eq!(inner, (2, 123));
        assert_eq!(min_chunk(), outer, "override must not leak");
    }

    #[test]
    fn with_pool_nests() {
        let p2 = ThreadPool::new(2, 10);
        let p3 = ThreadPool::new(3, 20);
        with_pool(&p2, || {
            assert_eq!(threads(), 2);
            with_pool(&p3, || assert_eq!((threads(), min_chunk()), (3, 20)));
            assert_eq!((threads(), min_chunk()), (2, 10));
        });
    }

    #[test]
    fn run_tasks_uses_current_pool() {
        let pool = ThreadPool::new(4, 1);
        let out = with_pool(&pool, || {
            run_tasks((0..16).map(|i| move || i + 100).collect::<Vec<_>>())
        });
        assert_eq!(out, (100..116).collect::<Vec<_>>());
    }

    #[test]
    fn grain_for_scales_inverse_to_cost() {
        let pool = ThreadPool::new(1, 1000);
        with_pool(&pool, || {
            assert_eq!(grain_for(10), 100);
            assert_eq!(grain_for(0), 1000, "zero cost clamps to 1");
            assert_eq!(grain_for(1_000_000), 1, "huge cost floors at one item");
        });
    }
}
