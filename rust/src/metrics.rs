//! Wallclock timing helpers for the benchmark harness (criterion is
//! unavailable offline; this is the in-repo replacement: warmup +
//! repeated measurement + robust summary), plus the latency-percentile
//! summaries the serving load generator reports.

use std::time::Instant;

/// JSON number for an f64 — `null` for NaN/±∞, which are **invalid
/// JSON tokens**. Every JSON emitter in the crate (the serving layer's
/// endpoints, [`crate::fit::MetricsSink::to_json`], the bench JSON
/// records) must route f64s through this: T-bLARS observer events
/// legitimately carry NaN for γ/λ (no scalar step per outer
/// iteration), and a raw `{:.3}` of such a value would emit `NaN` and
/// corrupt the document.
pub fn json_f64(v: f64) -> String {
    if v.is_finite() {
        v.to_string()
    } else {
        "null".to_string()
    }
}

/// Like [`json_f64`] but with fixed decimal places for finite values
/// (the bench records' compact latencies).
pub fn json_f64_rounded(v: f64, digits: usize) -> String {
    if v.is_finite() {
        format!("{v:.digits$}")
    } else {
        "null".to_string()
    }
}

/// Summary of repeated timing measurements, in seconds.
#[derive(Clone, Copy, Debug)]
pub struct TimingSummary {
    pub best: f64,
    pub median: f64,
    pub mean: f64,
    pub worst: f64,
    pub iters: usize,
}

impl TimingSummary {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.total_cmp(b));
        let n = samples.len();
        TimingSummary {
            best: samples[0],
            median: samples[n / 2],
            mean: samples.iter().sum::<f64>() / n as f64,
            worst: samples[n - 1],
            iters: n,
        }
    }
}

/// Nearest-rank percentile of an ascending-sorted slice (`q` in
/// `[0, 1]`); 0 for empty input.
///
/// Nearest-rank means the smallest element with at least a `q`
/// fraction of the sample at or below it: index `⌈q·n⌉ − 1`, with
/// `q = 0` mapping to the minimum. The previous `round(q·(n−1))`
/// interpolation-style rounding overshot on small samples (e.g. the
/// p50 of 100 samples landed on the 51st) and is what the serving
/// bench latency summaries used to report.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let rank = (q * sorted.len() as f64).ceil() as isize - 1;
    let idx = rank.max(0) as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Latency summary for a set of request timings (seconds). All zeros
/// for an empty sample set (e.g. a load run where every request
/// errored).
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyStats {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencyStats {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        if samples.is_empty() {
            return LatencyStats::default();
        }
        samples.sort_by(|a, b| a.total_cmp(b));
        let n = samples.len();
        LatencyStats {
            count: n,
            mean: samples.iter().sum::<f64>() / n as f64,
            p50: percentile(&samples, 0.50),
            p90: percentile(&samples, 0.90),
            p99: percentile(&samples, 0.99),
            max: samples[n - 1],
        }
    }
}

/// Measure `f` with `warmup` unmeasured runs then `iters` measured runs.
/// The closure's return value is black-boxed to keep the optimizer honest.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> TimingSummary {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    TimingSummary::from_samples(samples)
}

/// Minimal black_box (std::hint::black_box is stable — use it).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Human-readable counts (1.2k, 3.4M, …).
pub fn fmt_count(x: u64) -> String {
    let x = x as f64;
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_ordering() {
        let s = TimingSummary::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.best, 1.0);
        assert_eq!(s.worst, 3.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench(2, 5, || {
            count += 1;
            count
        });
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
        assert!(s.best >= 0.0);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.5), 50.0); // ⌈0.5 · 100⌉ − 1 = 49 → v[49]
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 0.999), 100.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn percentile_boundaries_small_n() {
        // n = 1: every q must return the only sample.
        for q in [0.0, 0.25, 0.5, 0.75, 0.99, 1.0] {
            assert_eq!(percentile(&[7.0], q), 7.0, "q={q}");
        }
        // n = 2: q ≤ 0.5 → first, q > 0.5 → second.
        let two = [1.0, 2.0];
        assert_eq!(percentile(&two, 0.0), 1.0);
        assert_eq!(percentile(&two, 0.5), 1.0);
        assert_eq!(percentile(&two, 0.500001), 2.0);
        assert_eq!(percentile(&two, 1.0), 2.0);
        // n = 3: thirds.
        let three = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&three, 0.0), 1.0);
        assert_eq!(percentile(&three, 1.0 / 3.0), 1.0);
        assert_eq!(percentile(&three, 0.5), 2.0);
        assert_eq!(percentile(&three, 2.0 / 3.0), 2.0);
        assert_eq!(percentile(&three, 0.7), 3.0);
        assert_eq!(percentile(&three, 1.0), 3.0);
        // n = 4: q = 0.75 must not overshoot to the max.
        let four = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&four, 0.75), 3.0);
        assert_eq!(percentile(&four, 0.76), 4.0);
        // Out-of-range q clamps.
        assert_eq!(percentile(&four, -1.0), 1.0);
        assert_eq!(percentile(&four, 2.0), 4.0);
    }

    #[test]
    fn percentile_exhaustive_small_n_reference() {
        // Cross-check against a literal reference implementation of
        // the nearest-rank definition for all n ≤ 8 and a q sweep.
        fn reference(sorted: &[f64], q: f64) -> f64 {
            let n = sorted.len();
            let mut idx = 0;
            while idx + 1 < n && ((idx + 1) as f64) < (q * n as f64).ceil() {
                idx += 1;
            }
            sorted[idx]
        }
        for n in 1..=8usize {
            let v: Vec<f64> = (1..=n).map(|i| i as f64).collect();
            for step in 0..=100 {
                let q = step as f64 / 100.0;
                assert_eq!(percentile(&v, q), reference(&v, q), "n={n} q={q}");
            }
        }
    }

    #[test]
    fn latency_stats_summary() {
        let s = LatencyStats::from_samples(vec![0.3, 0.1, 0.2, 0.4, 10.0]);
        assert_eq!(s.count, 5);
        assert_eq!(s.p50, 0.3);
        assert_eq!(s.max, 10.0);
        assert!(s.p99 >= s.p90 && s.p90 >= s.p50);
        let empty = LatencyStats::from_samples(vec![]);
        assert_eq!(empty.count, 0);
        assert_eq!(empty.max, 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert!(fmt_secs(0.002).contains("ms"));
        assert!(fmt_secs(2e-6).contains("µs"));
        assert!(fmt_secs(5e-9).contains("ns"));
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1500), "1.50k");
        assert_eq!(fmt_count(2_500_000), "2.50M");
        assert_eq!(fmt_count(3_000_000_000), "3.00G");
    }
}
