//! `calars::kern` — register-blocked, unrolled compute kernels.
//!
//! The paper's runtime is dominated by a handful of dense sweeps —
//! `Aᵀr` correlation products, Gram panels `A_Iᵀ A_B`, equiangular
//! direction application, and triangular solves. [`crate::par`] spread
//! those across threads; this module makes each thread fast: every
//! inner loop runs with multiple independent accumulators (groups of
//! [`UNROLL`] lanes) so the FP add chain no longer serializes, and the
//! paired traversals the fitters perform are fused into single passes
//! over the matrix ([`fused_step_panel`]).
//!
//! ## Canonical summation order
//!
//! Each kernel defines **one** summation order, used identically by
//! the serial whole-range path and by every fixed-grain chunk of the
//! [`crate::par`] parallel path:
//!
//! * **reduction kernels** ([`dot`], [`sq_norm`], [`dot_idx`],
//!   [`sparse_dot`]): four independent accumulators over lanes
//!   `i ≡ 0..4 (mod 4)`, combined pairwise as `(s0+s1) + (s2+s3)`,
//!   then the `len % 4` tail folded in sequentially;
//! * **row-streaming kernels** ([`at_r_panel`], [`col_sq_norms_panel`],
//!   [`gram_panel`], [`cols_dot_panel`], [`fused_step_panel`]): rows
//!   processed in groups of four anchored at the *start of the range*,
//!   each group's contribution to an output cell pre-reduced pairwise
//!   (`(p0+p1) + (p2+p3)`) before the single add into the accumulator,
//!   with the `rows % 4` tail handled one row at a time;
//! * **multi-response panel kernels** ([`at_r_multi_panel`],
//!   [`fused_step_multi_panel`]): the batch (`calars::batch`)
//!   analogues of `at_r_panel` / `fused_step_panel` — models are the
//!   inner loop over the same four-row packs, so `A` streams once per
//!   response panel while each model's accumulator walks the exact
//!   single-response summation order (per-model results are
//!   bit-identical to `k` separate single-response calls);
//! * **the γ-candidate scan body** ([`gamma_scan_range`]): the
//!   per-chunk step-length search both the single-model scan
//!   (`lars::serial`) and the batched multi-response scan run, so the
//!   two paths share one per-`j` arithmetic sequence.
//!
//! Because [`crate::par::chunk_ranges`] is a pure function of
//! `(len, grain)` — never of the thread count — the group boundaries
//! inside every chunk are reproducible, and chunked reductions stay
//! **bit-identical across `CALARS_THREADS` settings** exactly as the
//! pre-kern scalar kernels did (property-tested in `tests/par.rs` and
//! `tests/kern.rs`).
//!
//! The pre-kern scalar kernels survive as [`reference`] — the
//! mathematical definition written as naive one-accumulator loops —
//! against which every blocked kernel is tolerance-checked
//! (`tests/kern.rs`, and `benches/kernels.rs` gates CI on
//! `max |Δ| ≤ 1e-9`).
//!
//! ## SIMD backends
//!
//! Every kernel below is a thin wrapper over [`simd`], which routes the
//! call to an explicit vector implementation (AVX2 / AVX-512F / NEON)
//! or the canonical blocked-scalar code, chosen once per process by
//! runtime feature detection and overridable with
//! `CALARS_ISA=scalar|avx2|avx512|neon` / `--isa`. The 4-accumulator /
//! 4-row-pack shape above is exactly what makes this safe: AVX2's four
//! f64 lanes (and NEON's register pairs) *are* the four accumulators,
//! so those backends are bit-identical to scalar; only AVX-512's
//! 8-lane `dot`/`sq_norm` changes the reduction tree, and that pair is
//! gated at 1e-9 against [`reference`] (see [`simd`] and DESIGN.md
//! §"Kernel engine · SIMD backends"). Thread pools capture the backend
//! at construction ([`crate::par::ThreadPool`]), so workers and the
//! submitting thread always dispatch identically and the
//! thread-invariance contract holds under every backend.
//!
//! [`cache`] holds the cross-fit Gram/norm panel store the serving
//! layer binds around fits (see `DESIGN.md` §"Kernel engine").

pub mod cache;
pub mod reference;
pub mod simd;

/// Lanes per unrolled group (accumulators per reduction, rows per
/// streaming pack).
pub const UNROLL: usize = 4;

/// Dot product with four independent accumulators.
///
/// Canonical order: lane `i` of group `g` contributes to accumulator
/// `i`; the four accumulators combine pairwise, then the tail folds in
/// sequentially.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    simd::dot(a, b)
}

/// Sum of squares with four independent accumulators (same canonical
/// order as [`dot`]).
#[inline]
pub fn sq_norm(x: &[f64]) -> f64 {
    simd::sq_norm(x)
}

/// `y += alpha·x`, unrolled by four. Element-wise (one add per output
/// slot), so the result is identical to the naive loop — unrolling
/// here only widens the issue window.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    simd::axpy(alpha, x, y)
}

/// `x *= s` (element-wise, order-free).
#[inline]
pub fn scale(x: &mut [f64], s: f64) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// Gather dot `Σ_k row[cols[k]] · w[k]` with four accumulators — the
/// dense `gemv_cols` / `cols_dot` inner loop.
#[inline]
pub fn dot_idx(row: &[f64], cols: &[usize], w: &[f64]) -> f64 {
    simd::dot_idx(row, cols, w)
}

/// Sparse gather dot `Σ_k vals[k] · r[rows[k]]` with four accumulators
/// — the CSC `at_r` / `col_dot` / Gram inner loop.
#[inline]
pub fn sparse_dot(rows: &[u32], vals: &[f64], r: &[f64]) -> f64 {
    simd::sparse_dot(rows, vals, r)
}

/// Sparse scatter `out[rows[k]] += wk · vals[k]`, unrolled by four.
/// Row indices within a CSC column are distinct, so the unrolled slots
/// never alias and the result equals the naive loop exactly.
#[inline]
pub fn scatter_axpy(wk: f64, rows: &[u32], vals: &[f64], out: &mut [f64]) {
    simd::scatter_axpy(wk, rows, vals, out)
}

/// `acc[j] += Σ_i r[i]·rows_i[j]` over a row-major panel — the dense
/// `Aᵀr` kernel. `rows` holds `r.len()` consecutive rows of width `n`;
/// four rows are fused per accumulator pass (¼ the accumulator
/// traffic of the old axpy-per-row sweep), with the canonical pairwise
/// pre-reduction per output element.
pub fn at_r_panel(rows: &[f64], n: usize, r: &[f64], acc: &mut [f64]) {
    simd::at_r_panel(rows, n, r, acc)
}

/// `acc[j] += Σ_i rows_i[j]²` over a row-major panel — the column
/// squared-norm sweep, four rows fused per pass.
pub fn col_sq_norms_panel(rows: &[f64], n: usize, acc: &mut [f64]) {
    simd::col_sq_norms_panel(rows, n, acc)
}

/// Gram panel `acc[a·nb + b] += Σ_i rows_i[ii[a]] · rows_i[jj[b]]` — a
/// packed 4×4 micro-GEMM. Four rows' `ii`/`jj` values are gathered
/// into the contiguous panels `pi` (4·|ii|) and `pj` (4·|jj|) so the
/// inner tile runs on registers instead of strided re-loads; output is
/// walked in 4×4 tiles with the group contribution pre-reduced
/// pairwise per cell.
///
/// `pi`/`pj` are caller-provided scratch (≥ `4·ii.len()` and
/// `4·jj.len()`), letting chunked callers allocate once per task.
pub fn gram_panel(
    rows: &[f64],
    n: usize,
    ii: &[usize],
    jj: &[usize],
    pi: &mut [f64],
    pj: &mut [f64],
    acc: &mut [f64],
) {
    simd::gram_panel(rows, n, ii, jj, pi, pj, acc)
}

/// `acc[k] += Σ_i r[i]·rows_i[cols[k]]` — the dense `cols_dot` kernel
/// (correlations of a column *subset* with `r`), four rows fused per
/// accumulator pass.
pub fn cols_dot_panel(rows: &[f64], n: usize, cols: &[usize], r: &[f64], acc: &mut [f64]) {
    simd::cols_dot_panel(rows, n, cols, r, acc)
}

/// Fused equiangular step over a row-major panel: one pass computing
/// both `u = A[:, cols]·w` (written to `u`, one slot per panel row)
/// and the correlation update `av += Aᵀu` (accumulated into `av`,
/// width `n`). The fitters previously did this as two full sweeps over
/// `A` (`gemv_cols` then `at_r`); fusing halves the memory traffic of
/// the per-iteration hot path.
///
/// Canonical order: each `u` slot is a [`dot_idx`] gather; `av`
/// accumulates groups of four rows with the pairwise pre-reduction,
/// anchored at the panel start.
pub fn fused_step_panel(
    rows: &[f64],
    n: usize,
    cols: &[usize],
    w: &[f64],
    u: &mut [f64],
    av: &mut [f64],
) {
    simd::fused_step_panel(rows, n, cols, w, u, av)
}

/// Multi-response `Aᵀ R` panel: for every model `k`,
/// `accs[k][j] += Σ_i rs[k][i] · rows_i[j]`. The batch analogue of
/// [`at_r_panel`]: `A` streams through the cache **once** for the
/// whole response panel instead of once per model (the blocked panel
/// GEMM the multi-response fitter leans on), while each model's
/// accumulator sees the *identical* sequence of adds it would in `k`
/// separate [`at_r_panel`] calls — models are the inner loop over the
/// same four-row packs, so per-model results are bit-identical to the
/// single-response kernel at any batch width.
pub fn at_r_multi_panel(rows: &[f64], n: usize, rs: &[&[f64]], accs: &mut [&mut [f64]]) {
    simd::at_r_multi_panel(rows, n, rs, accs)
}

/// Multi-response fused equiangular step: for every model `k`, one
/// shared pass over the panel computes `us[k] = A[:, cols[k]]·ws[k]`
/// and `avs[k] += Aᵀ us[k]`. The batch analogue of
/// [`fused_step_panel`] with the same streaming amortization as
/// [`at_r_multi_panel`]: every model reads the same four-row pack
/// while it is hot, and each model's `u` gathers / `av` accumulations
/// follow exactly the single-response canonical order, so per-model
/// results are bit-identical to `k` separate [`fused_step_panel`]
/// calls.
pub fn fused_step_multi_panel(
    rows: &[f64],
    n: usize,
    cols: &[&[usize]],
    ws: &[&[f64]],
    us: &mut [&mut [f64]],
    avs: &mut [&mut [f64]],
) {
    simd::fused_step_multi_panel(rows, n, cols, ws, us, avs)
}

/// One fixed-grain chunk `[lo, hi)` of the LARS γ-candidate scan: for
/// every column `j` not yet in the model, the two step lengths
/// `γ₁ = (ck − c_j)/(ck·h − a_j)` and `γ₂ = (ck + c_j)/(ck·h + a_j)`
/// reduced to their smallest positive value and kept when it does not
/// overshoot the full step. Both the single-model scan
/// (`lars::serial`) and the batched multi-response scan
/// ([`crate::batch`]) call this exact routine per chunk, so the
/// batched path walks the identical per-`j` arithmetic — the
/// canonical-order contract extended to the γ search.
pub fn gamma_scan_range(
    lo: usize,
    hi: usize,
    in_model: &[bool],
    c: &[f64],
    av: &[f64],
    ck: f64,
    h: f64,
    gamma_full: f64,
    out: &mut Vec<(usize, f64)>,
) {
    for j in lo..hi {
        if in_model[j] {
            continue;
        }
        let g1 = (ck - c[j]) / (ck * h - av[j]);
        let g2 = (ck + c[j]) / (ck * h + av[j]);
        if let Some(g) = crate::linalg::select::min_positive2(g1, g2) {
            if g <= gamma_full * (1.0 + 1e-12) {
                out.push((j, g));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn randvec(len: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed);
        (0..len).map(|_| rng.normal()).collect()
    }

    #[test]
    fn dot_and_sq_norm_match_reference_awkward_lengths() {
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 33, 100] {
            let a = randvec(len, 1 + len as u64);
            let b = randvec(len, 100 + len as u64);
            let scale = 1.0 + reference::sq_norm(&a).sqrt();
            assert!(
                (dot(&a, &b) - reference::dot(&a, &b)).abs() < 1e-12 * scale,
                "dot len={len}"
            );
            assert!(
                (sq_norm(&a) - reference::sq_norm(&a)).abs() < 1e-12 * scale * scale,
                "sq_norm len={len}"
            );
        }
    }

    #[test]
    fn axpy_identical_to_naive() {
        for len in [0usize, 1, 3, 4, 9, 31] {
            let x = randvec(len, 7);
            let mut y1 = randvec(len, 8);
            let mut y2 = y1.clone();
            axpy(0.37, &x, &mut y1);
            for (yi, xi) in y2.iter_mut().zip(&x) {
                *yi += 0.37 * xi;
            }
            for (a, b) in y1.iter().zip(&y2) {
                assert_eq!(a.to_bits(), b.to_bits(), "axpy len={len}");
            }
        }
    }

    #[test]
    fn panels_match_reference_awkward_shapes() {
        for &(m, n) in &[(0usize, 5usize), (1, 5), (3, 7), (4, 4), (5, 0), (5, 1), (13, 9)] {
            let data = randvec(m * n, (m * 31 + n) as u64 + 1);
            let r = randvec(m, 999);
            // at_r
            let mut acc = vec![0.0; n];
            at_r_panel(&data, n, &r, &mut acc);
            let mut want = vec![0.0; n];
            reference::at_r(&data, m, n, &r, &mut want);
            for (j, (a, b)) in acc.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-12 * (1.0 + b.abs()), "at_r ({m},{n}) col {j}");
            }
            // col square norms
            let mut acc = vec![0.0; n];
            col_sq_norms_panel(&data, n, &mut acc);
            let want = reference::col_sq_norms(&data, m, n);
            for (a, b) in acc.iter().zip(&want) {
                assert!((a - b).abs() < 1e-12 * (1.0 + b.abs()), "norms ({m},{n})");
            }
            if n == 0 {
                continue;
            }
            // gram panel over a couple of column subsets
            let ii: Vec<usize> = (0..n).step_by(2).collect();
            let jj: Vec<usize> = (0..n).collect();
            let mut acc = vec![0.0; ii.len() * jj.len()];
            let mut pi = vec![0.0; 4 * ii.len()];
            let mut pj = vec![0.0; 4 * jj.len()];
            gram_panel(&data, n, &ii, &jj, &mut pi, &mut pj, &mut acc);
            let want = reference::gram_block(&data, m, n, &ii, &jj);
            for (a, b) in acc.iter().zip(&want) {
                assert!((a - b).abs() < 1e-12 * (1.0 + b.abs()), "gram ({m},{n})");
            }
        }
    }

    #[test]
    fn fused_step_matches_two_pass_reference() {
        let (m, n) = (23, 11);
        let data = randvec(m * n, 5);
        let cols = [0usize, 3, 4, 8, 10];
        let w = [0.5, -1.0, 0.25, 2.0, -0.125];
        let mut u = vec![0.0; m];
        let mut av = vec![0.0; n];
        fused_step_panel(&data, n, &cols, &w, &mut u, &mut av);
        let mut u_ref = vec![0.0; m];
        reference::gemv_cols(&data, m, n, &cols, &w, &mut u_ref);
        let mut av_ref = vec![0.0; n];
        reference::at_r(&data, m, n, &u_ref, &mut av_ref);
        for (a, b) in u.iter().zip(&u_ref) {
            assert!((a - b).abs() < 1e-12 * (1.0 + b.abs()), "u");
        }
        for (a, b) in av.iter().zip(&av_ref) {
            assert!((a - b).abs() < 1e-10 * (1.0 + b.abs()), "av");
        }
    }

    #[test]
    fn multi_panels_bit_identical_to_single_per_model() {
        // The multi-response kernels promise per-model bit-identity to
        // k separate single-response calls, at every batch width and
        // awkward row count (tail handling included).
        for &(m, n) in &[(0usize, 5usize), (1, 5), (3, 7), (4, 4), (5, 1), (13, 9), (23, 11)] {
            let data = randvec(m * n, (m * 131 + n) as u64 + 1);
            for k in [1usize, 2, 3, 5] {
                let rs_own: Vec<Vec<f64>> =
                    (0..k).map(|i| randvec(m, 500 + i as u64)).collect();
                let rs: Vec<&[f64]> = rs_own.iter().map(|v| v.as_slice()).collect();
                // at_r_multi_panel vs k at_r_panel calls
                let mut multi = vec![vec![0.0; n]; k];
                {
                    let mut accs: Vec<&mut [f64]> =
                        multi.iter_mut().map(|v| v.as_mut_slice()).collect();
                    at_r_multi_panel(&data, n, &rs, &mut accs);
                }
                for (i, r) in rs.iter().enumerate() {
                    let mut single = vec![0.0; n];
                    at_r_panel(&data, n, r, &mut single);
                    for (a, b) in multi[i].iter().zip(&single) {
                        assert_eq!(a.to_bits(), b.to_bits(), "at_r ({m},{n}) k={k} model {i}");
                    }
                }
                // fused_step_multi_panel vs k fused_step_panel calls,
                // each model with its own column subset and weights.
                if n == 0 {
                    continue;
                }
                let cols_own: Vec<Vec<usize>> =
                    (0..k).map(|i| (i % n..n).step_by(2).collect()).collect();
                let ws_own: Vec<Vec<f64>> =
                    cols_own.iter().enumerate().map(|(i, c)| randvec(c.len(), 900 + i as u64)).collect();
                let cols: Vec<&[usize]> = cols_own.iter().map(|v| v.as_slice()).collect();
                let ws: Vec<&[f64]> = ws_own.iter().map(|v| v.as_slice()).collect();
                let mut us = vec![vec![0.0; m]; k];
                let mut avs = vec![vec![0.0; n]; k];
                {
                    let mut u_sl: Vec<&mut [f64]> =
                        us.iter_mut().map(|v| v.as_mut_slice()).collect();
                    let mut av_sl: Vec<&mut [f64]> =
                        avs.iter_mut().map(|v| v.as_mut_slice()).collect();
                    fused_step_multi_panel(&data, n, &cols, &ws, &mut u_sl, &mut av_sl);
                }
                for i in 0..k {
                    let mut u1 = vec![0.0; m];
                    let mut av1 = vec![0.0; n];
                    fused_step_panel(&data, n, cols[i], ws[i], &mut u1, &mut av1);
                    for (a, b) in us[i].iter().zip(&u1) {
                        assert_eq!(a.to_bits(), b.to_bits(), "u ({m},{n}) k={k} model {i}");
                    }
                    for (a, b) in avs[i].iter().zip(&av1) {
                        assert_eq!(a.to_bits(), b.to_bits(), "av ({m},{n}) k={k} model {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn gamma_scan_range_concatenates_to_full_scan() {
        let n = 57;
        let c = randvec(n, 1);
        let av = randvec(n, 2);
        let mut in_model = vec![false; n];
        for j in (0..n).step_by(5) {
            in_model[j] = true;
        }
        let (ck, h, gamma_full) = (1.7, 0.9, 1.0 / 0.9);
        let mut whole = Vec::new();
        gamma_scan_range(0, n, &in_model, &c, &av, ck, h, gamma_full, &mut whole);
        let mut chunked = Vec::new();
        for lo in (0..n).step_by(13) {
            gamma_scan_range(lo, (lo + 13).min(n), &in_model, &c, &av, ck, h, gamma_full, &mut chunked);
        }
        assert_eq!(whole.len(), chunked.len());
        for ((j1, g1), (j2, g2)) in whole.iter().zip(&chunked) {
            assert_eq!(j1, j2);
            assert_eq!(g1.to_bits(), g2.to_bits());
        }
        assert!(!whole.is_empty(), "scan produced no candidates");
    }

    #[test]
    fn sparse_helpers_match_naive() {
        let rows: Vec<u32> = vec![0, 2, 3, 5, 8, 9, 11];
        let vals = randvec(rows.len(), 3);
        let r = randvec(12, 4);
        let naive: f64 = rows.iter().zip(&vals).map(|(&ri, &v)| v * r[ri as usize]).sum();
        assert!((sparse_dot(&rows, &vals, &r) - naive).abs() < 1e-12);
        let mut out1 = vec![0.0; 12];
        let mut out2 = vec![0.0; 12];
        scatter_axpy(1.5, &rows, &vals, &mut out1);
        for (&ri, &v) in rows.iter().zip(&vals) {
            out2[ri as usize] += 1.5 * v;
        }
        for (a, b) in out1.iter().zip(&out2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
