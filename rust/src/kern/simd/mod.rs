//! Runtime-dispatched SIMD backends for the kernel engine.
//!
//! The scalar kernels in [`scalar`] were deliberately shaped with
//! 4-accumulator lanes and 4-row packs so they would map onto vector
//! registers without changing the summation order. This module cashes
//! that in: explicit AVX2 / AVX-512F / NEON paths via `core::arch`,
//! selected **once** per process by runtime feature detection and
//! overridable with `CALARS_ISA=scalar|avx2|avx512|neon` (or `--isa`
//! on the CLI).
//!
//! # Determinism contract
//!
//! - Resolution order for [`current`]: a [`with_backend`] thread-local
//!   override, then the backend captured by the owning
//!   [`crate::par::ThreadPool`] (workers always agree with the thread
//!   that built their pool), then the process-global choice.
//! - AVX2 (4 × f64) and NEON (2 × f64 register pairs) reproduce the
//!   canonical order exactly: every kernel is bit-identical to
//!   [`scalar`].
//! - AVX-512F reduces `dot`/`sq_norm` with one 8-lane accumulator — a
//!   genuinely different reduction tree — so those two kernels are
//!   gated at 1e-9 against `kern::reference`; every other AVX-512
//!   kernel vectorizes the *output* index and stays bit-identical.
//! - No backend uses FMA: one rounding per multiply and one per add,
//!   exactly like the scalar code, on every ISA.
//!
//! The per-kernel dispatch table and divergence classes are documented
//! in DESIGN.md §"Kernel engine · SIMD backends".

use std::cell::Cell;
use std::sync::OnceLock;

use crate::error::{bail, Result};

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
mod avx512;
#[cfg(target_arch = "aarch64")]
mod neon;
mod scalar;

/// A kernel ISA backend. All variants exist on every architecture so
/// parsing, reporting and the cross-backend test matrix are uniform;
/// [`KernBackend::supported`] says whether the *host* can run one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernBackend {
    /// Portable blocked-scalar kernels — the canonical-order reference.
    Scalar,
    /// AVX2: 4 × f64 registers, bit-identical to scalar everywhere.
    Avx2,
    /// AVX-512F: 8 × f64; `dot`/`sq_norm` are 1e-9-gated, the rest
    /// bit-identical.
    Avx512,
    /// NEON (aarch64): 2 × f64 register pairs, bit-identical to scalar
    /// everywhere.
    Neon,
}

impl KernBackend {
    /// Every backend, in preference order (widest first).
    pub const ALL: [KernBackend; 4] =
        [KernBackend::Avx512, KernBackend::Avx2, KernBackend::Neon, KernBackend::Scalar];

    /// The lowercase name used by `CALARS_ISA`, `--isa`, `info --json`,
    /// `/stats` and `/metrics`.
    pub fn name(self) -> &'static str {
        match self {
            KernBackend::Scalar => "scalar",
            KernBackend::Avx2 => "avx2",
            KernBackend::Avx512 => "avx512",
            KernBackend::Neon => "neon",
        }
    }

    /// Parse a `CALARS_ISA` / `--isa` value (exact lowercase names).
    pub fn parse(s: &str) -> Option<KernBackend> {
        match s {
            "scalar" => Some(KernBackend::Scalar),
            "avx2" => Some(KernBackend::Avx2),
            "avx512" => Some(KernBackend::Avx512),
            "neon" => Some(KernBackend::Neon),
            _ => None,
        }
    }

    /// Whether this host can execute the backend, via runtime feature
    /// detection (`is_x86_feature_detected!` / aarch64 equivalent).
    pub fn supported(self) -> bool {
        match self {
            KernBackend::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            KernBackend::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            KernBackend::Avx512 => {
                is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx2")
            }
            #[cfg(target_arch = "aarch64")]
            KernBackend::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            _ => false,
        }
    }

    /// The widest backend this host supports.
    pub fn detect() -> KernBackend {
        for b in KernBackend::ALL {
            if b.supported() {
                return b;
            }
        }
        KernBackend::Scalar
    }

    /// Every backend the host supports, widest first
    /// ([`KernBackend::Scalar`] is always last).
    pub fn available() -> Vec<KernBackend> {
        KernBackend::ALL.into_iter().filter(|b| b.supported()).collect()
    }

    /// Whether every kernel under this backend is bit-identical to the
    /// scalar canonical order. Only AVX-512 diverges (its 8-lane
    /// `dot`/`sq_norm` reduction tree), and only within the 1e-9 gate.
    pub fn bit_identical_to_scalar(self) -> bool {
        !matches!(self, KernBackend::Avx512)
    }
}

static GLOBAL: OnceLock<KernBackend> = OnceLock::new();

thread_local! {
    /// Scoped override installed by [`with_backend`].
    static OVERRIDE: Cell<Option<KernBackend>> = const { Cell::new(None) };
}

/// The library default: `CALARS_ISA` when set, valid and supported
/// (warning on stderr otherwise, like `CALARS_THREADS`), else the
/// widest detected backend. The `calars` binary resolves the knob
/// loudly up front via [`init_from_cli`] instead.
fn default_backend() -> KernBackend {
    match std::env::var("CALARS_ISA") {
        Err(_) => KernBackend::detect(),
        Ok(v) => match KernBackend::parse(v.trim()) {
            Some(b) if b.supported() => b,
            Some(b) => {
                eprintln!(
                    "warning: CALARS_ISA={} is not supported on this host; using {}",
                    b.name(),
                    KernBackend::detect().name()
                );
                KernBackend::detect()
            }
            None => {
                eprintln!(
                    "warning: ignoring unrecognized CALARS_ISA={v:?} \
                     (expected scalar|avx2|avx512|neon); using {}",
                    KernBackend::detect().name()
                );
                KernBackend::detect()
            }
        },
    }
}

/// Install `b` as the process-global backend (first caller wins, like
/// `par::configure`). Returns `false` if the host cannot run `b` or a
/// *different* backend was already installed.
pub fn configure(b: KernBackend) -> bool {
    if !b.supported() {
        return false;
    }
    GLOBAL.set(b).is_ok() || GLOBAL.get() == Some(&b)
}

/// Resolve the ISA knob for the `calars` binary: `--isa` beats
/// `CALARS_ISA` beats detection, and — unlike the lazy library default
/// — an unknown or unsupported name is a hard error so a stale env var
/// cannot silently change which kernels run.
pub fn init_from_cli(cli: Option<&str>) -> Result<KernBackend> {
    let (src, raw) = match cli {
        Some(v) => ("--isa", v.to_string()),
        None => match std::env::var("CALARS_ISA") {
            Ok(v) => ("CALARS_ISA", v),
            Err(_) => {
                let b = KernBackend::detect();
                configure(b);
                return Ok(b);
            }
        },
    };
    let Some(b) = KernBackend::parse(raw.trim()) else {
        bail!("{src}: unknown kernel backend {raw:?} (expected scalar|avx2|avx512|neon)");
    };
    if !b.supported() {
        let avail: Vec<&str> = KernBackend::available().iter().map(|b| b.name()).collect();
        bail!(
            "{src}: backend '{}' is not supported on this host (available: {})",
            b.name(),
            avail.join(", ")
        );
    }
    if !configure(b) {
        bail!("{src}: kernel backend already configured as '{}'", current().name());
    }
    Ok(b)
}

/// The backend kernels dispatch to on this thread right now:
/// a [`with_backend`] override, else the backend captured by the pool
/// that owns this worker thread, else the process-global choice
/// (initialized lazily from `CALARS_ISA` / detection).
pub fn current() -> KernBackend {
    if let Some(b) = OVERRIDE.with(|o| o.get()) {
        return b;
    }
    if let Some(b) = crate::par::pool::worker_backend() {
        return b;
    }
    *GLOBAL.get_or_init(default_backend)
}

/// Run `f` with `b` as this thread's backend (panics if the host does
/// not support `b`). Restores the previous override on exit, including
/// on unwind. Pool workers do **not** see the override — construct the
/// pool *inside* the closure so it captures `b` for its workers.
pub fn with_backend<R>(b: KernBackend, f: impl FnOnce() -> R) -> R {
    assert!(b.supported(), "kernel backend {} is not supported on this host", b.name());
    struct Reset(Option<KernBackend>);
    impl Drop for Reset {
        fn drop(&mut self) {
            OVERRIDE.with(|o| o.set(self.0));
        }
    }
    let prev = OVERRIDE.with(|o| o.replace(Some(b)));
    let _reset = Reset(prev);
    f()
}

/// Route one kernel call to the active backend.
///
/// Each vector arm is compiled for its ISA via `#[target_feature]` and
/// is only reachable when [`current`] returned that backend, which
/// [`KernBackend::supported`] gates on runtime feature detection — so
/// the required CPU features are guaranteed present at every call.
macro_rules! dispatch {
    ($name:ident ( $($arg:expr),* $(,)? )) => {
        match current() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Avx2 is only current() after is_x86_feature_detected!("avx2").
            KernBackend::Avx2 => unsafe { avx2::$name($($arg),*) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Avx512 is only current() after is_x86_feature_detected!
            // verified both avx512f and avx2.
            KernBackend::Avx512 => unsafe { avx512::$name($($arg),*) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: Neon is only current() after is_aarch64_feature_detected!("neon").
            KernBackend::Neon => unsafe { neon::$name($($arg),*) },
            _ => scalar::$name($($arg),*),
        }
    };
}

/// Dispatched dot product (canonical order; AVX-512 is 1e-9-gated).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dispatch!(dot(a, b))
}

/// Dispatched sum of squares (canonical order; AVX-512 is 1e-9-gated).
#[inline]
pub fn sq_norm(x: &[f64]) -> f64 {
    dispatch!(sq_norm(x))
}

/// Dispatched `y += alpha·x` (element-wise: bit-identical everywhere).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    dispatch!(axpy(alpha, x, y))
}

/// Dispatched gather dot (4-accumulator order on every backend:
/// bit-identical everywhere).
#[inline]
pub fn dot_idx(row: &[f64], cols: &[usize], w: &[f64]) -> f64 {
    dispatch!(dot_idx(row, cols, w))
}

/// Dispatched sparse gather dot (bit-identical everywhere).
#[inline]
pub fn sparse_dot(rows: &[u32], vals: &[f64], r: &[f64]) -> f64 {
    dispatch!(sparse_dot(rows, vals, r))
}

/// Dispatched sparse scatter (bit-identical everywhere).
#[inline]
pub fn scatter_axpy(wk: f64, rows: &[u32], vals: &[f64], out: &mut [f64]) {
    dispatch!(scatter_axpy(wk, rows, vals, out))
}

/// Dispatched `Aᵀr` streaming panel (element-wise over the output:
/// bit-identical everywhere).
#[inline]
pub fn at_r_panel(rows: &[f64], n: usize, r: &[f64], acc: &mut [f64]) {
    dispatch!(at_r_panel(rows, n, r, acc))
}

/// Dispatched column square-norm panel (bit-identical everywhere).
#[inline]
pub fn col_sq_norms_panel(rows: &[f64], n: usize, acc: &mut [f64]) {
    dispatch!(col_sq_norms_panel(rows, n, acc))
}

/// Dispatched packed 4×4 gram micro-GEMM (bit-identical everywhere).
#[inline]
pub fn gram_panel(
    rows: &[f64],
    n: usize,
    ii: &[usize],
    jj: &[usize],
    pi: &mut [f64],
    pj: &mut [f64],
    acc: &mut [f64],
) {
    dispatch!(gram_panel(rows, n, ii, jj, pi, pj, acc))
}

/// Dispatched active-set gather panel (bit-identical everywhere).
#[inline]
pub fn cols_dot_panel(rows: &[f64], n: usize, cols: &[usize], r: &[f64], acc: &mut [f64]) {
    dispatch!(cols_dot_panel(rows, n, cols, r, acc))
}

/// Dispatched fused equiangular step (bit-identical everywhere — the
/// internal gather dot keeps the 4-accumulator order on every ISA).
#[inline]
pub fn fused_step_panel(
    rows: &[f64],
    n: usize,
    cols: &[usize],
    w: &[f64],
    u: &mut [f64],
    av: &mut [f64],
) {
    dispatch!(fused_step_panel(rows, n, cols, w, u, av))
}

/// Dispatched multi-response `Aᵀ R` panel (bit-identical everywhere).
#[inline]
pub fn at_r_multi_panel(rows: &[f64], n: usize, rs: &[&[f64]], accs: &mut [&mut [f64]]) {
    dispatch!(at_r_multi_panel(rows, n, rs, accs))
}

/// Dispatched multi-response fused step (bit-identical everywhere).
#[inline]
pub fn fused_step_multi_panel(
    rows: &[f64],
    n: usize,
    cols: &[&[usize]],
    ws: &[&[f64]],
    us: &mut [&mut [f64]],
    avs: &mut [&mut [f64]],
) {
    dispatch!(fused_step_multi_panel(rows, n, cols, ws, us, avs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_parse_round_trip() {
        for b in KernBackend::ALL {
            assert_eq!(KernBackend::parse(b.name()), Some(b));
        }
        assert_eq!(KernBackend::parse("sse2"), None);
        assert_eq!(KernBackend::parse("AVX2"), None, "names are exact lowercase");
    }

    #[test]
    fn detection_is_coherent() {
        let detected = KernBackend::detect();
        assert!(detected.supported());
        let avail = KernBackend::available();
        assert_eq!(avail.first().copied(), Some(detected), "detect() is the widest available");
        assert_eq!(avail.last().copied(), Some(KernBackend::Scalar), "scalar is always available");
        assert!(KernBackend::Scalar.bit_identical_to_scalar());
        assert!(!KernBackend::Avx512.bit_identical_to_scalar());
    }

    #[test]
    fn with_backend_overrides_and_restores() {
        let ambient = current();
        let inside = with_backend(KernBackend::Scalar, || {
            let inner = current();
            with_backend(KernBackend::Scalar, || assert_eq!(current(), KernBackend::Scalar));
            inner
        });
        assert_eq!(inside, KernBackend::Scalar);
        assert_eq!(current(), ambient, "override must be scoped");
    }

    #[test]
    fn every_available_backend_runs_a_kernel() {
        let a: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64 * 0.7).cos()).collect();
        let want = crate::kern::reference::dot(&a, &b);
        for backend in KernBackend::available() {
            let got = with_backend(backend, || dot(&a, &b));
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "{}: {got} vs {want}",
                backend.name()
            );
        }
    }
}
