//! AVX-512F backend: 8 × f64 per register.
//!
//! Divergence classes (see DESIGN.md §"Kernel engine · SIMD"):
//!
//! - [`dot`] / [`sq_norm`] use one 8-lane accumulator whose reduce tree
//!   `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` and 8-element tail differ
//!   from the canonical 4-accumulator order — these two kernels are the
//!   **only** 1e-9-gated divergences in the whole engine.
//! - The streaming panels vectorize the *output* index 8-wide; each
//!   output element sees exactly the scalar add tree, so they stay
//!   **bit-identical** at any lane width.
//! - Gather/scatter kernels and the gram micro-GEMM delegate to the
//!   [`super::avx2`] implementations (bit-identical by construction);
//!   an avx512f host always has avx2.
//!
//! No FMA anywhere: one rounding per multiply, one per add, exactly
//! like the scalar code.

use core::arch::x86_64::*;

/// Store the 8 lanes and combine `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`.
///
/// SAFETY: caller must ensure AVX-512F support (dispatcher-guaranteed).
#[target_feature(enable = "avx512f")]
unsafe fn hsum8(acc: __m512d) -> f64 {
    let mut lanes = [0.0f64; 8];
    _mm512_storeu_pd(lanes.as_mut_ptr(), acc);
    ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
}

/// AVX-512 dot: one 8-lane accumulator, 8-element tail. **Divergent**
/// from the canonical order (different reduction tree) — gated at 1e-9
/// against `kern::reference` instead of bit-identity.
///
/// SAFETY: caller must ensure AVX-512F support (dispatcher-guaranteed).
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let groups = n / 8;
    let mut acc = _mm512_setzero_pd();
    for g in 0..groups {
        let j = g * 8;
        let va = _mm512_loadu_pd(a.as_ptr().add(j));
        let vb = _mm512_loadu_pd(b.as_ptr().add(j));
        acc = _mm512_add_pd(acc, _mm512_mul_pd(va, vb));
    }
    let mut s = hsum8(acc);
    for j in groups * 8..n {
        s += a[j] * b[j];
    }
    s
}

/// AVX-512 sum of squares; **divergent** like [`dot`] (1e-9-gated).
///
/// SAFETY: caller must ensure AVX-512F support (dispatcher-guaranteed).
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn sq_norm(x: &[f64]) -> f64 {
    let n = x.len();
    let groups = n / 8;
    let mut acc = _mm512_setzero_pd();
    for g in 0..groups {
        let j = g * 8;
        let v = _mm512_loadu_pd(x.as_ptr().add(j));
        acc = _mm512_add_pd(acc, _mm512_mul_pd(v, v));
    }
    let mut s = hsum8(acc);
    for j in groups * 8..n {
        s += x[j] * x[j];
    }
    s
}

/// AVX-512 axpy, 8-wide; element-wise so bit-identical.
///
/// SAFETY: caller must ensure AVX-512F support (dispatcher-guaranteed).
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let groups = n / 8;
    let va = _mm512_set1_pd(alpha);
    for g in 0..groups {
        let j = g * 8;
        let vx = _mm512_loadu_pd(x.as_ptr().add(j));
        let vy = _mm512_loadu_pd(y.as_ptr().add(j));
        let vy = _mm512_add_pd(vy, _mm512_mul_pd(va, vx));
        _mm512_storeu_pd(y.as_mut_ptr().add(j), vy);
    }
    for j in groups * 8..n {
        y[j] += alpha * x[j];
    }
}

/// Delegates to the AVX2 gather kernel (canonical 4-accumulator order,
/// bit-identical) — the gather dominates, wider registers don't help.
///
/// SAFETY: caller must ensure AVX-512F+AVX2 support
/// (dispatcher-guaranteed; avx512f hosts have avx2).
#[target_feature(enable = "avx512f,avx2")]
pub(super) unsafe fn dot_idx(row: &[f64], cols: &[usize], w: &[f64]) -> f64 {
    super::avx2::dot_idx(row, cols, w)
}

/// Delegates to the AVX2 sparse gather kernel (bit-identical).
///
/// SAFETY: caller must ensure AVX-512F+AVX2 support
/// (dispatcher-guaranteed).
#[target_feature(enable = "avx512f,avx2")]
pub(super) unsafe fn sparse_dot(rows: &[u32], vals: &[f64], r: &[f64]) -> f64 {
    super::avx2::sparse_dot(rows, vals, r)
}

/// Delegates to the AVX2 scatter kernel (bit-identical).
///
/// SAFETY: caller must ensure AVX-512F+AVX2 support
/// (dispatcher-guaranteed).
#[target_feature(enable = "avx512f,avx2")]
pub(super) unsafe fn scatter_axpy(wk: f64, rows: &[u32], vals: &[f64], out: &mut [f64]) {
    super::avx2::scatter_axpy(wk, rows, vals, out)
}

/// AVX-512 `Aᵀr` panel: four broadcast row weights, output index `j`
/// vectorized 8-wide; per element the scalar add tree, bit-identical.
///
/// SAFETY: caller must ensure AVX-512F support (dispatcher-guaranteed).
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn at_r_panel(rows: &[f64], n: usize, r: &[f64], acc: &mut [f64]) {
    debug_assert_eq!(rows.len(), r.len() * n);
    debug_assert_eq!(acc.len(), n);
    let m = r.len();
    let packs = m / 4;
    let groups = n / 8;
    for p in 0..packs {
        let i = p * 4;
        let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        let (v0, v1, v2, v3) =
            (_mm512_set1_pd(r0), _mm512_set1_pd(r1), _mm512_set1_pd(r2), _mm512_set1_pd(r3));
        for g in 0..groups {
            let j = g * 8;
            let a = _mm512_loadu_pd(acc.as_ptr().add(j));
            let t01 = _mm512_add_pd(
                _mm512_mul_pd(v0, _mm512_loadu_pd(x0.as_ptr().add(j))),
                _mm512_mul_pd(v1, _mm512_loadu_pd(x1.as_ptr().add(j))),
            );
            let t23 = _mm512_add_pd(
                _mm512_mul_pd(v2, _mm512_loadu_pd(x2.as_ptr().add(j))),
                _mm512_mul_pd(v3, _mm512_loadu_pd(x3.as_ptr().add(j))),
            );
            _mm512_storeu_pd(acc.as_mut_ptr().add(j), _mm512_add_pd(a, _mm512_add_pd(t01, t23)));
        }
        for j in groups * 8..n {
            acc[j] += (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
        }
    }
    for i in packs * 4..m {
        let ri = r[i];
        let vri = _mm512_set1_pd(ri);
        let row = &rows[i * n..(i + 1) * n];
        for g in 0..groups {
            let j = g * 8;
            let a = _mm512_loadu_pd(acc.as_ptr().add(j));
            let x = _mm512_loadu_pd(row.as_ptr().add(j));
            _mm512_storeu_pd(acc.as_mut_ptr().add(j), _mm512_add_pd(a, _mm512_mul_pd(vri, x)));
        }
        for j in groups * 8..n {
            acc[j] += ri * row[j];
        }
    }
}

/// AVX-512 column square norms, 8-wide over `j`; bit-identical.
///
/// SAFETY: caller must ensure AVX-512F support (dispatcher-guaranteed).
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn col_sq_norms_panel(rows: &[f64], n: usize, acc: &mut [f64]) {
    debug_assert_eq!(acc.len(), n);
    if n == 0 {
        return;
    }
    let m = rows.len() / n;
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    let groups = n / 8;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for g in 0..groups {
            let j = g * 8;
            let a = _mm512_loadu_pd(acc.as_ptr().add(j));
            let w0 = _mm512_loadu_pd(x0.as_ptr().add(j));
            let w1 = _mm512_loadu_pd(x1.as_ptr().add(j));
            let w2 = _mm512_loadu_pd(x2.as_ptr().add(j));
            let w3 = _mm512_loadu_pd(x3.as_ptr().add(j));
            let t01 = _mm512_add_pd(_mm512_mul_pd(w0, w0), _mm512_mul_pd(w1, w1));
            let t23 = _mm512_add_pd(_mm512_mul_pd(w2, w2), _mm512_mul_pd(w3, w3));
            _mm512_storeu_pd(acc.as_mut_ptr().add(j), _mm512_add_pd(a, _mm512_add_pd(t01, t23)));
        }
        for j in groups * 8..n {
            acc[j] += (x0[j] * x0[j] + x1[j] * x1[j]) + (x2[j] * x2[j] + x3[j] * x3[j]);
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for g in 0..groups {
            let j = g * 8;
            let a = _mm512_loadu_pd(acc.as_ptr().add(j));
            let x = _mm512_loadu_pd(row.as_ptr().add(j));
            _mm512_storeu_pd(acc.as_mut_ptr().add(j), _mm512_add_pd(a, _mm512_mul_pd(x, x)));
        }
        for j in groups * 8..n {
            acc[j] += row[j] * row[j];
        }
    }
}

/// Delegates to the AVX2 4×4 micro-GEMM (bit-identical): the tile's
/// `b` dimension is 4 wide by construction, so 256-bit registers are
/// the natural width.
///
/// SAFETY: caller must ensure AVX-512F+AVX2 support
/// (dispatcher-guaranteed).
#[target_feature(enable = "avx512f,avx2")]
pub(super) unsafe fn gram_panel(
    rows: &[f64],
    n: usize,
    ii: &[usize],
    jj: &[usize],
    pi: &mut [f64],
    pj: &mut [f64],
    acc: &mut [f64],
) {
    super::avx2::gram_panel(rows, n, ii, jj, pi, pj, acc)
}

/// Delegates to the AVX2 active-set gather kernel (bit-identical).
///
/// SAFETY: caller must ensure AVX-512F+AVX2 support
/// (dispatcher-guaranteed).
#[target_feature(enable = "avx512f,avx2")]
pub(super) unsafe fn cols_dot_panel(
    rows: &[f64],
    n: usize,
    cols: &[usize],
    r: &[f64],
    acc: &mut [f64],
) {
    super::avx2::cols_dot_panel(rows, n, cols, r, acc)
}

/// AVX-512 fused equiangular step: `u` from the AVX2 [`dot_idx`]
/// (canonical 4-accumulator order), the `av` update 8-wide
/// element-wise; bit-identical — the 8-lane divergence is confined to
/// [`dot`]/[`sq_norm`].
///
/// SAFETY: caller must ensure AVX-512F+AVX2 support
/// (dispatcher-guaranteed).
#[target_feature(enable = "avx512f,avx2")]
pub(super) unsafe fn fused_step_panel(
    rows: &[f64],
    n: usize,
    cols: &[usize],
    w: &[f64],
    u: &mut [f64],
    av: &mut [f64],
) {
    debug_assert_eq!(cols.len(), w.len());
    debug_assert_eq!(av.len(), n);
    debug_assert_eq!(rows.len(), u.len() * n);
    let m = u.len();
    let packs = m / 4;
    let groups = n / 8;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        let u0 = super::avx2::dot_idx(x0, cols, w);
        let u1 = super::avx2::dot_idx(x1, cols, w);
        let u2 = super::avx2::dot_idx(x2, cols, w);
        let u3 = super::avx2::dot_idx(x3, cols, w);
        u[i] = u0;
        u[i + 1] = u1;
        u[i + 2] = u2;
        u[i + 3] = u3;
        let (v0, v1, v2, v3) =
            (_mm512_set1_pd(u0), _mm512_set1_pd(u1), _mm512_set1_pd(u2), _mm512_set1_pd(u3));
        for g in 0..groups {
            let j = g * 8;
            let a = _mm512_loadu_pd(av.as_ptr().add(j));
            let t01 = _mm512_add_pd(
                _mm512_mul_pd(v0, _mm512_loadu_pd(x0.as_ptr().add(j))),
                _mm512_mul_pd(v1, _mm512_loadu_pd(x1.as_ptr().add(j))),
            );
            let t23 = _mm512_add_pd(
                _mm512_mul_pd(v2, _mm512_loadu_pd(x2.as_ptr().add(j))),
                _mm512_mul_pd(v3, _mm512_loadu_pd(x3.as_ptr().add(j))),
            );
            _mm512_storeu_pd(av.as_mut_ptr().add(j), _mm512_add_pd(a, _mm512_add_pd(t01, t23)));
        }
        for j in groups * 8..n {
            av[j] += (u0 * x0[j] + u1 * x1[j]) + (u2 * x2[j] + u3 * x3[j]);
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        let ui = super::avx2::dot_idx(row, cols, w);
        u[i] = ui;
        let vui = _mm512_set1_pd(ui);
        for g in 0..groups {
            let j = g * 8;
            let a = _mm512_loadu_pd(av.as_ptr().add(j));
            let x = _mm512_loadu_pd(row.as_ptr().add(j));
            _mm512_storeu_pd(av.as_mut_ptr().add(j), _mm512_add_pd(a, _mm512_mul_pd(vui, x)));
        }
        for j in groups * 8..n {
            av[j] += ui * row[j];
        }
    }
}

/// AVX-512 multi-response `Aᵀ R`, 8-wide over `j`; per model
/// bit-identical to [`at_r_panel`].
///
/// SAFETY: caller must ensure AVX-512F support (dispatcher-guaranteed).
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn at_r_multi_panel(
    rows: &[f64],
    n: usize,
    rs: &[&[f64]],
    accs: &mut [&mut [f64]],
) {
    debug_assert_eq!(rs.len(), accs.len());
    let Some(first) = rs.first() else { return };
    let m = first.len();
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    let groups = n / 8;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for (r, acc) in rs.iter().zip(accs.iter_mut()) {
            debug_assert_eq!(r.len(), m);
            debug_assert_eq!(acc.len(), n);
            let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
            let (v0, v1, v2, v3) =
                (_mm512_set1_pd(r0), _mm512_set1_pd(r1), _mm512_set1_pd(r2), _mm512_set1_pd(r3));
            for g in 0..groups {
                let j = g * 8;
                let a = _mm512_loadu_pd(acc.as_ptr().add(j));
                let t01 = _mm512_add_pd(
                    _mm512_mul_pd(v0, _mm512_loadu_pd(x0.as_ptr().add(j))),
                    _mm512_mul_pd(v1, _mm512_loadu_pd(x1.as_ptr().add(j))),
                );
                let t23 = _mm512_add_pd(
                    _mm512_mul_pd(v2, _mm512_loadu_pd(x2.as_ptr().add(j))),
                    _mm512_mul_pd(v3, _mm512_loadu_pd(x3.as_ptr().add(j))),
                );
                _mm512_storeu_pd(
                    acc.as_mut_ptr().add(j),
                    _mm512_add_pd(a, _mm512_add_pd(t01, t23)),
                );
            }
            for j in groups * 8..n {
                acc[j] += (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
            }
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for (r, acc) in rs.iter().zip(accs.iter_mut()) {
            let ri = r[i];
            let vri = _mm512_set1_pd(ri);
            for g in 0..groups {
                let j = g * 8;
                let a = _mm512_loadu_pd(acc.as_ptr().add(j));
                let x = _mm512_loadu_pd(row.as_ptr().add(j));
                _mm512_storeu_pd(acc.as_mut_ptr().add(j), _mm512_add_pd(a, _mm512_mul_pd(vri, x)));
            }
            for j in groups * 8..n {
                acc[j] += ri * row[j];
            }
        }
    }
}

/// AVX-512 multi-response fused step: per model bit-identical to
/// [`fused_step_panel`].
///
/// SAFETY: caller must ensure AVX-512F+AVX2 support
/// (dispatcher-guaranteed).
#[target_feature(enable = "avx512f,avx2")]
pub(super) unsafe fn fused_step_multi_panel(
    rows: &[f64],
    n: usize,
    cols: &[&[usize]],
    ws: &[&[f64]],
    us: &mut [&mut [f64]],
    avs: &mut [&mut [f64]],
) {
    debug_assert_eq!(cols.len(), ws.len());
    debug_assert_eq!(cols.len(), us.len());
    debug_assert_eq!(cols.len(), avs.len());
    let Some(first) = us.first() else { return };
    let m = first.len();
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    let groups = n / 8;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for k in 0..cols.len() {
            let (ck, wk) = (cols[k], ws[k]);
            debug_assert_eq!(ck.len(), wk.len());
            let u0 = super::avx2::dot_idx(x0, ck, wk);
            let u1 = super::avx2::dot_idx(x1, ck, wk);
            let u2 = super::avx2::dot_idx(x2, ck, wk);
            let u3 = super::avx2::dot_idx(x3, ck, wk);
            let u = &mut us[k];
            u[i] = u0;
            u[i + 1] = u1;
            u[i + 2] = u2;
            u[i + 3] = u3;
            let av = &mut avs[k];
            let (v0, v1, v2, v3) =
                (_mm512_set1_pd(u0), _mm512_set1_pd(u1), _mm512_set1_pd(u2), _mm512_set1_pd(u3));
            for g in 0..groups {
                let j = g * 8;
                let a = _mm512_loadu_pd(av.as_ptr().add(j));
                let t01 = _mm512_add_pd(
                    _mm512_mul_pd(v0, _mm512_loadu_pd(x0.as_ptr().add(j))),
                    _mm512_mul_pd(v1, _mm512_loadu_pd(x1.as_ptr().add(j))),
                );
                let t23 = _mm512_add_pd(
                    _mm512_mul_pd(v2, _mm512_loadu_pd(x2.as_ptr().add(j))),
                    _mm512_mul_pd(v3, _mm512_loadu_pd(x3.as_ptr().add(j))),
                );
                _mm512_storeu_pd(av.as_mut_ptr().add(j), _mm512_add_pd(a, _mm512_add_pd(t01, t23)));
            }
            for j in groups * 8..n {
                av[j] += (u0 * x0[j] + u1 * x1[j]) + (u2 * x2[j] + u3 * x3[j]);
            }
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for k in 0..cols.len() {
            let ui = super::avx2::dot_idx(row, cols[k], ws[k]);
            us[k][i] = ui;
            let av = &mut avs[k];
            let vui = _mm512_set1_pd(ui);
            for g in 0..groups {
                let j = g * 8;
                let a = _mm512_loadu_pd(av.as_ptr().add(j));
                let x = _mm512_loadu_pd(row.as_ptr().add(j));
                _mm512_storeu_pd(av.as_mut_ptr().add(j), _mm512_add_pd(a, _mm512_mul_pd(vui, x)));
            }
            for j in groups * 8..n {
                av[j] += ui * row[j];
            }
        }
    }
}
