//! AVX2 backend: 4 × f64 per register, which maps *exactly* onto the
//! scalar kernels' four accumulators / four-row packs — lane `i` of a
//! vector register is scalar accumulator `i`, and the horizontal
//! reduce recombines lanes in the canonical `(s0+s1) + (s2+s3)` order.
//! Every kernel in this file is therefore **bit-identical** to
//! [`super::scalar`]; there is no gated divergence on AVX2.
//!
//! No FMA is used anywhere: the contract is one rounding per multiply
//! and one per add, exactly like the scalar code, even though the host
//! may advertise `fma`.
//!
//! The gather-shaped kernels (`dot_idx`, `sparse_dot`, `scatter_axpy`,
//! `cols_dot_panel`) keep the scalar 4-accumulator loop bodies inside
//! a `#[target_feature]` fn — they are index-chasing bound, and giving
//! the compiler the AVX2 feature set is worth more than hand-placed
//! gathers. `dot_idx`/`sparse_dot` additionally pack their four
//! gathered values with `_mm256_set_pd` (arguments high-lane-first) so
//! the arithmetic stays in the canonical lane order.

use core::arch::x86_64::*;

/// Store the 4 lanes and combine `(l0+l1) + (l2+l3)` — the canonical
/// scalar accumulator merge.
///
/// SAFETY: caller must ensure AVX support; every caller in this module
/// is an AVX2 fn (avx2 implies avx), reachable only after runtime
/// detection.
#[target_feature(enable = "avx")]
unsafe fn hsum4(acc: __m256d) -> f64 {
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    (lanes[0] + lanes[1]) + (lanes[2] + lanes[3])
}

/// AVX2 [`super::scalar::dot`]: one 4-lane accumulator register whose
/// lanes are the four scalar accumulators; bit-identical.
///
/// SAFETY: the caller must ensure the CPU supports AVX2 — the
/// dispatcher guarantees this via runtime feature detection.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let groups = n / 4;
    let mut acc = _mm256_setzero_pd();
    for g in 0..groups {
        let j = g * 4;
        let va = _mm256_loadu_pd(a.as_ptr().add(j));
        let vb = _mm256_loadu_pd(b.as_ptr().add(j));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(va, vb));
    }
    let mut s = hsum4(acc);
    for j in groups * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// AVX2 [`super::scalar::sq_norm`]; bit-identical (lanes are the four
/// scalar accumulators).
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn sq_norm(x: &[f64]) -> f64 {
    let n = x.len();
    let groups = n / 4;
    let mut acc = _mm256_setzero_pd();
    for g in 0..groups {
        let j = g * 4;
        let v = _mm256_loadu_pd(x.as_ptr().add(j));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(v, v));
    }
    let mut s = hsum4(acc);
    for j in groups * 4..n {
        s += x[j] * x[j];
    }
    s
}

/// AVX2 [`super::scalar::axpy`]; element-wise (`y[j] + alpha·x[j]`, one
/// mul + one add per element) so any vector width is bit-identical.
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let groups = n / 4;
    let va = _mm256_set1_pd(alpha);
    for g in 0..groups {
        let j = g * 4;
        let vx = _mm256_loadu_pd(x.as_ptr().add(j));
        let vy = _mm256_loadu_pd(y.as_ptr().add(j));
        let vy = _mm256_add_pd(vy, _mm256_mul_pd(va, vx));
        _mm256_storeu_pd(y.as_mut_ptr().add(j), vy);
    }
    for j in groups * 4..n {
        y[j] += alpha * x[j];
    }
}

/// AVX2 [`super::scalar::dot_idx`]: gathers via `_mm256_set_pd`
/// (high-lane-first arguments put `cols[k]` in lane 0), canonical
/// 4-accumulator order; bit-identical.
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot_idx(row: &[f64], cols: &[usize], w: &[f64]) -> f64 {
    debug_assert_eq!(cols.len(), w.len());
    let n = cols.len();
    let groups = n / 4;
    let mut acc = _mm256_setzero_pd();
    for g in 0..groups {
        let k = g * 4;
        let vr = _mm256_set_pd(row[cols[k + 3]], row[cols[k + 2]], row[cols[k + 1]], row[cols[k]]);
        let vw = _mm256_loadu_pd(w.as_ptr().add(k));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(vr, vw));
    }
    let mut s = hsum4(acc);
    for k in groups * 4..n {
        s += row[cols[k]] * w[k];
    }
    s
}

/// AVX2 [`super::scalar::sparse_dot`]: packed gathers, canonical
/// 4-accumulator order; bit-identical.
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn sparse_dot(rows: &[u32], vals: &[f64], r: &[f64]) -> f64 {
    debug_assert_eq!(rows.len(), vals.len());
    let n = rows.len();
    let groups = n / 4;
    let mut acc = _mm256_setzero_pd();
    for g in 0..groups {
        let k = g * 4;
        let vr = _mm256_set_pd(
            r[rows[k + 3] as usize],
            r[rows[k + 2] as usize],
            r[rows[k + 1] as usize],
            r[rows[k] as usize],
        );
        let vv = _mm256_loadu_pd(vals.as_ptr().add(k));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(vr, vv));
    }
    let mut s = hsum4(acc);
    for k in groups * 4..n {
        s += vals[k] * r[rows[k] as usize];
    }
    s
}

/// AVX2 [`super::scalar::scatter_axpy`]: scalar loop body (the scatter
/// is index-chasing bound) compiled with the AVX2 feature set;
/// trivially bit-identical.
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn scatter_axpy(wk: f64, rows: &[u32], vals: &[f64], out: &mut [f64]) {
    debug_assert_eq!(rows.len(), vals.len());
    let n = rows.len();
    let groups = n / 4;
    for g in 0..groups {
        let k = g * 4;
        out[rows[k] as usize] += wk * vals[k];
        out[rows[k + 1] as usize] += wk * vals[k + 1];
        out[rows[k + 2] as usize] += wk * vals[k + 2];
        out[rows[k + 3] as usize] += wk * vals[k + 3];
    }
    for k in groups * 4..n {
        out[rows[k] as usize] += wk * vals[k];
    }
}

/// AVX2 [`super::scalar::at_r_panel`]: four broadcast row weights, the
/// output index `j` vectorized 4-wide; per element the add tree is
/// `acc[j] + ((r0·x0 + r1·x1) + (r2·x2 + r3·x3))`, exactly the scalar
/// tree, so the panel is bit-identical at any lane width.
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn at_r_panel(rows: &[f64], n: usize, r: &[f64], acc: &mut [f64]) {
    debug_assert_eq!(rows.len(), r.len() * n);
    debug_assert_eq!(acc.len(), n);
    let m = r.len();
    let packs = m / 4;
    let groups = n / 4;
    for p in 0..packs {
        let i = p * 4;
        let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        let (v0, v1, v2, v3) =
            (_mm256_set1_pd(r0), _mm256_set1_pd(r1), _mm256_set1_pd(r2), _mm256_set1_pd(r3));
        for g in 0..groups {
            let j = g * 4;
            let a = _mm256_loadu_pd(acc.as_ptr().add(j));
            let t01 = _mm256_add_pd(
                _mm256_mul_pd(v0, _mm256_loadu_pd(x0.as_ptr().add(j))),
                _mm256_mul_pd(v1, _mm256_loadu_pd(x1.as_ptr().add(j))),
            );
            let t23 = _mm256_add_pd(
                _mm256_mul_pd(v2, _mm256_loadu_pd(x2.as_ptr().add(j))),
                _mm256_mul_pd(v3, _mm256_loadu_pd(x3.as_ptr().add(j))),
            );
            _mm256_storeu_pd(acc.as_mut_ptr().add(j), _mm256_add_pd(a, _mm256_add_pd(t01, t23)));
        }
        for j in groups * 4..n {
            acc[j] += (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
        }
    }
    for i in packs * 4..m {
        let ri = r[i];
        let vri = _mm256_set1_pd(ri);
        let row = &rows[i * n..(i + 1) * n];
        for g in 0..groups {
            let j = g * 4;
            let a = _mm256_loadu_pd(acc.as_ptr().add(j));
            let x = _mm256_loadu_pd(row.as_ptr().add(j));
            _mm256_storeu_pd(acc.as_mut_ptr().add(j), _mm256_add_pd(a, _mm256_mul_pd(vri, x)));
        }
        for j in groups * 4..n {
            acc[j] += ri * row[j];
        }
    }
}

/// AVX2 [`super::scalar::col_sq_norms_panel`]; element-wise over `j`,
/// bit-identical.
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn col_sq_norms_panel(rows: &[f64], n: usize, acc: &mut [f64]) {
    debug_assert_eq!(acc.len(), n);
    if n == 0 {
        return;
    }
    let m = rows.len() / n;
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    let groups = n / 4;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for g in 0..groups {
            let j = g * 4;
            let a = _mm256_loadu_pd(acc.as_ptr().add(j));
            let w0 = _mm256_loadu_pd(x0.as_ptr().add(j));
            let w1 = _mm256_loadu_pd(x1.as_ptr().add(j));
            let w2 = _mm256_loadu_pd(x2.as_ptr().add(j));
            let w3 = _mm256_loadu_pd(x3.as_ptr().add(j));
            let t01 = _mm256_add_pd(_mm256_mul_pd(w0, w0), _mm256_mul_pd(w1, w1));
            let t23 = _mm256_add_pd(_mm256_mul_pd(w2, w2), _mm256_mul_pd(w3, w3));
            _mm256_storeu_pd(acc.as_mut_ptr().add(j), _mm256_add_pd(a, _mm256_add_pd(t01, t23)));
        }
        for j in groups * 4..n {
            acc[j] += (x0[j] * x0[j] + x1[j] * x1[j]) + (x2[j] * x2[j] + x3[j] * x3[j]);
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for g in 0..groups {
            let j = g * 4;
            let a = _mm256_loadu_pd(acc.as_ptr().add(j));
            let x = _mm256_loadu_pd(row.as_ptr().add(j));
            _mm256_storeu_pd(acc.as_mut_ptr().add(j), _mm256_add_pd(a, _mm256_mul_pd(x, x)));
        }
        for j in groups * 4..n {
            acc[j] += row[j] * row[j];
        }
    }
}

/// AVX2 [`super::scalar::gram_panel`]: same row packing, the 4-wide
/// `b` dimension of each 4×4 tile done in one register; per output
/// cell the add tree matches the scalar micro-GEMM, so bit-identical.
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn gram_panel(
    rows: &[f64],
    n: usize,
    ii: &[usize],
    jj: &[usize],
    pi: &mut [f64],
    pj: &mut [f64],
    acc: &mut [f64],
) {
    let na = ii.len();
    let nb = jj.len();
    debug_assert!(pi.len() >= 4 * na && pj.len() >= 4 * nb);
    debug_assert_eq!(acc.len(), na * nb);
    if n == 0 || na == 0 || nb == 0 {
        return;
    }
    let m = rows.len() / n;
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    for p in 0..packs {
        let i = p * 4;
        for k in 0..4 {
            let row = &rows[(i + k) * n..(i + k + 1) * n];
            for (a, &col) in ii.iter().enumerate() {
                pi[k * na + a] = row[col];
            }
            for (b, &col) in jj.iter().enumerate() {
                pj[k * nb + b] = row[col];
            }
        }
        for a0 in (0..na).step_by(4) {
            for b0 in (0..nb).step_by(4) {
                let bw = nb.min(b0 + 4) - b0;
                for a in a0..na.min(a0 + 4) {
                    let v0 = pi[a];
                    let v1 = pi[na + a];
                    let v2 = pi[2 * na + a];
                    let v3 = pi[3 * na + a];
                    if bw == 4 {
                        let p0 = _mm256_loadu_pd(pj.as_ptr().add(b0));
                        let p1 = _mm256_loadu_pd(pj.as_ptr().add(nb + b0));
                        let p2 = _mm256_loadu_pd(pj.as_ptr().add(2 * nb + b0));
                        let p3 = _mm256_loadu_pd(pj.as_ptr().add(3 * nb + b0));
                        let t01 = _mm256_add_pd(
                            _mm256_mul_pd(_mm256_set1_pd(v0), p0),
                            _mm256_mul_pd(_mm256_set1_pd(v1), p1),
                        );
                        let t23 = _mm256_add_pd(
                            _mm256_mul_pd(_mm256_set1_pd(v2), p2),
                            _mm256_mul_pd(_mm256_set1_pd(v3), p3),
                        );
                        let o = acc.as_mut_ptr().add(a * nb + b0);
                        _mm256_storeu_pd(
                            o,
                            _mm256_add_pd(_mm256_loadu_pd(o), _mm256_add_pd(t01, t23)),
                        );
                    } else {
                        for b in b0..b0 + bw {
                            acc[a * nb + b] += (v0 * pj[b] + v1 * pj[nb + b])
                                + (v2 * pj[2 * nb + b] + v3 * pj[3 * nb + b]);
                        }
                    }
                }
            }
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for (b, &col) in jj.iter().enumerate() {
            pj[b] = row[col];
        }
        for (a, &col) in ii.iter().enumerate() {
            let v = row[col];
            let orow = &mut acc[a * nb..(a + 1) * nb];
            for (o, &x) in orow.iter_mut().zip(&pj[..nb]) {
                *o += v * x;
            }
        }
    }
}

/// AVX2 [`super::scalar::cols_dot_panel`]: scalar gather body (the
/// active-set gather dominates) under the AVX2 feature set;
/// bit-identical.
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn cols_dot_panel(
    rows: &[f64],
    n: usize,
    cols: &[usize],
    r: &[f64],
    acc: &mut [f64],
) {
    debug_assert_eq!(rows.len(), r.len() * n);
    debug_assert_eq!(acc.len(), cols.len());
    let m = r.len();
    let packs = m / 4;
    for p in 0..packs {
        let i = p * 4;
        let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for (o, &j) in acc.iter_mut().zip(cols) {
            *o += (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
        }
    }
    for i in packs * 4..m {
        let ri = r[i];
        let row = &rows[i * n..(i + 1) * n];
        for (o, &j) in acc.iter_mut().zip(cols) {
            *o += ri * row[j];
        }
    }
}

/// AVX2 [`super::scalar::fused_step_panel`]: `u` comes from the AVX2
/// [`dot_idx`] (itself bit-identical), the `av` update is the 4-wide
/// element-wise tree of [`at_r_panel`]; bit-identical.
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn fused_step_panel(
    rows: &[f64],
    n: usize,
    cols: &[usize],
    w: &[f64],
    u: &mut [f64],
    av: &mut [f64],
) {
    debug_assert_eq!(cols.len(), w.len());
    debug_assert_eq!(av.len(), n);
    debug_assert_eq!(rows.len(), u.len() * n);
    let m = u.len();
    let packs = m / 4;
    let groups = n / 4;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        let u0 = dot_idx(x0, cols, w);
        let u1 = dot_idx(x1, cols, w);
        let u2 = dot_idx(x2, cols, w);
        let u3 = dot_idx(x3, cols, w);
        u[i] = u0;
        u[i + 1] = u1;
        u[i + 2] = u2;
        u[i + 3] = u3;
        let (v0, v1, v2, v3) =
            (_mm256_set1_pd(u0), _mm256_set1_pd(u1), _mm256_set1_pd(u2), _mm256_set1_pd(u3));
        for g in 0..groups {
            let j = g * 4;
            let a = _mm256_loadu_pd(av.as_ptr().add(j));
            let t01 = _mm256_add_pd(
                _mm256_mul_pd(v0, _mm256_loadu_pd(x0.as_ptr().add(j))),
                _mm256_mul_pd(v1, _mm256_loadu_pd(x1.as_ptr().add(j))),
            );
            let t23 = _mm256_add_pd(
                _mm256_mul_pd(v2, _mm256_loadu_pd(x2.as_ptr().add(j))),
                _mm256_mul_pd(v3, _mm256_loadu_pd(x3.as_ptr().add(j))),
            );
            _mm256_storeu_pd(av.as_mut_ptr().add(j), _mm256_add_pd(a, _mm256_add_pd(t01, t23)));
        }
        for j in groups * 4..n {
            av[j] += (u0 * x0[j] + u1 * x1[j]) + (u2 * x2[j] + u3 * x3[j]);
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        let ui = dot_idx(row, cols, w);
        u[i] = ui;
        let vui = _mm256_set1_pd(ui);
        for g in 0..groups {
            let j = g * 4;
            let a = _mm256_loadu_pd(av.as_ptr().add(j));
            let x = _mm256_loadu_pd(row.as_ptr().add(j));
            _mm256_storeu_pd(av.as_mut_ptr().add(j), _mm256_add_pd(a, _mm256_mul_pd(vui, x)));
        }
        for j in groups * 4..n {
            av[j] += ui * row[j];
        }
    }
}

/// AVX2 [`super::scalar::at_r_multi_panel`]: models inner over shared
/// 4-row packs, `j` vectorized 4-wide; per model bit-identical to
/// [`at_r_panel`].
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn at_r_multi_panel(
    rows: &[f64],
    n: usize,
    rs: &[&[f64]],
    accs: &mut [&mut [f64]],
) {
    debug_assert_eq!(rs.len(), accs.len());
    let Some(first) = rs.first() else { return };
    let m = first.len();
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    let groups = n / 4;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for (r, acc) in rs.iter().zip(accs.iter_mut()) {
            debug_assert_eq!(r.len(), m);
            debug_assert_eq!(acc.len(), n);
            let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
            let (v0, v1, v2, v3) =
                (_mm256_set1_pd(r0), _mm256_set1_pd(r1), _mm256_set1_pd(r2), _mm256_set1_pd(r3));
            for g in 0..groups {
                let j = g * 4;
                let a = _mm256_loadu_pd(acc.as_ptr().add(j));
                let t01 = _mm256_add_pd(
                    _mm256_mul_pd(v0, _mm256_loadu_pd(x0.as_ptr().add(j))),
                    _mm256_mul_pd(v1, _mm256_loadu_pd(x1.as_ptr().add(j))),
                );
                let t23 = _mm256_add_pd(
                    _mm256_mul_pd(v2, _mm256_loadu_pd(x2.as_ptr().add(j))),
                    _mm256_mul_pd(v3, _mm256_loadu_pd(x3.as_ptr().add(j))),
                );
                _mm256_storeu_pd(
                    acc.as_mut_ptr().add(j),
                    _mm256_add_pd(a, _mm256_add_pd(t01, t23)),
                );
            }
            for j in groups * 4..n {
                acc[j] += (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
            }
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for (r, acc) in rs.iter().zip(accs.iter_mut()) {
            let ri = r[i];
            let vri = _mm256_set1_pd(ri);
            for g in 0..groups {
                let j = g * 4;
                let a = _mm256_loadu_pd(acc.as_ptr().add(j));
                let x = _mm256_loadu_pd(row.as_ptr().add(j));
                _mm256_storeu_pd(acc.as_mut_ptr().add(j), _mm256_add_pd(a, _mm256_mul_pd(vri, x)));
            }
            for j in groups * 4..n {
                acc[j] += ri * row[j];
            }
        }
    }
}

/// AVX2 [`super::scalar::fused_step_multi_panel`]: per model
/// bit-identical to [`fused_step_panel`] over the shared row packs.
///
/// SAFETY: caller must ensure AVX2 support (dispatcher-guaranteed).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn fused_step_multi_panel(
    rows: &[f64],
    n: usize,
    cols: &[&[usize]],
    ws: &[&[f64]],
    us: &mut [&mut [f64]],
    avs: &mut [&mut [f64]],
) {
    debug_assert_eq!(cols.len(), ws.len());
    debug_assert_eq!(cols.len(), us.len());
    debug_assert_eq!(cols.len(), avs.len());
    let Some(first) = us.first() else { return };
    let m = first.len();
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    let groups = n / 4;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for k in 0..cols.len() {
            let (ck, wk) = (cols[k], ws[k]);
            debug_assert_eq!(ck.len(), wk.len());
            let u0 = dot_idx(x0, ck, wk);
            let u1 = dot_idx(x1, ck, wk);
            let u2 = dot_idx(x2, ck, wk);
            let u3 = dot_idx(x3, ck, wk);
            let u = &mut us[k];
            u[i] = u0;
            u[i + 1] = u1;
            u[i + 2] = u2;
            u[i + 3] = u3;
            let av = &mut avs[k];
            let (v0, v1, v2, v3) =
                (_mm256_set1_pd(u0), _mm256_set1_pd(u1), _mm256_set1_pd(u2), _mm256_set1_pd(u3));
            for g in 0..groups {
                let j = g * 4;
                let a = _mm256_loadu_pd(av.as_ptr().add(j));
                let t01 = _mm256_add_pd(
                    _mm256_mul_pd(v0, _mm256_loadu_pd(x0.as_ptr().add(j))),
                    _mm256_mul_pd(v1, _mm256_loadu_pd(x1.as_ptr().add(j))),
                );
                let t23 = _mm256_add_pd(
                    _mm256_mul_pd(v2, _mm256_loadu_pd(x2.as_ptr().add(j))),
                    _mm256_mul_pd(v3, _mm256_loadu_pd(x3.as_ptr().add(j))),
                );
                _mm256_storeu_pd(av.as_mut_ptr().add(j), _mm256_add_pd(a, _mm256_add_pd(t01, t23)));
            }
            for j in groups * 4..n {
                av[j] += (u0 * x0[j] + u1 * x1[j]) + (u2 * x2[j] + u3 * x3[j]);
            }
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for k in 0..cols.len() {
            let ui = dot_idx(row, cols[k], ws[k]);
            us[k][i] = ui;
            let av = &mut avs[k];
            let vui = _mm256_set1_pd(ui);
            for g in 0..groups {
                let j = g * 4;
                let a = _mm256_loadu_pd(av.as_ptr().add(j));
                let x = _mm256_loadu_pd(row.as_ptr().add(j));
                _mm256_storeu_pd(av.as_mut_ptr().add(j), _mm256_add_pd(a, _mm256_mul_pd(vui, x)));
            }
            for j in groups * 4..n {
                av[j] += ui * row[j];
            }
        }
    }
}
