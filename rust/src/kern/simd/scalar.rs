//! The portable scalar backend: the register-blocked, 4-accumulator
//! kernel bodies that define the crate's **canonical summation order**
//! (see `kern` module docs). Every vector backend in this directory is
//! specified *against this file*: a vector path is correct iff it
//! performs the same IEEE-754 operations in the same order (bit
//! identity), or is explicitly gated at 1e-9 with its divergence class
//! documented in DESIGN.md §"Kernel engine".
//!
//! These are the exact loop bodies `calars::kern` shipped before the
//! backend split — moving them here changed no instruction.

/// Dot product with four independent accumulators: lane `i` of group
/// `g` feeds accumulator `i`; combine `(s0+s1) + (s2+s3)`; sequential
/// tail.
#[inline]
pub(super) fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let groups = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for g in 0..groups {
        let j = g * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in groups * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Sum of squares, same canonical order as [`dot`].
#[inline]
pub(super) fn sq_norm(x: &[f64]) -> f64 {
    let n = x.len();
    let groups = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for g in 0..groups {
        let j = g * 4;
        s0 += x[j] * x[j];
        s1 += x[j + 1] * x[j + 1];
        s2 += x[j + 2] * x[j + 2];
        s3 += x[j + 3] * x[j + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in groups * 4..n {
        s += x[j] * x[j];
    }
    s
}

/// `y += alpha·x`, unrolled by four (element-wise: identical to the
/// naive loop at any unroll width).
#[inline]
pub(super) fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let groups = n / 4;
    for g in 0..groups {
        let j = g * 4;
        y[j] += alpha * x[j];
        y[j + 1] += alpha * x[j + 1];
        y[j + 2] += alpha * x[j + 2];
        y[j + 3] += alpha * x[j + 3];
    }
    for j in groups * 4..n {
        y[j] += alpha * x[j];
    }
}

/// Gather dot `Σ_k row[cols[k]] · w[k]` with four accumulators.
#[inline]
pub(super) fn dot_idx(row: &[f64], cols: &[usize], w: &[f64]) -> f64 {
    debug_assert_eq!(cols.len(), w.len());
    let n = cols.len();
    let groups = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for g in 0..groups {
        let k = g * 4;
        s0 += row[cols[k]] * w[k];
        s1 += row[cols[k + 1]] * w[k + 1];
        s2 += row[cols[k + 2]] * w[k + 2];
        s3 += row[cols[k + 3]] * w[k + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for k in groups * 4..n {
        s += row[cols[k]] * w[k];
    }
    s
}

/// Sparse gather dot `Σ_k vals[k] · r[rows[k]]` with four accumulators.
#[inline]
pub(super) fn sparse_dot(rows: &[u32], vals: &[f64], r: &[f64]) -> f64 {
    debug_assert_eq!(rows.len(), vals.len());
    let n = rows.len();
    let groups = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for g in 0..groups {
        let k = g * 4;
        s0 += vals[k] * r[rows[k] as usize];
        s1 += vals[k + 1] * r[rows[k + 1] as usize];
        s2 += vals[k + 2] * r[rows[k + 2] as usize];
        s3 += vals[k + 3] * r[rows[k + 3] as usize];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for k in groups * 4..n {
        s += vals[k] * r[rows[k] as usize];
    }
    s
}

/// Sparse scatter `out[rows[k]] += wk · vals[k]`, unrolled by four
/// (distinct row indices per CSC column ⇒ equals the naive loop).
#[inline]
pub(super) fn scatter_axpy(wk: f64, rows: &[u32], vals: &[f64], out: &mut [f64]) {
    debug_assert_eq!(rows.len(), vals.len());
    let n = rows.len();
    let groups = n / 4;
    for g in 0..groups {
        let k = g * 4;
        out[rows[k] as usize] += wk * vals[k];
        out[rows[k + 1] as usize] += wk * vals[k + 1];
        out[rows[k + 2] as usize] += wk * vals[k + 2];
        out[rows[k + 3] as usize] += wk * vals[k + 3];
    }
    for k in groups * 4..n {
        out[rows[k] as usize] += wk * vals[k];
    }
}

/// `acc[j] += Σ_i r[i]·rows_i[j]` over a row-major panel: four rows per
/// pack, pairwise pre-reduction per output element, one-row tail.
pub(super) fn at_r_panel(rows: &[f64], n: usize, r: &[f64], acc: &mut [f64]) {
    debug_assert_eq!(rows.len(), r.len() * n);
    debug_assert_eq!(acc.len(), n);
    let m = r.len();
    let packs = m / 4;
    for p in 0..packs {
        let i = p * 4;
        let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for j in 0..n {
            acc[j] += (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
        }
    }
    for i in packs * 4..m {
        let ri = r[i];
        let row = &rows[i * n..(i + 1) * n];
        for j in 0..n {
            acc[j] += ri * row[j];
        }
    }
}

/// `acc[j] += Σ_i rows_i[j]²`, four rows fused per pass.
pub(super) fn col_sq_norms_panel(rows: &[f64], n: usize, acc: &mut [f64]) {
    debug_assert_eq!(acc.len(), n);
    if n == 0 {
        return;
    }
    let m = rows.len() / n;
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for j in 0..n {
            acc[j] += (x0[j] * x0[j] + x1[j] * x1[j]) + (x2[j] * x2[j] + x3[j] * x3[j]);
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for j in 0..n {
            acc[j] += row[j] * row[j];
        }
    }
}

/// Gram panel `acc[a·nb + b] += Σ_i rows_i[ii[a]] · rows_i[jj[b]]` as a
/// packed 4×4 micro-GEMM (`pi`/`pj` caller scratch, ≥ 4·|ii| / 4·|jj|).
pub(super) fn gram_panel(
    rows: &[f64],
    n: usize,
    ii: &[usize],
    jj: &[usize],
    pi: &mut [f64],
    pj: &mut [f64],
    acc: &mut [f64],
) {
    let na = ii.len();
    let nb = jj.len();
    debug_assert!(pi.len() >= 4 * na && pj.len() >= 4 * nb);
    debug_assert_eq!(acc.len(), na * nb);
    if n == 0 || na == 0 || nb == 0 {
        return;
    }
    let m = rows.len() / n;
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    for p in 0..packs {
        let i = p * 4;
        for k in 0..4 {
            let row = &rows[(i + k) * n..(i + k + 1) * n];
            for (a, &col) in ii.iter().enumerate() {
                pi[k * na + a] = row[col];
            }
            for (b, &col) in jj.iter().enumerate() {
                pj[k * nb + b] = row[col];
            }
        }
        for a0 in (0..na).step_by(4) {
            for b0 in (0..nb).step_by(4) {
                for a in a0..na.min(a0 + 4) {
                    let v0 = pi[a];
                    let v1 = pi[na + a];
                    let v2 = pi[2 * na + a];
                    let v3 = pi[3 * na + a];
                    for b in b0..nb.min(b0 + 4) {
                        acc[a * nb + b] += (v0 * pj[b] + v1 * pj[nb + b])
                            + (v2 * pj[2 * nb + b] + v3 * pj[3 * nb + b]);
                    }
                }
            }
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for (b, &col) in jj.iter().enumerate() {
            pj[b] = row[col];
        }
        for (a, &col) in ii.iter().enumerate() {
            let v = row[col];
            let orow = &mut acc[a * nb..(a + 1) * nb];
            for (o, &x) in orow.iter_mut().zip(&pj[..nb]) {
                *o += v * x;
            }
        }
    }
}

/// `acc[k] += Σ_i r[i]·rows_i[cols[k]]`, four rows fused per pass.
pub(super) fn cols_dot_panel(rows: &[f64], n: usize, cols: &[usize], r: &[f64], acc: &mut [f64]) {
    debug_assert_eq!(rows.len(), r.len() * n);
    debug_assert_eq!(acc.len(), cols.len());
    let m = r.len();
    let packs = m / 4;
    for p in 0..packs {
        let i = p * 4;
        let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for (o, &j) in acc.iter_mut().zip(cols) {
            *o += (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
        }
    }
    for i in packs * 4..m {
        let ri = r[i];
        let row = &rows[i * n..(i + 1) * n];
        for (o, &j) in acc.iter_mut().zip(cols) {
            *o += ri * row[j];
        }
    }
}

/// Fused equiangular step: `u = A[:, cols]·w` ([`dot_idx`] per row) and
/// `av += Aᵀu`, one pass, four rows per pack.
pub(super) fn fused_step_panel(
    rows: &[f64],
    n: usize,
    cols: &[usize],
    w: &[f64],
    u: &mut [f64],
    av: &mut [f64],
) {
    debug_assert_eq!(cols.len(), w.len());
    debug_assert_eq!(av.len(), n);
    debug_assert_eq!(rows.len(), u.len() * n);
    let m = u.len();
    let packs = m / 4;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        let u0 = dot_idx(x0, cols, w);
        let u1 = dot_idx(x1, cols, w);
        let u2 = dot_idx(x2, cols, w);
        let u3 = dot_idx(x3, cols, w);
        u[i] = u0;
        u[i + 1] = u1;
        u[i + 2] = u2;
        u[i + 3] = u3;
        for j in 0..n {
            av[j] += (u0 * x0[j] + u1 * x1[j]) + (u2 * x2[j] + u3 * x3[j]);
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        let ui = dot_idx(row, cols, w);
        u[i] = ui;
        for j in 0..n {
            av[j] += ui * row[j];
        }
    }
}

/// Multi-response `Aᵀ R`: models are the inner loop over the same
/// four-row packs, so per-model results are bit-identical to `k`
/// separate [`at_r_panel`] calls.
pub(super) fn at_r_multi_panel(rows: &[f64], n: usize, rs: &[&[f64]], accs: &mut [&mut [f64]]) {
    debug_assert_eq!(rs.len(), accs.len());
    let Some(first) = rs.first() else { return };
    let m = first.len();
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for (r, acc) in rs.iter().zip(accs.iter_mut()) {
            debug_assert_eq!(r.len(), m);
            debug_assert_eq!(acc.len(), n);
            let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
            for j in 0..n {
                acc[j] += (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
            }
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for (r, acc) in rs.iter().zip(accs.iter_mut()) {
            let ri = r[i];
            for j in 0..n {
                acc[j] += ri * row[j];
            }
        }
    }
}

/// Multi-response fused equiangular step: per-model bit-identical to
/// `k` separate [`fused_step_panel`] calls.
pub(super) fn fused_step_multi_panel(
    rows: &[f64],
    n: usize,
    cols: &[&[usize]],
    ws: &[&[f64]],
    us: &mut [&mut [f64]],
    avs: &mut [&mut [f64]],
) {
    debug_assert_eq!(cols.len(), ws.len());
    debug_assert_eq!(cols.len(), us.len());
    debug_assert_eq!(cols.len(), avs.len());
    let Some(first) = us.first() else { return };
    let m = first.len();
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for k in 0..cols.len() {
            let (ck, wk) = (cols[k], ws[k]);
            debug_assert_eq!(ck.len(), wk.len());
            let u0 = dot_idx(x0, ck, wk);
            let u1 = dot_idx(x1, ck, wk);
            let u2 = dot_idx(x2, ck, wk);
            let u3 = dot_idx(x3, ck, wk);
            let u = &mut us[k];
            u[i] = u0;
            u[i + 1] = u1;
            u[i + 2] = u2;
            u[i + 3] = u3;
            let av = &mut avs[k];
            for j in 0..n {
                av[j] += (u0 * x0[j] + u1 * x1[j]) + (u2 * x2[j] + u3 * x3[j]);
            }
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for k in 0..cols.len() {
            let ui = dot_idx(row, cols[k], ws[k]);
            us[k][i] = ui;
            let av = &mut avs[k];
            for j in 0..n {
                av[j] += ui * row[j];
            }
        }
    }
}
