//! NEON backend (aarch64): 2 × f64 per register, so a *pair* of
//! registers emulates the four scalar accumulators — `acc01` holds
//! (s0, s1) and `acc23` holds (s2, s3), and the reduce recombines the
//! lanes in the canonical `(s0+s1) + (s2+s3)` order. Every kernel in
//! this file is **bit-identical** to [`super::scalar`]; there is no
//! gated divergence on NEON.
//!
//! The gather/scatter kernels and the gram micro-GEMM reuse the scalar
//! bodies inside `#[target_feature]` fns — they are index-chasing
//! bound, and on aarch64 NEON is baseline so the compiler already
//! vectorizes what it can. No FMA anywhere: one rounding per multiply,
//! one per add.

use core::arch::aarch64::*;

/// NEON dot: register pair (s0,s1)/(s2,s3), canonical merge
/// `(s0+s1) + (s2+s3)`; bit-identical.
///
/// SAFETY: the caller must ensure the CPU supports NEON — the
/// dispatcher guarantees this via runtime feature detection.
#[target_feature(enable = "neon")]
pub(super) unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let groups = n / 4;
    let mut acc01 = vdupq_n_f64(0.0);
    let mut acc23 = vdupq_n_f64(0.0);
    for g in 0..groups {
        let j = g * 4;
        let a01 = vld1q_f64(a.as_ptr().add(j));
        let b01 = vld1q_f64(b.as_ptr().add(j));
        acc01 = vaddq_f64(acc01, vmulq_f64(a01, b01));
        let a23 = vld1q_f64(a.as_ptr().add(j + 2));
        let b23 = vld1q_f64(b.as_ptr().add(j + 2));
        acc23 = vaddq_f64(acc23, vmulq_f64(a23, b23));
    }
    let mut s = (vgetq_lane_f64::<0>(acc01) + vgetq_lane_f64::<1>(acc01))
        + (vgetq_lane_f64::<0>(acc23) + vgetq_lane_f64::<1>(acc23));
    for j in groups * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// NEON sum of squares, same register-pair scheme; bit-identical.
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn sq_norm(x: &[f64]) -> f64 {
    let n = x.len();
    let groups = n / 4;
    let mut acc01 = vdupq_n_f64(0.0);
    let mut acc23 = vdupq_n_f64(0.0);
    for g in 0..groups {
        let j = g * 4;
        let v01 = vld1q_f64(x.as_ptr().add(j));
        acc01 = vaddq_f64(acc01, vmulq_f64(v01, v01));
        let v23 = vld1q_f64(x.as_ptr().add(j + 2));
        acc23 = vaddq_f64(acc23, vmulq_f64(v23, v23));
    }
    let mut s = (vgetq_lane_f64::<0>(acc01) + vgetq_lane_f64::<1>(acc01))
        + (vgetq_lane_f64::<0>(acc23) + vgetq_lane_f64::<1>(acc23));
    for j in groups * 4..n {
        s += x[j] * x[j];
    }
    s
}

/// NEON axpy, 2-wide; element-wise so bit-identical.
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let groups = n / 2;
    let va = vdupq_n_f64(alpha);
    for g in 0..groups {
        let j = g * 2;
        let vx = vld1q_f64(x.as_ptr().add(j));
        let vy = vld1q_f64(y.as_ptr().add(j));
        vst1q_f64(y.as_mut_ptr().add(j), vaddq_f64(vy, vmulq_f64(va, vx)));
    }
    for j in groups * 2..n {
        y[j] += alpha * x[j];
    }
}

/// Scalar gather body (canonical 4-accumulator order) under the NEON
/// feature set; bit-identical.
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn dot_idx(row: &[f64], cols: &[usize], w: &[f64]) -> f64 {
    super::scalar::dot_idx(row, cols, w)
}

/// Scalar sparse gather body under the NEON feature set; bit-identical.
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn sparse_dot(rows: &[u32], vals: &[f64], r: &[f64]) -> f64 {
    super::scalar::sparse_dot(rows, vals, r)
}

/// Scalar scatter body under the NEON feature set; bit-identical.
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn scatter_axpy(wk: f64, rows: &[u32], vals: &[f64], out: &mut [f64]) {
    super::scalar::scatter_axpy(wk, rows, vals, out)
}

/// NEON `Aᵀr` panel: four broadcast row weights, output index `j`
/// vectorized 2-wide; per element the scalar add tree, bit-identical.
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn at_r_panel(rows: &[f64], n: usize, r: &[f64], acc: &mut [f64]) {
    debug_assert_eq!(rows.len(), r.len() * n);
    debug_assert_eq!(acc.len(), n);
    let m = r.len();
    let packs = m / 4;
    let groups = n / 2;
    for p in 0..packs {
        let i = p * 4;
        let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        let (v0, v1, v2, v3) =
            (vdupq_n_f64(r0), vdupq_n_f64(r1), vdupq_n_f64(r2), vdupq_n_f64(r3));
        for g in 0..groups {
            let j = g * 2;
            let a = vld1q_f64(acc.as_ptr().add(j));
            let t01 = vaddq_f64(
                vmulq_f64(v0, vld1q_f64(x0.as_ptr().add(j))),
                vmulq_f64(v1, vld1q_f64(x1.as_ptr().add(j))),
            );
            let t23 = vaddq_f64(
                vmulq_f64(v2, vld1q_f64(x2.as_ptr().add(j))),
                vmulq_f64(v3, vld1q_f64(x3.as_ptr().add(j))),
            );
            vst1q_f64(acc.as_mut_ptr().add(j), vaddq_f64(a, vaddq_f64(t01, t23)));
        }
        for j in groups * 2..n {
            acc[j] += (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
        }
    }
    for i in packs * 4..m {
        let ri = r[i];
        let vri = vdupq_n_f64(ri);
        let row = &rows[i * n..(i + 1) * n];
        for g in 0..groups {
            let j = g * 2;
            let a = vld1q_f64(acc.as_ptr().add(j));
            let x = vld1q_f64(row.as_ptr().add(j));
            vst1q_f64(acc.as_mut_ptr().add(j), vaddq_f64(a, vmulq_f64(vri, x)));
        }
        for j in groups * 2..n {
            acc[j] += ri * row[j];
        }
    }
}

/// NEON column square norms, 2-wide over `j`; bit-identical.
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn col_sq_norms_panel(rows: &[f64], n: usize, acc: &mut [f64]) {
    debug_assert_eq!(acc.len(), n);
    if n == 0 {
        return;
    }
    let m = rows.len() / n;
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    let groups = n / 2;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for g in 0..groups {
            let j = g * 2;
            let a = vld1q_f64(acc.as_ptr().add(j));
            let w0 = vld1q_f64(x0.as_ptr().add(j));
            let w1 = vld1q_f64(x1.as_ptr().add(j));
            let w2 = vld1q_f64(x2.as_ptr().add(j));
            let w3 = vld1q_f64(x3.as_ptr().add(j));
            let t01 = vaddq_f64(vmulq_f64(w0, w0), vmulq_f64(w1, w1));
            let t23 = vaddq_f64(vmulq_f64(w2, w2), vmulq_f64(w3, w3));
            vst1q_f64(acc.as_mut_ptr().add(j), vaddq_f64(a, vaddq_f64(t01, t23)));
        }
        for j in groups * 2..n {
            acc[j] += (x0[j] * x0[j] + x1[j] * x1[j]) + (x2[j] * x2[j] + x3[j] * x3[j]);
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for g in 0..groups {
            let j = g * 2;
            let a = vld1q_f64(acc.as_ptr().add(j));
            let x = vld1q_f64(row.as_ptr().add(j));
            vst1q_f64(acc.as_mut_ptr().add(j), vaddq_f64(a, vmulq_f64(x, x)));
        }
        for j in groups * 2..n {
            acc[j] += row[j] * row[j];
        }
    }
}

/// Scalar packed micro-GEMM body under the NEON feature set;
/// bit-identical.
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn gram_panel(
    rows: &[f64],
    n: usize,
    ii: &[usize],
    jj: &[usize],
    pi: &mut [f64],
    pj: &mut [f64],
    acc: &mut [f64],
) {
    super::scalar::gram_panel(rows, n, ii, jj, pi, pj, acc)
}

/// Scalar active-set gather body under the NEON feature set;
/// bit-identical.
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn cols_dot_panel(
    rows: &[f64],
    n: usize,
    cols: &[usize],
    r: &[f64],
    acc: &mut [f64],
) {
    super::scalar::cols_dot_panel(rows, n, cols, r, acc)
}

/// NEON fused equiangular step: `u` from the canonical scalar
/// [`super::scalar::dot_idx`], the `av` update 2-wide element-wise;
/// bit-identical.
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn fused_step_panel(
    rows: &[f64],
    n: usize,
    cols: &[usize],
    w: &[f64],
    u: &mut [f64],
    av: &mut [f64],
) {
    debug_assert_eq!(cols.len(), w.len());
    debug_assert_eq!(av.len(), n);
    debug_assert_eq!(rows.len(), u.len() * n);
    let m = u.len();
    let packs = m / 4;
    let groups = n / 2;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        let u0 = super::scalar::dot_idx(x0, cols, w);
        let u1 = super::scalar::dot_idx(x1, cols, w);
        let u2 = super::scalar::dot_idx(x2, cols, w);
        let u3 = super::scalar::dot_idx(x3, cols, w);
        u[i] = u0;
        u[i + 1] = u1;
        u[i + 2] = u2;
        u[i + 3] = u3;
        let (v0, v1, v2, v3) =
            (vdupq_n_f64(u0), vdupq_n_f64(u1), vdupq_n_f64(u2), vdupq_n_f64(u3));
        for g in 0..groups {
            let j = g * 2;
            let a = vld1q_f64(av.as_ptr().add(j));
            let t01 = vaddq_f64(
                vmulq_f64(v0, vld1q_f64(x0.as_ptr().add(j))),
                vmulq_f64(v1, vld1q_f64(x1.as_ptr().add(j))),
            );
            let t23 = vaddq_f64(
                vmulq_f64(v2, vld1q_f64(x2.as_ptr().add(j))),
                vmulq_f64(v3, vld1q_f64(x3.as_ptr().add(j))),
            );
            vst1q_f64(av.as_mut_ptr().add(j), vaddq_f64(a, vaddq_f64(t01, t23)));
        }
        for j in groups * 2..n {
            av[j] += (u0 * x0[j] + u1 * x1[j]) + (u2 * x2[j] + u3 * x3[j]);
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        let ui = super::scalar::dot_idx(row, cols, w);
        u[i] = ui;
        let vui = vdupq_n_f64(ui);
        for g in 0..groups {
            let j = g * 2;
            let a = vld1q_f64(av.as_ptr().add(j));
            let x = vld1q_f64(row.as_ptr().add(j));
            vst1q_f64(av.as_mut_ptr().add(j), vaddq_f64(a, vmulq_f64(vui, x)));
        }
        for j in groups * 2..n {
            av[j] += ui * row[j];
        }
    }
}

/// NEON multi-response `Aᵀ R`, 2-wide over `j`; per model
/// bit-identical to [`at_r_panel`].
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn at_r_multi_panel(
    rows: &[f64],
    n: usize,
    rs: &[&[f64]],
    accs: &mut [&mut [f64]],
) {
    debug_assert_eq!(rs.len(), accs.len());
    let Some(first) = rs.first() else { return };
    let m = first.len();
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    let groups = n / 2;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for (r, acc) in rs.iter().zip(accs.iter_mut()) {
            debug_assert_eq!(r.len(), m);
            debug_assert_eq!(acc.len(), n);
            let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
            let (v0, v1, v2, v3) =
                (vdupq_n_f64(r0), vdupq_n_f64(r1), vdupq_n_f64(r2), vdupq_n_f64(r3));
            for g in 0..groups {
                let j = g * 2;
                let a = vld1q_f64(acc.as_ptr().add(j));
                let t01 = vaddq_f64(
                    vmulq_f64(v0, vld1q_f64(x0.as_ptr().add(j))),
                    vmulq_f64(v1, vld1q_f64(x1.as_ptr().add(j))),
                );
                let t23 = vaddq_f64(
                    vmulq_f64(v2, vld1q_f64(x2.as_ptr().add(j))),
                    vmulq_f64(v3, vld1q_f64(x3.as_ptr().add(j))),
                );
                vst1q_f64(acc.as_mut_ptr().add(j), vaddq_f64(a, vaddq_f64(t01, t23)));
            }
            for j in groups * 2..n {
                acc[j] += (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
            }
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for (r, acc) in rs.iter().zip(accs.iter_mut()) {
            let ri = r[i];
            let vri = vdupq_n_f64(ri);
            for g in 0..groups {
                let j = g * 2;
                let a = vld1q_f64(acc.as_ptr().add(j));
                let x = vld1q_f64(row.as_ptr().add(j));
                vst1q_f64(acc.as_mut_ptr().add(j), vaddq_f64(a, vmulq_f64(vri, x)));
            }
            for j in groups * 2..n {
                acc[j] += ri * row[j];
            }
        }
    }
}

/// NEON multi-response fused step: per model bit-identical to
/// [`fused_step_panel`].
///
/// SAFETY: caller must ensure NEON support (dispatcher-guaranteed).
#[target_feature(enable = "neon")]
pub(super) unsafe fn fused_step_multi_panel(
    rows: &[f64],
    n: usize,
    cols: &[&[usize]],
    ws: &[&[f64]],
    us: &mut [&mut [f64]],
    avs: &mut [&mut [f64]],
) {
    debug_assert_eq!(cols.len(), ws.len());
    debug_assert_eq!(cols.len(), us.len());
    debug_assert_eq!(cols.len(), avs.len());
    let Some(first) = us.first() else { return };
    let m = first.len();
    debug_assert_eq!(rows.len(), m * n);
    let packs = m / 4;
    let groups = n / 2;
    for p in 0..packs {
        let i = p * 4;
        let x0 = &rows[i * n..(i + 1) * n];
        let x1 = &rows[(i + 1) * n..(i + 2) * n];
        let x2 = &rows[(i + 2) * n..(i + 3) * n];
        let x3 = &rows[(i + 3) * n..(i + 4) * n];
        for k in 0..cols.len() {
            let (ck, wk) = (cols[k], ws[k]);
            debug_assert_eq!(ck.len(), wk.len());
            let u0 = super::scalar::dot_idx(x0, ck, wk);
            let u1 = super::scalar::dot_idx(x1, ck, wk);
            let u2 = super::scalar::dot_idx(x2, ck, wk);
            let u3 = super::scalar::dot_idx(x3, ck, wk);
            let u = &mut us[k];
            u[i] = u0;
            u[i + 1] = u1;
            u[i + 2] = u2;
            u[i + 3] = u3;
            let av = &mut avs[k];
            let (v0, v1, v2, v3) =
                (vdupq_n_f64(u0), vdupq_n_f64(u1), vdupq_n_f64(u2), vdupq_n_f64(u3));
            for g in 0..groups {
                let j = g * 2;
                let a = vld1q_f64(av.as_ptr().add(j));
                let t01 = vaddq_f64(
                    vmulq_f64(v0, vld1q_f64(x0.as_ptr().add(j))),
                    vmulq_f64(v1, vld1q_f64(x1.as_ptr().add(j))),
                );
                let t23 = vaddq_f64(
                    vmulq_f64(v2, vld1q_f64(x2.as_ptr().add(j))),
                    vmulq_f64(v3, vld1q_f64(x3.as_ptr().add(j))),
                );
                vst1q_f64(av.as_mut_ptr().add(j), vaddq_f64(a, vaddq_f64(t01, t23)));
            }
            for j in groups * 2..n {
                av[j] += (u0 * x0[j] + u1 * x1[j]) + (u2 * x2[j] + u3 * x3[j]);
            }
        }
    }
    for i in packs * 4..m {
        let row = &rows[i * n..(i + 1) * n];
        for k in 0..cols.len() {
            let ui = super::scalar::dot_idx(row, cols[k], ws[k]);
            us[k][i] = ui;
            let av = &mut avs[k];
            let vui = vdupq_n_f64(ui);
            for g in 0..groups {
                let j = g * 2;
                let a = vld1q_f64(av.as_ptr().add(j));
                let x = vld1q_f64(row.as_ptr().add(j));
                vst1q_f64(av.as_mut_ptr().add(j), vaddq_f64(a, vmulq_f64(vui, x)));
            }
            for j in groups * 2..n {
                av[j] += ui * row[j];
            }
        }
    }
}
