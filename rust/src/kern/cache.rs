//! Cross-fit Gram/norm panel cache.
//!
//! LARS-family fits recompute the same small Gram panels
//! (`A_Iᵀ A_B`, at most `t × b`) on every warm-started refit of a
//! model family: the selection prefix is identical, so the panel keys
//! — the ordered `(ii, jj)` column-index pairs — repeat exactly, while
//! each panel costs a full stream over `A` to materialize. The
//! communication-avoiding block-coordinate analysis of Devarakonda et
//! al. (arXiv:1612.04003) identifies exactly this reuse as where the
//! constant factors live.
//!
//! [`PanelStore`] memoizes those panels (plus the dataset's column
//! norms) per dataset, LRU-bounded by payload bytes. The serving layer
//! owns one store per dataset (`calars::serve::GramCache`) and binds
//! it around a fit with [`with_store`]; `Matrix::gram_block` consults
//! the binding through [`bound_for`], which only matches when the
//! matrix shape equals the shape the store was built for — so bLARS
//! row shards (different `m`) and T-bLARS threaded leaves (pool worker
//! threads carry no binding) silently bypass the cache instead of
//! poisoning it.
//!
//! Correctness note: a store caches *values*, so it must only ever be
//! bound around matrices with identical contents. The serving layer
//! guarantees that by keying stores on the dataset name and
//! invalidating when the dataset fingerprint changes (re-upload with
//! different contents).

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Minimal recency queue shared by the crate's caches ([`PanelStore`]
/// here, `calars::serve::GramCache`): front = least recently used.
/// One place for the touch/evict idiom instead of a hand-rolled copy
/// per cache.
pub(crate) struct LruQueue<K: PartialEq>(Vec<K>);

impl<K: PartialEq> Default for LruQueue<K> {
    fn default() -> Self {
        LruQueue(Vec::new())
    }
}

impl<K: PartialEq> LruQueue<K> {
    pub(crate) fn new() -> Self {
        Self::default()
    }

    /// Mark `key` most-recently-used, inserting it if absent.
    pub(crate) fn touch_or_push(&mut self, key: K) {
        if let Some(pos) = self.0.iter().position(|k| *k == key) {
            self.0.remove(pos);
        }
        self.0.push(key);
    }

    /// Drop the entry matching `pred`, if any.
    pub(crate) fn remove_by(&mut self, pred: impl Fn(&K) -> bool) {
        if let Some(pos) = self.0.iter().position(|k| pred(k)) {
            self.0.remove(pos);
        }
    }

    /// Pop the least-recently-used key.
    pub(crate) fn pop_lru(&mut self) -> Option<K> {
        if self.0.is_empty() {
            None
        } else {
            Some(self.0.remove(0))
        }
    }
}

/// Counter snapshot (`/stats` → `gram_cache`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PanelCounters {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Cached panels currently held.
    pub panels: usize,
    /// Approximate payload bytes currently held.
    pub bytes: usize,
}

type PanelKey = (Vec<usize>, Vec<usize>);

struct StoreInner {
    panels: HashMap<PanelKey, Arc<Vec<f64>>>,
    lru: LruQueue<PanelKey>,
    bytes: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
    norms: Option<Arc<Vec<f64>>>,
}

/// Thread-safe per-dataset panel + norm store, LRU-bounded by bytes.
pub struct PanelStore {
    /// `(nrows, ncols)` of the matrix the cached values belong to.
    shape: (usize, usize),
    /// Kernel ISA backend active when the store was created. Every
    /// panel-producing kernel reduces in the canonical scalar order on
    /// every backend (see `kern::simd`), so cached panels are in fact
    /// backend-independent — this guard is defensive: should a future
    /// backend ever trade that invariant away, a store filled under it
    /// silently stops matching rather than serving foreign roundings.
    backend: crate::kern::simd::KernBackend,
    max_bytes: usize,
    inner: Mutex<StoreInner>,
}

impl PanelStore {
    /// Store for a matrix of `shape`, holding at most `max_bytes` of
    /// panel payload. Captures the calling thread's kernel backend.
    pub fn new(shape: (usize, usize), max_bytes: usize) -> Self {
        PanelStore {
            shape,
            backend: crate::kern::simd::current(),
            max_bytes,
            inner: Mutex::new(StoreInner {
                panels: HashMap::new(),
                lru: LruQueue::new(),
                bytes: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                norms: None,
            }),
        }
    }

    /// The matrix shape this store was built for.
    pub fn shape(&self) -> (usize, usize) {
        self.shape
    }

    /// The kernel backend this store was built under.
    pub fn backend(&self) -> crate::kern::simd::KernBackend {
        self.backend
    }

    /// Cached panel for `(ii, jj)`, marking it most-recently-used.
    /// Counts a hit or a miss.
    pub fn lookup(&self, ii: &[usize], jj: &[usize]) -> Option<Arc<Vec<f64>>> {
        let mut g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let key = (ii.to_vec(), jj.to_vec());
        match g.panels.get(&key).cloned() {
            Some(panel) => {
                g.lru.touch_or_push(key);
                g.hits += 1;
                crate::obs::instant("gram_panel_hit");
                Some(panel)
            }
            None => {
                g.misses += 1;
                crate::obs::instant("gram_panel_miss");
                None
            }
        }
    }

    /// Insert a freshly materialized panel, evicting least-recently-
    /// used panels while over the byte bound. A panel larger than the
    /// whole bound is not cached at all.
    pub fn insert(&self, ii: &[usize], jj: &[usize], panel: Arc<Vec<f64>>) {
        let add = panel.len() * std::mem::size_of::<f64>();
        if add > self.max_bytes {
            return;
        }
        let mut g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let key = (ii.to_vec(), jj.to_vec());
        if let Some(old) = g.panels.insert(key.clone(), panel) {
            // Same key re-inserted (two workers raced): keep byte
            // accounting exact; touch_or_push refreshes recency.
            g.bytes -= old.len() * std::mem::size_of::<f64>();
        }
        g.bytes += add;
        g.lru.touch_or_push(key);
        while g.bytes > self.max_bytes {
            let Some(victim) = g.lru.pop_lru() else { break };
            if let Some(old) = g.panels.remove(&victim) {
                g.bytes -= old.len() * std::mem::size_of::<f64>();
                g.evictions += 1;
            }
        }
    }

    /// Column norms recorded for this dataset (set once at
    /// registration from the normalization pass).
    pub fn norms(&self) -> Option<Arc<Vec<f64>>> {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner).norms.clone()
    }

    /// Record the dataset's column norms (idempotent).
    pub fn set_norms(&self, norms: Arc<Vec<f64>>) {
        let mut g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if g.norms.is_none() {
            g.norms = Some(norms);
        }
    }

    /// Counter snapshot.
    pub fn counters(&self) -> PanelCounters {
        let g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        PanelCounters {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            panels: g.panels.len(),
            bytes: g.bytes,
        }
    }
}

thread_local! {
    /// Ambient store installed by [`with_store`] for the duration of a
    /// fit on the calling thread.
    static BOUND: RefCell<Option<Arc<PanelStore>>> = const { RefCell::new(None) };
}

/// Run `f` with `store` bound as the calling thread's panel cache.
/// `Matrix::gram_block` calls made by `f` *on this thread* consult it;
/// kernels forked onto pool workers do not (their chunks are fractions
/// of one panel anyway). Nested bindings restore the previous store on
/// exit, including unwinds.
pub fn with_store<R>(store: &Arc<PanelStore>, f: impl FnOnce() -> R) -> R {
    struct Reset(Option<Arc<PanelStore>>);
    impl Drop for Reset {
        fn drop(&mut self) {
            BOUND.with(|b| *b.borrow_mut() = self.0.take());
        }
    }
    let prev = BOUND.with(|b| b.borrow_mut().replace(Arc::clone(store)));
    let _reset = Reset(prev);
    f()
}

/// The bound store, if any, **and only if** its recorded shape matches
/// `shape` — the guard that keeps shard-local Gram products (bLARS row
/// slices) from colliding with full-matrix panels under one binding —
/// and its recorded kernel backend matches the calling thread's (a
/// defensive no-op today; see the `backend` field).
pub fn bound_for(shape: (usize, usize)) -> Option<Arc<PanelStore>> {
    BOUND.with(|b| {
        b.borrow()
            .as_ref()
            .filter(|s| s.shape() == shape && s.backend() == crate::kern::simd::current())
            .cloned()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_insert_roundtrip_counts() {
        let store = PanelStore::new((10, 4), 1 << 20);
        assert!(store.lookup(&[0, 1], &[2]).is_none());
        store.insert(&[0, 1], &[2], Arc::new(vec![1.0, 2.0]));
        let back = store.lookup(&[0, 1], &[2]).expect("cached");
        assert_eq!(back.as_slice(), &[1.0, 2.0]);
        // Key is the ordered pair: different jj misses.
        assert!(store.lookup(&[0, 1], &[3]).is_none());
        let c = store.counters();
        assert_eq!((c.hits, c.misses, c.panels), (1, 2, 1));
        assert_eq!(c.bytes, 16);
    }

    #[test]
    fn byte_bound_evicts_lru() {
        // Bound fits two 2-value panels; the third insert evicts the
        // least recently used.
        let store = PanelStore::new((8, 8), 32);
        store.insert(&[0], &[0, 1], Arc::new(vec![1.0, 2.0]));
        store.insert(&[1], &[0, 1], Arc::new(vec![3.0, 4.0]));
        store.lookup(&[0], &[0, 1]); // touch: [0] now more recent than [1]
        store.insert(&[2], &[0, 1], Arc::new(vec![5.0, 6.0]));
        assert!(store.lookup(&[1], &[0, 1]).is_none(), "LRU panel evicted");
        assert!(store.lookup(&[0], &[0, 1]).is_some());
        assert!(store.lookup(&[2], &[0, 1]).is_some());
        assert_eq!(store.counters().evictions, 1);
        // An oversized panel is skipped entirely.
        store.insert(&[3], &[0, 1, 2, 3, 4], Arc::new(vec![0.0; 64]));
        assert!(store.lookup(&[3], &[0, 1, 2, 3, 4]).is_none());
    }

    #[test]
    fn binding_scopes_and_shape_guards() {
        let store = Arc::new(PanelStore::new((100, 20), 1 << 20));
        assert!(bound_for((100, 20)).is_none(), "no ambient store outside with_store");
        with_store(&store, || {
            assert!(bound_for((100, 20)).is_some());
            assert!(bound_for((50, 20)).is_none(), "shard shapes must not match");
            // Nested binding wins, then restores.
            let inner = Arc::new(PanelStore::new((7, 7), 1024));
            with_store(&inner, || {
                assert!(bound_for((100, 20)).is_none());
                assert!(bound_for((7, 7)).is_some());
            });
            assert!(bound_for((100, 20)).is_some());
        });
        assert!(bound_for((100, 20)).is_none(), "binding must not leak");
    }

    #[test]
    fn backend_guard_filters_mismatched_stores() {
        use crate::kern::simd::{self, KernBackend};
        let store = Arc::new(simd::with_backend(KernBackend::Scalar, || {
            PanelStore::new((5, 5), 1024)
        }));
        assert_eq!(store.backend(), KernBackend::Scalar);
        with_store(&store, || {
            simd::with_backend(KernBackend::Scalar, || {
                assert!(bound_for((5, 5)).is_some());
            });
            // Under any vector backend this host supports, a store
            // recorded as scalar must not match.
            for b in KernBackend::available() {
                if b != KernBackend::Scalar {
                    simd::with_backend(b, || assert!(bound_for((5, 5)).is_none()));
                }
            }
        });
    }

    #[test]
    fn norms_set_once() {
        let store = PanelStore::new((4, 2), 1024);
        assert!(store.norms().is_none());
        store.set_norms(Arc::new(vec![1.0, 2.0]));
        store.set_norms(Arc::new(vec![9.0, 9.0]));
        assert_eq!(store.norms().unwrap().as_slice(), &[1.0, 2.0]);
    }
}
