//! `kern::reference` — the scalar reference kernels.
//!
//! Two families, both off the hot path, kept so the blocked
//! [`crate::kern`] kernels stay *checkable*:
//!
//! * the **textbook scalar definitions** ([`dot`], [`at_r`],
//!   [`gemv_cols`], [`gram_block`], [`col_sq_norms`], [`gemv`]):
//!   one-accumulator loops in the mathematical traversal order
//!   (column-at-a-time for `Aᵀr` and Gram) — the numeric oracle every
//!   kern kernel is tolerance-checked against (`tests/kern.rs`, and
//!   the `benches/kernels.rs` CI gate fails on `max |Δ| > 1e-9`);
//! * the **pre-kern row-streaming loops** ([`at_r_streamed`],
//!   [`gram_block_streamed`]): faithful reproductions of the inner
//!   loops this crate actually shipped before the kernel engine
//!   (axpy-per-row `Aᵀr`, hoisted-`rj` rank-1 Gram updates), so
//!   `BENCH_kernels.json` records the honest old-code → kern delta
//!   alongside the textbook-scalar speedups.

/// Naive dot product (single accumulator, left to right).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

/// Naive sum of squares.
pub fn sq_norm(x: &[f64]) -> f64 {
    let mut s = 0.0;
    for v in x {
        s += v * v;
    }
    s
}

/// Scalar `Aᵀr` on a row-major `m × n` buffer: one strided
/// column-at-a-time dot per output — the textbook correlation sweep.
pub fn at_r(data: &[f64], m: usize, n: usize, r: &[f64], out: &mut [f64]) {
    debug_assert_eq!(data.len(), m * n);
    debug_assert_eq!(r.len(), m);
    debug_assert_eq!(out.len(), n);
    for (j, o) in out.iter_mut().enumerate() {
        let mut s = 0.0;
        for i in 0..m {
            s += data[i * n + j] * r[i];
        }
        *o = s;
    }
}

/// Scalar `A[:, cols]·w` on a row-major buffer (per-row scalar gather).
pub fn gemv_cols(data: &[f64], m: usize, n: usize, cols: &[usize], w: &[f64], out: &mut [f64]) {
    debug_assert_eq!(data.len(), m * n);
    debug_assert_eq!(cols.len(), w.len());
    debug_assert_eq!(out.len(), m);
    for (i, o) in out.iter_mut().enumerate() {
        let row = &data[i * n..(i + 1) * n];
        let mut s = 0.0;
        for (&j, &x) in cols.iter().zip(w) {
            s += row[j] * x;
        }
        *o = s;
    }
}

/// Scalar Gram block `A[:, ii]ᵀ A[:, jj]` (row-major output,
/// `|ii| × |jj|`): one strided column-pair dot per output cell.
pub fn gram_block(data: &[f64], m: usize, n: usize, ii: &[usize], jj: &[usize]) -> Vec<f64> {
    debug_assert_eq!(data.len(), m * n);
    let nb = jj.len();
    let mut out = vec![0.0; ii.len() * nb];
    for (a, &ci) in ii.iter().enumerate() {
        for (b, &cj) in jj.iter().enumerate() {
            let mut s = 0.0;
            for i in 0..m {
                s += data[i * n + ci] * data[i * n + cj];
            }
            out[a * nb + b] = s;
        }
    }
    out
}

/// Scalar per-column squared norms on a row-major buffer.
pub fn col_sq_norms(data: &[f64], m: usize, n: usize) -> Vec<f64> {
    debug_assert_eq!(data.len(), m * n);
    let mut out = vec![0.0; n];
    for (j, o) in out.iter_mut().enumerate() {
        let mut s = 0.0;
        for i in 0..m {
            let v = data[i * n + j];
            s += v * v;
        }
        *o = s;
    }
    out
}

/// Pre-kern row-streaming `Aᵀr`: accumulate `r_i · row_i` with an
/// axpy per row — byte-for-byte the loop `DenseMatrix::at_r` ran
/// before the kernel engine (including the `r_i == 0` skip; the old
/// `axpy` was a plain element-wise zip).
pub fn at_r_streamed(data: &[f64], m: usize, n: usize, r: &[f64], out: &mut [f64]) {
    debug_assert_eq!(data.len(), m * n);
    debug_assert_eq!(r.len(), m);
    debug_assert_eq!(out.len(), n);
    out.fill(0.0);
    for i in 0..m {
        let ri = r[i];
        if ri != 0.0 {
            let row = &data[i * n..(i + 1) * n];
            for (o, &x) in out.iter_mut().zip(row) {
                *o += ri * x;
            }
        }
    }
}

/// Pre-kern row-streaming Gram block: one pass over `A` with the `jj`
/// values of each row hoisted into a contiguous scratch buffer and a
/// rank-1 update per `ii` column — the loop `DenseMatrix::gram_block`
/// ran before the 4×4 micro-GEMM replaced it.
pub fn gram_block_streamed(
    data: &[f64],
    m: usize,
    n: usize,
    ii: &[usize],
    jj: &[usize],
) -> Vec<f64> {
    debug_assert_eq!(data.len(), m * n);
    let nb = jj.len();
    let na = ii.len();
    let mut out = vec![0.0; na * nb];
    let mut rj = vec![0.0; nb];
    for i in 0..m {
        let row = &data[i * n..(i + 1) * n];
        for (x, &j) in rj.iter_mut().zip(jj) {
            *x = row[j];
        }
        for (a, &c) in ii.iter().enumerate() {
            let v = row[c];
            if v != 0.0 {
                let orow = &mut out[a * nb..(a + 1) * nb];
                for (o, &x) in orow.iter_mut().zip(&rj) {
                    *o += v * x;
                }
            }
        }
    }
    out
}

/// Scalar multi-response `Aᵀ R`: the mathematical definition of
/// [`crate::kern::at_r_multi_panel`] — `k` independent textbook
/// [`at_r`] sweeps, one per response column.
pub fn at_r_multi(data: &[f64], m: usize, n: usize, rs: &[&[f64]], outs: &mut [Vec<f64>]) {
    debug_assert_eq!(rs.len(), outs.len());
    for (r, out) in rs.iter().zip(outs.iter_mut()) {
        at_r(data, m, n, r, out);
    }
}

/// Scalar multi-response fused step: the mathematical definition of
/// [`crate::kern::fused_step_multi_panel`] — `k` independent
/// two-pass [`gemv_cols`] + [`at_r`] sweeps.
#[allow(clippy::too_many_arguments)]
pub fn fused_step_multi(
    data: &[f64],
    m: usize,
    n: usize,
    cols: &[&[usize]],
    ws: &[&[f64]],
    us: &mut [Vec<f64>],
    avs: &mut [Vec<f64>],
) {
    debug_assert_eq!(cols.len(), ws.len());
    for k in 0..cols.len() {
        gemv_cols(data, m, n, cols[k], ws[k], &mut us[k]);
        at_r(data, m, n, &us[k], &mut avs[k]);
    }
}

/// Scalar full GEMV `out = A x` on a row-major buffer.
pub fn gemv(data: &[f64], m: usize, n: usize, x: &[f64], out: &mut [f64]) {
    debug_assert_eq!(data.len(), m * n);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(out.len(), m);
    for (i, o) in out.iter_mut().enumerate() {
        *o = dot(&data[i * n..(i + 1) * n], x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_exact_values() {
        // 3×2 [[1,2],[3,4],[5,6]] — all sums exact in f64.
        let data = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut c = vec![0.0; 2];
        at_r(&data, 3, 2, &[1.0, -1.0, 2.0], &mut c);
        assert_eq!(c, vec![8.0, 10.0]);
        let g = gram_block(&data, 3, 2, &[0, 1], &[0, 1]);
        assert_eq!(g, vec![35.0, 44.0, 44.0, 56.0]);
        assert_eq!(col_sq_norms(&data, 3, 2), vec![35.0, 56.0]);
        let mut u = vec![0.0; 3];
        gemv_cols(&data, 3, 2, &[1], &[2.0], &mut u);
        assert_eq!(u, vec![4.0, 8.0, 12.0]);
        let mut y = vec![0.0; 3];
        gemv(&data, 3, 2, &[1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 7.0, 11.0]);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(sq_norm(&[3.0, 4.0]), 25.0);
    }

    #[test]
    fn streamed_forms_match_textbook_definitions() {
        let data = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let r = [1.0, -1.0, 2.0];
        let mut a = vec![0.0; 2];
        at_r(&data, 3, 2, &r, &mut a);
        let mut b = vec![0.0; 2];
        at_r_streamed(&data, 3, 2, &r, &mut b);
        assert_eq!(a, b);
        let g = gram_block(&data, 3, 2, &[0, 1], &[0, 1]);
        let gs = gram_block_streamed(&data, 3, 2, &[0, 1], &[0, 1]);
        assert_eq!(g, gs);
    }
}
