//! Classic Forward Selection (paper §2; Weisberg [40] §8.5).
//!
//! Greedy: pick the column most correlated with the current residual,
//! fully solve the least-squares problem on the selected set, repeat.
//! "Aggressive" in the paper's terms — it zeroes the selected
//! correlations every step.
//!
//! [`fit_observed`] is the fallible, observer-carrying core the
//! [`crate::fit`] estimator API dispatches to
//! (`Algorithm::ForwardSelection`); the legacy [`forward_selection`]
//! free function remains as a thin deprecated shim.

use crate::error::Result;
use crate::fit::observers::{FitEvent, FitObserver, NoopObserver, ObserverControl};
use crate::lars::path::ls_coefficients;
use crate::lars::{LarsOutput, StopReason};
use crate::linalg::{norm2, Matrix};

/// Output of forward selection.
#[derive(Clone, Debug)]
pub struct ForwardOutput {
    pub selected: Vec<usize>,
    /// Residual norm after each selection (index 0 = ‖b‖).
    pub residual_norms: Vec<f64>,
    /// Final LS coefficients on the selected support.
    pub coefs: Vec<f64>,
}

/// Select `t` columns by forward selection.
#[deprecated(
    since = "0.4.0",
    note = "use calars::fit::FitSpec::new(Algorithm::ForwardSelection) — this shim panics on invalid input"
)]
pub fn forward_selection(a: &Matrix, b: &[f64], t: usize) -> ForwardOutput {
    let (out, coefs) =
        fit_observed(a, b, t, 1e-12, &mut NoopObserver).expect("invalid forward-selection input");
    ForwardOutput { selected: out.selected, residual_norms: out.residual_norms, coefs }
}

/// Forward-selection core: validated inputs, per-selection
/// [`FitObserver`] events, and the family-shaped
/// ([`LarsOutput`], final coefficients) return.
pub fn fit_observed(
    a: &Matrix,
    b: &[f64],
    t: usize,
    tol: f64,
    obs: &mut dyn FitObserver,
) -> Result<(LarsOutput, Vec<f64>)> {
    let n = a.ncols();
    let m = a.nrows();
    crate::lars::check_fit_inputs(a, b, tol)?;
    let t = t.min(n.min(m));
    let mut selected: Vec<usize> = Vec::new();
    let mut in_model = vec![false; n];
    let mut r = b.to_vec();
    let mut c = vec![0.0; n];
    let mut residual_norms = vec![norm2(&r)];
    let mut coefs: Vec<f64> = Vec::new();

    // Direction scratch reused across iterations (was a fresh
    // length-m allocation per selection).
    let mut ax = vec![0.0; m];

    let mut stop = StopReason::TargetReached;
    let mut iter = 0usize;
    while selected.len() < t {
        a.at_r(&r, &mut c);
        let best = (0..n)
            .filter(|&j| !in_model[j])
            .max_by(|&i, &j| c[i].abs().total_cmp(&c[j].abs()));
        let Some(j) = best else {
            stop = StopReason::PoolExhausted;
            break;
        };
        if c[j].abs() <= tol {
            stop = StopReason::Saturated;
            break;
        }
        let pick_corr = c[j].abs();
        in_model[j] = true;
        selected.push(j);
        // Full LS refit on the selected support (the aggressive step).
        match ls_coefficients(a, &selected, b) {
            Some(x) => {
                a.gemv_cols(&selected, &x, &mut ax);
                for i in 0..m {
                    r[i] = b[i] - ax[i];
                }
                coefs = x;
            }
            None => {
                // Collinear pick: drop it and stop.
                selected.pop();
                in_model[j] = false;
                stop = StopReason::RankDeficient;
                break;
            }
        }
        let rnorm = norm2(&r);
        residual_norms.push(rnorm);

        let observer_stop = obs.on_iteration(&FitEvent {
            iter,
            selected: &selected,
            gamma: f64::NAN,
            residual_norm: rnorm,
            lambda: pick_corr,
        }) == ObserverControl::Stop;
        iter += 1;
        if observer_stop {
            stop = StopReason::EarlyStopped;
            break;
        }
    }

    let cols_at_iter: Vec<usize> = (0..=selected.len()).collect();
    let y: Vec<f64> = b.iter().zip(&r).map(|(bi, ri)| bi - ri).collect();
    Ok((LarsOutput { selected, residual_norms, cols_at_iter, y, stop }, coefs))
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shim doubles as regression coverage

    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};

    #[test]
    fn recovers_planted_support() {
        let s = generate(
            &SyntheticSpec { m: 60, n: 30, density: 1.0, col_skew: 0.0, k_true: 4, noise: 0.0 },
            1,
        );
        let out = forward_selection(&s.a, &s.b, 4);
        let mut got = out.selected.clone();
        got.sort_unstable();
        assert_eq!(got, s.true_support);
        assert!(*out.residual_norms.last().unwrap() < 1e-8);
    }

    #[test]
    fn residuals_strictly_decrease() {
        let s = generate(
            &SyntheticSpec { m: 80, n: 40, density: 1.0, col_skew: 0.0, k_true: 10, noise: 0.1 },
            2,
        );
        let out = forward_selection(&s.a, &s.b, 10);
        for w in out.residual_norms.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn more_aggressive_than_lars_early() {
        // Forward selection minimizes the LS residual on its support, so
        // at equal support size its residual is ≤ the LARS y-estimate's.
        use crate::lars::serial::{lars, LarsOptions};
        let s = generate(
            &SyntheticSpec { m: 100, n: 50, density: 1.0, col_skew: 0.0, k_true: 15, noise: 0.2 },
            3,
        );
        let fs = forward_selection(&s.a, &s.b, 5);
        let la = lars(&s.a, &s.b, &LarsOptions { t: 5, ..Default::default() });
        assert!(
            fs.residual_norms.last().unwrap() <= la.residual_norms.last().unwrap(),
        );
    }

    #[test]
    fn fit_observed_reports_target_reached() {
        let s = generate(
            &SyntheticSpec { m: 60, n: 30, density: 1.0, col_skew: 0.0, k_true: 4, noise: 0.05 },
            4,
        );
        let (out, coefs) = fit_observed(&s.a, &s.b, 6, 1e-12, &mut NoopObserver).unwrap();
        assert_eq!(out.selected.len(), 6);
        assert_eq!(out.stop, StopReason::TargetReached);
        assert_eq!(coefs.len(), 6);
        assert_eq!(out.cols_at_iter, (0..=6).collect::<Vec<_>>());
    }
}
