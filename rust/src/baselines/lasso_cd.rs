//! LASSO via cyclic coordinate descent (paper §2 eq. (1), penalized
//! form `min ½‖Ax−b‖² + λ‖x‖₁`; cf. [28, 34, 42]).
//!
//! Context baseline: an *optimization* method producing a single model
//! per λ, versus the paper's LARS-family which produces the whole
//! sequence. Used by examples to contrast the two families, and by
//! tests (a LASSO solution's support at matched sparsity should be
//! close to the LARS path's).

use crate::linalg::{norm2, Matrix};

/// Output of a coordinate-descent LASSO solve.
#[derive(Clone, Debug)]
pub struct LassoOutput {
    /// Coefficients (length n).
    pub x: Vec<f64>,
    /// Support of x (nonzero indices, ascending).
    pub support: Vec<usize>,
    /// ‖Ax − b‖₂ at the solution.
    pub residual_norm: f64,
    /// Sweeps actually performed.
    pub sweeps: usize,
    /// True if the duality-free stopping criterion fired before
    /// `max_sweeps`.
    pub converged: bool,
}

/// Solve the penalized LASSO with cyclic coordinate descent.
///
/// Columns are assumed unit-norm (the crate's standing assumption), so
/// the per-coordinate update is the plain soft-threshold
/// `x_j ← S(x_j + A_jᵀr, λ)`.
pub fn lasso_cd(a: &Matrix, b: &[f64], lambda: f64, max_sweeps: usize, tol: f64) -> LassoOutput {
    let n = a.ncols();
    let m = a.nrows();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut col_buf = vec![0.0; m];
    let mut converged = false;
    let mut sweeps = 0;

    for sweep in 0..max_sweeps {
        let mut max_delta = 0.0_f64;
        for j in 0..n {
            let cj = a.col_dot(j, &r);
            let z = x[j] + cj;
            let xnew = soft_threshold(z, lambda);
            let delta = xnew - x[j];
            if delta != 0.0 {
                a.gemv_cols(&[j], &[1.0], &mut col_buf);
                for i in 0..m {
                    r[i] -= delta * col_buf[i];
                }
                x[j] = xnew;
                max_delta = max_delta.max(delta.abs());
            }
        }
        sweeps = sweep + 1;
        if max_delta <= tol {
            converged = true;
            break;
        }
    }
    let support: Vec<usize> = (0..n).filter(|&j| x[j] != 0.0).collect();
    LassoOutput { residual_norm: norm2(&r), x, support, sweeps, converged }
}

#[inline]
fn soft_threshold(z: f64, lambda: f64) -> f64 {
    if z > lambda {
        z - lambda
    } else if z < -lambda {
        z + lambda
    } else {
        0.0
    }
}

/// λ_max: the smallest λ with all-zero solution (= ‖Aᵀb‖∞).
pub fn lambda_max(a: &Matrix, b: &[f64]) -> f64 {
    let mut c = vec![0.0; a.ncols()];
    a.at_r(b, &mut c);
    crate::linalg::norm_inf(&c)
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // cross-checks against the legacy LARS shim

    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn problem(seed: u64) -> crate::data::synthetic::Synthetic {
        generate(
            &SyntheticSpec { m: 80, n: 40, density: 1.0, col_skew: 0.0, k_true: 5, noise: 0.01 },
            seed,
        )
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(2.0, 0.5), 1.5);
        assert_eq!(soft_threshold(-2.0, 0.5), -1.5);
        assert_eq!(soft_threshold(0.3, 0.5), 0.0);
    }

    #[test]
    fn lambda_max_zeroes_solution() {
        let s = problem(1);
        let lmax = lambda_max(&s.a, &s.b);
        let out = lasso_cd(&s.a, &s.b, lmax * 1.001, 50, 1e-10);
        assert!(out.support.is_empty(), "support {:?}", out.support);
    }

    #[test]
    fn small_lambda_fits_well() {
        let s = problem(2);
        let lmax = lambda_max(&s.a, &s.b);
        let out = lasso_cd(&s.a, &s.b, lmax * 0.01, 500, 1e-10);
        assert!(out.converged);
        assert!(out.residual_norm < 0.2 * norm2(&s.b));
    }

    #[test]
    fn kkt_conditions_hold() {
        let s = problem(3);
        let lambda = lambda_max(&s.a, &s.b) * 0.3;
        let out = lasso_cd(&s.a, &s.b, lambda, 1000, 1e-12);
        assert!(out.converged);
        // KKT: |A_jᵀ r| ≤ λ for all j, with equality (sign-matched) on the support.
        let r: Vec<f64> = {
            let mut ax = vec![0.0; s.a.nrows()];
            let support: Vec<usize> = out.support.clone();
            let coefs: Vec<f64> = support.iter().map(|&j| out.x[j]).collect();
            s.a.gemv_cols(&support, &coefs, &mut ax);
            s.b.iter().zip(&ax).map(|(bi, yi)| bi - yi).collect()
        };
        let mut c = vec![0.0; s.a.ncols()];
        s.a.at_r(&r, &mut c);
        for j in 0..s.a.ncols() {
            assert!(c[j].abs() <= lambda * (1.0 + 1e-6) + 1e-8, "KKT violated at {j}");
        }
        for &j in &out.support {
            assert!(
                (c[j] - lambda * out.x[j].signum()).abs() < 1e-6,
                "support KKT at {j}: c={} λ·sign={}",
                c[j],
                lambda * out.x[j].signum()
            );
        }
    }

    #[test]
    fn support_overlaps_lars_path() {
        use crate::lars::serial::{lars, LarsOptions};
        let s = problem(4);
        let lambda = lambda_max(&s.a, &s.b) * 0.5;
        let out = lasso_cd(&s.a, &s.b, lambda, 1000, 1e-12);
        let k = out.support.len().max(1);
        let la = lars(&s.a, &s.b, &LarsOptions { t: k, ..Default::default() });
        let overlap = crate::lars::quality::precision(&out.support, &la.selected);
        assert!(overlap >= 0.5, "LASSO support far from LARS path: {overlap}");
    }
}
