//! Orthogonal Matching Pursuit — the classic ℓ0 greedy (paper §2's
//! ℓ0-regularized family; Needell–Woolf [27] parallelize a stochastic
//! variant). Equivalent to Forward Selection with the orthogonal
//! projection done via the same incremental Cholesky machinery the
//! paper's bLARS uses — a good cross-check for [`crate::linalg::cholesky`].
//!
//! [`fit_observed`] is the fallible, observer-carrying core the
//! [`crate::fit`] estimator API dispatches to (`Algorithm::Omp`); the
//! legacy [`omp`] free function remains as a thin deprecated shim.

use crate::error::Result;
use crate::fit::observers::{FitEvent, FitObserver, NoopObserver, ObserverControl};
use crate::lars::{LarsOutput, StopReason};
use crate::linalg::{norm2, Cholesky, Matrix};

/// Output of OMP.
#[derive(Clone, Debug)]
pub struct OmpOutput {
    pub selected: Vec<usize>,
    pub coefs: Vec<f64>,
    pub residual_norms: Vec<f64>,
}

/// Select `t` columns by OMP (incremental-Cholesky implementation).
#[deprecated(
    since = "0.4.0",
    note = "use calars::fit::FitSpec::new(Algorithm::Omp) — this shim panics on invalid input"
)]
pub fn omp(a: &Matrix, b: &[f64], t: usize) -> OmpOutput {
    let (out, coefs) = fit_observed(a, b, t, 1e-12, &mut NoopObserver).expect("invalid OMP input");
    OmpOutput { selected: out.selected, coefs, residual_norms: out.residual_norms }
}

/// OMP core: validated inputs, per-selection [`FitObserver`] events,
/// and the family-shaped ([`LarsOutput`], final coefficients) return.
/// A collinear pick stops the run with [`StopReason::RankDeficient`].
pub fn fit_observed(
    a: &Matrix,
    b: &[f64],
    t: usize,
    tol: f64,
    obs: &mut dyn FitObserver,
) -> Result<(LarsOutput, Vec<f64>)> {
    let n = a.ncols();
    let m = a.nrows();
    crate::lars::check_fit_inputs(a, b, tol)?;
    let t = t.min(n.min(m));
    let mut selected: Vec<usize> = Vec::new();
    let mut in_model = vec![false; n];
    let mut chol = Cholesky::empty();
    let mut atb: Vec<f64> = Vec::new();
    let mut r = b.to_vec();
    let mut c = vec![0.0; n];
    let mut coefs: Vec<f64> = Vec::new();
    let mut residual_norms = vec![norm2(&r)];

    // Scratch reused across iterations (ax/grow used to reallocate
    // every selection).
    let mut ax = vec![0.0; m];
    let mut grow: Vec<f64> = Vec::new();

    let mut stop = StopReason::TargetReached;
    let mut iter = 0usize;
    while selected.len() < t {
        a.at_r(&r, &mut c);
        let best = (0..n)
            .filter(|&j| !in_model[j])
            .max_by(|&i, &j| c[i].abs().total_cmp(&c[j].abs()));
        let Some(j) = best else {
            stop = StopReason::PoolExhausted;
            break;
        };
        if c[j].abs() <= tol {
            stop = StopReason::Saturated;
            break;
        }
        let pick_corr = c[j].abs();
        // Extend the factor with column j.
        let gi = a.gram_block(&selected, &[j]);
        let gjj = a.gram_block(&[j], &[j]).get(0, 0);
        grow.clear();
        grow.extend((0..selected.len()).map(|i| gi.get(i, 0)));
        grow.push(gjj);
        if chol.push_row(&grow).is_err() {
            stop = StopReason::RankDeficient;
            break; // collinear — stop
        }
        in_model[j] = true;
        selected.push(j);
        atb.push(a.col_dot(j, b));
        // LS solve on the support, recompute the residual.
        chol.solve_into(&atb, &mut coefs);
        a.gemv_cols(&selected, &coefs, &mut ax);
        for i in 0..m {
            r[i] = b[i] - ax[i];
        }
        let rnorm = norm2(&r);
        residual_norms.push(rnorm);

        let observer_stop = obs.on_iteration(&FitEvent {
            iter,
            selected: &selected,
            gamma: f64::NAN,
            residual_norm: rnorm,
            lambda: pick_corr,
        }) == ObserverControl::Stop;
        iter += 1;
        if observer_stop {
            stop = StopReason::EarlyStopped;
            break;
        }
    }

    let cols_at_iter: Vec<usize> = (0..=selected.len()).collect();
    let y: Vec<f64> = b.iter().zip(&r).map(|(bi, ri)| bi - ri).collect();
    Ok((LarsOutput { selected, residual_norms, cols_at_iter, y, stop }, coefs))
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shims double as regression coverage

    use super::*;
    use crate::baselines::forward_selection::forward_selection;
    use crate::data::synthetic::{generate, SyntheticSpec};

    #[test]
    fn matches_forward_selection() {
        // OMP and forward selection are the same algorithm; this one uses
        // the incremental Cholesky, forward_selection refactors each step.
        let s = generate(
            &SyntheticSpec { m: 70, n: 35, density: 1.0, col_skew: 0.0, k_true: 6, noise: 0.05 },
            1,
        );
        let o = omp(&s.a, &s.b, 6);
        let f = forward_selection(&s.a, &s.b, 6);
        assert_eq!(o.selected, f.selected);
        for (x, y) in o.residual_norms.iter().zip(&f.residual_norms) {
            assert!((x - y).abs() < 1e-8);
        }
    }

    #[test]
    fn exact_recovery() {
        let s = generate(
            &SyntheticSpec { m: 50, n: 25, density: 1.0, col_skew: 0.0, k_true: 3, noise: 0.0 },
            2,
        );
        let o = omp(&s.a, &s.b, 3);
        let mut got = o.selected.clone();
        got.sort_unstable();
        assert_eq!(got, s.true_support);
        assert!(*o.residual_norms.last().unwrap() < 1e-8);
    }

    #[test]
    fn sparse_input_ok() {
        let s = generate(
            &SyntheticSpec { m: 100, n: 80, density: 0.2, col_skew: 0.5, k_true: 5, noise: 0.01 },
            3,
        );
        let o = omp(&s.a, &s.b, 8);
        assert_eq!(o.selected.len(), 8);
        for w in o.residual_norms.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
    }

    #[test]
    fn fit_observed_reports_target_reached() {
        let s = generate(
            &SyntheticSpec { m: 60, n: 30, density: 1.0, col_skew: 0.0, k_true: 4, noise: 0.05 },
            4,
        );
        let (out, coefs) = fit_observed(&s.a, &s.b, 5, 1e-12, &mut NoopObserver).unwrap();
        assert_eq!(out.selected.len(), 5);
        assert_eq!(out.stop, StopReason::TargetReached);
        assert_eq!(coefs.len(), 5);
    }
}
