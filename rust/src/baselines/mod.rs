//! Baseline feature-selection / sparse-regression algorithms (paper §2).
//!
//! LARS unifies Forward Selection (aggressive) and Forward Stagewise
//! (cautious); LASSO is the optimization-based alternative whose
//! solution path a LARS variant reproduces. These are implemented both
//! as correctness anchors for tests and so the example applications can
//! compare the paper's methods against the classical alternatives.

pub mod forward_selection;
pub mod lasso_cd;
pub mod omp;
pub mod stagewise;
