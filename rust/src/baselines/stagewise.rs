//! Forward Stagewise regression (paper §2; Hastie et al. [19, 20]).
//!
//! The cautious cousin of forward selection: at each step increment the
//! coefficient of the most-correlated column by ±ε. Many steps, tiny
//! moves; LARS was designed to take its limiting path in one shot.

use crate::linalg::{norm2, Matrix};

/// Output of forward stagewise.
#[derive(Clone, Debug)]
pub struct StagewiseOutput {
    /// Distinct columns touched, in first-touch order.
    pub selected: Vec<usize>,
    /// Coefficient vector (length n).
    pub x: Vec<f64>,
    /// Residual norm sampled every `sample_every` steps.
    pub residual_norms: Vec<f64>,
    /// Steps actually taken.
    pub steps: usize,
}

/// Run forward stagewise with step `eps` until `max_steps` or until the
/// maximum absolute correlation drops below `tol`.
pub fn stagewise(
    a: &Matrix,
    b: &[f64],
    eps: f64,
    max_steps: usize,
    tol: f64,
) -> StagewiseOutput {
    let n = a.ncols();
    let m = a.nrows();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut c = vec![0.0; n];
    let mut selected: Vec<usize> = Vec::new();
    let mut touched = vec![false; n];
    let sample_every = (max_steps / 200).max(1);
    let mut residual_norms = vec![norm2(&r)];
    let mut steps = 0;

    for step in 0..max_steps {
        a.at_r(&r, &mut c);
        let j = (0..n)
            .max_by(|&i, &j| c[i].abs().total_cmp(&c[j].abs()))
            .unwrap();
        if c[j].abs() <= tol {
            break;
        }
        let delta = eps * c[j].signum();
        x[j] += delta;
        // r ← r − δ·A_j (column update keeps this O(nnz(col))).
        let mut aj = vec![0.0; m];
        a.gemv_cols(&[j], &[1.0], &mut aj);
        for i in 0..m {
            r[i] -= delta * aj[i];
        }
        if !touched[j] {
            touched[j] = true;
            selected.push(j);
        }
        steps = step + 1;
        if steps % sample_every == 0 {
            residual_norms.push(norm2(&r));
        }
    }
    residual_norms.push(norm2(&r));
    StagewiseOutput { selected, x, residual_norms, steps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn problem(seed: u64) -> crate::data::synthetic::Synthetic {
        generate(
            &SyntheticSpec { m: 60, n: 30, density: 1.0, col_skew: 0.0, k_true: 3, noise: 0.0 },
            seed,
        )
    }

    #[test]
    fn takes_many_small_steps() {
        let s = problem(1);
        let out = stagewise(&s.a, &s.b, 0.01, 5000, 1e-3);
        assert!(out.steps > 50, "stagewise should be cautious, took {}", out.steps);
    }

    #[test]
    fn residual_decreases_overall() {
        let s = problem(2);
        let out = stagewise(&s.a, &s.b, 0.01, 3000, 1e-4);
        let first = out.residual_norms[0];
        let last = *out.residual_norms.last().unwrap();
        assert!(last < 0.5 * first, "{first} -> {last}");
    }

    #[test]
    fn touches_true_support_first() {
        let s = problem(3);
        let out = stagewise(&s.a, &s.b, 0.005, 8000, 1e-4);
        // The first few touched columns should mostly be in the support.
        let head: Vec<usize> = out.selected.iter().take(3).copied().collect();
        let hits = head.iter().filter(|j| s.true_support.contains(j)).count();
        assert!(hits >= 2, "head {head:?} vs support {:?}", s.true_support);
    }
}
