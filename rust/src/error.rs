//! Crate-local error handling (the offline environment has no `anyhow`;
//! this module is the drop-in replacement the rest of the crate builds
//! against).
//!
//! Provides the same working vocabulary: an opaque [`Error`] carrying a
//! context chain, a [`Result`] alias defaulting the error type, the
//! [`anyhow!`]/[`bail!`] constructor macros, and a [`Context`] extension
//! trait for `Result`/`Option`. Display shows the outermost context;
//! the alternate form (`{:#}`) renders the whole chain separated by
//! `": "`, matching what `calars`'s top-level error printer expects.

use std::fmt;

/// Coarse classification of an [`Error`], preserved through context
/// attachment. The serving layer maps kinds onto HTTP status codes
/// (`InvalidSpec` → 400, `RankDeficient` → 422, `Internal` → 500) so a
/// bad request can never take down a connection the way the old
/// `assert!`s could.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// No more specific classification (the default).
    Other,
    /// A user-supplied specification or input failed validation
    /// (wrong response length, zero block size, unknown algorithm…).
    InvalidSpec,
    /// The problem is numerically rank deficient (near-duplicate
    /// columns made a Gram factorization impossible). Note: the
    /// fitters report *recoverable* rank deficiency through
    /// [`crate::lars::StopReason::RankDeficient`] inside a successful
    /// result; this error kind is reserved for hard failures where no
    /// result can be produced at all.
    RankDeficient,
    /// A server-side invariant broke (e.g. a worker thread panicked
    /// mid-request). The request failed through no fault of the
    /// caller's input; the HTTP layer answers 500.
    Internal,
}

/// An opaque error: a chain of human-readable messages, outermost
/// first, plus an [`ErrorKind`] classification.
///
/// Deliberately does **not** implement `std::error::Error`, so the
/// blanket `From<E: std::error::Error>` conversion below stays coherent
/// (the same trade anyhow makes).
#[derive(Clone)]
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) context.
    chain: Vec<String>,
    kind: ErrorKind,
}

impl Error {
    /// Construct from a single message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { chain: vec![m.to_string()], kind: ErrorKind::Other }
    }

    /// An [`ErrorKind::InvalidSpec`] error (bad user-supplied input).
    pub fn invalid_spec(m: impl fmt::Display) -> Self {
        Error { chain: vec![m.to_string()], kind: ErrorKind::InvalidSpec }
    }

    /// An [`ErrorKind::RankDeficient`] error (singular Gram block).
    pub fn rank_deficient(m: impl fmt::Display) -> Self {
        Error { chain: vec![m.to_string()], kind: ErrorKind::RankDeficient }
    }

    /// An [`ErrorKind::Internal`] error (a server-side failure the
    /// caller's input did not cause — e.g. a panicked worker).
    pub fn internal(m: impl fmt::Display) -> Self {
        Error { chain: vec![m.to_string()], kind: ErrorKind::Internal }
    }

    /// The error's classification (survives [`Self::context`]).
    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// Attach an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost message.
    pub fn root(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                writeln!(f, "    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain, kind: ErrorKind::Other }
    }
}

/// Crate-wide result type; the error parameter defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format arguments (anyhow's `anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

pub use crate::{anyhow, bail};

/// Attach context to fallible values (`Result`/`Option`), converting the
/// error into [`Error`] in the process.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_outermost_alternate_full_chain() {
        let e = Error::msg("inner").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
    }

    #[test]
    fn from_std_error_keeps_source_chain() {
        let e: Error = io_err().into();
        assert_eq!(e.root(), "no such file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(e.root(), "missing field");

        let ok: Option<u32> = Some(7);
        assert_eq!(ok.context("unused").unwrap(), 7);
    }

    #[test]
    fn macros_build_and_bail() {
        fn fails(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative input {x}");
            }
            Ok(x)
        }
        assert_eq!(fails(3).unwrap(), 3);
        let e = fails(-2).unwrap_err();
        assert_eq!(e.root(), "negative input -2");
        let e2 = anyhow!("code {}", 42);
        assert_eq!(e2.root(), "code 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            let v: i32 = s.parse()?;
            Ok(v)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn kinds_classify_and_survive_context() {
        assert_eq!(Error::msg("x").kind(), ErrorKind::Other);
        assert_eq!(Error::invalid_spec("t = 0").kind(), ErrorKind::InvalidSpec);
        assert_eq!(Error::rank_deficient("dup").kind(), ErrorKind::RankDeficient);
        let e = Error::invalid_spec("t = 0").context("parsing /fit body");
        assert_eq!(e.kind(), ErrorKind::InvalidSpec, "context must not erase the kind");
        assert_eq!(format!("{e:#}"), "parsing /fit body: t = 0");
        let io: Error = io_err().into();
        assert_eq!(io.kind(), ErrorKind::Other);
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("root cause").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("root cause"));
    }
}
