//! Deterministic pseudo-random number generation.
//!
//! The environment is offline (no `rand` crate), so the crate carries its
//! own small, well-tested generator: PCG-XSH-RR 64/32 with a 64-bit
//! state-stream pair, plus the handful of distributions the dataset
//! generators need (uniform, normal, log-normal, Zipf-like power law).
//! Everything is deterministic given a seed, which the experiment drivers
//! rely on for reproducibility.

/// PCG-XSH-RR 64/32 generator (O'Neill 2014).
///
/// Small state, passes practical statistical tests, and is fully
/// deterministic across platforms — sufficient for synthetic data
/// generation and property-based testing.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed; the stream is derived from the seed.
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (seed.wrapping_mul(0x9E3779B97F4A7C15) | 1),
        };
        rng.state = rng.state.wrapping_add(seed).wrapping_mul(PCG_MULT);
        rng.next_u32();
        rng.next_u32();
        rng
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection-free-ish method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 64-bit multiply-shift; bias is negligible for n << 2^64 and the
        // generator is only used for data synthesis / test-case choice.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the second variate).
    pub fn normal(&mut self) -> f64 {
        // Avoid caching to keep the generator `Clone`-cheap and branch-free
        // determinism simple; Box–Muller cost is irrelevant here.
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal: `exp(N(mu, sigma))`. Used for skewed per-column nnz
    /// distributions matching the paper's Figure 2 histograms.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-like power-law sample over `[1, n]` with exponent `s` via
    /// inverse-CDF of the continuous Pareto approximation.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        let u = self.uniform().max(1e-12);
        if (s - 1.0).abs() < 1e-9 {
            let x = (n as f64).powf(u);
            (x as usize).clamp(1, n)
        } else {
            let a = 1.0 - s;
            let x = ((u * ((n as f64).powf(a) - 1.0)) + 1.0).powf(1.0 / a);
            (x as usize).clamp(1, n)
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // For small k relative to n, rejection sampling over a set would
        // work; partial shuffle is simple and O(n) which is fine here.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be nearly disjoint, got {same} collisions");
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut rng = Pcg64::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Pcg64::new(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = rng.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut rng = Pcg64::new(8);
        let n = 1000;
        let samples: Vec<usize> = (0..20_000).map(|_| rng.zipf(n, 1.3)).collect();
        assert!(samples.iter().all(|&x| (1..=n).contains(&x)));
        // Power law: small values should dominate.
        let small = samples.iter().filter(|&&x| x <= 10).count();
        assert!(small > samples.len() / 4, "small-count={small}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(10);
        let idx = rng.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
    }
}
