//! `calars` — launcher CLI for the communication-avoiding LARS
//! framework.
//!
//! ```text
//! calars run         --algo blars --dataset sector --t 60 --b 4 --p 16
//! calars batch       --dataset year --k 64 --algo lars --t 20
//! calars exp         <table1|table2|table3|fig2..fig8|all> [--quick]
//! calars suite       [--quick]      # every table+figure, in order
//! calars serve       [--port N] [--prefit tiny] [--oneshot]
//! calars bench-serve [--addr H:P] [--requests N] [--concurrency C]
//! calars info                       # datasets + runtime status
//! ```

use calars::cluster::ExecMode;
use calars::config::{Args, ServeConfig, SweepConfig};
use calars::data::datasets;
use calars::error::{bail, Result};
use calars::experiments;
use calars::fit::{Algorithm, FitSpec, Fitter, ProgressObserver, TraceObserver};
use calars::metrics::{fmt_count, fmt_secs, json_f64_rounded};
use calars::select::{Criterion, SelectSpec};
use calars::runtime::XlaRuntime;
use calars::serve::{
    spawn_server, FitRequest, LoadOptions, Selector, ServeClient, ServeOptions,
};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    if let Err(e) = init_par(&args).and_then(|_| dispatch(&args)) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Size the global [`calars::par`] pool and pin the kernel ISA backend
/// before any kernel runs: `CALARS_THREADS` / `CALARS_MIN_CHUNK` /
/// `CALARS_ISA` from the environment, overridden by `--par-threads` /
/// `--par-min-chunk` / `--isa`.
fn init_par(args: &Args) -> Result<()> {
    calars::config::init_isa_from_args(args)?;
    let cfg = calars::config::par_config_from_args(args)?;
    calars::par::configure(cfg);
    Ok(())
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(args),
        Some("batch") => cmd_batch(args),
        Some("trace") => cmd_trace(args),
        Some("select") => cmd_select(args),
        Some("exp") => cmd_exp(args),
        Some("suite") => cmd_suite(args),
        Some("serve") => cmd_serve(args),
        Some("bench-serve") => cmd_bench_serve(args),
        Some("info") => cmd_info(args),
        Some("audit") => cmd_audit(),
        Some(other) => bail!("unknown command '{other}'"),
        None => {
            println!("{}", usage());
            Ok(())
        }
    }
}

fn usage() -> &'static str {
    "calars — parallel & communication-avoiding LARS (paper reproduction)

USAGE:
  calars run   --algo <lars|blars|tblars|lasso|omp|fs> --dataset <name>
               [--t N] [--b N] [--p N] [--seed N] [--tol X] [--lambda-min X]
               [--threads] [--progress]
  calars batch --dataset <name> --k N [--algo <lars|lasso|omp|fs|blars|tblars>]
               [--t N] [--b N] [--p N] [--seed N] [--tol X] [--lambda-min X]
  calars trace --algo <lars|blars|tblars|lasso|omp|fs> --dataset <name>
               [--t N] [--b N] [--p N] [--seed N] [--tol X] [--lambda-min X] [--threads]
  calars select --dataset <name> [--algo A] [--t N] [--b N] [--p N] [--seed N]
               [--criterion <cp|aic|bic|cv>] [--k N] [--cv-seed N] [--threads]
  calars exp   <table1|table2|table3|fig2|fig3|fig4|fig5|fig6|fig7|fig8> [--quick] [--t N] [--seed N]
  calars suite [--quick]
  calars serve [--addr H:P] [--port N] [--fit-workers N] [--batch-window-us N]
               [--capacity N] [--cache N] [--persist DIR] [--prefit DATASET]
               [--slow-ms N] [--oneshot]
  calars bench-serve [--addr H:P] [--requests N] [--concurrency C] [--rows R]
               [--dataset NAME] [--algo A] [--t N] [--b N] [--step K | --lambda L]
               [--seed N] [--shutdown] [--json]
  calars info  [--json]
  calars audit [--root DIR] [--deny-warnings] [--explain RULE] [--list]

run drives the unified calars::fit estimator API: every algorithm —
the paper's three, the exact LASSO-LARS path, and the greedy
baselines (omp, fs) — goes through one FitSpec/Fitter call path.
--progress attaches a ProgressObserver (per-iteration lines on
stderr); --tol and --lambda-min are the spec's numerical knobs.

batch fits ONE design matrix against a panel of --k responses through
calars::batch (FitSpec::fit_batch): response 0 is the dataset's own b,
the rest are seeded synthetic draws. lars and lasso run in lockstep so
the per-iteration A^T R, direction, and gamma passes are batched across
models and Gram panels are shared; other algorithms fall back to
per-response fits inside the same scheduler. The shared-work ledger
(batched vs sequential-equivalent passes, Gram panel hits) prints after
the per-model summaries. A batch of one is bit-identical to calars run.

trace runs ONE fit with tracing force-enabled and prints its span
tree (per-phase Corr/Select/Cholesky/Gamma/Update timings with flops)
plus a phase-total table; when the algorithm also runs the simulated
cluster, the α-β-γ per-phase table prints next to the measured one.
The serving layer exposes the same spans per request at GET
/trace/<id> (chrome://tracing JSON) and aggregates at GET /metrics.

select fits the full path and then chooses WHICH step to serve
(calars::select): Mallows' Cp, AIC, or BIC per stored step (df =
active-set size), or --criterion cv for seeded k-fold
cross-validation whose fold fits fan out on the thread pool — the
chosen step is bit-identical at every CALARS_THREADS setting. The
serving layer exposes the same machinery as POST /select and the
'auto <criterion>' predict selector.

Every command honors --par-threads N / --par-min-chunk N (or the
CALARS_THREADS / CALARS_MIN_CHUNK environment variables) to size the
shared-memory kernel pool; threads=1 runs fully inline and results are
bit-identical at any thread count (see DESIGN.md). Every command also
honors --isa <scalar|avx2|avx512|neon> (or CALARS_ISA) to pin the SIMD
kernel backend; by default the fastest ISA the CPU supports is
auto-detected at startup. info reports the active backend.

serve runs the L4 model-serving subsystem: POST /fit, POST /predict,
GET /models, GET /stats, GET /metrics (Prometheus text), GET
/trace/<id> (chrome://tracing JSON for one request; every JSON
response echoes its trace_id) — see DESIGN.md. Requests slower than
--slow-ms land in a ring-buffered slow log. --oneshot additionally honors
POST /shutdown for scripted smoke runs. bench-serve is the closed-loop
load generator; without --addr it spins up an in-process server first.
--json emits one machine-readable perf record (scripts/ci.sh captures
it as BENCH_serving.json); info --json reports cores/threads/features
for annotating bench output.

audit runs the calars-audit static-analysis pass over the workspace
(DESIGN.md §'Static analysis & invariants'): determinism, panic-safety,
unsafe-budget and zero-dependency rules with file:line diagnostics.
--explain RULE documents one invariant; CI runs --deny-warnings.

Datasets: sector, year, e2006_log1p, e2006_tfidf (scaled synthetic
substitutes; see DESIGN.md), plus tiny / tiny_dense for smoke runs."
}

/// `calars audit` — delegate to the calars-audit library so the
/// subcommand and the standalone `calars-audit` binary are
/// byte-identical. The audit owns its own argv (and exit code: 1 means
/// findings, not a CLI error), so re-read the raw args past "audit".
fn cmd_audit() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let at = raw.iter().position(|a| a == "audit").map_or(raw.len(), |i| i + 1);
    std::process::exit(calars_audit::run_cli(&raw[at..]));
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = ServeConfig::from_args(args)?;
    // Normally a no-op (init_par already configured the pool), but it
    // keeps ServeConfig self-contained for library callers.
    calars::par::configure(cfg.par);
    let opts: ServeOptions = cfg.into();
    calars::serve::serve(&opts)
}

fn cmd_bench_serve(args: &Args) -> Result<()> {
    let json = args.flag("json");
    let requests = args.get_parse::<usize>("requests", 1000)?;
    let concurrency = args.get_parse::<usize>("concurrency", 4)?;
    let rows = args.get_parse::<usize>("rows", 4)?;
    if requests == 0 || concurrency == 0 || rows == 0 {
        bail!(
            "usage: calars bench-serve needs positive --requests/--concurrency/--rows \
             (got requests={requests} concurrency={concurrency} rows={rows})"
        );
    }
    let t = args.get_parse::<usize>("t", 16)?;
    let seed = args.get_parse::<u64>("seed", 42)?;
    // In JSON mode stdout carries exactly one machine-readable record
    // (scripts/ci.sh redirects it into BENCH_serving.json); narration
    // goes to stderr.
    let note = |line: String| {
        if json {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    };

    // Target: a running instance via --addr, or a self-contained
    // in-process server on an ephemeral port.
    let (addr, handle) = match args.get("addr") {
        Some(a) => (a.to_string(), None),
        None => {
            let opts = ServeOptions { addr: "127.0.0.1:0".to_string(), ..Default::default() };
            let handle = spawn_server(&opts)?;
            let addr = handle.addr_string();
            note(format!("spawned in-process server on {addr}"));
            (addr, Some(handle))
        }
    };

    // Ensure the target model exists (warm-reused if already fitted).
    let fit = FitRequest {
        dataset: args.get("dataset").unwrap_or("tiny").to_string(),
        algo: args.get("algo").unwrap_or("lars").to_string(),
        t,
        b: args.get_parse::<usize>("b", 1)?,
        p: args.get_parse::<usize>("p", 4)?,
        seed,
        ..Default::default()
    };
    let mut client = ServeClient::connect(&addr)?;
    let model = client.fit(&fit, true)?;
    let dim = client.model_dim(model)?;
    note(format!("target model {model} ({} t={t}, n={dim}) on {addr}", fit.dataset));

    let selector = match args.get("lambda") {
        Some(l) => Selector::Lambda(l.parse().map_err(|e| calars::anyhow!("--lambda: {e}"))?),
        None => Selector::Step(args.get_parse::<usize>("step", t)?),
    };
    let load = LoadOptions { requests, concurrency, rows, model, selector, dim, seed };
    note(format!(
        "load: {requests} requests x {rows} rows, {concurrency} connections, {selector:?}"
    ));
    // JSON mode also measures a concurrency-1 baseline so the record
    // carries a batching/concurrency speedup next to the raw wall
    // time. A discarded warm-up pass runs first so neither measurement
    // pays the one-time costs (coefficient-cache misses, first-touch
    // allocation, connection setup) — otherwise whichever load ran
    // first would bias the recorded speedup.
    let baseline = if json && concurrency > 1 {
        let warm = LoadOptions { requests: requests.min(32), ..load.clone() };
        let _ = calars::serve::run_load(&addr, &warm)?;
        let base = LoadOptions { concurrency: 1, ..load.clone() };
        Some(calars::serve::run_load(&addr, &base)?)
    } else {
        None
    };
    let report = calars::serve::run_load(&addr, &load)?;
    if json {
        let speedup = baseline
            .map(|b| b.wall_secs / report.wall_secs.max(1e-12))
            .unwrap_or(1.0);
        // Latency percentiles can be NaN when every request errored;
        // route all f64s through the null-for-non-finite formatter so
        // the record is always valid JSON.
        println!(
            "{{\"bench\":\"serve_predict\",\"threads\":{},\"wall_ms\":{},\"speedup\":{},\
             \"requests\":{},\"concurrency\":{concurrency},\"rows\":{rows},\
             \"req_per_s\":{},\"p50_ms\":{},\"p99_ms\":{},\"errors\":{}}}",
            calars::par::threads(),
            json_f64_rounded(report.wall_secs * 1e3, 3),
            json_f64_rounded(speedup, 3),
            report.requests,
            json_f64_rounded(report.request_throughput, 1),
            json_f64_rounded(report.latency.p50 * 1e3, 3),
            json_f64_rounded(report.latency.p99 * 1e3, 3),
            report.errors
        );
    } else {
        println!("{}", report.render());
    }

    if let Some(handle) = handle {
        handle.stop();
    } else if args.flag("shutdown") {
        client.shutdown()?;
        note(format!("server on {addr} asked to shut down"));
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("tiny");
    let seed = args.get_parse::<u64>("seed", 42)?;
    let t = args.get_parse::<usize>("t", 20)?;
    let b = args.get_parse::<usize>("b", 1)?;
    let p = args.get_parse::<usize>("p", 1)?;
    let tol = args.get_parse::<f64>("tol", 1e-12)?;
    let lambda_min = args.get_parse::<f64>("lambda-min", 1e-6)?;
    let mode = if args.flag("threads") { ExecMode::Threaded } else { ExecMode::Sequential };

    // Everything below goes through the one estimator call path
    // (calars::fit) — same as the serve layer, experiments, and benches.
    let algorithm = Algorithm::from_parts(args.get("algo").unwrap_or("lars"), b, p, lambda_min)?;
    let spec = FitSpec::new(algorithm).t(t).tol(tol).ranks(p).mode(mode);

    let ds = datasets::by_name(name, seed)
        .ok_or_else(|| calars::anyhow!("unknown dataset '{name}'"))?;
    println!(
        "dataset {} — m={} n={} nnz/mn={:.4}",
        ds.name,
        ds.a.nrows(),
        ds.a.ncols(),
        ds.stats().density
    );

    let result = if args.flag("progress") {
        let mut progress = ProgressObserver::new();
        spec.fit(&ds.a, &ds.b, &mut progress)?
    } else {
        spec.run(&ds.a, &ds.b)?
    };
    let out = &result.output;

    println!(
        "selected {} columns, stop={:?}, final residual {:.6}",
        out.selected.len(),
        out.stop,
        out.residual_norms.last().unwrap()
    );
    println!("first 10 selections: {:?}", &out.selected[..out.selected.len().min(10)]);
    println!("wallclock {}", fmt_secs(result.wall_secs));
    if let Some(path) = &result.lasso {
        println!(
            "lasso path: {} breakpoints, {} drop events, λ ∈ [{:.6}, {:.6}]",
            path.breakpoints.len(),
            path.drops,
            path.breakpoints.last().map_or(0.0, |bp| bp.lambda),
            path.breakpoints.first().map_or(0.0, |bp| bp.lambda)
        );
    }
    if let Some(sim) = &result.sim {
        let c = sim.counters;
        println!(
            "simulated time {} | F={} W={} L={}",
            fmt_secs(sim.sim_time),
            fmt_count(c.flops),
            fmt_count(c.words),
            fmt_count(c.msgs)
        );
        let cats = sim.categories;
        println!(
            "breakdown: matprod {} | gamma {} | comm {} | wait {} | other {}",
            fmt_secs(cats[0]),
            fmt_secs(cats[1]),
            fmt_secs(cats[2]),
            fmt_secs(cats[3]),
            fmt_secs(cats[4])
        );
    }
    Ok(())
}

/// `calars batch` — fit one design matrix against a whole response
/// panel through [`calars::batch`] and print the shared-work ledger.
fn cmd_batch(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("tiny");
    let seed = args.get_parse::<u64>("seed", 42)?;
    let k = args.get_parse::<usize>("k", 16)?;
    if k == 0 {
        bail!("usage: calars batch needs a positive --k (got 0)");
    }
    let t = args.get_parse::<usize>("t", 20)?;
    let b = args.get_parse::<usize>("b", 1)?;
    let p = args.get_parse::<usize>("p", 1)?;
    let tol = args.get_parse::<f64>("tol", 1e-12)?;
    let lambda_min = args.get_parse::<f64>("lambda-min", 1e-6)?;
    let algorithm = Algorithm::from_parts(args.get("algo").unwrap_or("lars"), b, p, lambda_min)?;
    let spec = FitSpec::new(algorithm).t(t).tol(tol).ranks(p);

    let ds = datasets::by_name(name, seed)
        .ok_or_else(|| calars::anyhow!("unknown dataset '{name}'"))?;
    let m = ds.a.nrows();
    println!(
        "dataset {} — m={} n={}, panel of {k} responses ({})",
        ds.name,
        m,
        ds.a.ncols(),
        spec.encode()
    );

    // Response 0 is the dataset's own b (so a batch of one reproduces
    // `calars run` bit-for-bit); the rest are seeded synthetic draws.
    let mut rng = calars::rng::Pcg64::new(seed ^ 0x9e37_79b9_7f4a_7c15);
    let responses: Vec<Vec<f64>> = (0..k)
        .map(|i| {
            if i == 0 {
                ds.b.clone()
            } else {
                (0..m).map(|_| rng.normal()).collect()
            }
        })
        .collect();

    let result = spec.fit_batch(&ds.a, &responses)?;
    let shown = result.fits.len().min(8);
    for (i, fit) in result.fits.iter().take(shown).enumerate() {
        println!(
            "  model {i:>4}: {} columns, stop={:?}, final residual {:.6}",
            fit.output.selected.len(),
            fit.output.stop,
            fit.output.residual_norms.last().unwrap()
        );
    }
    if result.fits.len() > shown {
        println!("  … {} more models", result.fits.len() - shown);
    }
    let sw = result.shared;
    println!(
        "shared work: {} batched passes replaced {} sequential-equivalent \
         ({} saved); gram panels {} hit / {} miss",
        sw.batched_passes,
        sw.sequential_passes,
        sw.passes_saved(),
        sw.gram_panel_hits,
        sw.gram_panel_misses
    );
    println!("wallclock {}", fmt_secs(result.wall_secs));
    Ok(())
}

/// `calars trace` — run one fit with tracing force-enabled and print
/// its span tree plus per-phase time/flops totals.
fn cmd_trace(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("tiny");
    let seed = args.get_parse::<u64>("seed", 42)?;
    let t = args.get_parse::<usize>("t", 20)?;
    let b = args.get_parse::<usize>("b", 1)?;
    let p = args.get_parse::<usize>("p", 1)?;
    let tol = args.get_parse::<f64>("tol", 1e-12)?;
    let lambda_min = args.get_parse::<f64>("lambda-min", 1e-6)?;
    let mode = if args.flag("threads") { ExecMode::Threaded } else { ExecMode::Sequential };

    let algorithm = Algorithm::from_parts(args.get("algo").unwrap_or("lars"), b, p, lambda_min)?;
    let spec = FitSpec::new(algorithm).t(t).tol(tol).ranks(p).mode(mode);
    let ds = datasets::by_name(name, seed)
        .ok_or_else(|| calars::anyhow!("unknown dataset '{name}'"))?;

    // The subcommand exists to look at spans — force tracing on even
    // under CALARS_TRACE=off.
    calars::obs::set_enabled(true);
    let mut tracer = TraceObserver::new();
    let trace = tracer.trace_id();
    let result = spec.fit(&ds.a, &ds.b, &mut tracer)?;
    // Spans that closed after the observer detached (the root "fit"
    // span itself) are still in this thread's buffer.
    calars::obs::flush_thread();
    let spans = calars::obs::sink()
        .get(trace)
        .ok_or_else(|| calars::anyhow!("no spans recorded for this fit"))?;

    println!(
        "trace {} — {} on {} (m={} n={}): {} spans, {} selected, stop={:?}, wall {}",
        calars::obs::format_trace_id(trace),
        spec.encode(),
        ds.name,
        ds.a.nrows(),
        ds.a.ncols(),
        spans.len(),
        result.output.selected.len(),
        result.output.stop,
        fmt_secs(result.wall_secs),
    );
    println!();
    print!("{}", calars::obs::span_tree(&spans));
    println!();
    print!("{}", calars::obs::PhaseTotals::from_spans(&spans).render_table("measured"));
    if let Some(sim) = &result.sim {
        // The cluster fitters also carry the α-β-γ simulated per-phase
        // trace; print it next to the measured one for comparison.
        println!();
        print!("{}", calars::obs::PhaseTotals::from_tracer(&sim.tracer).render_table("simulated"));
    }
    Ok(())
}

fn cmd_select(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("tiny");
    let seed = args.get_parse::<u64>("seed", 42)?;
    let t = args.get_parse::<usize>("t", 20)?;
    let b = args.get_parse::<usize>("b", 1)?;
    let p = args.get_parse::<usize>("p", 1)?;
    let tol = args.get_parse::<f64>("tol", 1e-12)?;
    let lambda_min = args.get_parse::<f64>("lambda-min", 1e-6)?;
    let k = args.get_parse::<usize>("k", 5)?;
    let cv_seed = args.get_parse::<u64>("cv-seed", 0)?;
    let criterion = Criterion::from_name(args.get("criterion").unwrap_or("cv"))?;
    let mode = if args.flag("threads") { ExecMode::Threaded } else { ExecMode::Sequential };

    let algorithm = Algorithm::from_parts(args.get("algo").unwrap_or("lars"), b, p, lambda_min)?;
    let fit_spec = FitSpec::new(algorithm).t(t).tol(tol).ranks(p).mode(mode);
    let sel_spec = SelectSpec::new(criterion).k(k).seed(cv_seed);

    let ds = datasets::by_name(name, seed)
        .ok_or_else(|| calars::anyhow!("unknown dataset '{name}'"))?;
    println!("dataset {} — m={} n={}", ds.name, ds.a.nrows(), ds.a.ncols());
    let t0 = std::time::Instant::now();
    let (result, snap, selection) =
        calars::select::select_model(&ds.a, &ds.b, &fit_spec, &sel_spec)?;
    println!(
        "fitted {} path steps ({}; stop={:?}) in {}",
        snap.len(),
        fit_spec.encode(),
        result.output.stop,
        fmt_secs(result.wall_secs),
    );
    let how = match criterion {
        Criterion::Cv => format!("held-out MSE, k={k}, fold seed {cv_seed}"),
        _ => format!("df = active-set size, m = {}", ds.a.nrows()),
    };
    println!("criterion {} ({how}):", criterion.name());
    println!("{:>6} {:>6} {:>18}", "step", "df", "score");
    for s in &selection.scores {
        let mark = if s.step == selection.best_step { "  <- best" } else { "" };
        println!("{:>6} {:>6} {:>18.8e}{mark}", s.step, s.df, s.score);
    }
    let chosen = &snap.steps[selection.best_step];
    println!(
        "serve step {}: {} active columns, ‖r‖={:.6e}, λ={:.6e}  (total {})",
        selection.best_step,
        chosen.support.len(),
        chosen.residual_norm,
        chosen.lambda,
        fmt_secs(t0.elapsed().as_secs_f64()),
    );
    Ok(())
}

fn sweep_from(args: &Args) -> Result<SweepConfig> {
    let quick = args.flag("quick");
    let mut sweep = if quick { SweepConfig::quick() } else { SweepConfig::default() };
    sweep.t = args.get_parse::<usize>("t", sweep.t)?;
    sweep.seed = args.get_parse::<u64>("seed", sweep.seed)?;
    Ok(sweep)
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| calars::anyhow!("usage: calars exp <id> [--quick]"))?;
    let sweep = sweep_from(args)?;
    let quick = args.flag("quick");
    if id == "all" {
        return cmd_suite(args);
    }
    let report = experiments::run_by_id(id, &sweep, quick)?;
    println!("{report}");
    Ok(())
}

fn cmd_suite(args: &Args) -> Result<()> {
    let sweep = sweep_from(args)?;
    let quick = args.flag("quick");
    for id in experiments::ALL_IDS {
        let t0 = std::time::Instant::now();
        let report = experiments::run_by_id(id, &sweep, quick)?;
        println!("{report}");
        eprintln!("[{id} done in {}]", fmt_secs(t0.elapsed().as_secs_f64()));
        println!();
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cores = calars::par::detected_cores();
    let threads = calars::par::threads();
    let min_chunk = calars::par::min_chunk();
    let isa = calars::kern::simd::current().name();
    let features: Vec<&str> = if cfg!(feature = "pjrt") { vec!["pjrt"] } else { Vec::new() };
    if args.flag("json") {
        // Machine-readable shape report: the CI perf stage uses this to
        // annotate the BENCH_*.json records with the runner's geometry.
        let feats =
            features.iter().map(|f| format!("\"{f}\"")).collect::<Vec<_>>().join(",");
        println!(
            "{{\"version\":\"{}\",\"cores\":{cores},\"threads\":{threads},\
             \"min_chunk\":{min_chunk},\"isa\":\"{isa}\",\"features\":[{feats}]}}",
            calars::VERSION
        );
        return Ok(());
    }
    println!("calars {} — dataset registry:", calars::VERSION);
    for ds in datasets::paper_suite(42) {
        let s = ds.stats();
        println!(
            "  {:<20} m={:<7} n={:<7} nnz={:<9} density={:.4}",
            s.name,
            s.m,
            s.n,
            fmt_count(s.nnz as u64),
            s.density
        );
    }
    println!(
        "parallel execution: {cores} cores detected, {threads} pool threads, \
         min_chunk {min_chunk} (CALARS_THREADS / --par-threads to change)"
    );
    println!(
        "kernel backend: {isa} (available: {}; CALARS_ISA / --isa to change)",
        calars::kern::simd::KernBackend::available()
            .iter()
            .map(|b| b.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
    println!(
        "features: {}",
        if features.is_empty() { "none".to_string() } else { features.join(", ") }
    );
    let dir = calars::runtime::default_artifacts_dir();
    match XlaRuntime::load(&dir) {
        Ok(rt) => {
            println!(
                "XLA runtime: platform={}, {} artifacts in {}",
                rt.platform(),
                rt.manifest().len(),
                dir.display()
            );
            for k in rt.manifest().keys() {
                println!("  {} {}x{}", k.op.name(), k.m, k.n);
            }
        }
        Err(e) => println!("XLA runtime unavailable ({e}); native kernels only"),
    }
    Ok(())
}
